//! K-medoid clustering over activation-feature cosine distance — the
//! offline construction step of the two-layer data structure (§4.3.1,
//! §5.2: cosine is the one distance metric that converged; the paper sets
//! K = 50 for C ≤ 3000 candidates).

use crate::util::rng::Rng;

/// Cosine distance in [0, 2]: 1 − cos(a, b). Zero vectors are treated as
/// maximally distant from everything (distance 1).
pub fn cosine_distance(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut dot = 0f32;
    let mut na = 0f32;
    let mut nb = 0f32;
    for i in 0..a.len() {
        dot += a[i] * b[i];
        na += a[i] * a[i];
        nb += b[i] * b[i];
    }
    if na <= 0.0 || nb <= 0.0 {
        return 1.0;
    }
    1.0 - dot / (na.sqrt() * nb.sqrt())
}

/// K-medoids (PAM-style alternate): k-means++-like seeding, then repeat
/// { assign to nearest medoid; re-pick each cluster's medoid as the member
/// minimizing total intra-cluster distance } until stable.
///
/// Returns `(medoids, assignment)` where `medoids[c]` is an index into
/// `features` and `assignment[i]` is the cluster of point i.
pub fn kmedoids(
    features: &[Vec<f32>],
    k: usize,
    max_iters: usize,
    rng: &mut Rng,
) -> (Vec<usize>, Vec<usize>) {
    let n = features.len();
    assert!(n > 0, "kmedoids on empty set");
    let k = k.min(n).max(1);

    // ---- seeding: first medoid random, rest d²-weighted (k-means++) ----
    let mut medoids: Vec<usize> = Vec::with_capacity(k);
    medoids.push(rng.below(n));
    let mut dist_to_nearest: Vec<f64> = features
        .iter()
        .map(|f| cosine_distance(f, &features[medoids[0]]) as f64)
        .collect();
    while medoids.len() < k {
        let weights: Vec<f64> =
            dist_to_nearest.iter().map(|d| (d * d).max(1e-12)).collect();
        let next = rng.categorical(&weights);
        medoids.push(next);
        for (i, f) in features.iter().enumerate() {
            let d = cosine_distance(f, &features[next]) as f64;
            if d < dist_to_nearest[i] {
                dist_to_nearest[i] = d;
            }
        }
    }

    let mut assignment = vec![0usize; n];
    for _ in 0..max_iters {
        // assign
        let mut changed = false;
        for (i, f) in features.iter().enumerate() {
            let mut best = 0usize;
            let mut best_d = f32::INFINITY;
            for (c, &m) in medoids.iter().enumerate() {
                let d = cosine_distance(f, &features[m]);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        // update medoids
        let mut members: Vec<Vec<usize>> = vec![vec![]; medoids.len()];
        for (i, &c) in assignment.iter().enumerate() {
            members[c].push(i);
        }
        let mut medoid_moved = false;
        for (c, mem) in members.iter().enumerate() {
            if mem.is_empty() {
                continue;
            }
            let mut best = medoids[c];
            let mut best_total = f64::INFINITY;
            for &cand in mem {
                let total: f64 = mem
                    .iter()
                    .map(|&o| cosine_distance(&features[cand], &features[o]) as f64)
                    .sum();
                if total < best_total {
                    best_total = total;
                    best = cand;
                }
            }
            if medoids[c] != best {
                medoids[c] = best;
                medoid_moved = true;
            }
        }
        if !changed && !medoid_moved {
            break;
        }
    }
    // final assignment against the settled medoids
    for (i, f) in features.iter().enumerate() {
        let mut best = 0usize;
        let mut best_d = f32::INFINITY;
        for (c, &m) in medoids.iter().enumerate() {
            let d = cosine_distance(f, &features[m]);
            if d < best_d {
                best_d = d;
                best = c;
            }
        }
        assignment[i] = best;
    }
    (medoids, assignment)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    fn blob(rng: &mut Rng, center: &[f32], n: usize, noise: f32) -> Vec<Vec<f32>> {
        (0..n)
            .map(|_| {
                center
                    .iter()
                    .map(|&c| c + noise * rng.normal() as f32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn cosine_distance_basics() {
        let a = [1.0f32, 0.0];
        let b = [0.0f32, 1.0];
        let c = [2.0f32, 0.0];
        assert!((cosine_distance(&a, &b) - 1.0).abs() < 1e-6);
        assert!(cosine_distance(&a, &c).abs() < 1e-6); // scale-invariant
        assert!((cosine_distance(&a, &[-1.0, 0.0]) - 2.0).abs() < 1e-6);
        assert_eq!(cosine_distance(&[0.0, 0.0], &a), 1.0); // zero vector
    }

    #[test]
    fn recovers_well_separated_clusters() {
        let mut rng = Rng::new(1);
        let mut feats = blob(&mut rng, &[10.0, 0.0, 0.0], 30, 0.1);
        feats.extend(blob(&mut rng, &[0.0, 10.0, 0.0], 30, 0.1));
        feats.extend(blob(&mut rng, &[0.0, 0.0, 10.0], 30, 0.1));
        let (medoids, assign) = kmedoids(&feats, 3, 20, &mut rng);
        assert_eq!(medoids.len(), 3);
        // All members of each ground-truth blob share one cluster label.
        for blob_idx in 0..3 {
            let labels: std::collections::BTreeSet<usize> =
                (blob_idx * 30..(blob_idx + 1) * 30).map(|i| assign[i]).collect();
            assert_eq!(labels.len(), 1, "blob {blob_idx} split: {labels:?}");
        }
    }

    #[test]
    fn k_clamped_to_n() {
        let mut rng = Rng::new(2);
        let feats = vec![vec![1.0f32, 0.0], vec![0.0, 1.0]];
        let (medoids, assign) = kmedoids(&feats, 10, 5, &mut rng);
        assert!(medoids.len() <= 2);
        assert_eq!(assign.len(), 2);
    }

    #[test]
    fn single_cluster_covers_all() {
        let mut rng = Rng::new(3);
        let feats = blob(&mut rng, &[1.0, 2.0], 20, 0.5);
        let (medoids, assign) = kmedoids(&feats, 1, 5, &mut rng);
        assert_eq!(medoids.len(), 1);
        assert!(assign.iter().all(|&c| c == 0));
    }

    #[test]
    fn prop_assignment_is_nearest_medoid() {
        check("each point assigned to its nearest medoid", 25, |rng| {
            let n = 5 + rng.below(40);
            let dim = 2 + rng.below(6);
            let feats: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
                .collect();
            let k = 1 + rng.below(5.min(n));
            let (medoids, assign) = kmedoids(&feats, k, 10, rng);
            for (i, f) in feats.iter().enumerate() {
                let mine = cosine_distance(f, &feats[medoids[assign[i]]]);
                for &m in &medoids {
                    let d = cosine_distance(f, &feats[m]);
                    ensure(
                        mine <= d + 1e-5,
                        format!("point {i}: assigned {} but {} closer", mine, d),
                    )?;
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_medoids_are_members() {
        check("medoid indices valid and in own cluster", 25, |rng| {
            let n = 3 + rng.below(30);
            let feats: Vec<Vec<f32>> = (0..n)
                .map(|_| vec![rng.normal() as f32, rng.normal() as f32])
                .collect();
            let k = 1 + rng.below(4);
            let (medoids, assign) = kmedoids(&feats, k, 10, rng);
            for (c, &m) in medoids.iter().enumerate() {
                ensure(m < n, "medoid out of range")?;
                ensure(assign[m] == c,
                       format!("medoid {m} not in own cluster {c}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::new(7);
        let mut r2 = Rng::new(7);
        let feats: Vec<Vec<f32>> = (0..50)
            .map(|i| vec![(i % 5) as f32, (i / 5) as f32 + 0.1])
            .collect();
        let a = kmedoids(&feats, 5, 10, &mut r1);
        let b = kmedoids(&feats, 5, 10, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn prop_deterministic_over_random_inputs() {
        // Bank construction must be replayable from a seed for *any*
        // feature set, not just the grid fixture above: identical seeds
        // give identical medoids AND assignments, and the clone-side run
        // consumes the same number of RNG draws (streams stay aligned).
        check("kmedoids bit-deterministic per seed", 20, |rng| {
            let n = 10 + rng.below(60);
            let dim = 3 + rng.below(8);
            let feats: Vec<Vec<f32>> = (0..n)
                .map(|_| (0..dim).map(|_| rng.normal() as f32).collect())
            .collect();
            let k = 1 + rng.below(6);
            let seed = rng.next_u64();
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let (m1, a1) = kmedoids(&feats, k, 15, &mut r1);
            let (m2, a2) = kmedoids(&feats, k, 15, &mut r2);
            ensure(m1 == m2, format!("medoids diverged: {m1:?} vs {m2:?}"))?;
            ensure(a1 == a2, "assignments diverged")?;
            ensure(r1.next_u64() == r2.next_u64(), "RNG streams desynced")?;
            Ok(())
        });
    }
}
