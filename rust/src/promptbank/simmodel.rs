//! Simulation-facing Prompt Bank: real two-layer state per LLM.
//!
//! The scheduler experiments (Figs 7/8/14, Tables 7/8) run on the
//! discrete-event simulator, where a real PJRT lookup per simulated job
//! would conflate simulated and wall-clock time. Earlier revisions used a
//! memoryless statistical stand-in (`BankModel`, a fixed Beta draw);
//! [`SimBank`] replaces it with *actual bank state*: synthetic per-task
//! feature vectors ([`task_feature`]), a maintained two-layer structure
//! (cluster representatives + members, as in Fig 5), insertion of newly
//! tuned prompts at job completion and redundancy-driven replacement —
//! so a cold bank warms up over a run, lookup quality is a deterministic
//! function of cluster coverage of the querying job's task, and both
//! quality and lookup latency respond to bank-size changes dynamically
//! (Fig 8d).
//!
//! Latency keeps the calibrated two-layer scaling law (evals × per-eval
//! cost; paper §6.3: 5.3/6.1/9.2 s for the three LLMs at K = 50,
//! C = 3000). Everything is bit-deterministic in the construction seed:
//! no RNG is consumed at lookup or insertion time beyond counters hashed
//! into jitter, so dense and coalesced simulator runs stay identical.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::promptbank::bankapi::{task_feature, Bank};
use crate::promptbank::kmedoid::cosine_distance;
use crate::util::rng::Rng;
use crate::workload::Llm;

/// Feature dimensionality of the synthetic task space.
pub const BANK_DIMS: usize = 8;

/// Cosine-distance radius inside which a candidate transfers to a query
/// task (beyond it the candidate contributes nothing): same-task
/// candidates sit at jitter distance (≈ full transfer); distinct random
/// task directions sit near distance 1 (no transfer).
const COVER_RADIUS: f32 = 0.35;

/// A candidate further than this from every representative seeds a new
/// cluster while fewer than K exist.
const NEW_CLUSTER_DIST: f32 = 0.30;

/// Per-dimension feature jitter of a stored candidate around its task's
/// direction (keeps same-task candidates distinct but tightly clustered).
const JITTER: f32 = 0.02;

/// Configuration of the simulator-facing bank (one bank per LLM is built
/// from this by [`SimBankSet::new`]).
#[derive(Clone, Debug)]
pub struct SimBankConfig {
    /// Candidates seeded at construction (0 = cold start). The paper's
    /// warm bank holds thousands of public prompts.
    pub initial_size: usize,
    /// Replacement ceiling C (paper default 3000).
    pub max_size: usize,
    /// Cluster count K (paper default 50).
    pub k: usize,
    /// Task universe the *seeded corpus* draws from. Wider than any one
    /// trace's task set: most public prompts are irrelevant to a given
    /// job, so shrinking the bank visibly loses per-task coverage
    /// (Fig 8d) instead of staying saturated.
    pub corpus_tasks: usize,
    /// Seconds per Eqn.-1 score evaluation, per LLM (calibrated from the
    /// real runtime; defaults reproduce the paper's 5.3–9.2 s at K = 50,
    /// C = 3000).
    pub eval_cost_s: [f64; Llm::COUNT],
    /// Build [`InductionBank`]s instead (the induction baseline [88]:
    /// the LLM writes its own initial prompt, no shared state, nothing
    /// learned) — same interface, for apples-to-apples ablations.
    pub induction: bool,
}

impl Default for SimBankConfig {
    fn default() -> Self {
        SimBankConfig {
            initial_size: 3000,
            max_size: 3000,
            k: 50,
            corpus_tasks: 256,
            // 5.3 s / (50 + 3000/50) evals ≈ 48 ms per eval for gpt2-base…
            eval_cost_s: [0.048, 0.055, 0.084, 0.30, 0.12],
            induction: false,
        }
    }
}

impl SimBankConfig {
    /// A cold-start bank (empty until completed jobs feed it).
    pub fn cold() -> Self {
        SimBankConfig { initial_size: 0, ..Default::default() }
    }
}

/// One stored candidate: the task it originated from, its quality for
/// that task, and its (jittered) synthetic activation feature.
#[derive(Clone, Debug)]
struct SimCandidate {
    task_id: usize,
    quality: f64,
    feature: Vec<f32>,
}

/// One cluster of the two-layer structure (representative + members;
/// the representative is a member of its own cluster).
#[derive(Clone, Debug)]
struct SimCluster {
    medoid: usize,
    members: Vec<usize>,
}

/// Per-task memo of [`SimBank::quality_scan`] results, valid for one
/// structural epoch of the bank.
#[derive(Clone, Debug, Default)]
struct QualityCache {
    epoch: u64,
    map: HashMap<usize, f64>,
}

/// Deterministic stateful bank for one LLM inside the simulator.
#[derive(Clone, Debug)]
pub struct SimBank {
    feat_seed: u64,
    k: usize,
    max_size: usize,
    cands: Vec<SimCandidate>,
    clusters: Vec<SimCluster>,
    /// Lifetime insertions (jitter stream position + telemetry).
    inserted: u64,
    /// Structural epoch: bumped by every insertion, eviction and
    /// ceiling change. `quality_for` memoizes per task while the epoch
    /// holds — the scheduler re-scores whole queues per round against
    /// banks that usually did not change.
    epoch: u64,
    /// Interior-mutable so the `&self` lookup path can memoize; lookup
    /// results stay a pure function of bank state (bit-identity with
    /// the uncached scan is property-enforced below).
    cache: RefCell<QualityCache>,
}

impl SimBank {
    /// Build the bank for `llm`, seeding `cfg.initial_size` corpus
    /// candidates (0 = cold). Bit-deterministic in `seed`.
    pub fn new(cfg: &SimBankConfig, llm: Llm, seed: u64) -> SimBank {
        let mut bank = SimBank {
            // Task features are a property of the task space, shared by
            // every per-LLM bank of the run.
            feat_seed: seed ^ 0x7A5C_FEA7_0000_0001,
            k: cfg.k.max(1),
            max_size: cfg.max_size.max(1),
            cands: vec![],
            clusters: vec![],
            inserted: 0,
            epoch: 1,
            cache: RefCell::new(QualityCache::default()),
        };
        let mut rng = Rng::new(
            seed ^ 0x5EED_BA4C_0000_0000
                ^ (llm.index() as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        let n = cfg.initial_size.min(cfg.max_size);
        for _ in 0..n {
            // Public corpus prompts: random tasks from the wide universe,
            // decent but not tuned quality.
            let task = rng.below(cfg.corpus_tasks.max(1));
            let quality = rng.range_f64(0.55, 0.90);
            bank.insert_candidate(task, quality);
        }
        bank
    }

    /// The synthetic activation feature of a task (any id is valid).
    fn feature_of(&self, task_id: usize) -> Vec<f32> {
        task_feature(self.feat_seed, task_id, BANK_DIMS)
    }

    /// Lifetime insertions (seeded + fed back).
    pub fn inserted(&self) -> u64 {
        self.inserted
    }

    /// Quality × coverage contribution of candidate `idx` at cosine
    /// distance `d` from the query task's feature.
    fn contrib(&self, idx: usize, d: f32) -> f64 {
        let coverage = (1.0 - d / COVER_RADIUS).clamp(0.0, 1.0) as f64;
        self.cands[idx].quality * coverage
    }

    /// Nearest representative to `feature`: (cluster index, distance).
    fn nearest_cluster(&self, feature: &[f32]) -> Option<(usize, f32)> {
        if self.clusters.is_empty() {
            return None;
        }
        let mut best_c = 0usize;
        let mut best_d = f32::INFINITY;
        for (c, cl) in self.clusters.iter().enumerate() {
            let d = cosine_distance(&self.cands[cl.medoid].feature, feature);
            if d < best_d {
                best_d = d;
                best_c = c;
            }
        }
        Some((best_c, best_d))
    }

    /// Insert one candidate: attach to the nearest representative's
    /// cluster (or seed a new cluster while fewer than K exist and the
    /// candidate is far from all of them), then evict the most redundant
    /// member if the ceiling is exceeded. Deterministic — the only
    /// "randomness" is jitter hashed from the insertion counter.
    fn insert_candidate(&mut self, task_id: usize, quality: f64) {
        self.epoch += 1;
        let mut feature = self.feature_of(task_id);
        let mut jr = Rng::new(
            self.feat_seed
                ^ 0xA11C_E000_0000_0000
                ^ self.inserted.wrapping_mul(0x9E37_79B9_7F4A_7C15),
        );
        for x in feature.iter_mut() {
            *x += JITTER * jr.normal() as f32;
        }
        self.inserted += 1;
        let idx = self.cands.len();
        let nearest = self.nearest_cluster(&feature);
        self.cands.push(SimCandidate {
            task_id,
            quality: quality.clamp(0.0, 1.0),
            feature,
        });
        match nearest {
            Some((c, d))
                if self.clusters.len() >= self.k || d <= NEW_CLUSTER_DIST =>
            {
                self.clusters[c].members.push(idx);
            }
            _ => {
                // New representative; re-home members so every candidate
                // stays assigned to its nearest representative.
                self.clusters
                    .push(SimCluster { medoid: idx, members: vec![idx] });
                self.reassign_members();
            }
        }
        if self.cands.len() > self.max_size {
            self.evict_redundant(idx);
        }
    }

    /// Reassign every non-representative member to its nearest
    /// representative (called when a new cluster is seeded).
    fn reassign_members(&mut self) {
        let medoids: Vec<usize> =
            self.clusters.iter().map(|c| c.medoid).collect();
        for cl in self.clusters.iter_mut() {
            cl.members.clear();
            cl.members.push(cl.medoid);
        }
        for i in 0..self.cands.len() {
            if medoids.contains(&i) {
                continue;
            }
            let mut best_c = 0usize;
            let mut best_d = f32::INFINITY;
            for (c, &m) in medoids.iter().enumerate() {
                let d = cosine_distance(&self.cands[i].feature,
                                        &self.cands[m].feature);
                if d < best_d {
                    best_d = d;
                    best_c = c;
                }
            }
            self.clusters[best_c].members.push(i);
        }
    }

    /// Evict the most redundant candidate: the non-representative member
    /// closest to its own representative (maximizing remaining
    /// diversity), preferring any victim other than `keep`. When only
    /// representatives remain (every cluster a singleton — possible when
    /// the ceiling sits below the cluster count), the most redundant
    /// *representative* (nearest to another one) is dissolved with its
    /// cluster, so the `len ≤ max_size` invariant always makes progress.
    fn evict_redundant(&mut self, keep: usize) {
        let mut best: Option<usize> = None;
        let mut best_d = f32::INFINITY;
        let mut keep_only: Option<usize> = None;
        for cl in &self.clusters {
            for &m in &cl.members {
                if m == cl.medoid {
                    continue;
                }
                let d = cosine_distance(&self.cands[m].feature,
                                        &self.cands[cl.medoid].feature);
                if m == keep {
                    keep_only = Some(m);
                    continue;
                }
                if d < best_d {
                    best_d = d;
                    best = Some(m);
                }
            }
        }
        if let Some(v) = best.or(keep_only) {
            self.remove_candidate(v);
            return;
        }
        // Only lone representatives left: dissolve the one nearest to
        // another representative (its cluster has no members to re-home).
        if self.clusters.len() < 2 {
            return;
        }
        let mut victim_c = 0usize;
        let mut victim_d = f32::INFINITY;
        for (a, ca) in self.clusters.iter().enumerate() {
            for cb in &self.clusters {
                if ca.medoid == cb.medoid {
                    continue;
                }
                let d = cosine_distance(&self.cands[ca.medoid].feature,
                                        &self.cands[cb.medoid].feature);
                if d < victim_d {
                    victim_d = d;
                    victim_c = a;
                }
            }
        }
        let m = self.clusters[victim_c].medoid;
        self.clusters.remove(victim_c);
        self.remove_candidate(m);
    }

    /// Remove a candidate by index (swap-remove with index fix-ups,
    /// mirroring `TwoLayerBank::remove_candidate`).
    fn remove_candidate(&mut self, idx: usize) {
        self.epoch += 1;
        let last = self.cands.len() - 1;
        self.cands.swap_remove(idx);
        for cl in self.clusters.iter_mut() {
            cl.members.retain(|&m| m != idx);
            for m in cl.members.iter_mut() {
                if *m == last {
                    *m = idx;
                }
            }
            if cl.medoid == last {
                cl.medoid = idx;
            }
        }
    }

    /// Total members across clusters (== len(); structural invariant).
    pub fn member_count(&self) -> usize {
        self.clusters.iter().map(|c| c.members.len()).sum()
    }

    /// (representative, members) view for invariant checks.
    pub fn clusters_view(&self) -> Vec<(usize, &[usize])> {
        self.clusters
            .iter()
            .map(|c| (c.medoid, c.members.as_slice()))
            .collect()
    }

    /// Cosine distance between candidate `i`'s feature and candidate
    /// `j`'s feature (test/invariant helper).
    pub fn candidate_distance(&self, i: usize, j: usize) -> f32 {
        cosine_distance(&self.cands[i].feature, &self.cands[j].feature)
    }

    /// The uncached two-layer lookup scan (Fig 5a), deterministically
    /// from state: score the K representatives against the task's
    /// feature, descend into the nearest cluster, take the best
    /// quality × coverage over everything evaluated. An empty bank
    /// covers nothing (0.0 — callers floor at the user's own prompt
    /// quality). `Bank::quality_for` memoizes this per task behind the
    /// structural epoch; the memo is bit-identical to this scan
    /// (property-enforced by the module tests).
    pub fn quality_scan(&self, task_id: usize) -> f64 {
        if self.clusters.is_empty() {
            return 0.0;
        }
        let f = self.feature_of(task_id);
        let mut best_c = 0usize;
        let mut best_d = f32::INFINITY;
        let mut q = 0.0f64;
        for (c, cl) in self.clusters.iter().enumerate() {
            let d = cosine_distance(&self.cands[cl.medoid].feature, &f);
            q = q.max(self.contrib(cl.medoid, d));
            if d < best_d {
                best_d = d;
                best_c = c;
            }
        }
        for &m in &self.clusters[best_c].members {
            if m == self.clusters[best_c].medoid {
                continue;
            }
            let d = cosine_distance(&self.cands[m].feature, &f);
            q = q.max(self.contrib(m, d));
        }
        q
    }
}

impl Bank for SimBank {
    fn len(&self) -> usize {
        self.cands.len()
    }

    fn max_size(&self) -> usize {
        self.max_size
    }

    fn set_max_size(&mut self, max_size: usize) {
        self.epoch += 1;
        self.max_size = max_size.max(1);
        while self.cands.len() > self.max_size {
            let before = self.cands.len();
            self.evict_redundant(usize::MAX);
            if self.cands.len() == before {
                break; // single lone representative: nothing evictable
            }
        }
    }

    fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    fn lookup_evals(&self) -> usize {
        if self.cands.is_empty() {
            return 0;
        }
        let k = self.clusters.len().max(1);
        k + self.cands.len() / k
    }

    /// Two-layer lookup quality: [`SimBank::quality_scan`] memoized per
    /// task while the bank's structural epoch holds. The scheduler
    /// refreshes estimates for whole queues every round; between
    /// insertions the bank is immutable, so the O(K + C/K) scan runs
    /// once per (epoch, task) and repeats are an O(1) hash hit with the
    /// exact same bits.
    fn quality_for(&self, task_id: usize) -> f64 {
        let mut cache = self.cache.borrow_mut();
        if cache.epoch != self.epoch {
            cache.map.clear();
            cache.epoch = self.epoch;
        }
        if let Some(&q) = cache.map.get(&task_id) {
            return q;
        }
        let q = self.quality_scan(task_id);
        cache.map.insert(task_id, q);
        q
    }

    fn insert_tuned(&mut self, task_id: usize, quality: f64) {
        self.insert_candidate(task_id, quality);
    }
}

// ------------------------------------------------------ induction baseline

/// The induction baseline [88] behind the same [`Bank`] interface: the
/// base LLM writes its own initial prompt. No lookup cost, no shared
/// state, nothing learned — quality is a fixed deterministic draw per
/// (LLM, task) tracking the base model's capability (paper Fig 9b:
/// weakest for GPT2-Base, best for Vicuna-7B).
#[derive(Clone, Debug)]
pub struct InductionBank {
    llm: Llm,
    seed: u64,
}

impl InductionBank {
    pub fn new(llm: Llm, seed: u64) -> InductionBank {
        InductionBank { llm, seed }
    }
}

/// Deterministic induction-prompt quality for one (LLM, task, seed).
pub fn induction_quality(llm: Llm, task_id: usize, seed: u64) -> f64 {
    let cap = match llm {
        Llm::Gpt2B => 0.30,
        Llm::Gpt2L => 0.45,
        Llm::V7B => 0.62,
        Llm::Llama30B => 0.68,
        Llm::Qwen7BR1 => 0.66,
    };
    let mut rng = Rng::new(
        seed ^ 0x1BDC_7104_0000_0000
            ^ (task_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
            ^ ((llm.index() as u64 + 1) << 56),
    );
    (cap + 0.12 * rng.normal()).clamp(0.02, 0.95)
}

impl Bank for InductionBank {
    fn len(&self) -> usize {
        0
    }
    fn max_size(&self) -> usize {
        0
    }
    fn set_max_size(&mut self, _max_size: usize) {}
    fn n_clusters(&self) -> usize {
        0
    }
    fn lookup_evals(&self) -> usize {
        0 // the model prompts itself: no bank scan, no added latency
    }
    fn quality_for(&self, task_id: usize) -> f64 {
        induction_quality(self.llm, task_id, self.seed)
    }
    fn insert_tuned(&mut self, _task_id: usize, _quality: f64) {}
}

// ----------------------------------------------------------- per-LLM set

/// The per-LLM bank set a policy owns: one [`Bank`] per LLM behind the
/// trait, plus the calibrated per-eval cost that turns `lookup_evals`
/// into lookup latency.
pub struct SimBankSet {
    banks: [Box<dyn Bank>; Llm::COUNT],
    eval_cost_s: [f64; Llm::COUNT],
}

impl SimBankSet {
    /// Build one bank per LLM (bit-deterministic in `seed`; an
    /// `induction` config builds [`InductionBank`]s instead).
    pub fn new(cfg: &SimBankConfig, seed: u64) -> SimBankSet {
        let banks = Llm::ALL.map(|llm| -> Box<dyn Bank> {
            if cfg.induction {
                Box::new(InductionBank::new(llm, seed))
            } else {
                Box::new(SimBank::new(cfg, llm, seed))
            }
        });
        SimBankSet { banks, eval_cost_s: cfg.eval_cost_s }
    }

    pub fn bank(&self, llm: Llm) -> &dyn Bank {
        self.banks[llm.index()].as_ref()
    }

    pub fn bank_mut(&mut self, llm: Llm) -> &mut dyn Bank {
        self.banks[llm.index()].as_mut()
    }

    /// Lookup latency for one LLM right now (seconds): evals of the
    /// current two-layer structure × the calibrated per-eval cost.
    pub fn lookup_latency(&self, llm: Llm) -> f64 {
        self.bank(llm).lookup_evals() as f64 * self.eval_cost_s[llm.index()]
    }

    pub fn quality_for(&self, llm: Llm, task_id: usize) -> f64 {
        self.bank(llm).quality_for(task_id)
    }

    pub fn insert_tuned(&mut self, llm: Llm, task_id: usize, quality: f64) {
        self.bank_mut(llm).insert_tuned(task_id, quality);
    }

    /// Move every per-LLM ceiling (§4.4.3 shrink/grow under pressure).
    pub fn set_max_size_all(&mut self, max_size: usize) {
        for bank in self.banks.iter_mut() {
            bank.set_max_size(max_size);
        }
    }

    /// Total candidates across all per-LLM banks.
    pub fn total_len(&self) -> usize {
        self.banks.iter().map(|b| b.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    fn warm(size: usize, seed: u64) -> SimBank {
        let cfg = SimBankConfig {
            initial_size: size,
            max_size: size.max(1),
            ..Default::default()
        };
        SimBank::new(&cfg, Llm::Gpt2B, seed)
    }

    /// Mean delivered quality over the default trace task range.
    fn mean_quality(bank: &SimBank, tasks: usize) -> f64 {
        (0..tasks).map(|t| bank.quality_for(t)).sum::<f64>() / tasks as f64
    }

    #[test]
    fn default_latency_matches_paper_range() {
        let set = SimBankSet::new(&SimBankConfig::default(), 1);
        // paper §6.3: 5.3 s (GPT2-B), 6.1 s (GPT2-L), 9.2 s (V7B) at K=50
        let lat_b = set.lookup_latency(Llm::Gpt2B);
        let lat_l = set.lookup_latency(Llm::Gpt2L);
        let lat_v = set.lookup_latency(Llm::V7B);
        assert!((4.5..6.5).contains(&lat_b), "{lat_b}");
        assert!((5.0..7.5).contains(&lat_l), "{lat_l}");
        assert!((8.0..10.5).contains(&lat_v), "{lat_v}");
        assert!(lat_b < lat_l && lat_l < lat_v);
    }

    #[test]
    fn evals_follow_k_plus_c_over_k() {
        let bank = warm(3000, 2);
        assert_eq!(bank.n_clusters(), 50, "clusters reach K");
        assert_eq!(bank.lookup_evals(), 50 + 3000 / 50);
        // an empty bank has nothing to scan
        let cold = warm(0, 2);
        assert_eq!(cold.lookup_evals(), 0);
        assert_eq!(cold.quality_for(5), 0.0);
    }

    #[test]
    fn warm_bank_covers_trace_tasks() {
        let bank = warm(3000, 3);
        let mean = mean_quality(&bank, 64);
        assert!(mean > 0.75, "warm coverage too weak: {mean}");
    }

    #[test]
    fn smaller_bank_loses_coverage() {
        // Fig 8d: shrinking the corpus loses per-task coverage.
        let big = mean_quality(&warm(3000, 4), 64);
        let small = mean_quality(&warm(150, 4), 64);
        assert!(small < big - 0.05, "big {big} vs small {small}");
    }

    #[test]
    fn shrinking_ceiling_evicts_and_cuts_lookup_cost() {
        let mut bank = warm(3000, 5);
        let evals_before = bank.lookup_evals();
        let q_before = mean_quality(&bank, 64);
        bank.set_max_size(500);
        assert!(bank.len() <= 500, "len {}", bank.len());
        assert_eq!(bank.member_count(), bank.len());
        assert!(bank.lookup_evals() < evals_before,
                "{} !< {evals_before}", bank.lookup_evals());
        // eviction keeps the most diverse members, so quality degrades
        // gracefully (never improves)
        assert!(mean_quality(&bank, 64) <= q_before + 1e-9);
    }

    #[test]
    fn cold_bank_warms_up_through_feedback() {
        let mut bank = warm(0, 6);
        let before = bank.quality_for(7);
        assert_eq!(before, 0.0);
        bank.insert_tuned(7, 0.97);
        let after = bank.quality_for(7);
        assert!(after > 0.9, "tuned insert did not raise quality: {after}");
        // the neighbor task is a different random direction: no transfer
        assert!(bank.quality_for(8) < 0.2);
    }

    #[test]
    fn bank_beats_induction_on_covered_tasks() {
        let bank = warm(3000, 8);
        for llm in Llm::MAIN {
            let ind = InductionBank::new(llm, 8);
            let n = 64;
            let bank_mean = mean_quality(&bank, n);
            let ind_mean: f64 =
                (0..n).map(|t| ind.quality_for(t)).sum::<f64>() / n as f64;
            assert!(bank_mean > ind_mean + 0.1,
                    "{llm:?}: bank {bank_mean} vs induction {ind_mean}");
        }
    }

    #[test]
    fn induction_tracks_model_capability() {
        let mean = |llm| -> f64 {
            let b = InductionBank::new(llm, 9);
            (0..500).map(|t| b.quality_for(t)).sum::<f64>() / 500.0
        };
        assert!(mean(Llm::Gpt2B) < mean(Llm::Gpt2L));
        assert!(mean(Llm::Gpt2L) < mean(Llm::V7B));
    }

    #[test]
    fn deterministic_per_seed_and_insert_sequence() {
        let mk = || {
            let mut b = warm(300, 11);
            for t in [3usize, 70, 3, 900, 12] {
                b.insert_tuned(t, 0.97);
            }
            b
        };
        let a = mk();
        let b = mk();
        for t in 0..80 {
            assert_eq!(a.quality_for(t).to_bits(), b.quality_for(t).to_bits());
        }
        assert_eq!(a.len(), b.len());
        assert_eq!(a.lookup_evals(), b.lookup_evals());
        // a different seed actually changes the bank
        let c = warm(300, 12);
        assert!((0..80).any(|t| {
            a.quality_for(t).to_bits() != c.quality_for(t).to_bits()
        }));
    }

    #[test]
    fn qualities_stay_in_unit_interval() {
        let mut bank = warm(500, 13);
        bank.insert_tuned(1 << 20, 5.0); // clamped
        for t in 0..200 {
            let q = bank.quality_for(t);
            assert!((0.0..=1.0).contains(&q), "{q}");
        }
    }

    #[test]
    fn ceiling_holds_even_when_k_exceeds_max_size() {
        // Every insert of a mutually-distant task seeds a singleton
        // cluster; with k > max_size the only evictable candidates are
        // representatives, which must be dissolved rather than letting
        // the bank exceed its ceiling.
        let cfg = SimBankConfig {
            initial_size: 0,
            max_size: 2,
            k: 50,
            ..Default::default()
        };
        let mut bank = SimBank::new(&cfg, Llm::Gpt2B, 14);
        for t in 0..6 {
            bank.insert_tuned(t, 0.97);
            assert!(bank.len() <= 2, "len {} after task {t}", bank.len());
            assert_eq!(bank.member_count(), bank.len());
        }
        let mut shrunk = warm(3000, 14);
        shrunk.set_max_size(10);
        assert!(shrunk.len() <= 10, "len {}", shrunk.len());
        assert_eq!(shrunk.member_count(), shrunk.len());
    }

    #[test]
    fn prop_two_layer_invariants_under_insert_and_replacement() {
        check("SimBank two-layer invariants", 20, |rng| {
            let initial = rng.below(400);
            let max = 20 + rng.below(400);
            let cfg = SimBankConfig {
                initial_size: initial,
                max_size: max,
                k: 1 + rng.below(30),
                ..Default::default()
            };
            let mut bank = SimBank::new(&cfg, Llm::V7B, rng.next_u64());
            for _ in 0..rng.below(120) {
                bank.insert_tuned(rng.below(400), 0.5 + 0.5 * rng.f64());
            }
            ensure(bank.len() <= bank.max_size(), "size over ceiling")?;
            ensure(bank.member_count() == bank.len(),
                   format!("{} members vs {} candidates",
                           bank.member_count(), bank.len()))?;
            ensure(bank.n_clusters() <= cfg.k.max(1), "too many clusters")?;
            // every index appears exactly once; medoid in own cluster;
            // every member assigned to (one of) its nearest representatives
            let view = bank.clusters_view();
            let medoids: Vec<usize> = view.iter().map(|(m, _)| *m).collect();
            let mut seen = vec![0usize; bank.len()];
            for (medoid, members) in &view {
                ensure(members.contains(medoid),
                       "medoid missing from own cluster")?;
                for &m in *members {
                    ensure(m < bank.len(), "member out of range")?;
                    seen[m] += 1;
                    let mine = bank.candidate_distance(m, *medoid);
                    for &other in &medoids {
                        ensure(
                            mine <= bank.candidate_distance(m, other) + 1e-5,
                            format!("member {m} not at nearest medoid"),
                        )?;
                    }
                }
            }
            ensure(seen.iter().all(|&c| c == 1), "index seen != once")?;
            Ok(())
        });
    }

    #[test]
    fn prop_quality_monotone_in_task_coverage() {
        // Feeding a tuned prompt for task t back never lowers the bank's
        // delivered quality for t while capacity remains (the flywheel is
        // monotone in coverage) — and with full clusters it strictly
        // improves an uncovered task.
        check("SimBank quality monotone under feedback", 20, |rng| {
            let cfg = SimBankConfig {
                initial_size: 50 + rng.below(200),
                max_size: 5000,
                ..Default::default()
            };
            let mut bank = SimBank::new(&cfg, Llm::Gpt2L, rng.next_u64());
            for _ in 0..20 {
                let t = rng.below(600);
                let before = bank.quality_for(t);
                bank.insert_tuned(t, 0.97);
                let after = bank.quality_for(t);
                ensure(after + 1e-9 >= before,
                       format!("task {t}: {before} -> {after}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn mean_quality_monotone_in_seeded_size() {
        // Aggregate coverage grows with the seeded corpus.
        for seed in [21u64, 22, 23] {
            let m50 = mean_quality(&warm(50, seed), 64);
            let m500 = mean_quality(&warm(500, seed), 64);
            let m3000 = mean_quality(&warm(3000, seed), 64);
            assert!(m50 <= m500 + 0.02, "seed {seed}: {m50} vs {m500}");
            assert!(m500 <= m3000 + 0.02, "seed {seed}: {m500} vs {m3000}");
            assert!(m3000 > m50 + 0.1, "seed {seed}: no coverage growth");
        }
    }

    #[test]
    fn bank_set_routes_per_llm_and_replacement_caps_growth() {
        let cfg = SimBankConfig {
            initial_size: 60,
            max_size: 60,
            ..Default::default()
        };
        let mut set = SimBankSet::new(&cfg, 31);
        let before_v7b = set.bank(Llm::V7B).len();
        for i in 0..40 {
            set.insert_tuned(Llm::Gpt2B, i, 0.97);
        }
        // replacement holds the ceiling; the other LLM's bank is untouched
        assert_eq!(set.bank(Llm::Gpt2B).len(), 60);
        assert_eq!(set.bank(Llm::V7B).len(), before_v7b);
        assert_eq!(set.total_len(), 60 * Llm::COUNT);
    }

    #[test]
    fn prop_memoized_quality_matches_uncached_scan() {
        // Random insert / shrink / grow interleaved with double lookups:
        // the epoch-stamped memo must return the scan's exact bits, and
        // the memo itself must never change what a mutation produces.
        check("memoized quality == uncached scan", 30, |rng| {
            let mut bank = warm(40 + rng.below(60), rng.next_u64());
            for step in 0..120 {
                let t = rng.below(96);
                match rng.below(5) {
                    0 => bank.insert_tuned(t, rng.range_f64(0.3, 0.99)),
                    1 if step % 3 == 0 => {
                        bank.set_max_size(20 + rng.below(80))
                    }
                    _ => {}
                }
                let scan = bank.quality_scan(t);
                let memo1 = bank.quality_for(t);
                let memo2 = bank.quality_for(t);
                ensure(
                    memo1.to_bits() == scan.to_bits(),
                    format!("task {t}: memo {memo1} != scan {scan}"),
                )?;
                ensure(
                    memo2.to_bits() == scan.to_bits(),
                    format!("task {t}: repeat {memo2} != scan {scan}"),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn quality_cache_invalidates_on_insert_eviction_and_ceiling() {
        let mut bank = warm(200, 77);
        let t = 9usize;
        let q0 = bank.quality_for(t);
        assert_eq!(bank.quality_for(t).to_bits(), q0.to_bits());

        // Insertion (with the bank at its ceiling this also evicts):
        // the next lookup must see the new state, not the memo.
        bank.insert_tuned(t, 0.99);
        let q1 = bank.quality_for(t);
        assert_eq!(q1.to_bits(), bank.quality_scan(t).to_bits());
        assert!(q1 + 1e-9 >= q0,
                "a 0.99 same-task prompt lowered quality: {q0} -> {q1}");

        // Ceiling shrink evicts many candidates; the memo must follow.
        bank.set_max_size(25);
        let q2 = bank.quality_for(t);
        assert_eq!(q2.to_bits(), bank.quality_scan(t).to_bits());

        // Growing the ceiling alone changes no contents but still
        // re-stamps — lookups keep matching the scan bit-for-bit.
        bank.set_max_size(400);
        assert_eq!(bank.quality_for(t).to_bits(),
                   bank.quality_scan(t).to_bits());
        assert_eq!(bank.quality_for(t).to_bits(), q2.to_bits());
    }
}
