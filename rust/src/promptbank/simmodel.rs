//! Simulation-facing Prompt Bank model.
//!
//! The scheduler experiments (Figs 7/8, Tables 7/8) run on the
//! discrete-event simulator, where running a real PJRT lookup per
//! simulated job would conflate simulated and wall-clock time. This model
//! captures the bank's *measured* behaviour — lookup latency (paper §6.3:
//! 5.3/6.1/9.2 s for the three LLMs at K = 50) and the quality of the
//! selected prompt (Fig 9a: ≥90 % of ideal for most jobs) — with the
//! latency scaling law of the two-layer structure (evals × per-eval cost).

use crate::util::rng::Rng;
use crate::workload::Llm;

/// Measured-behaviour model of the Prompt Bank for the simulator.
#[derive(Clone, Debug)]
pub struct BankModel {
    /// Candidate count C.
    pub bank_size: usize,
    /// Cluster count K.
    pub k: usize,
    /// Seconds per Eqn.-1 score evaluation, per LLM (calibrated from the
    /// real runtime; defaults reproduce the paper's 5.3–9.2 s at K=50,
    /// C=3000).
    pub eval_cost_s: [f64; 5],
    /// Quality (fraction of ideal ITA performance) of the selected prompt:
    /// Beta-distributed near 1 (Fig 9a: most candidates ≥ 0.9 of ideal).
    pub quality_alpha: f64,
    pub quality_beta: f64,
}

impl Default for BankModel {
    fn default() -> Self {
        BankModel {
            bank_size: 3000,
            k: 50,
            // 5.3 s / (50 + 3000/50) evals ≈ 48 ms per eval for gpt2-base…
            eval_cost_s: [0.048, 0.055, 0.084, 0.30, 0.12],
            quality_alpha: 14.0,
            quality_beta: 1.2,
        }
    }
}

impl BankModel {
    /// Number of Eqn.-1 evaluations of a two-layer lookup: K + C/K.
    pub fn lookup_evals(&self) -> usize {
        self.k + self.bank_size / self.k.max(1)
    }

    /// Lookup latency for one LLM (seconds).
    pub fn lookup_latency(&self, llm: Llm) -> f64 {
        self.lookup_evals() as f64 * self.eval_cost_s[llm.index()]
    }

    /// Draw the prompt quality produced by a bank lookup. Shrinking the
    /// bank below ~3000 candidates loses coverage (paper Fig 8d): quality
    /// degrades with the coverage ratio.
    pub fn draw_quality(&self, rng: &mut Rng) -> f64 {
        let q = rng.beta(self.quality_alpha, self.quality_beta);
        let coverage = (self.bank_size as f64 / 3000.0).min(1.0).powf(0.35);
        (q * coverage).clamp(0.0, 1.0)
    }

    /// Quality of the *induction* baseline [88]: an LLM generating its own
    /// initial prompt — quality tracks the base model's capability
    /// (paper Fig 9b: weakest for GPT2-Base, best for Vicuna-7B).
    pub fn draw_induction_quality(&self, llm: Llm, rng: &mut Rng) -> f64 {
        let cap = match llm {
            Llm::Gpt2B => 0.30,
            Llm::Gpt2L => 0.45,
            Llm::V7B => 0.62,
            Llm::Llama30B => 0.68,
            Llm::Qwen7BR1 => 0.66,
        };
        (cap + 0.12 * rng.normal()).clamp(0.02, 0.95)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_latency_matches_paper_range() {
        let m = BankModel::default();
        // paper §6.3: 5.3 s (GPT2-B), 6.1 s (GPT2-L), 9.2 s (V7B) at K=50
        let lat_b = m.lookup_latency(Llm::Gpt2B);
        let lat_l = m.lookup_latency(Llm::Gpt2L);
        let lat_v = m.lookup_latency(Llm::V7B);
        assert!((4.5..6.5).contains(&lat_b), "{lat_b}");
        assert!((5.0..7.5).contains(&lat_l), "{lat_l}");
        assert!((8.0..10.5).contains(&lat_v), "{lat_v}");
        assert!(lat_b < lat_l && lat_l < lat_v);
    }

    #[test]
    fn evals_follow_k_plus_c_over_k() {
        let m = BankModel { bank_size: 3000, k: 50, ..Default::default() };
        assert_eq!(m.lookup_evals(), 50 + 60);
        let brute = BankModel { bank_size: 3000, k: 1, ..Default::default() };
        // K=1 degenerates to brute force (paper: hours)
        assert_eq!(brute.lookup_evals(), 1 + 3000);
        assert!(brute.lookup_latency(Llm::Gpt2B) / m.lookup_latency(Llm::Gpt2B) > 20.0);
    }

    #[test]
    fn bank_quality_beats_induction() {
        let m = BankModel::default();
        let mut rng = Rng::new(1);
        let n = 2000;
        let bank: f64 =
            (0..n).map(|_| m.draw_quality(&mut rng)).sum::<f64>() / n as f64;
        for llm in Llm::MAIN {
            let ind: f64 = (0..n)
                .map(|_| m.draw_induction_quality(llm, &mut rng))
                .sum::<f64>()
                / n as f64;
            assert!(bank > ind + 0.15, "{llm:?}: bank {bank} vs induction {ind}");
        }
    }

    #[test]
    fn induction_tracks_model_capability() {
        let m = BankModel::default();
        let mut rng = Rng::new(2);
        let n = 3000;
        let mean = |llm| {
            let mut r = Rng::new(2);
            (0..n).map(|_| m.draw_induction_quality(llm, &mut r)).sum::<f64>() / n as f64
        };
        assert!(mean(Llm::Gpt2B) < mean(Llm::Gpt2L));
        assert!(mean(Llm::Gpt2L) < mean(Llm::V7B));
        let _ = &mut rng;
    }

    #[test]
    fn smaller_bank_degrades_quality() {
        let big = BankModel::default();
        let small = BankModel { bank_size: 500, ..Default::default() };
        let mean = |m: &BankModel| {
            let mut r = Rng::new(3);
            (0..2000).map(|_| m.draw_quality(&mut r)).sum::<f64>() / 2000.0
        };
        assert!(mean(&big) > mean(&small) + 0.1);
    }

    #[test]
    fn qualities_in_unit_interval() {
        let m = BankModel::default();
        let mut rng = Rng::new(4);
        for _ in 0..1000 {
            let q = m.draw_quality(&mut rng);
            assert!((0.0..=1.0).contains(&q));
            let i = m.draw_induction_quality(Llm::V7B, &mut rng);
            assert!((0.0..=1.0).contains(&i));
        }
    }
}
