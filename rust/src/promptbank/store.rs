//! Bank persistence (the paper's offline phase, §5.2: construction takes
//! minutes and the structure is reused across all jobs of an LLM, so the
//! service stores it per model — "storage size remains under 5 GB").
//!
//! Binary layout (little-endian):
//! ```text
//! u32 magic "PTBK", u32 version, u32 max_size,
//! u32 n_prompts, u32 n_clusters, u32 tok_len, u32 feat_dim
//! per prompt:  i32 source_task (-1 = none), i32 tokens[tok_len],
//!              f32 feature[feat_dim]
//! per cluster: u32 medoid, u32 n_members, u32 members[n_members]
//! ```

use std::path::Path;

use anyhow::{bail, Result};

use crate::promptbank::bank::{PromptCandidate, TwoLayerBank};
use crate::util::binio::{read_all, LeReader, LeWriter};

const MAGIC: u32 = 0x5054_424B; // "PTBK"
const VERSION: u32 = 1;

/// Serialize a bank to disk.
pub fn save(bank: &TwoLayerBank, path: impl AsRef<Path>) -> Result<()> {
    if bank.is_empty() {
        bail!("refusing to save an empty bank");
    }
    let tok_len = bank.candidate(0).tokens.len();
    let feat_dim = bank.candidate(0).feature.len();
    let clusters = bank.clusters_view();
    let mut w = LeWriter::new();
    w.u32(MAGIC);
    w.u32(VERSION);
    w.u32(bank.max_size as u32);
    w.u32(bank.len() as u32);
    w.u32(clusters.len() as u32);
    w.u32(tok_len as u32);
    w.u32(feat_dim as u32);
    for i in 0..bank.len() {
        let c = bank.candidate(i);
        if c.tokens.len() != tok_len || c.feature.len() != feat_dim {
            bail!("candidate {i} has inconsistent dims");
        }
        w.i32_slice(&[c.source_task.map(|t| t as i32).unwrap_or(-1)]);
        w.i32_slice(&c.tokens);
        w.f32_slice(&c.feature);
    }
    for (medoid, members) in clusters {
        w.u32(medoid as u32);
        w.u32(members.len() as u32);
        for &m in members {
            w.u32(m as u32);
        }
    }
    w.write_to(path)
}

/// Load a bank saved by [`save`]; the structural invariants (partition,
/// medoid membership) are re-validated.
pub fn load(path: impl AsRef<Path>) -> Result<TwoLayerBank> {
    let bytes = read_all(path)?;
    let mut r = LeReader::new(&bytes);
    let magic = r.u32()?;
    let version = r.u32()?;
    if magic != MAGIC || version != VERSION {
        bail!("bad bank file header: magic={magic:#x} version={version}");
    }
    let max_size = r.u32()? as usize;
    let n_prompts = r.u32()? as usize;
    let n_clusters = r.u32()? as usize;
    let tok_len = r.u32()? as usize;
    let feat_dim = r.u32()? as usize;
    let mut prompts = Vec::with_capacity(n_prompts);
    for _ in 0..n_prompts {
        let source = r.i32_vec(1)?[0];
        let tokens = r.i32_vec(tok_len)?;
        let feature = r.f32_vec(feat_dim)?;
        prompts.push(PromptCandidate {
            tokens,
            feature,
            source_task: (source >= 0).then_some(source as usize),
        });
    }
    let mut clusters = Vec::with_capacity(n_clusters);
    for _ in 0..n_clusters {
        let medoid = r.u32()? as usize;
        let n_members = r.u32()? as usize;
        let mut members = Vec::with_capacity(n_members);
        for _ in 0..n_members {
            members.push(r.u32()? as usize);
        }
        clusters.push((medoid, members));
    }
    if r.remaining() != 0 {
        bail!("bank file has {} trailing bytes", r.remaining());
    }
    TwoLayerBank::from_parts(prompts, clusters, max_size)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_bank(seed: u64, n: usize) -> TwoLayerBank {
        let mut rng = Rng::new(seed);
        let cands: Vec<PromptCandidate> = (0..n)
            .map(|i| PromptCandidate {
                tokens: vec![i as i32, (i * 2) as i32, 7],
                feature: (0..6).map(|_| rng.normal() as f32).collect(),
                source_task: if i % 3 == 0 { Some(i) } else { None },
            })
            .collect();
        TwoLayerBank::build(cands, 4, 100, &mut rng).unwrap()
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("pt_bank_store");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_preserves_everything() {
        let bank = sample_bank(1, 30);
        let path = tmp("roundtrip.bank");
        save(&bank, &path).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), bank.len());
        assert_eq!(back.n_clusters(), bank.n_clusters());
        assert_eq!(back.max_size, bank.max_size);
        for i in 0..bank.len() {
            assert_eq!(back.candidate(i).tokens, bank.candidate(i).tokens);
            assert_eq!(back.candidate(i).feature, bank.candidate(i).feature);
            assert_eq!(back.candidate(i).source_task, bank.candidate(i).source_task);
        }
        assert_eq!(back.clusters_view(), bank.clusters_view());
    }

    #[test]
    fn loaded_bank_answers_lookups_identically() {
        let bank = sample_bank(2, 40);
        let path = tmp("lookup.bank");
        save(&bank, &path).unwrap();
        let back = load(&path).unwrap();
        let scorer = |t: &[i32]| (t[0] % 13) as f32;
        let a = bank.lookup(&mut { scorer });
        let b = back.lookup(&mut { scorer });
        assert_eq!(a.best, b.best);
        assert_eq!(a.evals, b.evals);
    }

    #[test]
    fn rejects_corrupt_files() {
        let path = tmp("corrupt.bank");
        std::fs::write(&path, [0u8; 16]).unwrap();
        assert!(load(&path).is_err());
        // truncated valid file
        let bank = sample_bank(3, 10);
        let good = tmp("trunc.bank");
        save(&bank, &good).unwrap();
        let bytes = std::fs::read(&good).unwrap();
        std::fs::write(&good, &bytes[..bytes.len() - 5]).unwrap();
        assert!(load(&good).is_err());
    }

    #[test]
    fn rejects_invalid_structure() {
        // hand-craft a file whose cluster members don't partition prompts
        let bank = sample_bank(4, 8);
        let path = tmp("invalid.bank");
        save(&bank, &path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // duplicate the last member index (breaks the partition invariant)
        let n = bytes.len();
        let last4: [u8; 4] = bytes[n - 4..].try_into().unwrap();
        bytes.extend_from_slice(&last4);
        // fix the member count of the last cluster? no — leave inconsistent
        std::fs::write(&path, &bytes).unwrap();
        assert!(load(&path).is_err());
    }
}
