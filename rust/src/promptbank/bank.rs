//! The two-layer Prompt Bank structure: lookup (§4.3.2), insertion &
//! replacement (§4.3.3).

use anyhow::{bail, Result};

use crate::promptbank::bankapi::{task_feature, Bank, COVERED_TASK_QUALITY};
use crate::promptbank::kmedoid::{cosine_distance, kmedoids};
use crate::util::rng::Rng;

/// One candidate initial prompt: a discrete token sequence plus its
/// activation feature (extracted by the base LLM at construction time).
#[derive(Clone, Debug)]
pub struct PromptCandidate {
    pub tokens: Vec<i32>,
    pub feature: Vec<f32>,
    /// Universe task this candidate originated from (None for synthetic
    /// perturbations); used by evaluation, not by the bank itself.
    pub source_task: Option<usize>,
}

/// Paper Eqn. 1: score(p) = mean eval-sample loss with candidate p as the
/// prompt. Implemented by the PJRT runtime for real runs and by synthetic
/// scorers in tests/benches. Lower is better.
pub trait Scorer {
    fn score(&mut self, tokens: &[i32]) -> f32;
}

impl<F: FnMut(&[i32]) -> f32> Scorer for F {
    fn score(&mut self, tokens: &[i32]) -> f32 {
        self(tokens)
    }
}

/// Result of a lookup: the selected candidate and the query's cost.
#[derive(Clone, Debug)]
pub struct LookupResult {
    pub best: usize,
    pub best_score: f32,
    /// Number of Eqn.-1 score evaluations performed (K + |cluster|).
    pub evals: usize,
}

/// One cluster of the two-layer structure.
#[derive(Clone, Debug)]
struct Cluster {
    /// Index into `prompts` of the representative (medoid) prompt.
    medoid: usize,
    /// Indices into `prompts` (includes the medoid).
    members: Vec<usize>,
}

/// The two-layer data structure (Fig 5).
pub struct TwoLayerBank {
    prompts: Vec<PromptCandidate>,
    clusters: Vec<Cluster>,
    /// Replacement threshold (paper default 3000).
    pub max_size: usize,
}

impl TwoLayerBank {
    /// Build the structure by K-medoid clustering of activation features
    /// (§4.3.1). `k` is the cluster count (paper default 50).
    pub fn build(
        prompts: Vec<PromptCandidate>,
        k: usize,
        max_size: usize,
        rng: &mut Rng,
    ) -> Result<TwoLayerBank> {
        if prompts.is_empty() {
            bail!("cannot build a Prompt Bank from zero candidates");
        }
        let features: Vec<Vec<f32>> =
            prompts.iter().map(|p| p.feature.clone()).collect();
        let (medoids, assignment) = kmedoids(&features, k, 30, rng);
        let mut clusters: Vec<Cluster> = medoids
            .iter()
            .map(|&m| Cluster { medoid: m, members: vec![] })
            .collect();
        for (i, &c) in assignment.iter().enumerate() {
            clusters[c].members.push(i);
        }
        clusters.retain(|c| !c.members.is_empty());
        Ok(TwoLayerBank { prompts, clusters, max_size })
    }

    pub fn len(&self) -> usize {
        self.prompts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.prompts.is_empty()
    }

    pub fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    pub fn candidate(&self, idx: usize) -> &PromptCandidate {
        &self.prompts[idx]
    }

    /// Two-layer lookup (Fig 5a): score the K representatives, descend
    /// into the best cluster, score its members, return the best.
    pub fn lookup(&self, scorer: &mut dyn Scorer) -> LookupResult {
        debug_assert!(!self.clusters.is_empty());
        let mut evals = 0usize;
        // layer 1: representatives
        let mut best_cluster = 0usize;
        let mut best_rep_score = f32::INFINITY;
        for (c, cl) in self.clusters.iter().enumerate() {
            let s = scorer.score(&self.prompts[cl.medoid].tokens);
            evals += 1;
            if s < best_rep_score {
                best_rep_score = s;
                best_cluster = c;
            }
        }
        // layer 2: members of the matched cluster
        let mut best = self.clusters[best_cluster].medoid;
        let mut best_score = best_rep_score;
        for &m in &self.clusters[best_cluster].members {
            if m == self.clusters[best_cluster].medoid {
                continue; // already scored at layer 1
            }
            let s = scorer.score(&self.prompts[m].tokens);
            evals += 1;
            if s < best_score {
                best_score = s;
                best = m;
            }
        }
        LookupResult { best, best_score, evals }
    }

    /// Brute-force lookup over all C candidates (the K = 1 baseline the
    /// paper reports hours for; used to quantify the two-layer speedup).
    pub fn lookup_bruteforce(&self, scorer: &mut dyn Scorer) -> LookupResult {
        let mut best = 0usize;
        let mut best_score = f32::INFINITY;
        for (i, p) in self.prompts.iter().enumerate() {
            let s = scorer.score(&p.tokens);
            if s < best_score {
                best_score = s;
                best = i;
            }
        }
        LookupResult { best, best_score, evals: self.prompts.len() }
    }

    /// Insertion & replacement (Fig 5b): attach the new candidate to the
    /// cluster whose representative is nearest in feature space (no Eqn.-1
    /// scoring involved); if the bank now exceeds `max_size`, evict the
    /// member of that cluster closest to its representative (maximizing
    /// remaining diversity). Returns the index of the inserted candidate.
    pub fn insert(&mut self, cand: PromptCandidate) -> usize {
        // nearest cluster by cosine distance of activation features
        let mut best_c = 0usize;
        let mut best_d = f32::INFINITY;
        for (c, cl) in self.clusters.iter().enumerate() {
            let d = cosine_distance(&cand.feature,
                                    &self.prompts[cl.medoid].feature);
            if d < best_d {
                best_d = d;
                best_c = c;
            }
        }
        let idx = self.prompts.len();
        self.prompts.push(cand);
        self.clusters[best_c].members.push(idx);
        if self.prompts.len() > self.max_size {
            self.replace_within(best_c, idx);
        }
        // replace_within is cluster-local and finds no victim when the
        // receiving cluster holds nothing evictable (e.g. a singleton
        // representative): fall back to the global eviction so the
        // `len ≤ max_size` ceiling always holds.
        while self.prompts.len() > self.max_size {
            if !self.evict_most_redundant() {
                break;
            }
        }
        idx
    }

    /// Evict the member of cluster `c` with minimal cosine distance to the
    /// representative (never the representative itself, never `keep`).
    fn replace_within(&mut self, c: usize, keep: usize) {
        let medoid = self.clusters[c].medoid;
        let mut victim: Option<usize> = None;
        let mut victim_d = f32::INFINITY;
        for &m in &self.clusters[c].members {
            if m == medoid || m == keep {
                continue;
            }
            let d = cosine_distance(&self.prompts[m].feature,
                                    &self.prompts[medoid].feature);
            if d < victim_d {
                victim_d = d;
                victim = Some(m);
            }
        }
        if let Some(v) = victim {
            self.remove_candidate(v);
        }
    }

    /// Remove a candidate by index (swap-remove with index fix-ups).
    fn remove_candidate(&mut self, idx: usize) {
        let last = self.prompts.len() - 1;
        self.prompts.swap_remove(idx);
        for cl in self.clusters.iter_mut() {
            cl.members.retain(|&m| m != idx);
            for m in cl.members.iter_mut() {
                if *m == last {
                    *m = idx;
                }
            }
            if cl.medoid == last {
                cl.medoid = idx;
            }
        }
    }

    /// Reassemble a bank from serialized parts (see `store`), validating
    /// the structural invariants: members partition the candidate set and
    /// every medoid belongs to its own cluster.
    pub fn from_parts(
        prompts: Vec<PromptCandidate>,
        clusters: Vec<(usize, Vec<usize>)>,
        max_size: usize,
    ) -> Result<TwoLayerBank> {
        if prompts.is_empty() || clusters.is_empty() {
            bail!("empty bank parts");
        }
        let n = prompts.len();
        let mut seen = vec![false; n];
        for (medoid, members) in &clusters {
            if members.is_empty() {
                bail!("empty cluster");
            }
            if !members.contains(medoid) {
                bail!("medoid {medoid} not a member of its cluster");
            }
            for &m in members {
                if m >= n {
                    bail!("member index {m} out of range {n}");
                }
                if seen[m] {
                    bail!("candidate {m} in two clusters");
                }
                seen[m] = true;
            }
        }
        if !seen.iter().all(|&s| s) {
            bail!("cluster members do not cover all candidates");
        }
        Ok(TwoLayerBank {
            prompts,
            clusters: clusters
                .into_iter()
                .map(|(medoid, members)| Cluster { medoid, members })
                .collect(),
            max_size,
        })
    }

    /// Total members across clusters (== len(); structural invariant).
    pub fn member_count(&self) -> usize {
        self.clusters.iter().map(|c| c.members.len()).sum()
    }

    /// Iterate candidate indices cluster by cluster.
    pub fn clusters_view(&self) -> Vec<(usize, &[usize])> {
        self.clusters
            .iter()
            .map(|c| (c.medoid, c.members.as_slice()))
            .collect()
    }

    /// Evict the globally most redundant candidate: the non-representative
    /// member closest to its own representative (maximizing remaining
    /// diversity). When only lone representatives remain, the one nearest
    /// to another representative is dissolved with its (empty) cluster,
    /// so shrinking always makes progress. Returns false only when a
    /// single candidate is left. (Kept in behavioral lockstep with
    /// `SimBank::evict_redundant` — change both together.)
    fn evict_most_redundant(&mut self) -> bool {
        let mut victim: Option<usize> = None;
        let mut victim_d = f32::INFINITY;
        for cl in &self.clusters {
            for &m in &cl.members {
                if m == cl.medoid {
                    continue;
                }
                let d = cosine_distance(&self.prompts[m].feature,
                                        &self.prompts[cl.medoid].feature);
                if d < victim_d {
                    victim_d = d;
                    victim = Some(m);
                }
            }
        }
        if let Some(v) = victim {
            self.remove_candidate(v);
            return true;
        }
        // Only lone representatives left: dissolve the most redundant.
        if self.clusters.len() < 2 {
            return false;
        }
        let mut victim_c = 0usize;
        let mut best_d = f32::INFINITY;
        for (a, ca) in self.clusters.iter().enumerate() {
            for cb in &self.clusters {
                if ca.medoid == cb.medoid {
                    continue;
                }
                let d = cosine_distance(&self.prompts[ca.medoid].feature,
                                        &self.prompts[cb.medoid].feature);
                if d < best_d {
                    best_d = d;
                    victim_c = a;
                }
            }
        }
        let m = self.clusters[victim_c].medoid;
        self.clusters.remove(victim_c);
        self.remove_candidate(m);
        true
    }
}

/// The serve plane's real bank behind the shared [`Bank`] interface.
/// Real selection quality comes from Eqn.-1 scoring ([`TwoLayerBank::lookup`]
/// with a [`Scorer`]); `quality_for` reports the structural-coverage
/// estimate the trait's planning consumers need (does the bank hold
/// candidates sourced from this task?), and `insert_tuned` synthesizes the
/// tuned prompt's entry next to its task's existing candidates.
impl Bank for TwoLayerBank {
    fn len(&self) -> usize {
        self.prompts.len()
    }

    fn max_size(&self) -> usize {
        self.max_size
    }

    fn set_max_size(&mut self, max_size: usize) {
        self.max_size = max_size.max(1);
        while self.prompts.len() > self.max_size {
            if !self.evict_most_redundant() {
                break; // only representatives left
            }
        }
    }

    fn n_clusters(&self) -> usize {
        self.clusters.len()
    }

    fn lookup_evals(&self) -> usize {
        if self.prompts.is_empty() {
            return 0;
        }
        let k = self.clusters.len().max(1);
        k + self.prompts.len() / k
    }

    fn quality_for(&self, task_id: usize) -> f64 {
        let covered = self
            .prompts
            .iter()
            .any(|p| p.source_task == Some(task_id));
        if covered {
            COVERED_TASK_QUALITY
        } else {
            0.0
        }
    }

    fn insert_tuned(&mut self, task_id: usize, _quality: f64) {
        // Place the tuned prompt's feature next to the task's existing
        // candidates (same activation neighborhood); a never-seen task
        // gets a deterministic synthetic direction.
        let dims = self.prompts.first().map_or(8, |p| p.feature.len());
        let feature = self
            .prompts
            .iter()
            .find(|p| p.source_task == Some(task_id))
            .map(|p| p.feature.clone())
            .unwrap_or_else(|| task_feature(0x7A5C_FEA7, task_id, dims));
        self.insert(PromptCandidate {
            tokens: vec![task_id as i32],
            feature,
            source_task: Some(task_id),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    /// Synthetic candidates on `nc` feature clusters; the "true" best
    /// candidate is the one whose feature is closest to `target`.
    fn make_candidates(rng: &mut Rng, n: usize, nc: usize) -> Vec<PromptCandidate> {
        let centers: Vec<Vec<f32>> = (0..nc)
            .map(|_| (0..8).map(|_| rng.normal() as f32 * 4.0).collect())
            .collect();
        (0..n)
            .map(|i| {
                let c = i % nc;
                let feature: Vec<f32> = centers[c]
                    .iter()
                    .map(|&x| x + 0.3 * rng.normal() as f32)
                    .collect();
                PromptCandidate {
                    tokens: vec![i as i32; 4],
                    feature,
                    source_task: Some(c),
                }
            })
            .collect()
    }

    /// Scorer: score = distance of candidate's (known) feature to target.
    struct FeatScorer<'a> {
        bank_feats: Vec<(&'a [i32], Vec<f32>)>,
        target: Vec<f32>,
    }
    impl Scorer for FeatScorer<'_> {
        fn score(&mut self, tokens: &[i32]) -> f32 {
            let f = &self
                .bank_feats
                .iter()
                .find(|(t, _)| *t == tokens)
                .expect("unknown candidate")
                .1;
            cosine_distance(f, &self.target)
        }
    }

    fn build(rng: &mut Rng, n: usize, nc: usize, k: usize) -> TwoLayerBank {
        let cands = make_candidates(rng, n, nc);
        TwoLayerBank::build(cands, k, 10_000, rng).unwrap()
    }

    #[test]
    fn build_rejects_empty() {
        let mut rng = Rng::new(0);
        assert!(TwoLayerBank::build(vec![], 5, 100, &mut rng).is_err());
    }

    #[test]
    fn lookup_costs_k_plus_cluster_size() {
        let mut rng = Rng::new(1);
        let bank = build(&mut rng, 120, 6, 6);
        let mut calls = 0usize;
        let res = bank.lookup(&mut |_: &[i32]| {
            calls += 1;
            calls as f32
        });
        assert_eq!(res.evals, calls);
        // two-layer cost must be far below brute force
        assert!(res.evals < 120 / 2, "evals = {}", res.evals);
    }

    #[test]
    fn lookup_close_to_bruteforce_on_clustered_data() {
        let mut rng = Rng::new(2);
        let cands = make_candidates(&mut rng, 200, 8);
        let feats: Vec<(Vec<i32>, Vec<f32>)> = cands
            .iter()
            .map(|c| (c.tokens.clone(), c.feature.clone()))
            .collect();
        let target = cands[17].feature.clone();
        let bank = TwoLayerBank::build(cands, 8, 10_000, &mut rng).unwrap();
        let mk = || FeatScorer {
            bank_feats: feats.iter().map(|(t, f)| (t.as_slice(), f.clone())).collect(),
            target: target.clone(),
        };
        let two = bank.lookup(&mut mk());
        let brute = bank.lookup_bruteforce(&mut mk());
        // the two-layer result must be near the global optimum
        assert!(two.best_score <= brute.best_score + 0.05,
                "two {} vs brute {}", two.best_score, brute.best_score);
        assert!(two.evals < brute.evals / 4);
    }

    #[test]
    fn insert_grows_and_respects_max_size() {
        let mut rng = Rng::new(3);
        let cands = make_candidates(&mut rng, 50, 5);
        let mut bank = TwoLayerBank::build(cands, 5, 52, &mut rng).unwrap();
        let extra = make_candidates(&mut rng, 10, 5);
        for c in extra {
            bank.insert(c);
        }
        assert!(bank.len() <= 52, "len = {}", bank.len());
        assert_eq!(bank.member_count(), bank.len());
    }

    #[test]
    fn replacement_evicts_most_redundant() {
        let mut rng = Rng::new(4);
        // two clusters far apart; cap at current size so insert must evict
        let cands = make_candidates(&mut rng, 20, 2);
        let mut bank = TwoLayerBank::build(cands, 2, 20, &mut rng).unwrap();
        let before = bank.len();
        let new = PromptCandidate {
            tokens: vec![999; 4],
            feature: vec![100.0; 8],
            source_task: None,
        };
        bank.insert(new);
        assert_eq!(bank.len(), before); // one in, one out
        // the inserted candidate must still be present
        assert!((0..bank.len()).any(|i| bank.candidate(i).tokens == vec![999; 4]));
    }

    #[test]
    fn medoids_survive_replacement() {
        let mut rng = Rng::new(5);
        let cands = make_candidates(&mut rng, 30, 3);
        let mut bank = TwoLayerBank::build(cands, 3, 30, &mut rng).unwrap();
        let medoid_tokens: Vec<Vec<i32>> = bank
            .clusters_view()
            .iter()
            .map(|(m, _)| bank.candidate(*m).tokens.clone())
            .collect();
        for _ in 0..10 {
            let c = make_candidates(&mut rng, 1, 3).pop().unwrap();
            bank.insert(c);
        }
        for mt in &medoid_tokens {
            assert!(
                bank.clusters_view()
                    .iter()
                    .any(|(m, _)| &bank.candidate(*m).tokens == mt),
                "medoid evicted"
            );
        }
    }

    #[test]
    fn prop_membership_partition_invariant() {
        check("members partition candidates", 20, |rng| {
            let n = 10 + rng.below(60);
            let nc = 1 + rng.below(5);
            let k = 1 + rng.below(8);
            let mut bank = build(rng, n, nc, k);
            for _ in 0..rng.below(20) {
                let c = make_candidates(rng, 1, nc).pop().unwrap();
                bank.insert(c);
            }
            ensure(bank.member_count() == bank.len(),
                   format!("{} members vs {} prompts",
                           bank.member_count(), bank.len()))?;
            // every index appears exactly once
            let mut seen = vec![0usize; bank.len()];
            for (_, members) in bank.clusters_view() {
                for &m in members {
                    ensure(m < bank.len(), "member out of range")?;
                    seen[m] += 1;
                }
            }
            ensure(seen.iter().all(|&c| c == 1), "index seen != once")?;
            Ok(())
        });
    }

    #[test]
    fn prop_lookup_returns_minimum_of_evaluated() {
        check("lookup best is min over evaluated", 20, |rng| {
            let n = 20 + rng.below(80);
            let bank = build(rng, n, 4, 5);
            let mut scores = std::collections::HashMap::new();
            let mut r2 = rng.fork(1);
            let res = bank.lookup(&mut |t: &[i32]| {
                *scores.entry(t.to_vec()).or_insert_with(|| r2.f32())
            });
            let best = bank.candidate(res.best).tokens.clone();
            ensure(
                scores.values().all(|&s| res.best_score <= s)
                    || scores[&best] == res.best_score,
                "best_score inconsistent",
            )?;
            ensure((res.best_score - scores[&best]).abs() < 1e-6,
                   "returned score mismatch")?;
            Ok(())
        });
    }

    #[test]
    fn trait_feedback_and_shrink_keep_invariants() {
        let mut rng = Rng::new(7);
        let cands = make_candidates(&mut rng, 60, 6);
        let mut bank = TwoLayerBank::build(cands, 6, 60, &mut rng).unwrap();
        // structural coverage: a sourced task is covered, a novel one not
        assert!(bank.quality_for(2) > 0.0);
        assert_eq!(bank.quality_for(999), 0.0);
        // feedback: the tuned prompt makes the novel task covered
        bank.insert_tuned(999, 0.97);
        assert!(bank.quality_for(999) > 0.0);
        // elastic shrink evicts down to the ceiling, keeping the partition
        bank.set_max_size(30);
        assert!(bank.len() <= 30, "len {}", bank.len());
        assert_eq!(bank.member_count(), bank.len());
        assert!(bank.lookup_evals() > 0);
        assert!(bank.lookup_evals() < 60);
        // insertion after a deep shrink cannot leak past the ceiling,
        // even when the receiving cluster has nothing cluster-local to
        // evict (the global fallback must fire)
        for t in 0usize..10 {
            bank.insert_tuned(2000 + t, 0.97);
            assert!(bank.len() <= 30, "ceiling leaked to {}", bank.len());
            assert_eq!(bank.member_count(), bank.len());
        }
    }

    #[test]
    fn bruteforce_finds_global_min() {
        let mut rng = Rng::new(6);
        let bank = build(&mut rng, 60, 3, 4);
        let res = bank.lookup_bruteforce(&mut |t: &[i32]| t[0] as f32);
        assert_eq!(res.evals, 60);
        assert_eq!(bank.candidate(res.best).tokens[0], 0);
    }
}
