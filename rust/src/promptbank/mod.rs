//! The Prompt Bank (§4.3): a two-layer query engine over a corpus of
//! candidate initial prompts.
//!
//! Layer 1 holds each cluster's *representative prompt* (the K-medoid
//! medoid over activation-feature cosine distance); layer 2 holds the
//! cluster members. `lookup` scores the K representatives, descends into
//! the best cluster and scores its members — `K + C/K` score evaluations
//! instead of `C` (paper: up to 40× cheaper at <10 % ITA loss).
//!
//! The bank is generic over a [`Scorer`] (paper Eqn. 1) so it runs both
//! against the real PJRT runtime (`runtime::scorer`) and against synthetic
//! scorers in tests/simulation.

pub mod bank;
pub mod kmedoid;
pub mod offline;
pub mod simmodel;
pub mod store;

pub use bank::{LookupResult, PromptCandidate, Scorer, TwoLayerBank};
pub use kmedoid::{cosine_distance, kmedoids};
pub use offline::{build_bank, build_corpus};
pub use simmodel::BankModel;
