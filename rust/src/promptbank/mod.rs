//! The Prompt Bank (§4.3): a two-layer query engine over a corpus of
//! candidate initial prompts.
//!
//! Layer 1 holds each cluster's *representative prompt* (the K-medoid
//! medoid over activation-feature cosine distance); layer 2 holds the
//! cluster members. `lookup` scores the K representatives, descends into
//! the best cluster and scores its members — `K + C/K` score evaluations
//! instead of `C` (paper: up to 40× cheaper at <10 % ITA loss).
//!
//! One stateful [`Bank`] interface (lookup cost, quality-for-task,
//! insertion/replacement feedback, elastic sizing) is shared by every
//! consumer:
//! * [`TwoLayerBank`] — the serve plane's real bank: activation features
//!   extracted by the base LLM, Eqn.-1 scoring through a [`Scorer`];
//! * [`SimBank`] — the simulator's deterministic bank: synthetic
//!   per-task features, coverage-driven quality, fed by completed jobs
//!   (replacing the retired memoryless `BankModel` Beta stand-in);
//! * [`InductionBank`] — the induction baseline [88] behind the same
//!   interface (the LLM prompts itself; nothing shared, nothing learned).

pub mod bank;
pub mod bankapi;
pub mod kmedoid;
pub mod offline;
pub mod simmodel;
pub mod store;

pub use bank::{LookupResult, PromptCandidate, Scorer, TwoLayerBank};
pub use bankapi::{task_feature, Bank, COVERED_TASK_QUALITY,
                  TUNED_PROMPT_QUALITY};
pub use kmedoid::{cosine_distance, kmedoids};
pub use offline::{build_bank, build_corpus};
pub use simmodel::{induction_quality, InductionBank, SimBank, SimBankConfig,
                   SimBankSet, BANK_DIMS};
