//! The shared Prompt-Bank interface (§4.3): one stateful, feedback-driven
//! abstraction implemented by both the serve plane's [`TwoLayerBank`]
//! (real activation features + Eqn.-1 scoring) and the simulator's
//! [`SimBank`] (synthetic per-task features, deterministic
//! coverage-driven quality), plus the [`InductionBank`] stand-in for the
//! induction baseline [88].
//!
//! The scheduler, both baselines and the serve plane all talk to a bank
//! through this trait: lookup cost (`lookup_evals`), the quality the bank
//! delivers for a task *right now* (`quality_for` — a pure function of
//! bank state, so planning estimates and realized launches agree), the
//! feedback edge (`insert_tuned` at job completion, Fig 5b), and the
//! elasticity knob (`set_max_size`, §4.4.3 shrink-under-pressure).
//!
//! [`TwoLayerBank`]: crate::promptbank::TwoLayerBank
//! [`SimBank`]: crate::promptbank::SimBank
//! [`InductionBank`]: crate::promptbank::InductionBank

use crate::util::rng::Rng;

/// Quality (fraction of ideal ITA performance) of a freshly *tuned*
/// prompt flowing back into the bank at job completion: tuning ran to the
/// task's target accuracy, so the resulting prompt is near-ideal for its
/// own task.
pub const TUNED_PROMPT_QUALITY: f64 = 0.97;

/// Structural-coverage quality estimate the serve plane's real bank
/// reports for a task it holds candidates for (the paper's Fig 9a:
/// selected candidates reach ≥ 0.9 of ideal for most jobs). Actual
/// selection quality there comes from real Eqn.-1 scoring; this constant
/// only feeds admission-style estimates through the trait.
pub const COVERED_TASK_QUALITY: f64 = 0.9;

/// One bank serving one LLM: two-layer lookup state with insertion,
/// redundancy-driven replacement and elastic sizing. Object-safe so
/// policies can hold `Box<dyn Bank>` per LLM and swap implementations
/// (real / simulated / induction) without generics.
pub trait Bank {
    /// Candidate count C.
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Replacement ceiling (insertions beyond it evict the most redundant
    /// member of the receiving cluster).
    fn max_size(&self) -> usize;

    /// Move the replacement ceiling (§4.4.3 elasticity): shrinking evicts
    /// the most redundant members immediately, growing opens headroom for
    /// future insertions.
    fn set_max_size(&mut self, max_size: usize);

    /// Layer-1 cluster count K.
    fn n_clusters(&self) -> usize;

    /// Eqn.-1 score evaluations of one two-layer lookup (the K + C/K
    /// shape of Fig 5a). Lookup *latency* is `lookup_evals() ×` the
    /// per-LLM eval cost (see `SimBankSet::lookup_latency`), so it
    /// responds dynamically to bank growth and shrinking.
    fn lookup_evals(&self) -> usize;

    /// Quality of the prompt a lookup for `task_id` would select right
    /// now — a deterministic, pure function of the current bank state
    /// (coverage of the task's feature neighborhood), NOT a random draw.
    fn quality_for(&self, task_id: usize) -> f64;

    /// Insertion & replacement (Fig 5b): a completed job feeds its tuned
    /// prompt back. Raises `quality_for(task_id)` for subsequent lookups
    /// (the convergence flywheel); over the ceiling, the most redundant
    /// candidate of the receiving cluster is evicted.
    fn insert_tuned(&mut self, task_id: usize, quality: f64);
}

/// Deterministic synthetic activation feature of a universe task:
/// a fixed pseudo-random direction per `(seed, task_id)`, so the same
/// task always lands at the same point in feature space (any task id is
/// valid — novel tasks appearing mid-run hash to fresh directions).
pub fn task_feature(seed: u64, task_id: usize, dims: usize) -> Vec<f32> {
    let mut rng = Rng::new(
        seed ^ (task_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
    );
    (0..dims).map(|_| rng.normal() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::promptbank::cosine_distance;

    #[test]
    fn task_features_deterministic_and_distinct() {
        let a = task_feature(7, 3, 8);
        let b = task_feature(7, 3, 8);
        assert_eq!(a, b);
        let c = task_feature(7, 4, 8);
        assert_ne!(a, c);
        // distinct tasks are far apart in cosine distance (near-orthogonal
        // random directions), which is what makes coverage per-task
        let d = cosine_distance(&a, &c);
        assert!(d > 0.3, "tasks too close: {d}");
    }

    #[test]
    fn novel_task_ids_have_features_too() {
        let f = task_feature(1, 1 << 30, 8);
        assert_eq!(f.len(), 8);
        assert!(f.iter().any(|&x| x != 0.0));
    }
}
