//! Offline phase (§5.2): assemble the candidate corpus and build the
//! two-layer structure against a real model runtime — feature extraction
//! for every candidate, then K-medoid clustering.
//!
//! The corpus mirrors the paper's "thousands of public prompts": every
//! universe task's instruction tag plus noisy variants (the stand-in
//! documented in DESIGN.md §Substitutions).

use anyhow::Result;

use crate::promptbank::bank::{PromptCandidate, TwoLayerBank};
use crate::runtime::ModelRuntime;
use crate::tuning::data::TaskUniverse;
use crate::util::rng::Rng;

/// Assemble `size` candidates: each task's tag first, then noisy variants
/// round-robin across tasks. Features are extracted with the real model.
pub fn build_corpus(
    rt: &ModelRuntime,
    uni: &TaskUniverse,
    size: usize,
    flip_prob: f64,
    rng: &mut Rng,
) -> Result<Vec<PromptCandidate>> {
    let mut cands = Vec::with_capacity(size);
    for i in 0..size {
        let t = i % uni.n_tasks;
        let tokens = if i < uni.n_tasks {
            uni.tag(t).to_vec()
        } else {
            uni.noisy_tag(rng, t, flip_prob)
        };
        let feature = rt.features(&tokens)?;
        cands.push(PromptCandidate { tokens, feature, source_task: Some(t) });
    }
    Ok(cands)
}

/// Full offline phase: corpus + clustering. `k` clusters, replacement
/// threshold `max_size` (paper defaults: K = 50, 3000 candidates).
pub fn build_bank(
    rt: &ModelRuntime,
    uni: &TaskUniverse,
    size: usize,
    k: usize,
    max_size: usize,
    rng: &mut Rng,
) -> Result<TwoLayerBank> {
    let corpus = build_corpus(rt, uni, size, 0.3, rng)?;
    TwoLayerBank::build(corpus, k, max_size, rng)
}
