//! PromptTuner launcher: the L3 coordinator CLI.
//!
//! ```text
//! prompttuner simulate  --system prompttuner|infless|elasticflow
//!                       --load low|medium|high --slo 1.0 --gpus 32 [--seed N]
//! prompttuner trace     --load medium [--out trace.txt] [--seed N]
//! prompttuner calibrate [--variant sim-gpt2b] [--iters 30]
//! prompttuner bank      [--variant sim-gpt2b] [--size 300] [--k 20] [--task 3]
//! prompttuner tune      [--variant sim-gpt2b] --task 3 [--iters 200] [--lr 0.05]
//! prompttuner info
//! ```

use anyhow::{bail, Result};
use prompttuner::baselines::{ElasticFlow, ElasticFlowConfig, Infless, InflessConfig};
use prompttuner::cluster::{Policy, SimConfig, Simulator};
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::metrics::summary_line;
use prompttuner::runtime::ModelRuntime;
use prompttuner::trace::{Load, TraceConfig, TraceGenerator};
use prompttuner::tuning::{TaskUniverse, Trainer, TrainerConfig};
use prompttuner::util::cli::Args;
use prompttuner::util::manifest::Manifest;
use prompttuner::util::rng::Rng;
use prompttuner::workload::PerfModel;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let args = Args::parse(argv.iter().skip(1).cloned());
    let result = match cmd {
        "simulate" => cmd_simulate(&args),
        "trace" => cmd_trace(&args),
        "calibrate" => cmd_calibrate(&args),
        "bank" => cmd_bank(&args),
        "tune" => cmd_tune(&args),
        "info" => cmd_info(&args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprint!("unknown command '{other}'\n{}", HELP);
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
PromptTuner — SLO-aware elastic system for LLM prompt tuning (reproduction)

USAGE: prompttuner <command> [--options]

COMMANDS:
  simulate    run a scheduling policy over a generated trace
  trace       generate / inspect an LPT workload trace
  calibrate   measure real per-iteration & lookup times via the PJRT runtime
  bank        build a Prompt Bank and run a lookup for a task (real runtime)
  tune        run one real prompt-tuning job end to end (real runtime)
  info        show artifact manifest summary
";

fn load_level(s: &str) -> Result<Load> {
    Load::from_name(s).ok_or_else(|| anyhow::anyhow!("bad --load '{s}'"))
}

fn cmd_simulate(args: &Args) -> Result<()> {
    let system = args.get_or("system", "prompttuner");
    let load = load_level(args.get_or("load", "medium"))?;
    let slo: f64 = args.parse_or("slo", 1.0)?;
    let gpus: usize = args.parse_or("gpus", 32)?;
    let seed: u64 = args.parse_or("seed", 42)?;
    let perf = PerfModel::default();
    let mut gen = TraceGenerator::new(
        TraceConfig { seed, slo_emergence: slo, ..Default::default() },
        perf.clone(),
    );
    let jobs = gen.generate_main(load);
    let sim = Simulator::new(SimConfig { max_gpus: gpus, ..Default::default() }, perf);
    let mut policy: Box<dyn Policy> = match system {
        "prompttuner" => Box::new(PromptTuner::new(PromptTunerConfig {
            max_gpus: gpus,
            seed,
            ..Default::default()
        })),
        "infless" => Box::new(Infless::new(InflessConfig {
            max_gpus: gpus,
            seed,
            ..Default::default()
        })),
        "elasticflow" => Box::new(ElasticFlow::new(ElasticFlowConfig {
            cluster_size: gpus,
            seed,
            ..Default::default()
        })),
        other => bail!("unknown --system '{other}'"),
    };
    let res = sim.run(policy.as_mut(), jobs);
    println!("{}", summary_line(&res));
    Ok(())
}

fn cmd_trace(args: &Args) -> Result<()> {
    let load = load_level(args.get_or("load", "medium"))?;
    let seed: u64 = args.parse_or("seed", 42)?;
    let slo: f64 = args.parse_or("slo", 1.0)?;
    let perf = PerfModel::default();
    let mut gen = TraceGenerator::new(
        TraceConfig { seed, slo_emergence: slo, ..Default::default() },
        perf,
    );
    let jobs = gen.generate_main(load);
    if let Some(out) = args.get("out") {
        prompttuner::trace::save(out, &jobs)?;
        println!("wrote {} jobs to {out}", jobs.len());
    } else {
        let counts =
            prompttuner::trace::generator::arrivals_per_minute(&jobs, 1200.0);
        println!("{} jobs; arrivals/minute:", jobs.len());
        for (m, c) in counts.iter().enumerate() {
            println!("  min {m:>2}: {} {}", c, "#".repeat(*c));
        }
    }
    Ok(())
}

fn artifacts_dir(args: &Args) -> String {
    args.get_or("artifacts", prompttuner::DEFAULT_ARTIFACTS_DIR).to_string()
}

fn cmd_calibrate(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let variant = args.get_or("variant", "sim-gpt2b");
    let iters: usize = args.parse_or("iters", 30)?;
    let manifest = Manifest::load(&dir)?;
    let uni = TaskUniverse::load(manifest.tasks_path_abs())?;
    println!("loading {variant} ...");
    let rt = ModelRuntime::load(&manifest, variant)?;
    println!("  cold start (compile + weights): {:.2}s", rt.load_time_s);
    let mut rng = Rng::new(7);
    let (toks, tgts) = uni.sample_batch(&mut rng, 0, rt.info.batch_train, rt.info.seq);
    let mut state = prompttuner::runtime::TuneState::new(
        rt.embed_prompt(uni.tag(0))?,
    );
    // warmup
    rt.tune_step(&mut state, &toks, &tgts, 0.05)?;
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        rt.tune_step(&mut state, &toks, &tgts, 0.05)?;
    }
    let per_iter = t0.elapsed().as_secs_f64() / iters as f64;
    println!("  tune_step: {:.2} ms/iter", per_iter * 1e3);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        rt.score(uni.tag(0), &toks_eval(&uni, &rt)?, &tgts_eval(&uni, &rt)?)?;
    }
    println!("  score (Eqn.1): {:.2} ms/eval", t0.elapsed().as_secs_f64() / iters as f64 * 1e3);
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        rt.features(uni.tag(0))?;
    }
    println!("  features: {:.2} ms", t0.elapsed().as_secs_f64() / iters as f64 * 1e3);
    Ok(())
}

fn toks_eval(uni: &TaskUniverse, rt: &ModelRuntime) -> Result<Vec<i32>> {
    let mut rng = Rng::new(11);
    Ok(uni.sample_batch(&mut rng, 0, rt.info.batch_eval, rt.info.seq).0)
}

fn tgts_eval(uni: &TaskUniverse, rt: &ModelRuntime) -> Result<Vec<i32>> {
    let mut rng = Rng::new(11);
    Ok(uni.sample_batch(&mut rng, 0, rt.info.batch_eval, rt.info.seq).1)
}

fn cmd_bank(args: &Args) -> Result<()> {
    use prompttuner::promptbank::{build_bank, store};
    use prompttuner::runtime::RuntimeScorer;
    let dir = artifacts_dir(args);
    let variant = args.get_or("variant", "sim-gpt2b");
    let size: usize = args.parse_or("size", 300)?;
    let k: usize = args.parse_or("k", 20)?;
    let task: usize = args.parse_or("task", 3)?;
    let manifest = Manifest::load(&dir)?;
    let uni = TaskUniverse::load(manifest.tasks_path_abs())?;
    let rt = ModelRuntime::load(&manifest, variant)?;
    let mut rng = Rng::new(5);
    let bank = if let Some(path) = args.get("load") {
        println!("loading bank from {path} ...");
        store::load(path)?
    } else {
        println!("building bank: {size} candidates, K={k} (offline phase) ...");
        build_bank(&rt, &uni, size, k, 3000, &mut rng)?
    };
    if let Some(path) = args.get("save") {
        store::save(&bank, path)?;
        println!("bank persisted to {path}");
    }
    let trainer = Trainer::new(&rt, &uni, TrainerConfig::default());
    let (etoks, etgts) = trainer.eval_batch(task);
    let mut scorer = RuntimeScorer::new(&rt, etoks, etgts);
    let t0 = std::time::Instant::now();
    let res = bank.lookup(&mut scorer);
    let dt = t0.elapsed().as_secs_f64();
    let best = bank.candidate(res.best);
    println!(
        "lookup: {} evals in {:.2}s -> candidate from task {:?} (score {:.4})",
        res.evals, dt, best.source_task, res.best_score
    );
    Ok(())
}

fn cmd_tune(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let variant = args.get_or("variant", "sim-gpt2b");
    let task: usize = args.parse_or("task", 3)?;
    let iters: usize = args.parse_or("iters", 200)?;
    let lr: f32 = args.parse_or("lr", 0.05)?;
    let manifest = Manifest::load(&dir)?;
    let uni = TaskUniverse::load(manifest.tasks_path_abs())?;
    let rt = ModelRuntime::load(&manifest, variant)?;
    let trainer = Trainer::new(
        &rt,
        &uni,
        TrainerConfig { lr, max_iters: iters, ..Default::default() },
    );
    let init = uni.tag((task + 1) % uni.n_tasks).to_vec(); // a transfer prompt
    println!("tuning {variant} task {task} from a neighbour task's prompt ...");
    let out = trainer.tune(task, &init, 0.0)?; // target 0 => run all iters
    for (it, loss) in out.loss_curve.iter().step_by(10.max(iters / 20)) {
        println!("  iter {it:>4}: train loss {loss:.4}");
    }
    println!("final eval loss: {:.4}", out.final_eval_loss);
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = artifacts_dir(args);
    let manifest = Manifest::load(&dir)?;
    println!("artifacts: {}", manifest.dir.display());
    println!("task universe: seed {}", manifest.universe_seed);
    for (name, m) in &manifest.models {
        println!(
            "  {name}: d={} layers={} heads={} vocab={} seq={} P={} params={} \
             artifacts={} theta={}",
            m.d_model, m.n_layers, m.n_heads, m.vocab, m.seq, m.prompt_len,
            m.n_params, m.artifacts.len(),
            m.theta_path.is_some()
        );
    }
    Ok(())
}
