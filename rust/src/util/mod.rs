//! Shared substrates: deterministic PRNG, statistics helpers, a mini
//! property-testing harness, and parsers for the build-time artifacts
//! (`manifest.txt`, `theta.bin`, `tasks.bin`).
//!
//! Everything here is dependency-free by design — the only external crates
//! in the whole binary are `xla` (PJRT) and `anyhow`.

pub mod binio;
pub mod cli;
pub mod manifest;
pub mod prop;
pub mod rng;
pub mod stats;
