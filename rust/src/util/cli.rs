//! Tiny CLI argument parser (clap is not available offline): supports
//! `--key value`, `--key=value`, boolean `--flag`, and positional args.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse raw argv tokens. A token `--k=v` or `--k v` becomes an option;
    /// `--k` followed by another `--...` (or end) becomes a flag.
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Args {
        let tokens: Vec<String> = argv.into_iter().collect();
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(rest) = t.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    args.options.insert(rest.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.flags.push(rest.to_string());
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// From the process environment, skipping the binary name (and an
    /// optional subcommand already consumed by the caller).
    pub fn from_env(skip: usize) -> Args {
        Args::parse(std::env::args().skip(1 + skip))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| anyhow!("invalid value '{v}' for --{name}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_key_value_both_forms() {
        let a = parse("--load medium --slo=1.5");
        assert_eq!(a.get("load"), Some("medium"));
        assert_eq!(a.get("slo"), Some("1.5"));
    }

    #[test]
    fn flags_and_positionals() {
        let a = parse("simulate trace.txt --seed 7 --verbose");
        assert_eq!(a.positional, vec!["simulate", "trace.txt"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.get("seed"), Some("7"));
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("--x 1 --dry-run");
        assert!(a.flag("dry-run"));
        assert_eq!(a.get("x"), Some("1"));
    }

    #[test]
    fn parse_or_and_require() {
        let a = parse("--n 5");
        assert_eq!(a.parse_or("n", 0usize).unwrap(), 5);
        assert_eq!(a.parse_or("m", 9usize).unwrap(), 9);
        assert!(a.parse_or::<usize>("n", 0).is_ok());
        assert!(parse("--n x").parse_or::<usize>("n", 0).is_err());
        assert!(a.require("absent").is_err());
        assert_eq!(a.require("n").unwrap(), "5");
    }

    #[test]
    fn get_or_default() {
        let a = parse("");
        assert_eq!(a.get_or("load", "medium"), "medium");
    }
}
