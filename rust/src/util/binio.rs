//! Little-endian binary readers for the build-time artifacts:
//! `theta.bin` (flat f32 parameters) and `tasks.bin` (task universe).

use std::io::Read;
use std::path::Path;

use anyhow::{bail, Context, Result};

/// Read a whole file of little-endian f32 values.
pub fn read_f32_file(path: impl AsRef<Path>) -> Result<Vec<f32>> {
    let bytes = std::fs::read(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    if bytes.len() % 4 != 0 {
        bail!("{}: length {} not a multiple of 4", path.as_ref().display(), bytes.len());
    }
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Write little-endian f32 values (used to persist tuned prompts).
pub fn write_f32_file(path: impl AsRef<Path>, data: &[f32]) -> Result<()> {
    let mut bytes = Vec::with_capacity(data.len() * 4);
    for v in data {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    std::fs::write(path.as_ref(), bytes)
        .with_context(|| format!("writing {}", path.as_ref().display()))?;
    Ok(())
}

/// Streaming little-endian reader over an in-memory byte buffer.
pub struct LeReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> LeReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        LeReader { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.remaining() < n {
            bail!("unexpected EOF: need {n} bytes, have {}", self.remaining());
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    pub fn u32(&mut self) -> Result<u32> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    /// One little-endian f64 (exact bit round-trip with [`LeWriter::f64`];
    /// the trace/fixture formats depend on that exactness).
    pub fn f64(&mut self) -> Result<f64> {
        let b = self.take(8)?;
        Ok(f64::from_le_bytes([
            b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7],
        ]))
    }

    pub fn f32_vec(&mut self, n: usize) -> Result<Vec<f32>> {
        let b = self.take(4 * n)?;
        Ok(b.chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    pub fn i32_vec(&mut self, n: usize) -> Result<Vec<i32>> {
        let b = self.take(4 * n)?;
        Ok(b.chunks_exact(4)
            .map(|c| i32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }
}

/// Little-endian writer that mirrors [`LeReader`].
#[derive(Default)]
pub struct LeWriter {
    buf: Vec<u8>,
}

impl LeWriter {
    pub fn new() -> Self {
        LeWriter { buf: vec![] }
    }

    pub fn u32(&mut self, v: u32) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn f32_slice(&mut self, vs: &[f32]) {
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn i32_slice(&mut self, vs: &[i32]) {
        for v in vs {
            self.buf.extend_from_slice(&v.to_le_bytes());
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn write_to(self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path.as_ref(), self.buf)
            .with_context(|| format!("writing {}", path.as_ref().display()))?;
        Ok(())
    }
}

/// Read an entire file into memory (helper that keeps error context).
pub fn read_all(path: impl AsRef<Path>) -> Result<Vec<u8>> {
    let mut f = std::fs::File::open(path.as_ref())
        .with_context(|| format!("opening {}", path.as_ref().display()))?;
    let mut buf = vec![];
    f.read_to_end(&mut buf)?;
    Ok(buf)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_file_roundtrip() {
        let dir = std::env::temp_dir().join("pt_binio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("x.bin");
        let data = vec![0.0f32, 1.5, -2.25, f32::MAX];
        write_f32_file(&path, &data).unwrap();
        let back = read_f32_file(&path).unwrap();
        assert_eq!(back, data);
    }

    #[test]
    fn f32_file_rejects_bad_length() {
        let dir = std::env::temp_dir().join("pt_binio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bin");
        std::fs::write(&path, [0u8, 1, 2]).unwrap();
        assert!(read_f32_file(&path).is_err());
    }

    #[test]
    fn le_reader_sequencing() {
        let mut bytes = vec![];
        bytes.extend_from_slice(&7u32.to_le_bytes());
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        bytes.extend_from_slice(&(-3i32).to_le_bytes());
        let mut r = LeReader::new(&bytes);
        assert_eq!(r.u32().unwrap(), 7);
        assert_eq!(r.f32_vec(1).unwrap(), vec![1.5]);
        assert_eq!(r.i32_vec(1).unwrap(), vec![-3]);
        assert_eq!(r.remaining(), 0);
        assert!(r.u32().is_err());
    }

    #[test]
    fn le_writer_reader_roundtrip() {
        let mut w = LeWriter::new();
        w.u32(42);
        w.f32_slice(&[1.5, -2.0]);
        w.i32_slice(&[-7, 9]);
        let bytes = w.into_bytes();
        let mut r = LeReader::new(&bytes);
        assert_eq!(r.u32().unwrap(), 42);
        assert_eq!(r.f32_vec(2).unwrap(), vec![1.5, -2.0]);
        assert_eq!(r.i32_vec(2).unwrap(), vec![-7, 9]);
        assert_eq!(r.remaining(), 0);
    }

    #[test]
    fn le_reader_eof_is_error_not_panic() {
        let mut r = LeReader::new(&[1, 2]);
        assert!(r.u32().is_err());
        assert!(r.f32_vec(1).is_err());
        assert!(r.u64().is_err());
        assert!(r.f64().is_err());
    }

    #[test]
    fn u64_f64_roundtrip_is_bit_exact() {
        let mut w = LeWriter::new();
        w.u64(u64::MAX);
        w.u64(0x0123_4567_89AB_CDEF);
        for v in [0.0f64, -0.0, 1.5, f64::MIN_POSITIVE, f64::MAX, 1e-300,
                  std::f64::consts::PI] {
            w.f64(v);
        }
        let bytes = w.into_bytes();
        let mut r = LeReader::new(&bytes);
        assert_eq!(r.u64().unwrap(), u64::MAX);
        assert_eq!(r.u64().unwrap(), 0x0123_4567_89AB_CDEF);
        for v in [0.0f64, -0.0, 1.5, f64::MIN_POSITIVE, f64::MAX, 1e-300,
                  std::f64::consts::PI] {
            assert_eq!(r.f64().unwrap().to_bits(), v.to_bits());
        }
        assert_eq!(r.remaining(), 0);
    }
}
