//! Deterministic PRNG substrate (no external crates): SplitMix64 seeding +
//! xoshiro256** core, with the sampling helpers the simulator and workload
//! generators need (uniform, normal, exponential, Poisson, categorical).

/// xoshiro256** PRNG seeded via SplitMix64. Deterministic across platforms.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut x = seed;
        let mut next = || {
            x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next(), next(), next(), next()];
        Rng { s }
    }

    /// Derive an independent stream (for per-component seeding).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for simulation purposes.
        (self.f64() * n as f64) as usize % n
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Exponential with rate `lambda` (mean 1/lambda).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        debug_assert!(lambda > 0.0);
        -self.f64().max(1e-300).ln() / lambda
    }

    /// Poisson sample (Knuth for small means, normal approx for large).
    pub fn poisson(&mut self, mean: f64) -> u64 {
        if mean <= 0.0 {
            return 0;
        }
        if mean > 30.0 {
            return self.normal_ms(mean, mean.sqrt()).round().max(0.0) as u64;
        }
        let l = (-mean).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= self.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    }

    /// Index sample from unnormalized non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            u -= w;
            if u <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }

    /// Sample from logits via Gumbel-max (numerically robust softmax draw).
    pub fn from_logits(&mut self, logits: &[f32]) -> usize {
        let mut best = 0usize;
        let mut best_v = f64::NEG_INFINITY;
        for (i, &l) in logits.iter().enumerate() {
            let g = -(-(self.f64().max(1e-300)).ln()).ln();
            let v = l as f64 + g;
            if v > best_v {
                best_v = v;
                best = i;
            }
        }
        best
    }

    /// Beta(a, b) via Jöhnk/gamma-free approximation (a,b >= 1 paths use
    /// the ratio of two gamma draws through Marsaglia–Tsang).
    pub fn beta(&mut self, a: f64, b: f64) -> f64 {
        let x = self.gamma(a);
        let y = self.gamma(b);
        x / (x + y)
    }

    /// Gamma(shape, 1) via Marsaglia–Tsang (with Johnk boost for shape < 1).
    pub fn gamma(&mut self, shape: f64) -> f64 {
        if shape < 1.0 {
            let u = self.f64().max(1e-12);
            return self.gamma(shape + 1.0) * u.powf(1.0 / shape);
        }
        let d = shape - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Choose k distinct indices from [0, n).
    pub fn choose_k(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k.min(n));
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mut c = Rng::new(43);
        assert_ne!(Rng::new(42).next_u64(), c.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Rng::new(2);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(3);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn exp_mean() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exp(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn poisson_mean_small_and_large() {
        let mut r = Rng::new(5);
        for lam in [0.5, 3.0, 50.0] {
            let n = 20_000;
            let mean: f64 =
                (0..n).map(|_| r.poisson(lam) as f64).sum::<f64>() / n as f64;
            assert!((mean - lam).abs() < lam.max(1.0) * 0.08, "lam={lam} mean={mean}");
        }
    }

    #[test]
    fn below_in_range_and_covers() {
        let mut r = Rng::new(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn categorical_follows_weights() {
        let mut r = Rng::new(7);
        let w = [1.0, 3.0];
        let n = 50_000;
        let ones = (0..n).filter(|_| r.categorical(&w) == 1).count();
        let frac = ones as f64 / n as f64;
        assert!((frac - 0.75).abs() < 0.02, "{frac}");
    }

    #[test]
    fn gumbel_from_logits_prefers_large() {
        let mut r = Rng::new(8);
        let logits = [0.0f32, 2.0, 0.0];
        let n = 20_000;
        let mid = (0..n).filter(|_| r.from_logits(&logits) == 1).count();
        let frac = mid as f64 / n as f64;
        // softmax(0,2,0)[1] ≈ 0.787
        assert!((frac - 0.787).abs() < 0.03, "{frac}");
    }

    #[test]
    fn beta_in_unit_interval_and_mean() {
        let mut r = Rng::new(9);
        let n = 50_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.beta(2.0, 2.0);
            assert!((0.0..=1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "{mean}");
    }

    #[test]
    fn gamma_mean_matches_shape() {
        let mut r = Rng::new(10);
        for shape in [0.5, 1.0, 4.0] {
            let n = 50_000;
            let mean: f64 = (0..n).map(|_| r.gamma(shape)).sum::<f64>() / n as f64;
            assert!((mean - shape).abs() < 0.05 * shape.max(1.0), "{shape} {mean}");
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut xs: Vec<usize> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_k_distinct() {
        let mut r = Rng::new(12);
        let ks = r.choose_k(20, 8);
        assert_eq!(ks.len(), 8);
        let mut s = ks.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 8);
    }

    #[test]
    fn fork_streams_independent() {
        let mut root = Rng::new(13);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }
}
