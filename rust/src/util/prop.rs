//! A minimal property-based testing harness (proptest is not available in
//! this offline environment, so we build the substrate ourselves).
//!
//! Usage (`no_run`: rustdoc binaries miss the xla rpath in this env):
//! ```no_run
//! use prompttuner::util::prop::check;
//! check("addition commutes", 200, |rng| {
//!     let a = rng.below(1000) as i64;
//!     let b = rng.below(1000) as i64;
//!     if a + b == b + a { Ok(()) } else { Err(format!("{a} {b}")) }
//! });
//! ```
//!
//! Each case gets a fresh deterministic RNG; on failure the harness panics
//! with the case index and seed so the exact case can be replayed.

use super::rng::Rng;

/// Base seed for all property checks; override with PROP_SEED env var.
fn base_seed() -> u64 {
    std::env::var("PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5EED_CAFE)
}

/// Run `cases` random cases of `f`; panic with diagnostics on the first
/// failure. `f` returns `Err(msg)` to fail a case.
pub fn check<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: PROP_SEED={seed0}, case seed {seed}): {msg}"
            );
        }
    }
}

/// Like [`check`] but the property also receives the case index (handy for
/// size-scaling: small cases first, larger later).
pub fn check_sized<F>(name: &str, cases: u64, mut f: F)
where
    F: FnMut(&mut Rng, u64) -> Result<(), String>,
{
    let seed0 = base_seed();
    for case in 0..cases {
        let seed = seed0 ^ (case.wrapping_mul(0x9E37_79B9_7F4A_7C15));
        let mut rng = Rng::new(seed);
        if let Err(msg) = f(&mut rng, case) {
            panic!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay: PROP_SEED={seed0}, case seed {seed}): {msg}"
            );
        }
    }
}

/// Assert-style helper for property bodies.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut n = 0;
        check("always ok", 50, |_| {
            n += 1;
            Ok(())
        });
        assert_eq!(n, 50);
    }

    #[test]
    #[should_panic(expected = "property 'fails'")]
    fn failing_property_panics_with_name() {
        check("fails", 10, |rng| {
            ensure(rng.f64() < 2.0, "impossible")?;
            Err("boom".to_string())
        });
    }

    #[test]
    fn sized_variant_passes_index() {
        let mut seen = vec![];
        check_sized("sizes", 5, |_, i| {
            seen.push(i);
            Ok(())
        });
        assert_eq!(seen, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn cases_are_deterministic() {
        let mut a = vec![];
        check("collect a", 5, |rng| {
            a.push(rng.next_u64());
            Ok(())
        });
        let mut b = vec![];
        check("collect b", 5, |rng| {
            b.push(rng.next_u64());
            Ok(())
        });
        assert_eq!(a, b);
    }
}
