//! Parser for `artifacts/manifest.txt` — the contract between the Python
//! AOT pipeline and the Rust runtime. Plain whitespace-separated text; no
//! serde in this environment, so the parser is hand-rolled and strict.
//!
//! Line grammar (see python/compile/aot.py `write_manifest`):
//! ```text
//! manifest-version 1
//! tasks <relpath> seed=<u64>
//! model <name> d=<n> layers=<n> heads=<n> vocab=<n> seq=<n> prompt=<n>
//!       batch_train=<n> batch_eval=<n> n_params=<n>
//! segment <model> <name> <offset> <count> <init-kind> <init-param>
//! artifact <model> <fn> <relpath>
//! theta <model> <relpath>
//! ```

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// How a parameter segment is initialized when no pretrained theta exists.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum InitKind {
    /// Gaussian with the given standard deviation.
    Normal(f32),
    Zeros,
    Ones,
}

/// One contiguous slice of the flat parameter vector.
#[derive(Clone, Debug)]
pub struct Segment {
    pub name: String,
    pub offset: usize,
    pub count: usize,
    pub init: InitKind,
}

/// Architecture + AOT batch dims of one exported model variant.
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub name: String,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub vocab: usize,
    pub seq: usize,
    pub prompt_len: usize,
    pub batch_train: usize,
    pub batch_eval: usize,
    pub n_params: usize,
    pub segments: Vec<Segment>,
    /// function name -> HLO text path (relative to the artifacts dir).
    pub artifacts: BTreeMap<String, PathBuf>,
    /// pretrained flat theta, if exported.
    pub theta_path: Option<PathBuf>,
}

/// Parsed manifest: all model variants plus the shared task universe.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub tasks_path: PathBuf,
    pub universe_seed: u64,
    pub models: BTreeMap<String, ModelInfo>,
}

fn kv<'a>(tok: &'a str, key: &str) -> Result<&'a str> {
    tok.strip_prefix(key)
        .and_then(|s| s.strip_prefix('='))
        .ok_or_else(|| anyhow!("expected {key}=<v>, got '{tok}'"))
}

fn kv_usize(tok: &str, key: &str) -> Result<usize> {
    kv(tok, key)?.parse().with_context(|| format!("bad {key} in '{tok}'"))
}

impl Manifest {
    /// Load and validate `<dir>/manifest.txt`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (dir is retained for resolving relative paths).
    pub fn parse(text: &str, dir: PathBuf) -> Result<Manifest> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let first = lines.next().ok_or_else(|| anyhow!("empty manifest"))?;
        if first.trim() != "manifest-version 1" {
            bail!("unsupported manifest version: '{first}'");
        }
        let mut tasks_path = None;
        let mut universe_seed = 0u64;
        let mut models: BTreeMap<String, ModelInfo> = BTreeMap::new();
        for line in lines {
            let toks: Vec<&str> = line.split_whitespace().collect();
            match toks[0] {
                "tasks" => {
                    if toks.len() != 3 {
                        bail!("bad tasks line: '{line}'");
                    }
                    tasks_path = Some(PathBuf::from(toks[1]));
                    universe_seed = kv(toks[2], "seed")?.parse()?;
                }
                "model" => {
                    if toks.len() != 11 {
                        bail!("bad model line: '{line}'");
                    }
                    let name = toks[1].to_string();
                    let info = ModelInfo {
                        name: name.clone(),
                        d_model: kv_usize(toks[2], "d")?,
                        n_layers: kv_usize(toks[3], "layers")?,
                        n_heads: kv_usize(toks[4], "heads")?,
                        vocab: kv_usize(toks[5], "vocab")?,
                        seq: kv_usize(toks[6], "seq")?,
                        prompt_len: kv_usize(toks[7], "prompt")?,
                        batch_train: kv_usize(toks[8], "batch_train")?,
                        batch_eval: kv_usize(toks[9], "batch_eval")?,
                        n_params: kv_usize(toks[10], "n_params")?,
                        segments: vec![],
                        artifacts: BTreeMap::new(),
                        theta_path: None,
                    };
                    models.insert(name, info);
                }
                "segment" => {
                    if toks.len() != 7 {
                        bail!("bad segment line: '{line}'");
                    }
                    let model = models
                        .get_mut(toks[1])
                        .ok_or_else(|| anyhow!("segment before model: '{line}'"))?;
                    let init = match toks[5] {
                        "normal" => InitKind::Normal(toks[6].parse()?),
                        "zeros" => InitKind::Zeros,
                        "ones" => InitKind::Ones,
                        other => bail!("unknown init kind '{other}'"),
                    };
                    model.segments.push(Segment {
                        name: toks[2].to_string(),
                        offset: toks[3].parse()?,
                        count: toks[4].parse()?,
                        init,
                    });
                }
                "artifact" => {
                    if toks.len() != 4 {
                        bail!("bad artifact line: '{line}'");
                    }
                    let model = models
                        .get_mut(toks[1])
                        .ok_or_else(|| anyhow!("artifact before model: '{line}'"))?;
                    model
                        .artifacts
                        .insert(toks[2].to_string(), PathBuf::from(toks[3]));
                }
                "theta" => {
                    if toks.len() != 3 {
                        bail!("bad theta line: '{line}'");
                    }
                    let model = models
                        .get_mut(toks[1])
                        .ok_or_else(|| anyhow!("theta before model: '{line}'"))?;
                    model.theta_path = Some(PathBuf::from(toks[2]));
                }
                other => bail!("unknown manifest record '{other}'"),
            }
        }
        let manifest = Manifest {
            dir,
            tasks_path: tasks_path.ok_or_else(|| anyhow!("manifest missing tasks line"))?,
            universe_seed,
            models,
        };
        manifest.validate()?;
        Ok(manifest)
    }

    /// Structural validation: segment offsets contiguous and summing to
    /// n_params; prompt/batch dims positive.
    pub fn validate(&self) -> Result<()> {
        for m in self.models.values() {
            let mut off = 0usize;
            for seg in &m.segments {
                if seg.offset != off {
                    bail!("{}: segment {} offset {} != expected {off}",
                          m.name, seg.name, seg.offset);
                }
                off += seg.count;
            }
            if off != m.n_params {
                bail!("{}: segments sum {} != n_params {}", m.name, off, m.n_params);
            }
            if m.d_model == 0 || m.n_heads == 0 || m.d_model % m.n_heads != 0 {
                bail!("{}: bad d_model/heads", m.name);
            }
            if m.prompt_len == 0 || m.seq == 0 {
                bail!("{}: bad prompt/seq", m.name);
            }
        }
        Ok(())
    }

    /// Absolute path of a model's artifact file.
    pub fn artifact_path(&self, model: &str, func: &str) -> Result<PathBuf> {
        let m = self
            .models
            .get(model)
            .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
        let rel = m
            .artifacts
            .get(func)
            .ok_or_else(|| anyhow!("model '{model}' has no artifact '{func}'"))?;
        Ok(self.dir.join(rel))
    }

    /// Absolute path of the task universe binary.
    pub fn tasks_path_abs(&self) -> PathBuf {
        self.dir.join(&self.tasks_path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
manifest-version 1
tasks tasks.bin seed=77
model tiny d=8 layers=1 heads=2 vocab=16 seq=4 prompt=2 batch_train=2 batch_eval=3 n_params=20
segment tiny wte 0 12 normal 0.02
segment tiny rest 12 8 zeros 0.0
artifact tiny score tiny/score.hlo.txt
theta tiny tiny/theta.bin
";

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/a")).unwrap();
        assert_eq!(m.universe_seed, 77);
        assert_eq!(m.tasks_path_abs(), PathBuf::from("/a/tasks.bin"));
        let tiny = &m.models["tiny"];
        assert_eq!(tiny.d_model, 8);
        assert_eq!(tiny.n_params, 20);
        assert_eq!(tiny.segments.len(), 2);
        assert_eq!(tiny.segments[0].init, InitKind::Normal(0.02));
        assert_eq!(tiny.segments[1].init, InitKind::Zeros);
        assert_eq!(
            m.artifact_path("tiny", "score").unwrap(),
            PathBuf::from("/a/tiny/score.hlo.txt")
        );
        assert_eq!(tiny.theta_path.as_deref(),
                   Some(Path::new("tiny/theta.bin")));
    }

    #[test]
    fn rejects_bad_version() {
        assert!(Manifest::parse("manifest-version 2\n", PathBuf::new()).is_err());
        assert!(Manifest::parse("", PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_gap_in_segments() {
        let bad = SAMPLE.replace("segment tiny rest 12 8", "segment tiny rest 13 7");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_wrong_total() {
        let bad = SAMPLE.replace("n_params=20", "n_params=21");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn rejects_unknown_record() {
        let bad = format!("{SAMPLE}banana 1 2\n");
        assert!(Manifest::parse(&bad, PathBuf::new()).is_err());
    }

    #[test]
    fn unknown_lookups_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::new()).unwrap();
        assert!(m.artifact_path("tiny", "nope").is_err());
        assert!(m.artifact_path("nope", "score").is_err());
    }

    #[test]
    fn segment_names_preserved_in_order() {
        let m = Manifest::parse(SAMPLE, PathBuf::new()).unwrap();
        let names: Vec<&str> =
            m.models["tiny"].segments.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, vec!["wte", "rest"]);
    }
}
