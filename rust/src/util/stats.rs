//! Statistics helpers used by metrics reporting and the benches
//! (means, percentiles, CDF extraction, online accumulators).

/// Arithmetic mean; 0.0 for an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Population standard deviation; 0.0 for fewer than 2 points.
pub fn std(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Percentile (nearest-rank with linear interpolation), q in [0, 1].
pub fn percentile(xs: &[f64], q: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 0.5)
}

/// Empirical CDF sampled at `points` evenly spaced quantiles — the format
/// the figure benches print as (x, F(x)) series.
pub fn cdf_points(xs: &[f64], points: usize) -> Vec<(f64, f64)> {
    if xs.is_empty() || points == 0 {
        return vec![];
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    (0..points)
        .map(|i| {
            let q = (i as f64 + 1.0) / points as f64;
            let idx = ((q * v.len() as f64).ceil() as usize).clamp(1, v.len()) - 1;
            (v[idx], q)
        })
        .collect()
}

/// Histogram over [lo, hi) with `bins` equal-width buckets; out-of-range
/// values clamp into the first/last bucket.
pub fn histogram(xs: &[f64], lo: f64, hi: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    if bins == 0 || hi <= lo {
        return h;
    }
    let w = (hi - lo) / bins as f64;
    for &x in xs {
        let i = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[i] += 1;
    }
    h
}

/// Online mean/max accumulator (used for scheduler-overhead tracking).
#[derive(Clone, Debug, Default)]
pub struct Accum {
    pub n: u64,
    pub sum: f64,
    pub max: f64,
    pub min: f64,
}

impl Accum {
    pub fn new() -> Self {
        Accum { n: 0, sum: 0.0, max: f64::NEG_INFINITY, min: f64::INFINITY }
    }

    pub fn add(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if x < self.min {
            self.min = x;
        }
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum / self.n as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std_basic() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std(&xs) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn empty_inputs_are_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std(&[]), 0.0);
        assert_eq!(percentile(&[], 0.5), 0.0);
        assert!(cdf_points(&[], 10).is_empty());
    }

    #[test]
    fn percentile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert!((percentile(&xs, 0.0) - 1.0).abs() < 1e-12);
        assert!((percentile(&xs, 1.0) - 4.0).abs() < 1e-12);
        assert!((percentile(&xs, 0.5) - 2.5).abs() < 1e-12);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn percentile_unsorted_input() {
        let xs = [9.0, 1.0, 5.0];
        assert!((median(&xs) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn cdf_monotone_and_ends_at_one() {
        let xs: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let cdf = cdf_points(&xs, 10);
        assert_eq!(cdf.len(), 10);
        for w in cdf.windows(2) {
            assert!(w[0].0 <= w[1].0);
            assert!(w[0].1 < w[1].1);
        }
        assert!((cdf.last().unwrap().1 - 1.0).abs() < 1e-12);
        assert!((cdf.last().unwrap().0 - 99.0).abs() < 1e-12);
    }

    #[test]
    fn histogram_counts_and_clamping() {
        let xs = [-1.0, 0.1, 0.5, 0.9, 2.0];
        let h = histogram(&xs, 0.0, 1.0, 2);
        assert_eq!(h, vec![2, 3]); // -1.0 clamps low, 0.5/0.9/2.0 land high
        assert_eq!(h.iter().sum::<usize>(), xs.len());
    }

    #[test]
    fn accum_tracks_extremes() {
        let mut a = Accum::new();
        for x in [3.0, 1.0, 2.0] {
            a.add(x);
        }
        assert_eq!(a.n, 3);
        assert!((a.mean() - 2.0).abs() < 1e-12);
        assert_eq!(a.max, 3.0);
        assert_eq!(a.min, 1.0);
    }
}
