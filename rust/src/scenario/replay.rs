//! Binary trace (de)serialization for the replay scenario family and the
//! golden-snapshot fixtures: little-endian via `util::binio`, with exact
//! f64 round-trips (the plain-text format in `trace::save` rounds to 3–4
//! decimals, which is fine for inspection but not for bit-deterministic
//! replay).
//!
//! Layout: magic `u32` ("PTR1"), version `u32`, job count `u32`, then per
//! job `u32` llm index, `u32` task id, `u32` traced GPUs, and f64
//! submit/duration/base-iters/quality/slo. Job ids are implicit (record
//! order), re-assigned densely at load.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::binio::{read_all, LeReader, LeWriter};
use crate::workload::{JobSpec, Llm};

/// File magic: "PTR1" little-endian.
pub const MAGIC: u32 = u32::from_le_bytes(*b"PTR1");
pub const VERSION: u32 = 1;

/// Serialize a trace into bytes.
pub fn to_bytes(jobs: &[JobSpec]) -> Vec<u8> {
    let mut w = LeWriter::new();
    w.u32(MAGIC);
    w.u32(VERSION);
    w.u32(jobs.len() as u32);
    for j in jobs {
        w.u32(j.llm.index() as u32);
        w.u32(j.task_id as u32);
        w.u32(j.traced_gpus as u32);
        w.f64(j.submit_s);
        w.f64(j.duration_s);
        w.f64(j.base_iters);
        w.f64(j.user_prompt_quality);
        w.f64(j.slo_s);
    }
    w.into_bytes()
}

/// Parse a trace from bytes written by [`to_bytes`].
pub fn from_bytes(bytes: &[u8]) -> Result<Vec<JobSpec>> {
    let mut r = LeReader::new(bytes);
    let magic = r.u32().map_err(|e| e.context("binary trace: missing magic"))?;
    if magic != MAGIC {
        bail!("binary trace: bad magic {magic:#010x} (want {MAGIC:#010x})");
    }
    let version = r.u32()?;
    if version != VERSION {
        bail!("binary trace: unsupported version {version}");
    }
    let count = r.u32()? as usize;
    let mut jobs = Vec::with_capacity(count);
    for i in 0..count {
        let llm_idx = r.u32()? as usize;
        let llm = *Llm::ALL
            .get(llm_idx)
            .ok_or_else(|| anyhow::anyhow!("job {i}: bad LLM index {llm_idx}"))?;
        let task_id = r.u32()? as usize;
        let traced_gpus = r.u32()? as usize;
        let submit_s = r.f64()?;
        let duration_s = r.f64()?;
        let base_iters = r.f64()?;
        let user_prompt_quality = r.f64()?;
        let slo_s = r.f64()?;
        if !submit_s.is_finite() || submit_s < 0.0 {
            bail!("job {i}: bad submit time {submit_s}");
        }
        if !(duration_s.is_finite() && duration_s > 0.0) {
            bail!("job {i}: bad duration {duration_s}");
        }
        if !(slo_s.is_finite() && slo_s > 0.0) {
            bail!("job {i}: bad SLO {slo_s}");
        }
        if !(base_iters.is_finite() && base_iters > 0.0) {
            bail!("job {i}: bad base iterations {base_iters}");
        }
        if !(0.0..=1.0).contains(&user_prompt_quality) {
            bail!("job {i}: prompt quality {user_prompt_quality} outside [0, 1]");
        }
        if traced_gpus == 0 {
            bail!("job {i}: zero traced GPUs");
        }
        jobs.push(JobSpec {
            id: i,
            llm,
            task_id,
            submit_s,
            duration_s,
            traced_gpus,
            base_iters,
            user_prompt_quality,
            slo_s,
        });
    }
    if r.remaining() != 0 {
        bail!("binary trace: {} trailing bytes", r.remaining());
    }
    // The simulator indexes jobs by position and assumes submit order:
    // re-sort (stable, so equal-time records keep file order) and re-id.
    jobs.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
    }
    Ok(jobs)
}

/// Write a binary trace file.
pub fn save(path: impl AsRef<Path>, jobs: &[JobSpec]) -> Result<()> {
    std::fs::write(path.as_ref(), to_bytes(jobs))
        .with_context(|| format!("writing {}", path.as_ref().display()))?;
    Ok(())
}

/// Load a binary trace file written by [`save`].
pub fn load(path: impl AsRef<Path>) -> Result<Vec<JobSpec>> {
    let bytes = read_all(path.as_ref())?;
    from_bytes(&bytes)
        .map_err(|e| e.context(format!("parsing {}", path.as_ref().display())))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::{Load, TraceConfig, TraceGenerator};
    use crate::workload::PerfModel;

    #[test]
    fn roundtrip_is_bit_exact() {
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 3, ..Default::default() },
            PerfModel::default(),
        );
        let jobs = gen.generate_main(Load::Low);
        let back = from_bytes(&to_bytes(&jobs)).unwrap();
        assert_eq!(back.len(), jobs.len());
        for (a, b) in jobs.iter().zip(&back) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.llm, b.llm);
            assert_eq!(a.task_id, b.task_id);
            assert_eq!(a.traced_gpus, b.traced_gpus);
            assert_eq!(a.submit_s.to_bits(), b.submit_s.to_bits());
            assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
            assert_eq!(a.base_iters.to_bits(), b.base_iters.to_bits());
            assert_eq!(
                a.user_prompt_quality.to_bits(),
                b.user_prompt_quality.to_bits()
            );
            assert_eq!(a.slo_s.to_bits(), b.slo_s.to_bits());
        }
    }

    #[test]
    fn file_roundtrip_and_replay_scenario() {
        let dir = std::env::temp_dir().join("pt_replay_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bin");
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 4, ..Default::default() },
            PerfModel::default(),
        );
        let jobs = gen.generate_main(Load::Low);
        save(&path, &jobs).unwrap();
        let sc = crate::scenario::Scenario::Replay { path: path.clone() };
        let back = sc.generate(0, 1.0).unwrap(); // seed/slo ignored by replay
        assert_eq!(back.len(), jobs.len());
        assert_eq!(
            back[7].submit_s.to_bits(),
            jobs[7].submit_s.to_bits()
        );
    }

    #[test]
    fn rejects_corrupt_inputs() {
        assert!(from_bytes(&[]).is_err());
        assert!(from_bytes(&[0u8; 12]).is_err()); // bad magic
        let mut ok = to_bytes(&[]);
        assert!(from_bytes(&ok).unwrap().is_empty());
        ok.push(0); // trailing byte
        assert!(from_bytes(&ok).is_err());
        // truncated record
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 5, ..Default::default() },
            PerfModel::default(),
        );
        let jobs = gen.generate_main(Load::Low);
        let bytes = to_bytes(&jobs);
        assert!(from_bytes(&bytes[..bytes.len() - 4]).is_err());
    }

    #[test]
    fn rejects_non_physical_job_values() {
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 6, ..Default::default() },
            PerfModel::default(),
        );
        let jobs = gen.generate_main(Load::Low);
        let patches: [fn(&mut crate::workload::JobSpec); 4] = [
            |j| j.base_iters = f64::NAN,
            |j| j.user_prompt_quality = 1.5,
            |j| j.traced_gpus = 0,
            |j| j.duration_s = -1.0,
        ];
        for patch in patches {
            let mut bad = jobs.clone();
            patch(&mut bad[3]);
            assert!(from_bytes(&to_bytes(&bad)).is_err());
        }
    }
}
