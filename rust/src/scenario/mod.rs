//! Scenario engine: a catalogue of named workload families beyond the
//! paper's low/medium/high load levels (§6.1 evaluates one production
//! trace shape only), so the schedulers can be exercised under the
//! traffic regimes where related SLO-serving work shows rankings flip.
//!
//! Families:
//! * **diurnal** — sinusoidal arrival rate over a multi-hour window;
//! * **flash-crowd** — correlated spike storms (all LLMs surge in the
//!   same minutes) at configurable intensity;
//! * **heavy-tail** — bounded-Pareto job durations (the paper's
//!   log-uniform body plus a far tail);
//! * **multi-tenant** — several tenants with different SLO-emergence
//!   tiers sharing one cluster;
//! * **replay** — a trace previously serialized with [`replay::save`]
//!   (binary, `util::binio`, exact f64 round-trip);
//! * **spot-market** — the paper's spiky arrivals on a cluster losing
//!   capacity to seeded spot-reclaim waves (notice window, graceful
//!   checkpoints) — see [`Scenario::fault_plan`];
//! * **az-outage** — one correlated mass GPU failure mid-window (lost
//!   work back to the last checkpoint) with straggler slowdowns in the
//!   recovery wake;
//! * **task-drift** — novel tasks (ids from [`NOVEL_TASK_BASE`] up,
//!   outside every bank's seeded corpus) take over the arrival stream
//!   mid-run: a warm Prompt Bank's coverage dips cold for them and
//!   recovers as completed jobs feed tuned prompts back — only
//!   expressible with the stateful bank (`promptbank::SimBank`);
//! * **chaos-latency / chaos-flaky / chaos-storm** — the paper's spiky
//!   arrivals under a continuous-misbehavior profile
//!   ([`fault::ChaosProfile`](crate::fault::ChaosProfile)): launch/bank
//!   latency tails, failed completions that re-enter the queue with
//!   retry budgets and exponential backoff, and (storm only) rolling
//!   correlated rack failures — see [`Scenario::chaos_profile`].
//!
//! The fault families pair a workload with a [`FaultPlan`]
//! ([`Scenario::fault_plan`]); `bench::make_policy` wraps the policy in
//! the `fault::FaultInjector` automatically for such cells. The chaos
//! families additionally return a [`Scenario::chaos_profile`], which the
//! harness hands to the injector as a `fault::ChaosEngine`.
//!
//! Every family is produced through the existing
//! [`TraceGenerator`]/[`JobSpec`] pipeline — same per-job sampling, same
//! finalize pass — so all three policies run on them unchanged. The
//! conformance suite (`tests/prop_scenarios.rs`) pins determinism, job
//! counts, window containment and deadline sanity for each family; the
//! simulation oracle (`cluster::SimOracle`) audits the runs themselves.

pub mod replay;

use std::path::PathBuf;

use anyhow::Result;

use crate::fault::{ChaosKind, ChaosProfile, FaultPlan};
use crate::trace::{DurationDist, TraceConfig, TraceGenerator};
use crate::util::rng::Rng;
use crate::workload::{JobSpec, Llm, PerfModel};

/// Tenant SLO-emergence tiers (multi-tenant family): tenant t gets
/// `TIERS[t % 4] × S` — premium (tight) through relaxed.
pub const TENANT_TIERS: [f64; 4] = [0.5, 1.0, 1.5, 2.0];

/// First task id of the task-drift family's novel range — safely beyond
/// both the trace generator's default universe and the Prompt Bank's
/// seeded corpus (`SimBankConfig::corpus_tasks`), so a warm bank holds no
/// candidates for drifted tasks until completions feed them back.
pub const NOVEL_TASK_BASE: usize = 4096;

/// Every scenario-family name a bench record may carry: the synthetic
/// catalogue plus `replay` (file-backed, so absent from
/// [`Scenario::catalogue`]). This is the single source of truth the
/// bench reports embed (`families` key) so `tools/check_bench.py` never
/// hand-maintains the list again — a test below pins it against the
/// catalogue.
pub const FAMILIES: [&str; 11] = [
    "diurnal",
    "flash-crowd",
    "heavy-tail",
    "multi-tenant",
    "replay",
    "spot-market",
    "az-outage",
    "task-drift",
    "chaos-latency",
    "chaos-flaky",
    "chaos-storm",
];

/// A named workload family with its parameters.
#[derive(Clone, Debug)]
pub enum Scenario {
    /// Sinusoidal arrival rate over `hours`, trough → peak → trough;
    /// `peak_to_trough` is the rate ratio between the two.
    Diurnal { hours: f64, jobs_per_llm: usize, peak_to_trough: f64 },
    /// `storms` storm minutes shared by *all* LLMs (correlated surges),
    /// each at `intensity` × the base per-minute rate.
    FlashCrowd { storms: usize, intensity: f64, jobs_per_llm: usize },
    /// Bounded-Pareto durations (tail index `alpha`, min 5 s, cap 900 s)
    /// on the paper's spiky arrival shape.
    HeavyTail { alpha: f64, jobs_per_llm: usize },
    /// `tenants` tenants share the cluster; tenant t's SLOs use
    /// `TENANT_TIERS[t % 4]` × the base emergence S.
    MultiTenant { tenants: usize, jobs_per_tenant: usize },
    /// Replay a binary trace file written by [`replay::save`].
    Replay { path: PathBuf },
    /// Spot-instance market: the paper's spiky arrivals while `waves`
    /// reclaim waves each revoke `reclaim_frac` of the cluster with a
    /// 30 s notice (graceful checkpoints, capacity returns ~3 min
    /// later). The fault schedule comes from [`Scenario::fault_plan`].
    SpotMarket { waves: usize, reclaim_frac: f64, jobs_per_llm: usize },
    /// Availability-zone outage: one correlated mass failure of
    /// `outage_frac` of the cluster mid-window (no notice, work since
    /// the last checkpoint lost), repaired after `repair_s`, with
    /// straggler slowdowns in the recovery wake.
    AzOutage { outage_frac: f64, repair_s: f64, jobs_per_llm: usize },
    /// Task drift: jobs arriving after `drift_at_frac` of the window
    /// draw their task ids from a previously-unseen range of
    /// `novel_tasks` tasks (starting at [`NOVEL_TASK_BASE`]), so a warm
    /// bank goes cold for them mid-run and must recover through the
    /// completion-feedback flywheel.
    TaskDrift { drift_at_frac: f64, novel_tasks: usize, jobs_per_llm: usize },
    /// Continuous misbehavior: the paper's spiky arrivals while a
    /// [`ChaosProfile`] stretches launch/bank latencies, fails
    /// completions into retry-with-backoff, and (for
    /// [`ChaosKind::RackStorm`]) pairs with rolling correlated rack
    /// failures from [`Scenario::fault_plan`].
    Chaos { kind: ChaosKind, jobs_per_llm: usize },
}

impl Scenario {
    /// The default-parameterized synthetic catalogue (replay needs a
    /// file, so it is constructed explicitly where one exists).
    pub fn catalogue() -> Vec<Scenario> {
        vec![
            Scenario::Diurnal { hours: 3.0, jobs_per_llm: 80, peak_to_trough: 6.0 },
            Scenario::FlashCrowd { storms: 3, intensity: 25.0, jobs_per_llm: 70 },
            Scenario::HeavyTail { alpha: 1.1, jobs_per_llm: 60 },
            Scenario::MultiTenant { tenants: 4, jobs_per_tenant: 45 },
            Scenario::SpotMarket { waves: 3, reclaim_frac: 0.25,
                                   jobs_per_llm: 60 },
            Scenario::AzOutage { outage_frac: 0.5, repair_s: 300.0,
                                 jobs_per_llm: 60 },
            Scenario::TaskDrift { drift_at_frac: 0.4, novel_tasks: 8,
                                  jobs_per_llm: 60 },
            Scenario::Chaos { kind: ChaosKind::LatencyTail, jobs_per_llm: 60 },
            Scenario::Chaos { kind: ChaosKind::Flaky, jobs_per_llm: 60 },
            Scenario::Chaos { kind: ChaosKind::RackStorm, jobs_per_llm: 60 },
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            Scenario::Diurnal { .. } => "diurnal",
            Scenario::FlashCrowd { .. } => "flash-crowd",
            Scenario::HeavyTail { .. } => "heavy-tail",
            Scenario::MultiTenant { .. } => "multi-tenant",
            Scenario::Replay { .. } => "replay",
            Scenario::SpotMarket { .. } => "spot-market",
            Scenario::AzOutage { .. } => "az-outage",
            Scenario::TaskDrift { .. } => "task-drift",
            Scenario::Chaos { kind: ChaosKind::LatencyTail, .. } => {
                "chaos-latency"
            }
            Scenario::Chaos { kind: ChaosKind::Flaky, .. } => "chaos-flaky",
            Scenario::Chaos { kind: ChaosKind::RackStorm, .. } => "chaos-storm",
            Scenario::Chaos { kind: ChaosKind::Partition, .. } => {
                // Shard-plane only (no router to sever on one cluster);
                // named for completeness, absent from the catalogue.
                "chaos-partition"
            }
        }
    }

    /// Default-parameterized synthetic family by name (replay is
    /// excluded: it needs a path).
    pub fn from_name(name: &str) -> Option<Scenario> {
        Self::catalogue().into_iter().find(|s| s.name() == name)
    }

    /// Experiment window of the generated trace, seconds (None for
    /// replay, whose span comes from the file).
    pub fn window_s(&self) -> Option<f64> {
        match self {
            Scenario::Diurnal { hours, .. } => Some(hours * 3600.0),
            Scenario::FlashCrowd { .. } | Scenario::SpotMarket { .. } => {
                Some(1800.0)
            }
            Scenario::HeavyTail { .. }
            | Scenario::MultiTenant { .. }
            | Scenario::AzOutage { .. }
            | Scenario::TaskDrift { .. }
            | Scenario::Chaos { .. } => Some(1200.0),
            Scenario::Replay { .. } => None,
        }
    }

    /// Minimum experiment horizon (`SimConfig::horizon_s`) the family
    /// needs for every job to be *able* to finish: a heavy-tail job
    /// granted a single GPU can legally run for hours of simulated time,
    /// so the default 7200 s horizon would cut its tail off and
    /// under-report violations/cost. `bench::run_cell` applies this
    /// automatically; None means the default horizon suffices.
    pub fn horizon_hint(&self) -> Option<f64> {
        match self {
            Scenario::HeavyTail { .. } => Some(14400.0),
            _ => None,
        }
    }

    /// Exact number of jobs the family generates (None for replay).
    pub fn expected_jobs(&self) -> Option<usize> {
        match self {
            Scenario::Diurnal { jobs_per_llm, .. }
            | Scenario::FlashCrowd { jobs_per_llm, .. }
            | Scenario::HeavyTail { jobs_per_llm, .. }
            | Scenario::SpotMarket { jobs_per_llm, .. }
            | Scenario::AzOutage { jobs_per_llm, .. }
            | Scenario::TaskDrift { jobs_per_llm, .. }
            | Scenario::Chaos { jobs_per_llm, .. } => {
                Some(jobs_per_llm * Llm::MAIN.len())
            }
            Scenario::MultiTenant { tenants, jobs_per_tenant } => {
                Some(tenants * jobs_per_tenant)
            }
            Scenario::Replay { .. } => None,
        }
    }

    /// The family's involuntary-churn schedule, sized for a cluster of
    /// `cluster_gpus`, bit-deterministic in `seed` (None for the
    /// fault-free families). `bench::make_policy` wraps cells whose
    /// scenario returns a plan in the `fault::FaultInjector`.
    pub fn fault_plan(&self, seed: u64, cluster_gpus: usize) -> Option<FaultPlan> {
        let frac_gpus = |frac: f64| -> usize {
            ((cluster_gpus as f64 * frac).round() as usize)
                .clamp(1, cluster_gpus.max(1))
        };
        match self {
            Scenario::SpotMarket { waves, reclaim_frac, .. } => {
                Some(FaultPlan::spot_market(
                    seed,
                    self.window_s().unwrap(),
                    *waves,
                    frac_gpus(*reclaim_frac),
                    30.0,
                    180.0,
                ))
            }
            Scenario::AzOutage { outage_frac, repair_s, .. } => {
                Some(FaultPlan::az_outage(
                    seed,
                    self.window_s().unwrap(),
                    frac_gpus(*outage_frac),
                    *repair_s,
                    2,
                ))
            }
            Scenario::Chaos { kind: ChaosKind::RackStorm, .. } => {
                // Rolling hard failures; the chaos engine's domain
                // topology fans each one out to a whole rack.
                Some(FaultPlan::rolling_failures(
                    seed,
                    self.window_s().unwrap(),
                    3,
                    frac_gpus(0.2),
                    240.0,
                ))
            }
            _ => None,
        }
    }

    /// The family's continuous-misbehavior profile (None for the
    /// chaos-free families). `bench::make_policy` hands it to the
    /// `fault::FaultInjector` as a `fault::ChaosEngine` for such cells.
    pub fn chaos_profile(&self) -> Option<ChaosProfile> {
        match self {
            Scenario::Chaos { kind, .. } => Some(kind.profile()),
            _ => None,
        }
    }

    /// Generate the scenario's trace. `seed` drives all randomness (the
    /// family is bit-deterministic in it); `slo_emergence` scales every
    /// SLO (multi-tenant applies its per-tier factors on top; replay
    /// keeps the SLOs recorded in the file).
    pub fn generate(&self, seed: u64, slo_emergence: f64) -> Result<Vec<JobSpec>> {
        let base_cfg = |window_s: f64| TraceConfig {
            seed,
            window_s,
            slo_emergence,
            ..Default::default()
        };
        match self {
            Scenario::Diurnal { hours, jobs_per_llm, peak_to_trough } => {
                let window_s = hours * 3600.0;
                let minutes = (window_s / 60.0).ceil() as usize;
                // rate(m) = 1 + a·sin(2π m/minutes − π/2): trough at the
                // window edges, peak mid-window, peak/trough = r.
                let a = (peak_to_trough - 1.0) / (peak_to_trough + 1.0);
                let weights: Vec<f64> = (0..minutes)
                    .map(|m| {
                        let phase = 2.0 * std::f64::consts::PI * m as f64
                            / minutes as f64
                            - std::f64::consts::FRAC_PI_2;
                        1.0 + a * phase.sin()
                    })
                    .collect();
                let mut gen =
                    TraceGenerator::new(base_cfg(window_s), PerfModel::default());
                let mut jobs = vec![];
                for llm in Llm::MAIN {
                    jobs.extend(gen.generate_weighted(llm, *jobs_per_llm, &weights));
                }
                TraceGenerator::finalize(&mut jobs);
                Ok(jobs)
            }
            Scenario::FlashCrowd { storms, intensity, jobs_per_llm } => {
                let window_s = 1800.0;
                let minutes = (window_s / 60.0).ceil() as usize;
                // Storm minutes are drawn once and shared by every LLM —
                // that correlation is what distinguishes a flash crowd
                // from the generator's independent per-LLM spikes.
                let mut storm_rng = Rng::new(seed ^ 0xF1A5_4C40_57A0_0001);
                let storm_minutes =
                    storm_rng.choose_k(minutes, (*storms).min(minutes));
                let mut weights = vec![0.2f64; minutes];
                for &m in &storm_minutes {
                    weights[m] = 0.2 * intensity;
                }
                let mut gen =
                    TraceGenerator::new(base_cfg(window_s), PerfModel::default());
                let mut jobs = vec![];
                for llm in Llm::MAIN {
                    jobs.extend(gen.generate_weighted(llm, *jobs_per_llm, &weights));
                }
                TraceGenerator::finalize(&mut jobs);
                Ok(jobs)
            }
            Scenario::HeavyTail { alpha, jobs_per_llm } => {
                let cfg = TraceConfig {
                    duration: DurationDist::Pareto {
                        xm: 5.0,
                        alpha: *alpha,
                        cap: 900.0,
                    },
                    ..base_cfg(1200.0)
                };
                let mut gen = TraceGenerator::new(cfg, PerfModel::default());
                let mut jobs = vec![];
                for llm in Llm::MAIN {
                    jobs.extend(gen.generate_for(llm, *jobs_per_llm));
                }
                TraceGenerator::finalize(&mut jobs);
                Ok(jobs)
            }
            Scenario::MultiTenant { tenants, jobs_per_tenant } => {
                let mut jobs = vec![];
                for t in 0..*tenants {
                    let tier = TENANT_TIERS[t % TENANT_TIERS.len()];
                    let cfg = TraceConfig {
                        seed: seed
                            ^ (t as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15),
                        slo_emergence: slo_emergence * tier,
                        ..base_cfg(1200.0)
                    };
                    let mut gen = TraceGenerator::new(cfg, PerfModel::default());
                    for (i, llm) in Llm::MAIN.into_iter().enumerate() {
                        jobs.extend(gen.generate_for(
                            llm,
                            split_count(*jobs_per_tenant, Llm::MAIN.len(), i),
                        ));
                    }
                }
                TraceGenerator::finalize(&mut jobs);
                Ok(jobs)
            }
            Scenario::Replay { path } => replay::load(path),
            Scenario::TaskDrift { drift_at_frac, novel_tasks, jobs_per_llm } => {
                // The paper's spiky arrival shape; after the drift point
                // the stream switches to never-before-seen tasks, drawn
                // deterministically over the finalized (submit-sorted)
                // order so the remap is bit-stable.
                let window_s = self.window_s().unwrap();
                let mut gen =
                    TraceGenerator::new(base_cfg(window_s), PerfModel::default());
                let mut jobs = vec![];
                for llm in Llm::MAIN {
                    jobs.extend(gen.generate_for(llm, *jobs_per_llm));
                }
                TraceGenerator::finalize(&mut jobs);
                let drift_at = window_s * drift_at_frac.clamp(0.0, 1.0);
                let n = (*novel_tasks).max(1);
                let mut drift_rng = Rng::new(seed ^ 0xD41F_7D41_F7D4_1F70);
                for j in jobs.iter_mut() {
                    if j.submit_s >= drift_at {
                        j.task_id = NOVEL_TASK_BASE + drift_rng.below(n);
                    }
                }
                Ok(jobs)
            }
            Scenario::SpotMarket { jobs_per_llm, .. }
            | Scenario::AzOutage { jobs_per_llm, .. }
            | Scenario::Chaos { jobs_per_llm, .. } => {
                // The workload itself is the paper's spiky arrival shape;
                // the churn comes from the family's fault plan and/or
                // chaos profile (`Scenario::fault_plan`,
                // `Scenario::chaos_profile`), applied by the bench
                // harness.
                let window_s = self.window_s().unwrap();
                let mut gen =
                    TraceGenerator::new(base_cfg(window_s), PerfModel::default());
                let mut jobs = vec![];
                for llm in Llm::MAIN {
                    jobs.extend(gen.generate_for(llm, *jobs_per_llm));
                }
                TraceGenerator::finalize(&mut jobs);
                Ok(jobs)
            }
        }
    }
}

/// Split `total` jobs across `parts` LLMs: part `i` gets the base share
/// plus one of the remainder while it lasts, so the parts sum to `total`.
fn split_count(total: usize, parts: usize, i: usize) -> usize {
    total / parts + usize::from(i < total % parts)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn split_count_sums_to_total() {
        for total in [0usize, 1, 2, 3, 44, 45, 46, 100] {
            let sum: usize = (0..3).map(|i| split_count(total, 3, i)).sum();
            assert_eq!(sum, total, "total {total}");
        }
    }

    #[test]
    fn catalogue_names_are_unique_and_resolvable() {
        let cat = Scenario::catalogue();
        assert_eq!(cat.len(), 10);
        let mut names: Vec<&str> = cat.iter().map(|s| s.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), 10);
        for s in &cat {
            assert!(Scenario::from_name(s.name()).is_some(), "{}", s.name());
        }
        assert!(Scenario::from_name("replay").is_none());
        assert!(Scenario::from_name("nope").is_none());
    }

    #[test]
    fn families_constant_matches_catalogue_plus_replay() {
        let mut expected: Vec<&str> =
            Scenario::catalogue().iter().map(|s| s.name()).collect();
        expected.push("replay");
        expected.sort_unstable();
        let mut got: Vec<&str> = FAMILIES.to_vec();
        got.sort_unstable();
        assert_eq!(got, expected,
                   "scenario::FAMILIES drifted from the catalogue");
    }

    #[test]
    fn fault_plans_exist_exactly_for_fault_families() {
        for sc in Scenario::catalogue() {
            let faulted = matches!(
                sc,
                Scenario::SpotMarket { .. }
                    | Scenario::AzOutage { .. }
                    | Scenario::Chaos { kind: ChaosKind::RackStorm, .. }
            );
            let plan = sc.fault_plan(3, 32);
            assert_eq!(plan.is_some(), faulted, "{}", sc.name());
            if let Some(plan) = plan {
                assert!(!plan.is_empty(), "{}", sc.name());
                // deterministic in the seed and inside the window
                let again = sc.fault_plan(3, 32).unwrap();
                assert_eq!(plan.events(), again.events(), "{}", sc.name());
                let window = sc.window_s().unwrap();
                for ev in plan.events() {
                    assert!((0.0..window * 1.5).contains(&ev.at),
                            "{}: fault at {}", sc.name(), ev.at);
                }
            }
        }
    }

    #[test]
    fn families_emit_expected_counts_with_dense_ids() {
        for sc in Scenario::catalogue() {
            let jobs = sc.generate(5, 1.0).unwrap();
            assert_eq!(jobs.len(), sc.expected_jobs().unwrap(), "{}", sc.name());
            for (i, j) in jobs.iter().enumerate() {
                assert_eq!(j.id, i, "{}", sc.name());
            }
            for w in jobs.windows(2) {
                assert!(w[0].submit_s <= w[1].submit_s, "{}", sc.name());
            }
        }
    }

    #[test]
    fn diurnal_peaks_mid_window() {
        let sc = Scenario::Diurnal {
            hours: 2.0,
            jobs_per_llm: 400,
            peak_to_trough: 8.0,
        };
        let jobs = sc.generate(9, 1.0).unwrap();
        let window = sc.window_s().unwrap();
        let mid = jobs
            .iter()
            .filter(|j| {
                (window * 0.25..window * 0.75).contains(&j.submit_s)
            })
            .count();
        // the sinusoid concentrates arrivals around the mid-window peak
        assert!(
            mid as f64 > jobs.len() as f64 * 0.6,
            "{mid}/{} mid-window arrivals",
            jobs.len()
        );
    }

    #[test]
    fn flash_crowd_storms_are_correlated_across_llms() {
        let sc = Scenario::FlashCrowd {
            storms: 2,
            intensity: 40.0,
            jobs_per_llm: 120,
        };
        let jobs = sc.generate(11, 1.0).unwrap();
        // per-LLM top minute must coincide (the storms are shared)
        let top_minute = |llm: Llm| -> usize {
            let mut counts = vec![0usize; 30];
            for j in jobs.iter().filter(|j| j.llm == llm) {
                counts[((j.submit_s / 60.0) as usize).min(29)] += 1;
            }
            (0..30).max_by_key(|&m| counts[m]).unwrap()
        };
        let tops: Vec<usize> = Llm::MAIN.iter().map(|&l| top_minute(l)).collect();
        assert!(
            tops[0] == tops[1] || tops[0] == tops[2] || tops[1] == tops[2],
            "no shared storm minute: {tops:?}"
        );
    }

    #[test]
    fn heavy_tail_durations_exceed_paper_cap() {
        let sc = Scenario::HeavyTail { alpha: 1.1, jobs_per_llm: 400 };
        let jobs = sc.generate(13, 1.0).unwrap();
        let max = jobs.iter().map(|j| j.duration_s).fold(0.0f64, f64::max);
        assert!(max > 360.0, "tail never realized: max {max}");
        assert!(max <= 900.0 + 1e-9);
        let min = jobs.iter().map(|j| j.duration_s).fold(f64::MAX, f64::min);
        assert!(min >= 5.0 - 1e-9);
    }

    #[test]
    fn task_drift_switches_to_novel_tasks_mid_run() {
        let sc = Scenario::TaskDrift {
            drift_at_frac: 0.4,
            novel_tasks: 8,
            jobs_per_llm: 60,
        };
        let jobs = sc.generate(19, 1.0).unwrap();
        let drift_at = sc.window_s().unwrap() * 0.4;
        let mut pre = 0usize;
        let mut post = 0usize;
        let mut novel_seen = std::collections::BTreeSet::new();
        for j in &jobs {
            if j.submit_s >= drift_at {
                post += 1;
                assert!(j.task_id >= NOVEL_TASK_BASE,
                        "post-drift job {} kept old task {}", j.id, j.task_id);
                assert!(j.task_id < NOVEL_TASK_BASE + 8);
                novel_seen.insert(j.task_id);
            } else {
                pre += 1;
                assert!(j.task_id < NOVEL_TASK_BASE,
                        "pre-drift job {} has novel task {}", j.id, j.task_id);
            }
        }
        // both regimes are populated, and the novel range is exercised
        assert!(pre > 20 && post > 20, "pre {pre} post {post}");
        assert!(novel_seen.len() >= 4, "novel tasks {novel_seen:?}");
        // drifted jobs repeat novel tasks (the recovery flywheel needs
        // same-task repeats within each LLM's bank)
        assert!(post > novel_seen.len() * 3);
    }

    #[test]
    fn chaos_profiles_exist_exactly_for_chaos_families() {
        let mut chaos_names = vec![];
        for sc in Scenario::catalogue() {
            let is_chaos = matches!(sc, Scenario::Chaos { .. });
            let profile = sc.chaos_profile();
            assert_eq!(profile.is_some(), is_chaos, "{}", sc.name());
            if let Some(p) = profile {
                p.validate().unwrap_or_else(|e| {
                    panic!("{}: invalid profile: {e}", sc.name())
                });
                chaos_names.push(sc.name());
            }
        }
        assert_eq!(chaos_names,
                   vec!["chaos-latency", "chaos-flaky", "chaos-storm"]);
    }

    #[test]
    fn multi_tenant_spans_slo_tiers() {
        let sc = Scenario::MultiTenant { tenants: 4, jobs_per_tenant: 40 };
        let jobs = sc.generate(17, 1.0).unwrap();
        // implied emergence S = (slo − cold_start) / duration clusters
        // around the four tier factors
        let perf = PerfModel::default();
        let mut implied: Vec<f64> = jobs
            .iter()
            .map(|j| (j.slo_s - perf.cold_start(j.llm)) / j.duration_s)
            .collect();
        implied.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let lo = implied.first().unwrap();
        let hi = implied.last().unwrap();
        assert!((lo - 0.5).abs() < 1e-9, "{lo}");
        assert!((hi - 2.0).abs() < 1e-9, "{hi}");
    }
}
