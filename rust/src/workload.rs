//! Core domain types shared by the simulator, schedulers, and benches:
//! LLM variants, the calibrated performance/cost model, and LPT job specs.

use anyhow::{anyhow, Result};

/// Price of one GPU-second, from AWS p4de.24xlarge ($40.9664/h for 8
/// A100-80GB) — the paper's cost basis (§6.1).
pub const GPU_PRICE_PER_S: f64 = 40.9664 / 8.0 / 3600.0;

/// ElastiCache storage price per GB-hour (communication channel billing).
pub const STORAGE_PRICE_PER_GB_H: f64 = 0.125;

/// Prompt-gradient payload per sync step, GB (tiny: [P, D] f32 per worker).
pub const COMM_PAYLOAD_GB: f64 = 1e-4;

/// The LLMs served by the cluster. The first three have real AOT artifacts
/// (scaled-down stand-ins, see DESIGN.md); the last two are simulator-only
/// variants used by the paper's heavy-workload evaluation (Table 7).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Llm {
    Gpt2B,
    Gpt2L,
    V7B,
    Llama30B,
    Qwen7BR1,
}

/// Number of LLM variants (dense [`Llm::index`] range), for array-indexed
/// per-LLM state. Alias of [`Llm::COUNT`], kept for existing call sites.
pub const N_LLM: usize = Llm::COUNT;

impl Llm {
    /// Number of variants. Every per-LLM lookup table in the crate is
    /// sized `[T; Llm::COUNT]`, so adding a variant (which forces this
    /// constant and the `index` match to grow) fails to compile at each
    /// stale table instead of panicking at runtime on the new index.
    pub const COUNT: usize = 5;

    pub const ALL: [Llm; Llm::COUNT] =
        [Llm::Gpt2B, Llm::Gpt2L, Llm::V7B, Llm::Llama30B, Llm::Qwen7BR1];

    /// The three LLMs of the paper's main end-to-end experiments (Fig 7/8).
    pub const MAIN: [Llm; 3] = [Llm::Gpt2B, Llm::Gpt2L, Llm::V7B];

    pub fn name(self) -> &'static str {
        match self {
            Llm::Gpt2B => "gpt2-base",
            Llm::Gpt2L => "gpt2-large",
            Llm::V7B => "vicuna-7b",
            Llm::Llama30B => "llama-30b",
            Llm::Qwen7BR1 => "qwen7b-r1",
        }
    }

    pub fn from_name(s: &str) -> Result<Llm> {
        Llm::ALL
            .into_iter()
            .find(|l| l.name() == s || l.artifact_variant() == Some(s))
            .ok_or_else(|| anyhow!("unknown LLM '{s}'"))
    }

    /// Name of the AOT artifact variant backing this LLM, if any.
    pub fn artifact_variant(self) -> Option<&'static str> {
        match self {
            Llm::Gpt2B => Some("sim-gpt2b"),
            Llm::Gpt2L => Some("sim-gpt2l"),
            Llm::V7B => Some("sim-v7b"),
            _ => None,
        }
    }

    /// GPUs per model replica (tensor parallelism), §6.2: LLaMA-30B and
    /// Qwen7B-R1 are hosted on 4 GPUs each.
    pub fn gpus_per_replica(self) -> usize {
        match self {
            Llm::Llama30B | Llm::Qwen7BR1 => 4,
            _ => 1,
        }
    }

    /// Dense index for array-indexed per-LLM state.
    pub fn index(self) -> usize {
        match self {
            Llm::Gpt2B => 0,
            Llm::Gpt2L => 1,
            Llm::V7B => 2,
            Llm::Llama30B => 3,
            Llm::Qwen7BR1 => 4,
        }
    }
}

/// Calibrated performance model: per-iteration times, allocation
/// overheads, and the multi-GPU scaling law. Defaults follow DESIGN.md's
/// calibration targets; `calibrate` (runtime measurements) can override
/// the iteration times for the artifact-backed variants.
#[derive(Clone, Debug)]
pub struct PerfModel {
    /// Seconds per tuning iteration on one replica (indexed by Llm).
    pub iter_time_1: [f64; Llm::COUNT],
    /// Cold allocation overhead: container + framework + GPU runtime +
    /// weight load (37–41 % of mean exec time per Fig 2a).
    pub cold_start_s: [f64; Llm::COUNT],
    /// Warm allocation: rendezvous/IP-connect per multi-GPU group (§5.1).
    pub warm_connect_s: f64,
    /// Synchronous-communication overhead fraction per extra replica
    /// (Fig 2a: total comm 0.4–0.5 % of execution time).
    pub comm_frac_per_replica: f64,
}

impl Default for PerfModel {
    fn default() -> Self {
        PerfModel {
            // gpt2b, gpt2l, v7b, llama30b, qwen7b-r1
            iter_time_1: [0.12, 0.35, 1.10, 4.2, 1.6],
            cold_start_s: [18.0, 24.0, 40.0, 75.0, 42.0],
            warm_connect_s: 2.0,
            comm_frac_per_replica: 0.005,
        }
    }
}

impl PerfModel {
    /// Seconds per iteration when the job runs on `gpus` GPUs. GPUs are
    /// grouped into replicas of `gpus_per_replica`; data-parallel replicas
    /// scale nearly linearly with a small synchronous-comm penalty.
    pub fn iter_time(&self, llm: Llm, gpus: usize) -> f64 {
        let per = llm.gpus_per_replica();
        let replicas = (gpus / per).max(1) as f64;
        let base = self.iter_time_1[llm.index()];
        base / replicas * (1.0 + self.comm_frac_per_replica * (replicas - 1.0))
    }

    pub fn cold_start(&self, llm: Llm) -> f64 {
        self.cold_start_s[llm.index()]
    }

    /// Execution time for `iters` iterations at `gpus` GPUs.
    pub fn exec_time(&self, llm: Llm, iters: f64, gpus: usize) -> f64 {
        iters * self.iter_time(llm, gpus)
    }
}

/// Iterations-to-accuracy multiplier as a function of initial-prompt
/// quality q in [0, 1]. Calibrated to Fig 2c: best prompt = 1×, median
/// ≈ 1.7–2×, worst ≈ 4.5×.
pub const ITA_MAX_MULT: f64 = 4.5;
pub fn ita_multiplier(quality: f64) -> f64 {
    let q = quality.clamp(0.0, 1.0);
    1.0 + (ITA_MAX_MULT - 1.0) * (1.0 - q).powf(1.3)
}

/// Prompt quality of the median user-supplied initial prompt; traced job
/// durations are assumed to reflect this quality (DESIGN.md).
pub const MEDIAN_USER_QUALITY: f64 = 0.55;

/// One LPT request as submitted by a user (paper Table 3).
#[derive(Clone, Debug)]
pub struct JobSpec {
    pub id: usize,
    pub llm: Llm,
    /// Synthetic task id in the task universe (stand-in for Table 6).
    pub task_id: usize,
    /// Submission time, seconds from experiment start.
    pub submit_s: f64,
    /// Traced duration (seconds) at the traced GPU count — defines work.
    pub duration_s: f64,
    /// Traced number of allocated GPUs.
    pub traced_gpus: usize,
    /// Iterations needed with the *best* initial prompt (quality 1.0).
    pub base_iters: f64,
    /// Quality of the user-supplied initial prompt.
    pub user_prompt_quality: f64,
    /// Latency SLO in seconds (duration × S + allocation overhead, §6.1).
    pub slo_s: f64,
}

impl JobSpec {
    /// Iterations this job needs when started from a prompt of quality q.
    pub fn iters_at(&self, quality: f64) -> f64 {
        self.base_iters * ita_multiplier(quality)
    }

    /// Absolute SLO deadline.
    pub fn deadline(&self) -> f64 {
        self.submit_s + self.slo_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn llm_roundtrip_names() {
        for llm in Llm::ALL {
            assert_eq!(Llm::from_name(llm.name()).unwrap(), llm);
        }
        assert!(Llm::from_name("nope").is_err());
        assert_eq!(Llm::from_name("sim-v7b").unwrap(), Llm::V7B);
    }

    #[test]
    fn indices_are_dense_and_unique() {
        let mut seen = [false; Llm::COUNT];
        for llm in Llm::ALL {
            assert!(!seen[llm.index()]);
            seen[llm.index()] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn iter_time_scales_nearly_linearly() {
        let pm = PerfModel::default();
        let t1 = pm.iter_time(Llm::V7B, 1);
        let t4 = pm.iter_time(Llm::V7B, 4);
        assert!(t4 < t1 / 3.5, "expected near-linear speedup: {t1} -> {t4}");
        assert!(t4 > t1 / 4.0, "comm penalty must be positive");
    }

    #[test]
    fn tensor_parallel_replicas_use_gpu_groups() {
        let pm = PerfModel::default();
        // 4 GPUs = one llama replica: no data-parallel speedup.
        assert_eq!(pm.iter_time(Llm::Llama30B, 4), pm.iter_time_1[3]);
        // 8 GPUs = two replicas.
        let t8 = pm.iter_time(Llm::Llama30B, 8);
        assert!(t8 < pm.iter_time_1[3] / 1.9);
    }

    #[test]
    fn comm_fraction_is_under_one_percent() {
        // Fig 2a: comm is 0.4–0.5 % of execution; our model keeps the
        // penalty in that range for small replica counts.
        let pm = PerfModel::default();
        let t1 = pm.iter_time(Llm::Gpt2B, 1);
        let t2 = pm.iter_time(Llm::Gpt2B, 2);
        let overhead = t2 * 2.0 / t1 - 1.0;
        assert!(overhead > 0.0 && overhead < 0.01, "{overhead}");
    }

    #[test]
    fn ita_multiplier_matches_fig2c_span() {
        assert!((ita_multiplier(1.0) - 1.0).abs() < 1e-12);
        assert!((ita_multiplier(0.0) - ITA_MAX_MULT).abs() < 1e-12);
        let med = ita_multiplier(MEDIAN_USER_QUALITY);
        assert!((1.7..=2.6).contains(&med), "median multiplier {med}");
        // monotone decreasing in quality
        let mut prev = f64::INFINITY;
        for i in 0..=10 {
            let m = ita_multiplier(i as f64 / 10.0);
            assert!(m <= prev);
            prev = m;
        }
    }

    #[test]
    fn job_spec_iters_and_deadline() {
        let spec = JobSpec {
            id: 0,
            llm: Llm::Gpt2B,
            task_id: 3,
            submit_s: 10.0,
            duration_s: 60.0,
            traced_gpus: 2,
            base_iters: 100.0,
            user_prompt_quality: 0.5,
            slo_s: 90.0,
        };
        assert!((spec.deadline() - 100.0).abs() < 1e-12);
        assert!(spec.iters_at(0.5) > spec.iters_at(0.9));
        assert!((spec.iters_at(1.0) - 100.0).abs() < 1e-9);
    }

    #[test]
    fn gpu_price_sane() {
        // ~$5.12 per GPU-hour
        assert!((GPU_PRICE_PER_S * 3600.0 - 5.1208).abs() < 1e-3);
    }
}
