//! A real (non-simulated) miniature of the serving plane: worker threads
//! stand in for GPUs, each owning at most one loaded [`ModelRuntime`];
//! loading (PJRT compile + weight upload) is the *real, measured* cold
//! start, and routing jobs to a worker that already holds the right model
//! is the *real* runtime reusing of the paper. Python is never involved —
//! workers execute AOT artifacts only.
//!
//! `examples/cluster_serving.rs` drives this engine over a trace and
//! reports warm/cold start times and SLO attainment.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::promptbank::TwoLayerBank;
use crate::runtime::{ModelRuntime, RuntimeScorer};
use crate::tuning::data::TaskUniverse;
use crate::tuning::trainer::{Trainer, TrainerConfig};
use crate::util::manifest::Manifest;

/// One real LPT request.
#[derive(Clone, Debug)]
pub struct ServeJob {
    pub id: usize,
    /// Artifact variant name (e.g. "sim-gpt2b").
    pub variant: String,
    pub task_id: usize,
    /// Initial prompt candidate tokens (length = prompt_len) — the user's
    /// own prompt; replaced by the bank's pick when `use_bank` is set.
    pub init_tokens: Vec<i32>,
    /// Route through the Prompt Bank first (the caller applies the 20 %
    /// latency budget, §4.4.3).
    pub use_bank: bool,
    pub target_loss: f32,
    pub max_iters: usize,
    pub lr: f32,
}

/// Completion record of one request.
#[derive(Clone, Debug)]
pub struct ServeOutcome {
    pub id: usize,
    pub worker: usize,
    /// Seconds spent loading the model (0 when served warm).
    pub cold_start_s: f64,
    /// Seconds spent on the Prompt Bank lookup (0 when skipped).
    pub bank_s: f64,
    /// Eqn.-1 evaluations the lookup performed.
    pub bank_evals: usize,
    /// Seconds spent tuning.
    pub tune_s: f64,
    pub iters: usize,
    pub reached_target: bool,
    pub final_loss: f32,
}

enum Msg {
    Run(ServeJob),
    Shutdown,
}

struct Worker {
    tx: Sender<Msg>,
    handle: JoinHandle<()>,
    /// Variant currently loaded on the worker (engine's routing view).
    loaded: Option<String>,
    /// Jobs dispatched and not yet collected.
    inflight: Arc<AtomicUsize>,
}

/// The serving engine: dispatcher + worker threads.
pub struct ServeEngine {
    workers: Vec<Worker>,
    result_rx: Receiver<ServeOutcome>,
    outstanding: usize,
}

impl ServeEngine {
    /// Spawn `n_workers` threads. Each worker lazily loads model variants
    /// on first use (the measured cold start). `bank` (if provided) is a
    /// pre-built two-layer Prompt Bank shared by all workers — jobs with
    /// `use_bank` run a real lookup on their worker before tuning (the
    /// paper's sequential bank-then-LPT execution, §5.2).
    pub fn start(artifacts_dir: impl Into<std::path::PathBuf>,
                 n_workers: usize, uni: Arc<TaskUniverse>,
                 bank: Option<Arc<TwoLayerBank>>) -> Result<ServeEngine> {
        let dir = artifacts_dir.into();
        let (result_tx, result_rx) = channel::<ServeOutcome>();
        let mut workers = vec![];
        for wid in 0..n_workers {
            let (tx, rx) = channel::<Msg>();
            let res_tx = result_tx.clone();
            let dir = dir.clone();
            let uni = uni.clone();
            let bank = bank.clone();
            let inflight = Arc::new(AtomicUsize::new(0));
            let inflight_w = inflight.clone();
            let handle = std::thread::spawn(move || {
                worker_loop(wid, &dir, &uni, bank, rx, res_tx, inflight_w);
            });
            workers.push(Worker { tx, handle, loaded: None, inflight });
        }
        Ok(ServeEngine { workers, result_rx, outstanding: 0 })
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Dispatch a job: prefer an idle worker that already holds the
    /// variant (warm), then any idle worker, then the least-loaded one.
    pub fn submit(&mut self, job: ServeJob) -> Result<()> {
        let variant = job.variant.clone();
        let pick = self
            .workers
            .iter()
            .enumerate()
            .filter(|(_, w)| w.inflight.load(Ordering::SeqCst) == 0
                    && w.loaded.as_deref() == Some(variant.as_str()))
            .map(|(i, _)| i)
            .next()
            .or_else(|| {
                self.workers
                    .iter()
                    .enumerate()
                    .filter(|(_, w)| w.inflight.load(Ordering::SeqCst) == 0)
                    .map(|(i, _)| i)
                    .next()
            })
            .unwrap_or_else(|| {
                // least loaded
                let mut best = 0;
                let mut load = usize::MAX;
                for (i, w) in self.workers.iter().enumerate() {
                    let l = w.inflight.load(Ordering::SeqCst);
                    if l < load {
                        load = l;
                        best = i;
                    }
                }
                best
            });
        let w = &mut self.workers[pick];
        w.inflight.fetch_add(1, Ordering::SeqCst);
        w.loaded = Some(variant);
        w.tx.send(Msg::Run(job)).map_err(|_| anyhow!("worker {pick} gone"))?;
        self.outstanding += 1;
        Ok(())
    }

    /// Collect `n` completed jobs (blocking).
    pub fn collect(&mut self, n: usize) -> Result<Vec<ServeOutcome>> {
        let mut out = vec![];
        for _ in 0..n.min(self.outstanding) {
            out.push(self.result_rx.recv().map_err(|_| anyhow!("workers gone"))?);
            self.outstanding -= 1;
        }
        Ok(out)
    }

    /// Drain everything outstanding.
    pub fn collect_all(&mut self) -> Result<Vec<ServeOutcome>> {
        self.collect(usize::MAX)
    }

    /// Stop all workers.
    pub fn shutdown(self) {
        for w in &self.workers {
            let _ = w.tx.send(Msg::Shutdown);
        }
        for w in self.workers {
            let _ = w.handle.join();
        }
    }
}

fn worker_loop(
    wid: usize,
    dir: &std::path::Path,
    uni: &TaskUniverse,
    bank: Option<Arc<TwoLayerBank>>,
    rx: Receiver<Msg>,
    res_tx: Sender<ServeOutcome>,
    inflight: Arc<AtomicUsize>,
) {
    let manifest = match Manifest::load(dir) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("worker {wid}: manifest load failed: {e}");
            return;
        }
    };
    let mut loaded: Option<(String, ModelRuntime)> = None;
    while let Ok(msg) = rx.recv() {
        let job = match msg {
            Msg::Run(j) => j,
            Msg::Shutdown => break,
        };
        // --- cold start when the wrong (or no) model is resident ---
        let mut cold_start_s = 0.0;
        let need_load =
            loaded.as_ref().map(|(v, _)| v != &job.variant).unwrap_or(true);
        if need_load {
            match ModelRuntime::load(&manifest, &job.variant) {
                Ok(rt) => {
                    cold_start_s = rt.load_time_s;
                    loaded = Some((job.variant.clone(), rt));
                }
                Err(e) => {
                    eprintln!("worker {wid}: load {} failed: {e}", job.variant);
                    inflight.fetch_sub(1, Ordering::SeqCst);
                    continue;
                }
            }
        }
        let rt = &loaded.as_ref().unwrap().1;
        let trainer = Trainer::new(
            rt,
            uni,
            TrainerConfig {
                lr: job.lr,
                max_iters: job.max_iters,
                eval_every: 10,
                seed: job.id as u64 + 1,
            },
        );
        // --- Prompt Bank lookup (sequential with the job, §5.2) ---
        let mut init_tokens = job.init_tokens.clone();
        let mut bank_s = 0.0;
        let mut bank_evals = 0;
        if job.use_bank {
            if let Some(bank) = bank.as_deref() {
                let (etoks, etgts) = trainer.eval_batch(job.task_id);
                let mut scorer = RuntimeScorer::new(rt, etoks, etgts);
                let tb = Instant::now();
                let pick = bank.lookup(&mut scorer);
                bank_s = tb.elapsed().as_secs_f64();
                bank_evals = pick.evals;
                init_tokens = bank.candidate(pick.best).tokens.clone();
            }
        }
        let t0 = Instant::now();
        let outcome = trainer.tune(job.task_id, &init_tokens, job.target_loss);
        let tune_s = t0.elapsed().as_secs_f64();
        inflight.fetch_sub(1, Ordering::SeqCst);
        match outcome {
            Ok(o) => {
                let _ = res_tx.send(ServeOutcome {
                    id: job.id,
                    worker: wid,
                    cold_start_s,
                    bank_s,
                    bank_evals,
                    tune_s,
                    iters: o.iters,
                    reached_target: o.reached_target,
                    final_loss: o.final_eval_loss,
                });
            }
            Err(e) => eprintln!("worker {wid}: job {} failed: {e}", job.id),
        }
    }
}
