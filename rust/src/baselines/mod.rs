//! Baseline cluster-management systems the paper compares against (§3, §6):
//!
//! * [`infless`] — an INFless-like SLO-aware serverless *inference* system:
//!   per-model instance autoscaling with keep-alive, one GPU per instance,
//!   extended (as in the paper, §5.1) with Memcached-style synchronous
//!   multi-instance execution. Its weakness: per-instance initialization is
//!   independent, so a multi-GPU job waits for its slowest instance
//!   (Fig 3b) and there is no globally optimal schedule.
//! * [`elasticflow`] — an ElasticFlow-like SLO-aware elastic *training*
//!   system: a statically provisioned fixed-size cluster (billed 24/7,
//!   Fig 3a: ~56 % utilization), deadline-driven elastic allocation, and no
//!   runtime reuse — every (re)allocation pays the full cold start.
//!
//! For fairness the paper grafts the Prompt Bank onto both baselines; the
//! shared [`BankRouter`] reproduces that.

pub mod elasticflow;
pub mod infless;

pub use elasticflow::{ElasticFlow, ElasticFlowConfig};
pub use infless::{Infless, InflessConfig};

use crate::promptbank::BankModel;
use crate::util::rng::Rng;
use crate::workload::JobSpec;

/// Prompt-Bank routing shared by the baselines (the paper reinforces both
/// baselines with the bank; they inherit the same 20 % latency budget).
#[derive(Clone, Debug)]
pub struct BankRouter {
    pub enabled: bool,
    pub budget_frac: f64,
    pub model: BankModel,
    pub est_quality: f64,
}

impl Default for BankRouter {
    fn default() -> Self {
        BankRouter {
            enabled: true,
            budget_frac: 0.2,
            model: BankModel::default(),
            est_quality: 0.85,
        }
    }
}

impl BankRouter {
    /// Decide at arrival: (use_bank, bank_latency).
    pub fn route(&self, spec: &JobSpec) -> (bool, f64) {
        if !self.enabled {
            return (false, 0.0);
        }
        let lat = self.model.lookup_latency(spec.llm);
        if lat <= self.budget_frac * spec.slo_s {
            (true, lat)
        } else {
            (false, 0.0)
        }
    }

    /// Realize quality at launch.
    pub fn realize(&self, spec: &JobSpec, use_bank: bool, rng: &mut Rng) -> f64 {
        if use_bank {
            self.model.draw_quality(rng).max(spec.user_prompt_quality)
        } else {
            spec.user_prompt_quality
        }
    }

    /// Quality to assume in completion-time predictions.
    pub fn estimate(&self, spec: &JobSpec, use_bank: bool) -> f64 {
        if use_bank {
            spec.user_prompt_quality.max(self.est_quality)
        } else {
            spec.user_prompt_quality
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Llm;

    fn spec(slo: f64) -> JobSpec {
        JobSpec {
            id: 0,
            llm: Llm::Gpt2B,
            task_id: 0,
            submit_s: 0.0,
            duration_s: 10.0,
            traced_gpus: 1,
            base_iters: 10.0,
            user_prompt_quality: 0.5,
            slo_s: slo,
        }
    }

    #[test]
    fn router_respects_budget() {
        let r = BankRouter::default();
        // gpt2-base lookup ≈ 5.3 s; budget 20 % => SLO must be ≥ ~26.4 s
        let (use_short, _) = r.route(&spec(10.0));
        assert!(!use_short);
        let (use_long, lat) = r.route(&spec(120.0));
        assert!(use_long);
        assert!(lat > 1.0);
    }

    #[test]
    fn disabled_router_never_uses_bank() {
        let r = BankRouter { enabled: false, ..Default::default() };
        assert_eq!(r.route(&spec(1e9)), (false, 0.0));
    }

    #[test]
    fn realize_respects_user_floor() {
        let r = BankRouter::default();
        let mut rng = Rng::new(1);
        let mut s = spec(100.0);
        s.user_prompt_quality = 0.97;
        for _ in 0..100 {
            assert!(r.realize(&s, true, &mut rng) >= 0.97);
        }
        assert_eq!(r.realize(&s, false, &mut rng), 0.97);
    }

    #[test]
    fn estimate_is_conservative() {
        let r = BankRouter::default();
        let s = spec(100.0);
        assert_eq!(r.estimate(&s, true), 0.85);
        assert_eq!(r.estimate(&s, false), 0.5);
    }
}
