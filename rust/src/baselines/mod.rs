//! Baseline cluster-management systems the paper compares against (§3, §6):
//!
//! * [`infless`] — an INFless-like SLO-aware serverless *inference* system:
//!   per-model instance autoscaling with keep-alive, one GPU per instance,
//!   extended (as in the paper, §5.1) with Memcached-style synchronous
//!   multi-instance execution. Its weakness: per-instance initialization is
//!   independent, so a multi-GPU job waits for its slowest instance
//!   (Fig 3b) and there is no globally optimal schedule.
//! * [`elasticflow`] — an ElasticFlow-like SLO-aware elastic *training*
//!   system: a statically provisioned fixed-size cluster (billed 24/7,
//!   Fig 3a: ~56 % utilization), deadline-driven elastic allocation, and no
//!   runtime reuse — every (re)allocation pays the full cold start.
//!
//! For fairness the paper grafts the Prompt Bank onto both baselines; the
//! shared [`BankRouter`] reproduces that.

pub mod elasticflow;
pub mod infless;

pub use elasticflow::{ElasticFlow, ElasticFlowConfig};
pub use infless::{Infless, InflessConfig};

use crate::promptbank::{SimBankConfig, SimBankSet, TUNED_PROMPT_QUALITY};
use crate::workload::{JobSpec, Llm};

/// Prompt-Bank routing shared by the baselines (the paper reinforces both
/// baselines with the bank; they inherit the same 20 % latency budget).
/// The router is pure policy math over a [`SimBankSet`] the baseline
/// owns — the same stateful per-LLM banks (built through
/// [`BankRouter::build`]) the PromptTuner scheduler uses, so quality is a
/// deterministic function of coverage state and completed jobs feed tuned
/// prompts back through [`BankRouter::complete`].
#[derive(Clone, Debug)]
pub struct BankRouter {
    pub enabled: bool,
    pub budget_frac: f64,
    /// Bank construction parameters (`induction: true` swaps in the
    /// induction baseline behind the same interface).
    pub cfg: SimBankConfig,
}

impl Default for BankRouter {
    fn default() -> Self {
        BankRouter {
            enabled: true,
            budget_frac: 0.2,
            cfg: SimBankConfig::default(),
        }
    }
}

impl BankRouter {
    /// Build the per-LLM bank state this router routes over
    /// (bit-deterministic in `seed`).
    pub fn build(&self, seed: u64) -> SimBankSet {
        SimBankSet::new(&self.cfg, seed)
    }

    /// Decide at arrival: (use_bank, bank_latency). Latency follows the
    /// live two-layer shape, so it responds to bank growth/shrinking.
    pub fn route(&self, banks: &SimBankSet, spec: &JobSpec) -> (bool, f64) {
        if !self.enabled {
            return (false, 0.0);
        }
        let lat = banks.lookup_latency(spec.llm);
        if lat <= self.budget_frac * spec.slo_s {
            (true, lat)
        } else {
            (false, 0.0)
        }
    }

    /// Quality the bank delivers for this job *right now* — used both in
    /// completion-time predictions and at launch (the coverage state is
    /// the realized quality; there is no draw, so estimates and launches
    /// agree by construction).
    pub fn quality(&self, banks: &SimBankSet, spec: &JobSpec,
                   use_bank: bool) -> f64 {
        if use_bank {
            banks
                .quality_for(spec.llm, spec.task_id)
                .max(spec.user_prompt_quality)
        } else {
            spec.user_prompt_quality
        }
    }

    /// Completion feedback (Fig 5b): the finished job's tuned prompt
    /// flows back into its LLM's bank. Returns whether a prompt was
    /// actually inserted (false when the router is disabled), so gossiping
    /// callers know what to log.
    pub fn complete(&self, banks: &mut SimBankSet, llm: Llm, task_id: usize)
                    -> bool {
        if self.enabled {
            banks.insert_tuned(llm, task_id, TUNED_PROMPT_QUALITY);
            true
        } else {
            false
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(slo: f64) -> JobSpec {
        JobSpec {
            id: 0,
            llm: Llm::Gpt2B,
            task_id: 0,
            submit_s: 0.0,
            duration_s: 10.0,
            traced_gpus: 1,
            base_iters: 10.0,
            user_prompt_quality: 0.5,
            slo_s: slo,
        }
    }

    #[test]
    fn router_respects_budget() {
        let r = BankRouter::default();
        let banks = r.build(1);
        // gpt2-base lookup ≈ 5.3 s; budget 20 % => SLO must be ≥ ~26.4 s
        let (use_short, _) = r.route(&banks, &spec(10.0));
        assert!(!use_short);
        let (use_long, lat) = r.route(&banks, &spec(120.0));
        assert!(use_long);
        assert!(lat > 1.0);
    }

    #[test]
    fn disabled_router_never_uses_bank() {
        let r = BankRouter { enabled: false, ..Default::default() };
        let banks = r.build(1);
        assert_eq!(r.route(&banks, &spec(1e9)), (false, 0.0));
    }

    #[test]
    fn quality_respects_user_floor_and_skip() {
        let r = BankRouter::default();
        let banks = r.build(2);
        let mut s = spec(100.0);
        s.user_prompt_quality = 0.99;
        assert!(r.quality(&banks, &s, true) >= 0.99);
        assert_eq!(r.quality(&banks, &s, false), 0.99);
    }

    #[test]
    fn completion_feedback_raises_quality() {
        let r = BankRouter {
            cfg: SimBankConfig::cold(),
            ..Default::default()
        };
        let mut banks = r.build(3);
        let s = spec(100.0);
        let before = r.quality(&banks, &s, true);
        assert_eq!(before, s.user_prompt_quality); // cold bank: user floor
        r.complete(&mut banks, s.llm, s.task_id);
        let after = r.quality(&banks, &s, true);
        assert!(after > 0.9, "feedback did not warm the bank: {after}");
    }
}
