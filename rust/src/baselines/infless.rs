//! INFless-like baseline (§3.2): SLO-aware serverless inference serving
//! with per-model instance pools, keep-alive, and traffic-based
//! autoscaling — extended with synchronous multi-instance execution so a
//! single LPT job can span several 1-GPU instances (the paper's §5.1
//! extension via Memcached).
//!
//! Captured inefficiencies (the paper's "Inefficiency 2"):
//! * each instance initializes independently — a multi-instance job waits
//!   for its slowest instance (up to tens of seconds, Fig 3b);
//! * each model's pool scales independently — no globally optimal
//!   schedule, no cross-LLM GPU sharing, no delay-based planning.

use crate::baselines::BankRouter;
use crate::cluster::{ClusterState, JobStatus, KnobSpec, Policy,
                     RetryEvent, RevokeEvent, TunedPrompt, Wake};
use crate::promptbank::TUNED_PROMPT_QUALITY;
use crate::coordinator::pools::WarmPool;
use crate::promptbank::SimBankSet;
use crate::util::rng::Rng;
use crate::workload::{Llm, N_LLM};

/// INFless configuration.
#[derive(Clone, Debug)]
pub struct InflessConfig {
    /// Provider GPU budget (instances across all models).
    pub max_gpus: usize,
    /// Keep-alive of idle instances (serverless default: 60 s).
    pub keep_alive_s: f64,
    /// Per-job instance cap.
    pub max_gpus_per_job: usize,
    /// Traffic-based autoscaling: pre-warm `autoscale_factor` idle
    /// instances per arrival observed in the trailing window (each model
    /// pool scales independently — no global coordination).
    pub autoscale_factor: f64,
    pub autoscale_window_s: f64,
    pub bank: BankRouter,
    pub seed: u64,
}

impl Default for InflessConfig {
    fn default() -> Self {
        InflessConfig {
            max_gpus: 32,
            keep_alive_s: 60.0,
            max_gpus_per_job: 8,
            autoscale_factor: 0.5,
            autoscale_window_s: 60.0,
            bank: BankRouter::default(),
            seed: 1,
        }
    }
}

/// The INFless-like policy.
pub struct Infless {
    pub cfg: InflessConfig,
    rng: Rng,
    /// Stateful per-LLM Prompt Banks (the paper grafts the bank onto the
    /// baselines for fairness) — same coverage-driven quality and
    /// completion feedback as PromptTuner's, routed by `cfg.bank`.
    banks: SimBankSet,
    /// Per-LLM warm instance pools (keep-alive).
    pools: [WarmPool; N_LLM],
    /// Per-LLM FCFS queues in delivery order (normally submit order; an
    /// admission layer may deliver deferred jobs late). The seed's
    /// per-round stable sort was a no-op and has been dropped.
    pending: [Vec<usize>; N_LLM],
    /// (use_bank, bank_latency) per job id.
    plans: Vec<(bool, f64)>,
    /// Recent arrival timestamps per LLM (autoscaling signal;
    /// time-ordered, stale entries are a prefix).
    arrivals: [Vec<f64>; N_LLM],
    /// Instances currently cold-starting for the pre-warm pool:
    /// (ready_time, llm index).
    warming: Vec<(f64, usize)>,
    /// Failed runs held back until their retry backoff expires:
    /// (not_before, job). Re-delivered FCFS by `on_tick`; the earliest
    /// entry is declared through `next_timed_action` so coalesced runs
    /// wake exactly when a backoff expires.
    retry_holdback: Vec<(f64, usize)>,
    /// State changed since the last round — the next round must run
    /// densely before idle-round coalescing may resume.
    needs_round: bool,
    /// Tuned prompts fed back since the last gossip drain (only recorded
    /// when a shard plane enabled the log — see [`Policy::enable_gossip_log`]).
    gossip_log: Vec<TunedPrompt>,
    gossip_enabled: bool,
    /// Scratch buffer for warming-instance completions (no per-round
    /// allocation).
    scratch_ready: Vec<usize>,
}

impl Infless {
    pub fn new(cfg: InflessConfig) -> Self {
        let rng = Rng::new(cfg.seed);
        let banks = cfg.bank.build(cfg.seed);
        Infless {
            cfg,
            rng,
            banks,
            pools: Default::default(),
            pending: Default::default(),
            plans: vec![],
            arrivals: Default::default(),
            warming: vec![],
            retry_holdback: vec![],
            needs_round: true,
            gossip_log: vec![],
            gossip_enabled: false,
            scratch_ready: vec![],
        }
    }

    fn used_gpus(&self) -> usize {
        let pooled: usize = self.pools.iter().map(|p| p.total()).sum();
        pooled + self.warming.len()
    }

    fn free_budget(&self) -> usize {
        self.cfg.max_gpus.saturating_sub(self.used_gpus())
    }

    fn update_billable(&self, st: &mut ClusterState) {
        st.set_billable(self.used_gpus() as f64);
    }

    /// Try to start `job` now. INFless picks the smallest instance count
    /// meeting the SLO (or the largest available for already-late jobs),
    /// draws per-instance init times, and waits for the slowest.
    fn try_start(&mut self, st: &mut ClusterState, llm: Llm, job: usize) -> bool {
        let li = llm.index();
        let replica = llm.gpus_per_replica();
        let (use_bank, bank_lat) = self.plans[job];
        let spec = &st.jobs[job].spec;
        // Deterministic coverage-state quality: the prediction below and
        // the launch use the same value.
        let q = self.cfg.bank.quality(&self.banks, spec, use_bank);
        let deadline = spec.deadline();
        let warm_free = self.pools[li].free();
        let budget = self.free_budget() + warm_free;
        let cap = self.cfg.max_gpus_per_job.min(budget) / replica * replica;
        if cap == 0 {
            return false;
        }
        // smallest n meeting the SLO under optimistic (warm) init
        let mut n = replica;
        loop {
            let est = st.estimate_completion(
                job, n, st.perf.warm_connect_s, bank_lat, q);
            if est <= deadline || n + replica > cap {
                break;
            }
            n += replica;
        }
        // per-instance init: warm instances connect fast, cold instances
        // pay an independently drawn cold start; the job waits for max.
        let from_warm = warm_free.min(n);
        let from_cold = n - from_warm;
        if from_cold > self.free_budget() {
            return false;
        }
        let mut init = st.perf.warm_connect_s;
        for _ in 0..from_cold {
            let draw = st.perf.cold_start(llm) * self.rng.range_f64(0.7, 1.3);
            init = init.max(draw);
        }
        if from_warm > 0 {
            self.pools[li].allocate(from_warm);
        }
        if from_cold > 0 {
            self.pools[li].add_busy_from_cold(from_cold);
        }
        st.launch(job, n, init, bank_lat, q);
        true
    }
}

impl Policy for Infless {
    fn name(&self) -> &str {
        "infless"
    }

    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        while self.plans.len() <= job_id {
            self.plans.push((false, 0.0));
        }
        let spec = &st.jobs[job_id].spec;
        self.plans[job_id] = self.cfg.bank.route(&self.banks, spec);
        let li = spec.llm.index();
        // FCFS in delivery order. (Deliveries are normally submit-ordered,
        // but an admission layer — `slo::Governed` — may deliver a
        // deferred job after its deadline, so no submit-order invariant
        // is assumed here.)
        self.pending[li].push(job_id);
        self.arrivals[li].push(st.now());
        self.needs_round = true;
        self.update_billable(st);
    }

    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        let job = &st.jobs[job_id];
        let llm = job.spec.llm;
        let task_id = job.spec.task_id;
        let gpus = (job.gpu_seconds
            / (job.completed_at - job.launched_at).max(1e-9))
            .round() as usize;
        self.pools[llm.index()].release(gpus, st.now());
        // Completion feedback: the tuned prompt flows back into the bank.
        if self.cfg.bank.complete(&mut self.banks, llm, task_id)
            && self.gossip_enabled
        {
            self.gossip_log.push(TunedPrompt {
                llm,
                task_id,
                quality: TUNED_PROMPT_QUALITY,
            });
        }
        self.needs_round = true;
        self.update_billable(st);
    }

    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        // The attempt's instances return to keep-alive — the hardware is
        // fine, only the tuning result was rejected. No bank feedback:
        // the failed run produced no usable tuned prompt.
        let li = st.jobs[ev.job_id].spec.llm.index();
        self.pools[li].release(ev.gpus, st.now());
        // Hold the job back until its backoff expires, then re-deliver.
        self.retry_holdback.push((ev.not_before, ev.job_id));
        self.needs_round = true;
        self.update_billable(st);
    }

    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        let now = st.now();
        for v in &ev.victims {
            let li = st.jobs[v.job_id].spec.llm.index();
            // Failed instances leave the model pool; the victim's
            // surviving instances return to keep-alive.
            self.pools[li].lose_busy(v.failed);
            self.pools[li].release(v.held - v.failed, now);
            // Re-deliver the preempted job (FCFS in delivery order).
            self.pending[li].push(v.job_id);
        }
        // Failed instances beyond the victims hit idle keep-alive
        // capacity first, then cancel in-flight pre-warm cold starts
        // (those GPUs are gone too).
        let mut need = ev.idle_gpus_lost;
        for pool in self.pools.iter_mut() {
            if need == 0 {
                break;
            }
            need -= pool.lose_idle(need);
        }
        while need > 0 && self.warming.pop().is_some() {
            need -= 1;
        }
        self.needs_round = true;
        self.update_billable(st);
    }

    fn on_tick(&mut self, st: &mut ClusterState) {
        let now = st.now();
        // Track whether this round changed anything: a changed round may
        // enable follow-up work next round (e.g. a warm launch shrinking
        // `free` below the autoscale target), so coalescing only resumes
        // after a round that proves itself a no-op.
        let mut changed = false;
        // release held-back retries whose backoff expired (FCFS
        // re-delivery, like a fresh arrival)
        if !self.retry_holdback.is_empty() {
            let mut i = 0;
            while i < self.retry_holdback.len() {
                let (t, j) = self.retry_holdback[i];
                if t <= now {
                    self.retry_holdback.swap_remove(i);
                    let li = st.jobs[j].spec.llm.index();
                    self.pending[li].push(j);
                    changed = true;
                } else {
                    i += 1;
                }
            }
        }
        // keep-alive expiry (independent per model pool)
        for pool in self.pools.iter_mut() {
            if pool.expire_idle(now, self.cfg.keep_alive_s) > 0 {
                changed = true;
            }
        }
        // finish pre-warm cold starts
        let mut ready = std::mem::take(&mut self.scratch_ready);
        ready.clear();
        self.warming.retain(|&(t, li)| {
            if t <= now {
                ready.push(li);
                false
            } else {
                true
            }
        });
        for &li in ready.iter() {
            self.pools[li].add_idle_from_cold(1, now);
            changed = true;
        }
        ready.clear();
        self.scratch_ready = ready;
        // traffic-based autoscaling: pre-warm idle instances per model in
        // proportion to the trailing arrival rate (billed while warming —
        // the serverless cost the paper's Fig 7 cost gap comes from).
        for llm in Llm::ALL {
            let li = llm.index();
            let win = self.cfg.autoscale_window_s;
            // arrivals are time-ordered: stale entries are a prefix
            let stale = self.arrivals[li].partition_point(|&t| now - t > win);
            if stale > 0 {
                self.arrivals[li].drain(..stale);
            }
            let desired =
                (self.arrivals[li].len() as f64 * self.cfg.autoscale_factor).ceil()
                    as usize;
            let warming_here =
                self.warming.iter().filter(|&&(_, l)| l == li).count();
            let have = self.pools[li].free() + warming_here;
            let mut want = desired.saturating_sub(have);
            while want > 0 && self.free_budget() > 0 {
                self.warming.push((now + st.perf.cold_start(llm), li));
                changed = true;
                want -= 1;
            }
        }
        // FCFS per model — no global coordination across LLMs. Launched
        // jobs leave the queue through one status-based compaction pass
        // instead of one retain per launch.
        for llm in Llm::ALL {
            let li = llm.index();
            if self.pending[li].is_empty() {
                continue;
            }
            let mut launched = false;
            let mut i = 0;
            while i < self.pending[li].len() {
                let job = self.pending[li][i];
                if self.try_start(st, llm, job) {
                    launched = true;
                    i += 1;
                } else {
                    break; // FCFS head-of-line blocking
                }
            }
            if launched {
                changed = true;
                let st_ref: &ClusterState = st;
                self.pending[li]
                    .retain(|&j| st_ref.jobs[j].status == JobStatus::Pending);
            }
        }
        self.update_billable(st);
        self.needs_round = changed;
    }

    fn next_timed_action(&self, st: &ClusterState) -> Wake {
        let _ = st;
        if self.needs_round {
            return Wake::Dense;
        }
        if self.pending.iter().any(|q| !q.is_empty()) {
            return Wake::Dense;
        }
        // Empty queues after a no-op round: the next possible actions are
        // a keep-alive expiry (changes billing and the autoscale target)
        // or a pre-warm instance becoming ready (its idle timestamp must
        // be taken at the right round). Starved-wake audit (batch-skip
        // core): keep-alive, pre-warm and retry-holdback expiries are
        // all merged unconditionally below — no early return can drop a
        // due action, so every `retry_not_before` in the future is
        // covered by the returned wake.
        let mut next = f64::INFINITY;
        for pool in &self.pools {
            if let Some(t) = pool.earliest_idle() {
                let expiry = t + self.cfg.keep_alive_s;
                if expiry < next {
                    next = expiry;
                }
            }
        }
        for &(t, _) in &self.warming {
            if t < next {
                next = t;
            }
        }
        for &(t, _) in &self.retry_holdback {
            if t < next {
                next = t;
            }
        }
        if next.is_finite() {
            Wake::At(next)
        } else {
            Wake::Idle
        }
    }

    fn capacity(&self) -> Option<usize> {
        Some(self.cfg.max_gpus)
    }

    fn set_capacity(&mut self, _st: &mut ClusterState, gpus: usize) {
        // Instance-budget knob (driven by `slo::Governed`): billing
        // follows the live pools, so only the ceiling moves; a shrink
        // takes effect as keep-alive expiry and completions drain
        // instances below the new budget.
        self.cfg.max_gpus = gpus;
        self.needs_round = true;
    }

    // Self-tuning declaration (`slo::Tuned`): the instance budget is the
    // one knob this baseline exposes; moving it routes through the same
    // path the governor drives.
    fn knobs(&self) -> Vec<KnobSpec> {
        let base = self.cfg.max_gpus;
        vec![KnobSpec {
            name: "capacity",
            lo: (base / 2).max(1) as f64,
            hi: (base + (base / 4).max(1)) as f64,
            steps: 4,
        }]
    }

    fn knob_value(&self, name: &str) -> Option<f64> {
        match name {
            "capacity" => Some(self.cfg.max_gpus as f64),
            _ => None,
        }
    }

    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        if name == "capacity" {
            self.set_capacity(st, value.round().max(1.0) as usize);
        }
    }

    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        if self.cfg.bank.enabled {
            Some(self.banks.quality_for(llm, task_id))
        } else {
            None
        }
    }

    fn enable_gossip_log(&mut self) {
        self.gossip_enabled = true;
    }

    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        out.append(&mut self.gossip_log);
    }

    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        // Remote prompts are first-hand tunes from other shards: insert,
        // never re-log (each item crosses a shard boundary at most once).
        if self.cfg.bank.enabled {
            for it in items {
                self.banks.insert_tuned(it.llm, it.task_id, it.quality);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SimConfig, Simulator};
    use crate::trace::{Load, TraceConfig, TraceGenerator};
    use crate::workload::PerfModel;

    fn run(cfg: InflessConfig, load: Load, seed: u64) -> crate::cluster::SimResult {
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(load);
        let sim = Simulator::new(
            SimConfig { max_gpus: cfg.max_gpus, ..Default::default() },
            perf,
        );
        let mut policy = Infless::new(cfg);
        sim.run(&mut policy, jobs)
    }

    #[test]
    fn completes_all_jobs() {
        let res = run(InflessConfig::default(), Load::Medium, 21);
        assert_eq!(res.n_done, res.n_jobs);
    }

    #[test]
    fn multi_instance_jobs_pay_init_wait() {
        let res = run(InflessConfig::default(), Load::High, 22);
        // Fig 3b: instance initialization contributes to latency; at least
        // some jobs must show non-trivial init waits.
        let waits: Vec<f64> =
            res.job_latencies.iter().map(|(_, _, w, _)| *w).collect();
        let max_wait = waits.iter().cloned().fold(0.0f64, f64::max);
        assert!(max_wait > 10.0, "max init wait {max_wait}");
    }

    #[test]
    fn keep_alive_bills_idle_instances() {
        let res = run(InflessConfig::default(), Load::Low, 23);
        // billed capacity strictly exceeds busy time because of keep-alive
        assert!(res.gpu_seconds_billed > res.gpu_seconds_busy,
                "billed {} busy {}", res.gpu_seconds_billed, res.gpu_seconds_busy);
    }

    #[test]
    fn respects_gpu_budget() {
        let res = run(InflessConfig { max_gpus: 8, ..Default::default() },
                      Load::High, 24);
        assert_eq!(res.n_done, res.n_jobs);
        // utilization over billed capacity can never exceed 1
        assert!(res.mean_utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(InflessConfig::default(), Load::Low, 25);
        let b = run(InflessConfig::default(), Load::Low, 25);
        assert_eq!(a.n_violations, b.n_violations);
        assert!((a.cost_usd - b.cost_usd).abs() < 1e-9);
    }

    #[test]
    fn coalescing_engages_on_idle_stretches() {
        let res = run(InflessConfig::default(), Load::Low, 26);
        assert_eq!(res.n_done, res.n_jobs);
        assert!(res.rounds_coalesced > 0, "no rounds coalesced");
    }

    #[test]
    fn survives_heavy_tail_scenario_under_oracle() {
        // Pareto durations stress keep-alive/autoscale accounting: a few
        // jobs hold instances for tens of minutes while spikes keep
        // arriving. The collecting oracle audits every executed round.
        use crate::cluster::SimOracle;
        use crate::scenario::Scenario;
        let sc = Scenario::HeavyTail { alpha: 1.1, jobs_per_llm: 50 };
        let jobs = sc.generate(27, 1.0).unwrap();
        let n = jobs.len();
        // widen the horizon: a tail job granted a single GPU can legally
        // run for hours of simulated time
        let sim = Simulator::new(
            SimConfig { max_gpus: 32, horizon_s: 14400.0, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = SimOracle::collecting(Infless::new(InflessConfig {
            max_gpus: 32,
            seed: 27,
            ..Default::default()
        }));
        let res = sim.run(&mut policy, jobs);
        assert_eq!(res.n_done, n);
        assert!(policy.violations().is_empty());
    }
}
