//! ElasticFlow-like baseline (§3.1): an SLO-aware elastic *training*
//! scheduler on a statically provisioned fixed-size GPU cluster.
//!
//! Captured behaviours:
//! * the whole cluster is billed for the entire experiment regardless of
//!   use (the paper's "Inefficiency 1"; Fig 3a shows ~56 % utilization);
//! * deadline-ordered admission with minimum-satisfactory elastic
//!   allocation, growing a running job when it is predicted to miss its
//!   deadline;
//! * **no runtime reuse** — every allocation and every scale-up pays the
//!   full cold start (framework + weights load).

use crate::baselines::BankRouter;
use crate::cluster::{ClusterState, JobStatus, KnobSpec, Policy,
                     RetryEvent, RevokeEvent, TunedPrompt, Wake};
use crate::promptbank::{SimBankSet, TUNED_PROMPT_QUALITY};
use crate::workload::Llm;

/// ElasticFlow configuration.
#[derive(Clone, Debug)]
pub struct ElasticFlowConfig {
    /// Statically provisioned cluster size (all billed, §3.1).
    pub cluster_size: usize,
    pub max_gpus_per_job: usize,
    pub bank: BankRouter,
    pub seed: u64,
}

impl Default for ElasticFlowConfig {
    fn default() -> Self {
        ElasticFlowConfig {
            cluster_size: 32,
            max_gpus_per_job: 8,
            bank: BankRouter::default(),
            seed: 1,
        }
    }
}

/// The ElasticFlow-like policy.
pub struct ElasticFlow {
    pub cfg: ElasticFlowConfig,
    /// Stateful per-LLM Prompt Banks (the paper grafts the bank onto the
    /// baselines for fairness) — same coverage-driven quality and
    /// completion feedback as PromptTuner's, routed by `cfg.bank`.
    banks: SimBankSet,
    /// Admission queue, kept sorted by absolute deadline (ties in
    /// arrival order) — deadlines are static, so sorting at arrival
    /// replaces the seed's per-round sort.
    pending: Vec<usize>,
    busy_gpus: usize,
    plans: Vec<(bool, f64)>,
    started: bool,
    /// Last elastic-rescale time per job (throttles the frequent
    /// reallocation the training scheduler performs, §3.1).
    last_rescale: Vec<f64>,
    /// Failed runs held back until their retry backoff expires:
    /// (not_before, job). Requeued deadline-sorted by `on_tick`; the
    /// earliest entry is declared through `next_timed_action` so
    /// coalesced runs wake exactly when a backoff expires.
    retry_holdback: Vec<(f64, usize)>,
    /// State changed since the last round — the next round must run
    /// densely before idle-round coalescing may resume.
    needs_round: bool,
    /// Tuned prompts fed back since the last gossip drain (only recorded
    /// when a shard plane enabled the log — see [`Policy::enable_gossip_log`]).
    gossip_log: Vec<TunedPrompt>,
    gossip_enabled: bool,
    // ---- reusable scratch buffers ----
    scratch_ids: Vec<usize>,
    scratch_rank: Vec<(f64, usize)>,
}

impl ElasticFlow {
    pub fn new(cfg: ElasticFlowConfig) -> Self {
        let banks = cfg.bank.build(cfg.seed);
        ElasticFlow {
            cfg,
            banks,
            pending: vec![],
            busy_gpus: 0,
            plans: vec![],
            started: false,
            last_rescale: vec![],
            retry_holdback: vec![],
            needs_round: true,
            gossip_log: vec![],
            gossip_enabled: false,
            scratch_ids: vec![],
            scratch_rank: vec![],
        }
    }

    fn free(&self) -> usize {
        self.cfg.cluster_size.saturating_sub(self.busy_gpus)
    }

    /// Launch `job` with the minimum allocation meeting its deadline (or
    /// one replica best-effort if the deadline already passed).
    fn try_start(&mut self, st: &mut ClusterState, job: usize) -> bool {
        let spec = &st.jobs[job].spec;
        let llm = spec.llm;
        let replica = llm.gpus_per_replica();
        let (use_bank, bank_lat) = self.plans[job];
        // Deterministic coverage-state quality: the admission prediction
        // and the launch use the same value.
        let q = self.cfg.bank.quality(&self.banks, spec, use_bank);
        let deadline = spec.deadline();
        let cap = self.cfg.max_gpus_per_job.min(self.free()) / replica * replica;
        if cap == 0 {
            return false;
        }
        let cold = st.perf.cold_start(llm);
        let mut n = replica;
        while st.estimate_completion(job, n, cold, bank_lat, q) > deadline
            && n + replica <= cap
        {
            n += replica;
        }
        let meets =
            st.estimate_completion(job, n, cold, bank_lat, q) <= deadline;
        let expired = deadline < st.now();
        if !meets && !expired {
            // deadline-ordered admission: hold the job, hoping GPUs free
            // up; once the deadline passes it runs best-effort.
            return false;
        }
        let n = if expired { replica } else { n };
        self.busy_gpus += n;
        st.launch(job, n, cold, bank_lat, q);
        true
    }

    /// Collect Running jobs in ascending id order (the order the seed's
    /// full `st.jobs` scan produced) from the cluster's incremental
    /// active-job index, into the reusable scratch buffer.
    ///
    /// Note: jobs only transition Initializing→Running through
    /// `ClusterState::realloc` (i.e. through this policy's own rescale
    /// path), so in practice this set is empty and the elastic paths
    /// below are dormant — faithfully preserving the seed's behavior,
    /// which had the same fixpoint. Kept (cheaply, via the index) so the
    /// baseline's documented elastic machinery stays exercised the
    /// moment job-state bookkeeping ever promotes runners.
    fn collect_running(&mut self, st: &ClusterState,
                       keep: impl Fn(&Self, usize) -> bool) -> Vec<usize> {
        let mut ids = std::mem::take(&mut self.scratch_ids);
        ids.clear();
        for llm in Llm::ALL {
            for &i in st.active_jobs(llm) {
                if st.jobs[i].status == JobStatus::Running && keep(self, i) {
                    ids.push(i);
                }
            }
        }
        ids.sort_unstable();
        ids
    }

    /// Elastic scale-up: grow running jobs predicted to miss deadlines.
    /// Scaling pays the cold start again on the reshaped allocation (no
    /// runtime reuse, §3.1 — the ~1-minute reallocation overhead).
    /// Returns whether any job was rescaled.
    fn rescale_running(&mut self, st: &mut ClusterState) -> bool {
        let now = st.now();
        let mut acted = false;
        let ids = self.collect_running(st, |_, _| true);
        for &id in ids.iter() {
            if self.free() == 0 {
                break;
            }
            let job = &st.jobs[id];
            let llm = job.spec.llm;
            let replica = llm.gpus_per_replica();
            let it = st.eff_iter_time(llm, job.gpus);
            let predicted = job.last_progress_t + job.iters_remaining * it;
            let deadline = job.spec.deadline();
            if predicted <= deadline || deadline < now {
                continue;
            }
            // grow by replicas until predicted to meet (cap by free pool)
            let cold = st.perf.cold_start(llm);
            let cap = self
                .cfg
                .max_gpus_per_job
                .min(job.gpus + self.free())
                / replica
                * replica;
            let mut n = job.gpus + replica;
            let mut found = None;
            while n <= cap {
                let t = now + cold + job.iters_remaining * st.eff_iter_time(llm, n);
                if t <= deadline {
                    found = Some(n);
                    break;
                }
                n += replica;
            }
            if let Some(n) = found {
                let old = st.realloc(id, n, cold);
                self.busy_gpus += n - old;
                self.mark_rescaled(id, now);
                acted = true;
            }
        }
        self.scratch_ids = ids;
        acted
    }

    fn mark_rescaled(&mut self, id: usize, now: f64) {
        while self.last_rescale.len() <= id {
            self.last_rescale.push(f64::NEG_INFINITY);
        }
        self.last_rescale[id] = now;
    }

    fn rescaled_recently(&self, id: usize, now: f64, window: f64) -> bool {
        self.last_rescale.get(id).is_some_and(|&t| now - t < window)
    }

    /// Work-conserving elastic growth: DL training schedulers hand idle
    /// GPUs to running jobs to maximize utilization (§3.1). For LPT this
    /// backfires — each reallocation pays the full runtime reload (tens of
    /// seconds to ~1 min for LLMs), stalling jobs near their deadlines.
    /// Returns whether any job was grown.
    fn greedy_grow(&mut self, st: &mut ClusterState) -> bool {
        let now = st.now();
        if self.free() == 0 {
            return false;
        }
        let mut acted = false;
        // longest predicted remaining work first (stable sort: ties keep
        // ascending-id order, as in the seed's full scan)
        let ids = self.collect_running(st, |s, i| {
            !s.rescaled_recently(i, now, 60.0)
        });
        let mut ranked = std::mem::take(&mut self.scratch_rank);
        ranked.clear();
        for &i in ids.iter() {
            let job = &st.jobs[i];
            let it = st.eff_iter_time(job.spec.llm, job.gpus);
            ranked.push((job.iters_remaining * it, i));
        }
        ranked.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for &(remaining, id) in ranked.iter() {
            if self.free() == 0 {
                break;
            }
            let job = &st.jobs[id];
            let llm = job.spec.llm;
            let replica = llm.gpus_per_replica();
            let cold = st.perf.cold_start(llm);
            // only grow when the remaining work dwarfs the reload cost —
            // the scheduler believes the trade is profitable
            if job.gpus + replica > self.cfg.max_gpus_per_job
                || self.free() < replica
                || remaining < 2.0 * cold
            {
                continue;
            }
            let n = job.gpus + replica;
            let old = st.realloc(id, n, cold);
            self.busy_gpus += n - old;
            self.mark_rescaled(id, now);
            acted = true;
        }
        ranked.clear();
        self.scratch_rank = ranked;
        self.scratch_ids = ids;
        acted
    }
}

impl Policy for ElasticFlow {
    fn name(&self) -> &str {
        "elasticflow"
    }

    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        while self.plans.len() <= job_id {
            self.plans.push((false, 0.0));
        }
        if !self.started {
            // static provisioning: the fixed cluster is billed from the
            // first arrival onward, used or not.
            st.set_billable(self.cfg.cluster_size as f64);
            self.started = true;
        }
        let spec = &st.jobs[job_id].spec;
        self.plans[job_id] = self.cfg.bank.route(&self.banks, spec);
        // Sorted insert by deadline; equal deadlines keep arrival order
        // (matches the stable per-round sort this replaces).
        let dl = spec.deadline();
        let st_ref: &ClusterState = st;
        let pos = self
            .pending
            .partition_point(|&j| st_ref.jobs[j].spec.deadline() <= dl);
        self.pending.insert(pos, job_id);
        self.needs_round = true;
    }

    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        let job = &st.jobs[job_id];
        let llm = job.spec.llm;
        let task_id = job.spec.task_id;
        let gpus = (job.gpu_seconds
            / (job.completed_at - job.launched_at).max(1e-9))
            .round() as usize;
        self.busy_gpus = self.busy_gpus.saturating_sub(gpus);
        // Completion feedback: the tuned prompt flows back into the bank.
        if self.cfg.bank.complete(&mut self.banks, llm, task_id)
            && self.gossip_enabled
        {
            self.gossip_log.push(TunedPrompt {
                llm,
                task_id,
                quality: TUNED_PROMPT_QUALITY,
            });
        }
        self.needs_round = true;
        let _ = st;
    }

    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        // The attempt's GPUs return to the fixed cluster's free capacity
        // — the hardware is fine, only the tuning result was rejected.
        // No bank feedback: the failed run produced no usable prompt.
        self.busy_gpus = self.busy_gpus.saturating_sub(ev.gpus);
        // Hold the job back until its backoff expires, then requeue.
        self.retry_holdback.push((ev.not_before, ev.job_id));
        self.needs_round = true;
        let _ = st;
    }

    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        for v in &ev.victims {
            // The victim's whole allocation returns to the fixed
            // cluster's free capacity; the failed GPUs themselves leave
            // the fleet through the engine's follow-up `set_capacity`
            // (a statically billed cluster has no pools to shed, so
            // `idle_gpus_lost` needs no handling here).
            self.busy_gpus = self.busy_gpus.saturating_sub(v.held);
            // Requeue deadline-sorted, like arrival.
            let dl = st.jobs[v.job_id].spec.deadline();
            let st_ref: &ClusterState = st;
            let pos = self
                .pending
                .partition_point(|&j| st_ref.jobs[j].spec.deadline() <= dl);
            self.pending.insert(pos, v.job_id);
        }
        self.needs_round = true;
    }

    fn on_tick(&mut self, st: &mut ClusterState) {
        let now = st.now();
        // earliest-deadline-first admission (queue kept deadline-sorted
        // at arrival; launched jobs leave it through one status-based
        // compaction pass instead of one retain per launch)
        let mut changed = false;
        // release held-back retries whose backoff expired (deadline-
        // sorted requeue, like arrival/revocation)
        if !self.retry_holdback.is_empty() {
            let mut i = 0;
            while i < self.retry_holdback.len() {
                let (t, j) = self.retry_holdback[i];
                if t <= now {
                    self.retry_holdback.swap_remove(i);
                    let dl = st.jobs[j].spec.deadline();
                    let st_ref: &ClusterState = st;
                    let pos = self.pending.partition_point(|&k| {
                        st_ref.jobs[k].spec.deadline() <= dl
                    });
                    self.pending.insert(pos, j);
                    changed = true;
                } else {
                    i += 1;
                }
            }
        }
        let mut i = 0;
        while i < self.pending.len() {
            let job = self.pending[i];
            if self.try_start(st, job) {
                changed = true;
            }
            i += 1;
        }
        if changed {
            let st_ref: &ClusterState = st;
            self.pending
                .retain(|&j| st_ref.jobs[j].status == JobStatus::Pending);
        }
        changed |= self.rescale_running(st);
        changed |= self.greedy_grow(st);
        self.needs_round = changed;
    }

    fn next_timed_action(&self, st: &ClusterState) -> Wake {
        if self.needs_round {
            return Wake::Dense;
        }
        if !self.pending.is_empty() {
            return Wake::Dense;
        }
        // A held-back retry re-enters the queue at its backoff expiry —
        // even on a fully busy cluster, so the requeue order (and hence
        // coalesced/dense bit-equality) does not depend on when capacity
        // next frees up.
        let mut next = f64::INFINITY;
        for &(t, _) in &self.retry_holdback {
            if t < next {
                next = t;
            }
        }
        // Empty queue and the round that just ran proved itself a no-op:
        // rescale decisions are monotone in time (a plan that misses now
        // misses later), so the only future time-driven action is greedy
        // growth currently suppressed by the 60 s rescale window. Merge
        // every open window's expiry *unconditionally* — an earlier
        // version returned early when `free() == 0`, dropping pending
        // window expiries on a full cluster (a lost wakeup: if the
        // policy's free-capacity bookkeeping ever went stale-zero, the
        // run slept forever past a due growth round; the starved-wake
        // `StateAudit::check_wake` patrols this bug class now). An early
        // wake on a still-full cluster just executes a cheap no-op
        // round, so honesty costs almost nothing.
        let now = st.now();
        for llm in Llm::ALL {
            let replica = llm.gpus_per_replica();
            for &i in st.active_jobs(llm) {
                let job = &st.jobs[i];
                if job.status != JobStatus::Running {
                    continue;
                }
                if job.gpus + replica > self.cfg.max_gpus_per_job {
                    continue;
                }
                let it = st.eff_iter_time(llm, job.gpus);
                if job.iters_remaining * it < 2.0 * st.perf.cold_start(llm) {
                    continue;
                }
                if self.rescaled_recently(i, now, 60.0) {
                    let t = self.last_rescale[i] + 60.0;
                    if t < next {
                        next = t;
                    }
                } else if self.free() >= replica {
                    // An eligible, unsuppressed candidate with capacity
                    // should have been grown by the round that just ran;
                    // stay dense rather than risk divergence.
                    return Wake::Dense;
                }
                // Eligible, out of its window, but capacity-starved:
                // nothing time-driven to merge — growth is blocked on a
                // completion event, which re-queries this hint.
            }
        }
        if next.is_finite() {
            Wake::At(next)
        } else {
            Wake::Idle
        }
    }

    fn capacity(&self) -> Option<usize> {
        Some(self.cfg.cluster_size)
    }

    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        // Statically provisioned cluster (driven by `slo::Governed`): the
        // resized fleet is billed from now on. GPUs currently running
        // jobs cannot be released, so the size clamps to the busy level
        // (preserving busy ≤ billable for the oracle).
        let new = gpus.max(self.busy_gpus);
        self.cfg.cluster_size = new;
        if self.started {
            st.set_billable(new as f64);
        }
        self.needs_round = true;
    }

    // Self-tuning declaration (`slo::Tuned`): the statically-billed
    // cluster size is the one knob this baseline exposes; moving it
    // routes through `set_capacity` (with its busy-level clamp), so a
    // tuned shrink re-bills the smaller fleet immediately.
    fn knobs(&self) -> Vec<KnobSpec> {
        let base = self.cfg.cluster_size;
        vec![KnobSpec {
            name: "capacity",
            lo: (base / 2).max(1) as f64,
            hi: (base + (base / 4).max(1)) as f64,
            steps: 4,
        }]
    }

    fn knob_value(&self, name: &str) -> Option<f64> {
        match name {
            "capacity" => Some(self.cfg.cluster_size as f64),
            _ => None,
        }
    }

    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        if name == "capacity" {
            self.set_capacity(st, value.round().max(1.0) as usize);
        }
    }

    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        if self.cfg.bank.enabled {
            Some(self.banks.quality_for(llm, task_id))
        } else {
            None
        }
    }

    fn enable_gossip_log(&mut self) {
        self.gossip_enabled = true;
    }

    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        out.append(&mut self.gossip_log);
    }

    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        // Remote prompts are first-hand tunes from other shards: insert,
        // never re-log (each item crosses a shard boundary at most once).
        if self.cfg.bank.enabled {
            for it in items {
                self.banks.insert_tuned(it.llm, it.task_id, it.quality);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SimConfig, Simulator};
    use crate::trace::{Load, TraceConfig, TraceGenerator};
    use crate::workload::PerfModel;

    fn run(cfg: ElasticFlowConfig, load: Load, seed: u64) -> crate::cluster::SimResult {
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(load);
        let sim = Simulator::new(
            SimConfig { max_gpus: cfg.cluster_size, ..Default::default() },
            perf,
        );
        let mut policy = ElasticFlow::new(cfg);
        sim.run(&mut policy, jobs)
    }

    #[test]
    fn completes_all_jobs() {
        let res = run(ElasticFlowConfig::default(), Load::Medium, 31);
        assert_eq!(res.n_done, res.n_jobs);
    }

    #[test]
    fn static_provisioning_bills_idle_capacity() {
        let res = run(ElasticFlowConfig::default(), Load::Low, 32);
        // Fig 3a: utilization well below 1 because the full cluster is
        // billed around the clock.
        assert!(res.mean_utilization < 0.9, "util {}", res.mean_utilization);
        assert!(res.gpu_seconds_billed > res.gpu_seconds_busy * 1.1);
    }

    #[test]
    fn every_job_pays_cold_start() {
        let res = run(ElasticFlowConfig::default(), Load::Low, 33);
        let min_wait = res
            .job_latencies
            .iter()
            .map(|(_, _, w, _)| *w)
            .fold(f64::MAX, f64::min);
        // no runtime reuse: even the luckiest job waits a full cold start
        assert!(min_wait >= 18.0 - 1e-6, "min init wait {min_wait}");
    }

    #[test]
    fn respects_cluster_size() {
        let res = run(
            ElasticFlowConfig { cluster_size: 8, ..Default::default() },
            Load::High,
            34,
        );
        assert_eq!(res.n_done, res.n_jobs);
        assert!(res.mean_utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(ElasticFlowConfig::default(), Load::Low, 35);
        let b = run(ElasticFlowConfig::default(), Load::Low, 35);
        assert_eq!(a.n_violations, b.n_violations);
        assert!((a.cost_usd - b.cost_usd).abs() < 1e-9);
    }

    #[test]
    fn coalescing_engages_on_idle_stretches() {
        let res = run(ElasticFlowConfig::default(), Load::Low, 36);
        assert_eq!(res.n_done, res.n_jobs);
        assert!(res.rounds_coalesced > 0, "no rounds coalesced");
    }

    #[test]
    fn survives_multi_tenant_scenario_under_oracle() {
        // Four SLO tiers share the fixed cluster: premium deadlines mix
        // with relaxed ones in the deadline-ordered queue. The collecting
        // oracle audits every executed round.
        use crate::cluster::SimOracle;
        use crate::scenario::Scenario;
        let sc = Scenario::MultiTenant { tenants: 4, jobs_per_tenant: 45 };
        let jobs = sc.generate(37, 1.0).unwrap();
        let n = jobs.len();
        let sim = Simulator::new(
            SimConfig { max_gpus: 32, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = SimOracle::collecting(ElasticFlow::new(ElasticFlowConfig {
            cluster_size: 32,
            seed: 37,
            ..Default::default()
        }));
        let res = sim.run(&mut policy, jobs);
        assert_eq!(res.n_done, n);
        assert!(policy.violations().is_empty());
    }
}
