//! Experiment configuration: a TOML-subset parser (offline environment —
//! no serde) plus typed experiment configs assembled from file + CLI
//! overrides.
//!
//! Supported TOML subset: `[section]` headers, `key = value` with string
//! (`"..."`), boolean, integer and float values, `#` comments.

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

/// A parsed configuration value.
#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Bool(bool),
    Int(i64),
    Float(f64),
}

impl Value {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Value::Int(i) if *i >= 0 => Some(*i as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Flat `section.key -> value` configuration map.
#[derive(Clone, Debug, Default)]
pub struct Config {
    values: BTreeMap<String, Value>,
}

impl Config {
    /// Parse the TOML subset.
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim().to_string();
            if line.is_empty() {
                continue;
            }
            if line.starts_with('[') {
                if !line.ends_with(']') {
                    bail!("line {}: malformed section '{raw}'", lineno + 1);
                }
                section = line[1..line.len() - 1].trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            values.insert(key, parse_value(v.trim(), lineno + 1)?);
        }
        Ok(Config { values })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Config> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(Value::as_f64).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(Value::as_usize).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(Value::as_bool).unwrap_or(default)
    }

    pub fn str_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).and_then(Value::as_str).unwrap_or(default)
    }

    /// Overlay another config (e.g. CLI overrides win over file values).
    pub fn merge_from(&mut self, other: Config) {
        self.values.extend(other.values);
    }

    pub fn set(&mut self, key: &str, value: Value) {
        self.values.insert(key.to_string(), value);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }
}

fn strip_comment(line: &str) -> &str {
    // respect '#' inside quoted strings
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(s: &str, lineno: usize) -> Result<Value> {
    if s.starts_with('"') && s.ends_with('"') && s.len() >= 2 {
        return Ok(Value::Str(s[1..s.len() - 1].to_string()));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = s.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("line {lineno}: cannot parse value '{s}'")
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# experiment config
seed = 7
[scheduler]
max_gpus = 32
window_s = 60.0
use_bank = true
name = "prompttuner"  # inline comment
"#;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(SAMPLE).unwrap();
        assert_eq!(c.usize_or("seed", 0), 7);
        assert_eq!(c.usize_or("scheduler.max_gpus", 0), 32);
        assert_eq!(c.f64_or("scheduler.window_s", 0.0), 60.0);
        assert!(c.bool_or("scheduler.use_bank", false));
        assert_eq!(c.str_or("scheduler.name", ""), "prompttuner");
    }

    #[test]
    fn defaults_for_missing_keys() {
        let c = Config::parse("").unwrap();
        assert_eq!(c.usize_or("nope", 5), 5);
        assert!(c.is_empty());
    }

    #[test]
    fn int_value_readable_as_f64() {
        let c = Config::parse("x = 3").unwrap();
        assert_eq!(c.f64_or("x", 0.0), 3.0);
    }

    #[test]
    fn rejects_malformed() {
        assert!(Config::parse("[unclosed\n").is_err());
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("x = @@\n").is_err());
    }

    #[test]
    fn merge_overrides() {
        let mut base = Config::parse("a = 1\nb = 2").unwrap();
        let over = Config::parse("b = 9\nc = 3").unwrap();
        base.merge_from(over);
        assert_eq!(base.usize_or("a", 0), 1);
        assert_eq!(base.usize_or("b", 0), 9);
        assert_eq!(base.usize_or("c", 0), 3);
    }

    #[test]
    fn comment_inside_string_preserved() {
        let c = Config::parse("s = \"a # b\"").unwrap();
        assert_eq!(c.str_or("s", ""), "a # b");
    }

    #[test]
    fn negative_and_float_values() {
        let c = Config::parse("a = -4\nb = 2.5e-3").unwrap();
        assert_eq!(c.get("a"), Some(&Value::Int(-4)));
        assert!((c.f64_or("b", 0.0) - 2.5e-3).abs() < 1e-12);
        assert_eq!(c.usize_or("a", 7), 7); // negative not a usize
    }
}
