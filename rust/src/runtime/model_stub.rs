//! Stub [`ModelRuntime`] used when the crate is built without the `pjrt`
//! feature (the offline default: the `xla` crate is unavailable).
//!
//! It exposes the full PJRT API surface so the serving plane, tuning
//! paths, CLI and benches all compile unchanged; every entry point fails
//! at `load` time with a clear message. The simulator stack (cluster,
//! coordinator, baselines, benches of Figs 7/8 and Tables 7/8) never
//! touches this type and is unaffected.

use anyhow::{bail, Result};

use super::common::TuneState;
use crate::util::manifest::{Manifest, ModelInfo};

const NO_PJRT: &str =
    "this build has no PJRT runtime: rebuild with `--features pjrt` \
     (requires the `xla` crate; see rust/Cargo.toml)";

/// Stand-in for the PJRT-backed model runtime. Never constructible:
/// [`ModelRuntime::load`] always errors in non-`pjrt` builds.
pub struct ModelRuntime {
    pub info: ModelInfo,
    /// Wall-clock seconds spent loading (always unset in the stub).
    pub load_time_s: f64,
}

impl ModelRuntime {
    pub fn load(manifest: &Manifest, variant: &str) -> Result<ModelRuntime> {
        let _ = (manifest, variant);
        bail!(NO_PJRT)
    }

    pub fn embed_prompt(&self, ptoks: &[i32]) -> Result<Vec<f32>> {
        let _ = ptoks;
        bail!(NO_PJRT)
    }

    pub fn score(&self, ptoks: &[i32], toks: &[i32], tgts: &[i32]) -> Result<f32> {
        let _ = (ptoks, toks, tgts);
        bail!(NO_PJRT)
    }

    pub fn features(&self, ptoks: &[i32]) -> Result<Vec<f32>> {
        let _ = ptoks;
        bail!(NO_PJRT)
    }

    pub fn eval_loss(&self, prompt: &[f32], toks: &[i32], tgts: &[i32]) -> Result<f32> {
        let _ = (prompt, toks, tgts);
        bail!(NO_PJRT)
    }

    pub fn tune_step(&self, state: &mut TuneState, toks: &[i32], tgts: &[i32],
                     lr: f32) -> Result<f32> {
        let _ = (state, toks, tgts, lr);
        bail!(NO_PJRT)
    }

    pub fn grad_prompt(&self, prompt: &[f32], toks: &[i32], tgts: &[i32])
                       -> Result<(Vec<f32>, f32)> {
        let _ = (prompt, toks, tgts);
        bail!(NO_PJRT)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn load_fails_with_actionable_message() {
        let manifest = Manifest {
            dir: std::path::PathBuf::new(),
            tasks_path: std::path::PathBuf::new(),
            universe_seed: 0,
            models: Default::default(),
        };
        let err = ModelRuntime::load(&manifest, "sim-gpt2b").unwrap_err();
        assert!(format!("{err}").contains("pjrt"), "{err}");
    }
}
