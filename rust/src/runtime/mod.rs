//! The PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them from the Rust hot path.
//!
//! Python never runs at request time — the flow is
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::cpu().compile` → `execute_b` with device-resident
//! parameters (the flat `theta` buffer is uploaded once per model load).
//!
//! HLO **text** (not serialized protos) is the interchange format: jax
//! ≥ 0.5 emits 64-bit instruction ids that xla_extension 0.5.1 rejects;
//! the text parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The `xla` dependency is gated behind the `pjrt` cargo feature; without
//! it (the offline default) an API-identical stub is compiled instead
//! whose `load` errors, so everything downstream still builds.

pub mod common;
#[cfg(feature = "pjrt")]
pub mod model;
#[cfg(not(feature = "pjrt"))]
#[path = "model_stub.rs"]
pub mod model;
pub mod scorer;

pub use common::{init_theta, TuneState};
pub use model::ModelRuntime;
pub use scorer::RuntimeScorer;
