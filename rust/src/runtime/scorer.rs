//! The real Eqn.-1 scorer: adapts [`ModelRuntime::score`] to the Prompt
//! Bank's [`Scorer`] trait with a fixed eval batch (the paper uses a
//! handful of eval samples — 16 — so labelling effort stays minimal).

use crate::promptbank::Scorer;
use crate::runtime::ModelRuntime;

/// Scores candidates against one job's eval batch via the PJRT runtime.
pub struct RuntimeScorer<'a> {
    rt: &'a ModelRuntime,
    toks: Vec<i32>,
    tgts: Vec<i32>,
    /// Number of score evaluations performed (latency accounting).
    pub evals: usize,
}

impl<'a> RuntimeScorer<'a> {
    /// `toks`/`tgts` must be `batch_eval × seq` row-major token ids.
    pub fn new(rt: &'a ModelRuntime, toks: Vec<i32>, tgts: Vec<i32>) -> Self {
        assert_eq!(toks.len(), rt.info.batch_eval * rt.info.seq);
        assert_eq!(tgts.len(), toks.len());
        RuntimeScorer { rt, toks, tgts, evals: 0 }
    }
}

impl Scorer for RuntimeScorer<'_> {
    fn score(&mut self, tokens: &[i32]) -> f32 {
        self.evals += 1;
        self.rt
            .score(tokens, &self.toks, &self.tgts)
            .expect("runtime score failed")
    }
}
