//! Per-variant model runtime: compiled executables for every exported
//! function plus the device-resident flat parameter buffer.

use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Result};
use xla::{HloModuleProto, Literal, PjRtBuffer, PjRtClient, PjRtLoadedExecutable,
          XlaComputation};

use super::common::{init_theta, TuneState};
use crate::util::binio::read_f32_file;
use crate::util::manifest::{Manifest, ModelInfo};

/// A loaded model variant: PJRT client, compiled executables, theta.
pub struct ModelRuntime {
    pub info: ModelInfo,
    client: PjRtClient,
    theta: PjRtBuffer,
    exe_embed: PjRtLoadedExecutable,
    exe_score: PjRtLoadedExecutable,
    exe_features: PjRtLoadedExecutable,
    exe_tune_step: PjRtLoadedExecutable,
    exe_eval_loss: PjRtLoadedExecutable,
    exe_grad: PjRtLoadedExecutable,
    /// Wall-clock seconds spent loading (compile + weight upload) — the
    /// real "cold start" this architecture pays (cf. §2.2).
    pub load_time_s: f64,
}

fn compile(client: &PjRtClient, path: &Path) -> Result<PjRtLoadedExecutable> {
    let proto = HloModuleProto::from_text_file(path)
        .map_err(|e| anyhow!("parsing {}: {e}", path.display()))?;
    let comp = XlaComputation::from_proto(&proto);
    client
        .compile(&comp)
        .map_err(|e| anyhow!("compiling {}: {e}", path.display()))
}

impl ModelRuntime {
    /// Load a variant: compile all six artifacts and upload theta. When
    /// the manifest carries no pretrained theta (the e2e variant), the
    /// parameters are initialized from the manifest's segment init specs.
    pub fn load(manifest: &Manifest, variant: &str) -> Result<ModelRuntime> {
        let t0 = Instant::now();
        let info = manifest
            .models
            .get(variant)
            .ok_or_else(|| anyhow!("variant '{variant}' not in manifest"))?
            .clone();
        let client = PjRtClient::cpu().map_err(|e| anyhow!("pjrt: {e}"))?;
        let theta_host = match &info.theta_path {
            Some(rel) => {
                let theta = read_f32_file(manifest.dir.join(rel))?;
                if theta.len() != info.n_params {
                    bail!("theta.bin has {} params, manifest says {}",
                          theta.len(), info.n_params);
                }
                theta
            }
            None => init_theta(&info, 1),
        };
        let theta = client
            .buffer_from_host_buffer(&theta_host, &[info.n_params], None)
            .map_err(|e| anyhow!("theta upload: {e}"))?;
        let exe = |f: &str| -> Result<PjRtLoadedExecutable> {
            compile(&client, &manifest.artifact_path(variant, f)?)
        };
        let rt = ModelRuntime {
            exe_embed: exe("embed_prompt")?,
            exe_score: exe("score")?,
            exe_features: exe("features")?,
            exe_tune_step: exe("tune_step")?,
            exe_eval_loss: exe("eval_loss")?,
            exe_grad: exe("grad_prompt")?,
            info,
            client,
            theta,
            load_time_s: 0.0,
        };
        let mut rt = rt;
        rt.load_time_s = t0.elapsed().as_secs_f64();
        Ok(rt)
    }

    fn buf_f32(&self, data: &[f32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("f32 upload: {e}"))
    }

    fn buf_i32(&self, data: &[i32], dims: &[usize]) -> Result<PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow!("i32 upload: {e}"))
    }

    /// Run an executable and decompose the 1-tuple/(n)-tuple result.
    fn run(&self, exe: &PjRtLoadedExecutable, args: &[&PjRtBuffer])
           -> Result<Vec<Literal>> {
        let out = exe.execute_b(args).map_err(|e| anyhow!("execute: {e}"))?;
        let lit = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        lit.to_tuple().map_err(|e| anyhow!("untuple: {e}"))
    }

    fn check_ptoks(&self, ptoks: &[i32]) -> Result<()> {
        if ptoks.len() != self.info.prompt_len {
            bail!("prompt tokens: expected {}, got {}",
                  self.info.prompt_len, ptoks.len());
        }
        Ok(())
    }

    fn check_batch(&self, toks: &[i32], tgts: &[i32], batch: usize) -> Result<()> {
        let want = batch * self.info.seq;
        if toks.len() != want || tgts.len() != want {
            bail!("batch: expected {}x{}={} tokens, got {}/{}",
                  batch, self.info.seq, want, toks.len(), tgts.len());
        }
        Ok(())
    }

    /// Candidate tokens -> continuous initial prompt ([P*D] row-major).
    pub fn embed_prompt(&self, ptoks: &[i32]) -> Result<Vec<f32>> {
        self.check_ptoks(ptoks)?;
        let pt = self.buf_i32(ptoks, &[self.info.prompt_len])?;
        let parts = self.run(&self.exe_embed, &[&self.theta, &pt])?;
        parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))
    }

    /// Paper Eqn. 1: mean eval loss of a *discrete* candidate prompt over
    /// an eval batch of `batch_eval` sequences.
    pub fn score(&self, ptoks: &[i32], toks: &[i32], tgts: &[i32]) -> Result<f32> {
        self.check_ptoks(ptoks)?;
        self.check_batch(toks, tgts, self.info.batch_eval)?;
        let be = self.info.batch_eval;
        let s = self.info.seq;
        let pt = self.buf_i32(ptoks, &[self.info.prompt_len])?;
        let tk = self.buf_i32(toks, &[be, s])?;
        let tg = self.buf_i32(tgts, &[be, s])?;
        let parts = self.run(&self.exe_score, &[&self.theta, &pt, &tk, &tg])?;
        parts[0].get_first_element::<f32>().map_err(|e| anyhow!("{e}"))
    }

    /// Activation feature of a candidate prompt ([D]).
    pub fn features(&self, ptoks: &[i32]) -> Result<Vec<f32>> {
        self.check_ptoks(ptoks)?;
        let pt = self.buf_i32(ptoks, &[self.info.prompt_len])?;
        let parts = self.run(&self.exe_features, &[&self.theta, &pt])?;
        parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))
    }

    /// Mean eval loss of a *continuous* prompt (ITA termination check).
    pub fn eval_loss(&self, prompt: &[f32], toks: &[i32], tgts: &[i32]) -> Result<f32> {
        let (p, d) = (self.info.prompt_len, self.info.d_model);
        if prompt.len() != p * d {
            bail!("prompt: expected {}x{}={}, got {}", p, d, p * d, prompt.len());
        }
        self.check_batch(toks, tgts, self.info.batch_eval)?;
        let be = self.info.batch_eval;
        let s = self.info.seq;
        let pr = self.buf_f32(prompt, &[p, d])?;
        let tk = self.buf_i32(toks, &[be, s])?;
        let tg = self.buf_i32(tgts, &[be, s])?;
        let parts = self.run(&self.exe_eval_loss, &[&self.theta, &pr, &tk, &tg])?;
        parts[0].get_first_element::<f32>().map_err(|e| anyhow!("{e}"))
    }

    /// One fused Adam step on the soft prompt; updates `state` in place
    /// and returns the training loss of the micro-batch.
    pub fn tune_step(&self, state: &mut TuneState, toks: &[i32], tgts: &[i32],
                     lr: f32) -> Result<f32> {
        let (p, d) = (self.info.prompt_len, self.info.d_model);
        self.check_batch(toks, tgts, self.info.batch_train)?;
        let bt = self.info.batch_train;
        let s = self.info.seq;
        state.step += 1.0;
        let pr = self.buf_f32(&state.prompt, &[p, d])?;
        let m = self.buf_f32(&state.m, &[p, d])?;
        let v = self.buf_f32(&state.v, &[p, d])?;
        let st = self.buf_f32(&[state.step], &[])?;
        let tk = self.buf_i32(toks, &[bt, s])?;
        let tg = self.buf_i32(tgts, &[bt, s])?;
        let lrb = self.buf_f32(&[lr], &[])?;
        let parts = self.run(
            &self.exe_tune_step,
            &[&self.theta, &pr, &m, &v, &st, &tk, &tg, &lrb],
        )?;
        if parts.len() != 4 {
            bail!("tune_step returned {} outputs, expected 4", parts.len());
        }
        state.prompt = parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        state.m = parts[1].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        state.v = parts[2].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        parts[3].get_first_element::<f32>().map_err(|e| anyhow!("{e}"))
    }

    /// Prompt gradient + loss for one micro-batch (the data-parallel
    /// worker unit; the coordinator averages gradients and applies Adam
    /// host-side — see `tuning::dp`).
    pub fn grad_prompt(&self, prompt: &[f32], toks: &[i32], tgts: &[i32])
                       -> Result<(Vec<f32>, f32)> {
        let (p, d) = (self.info.prompt_len, self.info.d_model);
        if prompt.len() != p * d {
            bail!("prompt: expected {}, got {}", p * d, prompt.len());
        }
        self.check_batch(toks, tgts, self.info.batch_train)?;
        let bt = self.info.batch_train;
        let s = self.info.seq;
        let pr = self.buf_f32(prompt, &[p, d])?;
        let tk = self.buf_i32(toks, &[bt, s])?;
        let tg = self.buf_i32(tgts, &[bt, s])?;
        let parts = self.run(&self.exe_grad, &[&self.theta, &pr, &tk, &tg])?;
        let grad = parts[0].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?;
        let loss = parts[1].get_first_element::<f32>().map_err(|e| anyhow!("{e}"))?;
        Ok((grad, loss))
    }
}

// `TuneState` and `init_theta` live in `super::common` (shared with the
// no-`pjrt` stub).
