//! Backend-independent runtime pieces shared by the real PJRT model
//! runtime and the no-`pjrt` stub: the host-side Adam state and the
//! manifest-driven parameter initializer.

use crate::util::manifest::{InitKind, ModelInfo};
use crate::util::rng::Rng;

/// Host-side Adam state of one prompt-tuning session. The tensors are
/// small ([P, D] each), so round-tripping them through the host between
/// steps costs microseconds; the heavyweight `theta` stays on device.
#[derive(Clone, Debug)]
pub struct TuneState {
    pub prompt: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    /// 1-based Adam step counter.
    pub step: f32,
}

impl TuneState {
    pub fn new(prompt: Vec<f32>) -> Self {
        let n = prompt.len();
        TuneState { prompt, m: vec![0.0; n], v: vec![0.0; n], step: 0.0 }
    }
}

/// Initialize theta from the manifest's segment init specs (used for the
/// e2e variant, which ships no pretrained weights).
pub fn init_theta(info: &ModelInfo, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut theta = vec![0.0f32; info.n_params];
    for seg in &info.segments {
        let slice = &mut theta[seg.offset..seg.offset + seg.count];
        match seg.init {
            InitKind::Normal(std) => {
                for x in slice.iter_mut() {
                    *x = (rng.normal() as f32) * std;
                }
            }
            InitKind::Zeros => {}
            InitKind::Ones => slice.fill(1.0),
        }
    }
    theta
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_info() -> ModelInfo {
        use crate::util::manifest::Segment;
        ModelInfo {
            name: "t".into(),
            d_model: 4,
            n_layers: 1,
            n_heads: 2,
            vocab: 8,
            seq: 4,
            prompt_len: 2,
            batch_train: 2,
            batch_eval: 2,
            n_params: 10,
            segments: vec![
                Segment { name: "a".into(), offset: 0, count: 4,
                          init: InitKind::Normal(0.5) },
                Segment { name: "b".into(), offset: 4, count: 3,
                          init: InitKind::Ones },
                Segment { name: "c".into(), offset: 7, count: 3,
                          init: InitKind::Zeros },
            ],
            artifacts: Default::default(),
            theta_path: None,
        }
    }

    #[test]
    fn init_theta_follows_segments() {
        let theta = init_theta(&tiny_info(), 3);
        assert_eq!(theta.len(), 10);
        assert!(theta[0..4].iter().any(|&x| x != 0.0));
        assert_eq!(&theta[4..7], &[1.0, 1.0, 1.0]);
        assert_eq!(&theta[7..10], &[0.0, 0.0, 0.0]);
    }

    #[test]
    fn init_theta_deterministic() {
        assert_eq!(init_theta(&tiny_info(), 9), init_theta(&tiny_info(), 9));
        assert_ne!(init_theta(&tiny_info(), 9)[0], init_theta(&tiny_info(), 10)[0]);
    }

    #[test]
    fn tune_state_zero_moments() {
        let s = TuneState::new(vec![1.0; 8]);
        assert_eq!(s.m, vec![0.0; 8]);
        assert_eq!(s.v, vec![0.0; 8]);
        assert_eq!(s.step, 0.0);
    }
}
