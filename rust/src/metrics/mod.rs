//! Metrics & reporting: SLO-violation / cost summaries and the plain-text
//! table/series printers the benches use to regenerate the paper's
//! figures and tables.

use crate::cluster::SimResult;

/// One row of a paper-style comparison table.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub violation_pct: f64,
    pub cost_usd: f64,
}

impl From<&SimResult> for Row {
    fn from(r: &SimResult) -> Row {
        Row {
            label: r.policy.clone(),
            violation_pct: r.violation_rate() * 100.0,
            cost_usd: r.cost_usd,
        }
    }
}

/// Render a violation/cost comparison table (the Fig 7 / Table 7 format).
pub fn render_table(title: &str, rows: &[Row]) -> String {
    let mut out = format!("== {title} ==\n");
    out.push_str(&format!("{:<24} {:>16} {:>12}\n", "system",
                          "SLO violation %", "cost $"));
    for r in rows {
        out.push_str(&format!("{:<24} {:>16.1} {:>12.2}\n",
                              r.label, r.violation_pct, r.cost_usd));
    }
    out
}

/// Render an (x, y) series as aligned text (the figure-series format).
pub fn render_series(title: &str, xlabel: &str, ylabel: &str,
                     points: &[(f64, f64)]) -> String {
    let mut out = format!("== {title} ==\n{:<14} {:<14}\n", xlabel, ylabel);
    for (x, y) in points {
        out.push_str(&format!("{:<14.4} {:<14.4}\n", x, y));
    }
    out
}

/// Improvement factors of `ours` vs `other` (the paper's "N.N×" numbers).
pub fn improvement(ours: &SimResult, other: &SimResult) -> (f64, f64) {
    let viol = if ours.violation_rate() > 0.0 {
        other.violation_rate() / ours.violation_rate()
    } else if other.violation_rate() > 0.0 {
        f64::INFINITY
    } else {
        1.0
    };
    let cost = if ours.cost_usd > 0.0 {
        other.cost_usd / ours.cost_usd
    } else {
        1.0
    };
    (viol, cost)
}

/// A compact one-line summary of a run.
pub fn summary_line(r: &SimResult) -> String {
    format!(
        "{:<24} jobs={:<4} done={:<4} viol={:>5.1}% cost=${:<8.2} util={:>5.1}% \
         sched_ms avg/max={:.2}/{:.2}",
        r.policy,
        r.n_jobs,
        r.n_done,
        r.violation_rate() * 100.0,
        r.cost_usd,
        r.mean_utilization * 100.0,
        r.sched_overhead_ms_mean,
        r.sched_overhead_ms_max
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(policy: &str, viol: usize, n: usize, cost: f64) -> SimResult {
        SimResult {
            policy: policy.into(),
            n_jobs: n,
            n_done: n,
            n_violations: viol,
            cost_usd: cost,
            gpu_seconds_billed: 0.0,
            gpu_seconds_busy: 0.0,
            mean_utilization: 0.5,
            util_timeline: vec![],
            job_latencies: vec![],
            sched_overhead_ms_mean: 1.0,
            sched_overhead_ms_max: 2.0,
            rounds_executed: 0,
            rounds_coalesced: 0,
            wall_s: 0.0,
        }
    }

    #[test]
    fn table_contains_rows_and_title() {
        let rows = vec![Row::from(&result("a", 1, 10, 5.0))];
        let t = render_table("Fig 7a", &rows);
        assert!(t.contains("Fig 7a"));
        assert!(t.contains("a"));
        assert!(t.contains("10.0"));
    }

    #[test]
    fn improvement_factors() {
        let ours = result("pt", 5, 100, 10.0);
        let other = result("b", 20, 100, 45.0);
        let (v, c) = improvement(&ours, &other);
        assert!((v - 4.0).abs() < 1e-9);
        assert!((c - 4.5).abs() < 1e-9);
    }

    #[test]
    fn improvement_handles_zero_violations() {
        let ours = result("pt", 0, 100, 10.0);
        let other = result("b", 20, 100, 45.0);
        let (v, _) = improvement(&ours, &other);
        assert!(v.is_infinite());
        let (v2, _) = improvement(&ours, &result("c", 0, 100, 45.0));
        assert_eq!(v2, 1.0);
    }

    #[test]
    fn improvement_handles_zero_cost() {
        // a zero-cost "ours" must not divide by zero: factor pins to 1.0
        let mut ours = result("pt", 5, 100, 0.0);
        let other = result("b", 20, 100, 45.0);
        let (v, c) = improvement(&ours, &other);
        assert_eq!(c, 1.0);
        assert!((v - 4.0).abs() < 1e-9);
        // both axes degenerate: identity on both
        ours.n_violations = 0;
        let (v2, c2) = improvement(&ours, &result("c", 0, 100, 0.0));
        assert_eq!((v2, c2), (1.0, 1.0));
    }

    #[test]
    fn improvement_zero_jobs_is_identity() {
        // violation_rate() of an empty run is 0 on both sides → 1.0
        let ours = result("pt", 0, 0, 1.0);
        let other = result("b", 0, 0, 1.0);
        assert_eq!(improvement(&ours, &other).0, 1.0);
    }

    #[test]
    fn render_table_aligns_columns_and_rounds() {
        let rows = vec![
            Row::from(&result("prompttuner", 1, 8, 5.126)),
            Row::from(&result("x", 0, 8, 0.0)),
        ];
        let t = render_table("T", &rows);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4); // title + header + 2 rows
        assert_eq!(lines[0], "== T ==");
        // fixed-width columns: every body line is equally long
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(lines[2].starts_with("prompttuner"));
        assert!(lines[2].contains("12.5")); // 1/8 violations, 1 decimal
        assert!(lines[2].ends_with("5.13")); // cost, 2 decimals
        assert!(lines[3].contains("0.0"));
    }

    #[test]
    fn render_series_aligns_and_rounds_to_4_decimals() {
        let s = render_series("S", "x", "y", &[(0.5, 1.0 / 3.0)]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "== S ==");
        assert!(lines[1].starts_with("x"));
        assert!(lines[2].starts_with("0.5000"));
        assert!(lines[2].contains("0.3333"));
        // empty series: header only
        assert_eq!(render_series("E", "x", "y", &[]).lines().count(), 2);
    }

    #[test]
    fn series_renders_points() {
        let s = render_series("Fig 2b", "minute", "arrivals",
                              &[(0.0, 3.0), (1.0, 15.0)]);
        assert!(s.contains("minute"));
        assert!(s.contains("15.0"));
    }

    #[test]
    fn summary_line_mentions_policy() {
        let s = summary_line(&result("prompttuner", 2, 10, 3.5));
        assert!(s.contains("prompttuner"));
        assert!(s.contains("20.0%"));
    }
}
