//! Metrics & reporting: SLO-violation / cost summaries and the plain-text
//! table/series printers the benches use to regenerate the paper's
//! figures and tables.

use crate::cluster::SimResult;
use crate::slo::AttainmentCell;
use crate::workload::GPU_PRICE_PER_S;

/// One row of a paper-style comparison table.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub violation_pct: f64,
    pub cost_usd: f64,
}

impl From<&SimResult> for Row {
    fn from(r: &SimResult) -> Row {
        Row {
            label: r.policy.clone(),
            violation_pct: r.violation_rate() * 100.0,
            cost_usd: r.cost_usd,
        }
    }
}

/// Render a violation/cost comparison table (the Fig 7 / Table 7 format).
pub fn render_table(title: &str, rows: &[Row]) -> String {
    let mut out = format!("== {title} ==\n");
    out.push_str(&format!("{:<24} {:>16} {:>12}\n", "system",
                          "SLO violation %", "cost $"));
    for r in rows {
        out.push_str(&format!("{:<24} {:>16.1} {:>12.2}\n",
                              r.label, r.violation_pct, r.cost_usd));
    }
    out
}

/// Render an (x, y) series as aligned text (the figure-series format).
pub fn render_series(title: &str, xlabel: &str, ylabel: &str,
                     points: &[(f64, f64)]) -> String {
    let mut out = format!("== {title} ==\n{:<14} {:<14}\n", xlabel, ylabel);
    for (x, y) in points {
        out.push_str(&format!("{:<14.4} {:<14.4}\n", x, y));
    }
    out
}

/// Improvement factors of `ours` vs `other` (the paper's "N.N×" numbers).
///
/// Degenerate denominators are floored so both factors stay finite: a
/// violation-free run is credited half a violation (rate `0.5/n`), and a
/// zero-cost run is floored at one billed GPU-second — a perfect run
/// yields a large-but-finite factor instead of ∞/NaN, so downstream
/// tables and JSON stay well-formed. A zero-job `ours` uses the
/// one-job floor (`0.5`): it used to degrade the floor to `0.0`, which
/// collapsed the ratio to a silent `1.0` against *any* baseline — an
/// empty run masquerading as "no improvement" instead of reporting the
/// baseline's violation rate against the half-violation credit. Both
/// axes degenerate → 1.0.
pub fn improvement(ours: &SimResult, other: &SimResult) -> (f64, f64) {
    let rate_floor = 0.5 / ours.n_jobs.max(1) as f64;
    let viol = ratio(other.violation_rate(), ours.violation_rate(), rate_floor);
    let cost = ratio(other.cost_usd, ours.cost_usd, GPU_PRICE_PER_S);
    (viol, cost)
}

/// `num / den` with `den` floored at `den_floor`; 1.0 when both sides
/// (and the floor) are degenerate.
fn ratio(num: f64, den: f64, den_floor: f64) -> f64 {
    if num <= 0.0 && den <= 0.0 {
        return 1.0;
    }
    let den = den.max(den_floor);
    if den <= 0.0 {
        return 1.0;
    }
    num / den
}

/// Render the per-class × per-LLM SLO attainment table produced by
/// `slo::SloMonitor::attainment_table` (the online per-tenant view).
pub fn render_attainment(title: &str, cells: &[AttainmentCell]) -> String {
    let mut out = format!("== {title} ==\n");
    out.push_str(&format!(
        "{:<8} {:<12} {:>6} {:>13} {:>12} {:>12}\n",
        "class", "llm", "jobs", "attainment %", "p50 late s", "p99 late s"
    ));
    for c in cells {
        let class_label = format!("S{:.1}", c.tier);
        out.push_str(&format!(
            "{:<8} {:<12} {:>6} {:>13.1} {:>12.2} {:>12.2}\n",
            class_label,
            c.llm.name(),
            c.jobs,
            c.attainment() * 100.0,
            c.p50_lateness_s,
            c.p99_lateness_s
        ));
    }
    out
}

/// A compact one-line summary of a run.
pub fn summary_line(r: &SimResult) -> String {
    format!(
        "{:<24} jobs={:<4} done={:<4} viol={:>5.1}% cost=${:<8.2} util={:>5.1}% \
         sched_ms avg/max={:.2}/{:.2}",
        r.policy,
        r.n_jobs,
        r.n_done,
        r.violation_rate() * 100.0,
        r.cost_usd,
        r.mean_utilization * 100.0,
        r.sched_overhead_ms_mean,
        r.sched_overhead_ms_max
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    fn result(policy: &str, viol: usize, n: usize, cost: f64) -> SimResult {
        SimResult {
            policy: policy.into(),
            n_jobs: n,
            n_done: n,
            n_violations: viol,
            cost_usd: cost,
            gpu_seconds_billed: 0.0,
            gpu_seconds_busy: 0.0,
            mean_utilization: 0.5,
            util_timeline: vec![],
            job_latencies: vec![],
            job_quality: vec![],
            mean_prompt_quality: 0.0,
            sched_overhead_ms_mean: 1.0,
            sched_overhead_ms_max: 2.0,
            rounds_executed: 0,
            rounds_coalesced: 0,
            events_processed: 0,
            revocations: 0,
            lost_iters: 0.0,
            straggler_iters: 0.0,
            retries: 0,
            retry_iters: 0.0,
            chaos_delay_s: 0.0,
            wall_s: 0.0,
        }
    }

    #[test]
    fn table_contains_rows_and_title() {
        let rows = vec![Row::from(&result("a", 1, 10, 5.0))];
        let t = render_table("Fig 7a", &rows);
        assert!(t.contains("Fig 7a"));
        assert!(t.contains("a"));
        assert!(t.contains("10.0"));
    }

    #[test]
    fn improvement_factors() {
        let ours = result("pt", 5, 100, 10.0);
        let other = result("b", 20, 100, 45.0);
        let (v, c) = improvement(&ours, &other);
        assert!((v - 4.0).abs() < 1e-9);
        assert!((c - 4.5).abs() < 1e-9);
    }

    #[test]
    fn improvement_handles_zero_violations() {
        // a violation-free run is credited half a violation so the factor
        // stays finite: 0.2 / (0.5/100) = 40
        let ours = result("pt", 0, 100, 10.0);
        let other = result("b", 20, 100, 45.0);
        let (v, _) = improvement(&ours, &other);
        assert!(v.is_finite());
        assert!((v - 40.0).abs() < 1e-9, "{v}");
        let (v2, _) = improvement(&ours, &result("c", 0, 100, 45.0));
        assert_eq!(v2, 1.0);
    }

    #[test]
    fn improvement_handles_zero_cost() {
        // a zero-cost "ours" is floored at one billed GPU-second: the
        // factor is huge but finite (no division by zero)
        let mut ours = result("pt", 5, 100, 0.0);
        let other = result("b", 20, 100, 45.0);
        let (v, c) = improvement(&ours, &other);
        assert!(c.is_finite());
        assert!((c - 45.0 / GPU_PRICE_PER_S).abs() < 1e-6, "{c}");
        assert!((v - 4.0).abs() < 1e-9);
        // both axes degenerate: identity on both
        ours.n_violations = 0;
        let (v2, c2) = improvement(&ours, &result("c", 0, 100, 0.0));
        assert_eq!((v2, c2), (1.0, 1.0));
    }

    #[test]
    fn improvement_never_returns_non_finite() {
        let runs = [
            result("a", 0, 0, 0.0),
            result("b", 0, 100, 0.0),
            result("c", 100, 100, 1e9),
            result("d", 1, 100, 1e-12),
        ];
        for ours in &runs {
            for other in &runs {
                let (v, c) = improvement(ours, other);
                assert!(v.is_finite(), "{} vs {}: viol {v}", ours.policy,
                        other.policy);
                assert!(c.is_finite(), "{} vs {}: cost {c}", ours.policy,
                        other.policy);
            }
        }
    }

    #[test]
    fn improvement_zero_jobs_is_identity() {
        // violation_rate() of an empty run is 0 on both sides → 1.0
        let ours = result("pt", 0, 0, 1.0);
        let other = result("b", 0, 0, 1.0);
        assert_eq!(improvement(&ours, &other).0, 1.0);
    }

    #[test]
    fn improvement_zero_job_ours_vs_violating_other_is_finite() {
        // Regression: a zero-job "ours" used to degrade the rate floor
        // to 0.0, collapsing the ratio to a silent 1.0 against any
        // baseline. The floor now falls back to the one-job credit
        // (0.5), so a violating baseline still registers:
        // 0.2 / 0.5 = 0.4, finite and responsive to `other`.
        let ours = result("pt", 0, 0, 10.0);
        let other = result("b", 20, 100, 45.0);
        let (v, c) = improvement(&ours, &other);
        assert!(v.is_finite() && v > 0.0, "{v}");
        assert!((v - 0.4).abs() < 1e-9, "{v}");
        assert!((c - 4.5).abs() < 1e-9, "{c}");
    }

    #[test]
    fn render_table_aligns_columns_and_rounds() {
        let rows = vec![
            Row::from(&result("prompttuner", 1, 8, 5.126)),
            Row::from(&result("x", 0, 8, 0.0)),
        ];
        let t = render_table("T", &rows);
        let lines: Vec<&str> = t.lines().collect();
        assert_eq!(lines.len(), 4); // title + header + 2 rows
        assert_eq!(lines[0], "== T ==");
        // fixed-width columns: every body line is equally long
        assert_eq!(lines[2].len(), lines[3].len());
        assert!(lines[2].starts_with("prompttuner"));
        assert!(lines[2].contains("12.5")); // 1/8 violations, 1 decimal
        assert!(lines[2].ends_with("5.13")); // cost, 2 decimals
        assert!(lines[3].contains("0.0"));
    }

    #[test]
    fn render_series_aligns_and_rounds_to_4_decimals() {
        let s = render_series("S", "x", "y", &[(0.5, 1.0 / 3.0)]);
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines[0], "== S ==");
        assert!(lines[1].starts_with("x"));
        assert!(lines[2].starts_with("0.5000"));
        assert!(lines[2].contains("0.3333"));
        // empty series: header only
        assert_eq!(render_series("E", "x", "y", &[]).lines().count(), 2);
    }

    #[test]
    fn series_renders_points() {
        let s = render_series("Fig 2b", "minute", "arrivals",
                              &[(0.0, 3.0), (1.0, 15.0)]);
        assert!(s.contains("minute"));
        assert!(s.contains("15.0"));
    }

    #[test]
    fn attainment_table_renders_rows() {
        use crate::workload::Llm;
        let cells = vec![AttainmentCell {
            class: 0,
            tier: 0.5,
            llm: Llm::Gpt2B,
            jobs: 8,
            met: 6,
            p50_lateness_s: 0.0,
            p99_lateness_s: 12.5,
        }];
        let t = render_attainment("T", &cells);
        assert!(t.contains("== T =="));
        assert!(t.contains("S0.5"));
        assert!(t.contains("gpt2-base"));
        assert!(t.contains("75.0"));
        assert!(t.contains("12.50"));
        // empty table: title + header only
        assert_eq!(render_attainment("E", &[]).lines().count(), 2);
    }

    #[test]
    fn summary_line_mentions_policy() {
        let s = summary_line(&result("prompttuner", 2, 10, 3.5));
        assert!(s.contains("prompttuner"));
        assert!(s.contains("20.0%"));
    }
}
