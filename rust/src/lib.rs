//! PromptTuner: an SLO-aware elastic cluster-management system for LLM
//! prompt-tuning (LPT) workloads — a full reproduction of the CS.DC 2026
//! paper, built as a three-layer Rust + JAX + Pallas stack.
//!
//! Layer map (see DESIGN.md):
//! - **L3 (this crate)** — the paper's coordination contribution: the
//!   [`coordinator`] Workload Scheduler (warm/cold GPU pools, Algorithms 1
//!   and 2, `DelaySchedulable`, latency-budget routing) and the
//!   [`promptbank`] two-layer query engine; plus every substrate they need:
//!   a discrete-event GPU [`cluster`] simulator (with the [`cluster::SimOracle`]
//!   invariant layer), [`trace`] generation plus the [`scenario`] engine's
//!   workload families, [`baselines`] (INFless-like, ElasticFlow-like),
//!   [`metrics`]/cost accounting, and a real execution engine
//!   ([`serve`], [`tuning`]).
//! - **L2/L1 (build-time Python)** — the LPT compute graph (tiny GPT with a
//!   tunable soft prompt, Pallas prefix-attention kernel) AOT-lowered to
//!   HLO text artifacts.
//! - **[`runtime`]** — loads those artifacts through the PJRT C API (`xla`
//!   crate) and executes them from the Rust hot path; Python is never on
//!   the request path.
//! - **[`slo`]** — the online SLO telemetry & error-budget control plane
//!   (SLI windows, burn rates, admission control, capacity governor).
//! - **[`fault`]** — the deterministic fault & preemption engine
//!   (seeded GPU-failure / spot-reclaim / straggler plans, the
//!   checkpoint/restore cost model, and the `FaultInjector` policy
//!   wrapper driving involuntary churn through `Policy::on_revoke`).
//! - **[`shard`]** — the hyperscale shard plane: N simulated cells fed
//!   by streaming [`trace`] sources, a coverage/queue/headroom router,
//!   periodic cross-shard Prompt-Bank gossip, and deterministic
//!   network-partition chaos.

// Style-lint policy for CI's `cargo clippy -- -D warnings` gate: the
// numeric simulation code deliberately keeps a few patterns clippy's
// style lints dislike (wide allocator signatures, index-driven loops over
// paired arrays, explicit range comparisons); the correctness lints stay
// armed.
#![allow(clippy::too_many_arguments)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::type_complexity)]
#![allow(clippy::manual_range_contains)]
#![allow(clippy::new_without_default)]

pub mod baselines;
pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod fault;
pub mod metrics;
pub mod promptbank;
pub mod runtime;
pub mod scenario;
pub mod serve;
pub mod shard;
pub mod slo;
pub mod trace;
pub mod tuning;
pub mod util;
pub mod workload;

/// Default location of AOT artifacts relative to the repo root.
pub const DEFAULT_ARTIFACTS_DIR: &str = "artifacts";
