//! Parallel sweep harness for the paper benches.
//!
//! Every fig/table bench is a grid of independent *(policy, trace, seed)*
//! cells; the seed ran them serially. [`run_sweep`] distributes cells
//! across scoped worker threads (`std::thread`, no external crates) and
//! returns results in input order, so bench output stays deterministic
//! while wall-clock drops by ~the core count.
//!
//! Each run also produces a machine-readable perf record
//! (`BENCH_<suite>.json`, hand-rolled JSON — no serde offline) with
//! per-cell wall-clock, executed/skipped round counts, rounds/s and
//! events/s (the batch-skip core's O(events) throughput), so the perf
//! trajectory of the simulator hot path is tracked from PR 1 onward.
//! `rounds_skipped` is the canonical name for the batch-skipped count;
//! `rounds_coalesced` is kept as an alias for older tooling. CI fails
//! if the record is malformed or a cell regresses against the committed
//! baseline (see `tools/check_bench.py`).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

use crate::baselines::{ElasticFlow, ElasticFlowConfig, Infless, InflessConfig};
use crate::cluster::{CheckpointModel, Policy, SimConfig, SimResult, Simulator,
                     TunerReport};
use crate::coordinator::{PromptTuner, PromptTunerConfig};
use crate::fault::{ChaosEngine, FaultInjector, FaultPlan};
use crate::promptbank::SimBankConfig;
use crate::scenario::Scenario;
use crate::slo::{Governed, GovernorConfig, Tuned, TunerConfig};
use crate::trace::{Load, TraceConfig, TraceGenerator, VecSource};
use crate::workload::{JobSpec, Llm, PerfModel};

/// The three systems every end-to-end comparison sweeps.
pub const SYSTEMS: [&str; 3] = ["prompttuner", "infless", "elasticflow"];

/// One independent simulated experiment of a sweep.
#[derive(Clone, Debug)]
pub struct SweepCell {
    /// Display/reporting label, e.g. "fig7/medium/S1.0".
    pub label: String,
    /// "prompttuner" | "infless" | "elasticflow".
    pub system: String,
    pub gpus: usize,
    pub seed: u64,
    pub load: Load,
    /// SLO emergence S of the generated trace.
    pub slo: f64,
    /// Load scale factor; 1.0 = the plain §6.1 trace.
    pub scale: f64,
    /// Heavy-workload trace (Table 7) for this LLM instead of the main
    /// mixed trace.
    pub heavy: Option<Llm>,
    /// Scenario-engine workload family (fig11) instead of the paper
    /// traces; takes precedence over `load`/`scale`/`heavy`.
    pub scenario: Option<Scenario>,
    /// Wrap the policy in the SLO control plane (`slo::Governed`): burn
    /// telemetry, admission deferral, and a capacity governor with surge
    /// headroom over the cell's GPU baseline (the simulator budget is
    /// widened to the surge ceiling by `run_cell`).
    pub governed: bool,
    /// Wrap the policy in the self-tuning control plane (`slo::Tuned`):
    /// a seeded successive-halving race over the policy's declared knob
    /// lattice with budget-guarded exploration (fig17). Like governed
    /// cells, the simulator budget is widened to the capacity knob's
    /// surge ceiling by `run_cell`.
    pub tuned: bool,
    /// PromptTuner config override (ablation sweeps); the cell seed is
    /// applied on top.
    pub cfg: Option<PromptTunerConfig>,
    /// Prompt-Bank construction override applied to *every* system's
    /// bank (the fig14 cold/warm sweep); None keeps each system's
    /// default (warm) bank.
    pub bank: Option<SimBankConfig>,
}

impl SweepCell {
    pub fn new(label: impl Into<String>, system: impl Into<String>,
               load: Load, slo: f64, gpus: usize, seed: u64) -> Self {
        SweepCell {
            label: label.into(),
            system: system.into(),
            gpus,
            seed,
            load,
            slo,
            scale: 1.0,
            heavy: None,
            scenario: None,
            governed: false,
            tuned: false,
            cfg: None,
            bank: None,
        }
    }

    /// Override every system's bank construction (fig14: cold vs warm).
    pub fn with_bank(mut self, bank: SimBankConfig) -> Self {
        self.bank = Some(bank);
        self
    }

    /// Mark the cell governed (fig12): the policy is wrapped in
    /// `slo::Governed` with `GovernorConfig::for_cluster(gpus)`.
    pub fn governed(mut self) -> Self {
        self.governed = true;
        self
    }

    /// Mark the cell tuned (fig17): the policy is wrapped in
    /// `slo::Tuned` with the default race parameters and the cell's
    /// seed, so per-seed knob trajectories are reproducible.
    pub fn tuned(mut self) -> Self {
        self.tuned = true;
        self
    }

    /// A scenario-engine cell (the fig11 sweep): `load`/`scale` are
    /// inert, the named family generates the trace.
    pub fn scenario(label: impl Into<String>, system: impl Into<String>,
                    scenario: Scenario, slo: f64, gpus: usize,
                    seed: u64) -> Self {
        let mut cell =
            SweepCell::new(label, system, Load::Medium, slo, gpus, seed);
        cell.scenario = Some(scenario);
        cell
    }
}

/// Result of one cell: the simulator metrics plus the cell's wall-clock.
#[derive(Clone, Debug)]
pub struct CellResult {
    pub cell: SweepCell,
    pub result: SimResult,
    pub wall_s: f64,
    /// End-of-run tuner telemetry (`Policy::tuner_report`): Some for
    /// tuned cells, None otherwise.
    pub tuner: Option<TunerReport>,
    /// Shard-plane executor width (clamped): Some for plane cells
    /// (fig16), None for single-simulator cells.
    pub plane_workers: Option<usize>,
    /// Wall-clock of the plane run itself, seconds (the cell `wall_s`
    /// additionally covers trace/plane construction).
    pub plane_wall_s: Option<f64>,
}

/// Build the policy a cell names (ablation override aware; governed
/// cells are wrapped in the SLO control plane; cells whose scenario
/// carries a fault plan — spot-market, az-outage, chaos-storm — are
/// wrapped in the fault engine with the default checkpoint/restore cost
/// model; cells whose scenario carries a chaos profile additionally get
/// a `fault::ChaosEngine` in the same wrapper).
pub fn make_policy(cell: &SweepCell) -> Box<dyn Policy> {
    let inner: Box<dyn Policy> = match cell.system.as_str() {
        "prompttuner" => {
            let mut base = cell.cfg.clone().unwrap_or_default();
            if let Some(bank) = &cell.bank {
                base.bank = bank.clone();
            }
            // The cell's seed and cluster size always win over the
            // override: the simulator is sized by cell.gpus, and a policy
            // silently capped at the override's max_gpus would simulate a
            // smaller scheduler inside a bigger cluster.
            Box::new(PromptTuner::new(PromptTunerConfig {
                seed: cell.seed,
                max_gpus: cell.gpus,
                ..base
            }))
        }
        "infless" => {
            let mut cfg = InflessConfig {
                max_gpus: cell.gpus,
                seed: cell.seed,
                ..Default::default()
            };
            if let Some(bank) = &cell.bank {
                cfg.bank.cfg = bank.clone();
            }
            Box::new(Infless::new(cfg))
        }
        "elasticflow" => {
            let mut cfg = ElasticFlowConfig {
                cluster_size: cell.gpus,
                seed: cell.seed,
                ..Default::default()
            };
            if let Some(bank) = &cell.bank {
                cfg.bank.cfg = bank.clone();
            }
            Box::new(ElasticFlow::new(cfg))
        }
        other => panic!("unknown system {other}"),
    };
    let policy: Box<dyn Policy> = if cell.governed {
        Box::new(Governed::new(inner, GovernorConfig::for_cluster(cell.gpus)))
    } else {
        inner
    };
    // The tuner sits in the control-plane slot, directly over the knobs
    // it races (and under the fault engine, which re-clamps capacity to
    // any degraded ceiling after every callback).
    let policy: Box<dyn Policy> = if cell.tuned {
        Box::new(Tuned::new(
            policy,
            TunerConfig { seed: cell.seed, ..Default::default() },
        ))
    } else {
        policy
    };
    let plan = cell
        .scenario
        .as_ref()
        .and_then(|sc| sc.fault_plan(cell.seed, cell.gpus));
    let chaos = cell.scenario.as_ref().and_then(Scenario::chaos_profile);
    match (plan, chaos) {
        (plan, Some(profile)) => Box::new(FaultInjector::with_chaos(
            policy,
            plan.unwrap_or_else(|| FaultPlan::new(vec![])),
            CheckpointModel::default(),
            ChaosEngine::new(profile, cell.seed, cell.gpus),
        )),
        (Some(plan), None) => Box::new(FaultInjector::new(
            policy,
            plan,
            CheckpointModel::default(),
        )),
        (None, None) => policy,
    }
}

/// Generate the cell's trace (same generator paths as the seed benches).
pub fn gen_jobs(cell: &SweepCell) -> Vec<JobSpec> {
    if let Some(sc) = &cell.scenario {
        return sc
            .generate(cell.seed, cell.slo)
            .unwrap_or_else(|e| panic!("scenario '{}': {e:#}", sc.name()));
    }
    let perf = PerfModel::default();
    let mut gen = TraceGenerator::new(
        TraceConfig {
            seed: cell.seed,
            slo_emergence: cell.slo,
            ..Default::default()
        },
        perf,
    );
    if let Some(llm) = cell.heavy {
        gen.generate_heavy(llm)
    } else if (cell.scale - 1.0).abs() > 1e-12 {
        gen.generate_scaled(cell.load, cell.scale)
    } else {
        gen.generate_main(cell.load)
    }
}

/// Run one cell to completion.
pub fn run_cell(cell: &SweepCell) -> CellResult {
    let t0 = Instant::now();
    let jobs = gen_jobs(cell);
    let mut cfg = SimConfig { max_gpus: cell.gpus, ..Default::default() };
    // Long-running families (heavy-tail) need a wider horizon or their
    // tail jobs get cut off and the cell under-reports violations/cost.
    if let Some(h) = cell.scenario.as_ref().and_then(Scenario::horizon_hint) {
        cfg.horizon_s = cfg.horizon_s.max(h);
    }
    // Governed cells may surge above the baseline: widen the provider
    // budget to the governor's ceiling (the policy still starts at
    // cell.gpus; only the burn-rate governor may claim the headroom).
    // Tuned cells get the same headroom — the capacity knob's lattice
    // tops out at the identical surge ceiling, so an up-lattice arm is
    // realizable instead of silently clamped.
    if cell.governed || cell.tuned {
        cfg.max_gpus = GovernorConfig::for_cluster(cell.gpus).ceiling_gpus;
    }
    let sim = Simulator::new(cfg, PerfModel::default());
    let mut policy = make_policy(cell);
    // Streamed through the same `StreamCore` every trace path uses now;
    // bit-identical to the materialized `Simulator::run` (the streaming
    // equivalence property in tests/prop_shard.rs enforces it per family).
    let result = sim.run_source(policy.as_mut(), &mut VecSource::new(jobs));
    let tuner = policy.tuner_report();
    CellResult {
        cell: cell.clone(),
        result,
        wall_s: t0.elapsed().as_secs_f64(),
        tuner,
        plane_workers: None,
        plane_wall_s: None,
    }
}

/// Map `f` over `items` on a scoped worker pool (one worker per
/// available core, capped at the item count); results come back in
/// input order. Work-stealing via a shared atomic cursor — the shared
/// harness behind [`run_sweep`] and the fig16 plane sweep.
pub fn run_parallel<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    if items.is_empty() {
        return vec![];
    }
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(items.len());
    let next = AtomicUsize::new(0);
    let slots: Vec<Mutex<Option<R>>> =
        items.iter().map(|_| Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            scope.spawn(|| loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= items.len() {
                    break;
                }
                let r = f(&items[i]);
                *slots[i].lock().unwrap() = Some(r);
            });
        }
    });
    slots
        .into_iter()
        .map(|m| {
            m.into_inner()
                .unwrap()
                .expect("worker thread dropped an item")
        })
        .collect()
}

/// Run all cells across worker threads; results come back in input
/// order. Cell execution order across threads is nondeterministic, but
/// every cell is self-contained and seeded, so results are not.
pub fn run_sweep(cells: &[SweepCell]) -> Vec<CellResult> {
    run_parallel(cells, run_cell)
}

// --------------------------------------------------------------- report

fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

fn json_f64(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.6}")
    } else {
        "0.0".to_string()
    }
}

/// A machine-readable perf record of one sweep (BENCH_<suite>.json).
pub struct BenchReport {
    /// Suite name; the perf-tracking suite is "sim" → BENCH_sim.json.
    pub suite: String,
    pub cells: Vec<CellResult>,
    pub total_wall_s: f64,
}

impl BenchReport {
    pub fn new(suite: impl Into<String>, cells: Vec<CellResult>,
               total_wall_s: f64) -> Self {
        BenchReport { suite: suite.into(), cells, total_wall_s }
    }

    pub fn to_json(&self) -> String {
        let created = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs())
            .unwrap_or(0);
        let mut out = String::new();
        out.push_str("{\n");
        out.push_str(&format!("  \"suite\": \"{}\",\n", json_escape(&self.suite)));
        out.push_str(&format!("  \"created_unix\": {created},\n"));
        out.push_str(&format!("  \"total_wall_s\": {},\n",
                              json_f64(self.total_wall_s)));
        // The scenario-family manifest, emitted from the Rust single
        // source of truth (`scenario::FAMILIES`) so tooling never
        // hand-maintains the list.
        out.push_str("  \"families\": [");
        for (i, f) in crate::scenario::FAMILIES.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{}\"", json_escape(f)));
        }
        out.push_str("],\n");
        out.push_str("  \"cells\": [\n");
        for (i, c) in self.cells.iter().enumerate() {
            let r = &c.result;
            out.push_str("    {");
            out.push_str(&format!("\"label\": \"{}\", ", json_escape(&c.cell.label)));
            out.push_str(&format!("\"system\": \"{}\", ",
                                  json_escape(&c.cell.system)));
            out.push_str(&format!("\"gpus\": {}, ", c.cell.gpus));
            out.push_str(&format!("\"seed\": {}, ", c.cell.seed));
            out.push_str(&format!("\"load\": \"{}\", ", c.cell.load.name()));
            out.push_str(&format!(
                "\"scenario\": \"{}\", ",
                c.cell.scenario.as_ref().map_or("none", |s| s.name())
            ));
            out.push_str(&format!("\"governed\": {}, ", c.cell.governed));
            out.push_str(&format!("\"tuned\": {}, ", c.cell.tuned));
            // Bank construction tag: "cold" / "warm:<seeded>" carries the
            // override's seeded-corpus size so size-capped sweeps stay
            // distinguishable; drift shows through the scenario tag.
            out.push_str(&format!(
                "\"bank\": \"{}\", ",
                c.cell.bank.as_ref().map_or_else(
                    || "default".to_string(),
                    |b| if b.initial_size == 0 {
                        "cold".to_string()
                    } else {
                        format!("warm:{}", b.initial_size)
                    },
                )
            ));
            out.push_str(&format!("\"slo\": {}, ", json_f64(c.cell.slo)));
            out.push_str(&format!("\"scale\": {}, ", json_f64(c.cell.scale)));
            out.push_str(&format!("\"wall_s\": {}, ", json_f64(c.wall_s)));
            // Shard-plane executor telemetry (fig16 cells only).
            if let Some(w) = c.plane_workers {
                out.push_str(&format!("\"plane_workers\": {w}, "));
            }
            if let Some(pw) = c.plane_wall_s {
                out.push_str(&format!("\"plane_wall_s\": {}, ",
                                      json_f64(pw)));
            }
            out.push_str(&format!("\"rounds_executed\": {}, ",
                                  r.rounds_executed));
            // `rounds_skipped` is the canonical batch-skip counter;
            // `rounds_coalesced` stays as an alias for older tooling.
            out.push_str(&format!("\"rounds_skipped\": {}, ",
                                  r.rounds_coalesced));
            out.push_str(&format!("\"rounds_coalesced\": {}, ",
                                  r.rounds_coalesced));
            out.push_str(&format!("\"ticks_per_s\": {}, ",
                                  json_f64(r.ticks_per_s())));
            out.push_str(&format!("\"events_processed\": {}, ",
                                  r.events_processed));
            out.push_str(&format!("\"events_per_s\": {}, ",
                                  json_f64(r.events_per_s())));
            out.push_str(&format!("\"revocations\": {}, ", r.revocations));
            out.push_str(&format!("\"lost_iters\": {}, ",
                                  json_f64(r.lost_iters)));
            out.push_str(&format!("\"retries\": {}, ", r.retries));
            out.push_str(&format!("\"retry_iters\": {}, ",
                                  json_f64(r.retry_iters)));
            out.push_str(&format!("\"chaos_delay_s\": {}, ",
                                  json_f64(r.chaos_delay_s)));
            out.push_str(&format!("\"n_jobs\": {}, ", r.n_jobs));
            out.push_str(&format!("\"n_done\": {}, ", r.n_done));
            out.push_str(&format!("\"n_violations\": {}, ", r.n_violations));
            out.push_str(&format!("\"cost_usd\": {}, ", json_f64(r.cost_usd)));
            out.push_str(&format!("\"mean_quality\": {}, ",
                                  json_f64(r.mean_prompt_quality)));
            out.push_str(&format!("\"mean_utilization\": {}, ",
                                  json_f64(r.mean_utilization)));
            out.push_str(&format!("\"sched_overhead_ms_mean\": {}, ",
                                  json_f64(r.sched_overhead_ms_mean)));
            out.push_str(&format!("\"sched_overhead_ms_max\": {}",
                                  json_f64(r.sched_overhead_ms_max)));
            // Tuner telemetry (fig17): decision counters plus per-knob
            // lattice bounds, final incumbent, and the set-value
            // extremes — check_bench asserts every trajectory stayed
            // inside its declared lattice.
            if let Some(t) = &c.tuner {
                out.push_str(&format!(", \"tuner_decisions\": {}, ",
                                      t.decisions));
                out.push_str(&format!("\"tuner_promotions\": {}, ",
                                      t.promotions));
                out.push_str(&format!("\"tuner_reverts\": {}, ", t.reverts));
                out.push_str(&format!("\"tuner_explore_bad\": {}, ",
                                      t.explore_bad));
                out.push_str(&format!("\"tuner_frozen\": {}, ", t.frozen));
                out.push_str("\"knobs\": [");
                for (j, k) in t.knobs.iter().enumerate() {
                    if j > 0 {
                        out.push_str(", ");
                    }
                    out.push_str(&format!(
                        "{{\"name\": \"{}\", \"lo\": {}, \"hi\": {}, \
                         \"value\": {}, \"min_seen\": {}, \"max_seen\": {}}}",
                        json_escape(k.name),
                        json_f64(k.lo),
                        json_f64(k.hi),
                        json_f64(k.value),
                        json_f64(k.min_seen),
                        json_f64(k.max_seen),
                    ));
                }
                out.push(']');
            }
            out.push_str(if i + 1 < self.cells.len() { "},\n" } else { "}\n" });
        }
        out.push_str("  ]\n}\n");
        out
    }

    /// Default output path: `<crate root>/BENCH_<suite>.json`, overridable
    /// with the BENCH_OUT_DIR environment variable.
    pub fn default_path(&self) -> PathBuf {
        let dir = std::env::var("BENCH_OUT_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from(env!("CARGO_MANIFEST_DIR")));
        dir.join(format!("BENCH_{}.json", self.suite))
    }

    pub fn write(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }

    /// Write to the default path and report where it went.
    pub fn write_default(&self) -> std::io::Result<PathBuf> {
        let path = self.default_path();
        self.write(&path)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cells() -> Vec<SweepCell> {
        SYSTEMS
            .iter()
            .map(|s| SweepCell::new(format!("t/{s}"), *s, Load::Low, 1.0, 16, 5))
            .collect()
    }

    #[test]
    fn sweep_runs_cells_in_order_and_completes_jobs() {
        let cells = tiny_cells();
        let results = run_sweep(&cells);
        assert_eq!(results.len(), cells.len());
        for (cell, res) in cells.iter().zip(&results) {
            assert_eq!(res.cell.system, cell.system);
            assert_eq!(res.result.n_done, res.result.n_jobs);
            assert!(res.wall_s >= 0.0);
        }
    }

    #[test]
    fn sweep_matches_serial_execution() {
        let cells = tiny_cells();
        let parallel = run_sweep(&cells);
        for (cell, p) in cells.iter().zip(&parallel) {
            let serial = run_cell(cell);
            assert_eq!(serial.result.n_violations, p.result.n_violations);
            assert!((serial.result.cost_usd - p.result.cost_usd).abs() < 1e-9);
        }
    }

    #[test]
    fn report_emits_valid_looking_json() {
        let cells = vec![SweepCell::new("a\"b", "prompttuner", Load::Low, 1.0, 8, 7)];
        let results = run_sweep(&cells);
        let report = BenchReport::new("test", results, 0.5);
        let json = report.to_json();
        assert!(json.contains("\"suite\": \"test\""));
        // every record carries the scenario-family manifest
        assert!(json.contains("\"families\": ["));
        for f in crate::scenario::FAMILIES {
            assert!(json.contains(&format!("\"{f}\"")), "missing family {f}");
        }
        assert!(json.contains("\\\"")); // label quote escaped
        assert!(json.contains("\"ticks_per_s\""));
        assert!(json.contains("\"rounds_coalesced\""));
        assert!(json.contains("\"rounds_skipped\""));
        assert!(json.contains("\"events_processed\""));
        assert!(json.contains("\"events_per_s\""));
        // crude structural checks (no JSON parser offline)
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn scenario_cells_run_all_systems_and_tag_the_record() {
        let sc = Scenario::FlashCrowd { storms: 2, intensity: 10.0,
                                        jobs_per_llm: 8 };
        let cells: Vec<SweepCell> = SYSTEMS
            .iter()
            .map(|s| SweepCell::scenario(
                format!("t/{s}"), *s, sc.clone(), 1.0, 16, 5))
            .collect();
        let results = run_sweep(&cells);
        for r in &results {
            assert_eq!(r.result.n_jobs, sc.expected_jobs().unwrap());
        }
        let report = BenchReport::new("scenarios", results, 0.1);
        let json = report.to_json();
        assert!(json.contains("\"scenario\": \"flash-crowd\""));
    }

    #[test]
    fn non_scenario_cells_tag_record_with_none() {
        let cells = vec![SweepCell::new("p", "prompttuner", Load::Low, 1.0, 8, 7)];
        let report = BenchReport::new("t", run_sweep(&cells), 0.1);
        let json = report.to_json();
        assert!(json.contains("\"scenario\": \"none\""));
        assert!(json.contains("\"governed\": false"));
    }

    #[test]
    fn governed_cells_wrap_policy_and_widen_budget() {
        let sc = Scenario::FlashCrowd { storms: 2, intensity: 10.0,
                                        jobs_per_llm: 8 };
        let cell = SweepCell::scenario("g", "prompttuner", sc, 1.0, 16, 5)
            .governed();
        let r = run_cell(&cell);
        assert_eq!(r.result.n_done, r.result.n_jobs);
        assert_eq!(r.result.policy, "prompttuner+slo");
        let report = BenchReport::new("slo", vec![r], 0.1);
        assert!(report.to_json().contains("\"governed\": true"));
    }

    #[test]
    fn tuned_cells_wrap_policy_and_emit_knob_telemetry() {
        let sc = Scenario::FlashCrowd { storms: 2, intensity: 10.0,
                                        jobs_per_llm: 8 };
        let cell = SweepCell::scenario("t", "prompttuner", sc, 1.0, 16, 5)
            .tuned();
        let r = run_cell(&cell);
        assert_eq!(r.result.n_done, r.result.n_jobs);
        assert_eq!(r.result.policy, "prompttuner+tuned");
        let rep = r.tuner.as_ref().expect("tuned cell must carry a report");
        assert!(!rep.knobs.is_empty(), "PromptTuner declares knobs");
        for k in &rep.knobs {
            assert!(k.lo <= k.min_seen && k.max_seen <= k.hi,
                    "{}: [{}, {}] seen [{}, {}]",
                    k.name, k.lo, k.hi, k.min_seen, k.max_seen);
        }
        let report = BenchReport::new("tuning", vec![r], 0.1);
        let json = report.to_json();
        assert!(json.contains("\"tuned\": true"));
        assert!(json.contains("\"tuner_decisions\""));
        assert!(json.contains("\"knobs\": ["));
        assert_eq!(json.matches('{').count(), json.matches('}').count());
        assert_eq!(json.matches('[').count(), json.matches(']').count());
    }

    #[test]
    fn untuned_cells_tag_record_and_carry_no_report() {
        let cells = vec![SweepCell::new("p", "prompttuner", Load::Low, 1.0,
                                        8, 7)];
        let results = run_sweep(&cells);
        assert!(results[0].tuner.is_none());
        let report = BenchReport::new("t", results, 0.1);
        let json = report.to_json();
        assert!(json.contains("\"tuned\": false"));
        assert!(!json.contains("\"knobs\""));
    }

    #[test]
    fn fault_scenario_cells_inject_faults_and_tag_the_record() {
        let sc = Scenario::AzOutage {
            outage_frac: 0.5,
            repair_s: 120.0,
            jobs_per_llm: 40,
        };
        let cells: Vec<SweepCell> = SYSTEMS
            .iter()
            .map(|s| SweepCell::scenario(
                format!("t/{s}"), *s, sc.clone(), 1.0, 16, 5))
            .collect();
        let results = run_sweep(&cells);
        for r in &results {
            assert_eq!(r.result.n_done, r.result.n_jobs,
                       "{} stranded revoked jobs", r.cell.system);
        }
        let total_revocations: u64 =
            results.iter().map(|r| r.result.revocations).sum();
        assert!(total_revocations > 0, "the outage preempted nothing");
        let report = BenchReport::new("faults", results, 0.1);
        let json = report.to_json();
        assert!(json.contains("\"scenario\": \"az-outage\""));
        assert!(json.contains("\"revocations\""));
        assert!(json.contains("\"lost_iters\""));
    }

    #[test]
    fn chaos_scenario_cells_inject_chaos_and_tag_the_record() {
        use crate::fault::ChaosKind;
        let sc = Scenario::Chaos { kind: ChaosKind::Flaky, jobs_per_llm: 15 };
        let cells: Vec<SweepCell> = SYSTEMS
            .iter()
            .map(|s| SweepCell::scenario(
                format!("t/{s}"), *s, sc.clone(), 1.0, 16, 5))
            .collect();
        let results = run_sweep(&cells);
        for r in &results {
            assert_eq!(r.result.n_done, r.result.n_jobs,
                       "{} stranded retried jobs", r.cell.system);
        }
        let total_retries: u64 =
            results.iter().map(|r| r.result.retries).sum();
        assert!(total_retries > 0, "the flaky profile failed nothing");
        let report = BenchReport::new("chaos", results, 0.1);
        let json = report.to_json();
        assert!(json.contains("\"scenario\": \"chaos-flaky\""));
        assert!(json.contains("\"retries\""));
        assert!(json.contains("\"retry_iters\""));
        assert!(json.contains("\"chaos_delay_s\""));
    }

    #[test]
    fn bank_override_reaches_every_system_and_tags_the_record() {
        let cold = SimBankConfig::cold();
        let cells: Vec<SweepCell> = SYSTEMS
            .iter()
            .map(|s| {
                SweepCell::new(format!("b/{s}"), *s, Load::Low, 1.0, 16, 5)
                    .with_bank(cold.clone())
            })
            .collect();
        let results = run_sweep(&cells);
        for r in &results {
            assert_eq!(r.result.n_done, r.result.n_jobs);
            assert!(r.result.mean_prompt_quality > 0.0);
        }
        let report = BenchReport::new("bank", results, 0.1);
        let json = report.to_json();
        assert!(json.contains("\"bank\": \"cold\""));
        assert!(json.contains("\"mean_quality\""));
    }

    #[test]
    fn ablation_override_keeps_cell_seed() {
        let mut cell = SweepCell::new("abl", "prompttuner", Load::Low, 1.0, 8, 9);
        cell.cfg = Some(PromptTunerConfig {
            use_bank: false,
            max_gpus: 8,
            seed: 12345, // overridden by the cell seed
            ..Default::default()
        });
        let r = run_cell(&cell);
        assert_eq!(r.result.n_done, r.result.n_jobs);
    }

    #[test]
    fn run_parallel_preserves_input_order_and_handles_empty() {
        let items: Vec<usize> = (0..37).collect();
        let out = run_parallel(&items, |&x| x * x);
        assert_eq!(out, items.iter().map(|&x| x * x).collect::<Vec<_>>());
        let none: Vec<usize> = vec![];
        assert!(run_parallel(&none, |&x: &usize| x).is_empty());
    }

    #[test]
    fn plane_fields_are_emitted_only_when_present() {
        let cell = SweepCell::new("p/prompttuner", "prompttuner",
                                  Load::Low, 1.0, 8, 9);
        let mut r = run_cell(&cell);
        let plain = BenchReport::new("scale", vec![r.clone()], 0.1).to_json();
        assert!(!plain.contains("plane_workers"));
        assert!(!plain.contains("plane_wall_s"));
        r.plane_workers = Some(4);
        r.plane_wall_s = Some(1.25);
        let tagged = BenchReport::new("scale", vec![r], 0.1).to_json();
        assert!(tagged.contains("\"plane_workers\": 4, "));
        assert!(tagged.contains("\"plane_wall_s\": 1.250000"));
    }
}
