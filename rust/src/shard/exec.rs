//! Fork-join executor for the shard plane.
//!
//! The plane's inner loop is embarrassingly parallel between router
//! decisions: cells are independent `StreamCore`s that only interact at
//! arrival injections and gossip barriers, both of which are sequential
//! by construction. This module factors the per-cell work behind the
//! [`PlaneExec`] trait with two interchangeable implementations:
//!
//! * [`InlineExec`] — the cells in a `Vec`, serviced on the caller's
//!   thread. `workers == 1` uses this and reproduces the original
//!   sequential loop instruction-for-instruction.
//! * [`PoolExec`] — a persistent std-only worker pool (plain threads +
//!   mpsc channels, the same idiom as `bench::run_parallel`). Each
//!   worker *owns* a disjoint contiguous slice of cells — it builds
//!   them itself from the cloned config, so the non-`Send` policy boxes
//!   never cross a thread boundary — and services broadcast commands
//!   from its FIFO channel. Commands that need answers (scores, gossip
//!   drains, finish) are barriers: the caller collects one reply per
//!   worker and merges them sorted by shard index.
//!
//! **Determinism argument.** Every cell receives the exact same command
//! sequence in the exact same order regardless of thread interleaving
//! (per-worker channels are FIFO and each cell belongs to exactly one
//! worker), each command's effect on a cell is a deterministic function
//! of the cell's state, and all cross-thread data is plain values
//! (`f64` bits are preserved by moves). Reply merging sorts by shard,
//! so the router sees scores and gossip pools in the same order the
//! sequential loop produced them. Hence the parallel plane is
//! bit-identical to the sequential one — property-enforced by
//! `tests/prop_shard.rs` across all three systems × gossip on/off ×
//! partition chaos.
//!
//! **Score caching.** Re-scoring every cell on every arrival pays an
//! O(bank) coverage lookup per cell even when nothing happened there.
//! [`ExecCell::score`] memoizes the router score per `(llm, task)`
//! behind a staleness stamp `(events_processed, rounds_executed,
//! absorbs)`: coverage, queue depth and busy level can only change
//! inside event callbacks, executed scheduler rounds, or gossip
//! absorbs, so an unchanged stamp proves the cached score is still
//! bit-exact. Coalesced (skipped) rounds run no policy code and
//! correctly leave the stamp untouched.

use std::collections::HashMap;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::{self, JoinHandle};

use crate::cluster::{Policy, SimResult, StreamCore, TunedPrompt};
use crate::workload::{JobSpec, Llm, PerfModel};

use super::{make_shard_policy, DenseWrap, ShardPlaneConfig, PHI};

/// One shard's simulator cell plus its router-score memo.
pub(super) struct ExecCell {
    pub(super) shard: usize,
    core: StreamCore,
    policy: Box<dyn Policy>,
    gpus: f64,
    w_coverage: f64,
    w_queue: f64,
    w_headroom: f64,
    /// Gossip absorbs applied to this cell — the third stamp component
    /// (absorbed prompts change the bank without an event or round).
    absorbs: u64,
    stamp: (u64, u64, u64),
    scores: HashMap<(usize, usize), f64>,
    hits: u64,
    misses: u64,
}

/// Everything the plane needs back from a finished cell, tagged with
/// its shard index so pool replies can be merged deterministically.
pub(super) struct CellDone {
    pub(super) shard: usize,
    pub(super) admitted: usize,
    pub(super) cache_hits: u64,
    pub(super) cache_misses: u64,
    pub(super) result: SimResult,
}

impl ExecCell {
    /// Build shard `shard`'s cell exactly as the sequential loop did:
    /// per-shard seed, optional dense pin, gossip log armed only when
    /// the plane actually gossips.
    pub(super) fn build(cfg: &ShardPlaneConfig, shard: usize,
                        n_total: usize, horizon: f64) -> ExecCell {
        let shard_seed = cfg.seed ^ (shard as u64).wrapping_mul(PHI);
        let mut policy = make_shard_policy(&cfg.system, shard_seed,
                                           cfg.gpus_per_shard);
        if cfg.force_dense {
            policy = Box::new(DenseWrap(policy));
        }
        if cfg.gossip && cfg.shards >= 2 {
            policy.enable_gossip_log();
        }
        let tick = policy.tick_interval();
        let mut sim = cfg.sim.clone();
        sim.max_gpus = cfg.gpus_per_shard;
        let core = StreamCore::new(sim, PerfModel::default(), tick,
                                   n_total, horizon);
        ExecCell {
            shard,
            core,
            policy,
            gpus: cfg.gpus_per_shard as f64,
            w_coverage: cfg.w_coverage,
            w_queue: cfg.w_queue,
            w_headroom: cfg.w_headroom,
            absorbs: 0,
            stamp: (0, 0, 0),
            scores: HashMap::new(),
            hits: 0,
            misses: 0,
        }
    }

    pub(super) fn advance(&mut self, key: Option<(f64, u64)>) {
        self.core.advance_until(self.policy.as_mut(), &mut (), key);
    }

    /// The router score, memoized per `(llm, task)` while the staleness
    /// stamp holds. Bit-identical to [`ExecCell::score_uncached`] —
    /// enforced by the module tests below.
    pub(super) fn score(&mut self, llm: Llm, task_id: usize) -> f64 {
        let cur = (self.core.events_processed(),
                   self.core.rounds_executed(), self.absorbs);
        if cur != self.stamp {
            self.scores.clear();
            self.stamp = cur;
        }
        if let Some(&s) = self.scores.get(&(llm.index(), task_id)) {
            self.hits += 1;
            return s;
        }
        let s = self.score_uncached(llm, task_id);
        self.scores.insert((llm.index(), task_id), s);
        self.misses += 1;
        s
    }

    /// The raw weighted coverage/queue/headroom score — the exact
    /// arithmetic the sequential loop computed per arrival.
    pub(super) fn score_uncached(&self, llm: Llm, task_id: usize) -> f64 {
        let cov = self.policy.bank_coverage(llm, task_id).unwrap_or(0.0);
        let queued = (self.core.admitted() - self.core.done()) as f64
            / self.gpus;
        let busy = self.core.state().busy() / self.gpus;
        self.w_coverage * (1.0 - cov) + self.w_queue * queued
            + self.w_headroom * busy
    }

    pub(super) fn inject(&mut self, spec: JobSpec) {
        self.core.inject_arrival(self.policy.as_mut(), &mut (), spec);
    }

    pub(super) fn drain(&mut self) -> Vec<TunedPrompt> {
        let mut out = vec![];
        self.policy.drain_tuned(&mut out);
        out
    }

    /// Absorb gossip pools in ascending-origin order, skipping our own
    /// and empty pools — the sequential exchange order exactly.
    pub(super) fn absorb(&mut self, pools: &[(usize, Vec<TunedPrompt>)]) {
        for (origin, pool) in pools {
            if *origin != self.shard && !pool.is_empty() {
                self.policy.absorb_tuned(pool);
                self.absorbs += 1;
            }
        }
    }

    pub(super) fn exhaust(&mut self) {
        self.core.exhaust();
    }

    pub(super) fn is_finished(&self) -> bool {
        self.core.is_finished()
    }

    pub(super) fn finish(self, wall_s: f64) -> CellDone {
        let ExecCell { shard, core, policy, hits, misses, .. } = self;
        let admitted = core.admitted();
        CellDone {
            shard,
            admitted,
            cache_hits: hits,
            cache_misses: misses,
            result: core.finalize(policy.as_ref(), &mut (), wall_s),
        }
    }
}

/// What the plane's drive loop needs from an executor. Methods that
/// return data are barriers; the rest may complete asynchronously as
/// long as per-cell command order is preserved.
pub(super) trait PlaneExec {
    /// Advance every cell to the event key (None = run to completion).
    fn advance(&mut self, key: Option<(f64, u64)>);
    /// Router scores for all cells, in shard order. Barrier.
    fn scores(&mut self, llm: Llm, task_id: usize) -> Vec<f64>;
    /// Inject an arrival into one shard's cell.
    fn inject(&mut self, shard: usize, spec: JobSpec);
    /// Drain the gossip logs of the `alive` shards (ascending), as
    /// `(origin, pool)` pairs in ascending-origin order. Barrier.
    fn drain(&mut self, alive: &[usize]) -> Vec<(usize, Vec<TunedPrompt>)>;
    /// Cross-absorb the drained pools into every alive shard.
    fn absorb(&mut self, alive: &[usize],
              pools: Vec<(usize, Vec<TunedPrompt>)>);
    /// Mark the stream exhausted in every cell.
    fn exhaust(&mut self);
    /// Are all cells finished? Barrier.
    fn all_finished(&mut self) -> bool;
    /// Finalize every cell; results sorted by shard. Barrier.
    fn finish(&mut self, wall_s: f64) -> Vec<CellDone>;
}

/// The sequential executor: cells serviced inline on the caller's
/// thread, in shard order — `workers == 1` and the conformance
/// reference for the pool.
pub(super) struct InlineExec {
    cells: Vec<ExecCell>,
}

impl InlineExec {
    pub(super) fn new(cfg: &ShardPlaneConfig, n_total: usize,
                      horizon: f64) -> InlineExec {
        InlineExec {
            cells: (0..cfg.shards)
                .map(|s| ExecCell::build(cfg, s, n_total, horizon))
                .collect(),
        }
    }
}

impl PlaneExec for InlineExec {
    fn advance(&mut self, key: Option<(f64, u64)>) {
        for cell in &mut self.cells {
            cell.advance(key);
        }
    }

    fn scores(&mut self, llm: Llm, task_id: usize) -> Vec<f64> {
        self.cells.iter_mut().map(|c| c.score(llm, task_id)).collect()
    }

    fn inject(&mut self, shard: usize, spec: JobSpec) {
        self.cells[shard].inject(spec);
    }

    fn drain(&mut self, alive: &[usize]) -> Vec<(usize, Vec<TunedPrompt>)> {
        alive.iter().map(|&s| (s, self.cells[s].drain())).collect()
    }

    fn absorb(&mut self, alive: &[usize],
              pools: Vec<(usize, Vec<TunedPrompt>)>) {
        for &s in alive {
            self.cells[s].absorb(&pools);
        }
    }

    fn exhaust(&mut self) {
        for cell in &mut self.cells {
            cell.exhaust();
        }
    }

    fn all_finished(&mut self) -> bool {
        self.cells.iter().all(|c| c.is_finished())
    }

    fn finish(&mut self, wall_s: f64) -> Vec<CellDone> {
        std::mem::take(&mut self.cells)
            .into_iter()
            .map(|c| c.finish(wall_s))
            .collect()
    }
}

/// A broadcast command. Per-worker channels are FIFO, so every cell
/// observes commands in issue order.
#[derive(Clone)]
enum Cmd {
    Advance(Option<(f64, u64)>),
    Scores { llm: Llm, task_id: usize },
    Inject { shard: usize, spec: JobSpec },
    Drain { alive: Arc<Vec<usize>> },
    Absorb { alive: Arc<Vec<usize>>, pools: Arc<Vec<(usize, Vec<TunedPrompt>)>> },
    Exhaust,
    Finished,
    Finish { wall_s: f64 },
}

enum Reply {
    Scores(Vec<(usize, f64)>),
    Drained(Vec<(usize, Vec<TunedPrompt>)>),
    Finished(bool),
    Done(Vec<CellDone>),
}

/// The persistent fork-join pool. Workers own disjoint contiguous cell
/// slices (built inside the worker thread, so policies never cross
/// threads) and run until the command channel closes or `Finish`
/// arrives.
pub(super) struct PoolExec {
    txs: Vec<Sender<Cmd>>,
    rx: Receiver<Reply>,
    handles: Vec<JoinHandle<()>>,
    /// `shard → worker` for targeted injects.
    owner: Vec<usize>,
}

impl PoolExec {
    pub(super) fn new(cfg: &ShardPlaneConfig, workers: usize,
                      n_total: usize, horizon: f64) -> PoolExec {
        debug_assert!(workers >= 2 && workers <= cfg.shards);
        let (reply_tx, rx) = channel();
        let mut txs = Vec::with_capacity(workers);
        let mut handles = Vec::with_capacity(workers);
        let mut owner = vec![0usize; cfg.shards];
        // Balanced contiguous split: the first `rem` workers take one
        // extra shard, so every worker owns at least one cell.
        let base = cfg.shards / workers;
        let rem = cfg.shards % workers;
        let mut lo = 0usize;
        for w in 0..workers {
            let hi = lo + base + usize::from(w < rem);
            for s in lo..hi {
                owner[s] = w;
            }
            let (tx, cmd_rx) = channel();
            let worker_cfg = cfg.clone();
            let worker_reply = reply_tx.clone();
            let handle = thread::Builder::new()
                .name(format!("pt-plane-{w}"))
                .spawn(move || {
                    worker_loop(worker_cfg, lo..hi, n_total, horizon,
                                cmd_rx, worker_reply)
                })
                .expect("spawn shard-plane worker");
            txs.push(tx);
            handles.push(handle);
            lo = hi;
        }
        PoolExec { txs, rx, handles, owner }
    }

    /// A worker exited early (its cell's fatal audit panicked). Join
    /// everyone and re-raise the original panic so the caller sees the
    /// real failure, not a broken channel.
    fn fail(&mut self, what: &str) -> ! {
        self.txs.clear();
        for h in std::mem::take(&mut self.handles) {
            if let Err(payload) = h.join() {
                std::panic::resume_unwind(payload);
            }
        }
        panic!("shard-plane worker {what} without panicking");
    }

    fn broadcast(&mut self, cmd: Cmd) {
        for w in 0..self.txs.len() {
            if self.txs[w].send(cmd.clone()).is_err() {
                self.fail("closed its command channel");
            }
        }
    }

    fn recv(&mut self) -> Reply {
        match self.rx.recv() {
            Ok(r) => r,
            Err(_) => self.fail("closed the reply channel"),
        }
    }
}

impl PlaneExec for PoolExec {
    fn advance(&mut self, key: Option<(f64, u64)>) {
        self.broadcast(Cmd::Advance(key));
    }

    fn scores(&mut self, llm: Llm, task_id: usize) -> Vec<f64> {
        self.broadcast(Cmd::Scores { llm, task_id });
        let mut tagged: Vec<(usize, f64)> =
            Vec::with_capacity(self.owner.len());
        for _ in 0..self.txs.len() {
            match self.recv() {
                Reply::Scores(v) => tagged.extend(v),
                _ => self.fail("sent a mismatched reply"),
            }
        }
        tagged.sort_by_key(|&(s, _)| s);
        tagged.into_iter().map(|(_, score)| score).collect()
    }

    fn inject(&mut self, shard: usize, spec: JobSpec) {
        let w = self.owner[shard];
        if self.txs[w].send(Cmd::Inject { shard, spec }).is_err() {
            self.fail("closed its command channel");
        }
    }

    fn drain(&mut self, alive: &[usize]) -> Vec<(usize, Vec<TunedPrompt>)> {
        self.broadcast(Cmd::Drain { alive: Arc::new(alive.to_vec()) });
        let mut pools: Vec<(usize, Vec<TunedPrompt>)> =
            Vec::with_capacity(alive.len());
        for _ in 0..self.txs.len() {
            match self.recv() {
                Reply::Drained(v) => pools.extend(v),
                _ => self.fail("sent a mismatched reply"),
            }
        }
        pools.sort_by_key(|&(s, _)| s);
        pools
    }

    fn absorb(&mut self, alive: &[usize],
              pools: Vec<(usize, Vec<TunedPrompt>)>) {
        self.broadcast(Cmd::Absorb {
            alive: Arc::new(alive.to_vec()),
            pools: Arc::new(pools),
        });
    }

    fn exhaust(&mut self) {
        self.broadcast(Cmd::Exhaust);
    }

    fn all_finished(&mut self) -> bool {
        self.broadcast(Cmd::Finished);
        let mut all = true;
        for _ in 0..self.txs.len() {
            match self.recv() {
                Reply::Finished(f) => all &= f,
                _ => self.fail("sent a mismatched reply"),
            }
        }
        all
    }

    fn finish(&mut self, wall_s: f64) -> Vec<CellDone> {
        self.broadcast(Cmd::Finish { wall_s });
        let mut done: Vec<CellDone> = Vec::with_capacity(self.owner.len());
        for _ in 0..self.txs.len() {
            match self.recv() {
                Reply::Done(v) => done.extend(v),
                _ => self.fail("sent a mismatched reply"),
            }
        }
        done.sort_by_key(|d| d.shard);
        self.txs.clear();
        for h in self.handles.drain(..) {
            if let Err(payload) = h.join() {
                std::panic::resume_unwind(payload);
            }
        }
        done
    }
}

impl Drop for PoolExec {
    fn drop(&mut self) {
        // Disconnect the command channels so workers fall out of their
        // recv loops, then reap them. A normal `finish` already did
        // both; this covers early unwinds in the drive loop.
        self.txs.clear();
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(cfg: ShardPlaneConfig, shards: std::ops::Range<usize>,
               n_total: usize, horizon: f64, rx: Receiver<Cmd>,
               tx: Sender<Reply>) {
    let mut cells: Vec<ExecCell> = shards
        .map(|s| ExecCell::build(&cfg, s, n_total, horizon))
        .collect();
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Advance(key) => {
                for cell in &mut cells {
                    cell.advance(key);
                }
            }
            Cmd::Scores { llm, task_id } => {
                let v: Vec<(usize, f64)> = cells
                    .iter_mut()
                    .map(|c| (c.shard, c.score(llm, task_id)))
                    .collect();
                let _ = tx.send(Reply::Scores(v));
            }
            Cmd::Inject { shard, spec } => {
                let cell = cells
                    .iter_mut()
                    .find(|c| c.shard == shard)
                    .expect("inject routed to the wrong worker");
                cell.inject(spec);
            }
            Cmd::Drain { alive } => {
                let v: Vec<(usize, Vec<TunedPrompt>)> = cells
                    .iter_mut()
                    .filter(|c| alive.contains(&c.shard))
                    .map(|c| (c.shard, c.drain()))
                    .collect();
                let _ = tx.send(Reply::Drained(v));
            }
            Cmd::Absorb { alive, pools } => {
                for cell in &mut cells {
                    if alive.contains(&cell.shard) {
                        cell.absorb(&pools);
                    }
                }
            }
            Cmd::Exhaust => {
                for cell in &mut cells {
                    cell.exhaust();
                }
            }
            Cmd::Finished => {
                let all = cells.iter().all(|c| c.is_finished());
                let _ = tx.send(Reply::Finished(all));
            }
            Cmd::Finish { wall_s } => {
                let done: Vec<CellDone> = cells
                    .drain(..)
                    .map(|c| c.finish(wall_s))
                    .collect();
                let _ = tx.send(Reply::Done(done));
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::TunedPrompt;
    use crate::scenario::NOVEL_TASK_BASE;
    use crate::trace::{ScaleSource, ScaleSourceConfig, TraceSource};

    fn cold_cell(seed: u64) -> (ExecCell, ScaleSource) {
        let src = ScaleSource::new(ScaleSourceConfig {
            seed,
            minutes: 10,
            jobs_per_minute: 8.0,
            n_tasks: 6,
            task_base: NOVEL_TASK_BASE,
            ..Default::default()
        });
        let cfg = ShardPlaneConfig::new("prompttuner", 2, 16, seed);
        let horizon = src.last_arrival_s() + cfg.sim.horizon_s;
        let cell = ExecCell::build(&cfg, 0, src.total_jobs(), horizon);
        (cell, src)
    }

    /// The staleness stamp is sound: a cached score is always bit-equal
    /// to a fresh recompute, before and after events, and an event
    /// (inject) always invalidates.
    #[test]
    fn score_cache_never_serves_stale_scores() {
        let (mut cell, mut src) = cold_cell(7);
        let mut injected = 0u64;
        let mut saw_hit = false;
        while let Some(spec) = src.next_job() {
            cell.advance(Some((spec.submit_s, injected + 1)));
            let fresh = cell.score_uncached(spec.llm, spec.task_id);
            let miss0 = cell.misses;
            let s1 = cell.score(spec.llm, spec.task_id);
            assert_eq!(s1.to_bits(), fresh.to_bits(),
                       "first score diverged from uncached");
            let hits0 = cell.hits;
            let s2 = cell.score(spec.llm, spec.task_id);
            assert_eq!(s2.to_bits(), s1.to_bits());
            assert_eq!(cell.hits, hits0 + 1, "repeat lookup must hit");
            saw_hit = true;
            cell.inject(spec.clone());
            injected += 1;
            // The inject bumped the cell's event count: the stamp is
            // stale, so the next score recomputes and matches fresh.
            let miss1 = cell.misses;
            let s3 = cell.score(spec.llm, spec.task_id);
            assert_eq!(cell.misses, miss1 + 1,
                       "score after an event must recompute");
            assert_eq!(
                s3.to_bits(),
                cell.score_uncached(spec.llm, spec.task_id).to_bits()
            );
            assert!(cell.misses > miss0);
        }
        assert!(saw_hit);
        assert!(cell.hits > 0 && cell.misses > 0);
    }

    /// Absorbing gossip changes the bank without an event or round —
    /// the absorb counter must invalidate the cache.
    #[test]
    fn absorbing_gossip_invalidates_cached_scores() {
        let (mut cell, mut src) = cold_cell(11);
        // Warm the cell with a few jobs so scoring is non-trivial.
        let mut injected = 0u64;
        let mut last = None;
        for _ in 0..5 {
            let spec = src.next_job().unwrap();
            cell.advance(Some((spec.submit_s, injected + 1)));
            last = Some((spec.llm, spec.task_id));
            cell.inject(spec);
            injected += 1;
        }
        let (llm, task_id) = last.unwrap();
        let before = cell.score(llm, task_id);
        let hits0 = cell.hits;
        assert_eq!(cell.score(llm, task_id).to_bits(), before.to_bits());
        assert_eq!(cell.hits, hits0 + 1);

        // A foreign shard gossips a near-perfect prompt for this task.
        let pools = vec![(1usize, vec![TunedPrompt {
            llm,
            task_id,
            quality: 0.99,
        }])];
        let misses0 = cell.misses;
        cell.absorb(&pools);
        let after = cell.score(llm, task_id);
        assert_eq!(cell.misses, misses0 + 1,
                   "absorb must invalidate the score cache");
        assert_eq!(after.to_bits(),
                   cell.score_uncached(llm, task_id).to_bits());
        assert!(after <= before,
                "a 0.99-quality prompt cannot worsen coverage: \
                 {after} > {before}");

        // A pool from our own shard is skipped and must NOT invalidate.
        let own = vec![(0usize, vec![TunedPrompt {
            llm,
            task_id,
            quality: 0.5,
        }])];
        let absorbs0 = cell.absorbs;
        cell.absorb(&own);
        assert_eq!(cell.absorbs, absorbs0, "own-origin pool absorbed");
    }
}
