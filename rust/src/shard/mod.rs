//! Hyperscale shard plane: N simulated cells, one router, gossiped banks.
//!
//! One `StreamCore` scales to a few hundred GPUs before the scheduling
//! policy itself becomes the bottleneck — the paper's control plane is
//! per-cluster by design (§5). This module scales *out* instead of up:
//! `shards` independent cells each run a local policy (PromptTuner,
//! INFless or ElasticFlow) over their own cluster state and Prompt-Bank,
//! and a thin global router places each arrival by a weighted score of
//!
//! * **bank coverage** — the quality the shard's bank already realizes
//!   for the job's `(llm, task)` (the [`crate::cluster::Policy::
//!   bank_coverage`] hook), so work lands where its prompts are warm;
//! * **queue depth** — outstanding (admitted − done) jobs per GPU;
//! * **headroom** — the shard's busy-GPU fraction.
//!
//! Lower score wins; ties break to the lowest shard index, so routing is
//! a pure function of (seed, trace, round) and bit-deterministic.
//!
//! **Gossip.** Every `gossip_period_s` the plane advances all cells to
//! the barrier instant and exchanges first-hand tuned prompts (the
//! Fig 5b completion-feedback edge, stretched across shards): each live
//! shard drains its [`crate::cluster::TunedPrompt`] log and every other
//! live shard absorbs it. Absorbed prompts are *not* re-logged, so an
//! item crosses each shard boundary at most once and traffic stays
//! O(tuned × shards) per period. With gossip off no log is even
//! recorded, which keeps a 1-shard plane bit-identical to the unsharded
//! simulator (property-enforced by `tests/prop_shard.rs`).
//!
//! **Partitions.** [`PartitionSchedule`] (fed by `ChaosProfile::
//! partition`) severs one pseudo-randomly chosen shard per period from
//! the router for a window: local scheduling continues, routing fails
//! over to the surviving shards, and the severed shard neither drains
//! nor absorbs gossip until a barrier finds it healed (its log simply
//! accumulates — nothing is lost). The plane audits, StateAudit-style,
//! that no job is routed into a severed shard while an alternative
//! exists and that every streamed job is admitted exactly once; any
//! breach lands in [`ShardPlaneResult::violations`].
//!
//! The barrier instant `t_k` uses event key `(t_k, 0)`: sequence 0 sorts
//! before every real event, so cells stop *before* anything scheduled at
//! the barrier time — the exchange is a consistent cut.
//!
//! **Parallel execution.** Between router decisions and gossip barriers
//! the cells are completely independent, so the plane advances them on
//! a persistent fork-join worker pool ([`exec`]): each worker owns a
//! disjoint slice of cells and services broadcast commands over FIFO
//! channels, with a reply barrier (merged in shard order) before every
//! sequential decision. Each cell sees the identical command sequence
//! regardless of thread interleaving, so the parallel plane is
//! **bit-identical** to the sequential one (`workers == 1`) — enforced
//! by `tests/prop_shard.rs` across systems × gossip × partitions.
//! Width comes from [`ShardPlaneConfig::workers`], defaulting to
//! `PT_PLANE_WORKERS` or the machine's available parallelism. Router
//! scores are memoized per `(llm, task)` behind an event/round/absorb
//! staleness stamp, so idle cells answer from cache.

mod exec;

use std::time::Instant;

use exec::{InlineExec, PlaneExec, PoolExec};

use crate::baselines::{ElasticFlow, ElasticFlowConfig, Infless,
                       InflessConfig};
use crate::cluster::{ClusterState, KnobSpec, Policy, RetryEvent,
                     RevokeEvent, SimConfig, SimResult, TunedPrompt,
                     TunerReport, Wake};
use crate::coordinator::{PromptTuner, PromptTunerConfig};
use crate::fault::ChaosProfile;
use crate::trace::TraceSource;
use crate::workload::Llm;

const PHI: u64 = 0x9E37_79B9_7F4A_7C15;

/// Configuration of a sharded simulation plane.
#[derive(Clone, Debug)]
pub struct ShardPlaneConfig {
    /// Number of cells. 1 reproduces the unsharded simulator exactly.
    pub shards: usize,
    /// Provider budget of each cell (total plane capacity is the
    /// product).
    pub gpus_per_shard: usize,
    /// "prompttuner" | "infless" | "elasticflow" — every shard runs the
    /// same system, seeded per shard (shard 0 keeps the plane seed).
    pub system: String,
    pub seed: u64,
    /// Cross-shard prompt synchronization (ignored below 2 shards).
    pub gossip: bool,
    /// Gossip barrier period, seconds.
    pub gossip_period_s: f64,
    /// Network-partition chaos: `partition_period_s`/`partition_s` of
    /// the profile drive a [`PartitionSchedule`]; None = no partitions.
    pub partition: Option<ChaosProfile>,
    /// Per-shard simulator config; `max_gpus` is overridden with
    /// `gpus_per_shard`.
    pub sim: SimConfig,
    /// Router weight on (1 − bank coverage).
    pub w_coverage: f64,
    /// Router weight on queued jobs per GPU.
    pub w_queue: f64,
    /// Router weight on the busy-GPU fraction.
    pub w_headroom: f64,
    /// Pin every shard policy to dense ticking (coalescing-vs-dense
    /// equivalence runs).
    pub force_dense: bool,
    /// Fork-join executor width (worker threads advancing cells in
    /// parallel). Clamped to `[1, shards]` at run time; `1` services
    /// the cells inline and reproduces the sequential loop exactly —
    /// and any width is bit-identical to it (property-enforced).
    pub workers: usize,
}

/// The default executor width: `PT_PLANE_WORKERS` (a positive integer)
/// when set, else the machine's available parallelism, else 1.
pub fn default_plane_workers() -> usize {
    if let Ok(v) = std::env::var("PT_PLANE_WORKERS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n >= 1 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

impl ShardPlaneConfig {
    pub fn new(system: impl Into<String>, shards: usize,
               gpus_per_shard: usize, seed: u64) -> Self {
        ShardPlaneConfig {
            shards,
            gpus_per_shard,
            system: system.into(),
            seed,
            gossip: true,
            gossip_period_s: 900.0,
            partition: None,
            sim: SimConfig { max_gpus: gpus_per_shard, ..Default::default() },
            w_coverage: 1.0,
            w_queue: 1.0,
            w_headroom: 0.5,
            force_dense: false,
            workers: default_plane_workers(),
        }
    }
}

/// Deterministic partition chaos: in window `k` (of `partition_period_s`
/// seconds) one pseudo-randomly chosen victim shard is severed from the
/// router for the first `partition_s` seconds. Pure functions of
/// `(seed, k)` — no state, so repeats and dense-vs-coalesced runs agree
/// bit-for-bit.
#[derive(Clone, Debug)]
pub struct PartitionSchedule {
    seed: u64,
    shards: usize,
    period_s: f64,
    window_s: f64,
}

fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(PHI);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl PartitionSchedule {
    /// Build from a chaos profile's partition knobs; None when the
    /// profile carries no partition window.
    pub fn from_profile(profile: &ChaosProfile, seed: u64,
                        shards: usize) -> Option<Self> {
        if profile.partition_period_s <= 0.0 || profile.partition_s <= 0.0 {
            return None;
        }
        Some(PartitionSchedule {
            seed,
            shards,
            period_s: profile.partition_period_s,
            window_s: profile.partition_s,
        })
    }

    /// The shard severed during period `k`.
    pub fn victim(&self, k: u64) -> usize {
        (mix64(self.seed ^ (k + 1).wrapping_mul(PHI)) % self.shards as u64)
            as usize
    }

    /// Is `shard` severed from the router at time `t`?
    pub fn severed(&self, shard: usize, t: f64) -> bool {
        if t < 0.0 || self.shards < 2 {
            return false;
        }
        let k = (t / self.period_s).floor();
        let start = k * self.period_s;
        t - start < self.window_s && self.victim(k as u64) == shard
    }
}

/// Build the bare (ungoverned, fault-free) policy a shard runs — the
/// same construction as `bench::make_policy`'s bare-system arm, so the
/// 1-shard conformance property can build an identical reference.
pub fn make_shard_policy(system: &str, seed: u64,
                         gpus: usize) -> Box<dyn Policy> {
    match system {
        "prompttuner" => Box::new(PromptTuner::new(PromptTunerConfig {
            seed,
            max_gpus: gpus,
            ..Default::default()
        })),
        "infless" => Box::new(Infless::new(InflessConfig {
            max_gpus: gpus,
            seed,
            ..Default::default()
        })),
        "elasticflow" => Box::new(ElasticFlow::new(ElasticFlowConfig {
            cluster_size: gpus,
            seed,
            ..Default::default()
        })),
        other => panic!("unknown system {other}"),
    }
}

/// Forces dense ticking on a wrapped policy while forwarding everything
/// else — the shard-plane analogue of the dense oracle wrapper the
/// equivalence properties use.
struct DenseWrap(Box<dyn Policy>);

impl Policy for DenseWrap {
    fn name(&self) -> &str {
        self.0.name()
    }
    fn tick_interval(&self) -> f64 {
        self.0.tick_interval()
    }
    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        self.0.on_arrival(st, job_id)
    }
    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        self.0.on_job_complete(st, job_id)
    }
    fn on_tick(&mut self, st: &mut ClusterState) {
        self.0.on_tick(st)
    }
    fn next_timed_action(&self, _st: &ClusterState) -> Wake {
        Wake::Dense
    }
    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        self.0.on_revoke(st, ev)
    }
    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        self.0.on_retry(st, ev)
    }
    fn capacity(&self) -> Option<usize> {
        self.0.capacity()
    }
    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        self.0.set_capacity(st, gpus)
    }
    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        self.0.bank_coverage(llm, task_id)
    }
    fn enable_gossip_log(&mut self) {
        self.0.enable_gossip_log()
    }
    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        self.0.drain_tuned(out)
    }
    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        self.0.absorb_tuned(items)
    }
    fn knobs(&self) -> Vec<KnobSpec> {
        self.0.knobs()
    }
    fn knob_value(&self, name: &str) -> Option<f64> {
        self.0.knob_value(name)
    }
    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        self.0.set_knob(st, name, value)
    }
    fn tuner_report(&self) -> Option<TunerReport> {
        self.0.tuner_report()
    }
}

/// Result of one plane run: per-shard simulator results plus the
/// plane-level routing/gossip/audit telemetry.
#[derive(Clone, Debug)]
pub struct ShardPlaneResult {
    pub system: String,
    pub shards: usize,
    pub gpus_per_shard: usize,
    pub per_shard: Vec<SimResult>,
    /// Jobs the router placed on each shard (sums to the trace length).
    pub routed: Vec<usize>,
    /// Gossip barriers at which an exchange actually happened.
    pub gossip_rounds: u64,
    /// First-hand tuned prompts drained across all exchanges.
    pub gossip_items: u64,
    /// Arrivals placed while *every* shard was severed (best-effort
    /// placement rather than job loss).
    pub failovers: u64,
    /// Plane-invariant breaches (empty on a correct run): a job routed
    /// into a severed shard while an alternative existed, or jobs
    /// lost/duplicated between router and cells.
    pub violations: Vec<String>,
    /// Executor width the run actually used (after clamping to
    /// `[1, shards]`).
    pub workers: usize,
    /// Wall-clock of the whole plane run, seconds.
    pub wall_s: f64,
    /// Router-score cache hits across all cells (scores served from
    /// the memo because the cell's staleness stamp had not moved).
    pub score_cache_hits: u64,
    /// Router-score cache misses (fresh recomputes) across all cells.
    pub score_cache_misses: u64,
}

impl ShardPlaneResult {
    /// Fold the per-shard results into one cluster-of-clusters summary.
    /// Counters add; means weight by their natural denominators; the
    /// utilization timeline is per-shard telemetry and stays empty here.
    pub fn merged(&self) -> SimResult {
        assert!(!self.per_shard.is_empty());
        let billed: f64 =
            self.per_shard.iter().map(|r| r.gpu_seconds_billed).sum();
        let n_done: usize = self.per_shard.iter().map(|r| r.n_done).sum();
        let rounds: u64 =
            self.per_shard.iter().map(|r| r.rounds_executed).sum();
        let mean_utilization = if billed > 0.0 {
            self.per_shard
                .iter()
                .map(|r| r.mean_utilization * r.gpu_seconds_billed)
                .sum::<f64>()
                / billed
        } else {
            0.0
        };
        let mean_prompt_quality = if n_done > 0 {
            self.per_shard
                .iter()
                .map(|r| r.mean_prompt_quality * r.n_done as f64)
                .sum::<f64>()
                / n_done as f64
        } else {
            0.0
        };
        let sched_overhead_ms_mean = if rounds > 0 {
            self.per_shard
                .iter()
                .map(|r| r.sched_overhead_ms_mean * r.rounds_executed as f64)
                .sum::<f64>()
                / rounds as f64
        } else {
            0.0
        };
        SimResult {
            policy: format!("{}@{}x{}", self.system, self.shards,
                            self.gpus_per_shard),
            n_jobs: self.per_shard.iter().map(|r| r.n_jobs).sum(),
            n_done,
            n_violations: self.per_shard.iter().map(|r| r.n_violations).sum(),
            cost_usd: self.per_shard.iter().map(|r| r.cost_usd).sum(),
            gpu_seconds_billed: billed,
            gpu_seconds_busy: self
                .per_shard
                .iter()
                .map(|r| r.gpu_seconds_busy)
                .sum(),
            mean_utilization,
            util_timeline: vec![],
            job_latencies: self
                .per_shard
                .iter()
                .flat_map(|r| r.job_latencies.iter().copied())
                .collect(),
            job_quality: self
                .per_shard
                .iter()
                .flat_map(|r| r.job_quality.iter().copied())
                .collect(),
            mean_prompt_quality,
            sched_overhead_ms_mean,
            sched_overhead_ms_max: self
                .per_shard
                .iter()
                .map(|r| r.sched_overhead_ms_max)
                .fold(0.0, f64::max),
            rounds_executed: rounds,
            rounds_coalesced: self
                .per_shard
                .iter()
                .map(|r| r.rounds_coalesced)
                .sum(),
            events_processed: self
                .per_shard
                .iter()
                .map(|r| r.events_processed)
                .sum(),
            revocations: self.per_shard.iter().map(|r| r.revocations).sum(),
            lost_iters: self.per_shard.iter().map(|r| r.lost_iters).sum(),
            straggler_iters: self
                .per_shard
                .iter()
                .map(|r| r.straggler_iters)
                .sum(),
            retries: self.per_shard.iter().map(|r| r.retries).sum(),
            retry_iters: self.per_shard.iter().map(|r| r.retry_iters).sum(),
            chaos_delay_s: self
                .per_shard
                .iter()
                .map(|r| r.chaos_delay_s)
                .sum(),
            wall_s: self
                .per_shard
                .iter()
                .map(|r| r.wall_s)
                .fold(0.0, f64::max),
        }
    }
}

/// The sharded plane itself. Construct with a validated config, then
/// [`ShardPlane::run`] any [`TraceSource`] through it.
pub struct ShardPlane {
    pub cfg: ShardPlaneConfig,
}

impl ShardPlane {
    pub fn new(cfg: ShardPlaneConfig) -> Self {
        assert!(cfg.shards >= 1, "shard plane needs at least one shard");
        assert!(cfg.gpus_per_shard >= 1, "shards need GPUs");
        assert!(cfg.gossip_period_s > 0.0 && cfg.gossip_period_s.is_finite(),
                "gossip period must be positive");
        for w in [cfg.w_coverage, cfg.w_queue, cfg.w_headroom] {
            assert!(w.is_finite() && w >= 0.0,
                    "router weights must be finite and non-negative");
        }
        ShardPlane { cfg }
    }

    /// Run the whole stream through the plane. Every arrival is placed
    /// on exactly one shard; determinism is inherited from the cells
    /// (seeded policies, seq-ordered events) plus the router and
    /// schedule being pure functions — and is independent of the
    /// executor width (`workers == 1` runs the cells inline, wider
    /// runs them on the fork-join pool, bit-identically).
    pub fn run(&self, source: &mut dyn TraceSource) -> ShardPlaneResult {
        let n_total = source.total_jobs();
        let horizon = source.last_arrival_s() + self.cfg.sim.horizon_s;
        let workers = self.cfg.workers.max(1).min(self.cfg.shards);
        if workers == 1 {
            let exec = InlineExec::new(&self.cfg, n_total, horizon);
            self.drive(source, exec, workers, n_total, horizon)
        } else {
            let exec = PoolExec::new(&self.cfg, workers, n_total, horizon);
            self.drive(source, exec, workers, n_total, horizon)
        }
    }

    /// The sequential decision loop, generic over the executor that
    /// services the cells. Both executors observe the identical
    /// command sequence, which is what makes width a pure performance
    /// knob.
    fn drive<E: PlaneExec>(&self, source: &mut dyn TraceSource,
                           mut exec: E, workers: usize, n_total: usize,
                           horizon: f64) -> ShardPlaneResult {
        let wall0 = Instant::now();
        let n_shards = self.cfg.shards;
        let sched = self.cfg.partition.as_ref().and_then(|p| {
            PartitionSchedule::from_profile(p, self.cfg.seed, n_shards)
        });
        let gossip_on = self.cfg.gossip && n_shards >= 2;

        let mut routed = vec![0usize; n_shards];
        let mut violations: Vec<String> = vec![];
        let mut failovers = 0u64;
        let mut gossip_rounds = 0u64;
        let mut gossip_items = 0u64;
        let mut next_k = 1u64;
        let mut injected = 0u64;

        while let Some(spec) = source.next_job() {
            // Barriers due at or before this arrival fire first, so the
            // router sees post-exchange coverage.
            while gossip_on
                && next_k as f64 * self.cfg.gossip_period_s <= spec.submit_s
            {
                let t_k = next_k as f64 * self.cfg.gossip_period_s;
                if let Some(items) =
                    barrier_step(&mut exec, n_shards, t_k, sched.as_ref())
                {
                    gossip_rounds += 1;
                    gossip_items += items;
                }
                next_k += 1;
            }
            // Advance every cell to the arrival's global event key —
            // seq i+1, the sequence the materialized loop pre-assigns
            // to arrival i — so all cells observe a consistent "now".
            exec.advance(Some((spec.submit_s, injected + 1)));
            let t = spec.submit_s;
            let scores = exec.scores(spec.llm, spec.task_id);
            let mut best: Option<(f64, usize)> = None;
            let mut best_any: Option<(f64, usize)> = None;
            for (s, &score) in scores.iter().enumerate() {
                // Strict < keeps the earliest index on ties.
                if best_any.is_none() || score < best_any.unwrap().0 {
                    best_any = Some((score, s));
                }
                let severed =
                    sched.as_ref().is_some_and(|p| p.severed(s, t));
                if !severed && (best.is_none() || score < best.unwrap().0) {
                    best = Some((score, s));
                }
            }
            let target = match best {
                Some((_, s)) => s,
                None => {
                    // Every shard severed: place best-effort rather than
                    // drop the job.
                    failovers += 1;
                    best_any.expect("plane has at least one shard").1
                }
            };
            if let Some(p) = sched.as_ref() {
                if p.severed(target, t)
                    && (0..n_shards)
                        .any(|s| s != target && !p.severed(s, t))
                {
                    violations.push(format!(
                        "job {injected} routed into severed shard {target} \
                         at t={t:.3} with alternatives live"
                    ));
                }
            }
            exec.inject(target, spec);
            routed[target] += 1;
            injected += 1;
        }

        // Stream exhausted: each cell now ends once its admitted jobs
        // are done. Keep gossiping until everyone is finished or the
        // horizon passes — queued jobs still launch and read banks.
        exec.exhaust();
        while gossip_on {
            let t_k = next_k as f64 * self.cfg.gossip_period_s;
            if t_k > horizon || exec.all_finished() {
                break;
            }
            if let Some(items) =
                barrier_step(&mut exec, n_shards, t_k, sched.as_ref())
            {
                gossip_rounds += 1;
                gossip_items += items;
            }
            next_k += 1;
        }
        exec.advance(None);

        let wall_s = wall0.elapsed().as_secs_f64();
        let done = exec.finish(wall_s);

        // Conservation audit: router placements and cell admissions must
        // both account for every streamed job exactly once.
        let admitted: usize = done.iter().map(|d| d.admitted).sum();
        if admitted != n_total {
            violations.push(format!(
                "plane admitted {admitted} of {n_total} streamed jobs"
            ));
        }
        for d in &done {
            if d.admitted != routed[d.shard] {
                violations.push(format!(
                    "shard {}: router placed {} jobs but the cell \
                     admitted {}",
                    d.shard, routed[d.shard], d.admitted
                ));
            }
        }

        let score_cache_hits = done.iter().map(|d| d.cache_hits).sum();
        let score_cache_misses =
            done.iter().map(|d| d.cache_misses).sum();
        let per_shard: Vec<SimResult> =
            done.into_iter().map(|d| d.result).collect();
        ShardPlaneResult {
            system: self.cfg.system.clone(),
            shards: n_shards,
            gpus_per_shard: self.cfg.gpus_per_shard,
            per_shard,
            routed,
            gossip_rounds,
            gossip_items,
            failovers,
            violations,
            workers,
            wall_s,
            score_cache_hits,
            score_cache_misses,
        }
    }
}

/// Advance every cell to the barrier cut `(t_k, 0)` and exchange
/// first-hand tuned prompts among the shards the partition schedule
/// leaves connected at `t_k`. Returns the number of items drained, or
/// None when fewer than two shards were reachable (nothing is drained
/// then — severed logs keep accumulating and deliver at heal).
fn barrier_step<E: PlaneExec>(exec: &mut E, n_shards: usize, t_k: f64,
                              sched: Option<&PartitionSchedule>)
                              -> Option<u64> {
    exec.advance(Some((t_k, 0)));
    let alive: Vec<usize> = (0..n_shards)
        .filter(|&s| !sched.is_some_and(|p| p.severed(s, t_k)))
        .collect();
    if alive.len() < 2 {
        return None;
    }
    let pools = exec.drain(&alive);
    let drained: u64 = pools.iter().map(|(_, p)| p.len() as u64).sum();
    exec.absorb(&alive, pools);
    Some(drained)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::Simulator;
    use crate::trace::{Load, ScaleSource, ScaleSourceConfig, TraceConfig,
                       TraceGenerator, VecSource};
    use crate::workload::PerfModel;

    fn small_trace(seed: u64) -> Vec<crate::workload::JobSpec> {
        let mut g = TraceGenerator::new(
            TraceConfig { seed, ..Default::default() },
            PerfModel::default(),
        );
        g.generate_main(Load::Low)
    }

    #[test]
    fn partition_schedule_is_deterministic_and_windowed() {
        let prof = ChaosProfile::partition();
        let a = PartitionSchedule::from_profile(&prof, 9, 4).unwrap();
        let b = PartitionSchedule::from_profile(&prof, 9, 4).unwrap();
        for k in 0..32 {
            assert!(a.victim(k) < 4);
            assert_eq!(a.victim(k), b.victim(k), "schedule not a pure fn");
        }
        // Victims move with the seed (32 draws over 4 shards).
        let c = PartitionSchedule::from_profile(&prof, 10, 4).unwrap();
        assert!((0..32).any(|k| a.victim(k) != c.victim(k)));
        // Window semantics: severed in [k·period, k·period + window).
        let k = 3u64;
        let v = a.victim(k);
        let start = k as f64 * 600.0;
        assert!(a.severed(v, start));
        assert!(a.severed(v, start + 119.9));
        assert!(!a.severed(v, start + 120.0));
        for s in 0..4 {
            if s != v {
                assert!(!a.severed(s, start + 10.0));
            }
        }
        // Profiles without partition knobs yield no schedule.
        assert!(PartitionSchedule::from_profile(
            &ChaosProfile::latency_tail(), 9, 4)
            .is_none());
    }

    #[test]
    fn one_shard_plane_matches_unsharded_simulator() {
        let jobs = small_trace(3);
        let mut cfg = ShardPlaneConfig::new("prompttuner", 1, 32, 3);
        cfg.gossip = false;
        let plane = ShardPlane::new(cfg);
        let pr = plane.run(&mut VecSource::new(jobs.clone()));
        assert!(pr.violations.is_empty(), "{:?}", pr.violations);

        let sim = Simulator::new(
            SimConfig { max_gpus: 32, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = make_shard_policy("prompttuner", 3, 32);
        let reference = sim.run(policy.as_mut(), jobs);

        let s = &pr.per_shard[0];
        assert_eq!(s.n_jobs, reference.n_jobs);
        assert_eq!(s.n_done, reference.n_done);
        assert_eq!(s.n_violations, reference.n_violations);
        assert_eq!(s.rounds_executed, reference.rounds_executed);
        assert_eq!(s.events_processed, reference.events_processed);
        assert_eq!(s.cost_usd.to_bits(), reference.cost_usd.to_bits());
        assert_eq!(s.mean_prompt_quality.to_bits(),
                   reference.mean_prompt_quality.to_bits());
        assert_eq!(s.job_quality.len(), reference.job_quality.len());
        for (x, y) in s.job_quality.iter().zip(&reference.job_quality) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
    }

    #[test]
    fn plane_conserves_jobs_and_replays_deterministically() {
        let src = ScaleSourceConfig {
            seed: 21,
            minutes: 20,
            jobs_per_minute: 6.0,
            n_tasks: 16,
            task_base: crate::scenario::NOVEL_TASK_BASE,
            ..Default::default()
        };
        let mut pc = ShardPlaneConfig::new("prompttuner", 3, 16, 21);
        pc.gossip_period_s = 300.0;
        let plane = ShardPlane::new(pc.clone());
        let r1 = plane.run(&mut ScaleSource::new(src.clone()));
        let total = ScaleSource::new(src.clone()).total_jobs();
        assert_eq!(r1.routed.iter().sum::<usize>(), total);
        assert!(r1.violations.is_empty(), "{:?}", r1.violations);
        assert!(r1.routed.iter().all(|&n| n > 0),
                "router starved a shard: {:?}", r1.routed);

        let r2 = ShardPlane::new(pc).run(&mut ScaleSource::new(src));
        assert_eq!(r1.routed, r2.routed);
        let (m1, m2) = (r1.merged(), r2.merged());
        assert_eq!(m1.n_jobs, total);
        assert_eq!(m1.n_done, m2.n_done);
        assert_eq!(m1.cost_usd.to_bits(), m2.cost_usd.to_bits());
        assert_eq!(m1.policy, "prompttuner@3x16");
    }

    #[test]
    fn gossip_exchanges_prompts_and_lifts_quality() {
        let src = ScaleSourceConfig {
            seed: 33,
            minutes: 30,
            jobs_per_minute: 8.0,
            n_tasks: 8,
            task_base: crate::scenario::NOVEL_TASK_BASE,
            ..Default::default()
        };
        let mut on = ShardPlaneConfig::new("prompttuner", 2, 16, 33);
        on.gossip_period_s = 120.0;
        let mut off = on.clone();
        off.gossip = false;
        let r_on = ShardPlane::new(on).run(&mut ScaleSource::new(src.clone()));
        let r_off = ShardPlane::new(off).run(&mut ScaleSource::new(src));
        assert!(r_on.gossip_rounds > 0);
        assert!(r_on.gossip_items > 0, "no prompts crossed shards");
        assert_eq!(r_off.gossip_items, 0);
        assert!(r_on.violations.is_empty() && r_off.violations.is_empty());
        // Shared tuned prompts can only help cold novel tasks.
        assert!(r_on.merged().mean_prompt_quality + 1e-12
                    >= r_off.merged().mean_prompt_quality,
                "gossip lowered quality: {} < {}",
                r_on.merged().mean_prompt_quality,
                r_off.merged().mean_prompt_quality);
    }

    #[test]
    fn pool_executor_matches_inline_and_clamps_width() {
        let src = ScaleSourceConfig {
            seed: 55,
            minutes: 15,
            jobs_per_minute: 8.0,
            n_tasks: 8,
            task_base: crate::scenario::NOVEL_TASK_BASE,
            ..Default::default()
        };
        let mut pc = ShardPlaneConfig::new("prompttuner", 3, 16, 55);
        pc.gossip_period_s = 300.0;
        let run = |w: usize| {
            let mut cfg = pc.clone();
            cfg.workers = w;
            ShardPlane::new(cfg).run(&mut ScaleSource::new(src.clone()))
        };
        let seq = run(1);
        assert_eq!(seq.workers, 1);
        let par = run(2);
        assert_eq!(par.workers, 2);
        // Width 8 clamps to the shard count.
        let wide = run(8);
        assert_eq!(wide.workers, 3);
        for other in [&par, &wide] {
            assert_eq!(seq.routed, other.routed);
            assert_eq!(seq.gossip_rounds, other.gossip_rounds);
            assert_eq!(seq.gossip_items, other.gossip_items);
            assert_eq!(seq.merged().cost_usd.to_bits(),
                       other.merged().cost_usd.to_bits());
            assert_eq!(seq.merged().mean_prompt_quality.to_bits(),
                       other.merged().mean_prompt_quality.to_bits());
            // The memo sees the same lookup stream either way.
            assert_eq!(seq.score_cache_hits, other.score_cache_hits);
            assert_eq!(seq.score_cache_misses, other.score_cache_misses);
        }
        assert!(seq.score_cache_misses > 0);
    }

    #[test]
    fn partition_windows_divert_routing_without_losing_jobs() {
        let src = ScaleSourceConfig {
            seed: 44,
            minutes: 30,
            jobs_per_minute: 6.0,
            ..Default::default()
        };
        let mut pc = ShardPlaneConfig::new("infless", 3, 16, 44);
        pc.gossip_period_s = 300.0;
        pc.partition = Some(ChaosProfile::partition());
        let r1 = ShardPlane::new(pc.clone())
            .run(&mut ScaleSource::new(src.clone()));
        assert!(r1.violations.is_empty(), "{:?}", r1.violations);
        assert_eq!(r1.failovers, 0,
                   "3-shard plane never loses every alternative");
        let total = ScaleSource::new(src.clone()).total_jobs();
        assert_eq!(r1.routed.iter().sum::<usize>(), total);

        let r2 = ShardPlane::new(pc).run(&mut ScaleSource::new(src));
        assert_eq!(r1.routed, r2.routed, "partitioned routing not replayable");
        assert_eq!(r1.merged().cost_usd.to_bits(),
                   r2.merged().cost_usd.to_bits());
    }
}
