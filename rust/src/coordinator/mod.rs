//! The Workload Scheduler (§4.4) — PromptTuner's resource-management
//! contribution.
//!
//! A single shared **cold** GPU pool feeds per-LLM **warm** pools whose
//! GPUs hold a pre-loaded runtime + weights (runtime reusing). Three
//! mechanisms cooperate every 50 ms round:
//!
//! * **Algorithm 1** ([`warm_alloc`]): fast multi-GPU allocation from a
//!   warm pool — grow each pending job's allocation until its SLO is met
//!   or the pool is exhausted.
//! * **Algorithm 2** ([`cold_alloc`]): grow warm pools from the cold pool
//!   for jobs whose SLOs cannot otherwise be met — unless
//!   `DelaySchedulable` shows that waiting for soon-to-be-released warm
//!   GPUs still meets the SLO.
//! * **Latency budget** ([`scheduler`]): route a job through the Prompt
//!   Bank only when the lookup fits in 20 % of its SLO.
//!
//! Warm pools shrink back to the cold pool after an idle window (§6.3:
//! 60 s balances violation vs cost).

pub mod cold_alloc;
pub mod pools;
pub mod scheduler;
pub mod warm_alloc;

pub use cold_alloc::{allocate_from_cold_pool, allocate_from_cold_pool_into,
                     delay_schedulable, ColdPlan};
pub use pools::WarmPool;
pub use scheduler::{PromptTuner, PromptTunerConfig};
pub use warm_alloc::{allocate_from_warm_pool, allocate_from_warm_pool_into,
                     WarmAllocation};
