//! The PromptTuner Workload Scheduler as a simulator [`Policy`]: per-LLM
//! warm pools + shared cold pool, Algorithm 1 + Algorithm 2 +
//! `DelaySchedulable` every 50 ms round, Prompt-Bank routing under the
//! latency budget (§4.4.3), and the idle-window shrink.
//!
//! Every paper ablation (Fig 8, Table 8) is a switch on
//! [`PromptTunerConfig`]: prompt reusing, runtime reusing, the warm
//! (simultaneous multi-GPU) allocator, `DelaySchedulable`, the latency
//! budget, the shrink window and the bank size.

use crate::cluster::{ClusterState, JobStatus, Policy};
use crate::coordinator::cold_alloc::allocate_from_cold_pool;
use crate::coordinator::pools::WarmPool;
use crate::coordinator::warm_alloc::allocate_from_warm_pool;
use crate::promptbank::BankModel;
use crate::util::rng::Rng;
use crate::workload::Llm;

/// Configuration (defaults = the full PromptTuner system of the paper).
#[derive(Clone, Debug)]
pub struct PromptTunerConfig {
    /// Size of the shared cold pool (the provider's GPU budget).
    pub max_gpus: usize,
    /// Idle-window before a warm GPU returns to the cold pool (§6.3: 60 s).
    pub window_s: f64,
    /// Prompt reusing (the Prompt Bank) on/off.
    pub use_bank: bool,
    /// Runtime reusing (warm pools) on/off — off = every allocation pays
    /// the full cold start.
    pub use_warm_pools: bool,
    /// Simultaneous multi-GPU warm allocation on/off — off = per-instance
    /// staggered initialization like DL inference systems (§3.2).
    pub use_warm_allocator: bool,
    /// The DelaySchedulable function of Algorithm 2 on/off.
    pub use_delay_schedulable: bool,
    /// The Prompt-Bank latency budget on/off — off = bank for every job.
    pub use_latency_budget: bool,
    /// Fraction of the SLO budgeted for the bank (§4.4.3: 20 %).
    pub latency_budget_frac: f64,
    /// Measured-behaviour model of the Prompt Bank.
    pub bank: BankModel,
    /// Conservative quality estimate used for completion-time prediction
    /// before the bank has actually run.
    pub est_bank_quality: f64,
    /// Per-job allocation cap.
    pub max_gpus_per_job: usize,
    pub seed: u64,
}

impl Default for PromptTunerConfig {
    fn default() -> Self {
        PromptTunerConfig {
            max_gpus: 32,
            window_s: 60.0,
            use_bank: true,
            use_warm_pools: true,
            use_warm_allocator: true,
            use_delay_schedulable: true,
            use_latency_budget: true,
            latency_budget_frac: 0.2,
            bank: BankModel::default(),
            est_bank_quality: 0.85,
            max_gpus_per_job: 8,
            seed: 1,
        }
    }
}

/// Per-job routing decision made at arrival.
#[derive(Clone, Copy, Debug)]
struct Plan {
    use_bank: bool,
    bank_latency: f64,
}

/// The PromptTuner scheduling policy.
pub struct PromptTuner {
    pub cfg: PromptTunerConfig,
    rng: Rng,
    pending: [Vec<usize>; 5],
    pools: [WarmPool; 5],
    plans: Vec<Option<Plan>>,
}

impl PromptTuner {
    pub fn new(cfg: PromptTunerConfig) -> Self {
        let rng = Rng::new(cfg.seed);
        PromptTuner {
            cfg,
            rng,
            pending: Default::default(),
            pools: Default::default(),
            plans: vec![],
        }
    }

    fn plan(&self, job: usize) -> Plan {
        self.plans[job].expect("plan must exist for pending job")
    }

    fn cold_free(&self) -> usize {
        let used: usize = self.pools.iter().map(|p| p.total()).sum();
        self.cfg.max_gpus.saturating_sub(used)
    }

    fn update_billable(&self, st: &mut ClusterState) {
        // Warm-pool GPUs are billed whether busy or idle (runtime +
        // weights resident). With pooling disabled, GPUs are only billed
        // while a job holds them (pools then only track busy GPUs).
        let total: usize = self.pools.iter().map(|p| p.total()).sum();
        st.set_billable(total as f64);
    }

    /// Estimated completion quality used for T_i predictions.
    fn est_quality(&self, st: &ClusterState, job: usize) -> f64 {
        let user = st.jobs[job].spec.user_prompt_quality;
        if self.plan(job).use_bank {
            user.max(self.cfg.est_bank_quality)
        } else {
            user
        }
    }

    /// Initialization delay realized at launch from a warm pool.
    fn warm_init_delay(&mut self, st: &ClusterState, job: usize, gpus: usize) -> f64 {
        let connect = st.perf.warm_connect_s;
        let replicas = (gpus / st.jobs[job].spec.llm.gpus_per_replica()).max(1);
        if self.cfg.use_warm_allocator || replicas == 1 {
            connect
        } else {
            // Staggered per-instance initialization (§3.2): the job waits
            // for the slowest of its instances.
            let mut worst: f64 = 0.0;
            for _ in 0..replicas {
                worst = worst.max(self.rng.range_f64(0.5, 10.0));
            }
            connect + worst
        }
    }

    /// Realized prompt quality + bank latency at launch.
    fn realize_bank(&mut self, st: &ClusterState, job: usize) -> (f64, f64) {
        let user = st.jobs[job].spec.user_prompt_quality;
        let plan = self.plan(job);
        if plan.use_bank {
            let q = self.cfg.bank.draw_quality(&mut self.rng).max(user);
            (q, plan.bank_latency)
        } else {
            (user, 0.0)
        }
    }

    fn launch_from_warm(&mut self, st: &mut ClusterState, llm: Llm,
                        job: usize, gpus: usize) {
        let ok = self.pools[llm.index()].allocate(gpus);
        debug_assert!(ok, "warm grant without free GPUs");
        let init = self.warm_init_delay(st, job, gpus);
        let (q, bank_lat) = self.realize_bank(st, job);
        st.launch(job, gpus, init, bank_lat, q);
    }

    fn launch_from_cold(&mut self, st: &mut ClusterState, llm: Llm,
                        job: usize, gpus: usize) {
        self.pools[llm.index()].add_busy_from_cold(gpus);
        let cold = st.perf.cold_start(llm);
        let extra = if self.cfg.use_warm_allocator {
            0.0
        } else {
            let replicas = (gpus / llm.gpus_per_replica()).max(1);
            if replicas > 1 {
                let mut worst: f64 = 0.0;
                for _ in 0..replicas {
                    worst = worst.max(self.rng.range_f64(0.5, 10.0));
                }
                worst
            } else {
                0.0
            }
        };
        let (q, bank_lat) = self.realize_bank(st, job);
        st.launch(job, gpus, cold + extra, bank_lat, q);
    }

    /// Predicted GPU-release times (E_l) for one LLM's busy warm GPUs.
    fn build_availability(&self, st: &ClusterState, llm: Llm) -> Vec<f64> {
        let mut e = vec![];
        for job in st.jobs.iter() {
            if job.spec.llm != llm || job.gpus == 0 {
                continue;
            }
            let completion = match job.status {
                JobStatus::Initializing => {
                    job.init_until
                        + job.iters_remaining
                            * st.perf.iter_time(llm, job.gpus)
                }
                JobStatus::Running => {
                    job.last_progress_t
                        + job.iters_remaining
                            * st.perf.iter_time(llm, job.gpus)
                }
                _ => continue,
            };
            for _ in 0..job.gpus {
                e.push(completion);
            }
        }
        e
    }

    /// Best-effort pass for jobs whose deadline already passed: they are
    /// violations either way, but must still complete (the user gets the
    /// optimized prompt). One replica each, lowest priority.
    fn schedule_expired(&mut self, st: &mut ClusterState) {
        for llm in Llm::ALL {
            let li = llm.index();
            let replica = llm.gpus_per_replica();
            let now = st.now();
            let expired: Vec<usize> = self.pending[li]
                .iter()
                .copied()
                .filter(|&j| st.jobs[j].spec.deadline() < now)
                .collect();
            for job in expired {
                if self.pools[li].free() >= replica {
                    self.pending[li].retain(|&j| j != job);
                    self.launch_from_warm(st, llm, job, replica);
                } else if self.cold_free() >= replica {
                    self.pending[li].retain(|&j| j != job);
                    self.launch_from_cold(st, llm, job, replica);
                }
            }
        }
    }
}

impl Policy for PromptTuner {
    fn name(&self) -> &str {
        "prompttuner"
    }

    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        while self.plans.len() <= job_id {
            self.plans.push(None);
        }
        let spec = &st.jobs[job_id].spec;
        let bank_latency = self.cfg.bank.lookup_latency(spec.llm);
        let within_budget = bank_latency
            <= self.cfg.latency_budget_frac * spec.slo_s;
        let use_bank = self.cfg.use_bank
            && (!self.cfg.use_latency_budget || within_budget);
        self.plans[job_id] = Some(Plan { use_bank, bank_latency });
        self.pending[spec.llm.index()].push(job_id);
        self.update_billable(st);
    }

    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        let job = &st.jobs[job_id];
        let llm = job.spec.llm;
        // the simulator has already zeroed job.gpus; recover from spec of
        // gpu_seconds bookkeeping
        let gpus = (job.gpu_seconds
            / (job.completed_at - job.launched_at).max(1e-9))
            .round() as usize;
        let pool = &mut self.pools[llm.index()];
        pool.release(gpus, st.now());
        if !self.cfg.use_warm_pools {
            pool.drain_idle();
        }
        self.update_billable(st);
    }

    fn on_tick(&mut self, st: &mut ClusterState) {
        let now = st.now();
        // ---- idle-window shrink (or immediate drain w/o runtime reuse) --
        for pool in self.pools.iter_mut() {
            if self.cfg.use_warm_pools {
                pool.expire_idle(now, self.cfg.window_s);
            } else {
                pool.drain_idle();
            }
        }

        for llm in Llm::ALL {
            let li = llm.index();
            if self.pending[li].is_empty() {
                continue;
            }
            let replica = llm.gpus_per_replica();
            // queue order: ascending absolute deadline (T_i^slo)
            self.pending[li].sort_by(|&a, &b| {
                st.jobs[a]
                    .spec
                    .deadline()
                    .partial_cmp(&st.jobs[b].spec.deadline())
                    .unwrap()
            });
            let not_expired: Vec<usize> = self.pending[li]
                .iter()
                .copied()
                .filter(|&j| st.jobs[j].spec.deadline() >= now)
                .collect();

            // ---------------- Algorithm 1: warm-pool allocation ----------
            let warm_free = self.pools[li].free();
            let est: Vec<(usize, f64, f64)> = not_expired
                .iter()
                .map(|&j| {
                    (j, self.est_quality(st, j), self.plan(j).bank_latency_if())
                })
                .collect();
            let connect = st.perf.warm_connect_s;
            let st_ref: &ClusterState = st;
            let (grants, _) = allocate_from_warm_pool(
                &not_expired,
                warm_free,
                replica,
                self.cfg.max_gpus_per_job,
                |j| st_ref.jobs[j].spec.deadline(),
                |j, g| {
                    let (_, q, bl) =
                        est.iter().find(|(id, _, _)| *id == j).unwrap();
                    st_ref.estimate_completion(j, g, connect, *bl, *q)
                },
            );
            for g in &grants {
                self.pending[li].retain(|&j| j != g.job_id);
                self.launch_from_warm(st, llm, g.job_id, g.gpus);
            }

            // ---------------- Algorithm 2: cold-pool allocation ----------
            let still_pending: Vec<usize> = self.pending[li]
                .iter()
                .copied()
                .filter(|&j| st.jobs[j].spec.deadline() >= now)
                .collect();
            if !still_pending.is_empty() {
                let mut e_l = self.build_availability(st, llm);
                // free warm GPUs are available immediately
                for _ in 0..self.pools[li].free() {
                    e_l.push(now);
                }
                let est2: Vec<(usize, f64, f64)> = still_pending
                    .iter()
                    .map(|&j| {
                        (j, self.est_quality(st, j), self.plan(j).bank_latency_if())
                    })
                    .collect();
                let st_ref: &ClusterState = st;
                let exec_dur = |j: usize, g: usize| {
                    let (_, q, bl) =
                        est2.iter().find(|(id, _, _)| *id == j).unwrap();
                    bl + st_ref.jobs[j].spec.iters_at(*q)
                        * st_ref.perf.iter_time(llm, g)
                };
                let plans = allocate_from_cold_pool(
                    &still_pending,
                    self.cold_free(),
                    replica,
                    self.cfg.max_gpus_per_job,
                    now,
                    |j| st_ref.jobs[j].spec.deadline(),
                    &exec_dur,
                    st.perf.cold_start(llm),
                    &mut e_l,
                    self.cfg.use_delay_schedulable,
                );
                for p in &plans {
                    self.pending[li].retain(|&j| j != p.job_id);
                    self.launch_from_cold(st, llm, p.job_id, p.gpus);
                }
            }
        }

        // ---- best-effort pass for already-violated jobs -----------------
        self.schedule_expired(st);
        self.update_billable(st);
    }
}

trait PlanExt {
    fn bank_latency_if(&self) -> f64;
}
impl PlanExt for Plan {
    fn bank_latency_if(&self) -> f64 {
        if self.use_bank {
            self.bank_latency
        } else {
            0.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SimConfig, Simulator};
    use crate::trace::{Load, TraceConfig, TraceGenerator};
    use crate::workload::PerfModel;

    fn run(cfg: PromptTunerConfig, load: Load, seed: u64) -> crate::cluster::SimResult {
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(load);
        let sim = Simulator::new(
            SimConfig { max_gpus: cfg.max_gpus, ..Default::default() },
            perf,
        );
        let mut policy = PromptTuner::new(cfg);
        sim.run(&mut policy, jobs)
    }

    #[test]
    fn completes_all_jobs_medium_load() {
        let res = run(PromptTunerConfig::default(), Load::Medium, 11);
        assert_eq!(res.n_done, res.n_jobs, "{:?}", res.n_done);
    }

    #[test]
    fn violation_rate_is_low_at_medium_load() {
        let res = run(PromptTunerConfig::default(), Load::Medium, 12);
        // paper Fig 7: PromptTuner ~10-15 % at medium load on 32 GPUs
        assert!(res.violation_rate() < 0.35,
                "violation {}", res.violation_rate());
        assert!(res.cost_usd > 0.0);
    }

    #[test]
    fn disabling_bank_hurts_violations_or_cost() {
        let on = run(PromptTunerConfig::default(), Load::Medium, 13);
        let off = run(
            PromptTunerConfig { use_bank: false, ..Default::default() },
            Load::Medium,
            13,
        );
        // prompt reusing shortens jobs: without it, cost and/or violations rise
        assert!(
            off.cost_usd > on.cost_usd * 1.05
                || off.violation_rate() > on.violation_rate(),
            "bank off: viol {} vs {}, cost {} vs {}",
            off.violation_rate(), on.violation_rate(),
            off.cost_usd, on.cost_usd
        );
    }

    #[test]
    fn disabling_runtime_reuse_hurts_violations() {
        let on = run(PromptTunerConfig::default(), Load::High, 14);
        let off = run(
            PromptTunerConfig { use_warm_pools: false, ..Default::default() },
            Load::High,
            14,
        );
        assert!(off.violation_rate() >= on.violation_rate(),
                "off {} vs on {}", off.violation_rate(), on.violation_rate());
    }

    #[test]
    fn billable_never_exceeds_max_gpus() {
        let cfg = PromptTunerConfig { max_gpus: 16, ..Default::default() };
        let res = run(cfg, Load::High, 15);
        // billed GPU-seconds cannot exceed capacity × makespan
        let makespan = res
            .job_latencies
            .iter()
            .map(|(l, ..)| *l)
            .fold(0.0f64, f64::max)
            + 1200.0;
        assert!(res.gpu_seconds_billed <= 16.0 * makespan + 1e-6);
        assert_eq!(res.n_done, res.n_jobs);
    }

    #[test]
    fn latency_budget_skips_bank_for_tight_slos() {
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 16, slo_emergence: 0.5, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(Load::Low);
        let sim = Simulator::new(SimConfig::default(), perf);
        let mut policy = PromptTuner::new(PromptTunerConfig::default());
        let res = sim.run(&mut policy, jobs);
        // some short jobs must have skipped the bank (bank_latency == 0)
        let skipped = res
            .job_latencies
            .iter()
            .filter(|(_, _, _, bank)| *bank == 0.0)
            .count();
        assert!(skipped > 0, "no job skipped the bank under tight SLOs");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(PromptTunerConfig::default(), Load::Low, 17);
        let b = run(PromptTunerConfig::default(), Load::Low, 17);
        assert_eq!(a.n_violations, b.n_violations);
        assert!((a.cost_usd - b.cost_usd).abs() < 1e-9);
    }
}
