//! The PromptTuner Workload Scheduler as a simulator [`Policy`]: per-LLM
//! warm pools + shared cold pool, Algorithm 1 + Algorithm 2 +
//! `DelaySchedulable` every 50 ms round, Prompt-Bank routing under the
//! latency budget (§4.4.3), and the idle-window shrink.
//!
//! Every paper ablation (Fig 8, Table 8) is a switch on
//! [`PromptTunerConfig`]: prompt reusing, runtime reusing, the warm
//! (simultaneous multi-GPU) allocator, `DelaySchedulable`, the latency
//! budget, the shrink window and the bank size.
//!
//! # Hot-path discipline
//!
//! The scheduling round is the paper's headline overhead metric (§6.2:
//! 13/67 ms avg/max), so the steady-state round is allocation-free:
//!
//! * pending queues are kept deadline-sorted at arrival (deadlines are
//!   static), so no per-round sort and no filtered copies — expired jobs
//!   are a queue prefix found by binary search;
//! * Algorithm 1/2 run through the `_into` allocator entry points over
//!   reusable scratch buffers, with O(1) per-job plan lookups instead of
//!   linear searches;
//! * `E_l` availability comes from the cluster's incremental per-LLM
//!   active-job index instead of scanning every job;
//! * warm totals (and thus `cold_free`) are cached incrementally;
//! * launched jobs leave their queue through one status-based compaction
//!   pass per round instead of one `retain` per grant.
//!
//! The policy also reports its next time-driven action (pool-window
//! expiry) so the simulator can coalesce idle rounds — see
//! [`crate::cluster::Wake`].

use crate::cluster::{ClusterState, JobStatus, KnobSpec, Policy,
                     RetryEvent, RevokeEvent, TunedPrompt, Wake};
use crate::coordinator::cold_alloc::{allocate_from_cold_pool_into, ColdPlan};
use crate::coordinator::pools::WarmPool;
use crate::coordinator::warm_alloc::{allocate_from_warm_pool_into, WarmAllocation};
use crate::promptbank::{SimBankConfig, SimBankSet, TUNED_PROMPT_QUALITY};
use crate::util::rng::Rng;
use crate::workload::{Llm, N_LLM};

/// Planning decisions between bank-pressure evaluations: the latency
/// budget's deny rate over this many routed arrivals drives the §4.4.3
/// bank shrink/grow step. Event-driven (arrivals, not wall-clock), so
/// dense and coalesced runs evaluate at identical points.
const BANK_PRESSURE_WINDOW: u32 = 16;

/// Configuration (defaults = the full PromptTuner system of the paper).
#[derive(Clone, Debug)]
pub struct PromptTunerConfig {
    /// Size of the shared cold pool (the provider's GPU budget).
    pub max_gpus: usize,
    /// Idle-window before a warm GPU returns to the cold pool (§6.3: 60 s).
    pub window_s: f64,
    /// Prompt reusing (the Prompt Bank) on/off.
    pub use_bank: bool,
    /// Runtime reusing (warm pools) on/off — off = every allocation pays
    /// the full cold start.
    pub use_warm_pools: bool,
    /// Simultaneous multi-GPU warm allocation on/off — off = per-instance
    /// staggered initialization like DL inference systems (§3.2).
    pub use_warm_allocator: bool,
    /// The DelaySchedulable function of Algorithm 2 on/off.
    pub use_delay_schedulable: bool,
    /// The Prompt-Bank latency budget on/off — off = bank for every job.
    pub use_latency_budget: bool,
    /// Fraction of the SLO budgeted for the bank (§4.4.3: 20 %).
    pub latency_budget_frac: f64,
    /// The stateful per-LLM simulation bank (§4.3): real two-layer state,
    /// coverage-driven quality, fed by completed jobs.
    pub bank: SimBankConfig,
    /// Elastic bank sizing (§4.4.3): when the latency budget keeps
    /// denying lookups, shrink the bank ceiling (shorter lookups fit more
    /// budgets); grow back toward the configured size once pressure
    /// clears.
    pub bank_autoscale: bool,
    /// Floor of the autoscaled bank ceiling.
    pub bank_min_size: usize,
    /// Per-job allocation cap.
    pub max_gpus_per_job: usize,
    pub seed: u64,
}

impl Default for PromptTunerConfig {
    fn default() -> Self {
        PromptTunerConfig {
            max_gpus: 32,
            window_s: 60.0,
            use_bank: true,
            use_warm_pools: true,
            use_warm_allocator: true,
            use_delay_schedulable: true,
            use_latency_budget: true,
            latency_budget_frac: 0.2,
            bank: SimBankConfig::default(),
            bank_autoscale: true,
            bank_min_size: 500,
            max_gpus_per_job: 8,
            seed: 1,
        }
    }
}

/// Per-job routing decision made at arrival.
#[derive(Clone, Copy, Debug)]
struct Plan {
    use_bank: bool,
    bank_latency: f64,
}

impl Plan {
    fn bank_latency_if(&self) -> f64 {
        if self.use_bank {
            self.bank_latency
        } else {
            0.0
        }
    }
}

/// The PromptTuner scheduling policy.
pub struct PromptTuner {
    pub cfg: PromptTunerConfig,
    rng: Rng,
    /// Stateful per-LLM Prompt Banks (consumed through the
    /// `promptbank::Bank` trait): routing latency, deterministic
    /// coverage-driven quality, and the completion-feedback edge.
    banks: SimBankSet,
    /// Per-LLM pending queues, kept sorted by absolute deadline (ties in
    /// arrival order) — deadlines are static, so sorting once at arrival
    /// replaces the per-round sort.
    pending: [Vec<usize>; N_LLM],
    pools: [WarmPool; N_LLM],
    plans: Vec<Option<Plan>>,
    /// Per-job bank-quality estimate, refreshed from live bank state at
    /// the top of each round for the queued jobs (so Algorithms 1/2 read
    /// an O(1) value instead of re-scanning the bank per candidate
    /// allocation).
    est_q: Vec<f64>,
    /// Current autoscaled bank ceiling (≤ cfg.bank.max_size).
    bank_ceiling: usize,
    /// Latency-budget pressure counters since the last autoscale step
    /// (arrival-driven — see [`BANK_PRESSURE_WINDOW`]).
    bank_planned: u32,
    bank_denied: u32,
    /// Cached Σ pools[l].total() — the warm GPUs currently drawn from the
    /// shared cold pool (kept incrementally; asserts against the pools in
    /// debug builds).
    warm_total: usize,
    /// An arrival/completion happened since the last round: the next
    /// round must run before idle-round coalescing may resume.
    needs_round: bool,
    /// Failed runs held back until their retry backoff expires:
    /// (not_before, job). Drained into the pending queues by `on_tick`;
    /// the earliest entry is declared through `next_timed_action` so
    /// coalesced runs wake exactly when a backoff expires.
    retry_holdback: Vec<(f64, usize)>,
    /// Tuned prompts fed back since the last gossip drain. Only recorded
    /// when a shard plane enabled the log — unsharded runs never touch it,
    /// keeping them bit-identical to pre-gossip behavior.
    gossip_log: Vec<TunedPrompt>,
    gossip_enabled: bool,
    // ---- reusable scratch buffers (steady-state rounds allocate nothing)
    scratch_ids: Vec<usize>,
    scratch_el: Vec<f64>,
    scratch_warm: Vec<WarmAllocation>,
    scratch_cold: Vec<ColdPlan>,
}

impl PromptTuner {
    pub fn new(cfg: PromptTunerConfig) -> Self {
        let rng = Rng::new(cfg.seed);
        let banks = SimBankSet::new(&cfg.bank, cfg.seed);
        let bank_ceiling = cfg.bank.max_size;
        PromptTuner {
            cfg,
            rng,
            banks,
            pending: Default::default(),
            pools: Default::default(),
            plans: vec![],
            est_q: vec![],
            bank_ceiling,
            bank_planned: 0,
            bank_denied: 0,
            warm_total: 0,
            needs_round: true,
            retry_holdback: vec![],
            gossip_log: vec![],
            gossip_enabled: false,
            scratch_ids: vec![],
            scratch_el: vec![],
            scratch_warm: vec![],
            scratch_cold: vec![],
        }
    }

    /// Read access to the live bank state (tests/benches).
    pub fn banks(&self) -> &SimBankSet {
        &self.banks
    }

    fn plan(&self, job: usize) -> Plan {
        self.plans[job].expect("plan must exist for pending job")
    }

    fn cold_free(&self) -> usize {
        self.cfg.max_gpus.saturating_sub(self.warm_total)
    }

    fn update_billable(&self, st: &mut ClusterState) {
        // Warm-pool GPUs are billed whether busy or idle (runtime +
        // weights resident). With pooling disabled, GPUs are only billed
        // while a job holds them (pools then only track busy GPUs).
        debug_assert_eq!(self.warm_total,
                         self.pools.iter().map(|p| p.total()).sum::<usize>());
        st.set_billable(self.warm_total as f64);
    }

    /// Staggered per-instance initialization penalty (§3.2): with the
    /// simultaneous warm allocator disabled, each replica initializes
    /// independently and the job waits for the slowest draw. Shared by
    /// the warm and cold launch paths (identical RNG draw order).
    fn staggered_init_penalty(&mut self, replicas: usize) -> f64 {
        if self.cfg.use_warm_allocator || replicas <= 1 {
            return 0.0;
        }
        let mut worst: f64 = 0.0;
        for _ in 0..replicas {
            worst = worst.max(self.rng.range_f64(0.5, 10.0));
        }
        worst
    }

    /// Realized prompt quality + bank latency at launch: the quality is
    /// the bank's *current coverage* of the job's task — a deterministic
    /// function of bank state (no draw), so completed jobs that fed
    /// tuned prompts back demonstrably raise later launches' quality.
    fn realize_bank(&mut self, st: &ClusterState, job: usize) -> (f64, f64) {
        let spec = &st.jobs[job].spec;
        let user = spec.user_prompt_quality;
        let plan = self.plan(job);
        if plan.use_bank {
            let q = self.banks.quality_for(spec.llm, spec.task_id).max(user);
            (q, plan.bank_latency)
        } else {
            (user, 0.0)
        }
    }

    /// Lookup latency this LLM would pay at the autoscale floor — the
    /// best a shrink can achieve. Denials that exceed even this are
    /// *hopeless* (the SLO is simply too tight for any bank) and must
    /// not drive elasticity either way.
    fn floor_latency(&self, llm: Llm) -> f64 {
        let target = self.cfg.bank.max_size;
        let floor = self.cfg.bank_min_size.min(target).max(1);
        let k = self.cfg.bank.k.max(1);
        (k + floor / k) as f64 * self.cfg.bank.eval_cost_s[llm.index()]
    }

    /// One §4.4.3 bank-elasticity step, taken every
    /// [`BANK_PRESSURE_WINDOW`] routed arrivals: a majority of *fixable*
    /// latency-budget denials shrinks the ceiling (evicting redundant
    /// candidates ⇒ fewer evals ⇒ more SLOs fit the budget); a
    /// mostly-clean window (≤ 25 % denials — hysteresis against
    /// shrink/grow flapping) grows it back toward the configured size
    /// (candidates return through completion feedback). Arrival-driven,
    /// so coalesced and dense runs take identical steps.
    fn bank_autoscale_step(&mut self) {
        let denied = self.bank_denied;
        let total = self.bank_planned.max(1);
        self.bank_planned = 0;
        self.bank_denied = 0;
        let target = self.cfg.bank.max_size;
        let floor = self.cfg.bank_min_size.min(target).max(1);
        if 2 * denied >= total && self.bank_ceiling > floor {
            self.bank_ceiling = (self.bank_ceiling * 3 / 4).max(floor);
            self.banks.set_max_size_all(self.bank_ceiling);
        } else if 4 * denied <= total && self.bank_ceiling < target {
            self.bank_ceiling =
                (self.bank_ceiling + (target / 4).max(1)).min(target);
            self.banks.set_max_size_all(self.bank_ceiling);
        }
    }

    fn launch_from_warm(&mut self, st: &mut ClusterState, llm: Llm,
                        job: usize, gpus: usize) {
        let ok = self.pools[llm.index()].allocate(gpus);
        debug_assert!(ok, "warm grant without free GPUs");
        let replicas = (gpus / llm.gpus_per_replica()).max(1);
        let init = st.perf.warm_connect_s + self.staggered_init_penalty(replicas);
        let (q, bank_lat) = self.realize_bank(st, job);
        st.launch(job, gpus, init, bank_lat, q);
    }

    fn launch_from_cold(&mut self, st: &mut ClusterState, llm: Llm,
                        job: usize, gpus: usize) {
        self.pools[llm.index()].add_busy_from_cold(gpus);
        self.warm_total += gpus;
        let replicas = (gpus / llm.gpus_per_replica()).max(1);
        let init = st.perf.cold_start(llm) + self.staggered_init_penalty(replicas);
        let (q, bank_lat) = self.realize_bank(st, job);
        st.launch(job, gpus, init, bank_lat, q);
    }

    /// Predicted GPU-release times (E_l) for one LLM's busy warm GPUs,
    /// from the cluster's incremental active-job index (order is
    /// irrelevant: DelaySchedulable sorts).
    fn build_availability_into(&self, st: &ClusterState, llm: Llm,
                               e: &mut Vec<f64>) {
        for &jid in st.active_jobs(llm) {
            let job = &st.jobs[jid];
            debug_assert!(job.gpus > 0);
            let completion = match job.status {
                JobStatus::Initializing => {
                    job.init_until
                        + job.iters_remaining
                            * st.eff_iter_time(llm, job.gpus)
                }
                JobStatus::Running => {
                    job.last_progress_t
                        + job.iters_remaining
                            * st.eff_iter_time(llm, job.gpus)
                }
                _ => continue,
            };
            for _ in 0..job.gpus {
                e.push(completion);
            }
        }
    }

    /// Best-effort pass for jobs whose deadline already passed: they are
    /// violations either way, but must still complete (the user gets the
    /// optimized prompt). One replica each, lowest priority.
    fn schedule_expired(&mut self, st: &mut ClusterState) {
        for llm in Llm::ALL {
            let li = llm.index();
            if self.pending[li].is_empty() {
                continue;
            }
            let replica = llm.gpus_per_replica();
            let now = st.now();
            // Deadline-sorted queue: expired jobs are the prefix.
            let st_ref: &ClusterState = st;
            let cut = self.pending[li]
                .partition_point(|&j| st_ref.jobs[j].spec.deadline() < now);
            if cut == 0 {
                continue;
            }
            let mut launched = false;
            for i in 0..cut {
                let job = self.pending[li][i];
                if self.pools[li].free() >= replica {
                    self.launch_from_warm(st, llm, job, replica);
                    launched = true;
                } else if self.cold_free() >= replica {
                    self.launch_from_cold(st, llm, job, replica);
                    launched = true;
                }
            }
            if launched {
                let st_ref: &ClusterState = st;
                self.pending[li]
                    .retain(|&j| st_ref.jobs[j].status == JobStatus::Pending);
            }
        }
    }
}

impl Policy for PromptTuner {
    fn name(&self) -> &str {
        "prompttuner"
    }

    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        while self.plans.len() <= job_id {
            self.plans.push(None);
            self.est_q.push(0.0);
        }
        let spec = &st.jobs[job_id].spec;
        // Routing reads the *live* bank: lookup latency follows the
        // current two-layer shape (a cold bank is near-free to query, a
        // shrunk one cheaper than a full one).
        let bank_latency = self.banks.lookup_latency(spec.llm);
        let within_budget = bank_latency
            <= self.cfg.latency_budget_frac * spec.slo_s;
        let use_bank = self.cfg.use_bank
            && (!self.cfg.use_latency_budget || within_budget);
        self.plans[job_id] = Some(Plan { use_bank, bank_latency });
        // §4.4.3 pressure tracking (only the budget can deny a lookup).
        // Hopeless denials — SLOs too tight for even a floor-size bank —
        // are excluded entirely: shrinking cannot rescue them, and they
        // must not hold the ceiling down once real pressure clears.
        if self.cfg.use_bank && self.cfg.use_latency_budget
            && self.cfg.bank_autoscale
        {
            let budget = self.cfg.latency_budget_frac * spec.slo_s;
            let fixable = self.floor_latency(spec.llm) <= budget;
            if within_budget || fixable {
                self.bank_planned += 1;
                if !within_budget {
                    self.bank_denied += 1;
                }
            }
            if self.bank_planned >= BANK_PRESSURE_WINDOW {
                self.bank_autoscale_step();
            }
        }
        // Sorted insert by deadline; equal deadlines keep arrival order
        // (matches the stable per-round sort this replaces).
        let li = spec.llm.index();
        let dl = spec.deadline();
        let st_ref: &ClusterState = st;
        let pos = self.pending[li]
            .partition_point(|&j| st_ref.jobs[j].spec.deadline() <= dl);
        self.pending[li].insert(pos, job_id);
        self.needs_round = true;
        self.update_billable(st);
    }

    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        let job = &st.jobs[job_id];
        let llm = job.spec.llm;
        let task_id = job.spec.task_id;
        // the simulator has already zeroed job.gpus; recover from spec of
        // gpu_seconds bookkeeping
        let gpus = (job.gpu_seconds
            / (job.completed_at - job.launched_at).max(1e-9))
            .round() as usize;
        let pool = &mut self.pools[llm.index()];
        pool.release(gpus, st.now());
        if !self.cfg.use_warm_pools {
            let drained = pool.drain_idle();
            self.warm_total -= drained;
        }
        // Feedback edge (Fig 5b): the completed job's tuned prompt flows
        // back into its LLM's bank, raising subsequent lookup quality for
        // this task (redundant candidates are evicted over the ceiling).
        // Completion is a discrete event, executed identically under
        // dense and coalesced ticking, so bank state stays bit-equal.
        if self.cfg.use_bank {
            self.banks.insert_tuned(llm, task_id, TUNED_PROMPT_QUALITY);
            if self.gossip_enabled {
                self.gossip_log.push(TunedPrompt {
                    llm,
                    task_id,
                    quality: TUNED_PROMPT_QUALITY,
                });
            }
        }
        self.needs_round = true;
        self.update_billable(st);
    }

    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        let now = st.now();
        for v in &ev.victims {
            let li = st.jobs[v.job_id].spec.llm.index();
            // The failed GPUs leave the warm pool entirely (the hardware
            // is gone); the victim's surviving GPUs return to it idle.
            self.pools[li].lose_busy(v.failed);
            self.warm_total -= v.failed;
            self.pools[li].release(v.held - v.failed, now);
            // Requeue the preempted job (deadline-sorted, like arrival);
            // its routing plan from arrival still stands.
            let dl = st.jobs[v.job_id].spec.deadline();
            let st_ref: &ClusterState = st;
            let pos = self.pending[li]
                .partition_point(|&j| st_ref.jobs[j].spec.deadline() <= dl);
            self.pending[li].insert(pos, v.job_id);
        }
        // Failed GPUs beyond the victims' allocations hit idle warm
        // capacity: shed it pool by pool.
        let mut need = ev.idle_gpus_lost;
        for pool in self.pools.iter_mut() {
            if need == 0 {
                break;
            }
            let shed = pool.lose_idle(need);
            self.warm_total -= shed;
            need -= shed;
        }
        self.needs_round = true;
        self.update_billable(st);
    }

    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        // The attempt's GPUs come home warm — the hardware is fine, only
        // the tuning result was rejected; without runtime reuse they
        // drain exactly as at completion. No bank feedback: the failed
        // run produced no usable tuned prompt.
        let li = st.jobs[ev.job_id].spec.llm.index();
        let pool = &mut self.pools[li];
        pool.release(ev.gpus, st.now());
        if !self.cfg.use_warm_pools {
            let drained = pool.drain_idle();
            self.warm_total -= drained;
        }
        // Hold the job back until its backoff expires, then requeue.
        self.retry_holdback.push((ev.not_before, ev.job_id));
        self.needs_round = true;
        self.update_billable(st);
    }

    fn on_tick(&mut self, st: &mut ClusterState) {
        let now = st.now();
        self.needs_round = false;
        // ---- release held-back retries whose backoff expired ------------
        if !self.retry_holdback.is_empty() {
            let mut i = 0;
            while i < self.retry_holdback.len() {
                let (t, j) = self.retry_holdback[i];
                if t <= now {
                    self.retry_holdback.swap_remove(i);
                    // deadline-sorted requeue, like arrival/revocation
                    let li = st.jobs[j].spec.llm.index();
                    let dl = st.jobs[j].spec.deadline();
                    let st_ref: &ClusterState = st;
                    let pos = self.pending[li].partition_point(|&k| {
                        st_ref.jobs[k].spec.deadline() <= dl
                    });
                    self.pending[li].insert(pos, j);
                } else {
                    i += 1;
                }
            }
        }
        // ---- idle-window shrink (or immediate drain w/o runtime reuse) --
        for pool in self.pools.iter_mut() {
            let expired = if self.cfg.use_warm_pools {
                pool.expire_idle(now, self.cfg.window_s)
            } else {
                pool.drain_idle()
            };
            self.warm_total -= expired;
        }

        let connect = st.perf.warm_connect_s;
        for llm in Llm::ALL {
            let li = llm.index();
            if self.pending[li].is_empty() {
                continue;
            }
            let replica = llm.gpus_per_replica();
            // Deadline-sorted queue (maintained at arrival): the expired
            // prefix is excluded from the SLO-driven algorithms.
            let st_ref: &ClusterState = st;
            let cut = self.pending[li]
                .partition_point(|&j| st_ref.jobs[j].spec.deadline() < now);

            // ---------------- Algorithm 1: warm-pool allocation ----------
            let mut ids = std::mem::take(&mut self.scratch_ids);
            ids.clear();
            ids.extend_from_slice(&self.pending[li][cut..]);
            // Refresh the queued jobs' quality estimates from *live* bank
            // state once per round (a deterministic coverage scan), so
            // Algorithms 1/2 read an O(1) value however often they
            // re-cost a job. Planning and launch agree by construction:
            // both evaluate the same bank state.
            for &j in ids.iter() {
                let spec = &st.jobs[j].spec;
                let user = spec.user_prompt_quality;
                let q = if self.plans[j].expect("plan").use_bank {
                    user.max(self.banks.quality_for(llm, spec.task_id))
                } else {
                    user
                };
                self.est_q[j] = q;
            }
            let mut grants = std::mem::take(&mut self.scratch_warm);
            grants.clear();
            let warm_free = self.pools[li].free();
            {
                let plans = &self.plans;
                let est_q = &self.est_q;
                let st_ref: &ClusterState = st;
                allocate_from_warm_pool_into(
                    &ids,
                    warm_free,
                    replica,
                    self.cfg.max_gpus_per_job,
                    |j| st_ref.jobs[j].spec.deadline(),
                    |j, g| {
                        let bl = plans[j].expect("plan").bank_latency_if();
                        st_ref.estimate_completion(j, g, connect, bl,
                                                   est_q[j])
                    },
                    &mut grants,
                );
            }
            let mut launched = false;
            for g in grants.iter() {
                self.launch_from_warm(st, llm, g.job_id, g.gpus);
                launched = true;
            }
            grants.clear();
            self.scratch_warm = grants;

            // ---------------- Algorithm 2: cold-pool allocation ----------
            // Jobs granted by Algorithm 1 are no longer Pending.
            {
                let st_ref: &ClusterState = st;
                ids.retain(|&j| st_ref.jobs[j].status == JobStatus::Pending);
            }
            if !ids.is_empty() {
                let mut e_l = std::mem::take(&mut self.scratch_el);
                e_l.clear();
                self.build_availability_into(st, llm, &mut e_l);
                // free warm GPUs are available immediately
                for _ in 0..self.pools[li].free() {
                    e_l.push(now);
                }
                let mut cold_plans = std::mem::take(&mut self.scratch_cold);
                cold_plans.clear();
                {
                    let plans = &self.plans;
                    let est_q = &self.est_q;
                    let st_ref: &ClusterState = st;
                    let exec_dur = |j: usize, g: usize| {
                        let job = &st_ref.jobs[j];
                        if job.needs_restore {
                            // Revoked job awaiting restore: it resumes
                            // its preserved remaining iterations after
                            // the restore overhead, with no second bank
                            // lookup (mirrors `launch`/
                            // `estimate_completion`).
                            let restore = st_ref
                                .checkpoint_model()
                                .map_or(0.0, |m| m.restore_s);
                            return restore
                                + job.iters_remaining
                                    * st_ref.eff_iter_time(llm, g);
                        }
                        let plan = plans[j].expect("plan must exist");
                        plan.bank_latency_if()
                            + job.spec.iters_at(est_q[j])
                                * st_ref.eff_iter_time(llm, g)
                    };
                    allocate_from_cold_pool_into(
                        &ids,
                        self.cold_free(),
                        replica,
                        self.cfg.max_gpus_per_job,
                        now,
                        |j| st_ref.jobs[j].spec.deadline(),
                        &exec_dur,
                        st_ref.perf.cold_start(llm),
                        &mut e_l,
                        self.cfg.use_delay_schedulable,
                        &mut cold_plans,
                    );
                }
                for p in cold_plans.iter() {
                    self.launch_from_cold(st, llm, p.job_id, p.gpus);
                    launched = true;
                }
                cold_plans.clear();
                self.scratch_cold = cold_plans;
                e_l.clear();
                self.scratch_el = e_l;
            }
            ids.clear();
            self.scratch_ids = ids;

            // One compaction pass instead of one retain per grant.
            if launched {
                let st_ref: &ClusterState = st;
                self.pending[li]
                    .retain(|&j| st_ref.jobs[j].status == JobStatus::Pending);
            }
        }

        // ---- best-effort pass for already-violated jobs -----------------
        self.schedule_expired(st);
        self.update_billable(st);
    }

    fn next_timed_action(&self, st: &ClusterState) -> Wake {
        let _ = st;
        if self.needs_round {
            return Wake::Dense;
        }
        // Any queued job keeps the round dense: allocation decisions and
        // expiry transitions depend on the current time.
        if self.pending.iter().any(|q| !q.is_empty()) {
            return Wake::Dense;
        }
        // Time-driven work left: held-back retries re-entering the queue
        // at their backoff expiry, and (with runtime reuse) the
        // idle-window shrink of the earliest-idle warm GPU. Without
        // warm pools idle GPUs are drained eagerly — no window expires.
        // Starved-wake audit (batch-skip core): both sources are merged
        // unconditionally below — there is no early return that could
        // drop a holdback expiry, so every `retry_not_before` in the
        // future is covered by the returned wake.
        let mut next = f64::INFINITY;
        for &(t, _) in &self.retry_holdback {
            if t < next {
                next = t;
            }
        }
        if self.cfg.use_warm_pools {
            for pool in &self.pools {
                if let Some(t) = pool.earliest_idle() {
                    let expiry = t + self.cfg.window_s;
                    if expiry < next {
                        next = expiry;
                    }
                }
            }
        }
        if next.is_finite() {
            Wake::At(next)
        } else {
            Wake::Idle
        }
    }

    fn capacity(&self) -> Option<usize> {
        Some(self.cfg.max_gpus)
    }

    fn set_capacity(&mut self, _st: &mut ClusterState, gpus: usize) {
        // Cold-pool budget knob (driven by `slo::Governed`): growing it
        // opens allocation headroom at the next round; shrinking lets the
        // idle-window drain warm pools back down over time. Billable
        // capacity tracks the warm pools, so no cluster update is needed.
        self.cfg.max_gpus = gpus;
        self.needs_round = true;
    }

    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        if self.cfg.use_bank {
            Some(self.banks.quality_for(llm, task_id))
        } else {
            None
        }
    }

    fn enable_gossip_log(&mut self) {
        self.gossip_enabled = true;
    }

    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        out.append(&mut self.gossip_log);
    }

    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        // Remote prompts land in the local bank like local completions do,
        // but are *not* re-logged: gossip forwards first-hand tunes only,
        // so an item crosses each shard boundary at most once.
        if self.cfg.use_bank {
            for it in items {
                self.banks.insert_tuned(it.llm, it.task_id, it.quality);
            }
        }
    }

    // Self-tuning knob declarations (`slo::Tuned`). The lattice bounds
    // mirror the knobs' hand-set operating ranges: capacity between half
    // the configured budget and the governor's 25 % surge ceiling, the
    // bank ceiling between the autoscale floor and the configured size,
    // and the §4.4.1 lookup budget around its hand-set 20 %.
    fn knobs(&self) -> Vec<KnobSpec> {
        let base = self.cfg.max_gpus;
        let target = self.cfg.bank.max_size;
        let floor = self.cfg.bank_min_size.min(target).max(1);
        let mut out = vec![KnobSpec {
            name: "capacity",
            lo: (base / 2).max(1) as f64,
            hi: (base + (base / 4).max(1)) as f64,
            steps: 4,
        }];
        if self.cfg.use_bank {
            out.push(KnobSpec {
                name: "bank_ceiling",
                lo: floor as f64,
                hi: target as f64,
                steps: 4,
            });
            out.push(KnobSpec {
                name: "latency_budget_frac",
                lo: 0.05,
                hi: 0.4,
                steps: 4,
            });
        }
        out
    }

    fn knob_value(&self, name: &str) -> Option<f64> {
        match name {
            "capacity" => Some(self.cfg.max_gpus as f64),
            "bank_ceiling" if self.cfg.use_bank => {
                Some(self.bank_ceiling as f64)
            }
            "latency_budget_frac" if self.cfg.use_bank => {
                Some(self.cfg.latency_budget_frac)
            }
            _ => None,
        }
    }

    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        match name {
            "capacity" => {
                self.set_capacity(st, value.round().max(1.0) as usize);
            }
            "bank_ceiling" if self.cfg.use_bank => {
                // Drive both the live ceiling and the §4.4.3 autoscale
                // target, so the pressure window flexes around the tuned
                // point instead of pulling back to the hand-set size.
                let size = value.round().max(1.0) as usize;
                self.cfg.bank.max_size = size;
                self.bank_ceiling = size;
                self.banks.set_max_size_all(size);
                self.needs_round = true;
            }
            "latency_budget_frac" if self.cfg.use_bank => {
                self.cfg.latency_budget_frac = value.clamp(0.0, 1.0);
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SimConfig, Simulator};
    use crate::trace::{Load, TraceConfig, TraceGenerator};
    use crate::workload::PerfModel;

    fn run(cfg: PromptTunerConfig, load: Load, seed: u64) -> crate::cluster::SimResult {
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(load);
        let sim = Simulator::new(
            SimConfig { max_gpus: cfg.max_gpus, ..Default::default() },
            perf,
        );
        let mut policy = PromptTuner::new(cfg);
        sim.run(&mut policy, jobs)
    }

    #[test]
    fn completes_all_jobs_medium_load() {
        let res = run(PromptTunerConfig::default(), Load::Medium, 11);
        assert_eq!(res.n_done, res.n_jobs, "{:?}", res.n_done);
    }

    #[test]
    fn violation_rate_is_low_at_medium_load() {
        let res = run(PromptTunerConfig::default(), Load::Medium, 12);
        // paper Fig 7: PromptTuner ~10-15 % at medium load on 32 GPUs
        assert!(res.violation_rate() < 0.35,
                "violation {}", res.violation_rate());
        assert!(res.cost_usd > 0.0);
    }

    #[test]
    fn disabling_bank_hurts_violations_or_cost() {
        let on = run(PromptTunerConfig::default(), Load::Medium, 13);
        let off = run(
            PromptTunerConfig { use_bank: false, ..Default::default() },
            Load::Medium,
            13,
        );
        // prompt reusing shortens jobs: without it, cost and/or violations rise
        assert!(
            off.cost_usd > on.cost_usd * 1.05
                || off.violation_rate() > on.violation_rate(),
            "bank off: viol {} vs {}, cost {} vs {}",
            off.violation_rate(), on.violation_rate(),
            off.cost_usd, on.cost_usd
        );
    }

    #[test]
    fn disabling_runtime_reuse_hurts_violations() {
        let on = run(PromptTunerConfig::default(), Load::High, 14);
        let off = run(
            PromptTunerConfig { use_warm_pools: false, ..Default::default() },
            Load::High,
            14,
        );
        assert!(off.violation_rate() >= on.violation_rate(),
                "off {} vs on {}", off.violation_rate(), on.violation_rate());
    }

    #[test]
    fn billable_never_exceeds_max_gpus() {
        let cfg = PromptTunerConfig { max_gpus: 16, ..Default::default() };
        let res = run(cfg, Load::High, 15);
        // billed GPU-seconds cannot exceed capacity × makespan
        let makespan = res
            .job_latencies
            .iter()
            .map(|(l, ..)| *l)
            .fold(0.0f64, f64::max)
            + 1200.0;
        assert!(res.gpu_seconds_billed <= 16.0 * makespan + 1e-6);
        assert_eq!(res.n_done, res.n_jobs);
    }

    #[test]
    fn latency_budget_skips_bank_for_tight_slos() {
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 16, slo_emergence: 0.5, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(Load::Low);
        let sim = Simulator::new(SimConfig::default(), perf);
        let mut policy = PromptTuner::new(PromptTunerConfig::default());
        let res = sim.run(&mut policy, jobs);
        // some short jobs must have skipped the bank (bank_latency == 0)
        let skipped = res
            .job_latencies
            .iter()
            .filter(|(_, _, _, bank)| *bank == 0.0)
            .count();
        assert!(skipped > 0, "no job skipped the bank under tight SLOs");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run(PromptTunerConfig::default(), Load::Low, 17);
        let b = run(PromptTunerConfig::default(), Load::Low, 17);
        assert_eq!(a.n_violations, b.n_violations);
        assert!((a.cost_usd - b.cost_usd).abs() < 1e-9);
    }

    #[test]
    fn completion_feedback_warms_a_cold_bank() {
        use crate::promptbank::SimBankConfig;
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 20, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(Load::Low);
        let first = (jobs[0].llm, jobs[0].task_id);
        let sim = Simulator::new(SimConfig::default(), perf);
        let mut policy = PromptTuner::new(PromptTunerConfig {
            bank: SimBankConfig::cold(),
            seed: 20,
            ..Default::default()
        });
        let res = sim.run(&mut policy, jobs);
        assert_eq!(res.n_done, res.n_jobs);
        // every completion fed a tuned prompt back into its LLM's bank...
        assert!(policy.banks().total_len() > 0, "cold bank never warmed");
        // ...so a task that ran is now covered near the tuned ceiling
        let q = policy.banks().quality_for(first.0, first.1);
        assert!(q > 0.9, "bank not warmed for completed task: {q}");
    }

    #[test]
    fn warm_bank_beats_cold_bank_on_quality() {
        use crate::promptbank::SimBankConfig;
        let warm = run(PromptTunerConfig::default(), Load::Medium, 21);
        let cold = run(
            PromptTunerConfig {
                bank: SimBankConfig::cold(),
                ..Default::default()
            },
            Load::Medium,
            21,
        );
        assert!(warm.mean_prompt_quality > cold.mean_prompt_quality,
                "warm {} vs cold {}",
                warm.mean_prompt_quality, cold.mean_prompt_quality);
        assert!(warm.n_violations <= cold.n_violations,
                "warm {} vs cold {} violations",
                warm.n_violations, cold.n_violations);
    }

    #[test]
    fn coalescing_engages_on_idle_stretches() {
        // A low-load run has long stretches with empty queues; the policy
        // must report them and the simulator must skip those rounds.
        let res = run(PromptTunerConfig::default(), Load::Low, 18);
        assert_eq!(res.n_done, res.n_jobs);
        assert!(res.rounds_coalesced > res.rounds_executed,
                "coalesced {} vs executed {}",
                res.rounds_coalesced, res.rounds_executed);
    }

    #[test]
    fn survives_flash_crowd_scenario_under_oracle() {
        // A correlated spike storm floods every per-LLM queue in the same
        // minutes — the adversarial case for the warm/cold split. The
        // collecting oracle audits every round; all jobs must still finish.
        use crate::cluster::SimOracle;
        use crate::scenario::Scenario;
        let sc = Scenario::FlashCrowd { storms: 3, intensity: 25.0,
                                        jobs_per_llm: 70 };
        let jobs = sc.generate(19, 1.0).unwrap();
        let n = jobs.len();
        let sim = Simulator::new(
            SimConfig { max_gpus: 32, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = SimOracle::collecting(PromptTuner::new(PromptTunerConfig {
            max_gpus: 32,
            seed: 19,
            ..Default::default()
        }));
        let res = sim.run(&mut policy, jobs);
        assert_eq!(res.n_done, n);
        assert!(policy.violations().is_empty());
        assert!(policy.audits() > 0);
    }
}
