//! Algorithm 2 (§4.4.2): GPU allocation from the shared cold pool, with
//! the `DelaySchedulable` test.
//!
//! For each still-pending job (ascending SLO): if delaying it until
//! already-running jobs release warm GPUs still meets its SLO, do nothing
//! (saving the cost of new warm GPUs). Otherwise grow a cold-pool
//! allocation until the SLO is met *including* the cold allocation
//! overhead T_l^cold; the granted GPUs join the LLM's warm pool.

/// One cold-pool grant: `gpus` move from the cold pool into the job's
/// LLM warm pool and start the job after the cold-start overhead.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ColdPlan {
    pub job_id: usize,
    pub gpus: usize,
}

/// The `DelaySchedulable` function (Algorithm 2 lines 23–35).
///
/// `e_l` holds, per busy warm GPU of this LLM, the earliest absolute time
/// it becomes available (sorted ascending by the caller or here). If some
/// k exists with `exec_dur(job, k) + e_l[k-1] <= deadline`, the job can be
/// delayed: the k reserved entries are pushed back to the job's own
/// predicted completion (line 30) and true is returned.
///
/// `replica` restricts k to replica multiples.
pub fn delay_schedulable(
    e_l: &mut Vec<f64>,
    job: usize,
    replica: usize,
    deadline: f64,
    exec_dur: impl Fn(usize, usize) -> f64,
) -> bool {
    e_l.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut k = replica;
    while k <= e_l.len() {
        let start = e_l[k - 1];
        let completion = start + exec_dur(job, k);
        if completion <= deadline {
            // reserve: the k earliest GPUs now free up when this job ends
            for slot in e_l.iter_mut().take(k) {
                *slot = completion;
            }
            e_l.sort_by(|a, b| a.partial_cmp(b).unwrap());
            return true;
        }
        k += replica;
    }
    false
}

/// Run Algorithm 2 over `pending` (sorted by SLO ascending). Returns the
/// cold-pool grants; `e_l` is mutated by successful DelaySchedulable
/// reservations.
///
/// * `cold_free` — GPUs available in the shared cold pool.
/// * `exec_dur(job, gpus)` — execution duration (bank + iterations) once
///   initialized, excluding allocation overheads.
/// * `cold_overhead` — T_l^cold for this LLM.
/// * `now` — current time (deadlines are absolute).
pub fn allocate_from_cold_pool(
    pending: &[usize],
    cold_free: usize,
    replica: usize,
    max_gpus_per_job: usize,
    now: f64,
    deadline: impl Fn(usize) -> f64,
    exec_dur: impl Fn(usize, usize) -> f64 + Copy,
    cold_overhead: f64,
    e_l: &mut Vec<f64>,
    use_delay: bool,
) -> Vec<ColdPlan> {
    let mut plans = vec![];
    allocate_from_cold_pool_into(
        pending, cold_free, replica, max_gpus_per_job, now, deadline,
        exec_dur, cold_overhead, e_l, use_delay, &mut plans,
    );
    plans
}

/// Allocation-free core of [`allocate_from_cold_pool`]: plans are pushed
/// into a caller-owned (reusable) buffer. The scheduler's steady-state
/// round uses this entry point with scratch buffers.
#[allow(clippy::too_many_arguments)]
pub fn allocate_from_cold_pool_into(
    pending: &[usize],
    mut cold_free: usize,
    replica: usize,
    max_gpus_per_job: usize,
    now: f64,
    deadline: impl Fn(usize) -> f64,
    exec_dur: impl Fn(usize, usize) -> f64 + Copy,
    cold_overhead: f64,
    e_l: &mut Vec<f64>,
    use_delay: bool,
    plans: &mut Vec<ColdPlan>,
) {
    debug_assert!(plans.is_empty());
    for &job in pending {
        // lines 7-9: skip jobs that can wait for released warm GPUs
        if use_delay
            && delay_schedulable(e_l, job, replica, deadline(job), exec_dur)
        {
            continue;
        }
        if cold_free < replica {
            continue;
        }
        let cap = max_gpus_per_job.min(cold_free) / replica * replica;
        if cap == 0 {
            continue;
        }
        // lines 10-14: grow until SLO met including the cold overhead
        let mut a = replica;
        while now + cold_overhead + exec_dur(job, a) > deadline(job)
            && a + replica <= cap
        {
            a += replica;
        }
        // line 15: only commit if the SLO is actually met
        if now + cold_overhead + exec_dur(job, a) <= deadline(job) {
            plans.push(ColdPlan { job_id: job, gpus: a });
            cold_free -= a;
            // line 19: these GPUs free up when the job completes
            let completion = now + cold_overhead + exec_dur(job, a);
            for _ in 0..a {
                e_l.push(completion);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    #[test]
    fn delay_schedulable_waits_for_one_gpu() {
        // one busy GPU frees at t=10; job runs 5 s; deadline 20 => delay ok
        let mut e = vec![10.0];
        assert!(delay_schedulable(&mut e, 0, 1, 20.0, |_, _| 5.0));
        // reservation recorded: GPU now frees at 15
        assert_eq!(e, vec![15.0]);
    }

    #[test]
    fn delay_schedulable_rejects_tight_deadline() {
        let mut e = vec![10.0];
        assert!(!delay_schedulable(&mut e, 0, 1, 12.0, |_, _| 5.0));
        assert_eq!(e, vec![10.0]); // untouched on failure
    }

    #[test]
    fn delay_schedulable_uses_more_gpus_when_faster() {
        // 4 GPUs free at 2,4,6,8; exec 16/k seconds; deadline 12:
        // k=1: 2+16=18 ✗; k=2: 4+8=12 ✓
        let mut e = vec![2.0, 4.0, 6.0, 8.0];
        assert!(delay_schedulable(&mut e, 0, 1, 12.0, |_, k| 16.0 / k as f64));
        assert_eq!(e, vec![6.0, 8.0, 12.0, 12.0]);
    }

    #[test]
    fn delay_respects_replica_granularity() {
        // replica = 2: only k = 2 considered; e[1] = 4
        let mut e = vec![2.0, 4.0];
        assert!(delay_schedulable(&mut e, 0, 2, 13.0, |_, k| 16.0 / k as f64));
        assert_eq!(e, vec![12.0, 12.0]);
        let mut e2 = vec![2.0];
        // replica 2 but only 1 busy GPU => cannot delay
        assert!(!delay_schedulable(&mut e2, 0, 2, 100.0, |_, _| 1.0));
    }

    #[test]
    fn cold_allocation_includes_overhead() {
        // exec 10/k s, cold overhead 8, deadline at 16 (now=0):
        // k=1: 8+10=18 ✗; k=2: 8+5=13 ✓
        let mut e = vec![];
        let plans = allocate_from_cold_pool(
            &[0],
            8,
            1,
            8,
            0.0,
            |_| 16.0,
            |_, k| 10.0 / k as f64,
            8.0,
            &mut e,
            true,
        );
        assert_eq!(plans, vec![ColdPlan { job_id: 0, gpus: 2 }]);
        assert_eq!(e.len(), 2);
        assert!((e[0] - 13.0).abs() < 1e-9);
    }

    #[test]
    fn unmeetable_slo_gets_nothing() {
        let mut e = vec![];
        let plans = allocate_from_cold_pool(
            &[0],
            8,
            1,
            8,
            0.0,
            |_| 5.0, // < cold overhead alone
            |_, k| 10.0 / k as f64,
            8.0,
            &mut e,
            true,
        );
        assert!(plans.is_empty());
        assert!(e.is_empty());
    }

    #[test]
    fn delayed_jobs_consume_no_cold_gpus() {
        // two identical jobs; one busy GPU frees at t=1, generous SLOs:
        // the first job is delay-schedulable, the second reserves after it.
        let mut e = vec![1.0];
        let plans = allocate_from_cold_pool(
            &[0, 1],
            8,
            1,
            8,
            0.0,
            |_| 100.0,
            |_, _| 5.0,
            8.0,
            &mut e,
            true,
        );
        assert!(plans.is_empty());
        assert!((e[0] - 11.0).abs() < 1e-9); // 1 + 5 + 5 via two reservations
    }

    #[test]
    fn delay_disabled_forces_cold_allocation() {
        let mut e = vec![1.0];
        let plans = allocate_from_cold_pool(
            &[0],
            8,
            1,
            8,
            0.0,
            |_| 100.0,
            |_, _| 5.0,
            8.0,
            &mut e,
            false,
        );
        assert_eq!(plans.len(), 1);
    }

    #[test]
    fn prop_cold_grants_meet_slo_and_conserve_gpus() {
        check("Algorithm 2 invariants", 200, |rng| {
            let n = 1 + rng.below(10);
            let cold0 = rng.below(24);
            let replica = [1usize, 1, 4][rng.below(3)];
            let now = rng.range_f64(0.0, 100.0);
            let overhead = rng.range_f64(1.0, 30.0);
            let work: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 300.0)).collect();
            let dl: Vec<f64> =
                (0..n).map(|_| now + rng.range_f64(5.0, 200.0)).collect();
            let mut pending: Vec<usize> = (0..n).collect();
            pending.sort_by(|&a, &b| dl[a].partial_cmp(&dl[b]).unwrap());
            let mut e_l: Vec<f64> =
                (0..rng.below(6)).map(|_| now + rng.range_f64(0.0, 50.0)).collect();
            let d = dl.clone();
            let w = work.clone();
            let exec_fn = move |j: usize, g: usize| w[j] / g as f64;
            let use_delay = rng.below(2) == 0;
            let plans = allocate_from_cold_pool(
                &pending,
                cold0,
                replica,
                16,
                now,
                move |j| d[j],
                &exec_fn,
                overhead,
                &mut e_l,
                use_delay,
            );
            let granted: usize = plans.iter().map(|p| p.gpus).sum();
            ensure(granted <= cold0, "cold pool oversubscribed")?;
            for p in &plans {
                ensure(p.gpus % replica == 0, "granularity")?;
                let completion = now + overhead + work[p.job_id] / p.gpus as f64;
                ensure(completion <= dl[p.job_id] + 1e-9,
                       format!("plan misses SLO: job {}", p.job_id))?;
            }
            let mut ids: Vec<usize> = plans.iter().map(|p| p.job_id).collect();
            ids.sort_unstable();
            ids.dedup();
            ensure(ids.len() == plans.len(), "duplicate plan")?;
            Ok(())
        });
    }

    #[test]
    fn prop_delay_reservation_monotone() {
        // After a successful reservation every entry of e_l is >= the
        // entry it replaced (reservations only push availability later).
        check("DelaySchedulable pushes availability later", 200, |rng| {
            let m = 1 + rng.below(8);
            let mut e: Vec<f64> = (0..m).map(|_| rng.range_f64(0.0, 20.0)).collect();
            e.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let before = e.clone();
            let dur = rng.range_f64(0.1, 10.0);
            let dl = rng.range_f64(0.0, 40.0);
            let ok = delay_schedulable(&mut e, 0, 1, dl, |_, k| dur / k as f64);
            ensure(e.len() == before.len(), "length changed")?;
            if ok {
                for i in 0..e.len() {
                    ensure(e[i] >= before[i] - 1e-9, "availability moved earlier")?;
                }
            } else {
                ensure(e == before, "failed delay mutated e_l")?;
            }
            Ok(())
        });
    }
}
