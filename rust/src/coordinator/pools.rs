//! Per-LLM warm GPU pool state: free-GPU tracking with idle timestamps so
//! the idle-window shrink (§4.4.2, Fig 8c) can return GPUs to the cold
//! pool GPU-by-GPU.

/// A warm pool for one LLM. GPUs in the pool are billed whether busy or
/// idle (they hold runtime + weights in memory); `free` GPUs carry the
/// timestamp they became idle.
#[derive(Clone, Debug, Default)]
pub struct WarmPool {
    /// Total GPUs in the pool (busy + free).
    total: usize,
    /// Idle GPUs: the timestamp each became free (kept LIFO so that the
    /// most recently used GPU is reused first and stale ones expire).
    free_since: Vec<f64>,
}

impl WarmPool {
    pub fn new() -> Self {
        WarmPool::default()
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn free(&self) -> usize {
        self.free_since.len()
    }

    pub fn busy(&self) -> usize {
        self.total - self.free_since.len()
    }

    /// Take `n` free GPUs for a job. Returns false (and does nothing) if
    /// fewer than `n` are free.
    pub fn allocate(&mut self, n: usize) -> bool {
        if self.free_since.len() < n {
            return false;
        }
        // LIFO: reuse the most recently released GPUs.
        self.free_since.truncate(self.free_since.len() - n);
        true
    }

    /// Return `n` GPUs from a finished job to the pool at time `now`.
    pub fn release(&mut self, n: usize, now: f64) {
        debug_assert!(self.busy() >= n, "releasing more GPUs than busy");
        for _ in 0..n {
            self.free_since.push(now);
        }
    }

    /// Grow the pool with `n` GPUs from the cold pool; they are
    /// immediately handed to a job by the caller (Algorithm 2), so they
    /// enter busy state.
    pub fn add_busy_from_cold(&mut self, n: usize) {
        self.total += n;
    }

    /// Grow the pool with `n` idle GPUs (pre-warming).
    pub fn add_idle_from_cold(&mut self, n: usize, now: f64) {
        self.total += n;
        for _ in 0..n {
            self.free_since.push(now);
        }
    }

    /// Timestamp at which the longest-idle free GPU became idle — the
    /// next idle-window expiry candidate (None when no GPU is free).
    /// Used by tick coalescing to compute the pool's next wake time.
    pub fn earliest_idle(&self) -> Option<f64> {
        self.free_since.iter().copied().reduce(f64::min)
    }

    /// Remove free GPUs idle longer than `window` (returns how many went
    /// back to the cold pool).
    pub fn expire_idle(&mut self, now: f64, window: f64) -> usize {
        let before = self.free_since.len();
        self.free_since.retain(|&t| now - t <= window);
        let expired = before - self.free_since.len();
        self.total -= expired;
        expired
    }

    /// Drop every free GPU immediately (used when warm pooling is
    /// disabled for the runtime-reusing ablation).
    pub fn drain_idle(&mut self) -> usize {
        let n = self.free_since.len();
        self.free_since.clear();
        self.total -= n;
        n
    }

    /// Remove `n` busy GPUs from the pool without freeing them: the
    /// hardware failed or was reclaimed (fault engine), so it leaves the
    /// pool entirely instead of returning to the idle list.
    pub fn lose_busy(&mut self, n: usize) {
        debug_assert!(self.busy() >= n, "losing more GPUs than busy");
        self.total -= n;
    }

    /// Drop up to `n` idle GPUs (longest-idle first — the fault engine
    /// sheds stale capacity before warm capacity). Returns how many were
    /// actually shed.
    pub fn lose_idle(&mut self, n: usize) -> usize {
        let k = n.min(self.free_since.len());
        self.free_since.drain(..k);
        self.total -= k;
        k
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};
    use crate::util::rng::Rng;

    #[test]
    fn allocate_and_release_roundtrip() {
        let mut p = WarmPool::new();
        p.add_idle_from_cold(4, 0.0);
        assert_eq!(p.total(), 4);
        assert_eq!(p.free(), 4);
        assert!(p.allocate(3));
        assert_eq!(p.busy(), 3);
        assert!(!p.allocate(2)); // only 1 free
        assert_eq!(p.free(), 1);
        p.release(3, 5.0);
        assert_eq!(p.free(), 4);
        assert_eq!(p.busy(), 0);
    }

    #[test]
    fn add_busy_from_cold_goes_straight_to_job() {
        let mut p = WarmPool::new();
        p.add_busy_from_cold(2);
        assert_eq!(p.total(), 2);
        assert_eq!(p.free(), 0);
        assert_eq!(p.busy(), 2);
        p.release(2, 1.0);
        assert_eq!(p.free(), 2);
    }

    #[test]
    fn idle_expiry_respects_window() {
        let mut p = WarmPool::new();
        p.add_idle_from_cold(2, 0.0);
        p.add_idle_from_cold(1, 50.0);
        // at t=70 with 60 s window: the two t=0 GPUs expire
        let expired = p.expire_idle(70.0, 60.0);
        assert_eq!(expired, 2);
        assert_eq!(p.total(), 1);
        assert_eq!(p.free(), 1);
        // the t=50 GPU expires at t=111
        assert_eq!(p.expire_idle(111.0, 60.0), 1);
        assert_eq!(p.total(), 0);
    }

    #[test]
    fn lifo_reuse_keeps_oldest_idle() {
        let mut p = WarmPool::new();
        p.add_idle_from_cold(1, 0.0);
        p.release_helper_for_test(); // no-op marker
        p.add_idle_from_cold(1, 100.0);
        assert!(p.allocate(1)); // takes the t=100 GPU (LIFO)
        // the remaining free GPU is the old one and expires
        assert_eq!(p.expire_idle(100.0, 60.0), 1);
    }

    impl WarmPool {
        fn release_helper_for_test(&mut self) {}
    }

    #[test]
    fn earliest_idle_reports_oldest_free_gpu() {
        let mut p = WarmPool::new();
        assert_eq!(p.earliest_idle(), None);
        p.add_idle_from_cold(1, 5.0);
        p.add_idle_from_cold(1, 2.0);
        p.add_idle_from_cold(1, 9.0);
        assert_eq!(p.earliest_idle(), Some(2.0));
        // expire the t=2 GPU; the oldest is now t=5
        p.expire_idle(63.0, 60.0);
        assert_eq!(p.earliest_idle(), Some(5.0));
    }

    #[test]
    fn drain_idle_removes_all_free() {
        let mut p = WarmPool::new();
        p.add_idle_from_cold(3, 0.0);
        assert!(p.allocate(1));
        assert_eq!(p.drain_idle(), 2);
        assert_eq!(p.total(), 1);
        assert_eq!(p.busy(), 1);
    }

    #[test]
    fn lose_busy_removes_failed_hardware() {
        let mut p = WarmPool::new();
        p.add_busy_from_cold(4);
        p.lose_busy(3);
        assert_eq!(p.total(), 1);
        assert_eq!(p.busy(), 1);
        assert_eq!(p.free(), 0);
    }

    #[test]
    fn lose_idle_sheds_oldest_first_and_caps_at_free() {
        let mut p = WarmPool::new();
        p.add_idle_from_cold(1, 0.0);
        p.add_idle_from_cold(1, 10.0);
        p.add_idle_from_cold(1, 20.0);
        assert_eq!(p.lose_idle(2), 2); // sheds the t=0 and t=10 GPUs
        assert_eq!(p.earliest_idle(), Some(20.0));
        assert_eq!(p.total(), 1);
        assert_eq!(p.lose_idle(5), 1); // capped at what is free
        assert_eq!(p.total(), 0);
    }

    #[test]
    fn prop_invariant_total_eq_busy_plus_free() {
        check("total == busy + free under random ops", 100, |rng: &mut Rng| {
            let mut p = WarmPool::new();
            let mut busy = 0usize;
            let mut t = 0.0;
            for _ in 0..50 {
                t += rng.f64();
                match rng.below(5) {
                    0 => p.add_idle_from_cold(rng.below(4) + 1, t),
                    1 => {
                        let n = rng.below(4) + 1;
                        if p.allocate(n) {
                            busy += n;
                        }
                    }
                    2 => {
                        if busy > 0 {
                            let n = rng.below(busy) + 1;
                            p.release(n, t);
                            busy -= n;
                        }
                    }
                    3 => {
                        let n = rng.below(3);
                        p.add_busy_from_cold(n);
                        busy += n;
                    }
                    _ => {
                        p.expire_idle(t, 2.0);
                    }
                }
                ensure(p.total() == p.busy() + p.free(), "total mismatch")?;
                ensure(p.busy() == busy, format!("busy {} vs {}", p.busy(), busy))?;
            }
            Ok(())
        });
    }
}
