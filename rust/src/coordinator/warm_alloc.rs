//! Algorithm 1 (§4.4.1): GPU allocation from a warm pool.
//!
//! Jobs are taken in ascending-SLO order; each job's allocation grows from
//! one replica until its predicted completion meets its SLO or the pool is
//! exhausted. Jobs whose SLO cannot be met from the warm pool get no
//! allocation (A_i = 0) and stay pending for Algorithm 2.

/// One granted allocation.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WarmAllocation {
    pub job_id: usize,
    pub gpus: usize,
}

/// Run Algorithm 1 over `pending` (must already be sorted by SLO
/// ascending — the caller owns queue ordering).
///
/// * `free` — free GPUs in this LLM's warm pool (R_l).
/// * `replica` — GPU granularity (tensor-parallel group size).
/// * `max_gpus_per_job` — allocation cap per job.
/// * `deadline(job)` — absolute SLO deadline T_i^slo.
/// * `completion(job, gpus)` — estimated absolute completion time
///   T_i^warm(a) when launched now from the warm pool.
///
/// Returns the granted allocations and the remaining free count.
pub fn allocate_from_warm_pool(
    pending: &[usize],
    free: usize,
    replica: usize,
    max_gpus_per_job: usize,
    deadline: impl Fn(usize) -> f64,
    completion: impl Fn(usize, usize) -> f64,
) -> (Vec<WarmAllocation>, usize) {
    let mut grants = vec![];
    let free = allocate_from_warm_pool_into(
        pending, free, replica, max_gpus_per_job, deadline, completion,
        &mut grants,
    );
    (grants, free)
}

/// Allocation-free core of [`allocate_from_warm_pool`]: grants are pushed
/// into a caller-owned (reusable) buffer; returns the remaining free
/// count. The scheduler's steady-state round uses this entry point with
/// scratch buffers.
pub fn allocate_from_warm_pool_into(
    pending: &[usize],
    mut free: usize,
    replica: usize,
    max_gpus_per_job: usize,
    deadline: impl Fn(usize) -> f64,
    completion: impl Fn(usize, usize) -> f64,
    grants: &mut Vec<WarmAllocation>,
) -> usize {
    debug_assert!(replica > 0);
    debug_assert!(grants.is_empty());
    for &job in pending {
        if free < replica {
            break; // pool depleted for every granularity
        }
        let cap = max_gpus_per_job.min(free) / replica * replica;
        if cap == 0 {
            continue;
        }
        // A_i = 1 replica; grow while the SLO is still missed (lines 6-9).
        let mut a = replica;
        while completion(job, a) > deadline(job) && a + replica <= cap {
            a += replica;
        }
        if completion(job, a) <= deadline(job) {
            grants.push(WarmAllocation { job_id: job, gpus: a });
            free -= a; // line 11: R_l -= A_i
        }
        // else: A_i = 0 (line 13) — job stays pending.
    }
    free
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    /// Completion model: now=0, job j needs work[j] GPU-seconds; perfect
    /// linear scaling.
    fn completion_for(work: Vec<f64>) -> impl Fn(usize, usize) -> f64 {
        move |job, gpus| work[job] / gpus as f64
    }

    #[test]
    fn grows_allocation_until_slo_met() {
        // job 0 needs 40 GPU-s, SLO at t=12 => needs 4 GPUs
        let (grants, free) = allocate_from_warm_pool(
            &[0],
            8,
            1,
            8,
            |_| 12.0,
            completion_for(vec![40.0]),
        );
        assert_eq!(grants, vec![WarmAllocation { job_id: 0, gpus: 4 }]);
        assert_eq!(free, 4);
    }

    #[test]
    fn single_gpu_when_slo_loose() {
        let (grants, free) = allocate_from_warm_pool(
            &[0],
            8,
            1,
            8,
            |_| 100.0,
            completion_for(vec![40.0]),
        );
        assert_eq!(grants, vec![WarmAllocation { job_id: 0, gpus: 1 }]);
        assert_eq!(free, 7);
    }

    #[test]
    fn unmeetable_job_gets_zero_and_blocks_nothing() {
        // job 0 needs 1000 GPU-s with SLO 10 (needs 100 GPUs, only 8 free);
        // job 1 trivially satisfiable.
        let (grants, free) = allocate_from_warm_pool(
            &[0, 1],
            8,
            1,
            8,
            |_| 10.0,
            completion_for(vec![1000.0, 5.0]),
        );
        assert_eq!(grants, vec![WarmAllocation { job_id: 1, gpus: 1 }]);
        assert_eq!(free, 7);
    }

    #[test]
    fn respects_replica_granularity() {
        // tensor-parallel LLM: replica = 4; job needs 6 GPU-s, SLO 1.0
        // => 8 GPUs (2 replicas) since 6/4 = 1.5 > 1.0.
        let (grants, _) = allocate_from_warm_pool(
            &[0],
            8,
            4,
            8,
            |_| 1.0,
            completion_for(vec![6.0]),
        );
        assert_eq!(grants, vec![WarmAllocation { job_id: 0, gpus: 8 }]);
    }

    #[test]
    fn pool_depletion_stops_early() {
        let (grants, free) = allocate_from_warm_pool(
            &[0, 1, 2],
            2,
            1,
            8,
            |_| 10.0,
            completion_for(vec![5.0, 5.0, 5.0]),
        );
        assert_eq!(grants.len(), 2);
        assert_eq!(free, 0);
    }

    #[test]
    fn max_gpus_per_job_caps_growth() {
        let (grants, _) = allocate_from_warm_pool(
            &[0],
            16,
            1,
            4,
            |_| 12.0,
            completion_for(vec![40.0]),
        );
        // needs 4 at cap 4 => exactly meets 40/4=10 <= 12
        assert_eq!(grants, vec![WarmAllocation { job_id: 0, gpus: 4 }]);
        // tighter SLO that would need more than the cap => nothing
        let (grants, free) = allocate_from_warm_pool(
            &[0],
            16,
            1,
            4,
            |_| 5.0,
            completion_for(vec![40.0]),
        );
        assert!(grants.is_empty());
        assert_eq!(free, 16);
    }

    #[test]
    fn prop_never_oversubscribes_and_all_grants_meet_slo() {
        check("Algorithm 1 invariants", 200, |rng| {
            let n = 1 + rng.below(12);
            let free0 = rng.below(20);
            let replica = [1usize, 1, 1, 4][rng.below(4)];
            let work: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 200.0)).collect();
            let slo: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 100.0)).collect();
            let mut pending: Vec<usize> = (0..n).collect();
            pending.sort_by(|&a, &b| slo[a].partial_cmp(&slo[b]).unwrap());
            let w = work.clone();
            let s = slo.clone();
            let (grants, free) = allocate_from_warm_pool(
                &pending,
                free0,
                replica,
                8,
                move |j| s[j],
                move |j, g| w[j] / g as f64,
            );
            let granted: usize = grants.iter().map(|g| g.gpus).sum();
            ensure(granted + free == free0, "GPU conservation")?;
            for g in &grants {
                ensure(g.gpus % replica == 0, "granularity")?;
                ensure(g.gpus <= 8, "cap")?;
                ensure(
                    work[g.job_id] / g.gpus as f64 <= slo[g.job_id] + 1e-9,
                    format!("grant misses SLO: job {}", g.job_id),
                )?;
            }
            // no duplicate grants
            let mut ids: Vec<usize> = grants.iter().map(|g| g.job_id).collect();
            ids.sort_unstable();
            ids.dedup();
            ensure(ids.len() == grants.len(), "duplicate job grant")?;
            Ok(())
        });
    }

    #[test]
    fn prop_minimal_sufficient_allocation() {
        // Algorithm 1 allocates the smallest replica multiple meeting the
        // SLO — granting fewer GPUs would miss it.
        check("Algorithm 1 minimality", 200, |rng| {
            let work = rng.range_f64(1.0, 100.0);
            let slo = rng.range_f64(0.5, 50.0);
            let (grants, _) = allocate_from_warm_pool(
                &[0],
                64,
                1,
                64,
                |_| slo,
                move |_, g| work / g as f64,
            );
            if let Some(g) = grants.first() {
                ensure(work / g.gpus as f64 <= slo, "meets SLO")?;
                if g.gpus > 1 {
                    ensure(
                        work / (g.gpus - 1) as f64 > slo,
                        format!("not minimal: {} gpus", g.gpus),
                    )?;
                }
            }
            Ok(())
        });
    }
}
