//! Config-driven chaos engine: continuous misbehavior layered on the
//! seeded fault plans.
//!
//! The fault engine models *discrete* capacity events; real fleets also
//! degrade continuously. This module adds three such regimes, all
//! deterministic in the run seed (hash-derived draws, no RNG state at
//! lookup time — the `promptbank::task_feature` discipline — so chaos
//! runs stay bit-identical under dense and coalesced ticking):
//!
//! * **latency tails** — a [`ChaosProfile`] configures tail fractions
//!   and stretch factors for the job-launch and bank-lookup paths; the
//!   profile compiles to a [`ChaosInjection`] the simulator applies
//!   inside `ClusterState::launch` (profile/injection split: the
//!   profile is config, the injection is the armed sampling model);
//! * **correlated failure domains** — a [`DomainTopology`] partitions
//!   the fleet into racks, and `FaultInjector` fans every
//!   `GpuFailure`/`SpotReclaim` revocation out to whole racks instead
//!   of independent GPUs, keeping each rack dead until its repair;
//! * **completion errors** — [`ChaosEngine::try_fail`] rejects a
//!   hash-selected fraction of completions back into the queue
//!   ([`ClusterState::fail_completion`]) with a retry budget and
//!   exponential backoff, delivered to policies through
//!   [`Policy::on_retry`](crate::cluster::Policy::on_retry); once the
//!   budget is spent the run is accepted best-effort (a give-up, not a
//!   stranded job).

use crate::cluster::{ChaosInjection, ClusterState, RetryEvent};
use crate::config::Config;
use crate::util::rng::Rng;

/// The built-in chaos profiles (the scenario catalogue's chaos families
/// map onto these 1:1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChaosKind {
    /// Latency tails only: slow launches and bank lookups, no failures.
    LatencyTail,
    /// Flaky completions: mild tails plus completion errors with
    /// retry-and-backoff recovery.
    Flaky,
    /// Rack storm: completion errors and tails on top of correlated
    /// whole-rack failures (pair with a `FaultPlan` of failure waves).
    RackStorm,
    /// Network partition: a shard is periodically severed from the
    /// global router for a window (`partition_period_s` / `partition_s`).
    /// Local scheduling continues inside the severed cell; only the
    /// routing and gossip planes are cut. Consumed by
    /// [`crate::shard::ShardPlane`] — single-cluster runs have no router
    /// to sever, so this kind stays out of [`ChaosKind::ALL`].
    Partition,
}

impl ChaosKind {
    /// The single-cluster chaos rotation (scenario catalogue, property
    /// tests). `Partition` is excluded: it only acts on the shard plane.
    pub const ALL: [ChaosKind; 3] =
        [ChaosKind::LatencyTail, ChaosKind::Flaky, ChaosKind::RackStorm];

    pub fn profile(self) -> ChaosProfile {
        match self {
            ChaosKind::LatencyTail => ChaosProfile::latency_tail(),
            ChaosKind::Flaky => ChaosProfile::flaky(),
            ChaosKind::RackStorm => ChaosProfile::rack_storm(),
            ChaosKind::Partition => ChaosProfile::partition(),
        }
    }
}

/// Chaos configuration: what misbehavior a run injects and how hard.
/// Pure data — building one does nothing until it is compiled into a
/// [`ChaosEngine`] (and its latency part into a [`ChaosInjection`]).
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosProfile {
    /// Profile name (bench labels, config round-trips).
    pub name: String,
    /// Fraction of launches whose initialization delay is stretched.
    pub launch_tail_frac: f64,
    /// Maximum initialization-delay multiplier (≥ 1).
    pub launch_tail_factor: f64,
    /// Fraction of bank lookups whose latency is stretched.
    pub lookup_tail_frac: f64,
    /// Maximum bank-lookup latency multiplier (≥ 1).
    pub lookup_tail_factor: f64,
    /// Fraction of completions rejected back into the queue.
    pub completion_error_frac: f64,
    /// Fraction of the job's full iteration count re-run per retry.
    pub redo_frac: f64,
    /// Failed completions a job may retry before the engine gives up
    /// and accepts the run best-effort.
    pub retry_budget: u32,
    /// First retry backoff, seconds.
    pub backoff_base_s: f64,
    /// Backoff growth per retry (≥ 1: exponential, monotone per job).
    pub backoff_factor: f64,
    /// Failure domains (racks) the fleet is partitioned into; 0 keeps
    /// today's independent per-GPU revocations.
    pub domains: usize,
    /// Network-partition cadence: one partition event per period
    /// (shard-plane only; 0 disables).
    pub partition_period_s: f64,
    /// How long each partition severs its victim shard from the router
    /// (must be ≤ the period; 0 disables).
    pub partition_s: f64,
}

impl ChaosProfile {
    /// Latency tails only — no failures, no topology.
    pub fn latency_tail() -> ChaosProfile {
        ChaosProfile {
            name: "latency-tail".into(),
            launch_tail_frac: 0.30,
            launch_tail_factor: 4.0,
            lookup_tail_frac: 0.30,
            lookup_tail_factor: 3.0,
            completion_error_frac: 0.0,
            redo_frac: 0.5,
            retry_budget: 0,
            backoff_base_s: 0.0,
            backoff_factor: 1.0,
            domains: 0,
            partition_period_s: 0.0,
            partition_s: 0.0,
        }
    }

    /// Flaky completions with retry/backoff recovery plus mild tails.
    pub fn flaky() -> ChaosProfile {
        ChaosProfile {
            name: "flaky".into(),
            launch_tail_frac: 0.10,
            launch_tail_factor: 2.0,
            lookup_tail_frac: 0.10,
            lookup_tail_factor: 2.0,
            completion_error_frac: 0.12,
            redo_frac: 0.5,
            retry_budget: 2,
            backoff_base_s: 15.0,
            backoff_factor: 2.0,
            domains: 0,
            partition_period_s: 0.0,
            partition_s: 0.0,
        }
    }

    /// Correlated rack failures plus flaky completions and tails.
    pub fn rack_storm() -> ChaosProfile {
        ChaosProfile {
            name: "rack-storm".into(),
            launch_tail_frac: 0.15,
            launch_tail_factor: 3.0,
            lookup_tail_frac: 0.15,
            lookup_tail_factor: 3.0,
            completion_error_frac: 0.08,
            redo_frac: 0.5,
            retry_budget: 2,
            backoff_base_s: 20.0,
            backoff_factor: 2.0,
            domains: 4,
            partition_period_s: 0.0,
            partition_s: 0.0,
        }
    }

    /// Network partitions only: every 10 minutes one shard loses its
    /// router link for 2 minutes. No tails, no completion errors — the
    /// profile isolates the routing/gossip failure mode so shard-plane
    /// runs attribute every effect to the partition itself.
    pub fn partition() -> ChaosProfile {
        ChaosProfile {
            name: "partition".into(),
            launch_tail_frac: 0.0,
            launch_tail_factor: 1.0,
            lookup_tail_frac: 0.0,
            lookup_tail_factor: 1.0,
            completion_error_frac: 0.0,
            redo_frac: 0.5,
            retry_budget: 0,
            backoff_base_s: 0.0,
            backoff_factor: 1.0,
            domains: 0,
            partition_period_s: 600.0,
            partition_s: 120.0,
        }
    }

    /// Resolve a built-in profile by name.
    pub fn by_name(name: &str) -> Option<ChaosProfile> {
        match name {
            "latency-tail" => Some(ChaosProfile::latency_tail()),
            "flaky" => Some(ChaosProfile::flaky()),
            "rack-storm" => Some(ChaosProfile::rack_storm()),
            "partition" => Some(ChaosProfile::partition()),
            _ => None,
        }
    }

    /// Build a profile from a `[chaos]` config section: `chaos.profile`
    /// names the built-in base, every numeric knob can be overridden
    /// individually. Validated before returning.
    ///
    /// ```text
    /// [chaos]
    /// profile = "flaky"
    /// completion_error_frac = 0.2
    /// retry_budget = 3
    /// ```
    pub fn from_config(cfg: &Config) -> Result<ChaosProfile, String> {
        let base = cfg.str_or("chaos.profile", "latency-tail");
        let mut p = ChaosProfile::by_name(base)
            .ok_or_else(|| format!("unknown chaos profile '{base}'"))?;
        p.launch_tail_frac =
            cfg.f64_or("chaos.launch_tail_frac", p.launch_tail_frac);
        p.launch_tail_factor =
            cfg.f64_or("chaos.launch_tail_factor", p.launch_tail_factor);
        p.lookup_tail_frac =
            cfg.f64_or("chaos.lookup_tail_frac", p.lookup_tail_frac);
        p.lookup_tail_factor =
            cfg.f64_or("chaos.lookup_tail_factor", p.lookup_tail_factor);
        p.completion_error_frac =
            cfg.f64_or("chaos.completion_error_frac", p.completion_error_frac);
        p.redo_frac = cfg.f64_or("chaos.redo_frac", p.redo_frac);
        p.retry_budget =
            cfg.usize_or("chaos.retry_budget", p.retry_budget as usize) as u32;
        p.backoff_base_s =
            cfg.f64_or("chaos.backoff_base_s", p.backoff_base_s);
        p.backoff_factor =
            cfg.f64_or("chaos.backoff_factor", p.backoff_factor);
        p.domains = cfg.usize_or("chaos.domains", p.domains);
        p.partition_period_s =
            cfg.f64_or("chaos.partition_period_s", p.partition_period_s);
        p.partition_s = cfg.f64_or("chaos.partition_s", p.partition_s);
        p.validate()?;
        Ok(p)
    }

    /// Check every knob is in its sane range.
    pub fn validate(&self) -> Result<(), String> {
        let frac = |name: &str, v: f64| {
            if (0.0..=1.0).contains(&v) && v.is_finite() {
                Ok(())
            } else {
                Err(format!("chaos.{name} = {v} outside [0, 1]"))
            }
        };
        frac("launch_tail_frac", self.launch_tail_frac)?;
        frac("lookup_tail_frac", self.lookup_tail_frac)?;
        frac("completion_error_frac", self.completion_error_frac)?;
        let factor = |name: &str, v: f64| {
            if v >= 1.0 && v.is_finite() {
                Ok(())
            } else {
                Err(format!("chaos.{name} = {v} must be ≥ 1"))
            }
        };
        factor("launch_tail_factor", self.launch_tail_factor)?;
        factor("lookup_tail_factor", self.lookup_tail_factor)?;
        factor("backoff_factor", self.backoff_factor)?;
        if !(self.redo_frac > 0.0 && self.redo_frac.is_finite()) {
            return Err(format!(
                "chaos.redo_frac = {} must be positive",
                self.redo_frac
            ));
        }
        if !(self.backoff_base_s >= 0.0 && self.backoff_base_s.is_finite()) {
            return Err(format!(
                "chaos.backoff_base_s = {} must be non-negative",
                self.backoff_base_s
            ));
        }
        for (name, v) in [
            ("partition_period_s", self.partition_period_s),
            ("partition_s", self.partition_s),
        ] {
            if !(v >= 0.0 && v.is_finite()) {
                return Err(format!("chaos.{name} = {v} must be non-negative"));
            }
        }
        if self.partition_s > 0.0 && self.partition_s > self.partition_period_s
        {
            return Err(format!(
                "chaos.partition_s = {} exceeds the period {}",
                self.partition_s, self.partition_period_s
            ));
        }
        Ok(())
    }

    /// Compile the latency part into the model the simulator arms
    /// (`ClusterState::set_chaos`); `None` when no tails are configured.
    pub fn injection(&self, salt: u64) -> Option<ChaosInjection> {
        if self.launch_tail_frac > 0.0 || self.lookup_tail_frac > 0.0 {
            Some(ChaosInjection {
                salt,
                launch_tail_frac: self.launch_tail_frac,
                launch_tail_factor: self.launch_tail_factor,
                lookup_tail_frac: self.lookup_tail_frac,
                lookup_tail_factor: self.lookup_tail_factor,
            })
        } else {
            None
        }
    }
}

/// A rack/AZ topology over the fleet: `domains` equal racks of
/// `cluster_gpus / domains` GPUs (leftover GPUs sit outside any rack).
/// A revocation request fans out to whole alive racks — one failure
/// event takes its entire domain down until repair — chosen by a
/// deterministic rotation so repeated events spread across racks.
#[derive(Clone, Debug)]
pub struct DomainTopology {
    domain_size: usize,
    /// Per-rack absolute time the rack is dead until.
    dead_until: Vec<f64>,
    /// Rotation cursor (deterministic rack choice across events).
    cursor: usize,
}

impl DomainTopology {
    pub fn new(cluster_gpus: usize, domains: usize) -> DomainTopology {
        let domains = domains.clamp(1, cluster_gpus.max(1));
        DomainTopology {
            domain_size: (cluster_gpus / domains).max(1),
            dead_until: vec![f64::NEG_INFINITY; domains],
            cursor: 0,
        }
    }

    pub fn domains(&self) -> usize {
        self.dead_until.len()
    }

    pub fn domain_size(&self) -> usize {
        self.domain_size
    }

    /// Expand a `requested`-GPU revocation to whole alive racks: racks
    /// are marked dead until `now + repair_s` (starting at the rotation
    /// cursor) until they cover the request, never claiming more than
    /// `headroom` (what the injector can actually revoke — keeping the
    /// oracle's `revoked ≥ dead-domain` invariant). Returns the fanned
    /// GPU count; when no whole rack fits the headroom the request
    /// passes through unfanned (and nothing is marked dead).
    pub fn fan_out(&mut self, now: f64, requested: usize, repair_s: f64,
                   headroom: usize) -> usize {
        let target = requested.min(headroom);
        if target == 0 {
            return 0;
        }
        let n = self.dead_until.len();
        let mut covered = 0usize;
        for k in 0..n {
            if covered >= target || covered + self.domain_size > headroom {
                break;
            }
            let d = (self.cursor + k) % n;
            if self.dead_until[d] > now {
                continue; // already dead
            }
            self.dead_until[d] = now + repair_s;
            covered += self.domain_size;
        }
        self.cursor = (self.cursor + 1) % n;
        if covered == 0 {
            requested
        } else {
            covered
        }
    }

    /// GPUs inside dead racks at `now`.
    pub fn dead_gpus(&self, now: f64) -> usize {
        self.dead_until.iter().filter(|&&t| t > now).count()
            * self.domain_size
    }
}

/// The runtime chaos engine `FaultInjector` drives: completion-error
/// draws, the failure-domain topology, and give-up accounting. The
/// latency part lives in the simulator as the armed [`ChaosInjection`].
pub struct ChaosEngine {
    profile: ChaosProfile,
    salt: u64,
    topology: Option<DomainTopology>,
    giveups: u64,
}

impl ChaosEngine {
    pub fn new(profile: ChaosProfile, seed: u64,
               cluster_gpus: usize) -> ChaosEngine {
        debug_assert!(profile.validate().is_ok());
        let topology = if profile.domains > 0 && cluster_gpus > 0 {
            Some(DomainTopology::new(cluster_gpus, profile.domains))
        } else {
            None
        };
        ChaosEngine {
            salt: seed ^ 0x5EED_C4A0_5107_0003,
            profile,
            topology,
            giveups: 0,
        }
    }

    pub fn profile(&self) -> &ChaosProfile {
        &self.profile
    }

    pub fn topology(&self) -> Option<&DomainTopology> {
        self.topology.as_ref()
    }

    /// Completed runs accepted best-effort after their retry budget was
    /// exhausted (the give-up path).
    pub fn giveups(&self) -> u64 {
        self.giveups
    }

    /// The armed latency-injection model (None = no tails configured).
    pub fn injection(&self) -> Option<ChaosInjection> {
        self.profile.injection(self.salt)
    }

    /// Completion-error draw for a just-completed job. On failure the
    /// job is already back in `Pending` ([`ClusterState::fail_completion`])
    /// and the returned event must be delivered to the policy's
    /// `on_retry`. `None` accepts the completion — either the draw
    /// passed, or the retry budget is exhausted (a give-up: the run is
    /// kept best-effort rather than stranded).
    pub fn try_fail(&mut self, st: &mut ClusterState,
                    job_id: usize) -> Option<RetryEvent> {
        let p = &self.profile;
        if p.completion_error_frac <= 0.0 {
            return None;
        }
        let job = &st.jobs[job_id];
        let attempt = job.retries; // completed attempts so far
        let u = Rng::new(
            self.salt
                ^ 0x31
                ^ (job_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (u64::from(attempt) + 1)
                    .wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        )
        .f64();
        if u >= p.completion_error_frac {
            return None;
        }
        if attempt >= p.retry_budget {
            self.giveups += 1;
            return None;
        }
        let now = st.now();
        let gpus = (job.gpu_seconds
            / (job.completed_at - job.launched_at).max(1e-9))
        .round() as usize;
        let redo = (job.spec.iters_at(job.quality) * p.redo_frac).max(1.0);
        let backoff =
            p.backoff_base_s * p.backoff_factor.powi(attempt as i32);
        st.fail_completion(job_id, redo, backoff);
        Some(RetryEvent {
            job_id,
            gpus,
            attempt: attempt + 1,
            not_before: now + backoff,
        })
    }

    /// Fan a revocation out to its failure domains (identity without a
    /// topology). See [`DomainTopology::fan_out`].
    pub fn fan_out(&mut self, now: f64, requested: usize, repair_s: f64,
                   headroom: usize) -> usize {
        match &mut self.topology {
            Some(t) => t.fan_out(now, requested, repair_s, headroom),
            None => requested,
        }
    }

    /// GPUs inside dead domains at `now` (0 without a topology).
    pub fn dead_gpus(&self, now: f64) -> usize {
        self.topology.as_ref().map_or(0, |t| t.dead_gpus(now))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builtin_profiles_validate_and_resolve_by_name() {
        for kind in ChaosKind::ALL {
            let p = kind.profile();
            p.validate().unwrap();
            assert_eq!(ChaosProfile::by_name(&p.name), Some(p));
        }
        assert_eq!(ChaosProfile::by_name("no-such-profile"), None);
    }

    #[test]
    fn partition_profile_validates_and_resolves() {
        let p = ChaosProfile::partition();
        p.validate().unwrap();
        assert_eq!(ChaosProfile::by_name("partition"), Some(p.clone()));
        assert_eq!(ChaosKind::Partition.profile(), p);
        assert!(p.partition_s > 0.0 && p.partition_s <= p.partition_period_s);
        // partitions inject no single-cluster chaos at all
        assert!(p.injection(1).is_none());
        assert_eq!(p.completion_error_frac, 0.0);
        // a window longer than its period is rejected
        let mut bad = p;
        bad.partition_s = bad.partition_period_s + 1.0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn from_config_overrides_the_base_profile() {
        let cfg = Config::parse(
            "[chaos]\n\
             profile = \"flaky\"\n\
             completion_error_frac = 0.25\n\
             retry_budget = 3\n\
             domains = 8\n",
        )
        .unwrap();
        let p = ChaosProfile::from_config(&cfg).unwrap();
        assert_eq!(p.name, "flaky");
        assert_eq!(p.completion_error_frac, 0.25);
        assert_eq!(p.retry_budget, 3);
        assert_eq!(p.domains, 8);
        // untouched knobs keep the base profile's values
        assert_eq!(p.backoff_base_s, ChaosProfile::flaky().backoff_base_s);
    }

    #[test]
    fn from_config_rejects_unknown_profiles_and_bad_knobs() {
        let cfg = Config::parse("[chaos]\nprofile = \"nope\"\n").unwrap();
        assert!(ChaosProfile::from_config(&cfg).is_err());
        let cfg = Config::parse(
            "[chaos]\nprofile = \"flaky\"\ncompletion_error_frac = 1.5\n",
        )
        .unwrap();
        let err = ChaosProfile::from_config(&cfg).unwrap_err();
        assert!(err.contains("completion_error_frac"), "{err}");
        let cfg = Config::parse(
            "[chaos]\nprofile = \"flaky\"\nbackoff_factor = 0.5\n",
        )
        .unwrap();
        assert!(ChaosProfile::from_config(&cfg).is_err());
    }

    #[test]
    fn injection_draws_are_deterministic_and_bounded() {
        let p = ChaosProfile::latency_tail();
        let inj = p.injection(42).expect("tails configured");
        for job in 0..200usize {
            for gen in 0..3u64 {
                let a = inj.launch_stretch(job, gen);
                let b = inj.launch_stretch(job, gen);
                assert_eq!(a.to_bits(), b.to_bits());
                assert!((1.0..=p.launch_tail_factor).contains(&a), "{a}");
                let l = inj.lookup_stretch(job, gen);
                assert!((1.0..=p.lookup_tail_factor).contains(&l), "{l}");
            }
        }
        // the configured fraction of launches is actually stretched
        let stretched = (0..1000usize)
            .filter(|&j| inj.launch_stretch(j, 0) > 1.0)
            .count();
        assert!((150..450).contains(&stretched), "{stretched}/1000");
        // no tails configured → no injection model at all
        let mut quiet = p.clone();
        quiet.launch_tail_frac = 0.0;
        quiet.lookup_tail_frac = 0.0;
        assert!(quiet.injection(42).is_none());
    }

    #[test]
    fn topology_fans_small_requests_to_whole_racks() {
        let mut t = DomainTopology::new(32, 4);
        assert_eq!(t.domain_size(), 8);
        let fanned = t.fan_out(100.0, 3, 300.0, 32);
        assert_eq!(fanned, 8, "one whole rack");
        assert_eq!(t.dead_gpus(100.0), 8);
        assert_eq!(t.dead_gpus(399.0), 8);
        assert_eq!(t.dead_gpus(400.0), 0, "repaired at now + repair_s");
        // a bigger request takes several racks
        let fanned = t.fan_out(100.0, 12, 300.0, 24);
        assert_eq!(fanned, 16);
        assert_eq!(t.dead_gpus(100.0), 24);
    }

    #[test]
    fn topology_never_exceeds_headroom() {
        let mut t = DomainTopology::new(32, 4);
        // headroom below one rack: the request passes through unfanned
        // and no rack is marked dead
        let fanned = t.fan_out(0.0, 6, 300.0, 4);
        assert_eq!(fanned, 6);
        assert_eq!(t.dead_gpus(0.0), 0);
        // headroom fits exactly one rack even though the request wants 2
        let fanned = t.fan_out(0.0, 16, 300.0, 8);
        assert_eq!(fanned, 8);
        assert_eq!(t.dead_gpus(0.0), 8);
    }

    #[test]
    fn topology_rotation_spreads_events_across_racks() {
        let mut t = DomainTopology::new(32, 4);
        assert_eq!(t.fan_out(0.0, 1, 1e9, 32), 8);
        assert_eq!(t.fan_out(1.0, 1, 1e9, 32), 8);
        assert_eq!(t.fan_out(2.0, 1, 1e9, 32), 8);
        assert_eq!(t.dead_gpus(3.0), 24, "three distinct racks dead");
    }

    #[test]
    fn engine_without_topology_passes_revocations_through() {
        let mut e = ChaosEngine::new(ChaosProfile::flaky(), 7, 32);
        assert!(e.topology().is_none());
        assert_eq!(e.fan_out(0.0, 5, 60.0, 32), 5);
        assert_eq!(e.dead_gpus(0.0), 0);
        let e = ChaosEngine::new(ChaosProfile::rack_storm(), 7, 32);
        assert_eq!(e.topology().unwrap().domains(), 4);
    }
}
