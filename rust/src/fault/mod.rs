//! Deterministic fault & preemption engine.
//!
//! The simulator's elasticity story was previously all *voluntary*: GPUs
//! only ever left a policy's footprint when the policy chose to release
//! them. This module adds involuntary churn — the regime where
//! ElasticFlow-style scaling plans break and crash-aware SLO budgeting
//! earns its keep:
//!
//! * a [`FaultPlan`] is a seeded, time-sorted schedule of
//!   [`FaultKind::GpuFailure`] (abrupt, loses work back to the last
//!   periodic checkpoint), [`FaultKind::SpotReclaim`] (a notice window
//!   first — the ceiling drops immediately, jobs checkpoint on the way
//!   out and lose nothing), and [`FaultKind::Straggler`] slowdowns
//!   (the running job with the most remaining work stretches);
//! * the [`FaultInjector`] policy wrapper drives the plan against any
//!   [`Policy`]: it preempts victims through
//!   [`ClusterState::revoke_job`], notifies the policy through the
//!   [`Policy::on_revoke`] hook, lowers the scheduling ceiling through
//!   `Policy::set_capacity`, and returns capacity on repair;
//! * the checkpoint/restore cost model ([`CheckpointModel`]) is charged
//!   through the existing cost integration: periodic checkpoints slow
//!   effective iteration time, lost work re-runs, and restores pay a
//!   fixed overhead at relaunch — no silent job restarts.
//!
//! The [`chaos`] submodule layers *continuous* misbehavior on top of the
//! discrete plans: hash-derived latency tails on launch/bank-lookup
//! paths, correlated failure domains (one event takes whole racks down),
//! and completion errors with retry-budget/backoff recovery delivered
//! through `Policy::on_retry` — see [`ChaosProfile`] / [`ChaosEngine`]
//! and [`FaultInjector::with_chaos`].
//!
//! Everything is deterministic in the plan seed and declared through
//! [`Wake::At`], so faulted runs stay bit-identical under dense and
//! coalesced ticking (enforced by
//! `prop_tick_coalescing_matches_dense_reference`) and oracle-clean
//! (`StateAudit` audits that revoked GPUs are never re-granted before
//! repair and that lost-work, retry, and dead-domain accounting is
//! conserved).

pub mod chaos;

pub use chaos::{ChaosEngine, ChaosKind, ChaosProfile, DomainTopology};

use crate::cluster::{CheckpointModel, ClusterState, JobStatus, KnobSpec,
                     Policy, RetryEvent, Revoked, RevokeEvent, TunedPrompt,
                     TunerReport, Wake};
use crate::util::rng::Rng;
use crate::workload::Llm;

/// One scheduled fault.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultKind {
    /// `gpus` fail abruptly (no notice): victims lose the work done
    /// since their last periodic checkpoint. Repaired `repair_s` later
    /// (`f64::INFINITY` = never).
    GpuFailure { gpus: usize, repair_s: f64 },
    /// Spot reclamation: the notice lands now (the scheduling ceiling
    /// drops immediately so nothing new is provisioned onto doomed
    /// capacity), the GPUs are revoked `notice_s` later — gracefully, so
    /// victims checkpoint and lose no work — and the capacity returns
    /// `repair_s` after the revocation (the reclaim wave ends).
    SpotReclaim { gpus: usize, notice_s: f64, repair_s: f64 },
    /// Slow the running job with the most remaining work by `factor`
    /// (≥ 1): its remaining iterations stretch by that factor.
    Straggler { factor: f64 },
}

/// A fault at an absolute simulated time. The injector applies it at the
/// first scheduling round at or after `at` (declared via [`Wake::At`], so
/// the round is never coalesced away).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultEvent {
    pub at: f64,
    pub kind: FaultKind,
}

/// A time-sorted schedule of faults, bit-deterministic in the seed that
/// built it.
#[derive(Clone, Debug, Default)]
pub struct FaultPlan {
    events: Vec<FaultEvent>,
}

impl FaultPlan {
    /// Build a plan from events (sorted by time; ties keep input order).
    pub fn new(mut events: Vec<FaultEvent>) -> Self {
        events.sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap());
        FaultPlan { events }
    }

    pub fn events(&self) -> &[FaultEvent] {
        &self.events
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Spot-market reclaim waves: `waves` reclamations of
    /// `gpus_per_wave` GPUs spread across the window (seeded ±60 s
    /// jitter), each with a `notice_s` warning and capacity returning
    /// `repair_s` after the revocation, plus one mid-window straggler
    /// (reclaim churn leaves degraded neighbors behind).
    pub fn spot_market(seed: u64, window_s: f64, waves: usize,
                       gpus_per_wave: usize, notice_s: f64,
                       repair_s: f64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0x5EED_5107_FA17_0001);
        let mut events = Vec::with_capacity(waves + 1);
        for i in 0..waves {
            let base = window_s * (i as f64 + 1.0) / (waves as f64 + 1.0);
            let at = (base + rng.range_f64(-60.0, 60.0)).max(0.0);
            events.push(FaultEvent {
                at,
                kind: FaultKind::SpotReclaim {
                    gpus: gpus_per_wave,
                    notice_s,
                    repair_s,
                },
            });
        }
        events.push(FaultEvent {
            at: (window_s * 0.5 + rng.range_f64(0.0, 30.0)).max(0.0),
            kind: FaultKind::Straggler { factor: 1.5 },
        });
        FaultPlan::new(events)
    }

    /// Availability-zone outage: one correlated mass failure of `gpus`
    /// GPUs at ~35 % of the window (seeded ±30 s jitter, no notice),
    /// repaired after `repair_s`, with `stragglers` slowdown events in
    /// the recovery wake (nodes come back degraded).
    pub fn az_outage(seed: u64, window_s: f64, gpus: usize, repair_s: f64,
                     stragglers: usize) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0x5EED_A207_FA17_0002);
        let at = (window_s * 0.35 + rng.range_f64(-30.0, 30.0)).max(0.0);
        let mut events = vec![FaultEvent {
            at,
            kind: FaultKind::GpuFailure { gpus, repair_s },
        }];
        for k in 0..stragglers {
            events.push(FaultEvent {
                at: at + repair_s + 30.0 + 45.0 * k as f64
                    + rng.range_f64(0.0, 15.0),
                kind: FaultKind::Straggler { factor: 1.5 },
            });
        }
        FaultPlan::new(events)
    }

    /// Rolling correlated failures: `waves` abrupt GPU-failure events of
    /// `gpus_per_wave` spread across the window (seeded ±45 s jitter),
    /// each repaired `repair_s` later. Built for the chaos engine's rack
    /// topology — each wave fans out to whole failure domains.
    pub fn rolling_failures(seed: u64, window_s: f64, waves: usize,
                            gpus_per_wave: usize,
                            repair_s: f64) -> FaultPlan {
        let mut rng = Rng::new(seed ^ 0x5EED_9077_FA17_0004);
        let mut events = Vec::with_capacity(waves);
        for i in 0..waves {
            let base = window_s * (i as f64 + 1.0) / (waves as f64 + 1.0);
            let at = (base + rng.range_f64(-45.0, 45.0)).max(0.0);
            events.push(FaultEvent {
                at,
                kind: FaultKind::GpuFailure { gpus: gpus_per_wave, repair_s },
            });
        }
        FaultPlan::new(events)
    }
}

/// Drives a [`FaultPlan`] against any wrapped [`Policy`]. Faults are
/// applied at the first executed round at/after their scheduled time
/// (times are declared through [`Wake::At`], so coalescing never skips
/// them); repairs and reclaim-notice expiries work the same way. The
/// wrapper is deterministic — no RNG, no wall clock — so faulted runs
/// stay bit-reproducible per (trace seed, plan).
pub struct FaultInjector<P: Policy> {
    inner: P,
    plan: FaultPlan,
    ckpt: CheckpointModel,
    /// Cursor into `plan.events`.
    next_event: usize,
    /// Reclaims inside their notice window: (revoke_at, gpus, repair_s).
    pending_reclaims: Vec<(f64, usize, f64)>,
    /// Scheduled repairs: (repair_at, gpus).
    repairs: Vec<(f64, usize)>,
    /// GPUs currently revoked (failed / reclaimed, not yet repaired).
    revoked_out: usize,
    /// The wrapped policy's capacity at start (the fleet the plan
    /// degrades and repairs back to).
    base_capacity: usize,
    started: bool,
    /// Chaos engine (latency tails, failure domains, completion
    /// errors). `None` keeps the plain fault-engine semantics.
    chaos: Option<ChaosEngine>,
}

impl<P: Policy> FaultInjector<P> {
    pub fn new(inner: P, plan: FaultPlan, ckpt: CheckpointModel) -> Self {
        FaultInjector {
            inner,
            plan,
            ckpt,
            next_event: 0,
            pending_reclaims: vec![],
            repairs: vec![],
            revoked_out: 0,
            base_capacity: 0,
            started: false,
            chaos: None,
        }
    }

    /// Like [`FaultInjector::new`], with a [`ChaosEngine`] layered on:
    /// latency tails are armed in the simulator at run start, plan
    /// revocations fan out to the engine's failure domains, and
    /// completions pass through its completion-error draw (failures are
    /// delivered to the policy's `on_retry` instead of
    /// `on_job_complete`). The plan may be empty for pure-chaos runs.
    pub fn with_chaos(inner: P, plan: FaultPlan, ckpt: CheckpointModel,
                      chaos: ChaosEngine) -> Self {
        let mut this = FaultInjector::new(inner, plan, ckpt);
        this.chaos = Some(chaos);
        this
    }

    pub fn into_inner(self) -> P {
        self.inner
    }

    /// GPUs currently revoked and awaiting repair.
    pub fn outstanding_revoked(&self) -> usize {
        self.revoked_out
    }

    /// The layered chaos engine, if any (telemetry: give-up counts).
    pub fn chaos(&self) -> Option<&ChaosEngine> {
        self.chaos.as_ref()
    }

    fn ensure_started(&mut self, st: &mut ClusterState) {
        if !self.started {
            self.started = true;
            self.base_capacity = self
                .inner
                .capacity()
                .unwrap_or(st.cfg.max_gpus)
                .min(st.cfg.max_gpus);
            st.set_checkpoint_model(Some(self.ckpt.clone()));
            if let Some(ch) = &self.chaos {
                st.set_chaos(ch.injection());
            }
        }
    }

    /// The ceiling the wrapped policy may schedule within: the base
    /// fleet minus revoked GPUs minus capacity already under a reclaim
    /// notice (doomed — nothing new should be provisioned onto it).
    fn ceiling(&self) -> usize {
        let noticed: usize =
            self.pending_reclaims.iter().map(|&(_, g, _)| g).sum();
        self.base_capacity
            .saturating_sub(self.revoked_out + noticed)
    }

    /// Apply every timeline item due at/before now: repairs first (the
    /// fleet heals before it degrades further), then reclaim-notice
    /// expiries, then plan events, in deterministic order.
    fn apply_due(&mut self, st: &mut ClusterState) {
        let now = st.now();
        let mut repaired = 0usize;
        self.repairs.retain(|&(t, g)| {
            if t <= now {
                repaired += g;
                false
            } else {
                true
            }
        });
        if repaired > 0 {
            self.revoked_out -= repaired;
            st.set_revoked(self.revoked_out as f64);
            // a repair brings its whole rack back: refresh the
            // dead-domain level alongside the revoked level, so the
            // oracle's `revoked ≥ dead-domain` invariant holds
            if let Some(ch) = &self.chaos {
                st.set_dead_domain(ch.dead_gpus(now) as f64);
            }
            self.inner.set_capacity(st, self.ceiling());
        }
        let mut due: Vec<(usize, f64)> = vec![];
        self.pending_reclaims.retain(|&(t, g, r)| {
            if t <= now {
                due.push((g, r));
                false
            } else {
                true
            }
        });
        for (gpus, repair_s) in due {
            self.revoke(st, gpus, true, repair_s);
        }
        while self.next_event < self.plan.events.len()
            && self.plan.events[self.next_event].at <= now
        {
            let ev = self.plan.events[self.next_event];
            self.next_event += 1;
            match ev.kind {
                FaultKind::GpuFailure { gpus, repair_s } => {
                    self.revoke(st, gpus, false, repair_s);
                }
                FaultKind::SpotReclaim { gpus, notice_s, repair_s } => {
                    if notice_s <= 0.0 {
                        self.revoke(st, gpus, true, repair_s);
                    } else {
                        self.pending_reclaims
                            .push((now + notice_s, gpus, repair_s));
                        // the doomed capacity is off-limits immediately
                        self.inner.set_capacity(st, self.ceiling());
                    }
                }
                FaultKind::Straggler { factor } => self.straggle(st, factor),
            }
        }
    }

    /// Whether the wrapped policy's capacity exceeds the degraded
    /// ceiling while faults are outstanding — the condition both the
    /// post-callback re-clamp and the coalescing guard key on (one
    /// definition, so the two can never silently diverge).
    fn governor_over_ceiling(&self) -> bool {
        self.started
            && (self.revoked_out > 0 || !self.pending_reclaims.is_empty())
            && self.inner.capacity().is_some_and(|c| c > self.ceiling())
    }

    /// Re-clamp the wrapped policy's capacity to the degraded ceiling.
    /// Called after every forwarded callback while faults are
    /// outstanding, so a wrapped governor (`slo::Governed`) that surged
    /// inside the callback can never leave an audited post-callback
    /// state with `billable > budget - revoked`.
    fn clamp_to_ceiling(&mut self, st: &mut ClusterState) {
        if self.governor_over_ceiling() {
            self.inner.set_capacity(st, self.ceiling());
        }
    }

    /// Revoke `gpus` GPUs now: fan the request out to its failure
    /// domains (chaos topology — one event takes whole racks down),
    /// preempt victims (ascending job id) until their allocations cover
    /// the failed count, notify the policy once with the full event, and
    /// lower the scheduling ceiling.
    fn revoke(&mut self, st: &mut ClusterState, gpus: usize, graceful: bool,
              repair_s: f64) {
        let headroom = self.base_capacity.saturating_sub(self.revoked_out);
        let want = match &mut self.chaos {
            Some(ch) => ch.fan_out(st.now(), gpus, repair_s, headroom),
            None => gpus,
        };
        let n = want.min(headroom);
        if n == 0 {
            return;
        }
        self.revoked_out += n;
        st.set_revoked(self.revoked_out as f64);
        if let Some(ch) = &self.chaos {
            st.set_dead_domain(ch.dead_gpus(st.now()) as f64);
        }
        if repair_s.is_finite() {
            self.repairs.push((st.now() + repair_s, n));
        }
        let mut ids: Vec<usize> = vec![];
        for llm in Llm::ALL {
            ids.extend_from_slice(st.active_jobs(llm));
        }
        ids.sort_unstable();
        let mut victims = vec![];
        let mut need = n;
        for id in ids {
            if need == 0 {
                break;
            }
            let held = st.jobs[id].gpus;
            let failed = held.min(need);
            st.revoke_job(id, graceful);
            victims.push(Revoked { job_id: id, held, failed });
            need -= failed;
        }
        let ev = RevokeEvent { victims, idle_gpus_lost: need, graceful };
        self.inner.on_revoke(st, &ev);
        self.inner.set_capacity(st, self.ceiling());
    }

    /// Straggler victim: the effectively-running job (Running, or past
    /// its init point) with the most remaining work, ties to the lowest
    /// id — deterministic given the cluster state.
    fn straggle(&mut self, st: &mut ClusterState, factor: f64) {
        let now = st.now();
        let mut best: Option<(f64, usize)> = None;
        for llm in Llm::ALL {
            for &id in st.active_jobs(llm) {
                let job = &st.jobs[id];
                let running = job.status == JobStatus::Running
                    || (job.status == JobStatus::Initializing
                        && job.init_until <= now);
                if !running {
                    continue;
                }
                // `iters_remaining` is advanced lazily (launch/realloc/
                // revoke), so subtract the progress made since
                // `last_progress_t` to rank by *actual* remaining work.
                let it = st.eff_iter_time(llm, job.gpus.max(1));
                let done = (now - job.last_progress_t).max(0.0) / it;
                let rem = (job.iters_remaining - done).max(0.0) * it;
                let better = match best {
                    None => true,
                    Some((b_rem, b_id)) => {
                        rem > b_rem || (rem == b_rem && id < b_id)
                    }
                };
                if better {
                    best = Some((rem, id));
                }
            }
        }
        if let Some((_, id)) = best {
            st.slow_job(id, factor);
        }
    }
}

impl<P: Policy> Policy for FaultInjector<P> {
    fn name(&self) -> &str {
        self.inner.name()
    }

    fn tick_interval(&self) -> f64 {
        self.inner.tick_interval()
    }

    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        self.ensure_started(st);
        self.inner.on_arrival(st, job_id);
        self.clamp_to_ceiling(st);
    }

    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        // Chaos completion-error draw: a failed run re-enters the queue
        // through `on_retry` and never reaches the policy's (or any
        // observer's) completion path — only the accepted completion is
        // sampled.
        if let Some(ch) = &mut self.chaos {
            if let Some(ev) = ch.try_fail(st, job_id) {
                self.inner.on_retry(st, &ev);
                self.clamp_to_ceiling(st);
                return;
            }
        }
        self.inner.on_job_complete(st, job_id);
        self.clamp_to_ceiling(st);
    }

    fn on_tick(&mut self, st: &mut ClusterState) {
        self.ensure_started(st);
        self.apply_due(st);
        self.inner.on_tick(st);
        self.clamp_to_ceiling(st);
    }

    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        self.inner.on_revoke(st, ev);
    }

    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        self.inner.on_retry(st, ev);
    }

    fn next_timed_action(&self, st: &ClusterState) -> Wake {
        // Belt-and-braces with `clamp_to_ceiling`: if a wrapped governor
        // somehow left capacity above the degraded ceiling, the next
        // round must execute so the re-clamp cannot land in a round
        // dense ticking runs but coalescing skips.
        // Starved-wake audit (batch-skip core): below this guard the
        // wrapper only merges *earlier* wakes (fault-plan events,
        // pending reclaims, repairs) on top of the inner hint via
        // `Wake::earliest`, so it can never starve an action the inner
        // policy declared.
        if self.governor_over_ceiling() {
            return Wake::Dense;
        }
        let wake = self.inner.next_timed_action(st);
        let mut next = f64::INFINITY;
        if let Some(ev) = self.plan.events.get(self.next_event) {
            next = next.min(ev.at);
        }
        for &(t, _, _) in &self.pending_reclaims {
            next = next.min(t);
        }
        for &(t, _) in &self.repairs {
            next = next.min(t);
        }
        if next.is_finite() {
            Wake::earliest(wake, Wake::At(next))
        } else {
            wake
        }
    }

    fn capacity(&self) -> Option<usize> {
        self.inner.capacity()
    }

    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        // External capacity requests may not exceed the degraded fleet.
        let clamped = if self.started { gpus.min(self.ceiling()) } else { gpus };
        self.inner.set_capacity(st, clamped);
    }

    // Gossip hooks: pure pass-throughs — the injector owns no bank, so
    // the wrapped policy's answers are authoritative.
    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        self.inner.bank_coverage(llm, task_id)
    }

    fn enable_gossip_log(&mut self) {
        self.inner.enable_gossip_log();
    }

    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        self.inner.drain_tuned(out);
    }

    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        self.inner.absorb_tuned(items);
    }

    // Knob hooks: forward the inner policy's declarations and add the
    // injector's own checkpoint period (the §self-tuning knob the fault
    // layer — not the policy — owns). The lattice spans aggressive
    // 30 s checkpoints to relaxed 4-minute ones around the 60 s default.
    fn knobs(&self) -> Vec<KnobSpec> {
        let mut out = self.inner.knobs();
        out.push(KnobSpec {
            name: "checkpoint_period_s",
            lo: 30.0,
            hi: 240.0,
            steps: 4,
        });
        out
    }

    fn knob_value(&self, name: &str) -> Option<f64> {
        if name == "checkpoint_period_s" {
            Some(self.ckpt.period_s)
        } else {
            self.inner.knob_value(name)
        }
    }

    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        if name == "checkpoint_period_s" {
            self.ckpt.period_s = value.max(1.0);
            if self.started {
                // Re-install so the amortized-slowdown model picks the
                // new period up for launches from now on.
                st.set_checkpoint_model(Some(self.ckpt.clone()));
            }
        } else {
            self.inner.set_knob(st, name, value);
            self.clamp_to_ceiling(st);
        }
    }

    fn tuner_report(&self) -> Option<TunerReport> {
        self.inner.tuner_report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::{ElasticFlow, ElasticFlowConfig, Infless,
                           InflessConfig};
    use crate::cluster::{SimConfig, SimOracle, SimResult, Simulator};
    use crate::coordinator::{PromptTuner, PromptTunerConfig};
    use crate::trace::{Load, TraceConfig, TraceGenerator};
    use crate::workload::{JobSpec, PerfModel};

    fn spec(id: usize, submit: f64, iters: f64) -> JobSpec {
        JobSpec {
            id,
            llm: Llm::Gpt2B,
            task_id: 0,
            submit_s: submit,
            duration_s: iters * 0.12,
            traced_gpus: 1,
            base_iters: iters,
            user_prompt_quality: 1.0,
            // Tight enough that DelaySchedulable cannot serialize the
            // batch onto one GPU (each job launches on its own GPU),
            // loose enough that a cold start + bank lookup still fits.
            slo_s: 100.0,
        }
    }

    fn pt(gpus: usize, seed: u64) -> PromptTuner {
        PromptTuner::new(PromptTunerConfig {
            max_gpus: gpus,
            seed,
            ..Default::default()
        })
    }

    fn medium_trace(seed: u64) -> Vec<JobSpec> {
        let mut gen = TraceGenerator::new(
            TraceConfig { seed, ..Default::default() },
            PerfModel::default(),
        );
        gen.generate_main(Load::Medium)
    }

    #[test]
    fn plan_builders_are_deterministic_and_sorted() {
        for plan in [
            FaultPlan::spot_market(7, 1800.0, 3, 8, 30.0, 180.0),
            FaultPlan::az_outage(7, 1200.0, 16, 300.0, 2),
        ] {
            assert!(!plan.is_empty());
            for w in plan.events().windows(2) {
                assert!(w[0].at <= w[1].at, "{:?}", plan.events());
            }
            for ev in plan.events() {
                assert!(ev.at >= 0.0);
            }
        }
        let a = FaultPlan::spot_market(9, 1800.0, 3, 8, 30.0, 180.0);
        let b = FaultPlan::spot_market(9, 1800.0, 3, 8, 30.0, 180.0);
        assert_eq!(a.events(), b.events());
        let c = FaultPlan::spot_market(10, 1800.0, 3, 8, 30.0, 180.0);
        assert_ne!(a.events(), c.events());
    }

    /// Eight 60 s single-GPU jobs on an 8-GPU PromptTuner cluster (all
    /// running in parallel by t = 30 s, past the ~23 s cold start + bank
    /// lookup); the plan disturbs half the fleet.
    fn run_small(plan: FaultPlan) -> (SimResult, Vec<String>, usize) {
        let jobs: Vec<JobSpec> = (0..8).map(|i| spec(i, 0.0, 500.0)).collect();
        let sim = Simulator::new(
            SimConfig { max_gpus: 8, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = SimOracle::collecting(FaultInjector::new(
            pt(8, 3),
            plan,
            CheckpointModel::default(),
        ));
        let res = sim.run(&mut policy, jobs);
        let violations = policy.violations().to_vec();
        let outstanding = policy.into_inner().outstanding_revoked();
        (res, violations, outstanding)
    }

    #[test]
    fn spot_reclaim_preempts_gracefully_and_repairs() {
        let plan = FaultPlan::new(vec![FaultEvent {
            at: 30.0,
            kind: FaultKind::SpotReclaim {
                gpus: 4,
                notice_s: 5.0,
                repair_s: 60.0,
            },
        }]);
        let (res, violations, outstanding) = run_small(plan);
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(res.n_done, 8);
        assert_eq!(res.revocations, 4, "one victim per reclaimed GPU");
        // graceful: victims checkpointed inside the notice window
        assert_eq!(res.lost_iters, 0.0);
        assert_eq!(outstanding, 0, "capacity repaired before the end");
    }

    #[test]
    fn gpu_failure_loses_work_back_to_the_checkpoint() {
        let plan = FaultPlan::new(vec![FaultEvent {
            at: 30.0,
            kind: FaultKind::GpuFailure { gpus: 4, repair_s: 60.0 },
        }]);
        let (res, violations, outstanding) = run_small(plan);
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(res.n_done, 8);
        assert_eq!(res.revocations, 4);
        assert!(res.lost_iters > 0.0, "abrupt failure must lose work");
        assert_eq!(outstanding, 0);
    }

    #[test]
    fn straggler_stretches_the_longest_running_job() {
        let plan = FaultPlan::new(vec![FaultEvent {
            at: 30.0,
            kind: FaultKind::Straggler { factor: 2.0 },
        }]);
        let (res, violations, _) = run_small(plan);
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(res.n_done, 8);
        assert_eq!(res.revocations, 0);
        assert!(res.straggler_iters > 0.0, "no straggler realized");
    }

    #[test]
    fn unrepaired_failure_keeps_capacity_revoked() {
        let plan = FaultPlan::new(vec![FaultEvent {
            at: 30.0,
            kind: FaultKind::GpuFailure { gpus: 4, repair_s: f64::INFINITY },
        }]);
        let (res, violations, outstanding) = run_small(plan);
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(res.n_done, 8, "jobs still finish on the degraded fleet");
        assert_eq!(outstanding, 4);
    }

    #[test]
    fn faulted_runs_are_bit_deterministic() {
        let run = || {
            let sim = Simulator::new(
                SimConfig { max_gpus: 32, ..Default::default() },
                PerfModel::default(),
            );
            let mut policy = FaultInjector::new(
                pt(32, 11),
                FaultPlan::az_outage(11, 1200.0, 16, 300.0, 2),
                CheckpointModel::default(),
            );
            sim.run(&mut policy, medium_trace(11))
        };
        let a = run();
        let b = run();
        assert_eq!(a.cost_usd, b.cost_usd);
        assert_eq!(a.n_violations, b.n_violations);
        assert_eq!(a.revocations, b.revocations);
        assert_eq!(a.job_latencies, b.job_latencies);
    }

    #[test]
    fn all_three_systems_recover_from_an_az_outage_under_oracle() {
        let jobs = medium_trace(13);
        let n = jobs.len();
        let plan = || FaultPlan::az_outage(13, 1200.0, 16, 300.0, 2);
        let policies: Vec<Box<dyn Policy>> = vec![
            Box::new(pt(32, 13)),
            Box::new(Infless::new(InflessConfig {
                max_gpus: 32,
                seed: 13,
                ..Default::default()
            })),
            Box::new(ElasticFlow::new(ElasticFlowConfig {
                cluster_size: 32,
                seed: 13,
                ..Default::default()
            })),
        ];
        for inner in policies {
            let name = inner.name().to_string();
            let sim = Simulator::new(
                SimConfig { max_gpus: 32, ..Default::default() },
                PerfModel::default(),
            );
            let mut policy = SimOracle::collecting(FaultInjector::new(
                inner,
                plan(),
                CheckpointModel::default(),
            ));
            let res = sim.run(&mut policy, jobs.clone());
            assert!(
                policy.violations().is_empty(),
                "{name}: {:?}",
                policy.violations().first()
            );
            assert_eq!(res.n_done, n, "{name} stranded revoked jobs");
            assert!(res.revocations > 0,
                    "{name}: the outage preempted nothing");
        }
    }

    // ------------------------------------------------------ chaos engine

    fn chaos_run(profile: ChaosProfile, plan: FaultPlan, seed: u64)
                 -> (SimResult, Vec<String>, u64) {
        let jobs = medium_trace(seed);
        let sim = Simulator::new(
            SimConfig { max_gpus: 32, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = SimOracle::collecting(FaultInjector::with_chaos(
            pt(32, seed),
            plan,
            CheckpointModel::default(),
            ChaosEngine::new(profile, seed, 32),
        ));
        let res = sim.run(&mut policy, jobs);
        let violations = policy.violations().to_vec();
        let giveups = policy.into_inner().chaos().unwrap().giveups();
        (res, violations, giveups)
    }

    #[test]
    fn latency_tails_delay_launches_without_failing_anything() {
        let (res, violations, _) =
            chaos_run(ChaosProfile::latency_tail(), FaultPlan::default(), 17);
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(res.n_done, res.n_jobs);
        assert!(res.chaos_delay_s > 0.0, "no tail ever fired");
        assert_eq!(res.retries, 0);
        assert_eq!(res.revocations, 0);
    }

    #[test]
    fn flaky_completions_retry_with_backoff_and_all_jobs_finish() {
        let (res, violations, _) =
            chaos_run(ChaosProfile::flaky(), FaultPlan::default(), 19);
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(res.n_done, res.n_jobs, "retried jobs were stranded");
        assert!(res.retries > 0, "completion errors never fired");
        assert!(res.retry_iters > 0.0);
    }

    #[test]
    fn exhausted_retry_budgets_give_up_instead_of_looping() {
        // error fraction 1: every completion draw fails, so each job
        // burns its full budget and is then accepted best-effort
        let mut p = ChaosProfile::flaky();
        p.completion_error_frac = 1.0;
        p.retry_budget = 1;
        let (res, violations, giveups) =
            chaos_run(p, FaultPlan::default(), 23);
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(res.n_done, res.n_jobs);
        assert_eq!(res.retries as usize, res.n_jobs,
                   "every job retries exactly its budget");
        assert_eq!(giveups as usize, res.n_jobs,
                   "every job then gives up once");
    }

    #[test]
    fn rack_storm_fans_failures_out_to_whole_domains() {
        let plan = FaultPlan::rolling_failures(29, 1200.0, 3, 6, 240.0);
        let (res, violations, _) =
            chaos_run(ChaosProfile::rack_storm(), plan, 29);
        assert!(violations.is_empty(), "{violations:?}");
        assert_eq!(res.n_done, res.n_jobs);
        // 32 GPUs / 4 domains: each 6-GPU wave fans to a whole 8-GPU
        // rack, so victims cover at least one rack's worth of GPUs
        assert!(res.revocations > 0, "the storm preempted nothing");
    }

    #[test]
    fn chaos_runs_are_bit_deterministic() {
        let run = || {
            chaos_run(
                ChaosProfile::rack_storm(),
                FaultPlan::rolling_failures(31, 1200.0, 3, 6, 240.0),
                31,
            )
        };
        let (a, _, ga) = run();
        let (b, _, gb) = run();
        assert_eq!(a.cost_usd, b.cost_usd);
        assert_eq!(a.job_latencies, b.job_latencies);
        assert_eq!(a.retries, b.retries);
        assert_eq!(a.chaos_delay_s.to_bits(), b.chaos_delay_s.to_bits());
        assert_eq!(ga, gb);
    }

    #[test]
    fn all_three_systems_recover_from_flaky_completions_under_oracle() {
        let jobs = medium_trace(37);
        let n = jobs.len();
        let policies: Vec<Box<dyn Policy>> = vec![
            Box::new(pt(32, 37)),
            Box::new(Infless::new(InflessConfig {
                max_gpus: 32,
                seed: 37,
                ..Default::default()
            })),
            Box::new(ElasticFlow::new(ElasticFlowConfig {
                cluster_size: 32,
                seed: 37,
                ..Default::default()
            })),
        ];
        for inner in policies {
            let name = inner.name().to_string();
            let sim = Simulator::new(
                SimConfig { max_gpus: 32, ..Default::default() },
                PerfModel::default(),
            );
            let mut policy = SimOracle::collecting(FaultInjector::with_chaos(
                inner,
                FaultPlan::default(),
                CheckpointModel::default(),
                ChaosEngine::new(ChaosProfile::flaky(), 37, 32),
            ));
            let res = sim.run(&mut policy, jobs.clone());
            assert!(
                policy.violations().is_empty(),
                "{name}: {:?}",
                policy.violations().first()
            );
            assert_eq!(res.n_done, n, "{name} stranded retried jobs");
            assert!(res.retries > 0, "{name}: no completion error fired");
        }
    }
}
