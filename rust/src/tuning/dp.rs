//! Data-parallel prompt tuning with synchronous gradient exchange — the
//! real counterpart of the paper's multi-GPU execution (§5.1, which uses
//! Memcached between Knative function instances; here the storage channel
//! is in-process and the "instances" are per-replica `grad_prompt` calls).
//!
//! Each replica computes the prompt gradient of its own micro-batch; the
//! coordinator all-reduces (averages) the gradients and applies Adam
//! host-side. With one replica this reproduces `tune_step` exactly (the
//! equivalence is asserted in rust/tests/runtime_integration.rs).

use anyhow::Result;

use crate::runtime::ModelRuntime;

/// Adam hyperparameters — must match python/compile/model.py.
pub const ADAM_B1: f32 = 0.9;
pub const ADAM_B2: f32 = 0.999;
pub const ADAM_EPS: f32 = 1e-8;

/// Host-side Adam state for data-parallel tuning.
#[derive(Clone, Debug)]
pub struct DpState {
    pub prompt: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub step: f32,
}

impl DpState {
    pub fn new(prompt: Vec<f32>) -> Self {
        let n = prompt.len();
        DpState { prompt, m: vec![0.0; n], v: vec![0.0; n], step: 0.0 }
    }
}

/// One synchronous data-parallel step: every `(toks, tgts)` micro-batch is
/// evaluated by `grad_prompt` (conceptually on its own GPU), gradients are
/// averaged, Adam applied. Returns the mean micro-batch loss.
pub fn dp_tune_step(
    rt: &ModelRuntime,
    state: &mut DpState,
    micro_batches: &[(Vec<i32>, Vec<i32>)],
    lr: f32,
) -> Result<f32> {
    assert!(!micro_batches.is_empty());
    let n = state.prompt.len();
    let mut grad_sum = vec![0.0f32; n];
    let mut loss_sum = 0.0f32;
    // --- scatter/compute: one grad_prompt per replica ---
    for (toks, tgts) in micro_batches {
        let (g, loss) = rt.grad_prompt(&state.prompt, toks, tgts)?;
        for i in 0..n {
            grad_sum[i] += g[i];
        }
        loss_sum += loss;
    }
    // --- all-reduce: average ---
    let k = micro_batches.len() as f32;
    for g in grad_sum.iter_mut() {
        *g /= k;
    }
    // --- Adam (identical to the fused tune_step artifact) ---
    state.step += 1.0;
    let bc1 = 1.0 - ADAM_B1.powf(state.step);
    let bc2 = 1.0 - ADAM_B2.powf(state.step);
    for i in 0..n {
        let g = grad_sum[i];
        state.m[i] = ADAM_B1 * state.m[i] + (1.0 - ADAM_B1) * g;
        state.v[i] = ADAM_B2 * state.v[i] + (1.0 - ADAM_B2) * g * g;
        let mhat = state.m[i] / bc1;
        let vhat = state.v[i] / bc2;
        state.prompt[i] -= lr * mhat / (vhat.sqrt() + ADAM_EPS);
    }
    Ok(loss_sum / k)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adam_math_matches_reference() {
        // hand-checked single Adam step on a 2-vector with known gradient
        let mut st = DpState::new(vec![1.0, -1.0]);
        st.step = 0.0;
        // fake a gradient application by inlining the update with g known
        let g = [0.5f32, -0.25];
        st.step += 1.0;
        let bc1 = 1.0 - ADAM_B1.powf(1.0);
        let bc2 = 1.0 - ADAM_B2.powf(1.0);
        for i in 0..2 {
            st.m[i] = ADAM_B1 * st.m[i] + (1.0 - ADAM_B1) * g[i];
            st.v[i] = ADAM_B2 * st.v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
            let mhat = st.m[i] / bc1;
            let vhat = st.v[i] / bc2;
            st.prompt[i] -= 0.1 * mhat / (vhat.sqrt() + ADAM_EPS);
        }
        // first Adam step moves by ~lr * sign(g)
        assert!((st.prompt[0] - (1.0 - 0.1)).abs() < 1e-3, "{}", st.prompt[0]);
        assert!((st.prompt[1] - (-1.0 + 0.1)).abs() < 1e-3, "{}", st.prompt[1]);
    }

    #[test]
    fn dp_state_init() {
        let st = DpState::new(vec![0.5; 6]);
        assert_eq!(st.m, vec![0.0; 6]);
        assert_eq!(st.v, vec![0.0; 6]);
        assert_eq!(st.step, 0.0);
    }
}
