//! The prompt-tuning trainer: drives `ModelRuntime::tune_step` over fresh
//! task batches until the termination condition (target eval loss or max
//! iterations) — the real counterpart of the simulator's ITA model.

use anyhow::Result;

use crate::runtime::{ModelRuntime, TuneState};
use crate::tuning::data::TaskUniverse;
use crate::util::rng::Rng;

/// Trainer parameters (the job's Hyperparam attributes, Table 3).
#[derive(Clone, Debug)]
pub struct TrainerConfig {
    pub lr: f32,
    pub max_iters: usize,
    /// Evaluate every `eval_every` steps (ITA is counted in iterations,
    /// evaluation cadence only bounds the detection delay).
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for TrainerConfig {
    fn default() -> Self {
        TrainerConfig { lr: 0.05, max_iters: 400, eval_every: 10, seed: 1 }
    }
}

/// Result of one tuning run.
#[derive(Clone, Debug)]
pub struct TuneOutcome {
    /// Iterations until the target was reached (== ITA), or max_iters.
    pub iters: usize,
    pub reached_target: bool,
    pub final_eval_loss: f32,
    /// (iteration, train loss) samples.
    pub loss_curve: Vec<(usize, f32)>,
    /// Final tuned prompt ([P*D]).
    pub prompt: Vec<f32>,
}

/// Runs LPT jobs against a loaded model runtime.
pub struct Trainer<'a> {
    pub rt: &'a ModelRuntime,
    pub uni: &'a TaskUniverse,
    pub cfg: TrainerConfig,
}

impl<'a> Trainer<'a> {
    pub fn new(rt: &'a ModelRuntime, uni: &'a TaskUniverse, cfg: TrainerConfig) -> Self {
        Trainer { rt, uni, cfg }
    }

    /// A held-out eval batch for the task (fixed per seed — the job's
    /// evaluation dataset).
    pub fn eval_batch(&self, task: usize) -> (Vec<i32>, Vec<i32>) {
        let mut rng = Rng::new(self.cfg.seed ^ 0xEEA1_BA7C ^ task as u64);
        self.uni
            .sample_batch(&mut rng, task, self.rt.info.batch_eval, self.rt.info.seq)
    }

    /// Eval loss of a *discrete* candidate prompt on the task's eval batch
    /// (Eqn. 1 — used by the Prompt Bank and the ideal/induction baselines).
    pub fn score_tokens(&self, task: usize, ptoks: &[i32]) -> Result<f32> {
        let (etoks, etgts) = self.eval_batch(task);
        self.rt.score(ptoks, &etoks, &etgts)
    }

    /// Tune starting from the prompt embedded from `init_tokens`, until
    /// eval loss <= `target_loss` or max_iters. Returns the ITA outcome.
    pub fn tune(&self, task: usize, init_tokens: &[i32], target_loss: f32)
                -> Result<TuneOutcome> {
        let prompt0 = self.rt.embed_prompt(init_tokens)?;
        self.tune_from(task, prompt0, target_loss)
    }

    /// The job's target loss, derived the way §6.1 sets target accuracy:
    /// the loss *achieved after tuning* from a reference prompt for a
    /// fixed budget, plus a small margin — so that ITA measures how fast
    /// a candidate initial prompt reaches a realistic tuned quality.
    pub fn reference_target(&self, task: usize, ref_tokens: &[i32],
                            budget_iters: usize, margin: f32) -> Result<f32> {
        let saved = self.cfg.max_iters;
        let trainer = Trainer {
            rt: self.rt,
            uni: self.uni,
            cfg: TrainerConfig { max_iters: budget_iters, ..self.cfg.clone() },
        };
        let _ = saved;
        let out = trainer.tune(task, ref_tokens, f32::NEG_INFINITY)?;
        Ok(out.final_eval_loss + margin)
    }

    /// Tune from an explicit continuous prompt.
    pub fn tune_from(&self, task: usize, prompt0: Vec<f32>, target_loss: f32)
                     -> Result<TuneOutcome> {
        let mut rng = Rng::new(self.cfg.seed ^ task as u64);
        let mut state = TuneState::new(prompt0);
        let (etoks, etgts) = self.eval_batch(task);
        let mut curve = vec![];
        let mut final_eval = self.rt.eval_loss(&state.prompt, &etoks, &etgts)?;
        if final_eval <= target_loss {
            return Ok(TuneOutcome {
                iters: 0,
                reached_target: true,
                final_eval_loss: final_eval,
                loss_curve: curve,
                prompt: state.prompt,
            });
        }
        for it in 1..=self.cfg.max_iters {
            let (toks, tgts) = self.uni.sample_batch(
                &mut rng, task, self.rt.info.batch_train, self.rt.info.seq);
            let loss = self.rt.tune_step(&mut state, &toks, &tgts, self.cfg.lr)?;
            curve.push((it, loss));
            if it % self.cfg.eval_every == 0 || it == self.cfg.max_iters {
                final_eval = self.rt.eval_loss(&state.prompt, &etoks, &etgts)?;
                if final_eval <= target_loss {
                    return Ok(TuneOutcome {
                        iters: it,
                        reached_target: true,
                        final_eval_loss: final_eval,
                        loss_curve: curve,
                        prompt: state.prompt,
                    });
                }
            }
        }
        Ok(TuneOutcome {
            iters: self.cfg.max_iters,
            reached_target: false,
            final_eval_loss: final_eval,
            loss_curve: curve,
            prompt: state.prompt,
        })
    }
}
