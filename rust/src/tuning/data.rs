//! The synthetic task universe, read from `artifacts/tasks.bin` — the
//! Rust mirror of `python/compile/tasks.py` (same distributions, same
//! binary layout, same ALPHA; the Python side *writes* the file, this
//! side samples workloads from it at run time).

use std::path::Path;

use anyhow::{bail, Result};

use crate::util::binio::{read_all, LeReader};
use crate::util::rng::Rng;

/// Task-shift strength — must match python/compile/tasks.py::ALPHA.
pub const ALPHA: f32 = 2.0;

const MAGIC: u32 = 0x50544E4B; // "PTNK"
const VERSION: u32 = 1;

/// Shared base language + per-task shift vectors + discrete tags.
#[derive(Clone, Debug)]
pub struct TaskUniverse {
    pub seed: u32,
    pub vocab: usize,
    pub n_tasks: usize,
    pub n_archetypes: usize,
    pub tag_len: usize,
    /// [vocab * vocab] row-major base bigram logits.
    pub base_logits: Vec<f32>,
    /// [n_tasks * vocab] task shift vectors.
    pub tvec: Vec<f32>,
    /// [n_tasks] archetype of each task.
    pub arch_id: Vec<i32>,
    /// [n_tasks * tag_len] instruction tags.
    pub tags: Vec<i32>,
}

impl TaskUniverse {
    /// Load `tasks.bin` (layout documented in tasks.py::write_bin).
    pub fn load(path: impl AsRef<Path>) -> Result<TaskUniverse> {
        let bytes = read_all(path)?;
        let mut r = LeReader::new(&bytes);
        let magic = r.u32()?;
        let version = r.u32()?;
        if magic != MAGIC || version != VERSION {
            bail!("bad tasks.bin header: magic={magic:#x} version={version}");
        }
        let seed = r.u32()?;
        let vocab = r.u32()? as usize;
        let n_tasks = r.u32()? as usize;
        let n_archetypes = r.u32()? as usize;
        let tag_len = r.u32()? as usize;
        let uni = TaskUniverse {
            seed,
            vocab,
            n_tasks,
            n_archetypes,
            tag_len,
            base_logits: r.f32_vec(vocab * vocab)?,
            tvec: r.f32_vec(n_tasks * vocab)?,
            arch_id: r.i32_vec(n_tasks)?,
            tags: r.i32_vec(n_tasks * tag_len)?,
        };
        if r.remaining() != 0 {
            bail!("tasks.bin has {} trailing bytes", r.remaining());
        }
        Ok(uni)
    }

    /// Build a small synthetic universe in-process (tests/benches that
    /// must not depend on artifacts).
    pub fn synthetic(seed: u64, vocab: usize, n_tasks: usize,
                     n_archetypes: usize, tag_len: usize) -> TaskUniverse {
        let mut rng = Rng::new(seed);
        let base_logits: Vec<f32> =
            (0..vocab * vocab).map(|_| rng.normal() as f32).collect();
        let arch: Vec<Vec<f32>> = (0..n_archetypes)
            .map(|_| (0..vocab).map(|_| rng.normal() as f32).collect())
            .collect();
        let arch_id: Vec<i32> =
            (0..n_tasks).map(|_| rng.below(n_archetypes) as i32).collect();
        let mut tvec = Vec::with_capacity(n_tasks * vocab);
        for &a in &arch_id {
            for j in 0..vocab {
                tvec.push(arch[a as usize][j] + 0.35 * rng.normal() as f32);
            }
        }
        let sig: Vec<Vec<i32>> = (0..n_archetypes)
            .map(|_| (0..tag_len).map(|_| rng.below(vocab) as i32).collect())
            .collect();
        let mut tags = Vec::with_capacity(n_tasks * tag_len);
        for &a in &arch_id {
            for p in 0..tag_len {
                if rng.f64() < 0.7 {
                    tags.push(sig[a as usize][p]);
                } else {
                    tags.push(rng.below(vocab) as i32);
                }
            }
        }
        TaskUniverse {
            seed: seed as u32,
            vocab,
            n_tasks,
            n_archetypes,
            tag_len,
            base_logits,
            tvec,
            arch_id,
            tags,
        }
    }

    /// The instruction tag of one task.
    pub fn tag(&self, task: usize) -> &[i32] {
        &self.tags[task * self.tag_len..(task + 1) * self.tag_len]
    }

    /// Task shift vector.
    pub fn task_vec(&self, task: usize) -> &[f32] {
        &self.tvec[task * self.vocab..(task + 1) * self.vocab]
    }

    /// Sample one Markov sequence of `len` tokens for `task`.
    pub fn sample_sequence(&self, rng: &mut Rng, task: usize, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len);
        let mut cur = rng.below(self.vocab);
        out.push(cur as i32);
        let tv = self.task_vec(task);
        let mut logits = vec![0.0f32; self.vocab];
        for _ in 1..len {
            let row = &self.base_logits[cur * self.vocab..(cur + 1) * self.vocab];
            for j in 0..self.vocab {
                logits[j] = row[j] + ALPHA * tv[j];
            }
            cur = rng.from_logits(&logits);
            out.push(cur as i32);
        }
        out
    }

    /// Sample a training batch: `(tokens, targets)` each `batch × seq`
    /// row-major, targets shifted by one.
    pub fn sample_batch(&self, rng: &mut Rng, task: usize, batch: usize,
                        seq: usize) -> (Vec<i32>, Vec<i32>) {
        let mut toks = Vec::with_capacity(batch * seq);
        let mut tgts = Vec::with_capacity(batch * seq);
        for _ in 0..batch {
            let s = self.sample_sequence(rng, task, seq + 1);
            toks.extend_from_slice(&s[..seq]);
            tgts.extend_from_slice(&s[1..]);
        }
        (toks, tgts)
    }

    /// A noisy variant of a task's tag (extra prompt-bank candidates).
    pub fn noisy_tag(&self, rng: &mut Rng, task: usize, flip_prob: f64) -> Vec<i32> {
        self.tag(task)
            .iter()
            .map(|&t| {
                if rng.f64() < flip_prob {
                    rng.below(self.vocab) as i32
                } else {
                    t
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn uni() -> TaskUniverse {
        TaskUniverse::synthetic(5, 32, 8, 3, 6)
    }

    #[test]
    fn synthetic_shapes() {
        let u = uni();
        assert_eq!(u.base_logits.len(), 32 * 32);
        assert_eq!(u.tvec.len(), 8 * 32);
        assert_eq!(u.tags.len(), 8 * 6);
        assert_eq!(u.tag(3).len(), 6);
        assert_eq!(u.task_vec(7).len(), 32);
    }

    #[test]
    fn sequences_in_vocab_and_deterministic() {
        let u = uni();
        let mut r1 = Rng::new(1);
        let mut r2 = Rng::new(1);
        let a = u.sample_sequence(&mut r1, 0, 50);
        let b = u.sample_sequence(&mut r2, 0, 50);
        assert_eq!(a, b);
        assert!(a.iter().all(|&t| t >= 0 && (t as usize) < u.vocab));
    }

    #[test]
    fn batch_targets_are_shifted() {
        let u = uni();
        let mut rng = Rng::new(2);
        let (toks, tgts) = u.sample_batch(&mut rng, 1, 3, 10);
        assert_eq!(toks.len(), 30);
        assert_eq!(tgts.len(), 30);
        // within each row, tgts[i] == toks[i+1]
        for row in 0..3 {
            for i in 0..9 {
                assert_eq!(tgts[row * 10 + i], toks[row * 10 + i + 1]);
            }
        }
    }

    #[test]
    fn tasks_have_distinct_marginals() {
        let u = uni();
        let mut rng = Rng::new(3);
        let count = |task: usize, rng: &mut Rng| {
            let mut c = vec![0usize; u.vocab];
            for _ in 0..50 {
                for t in u.sample_sequence(rng, task, 30) {
                    c[t as usize] += 1;
                }
            }
            c
        };
        let a = count(0, &mut rng);
        let b = count(4, &mut rng);
        let total: usize = a.iter().sum();
        let l1: f64 = a
            .iter()
            .zip(&b)
            .map(|(&x, &y)| ((x as f64) - (y as f64)).abs())
            .sum::<f64>()
            / total as f64;
        assert!(l1 > 0.1, "tasks indistinguishable: {l1}");
    }

    #[test]
    fn same_archetype_tags_agree_more() {
        let u = TaskUniverse::synthetic(7, 64, 24, 3, 12);
        let mut same = vec![];
        let mut cross = vec![];
        for i in 0..u.n_tasks {
            for j in i + 1..u.n_tasks {
                let agree = u
                    .tag(i)
                    .iter()
                    .zip(u.tag(j))
                    .filter(|(a, b)| a == b)
                    .count() as f64
                    / u.tag_len as f64;
                if u.arch_id[i] == u.arch_id[j] {
                    same.push(agree);
                } else {
                    cross.push(agree);
                }
            }
        }
        let m = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        assert!(m(&same) > m(&cross) + 0.1,
                "same {} cross {}", m(&same), m(&cross));
    }

    #[test]
    fn noisy_tag_flips_some() {
        let u = uni();
        let mut rng = Rng::new(4);
        let noisy = u.noisy_tag(&mut rng, 0, 0.5);
        assert_eq!(noisy.len(), u.tag_len);
        let same = noisy.iter().zip(u.tag(0)).filter(|(a, b)| a == b).count();
        assert!(same < u.tag_len); // at least one flip at p=0.5, len 6
    }

    #[test]
    fn loads_real_tasks_bin_if_present() {
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/tasks.bin");
        if !path.exists() {
            eprintln!("skipping: {} missing (run `make artifacts`)", path.display());
            return;
        }
        let u = TaskUniverse::load(path).unwrap();
        assert_eq!(u.vocab, 256);
        assert_eq!(u.n_tasks, 64);
        assert_eq!(u.tag_len, 16);
        assert!(u.tags.iter().all(|&t| t >= 0 && (t as usize) < u.vocab));
    }
}
