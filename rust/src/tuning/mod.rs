//! Real LPT execution: the synthetic task universe (shared with the
//! Python build via `tasks.bin`), batch sampling, the prompt-tuning
//! trainer that drives the PJRT runtime to a target loss (ITA), and the
//! data-parallel executor with synchronous gradient exchange.

pub mod data;
pub mod dp;
pub mod trainer;

pub use data::TaskUniverse;
pub use dp::{dp_tune_step, DpState};
pub use trainer::{TuneOutcome, Trainer, TrainerConfig};
