//! Per-job simulation state: lifecycle, progress accounting, and the
//! latency components the paper's figures break down (queueing, Prompt
//! Bank, initialization, execution).

use crate::workload::JobSpec;

/// Lifecycle of a simulated LPT job.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum JobStatus {
    /// Submitted, not yet allocated GPUs.
    Pending,
    /// GPUs held, paying allocation/initialization overhead (no progress).
    Initializing,
    /// Iterating.
    Running,
    /// Finished (reached its termination condition).
    Done,
}

/// Simulation state of one job.
#[derive(Clone, Debug)]
pub struct JobState {
    pub spec: JobSpec,
    pub status: JobStatus,
    /// Initial-prompt quality actually used (bank may improve the user's).
    pub quality: f64,
    /// Iterations still to run (set at launch from quality).
    pub iters_remaining: f64,
    /// Current GPU allocation (0 while pending).
    pub gpus: usize,
    /// Time initialization finishes and progress starts.
    pub init_until: f64,
    /// Last time `iters_remaining` was brought up to date.
    pub last_progress_t: f64,
    /// Completion-event generation (stale events are ignored).
    pub gen: u64,
    /// Time the job started holding GPUs (for breakdown metrics).
    pub launched_at: f64,
    /// Completion timestamp (valid when status == Done).
    pub completed_at: f64,
    /// Seconds spent on Prompt Bank lookup (part of the latency budget).
    pub bank_latency: f64,
    /// Seconds of initialization the job paid (Fig 3b numerator).
    pub init_wait: f64,
    /// GPU-seconds consumed by this job (including initialization hold).
    /// Set at completion from the *final* run segment (preempted segments
    /// are accounted in the cluster-level busy integral).
    pub gpu_seconds: f64,
    /// Time the current run segment started (or will start) making
    /// progress: `init_until` at launch/delayed realloc, the realloc
    /// instant otherwise. Checkpoints are periodic from this origin.
    pub seg_start_t: f64,
    /// Involuntary revocations this job suffered (fault engine).
    pub restarts: u32,
    /// The next launch must restore from the last checkpoint (pays the
    /// restore overhead; keeps realized quality + remaining iterations).
    pub needs_restore: bool,
    /// Iterations lost to restore-from-last-checkpoint across all
    /// revocations (conserved against `ClusterState` totals by the
    /// oracle).
    pub lost_iters: f64,
    /// Extra iterations added by straggler slowdowns.
    pub straggler_iters: f64,
    /// Failed completions this job suffered (chaos engine, conserved
    /// against `ClusterState` totals by the oracle).
    pub retries: u32,
    /// Iterations re-queued by those failed completions.
    pub retry_iters: f64,
    /// Last retry backoff applied (seconds). The oracle audits that it
    /// never shrinks — exponential backoff is monotone per job.
    pub retry_backoff_s: f64,
    /// Absolute time the last retry's backoff expires (`now + backoff`
    /// at the failed completion). While a job is `Pending` with this in
    /// the future, it is held back by backoff rather than capacity —
    /// the anchor of the starved-wake audit (`StateAudit::check_wake`):
    /// no policy may declare a wake that sleeps past it.
    pub retry_not_before: f64,
}

impl JobState {
    pub fn new(spec: JobSpec) -> Self {
        let quality = spec.user_prompt_quality;
        JobState {
            spec,
            status: JobStatus::Pending,
            quality,
            iters_remaining: 0.0,
            gpus: 0,
            init_until: 0.0,
            last_progress_t: 0.0,
            gen: 0,
            launched_at: 0.0,
            completed_at: f64::INFINITY,
            bank_latency: 0.0,
            init_wait: 0.0,
            gpu_seconds: 0.0,
            seg_start_t: 0.0,
            restarts: 0,
            needs_restore: false,
            lost_iters: 0.0,
            straggler_iters: 0.0,
            retries: 0,
            retry_iters: 0.0,
            retry_backoff_s: 0.0,
            retry_not_before: 0.0,
        }
    }

    /// Whether the job met its SLO (only meaningful once Done; an
    /// unfinished job at experiment end counts as a violation).
    pub fn met_slo(&self) -> bool {
        self.status == JobStatus::Done && self.completed_at <= self.spec.deadline()
    }

    /// End-to-end latency (submission to completion).
    pub fn latency(&self) -> f64 {
        self.completed_at - self.spec.submit_s
    }

    /// Bring `iters_remaining` up to date at time `now` (while Running).
    pub fn advance_progress(&mut self, now: f64, iter_time: f64) {
        if self.status == JobStatus::Running && now > self.last_progress_t {
            let done = (now - self.last_progress_t) / iter_time;
            self.iters_remaining = (self.iters_remaining - done).max(0.0);
            self.last_progress_t = now;
        } else if self.status == JobStatus::Initializing && now >= self.init_until {
            self.status = JobStatus::Running;
            self.last_progress_t = self.init_until;
            if now > self.init_until {
                self.advance_progress(now, iter_time);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Llm;

    fn spec() -> JobSpec {
        JobSpec {
            id: 0,
            llm: Llm::Gpt2B,
            task_id: 0,
            submit_s: 0.0,
            duration_s: 10.0,
            traced_gpus: 1,
            base_iters: 100.0,
            user_prompt_quality: 0.5,
            slo_s: 30.0,
        }
    }

    #[test]
    fn new_job_is_pending_with_user_quality() {
        let j = JobState::new(spec());
        assert_eq!(j.status, JobStatus::Pending);
        assert_eq!(j.quality, 0.5);
        assert!(!j.met_slo());
    }

    #[test]
    fn progress_advances_only_while_running() {
        let mut j = JobState::new(spec());
        j.status = JobStatus::Running;
        j.iters_remaining = 10.0;
        j.last_progress_t = 0.0;
        j.advance_progress(5.0, 1.0);
        assert!((j.iters_remaining - 5.0).abs() < 1e-9);
        j.advance_progress(20.0, 1.0);
        assert_eq!(j.iters_remaining, 0.0); // clamped at zero
    }

    #[test]
    fn init_transitions_to_running_and_progresses() {
        let mut j = JobState::new(spec());
        j.status = JobStatus::Initializing;
        j.init_until = 4.0;
        j.iters_remaining = 10.0;
        j.advance_progress(6.0, 1.0);
        assert_eq!(j.status, JobStatus::Running);
        assert!((j.iters_remaining - 8.0).abs() < 1e-9);
        assert_eq!(j.last_progress_t, 6.0);
    }

    #[test]
    fn init_not_elapsed_means_no_progress() {
        let mut j = JobState::new(spec());
        j.status = JobStatus::Initializing;
        j.init_until = 4.0;
        j.iters_remaining = 10.0;
        j.advance_progress(2.0, 1.0);
        assert_eq!(j.status, JobStatus::Initializing);
        assert_eq!(j.iters_remaining, 10.0);
    }

    #[test]
    fn met_slo_requires_done_before_deadline() {
        let mut j = JobState::new(spec());
        j.status = JobStatus::Done;
        j.completed_at = 29.0;
        assert!(j.met_slo());
        j.completed_at = 31.0;
        assert!(!j.met_slo());
        assert!((j.latency() - 31.0).abs() < 1e-12);
    }
}
