//! The discrete-event simulator core: event queue, cluster accounting,
//! the [`Policy`] trait that schedulers implement, and the run loop.
//!
//! Time is f64 seconds. Events are totally ordered by (time, sequence).
//! GPU *cost* is integrated from a `billable_gpus` level that the policy
//! maintains (warm-pool GPUs for PromptTuner, the whole fixed cluster for
//! ElasticFlow, live instances for INFless); GPU *usage* (busy) is
//! integrated automatically from job allocations.
//!
//! # Tick coalescing: O(events) batch skipping
//!
//! The paper's 50 ms scheduling round means a simulated experiment
//! executes hundreds of thousands of rounds, the vast majority of which
//! are no-ops (empty queues, nothing to expire). Policies can report
//! their next *time-driven* action through
//! [`Policy::next_timed_action`]; when the hint is [`Wake::At`] or
//! [`Wake::Idle`], the run loop *batch-skips* the tick stream: it
//! advances `tick_time` round by round — without integrating, querying
//! the policy, or touching the heap — until the first round at or past
//! the wake target or the next heap event, then resumes there with a
//! single `integrate_to`. Per-skipped-round work is three scalar ops,
//! so simulated cost is O(events + executed rounds), independent of how
//! much idle grid a trace spans. Bit-identity with dense ticking holds
//! because:
//!
//! * cost/utilization integration is *segment-based*: the GPU-second
//!   integrals accumulate in [`ClusterState::commit_levels`], invoked
//!   only when a level actually changes (launch / realloc / revoke /
//!   completion / `set_billable`) — and levels change only inside
//!   callbacks, which fire at identical times in dense and batch-skip
//!   runs, so both accumulate the exact same `level × dt` sequence;
//! * utilization samples flushed late (at the resume point) read levels
//!   that provably did not change during the skipped span, and the
//!   sample clock advances by the same repeated addition either way;
//! * `tick_time` advances by repeated addition of the period — the same
//!   float path dense ticking takes — and each skipped round consumes
//!   the event sequence number its next-tick push would have taken, so
//!   equal-time ordering between ticks and job events is unchanged;
//! * the default hint is [`Wake::Dense`] (tick every round), so policies
//!   that don't opt in behave exactly as before.
//!
//! The contract this puts on `next_timed_action` is load-bearing: a
//! policy that sleeps past a round where it would have acted diverges
//! from its dense reference (a *lost wakeup*). [`StateAudit::check_wake`]
//! patrols the state-observable class of that bug (pending retries held
//! back past a declared wake), and [`SimOracle`] applies it to every
//! wake hint the wrapped policy emits.

use std::cmp::Ordering;
use std::collections::BinaryHeap;
use std::time::Instant;

use crate::cluster::job::{JobState, JobStatus};
use crate::trace::source::TraceSource;
use crate::util::rng::Rng;
use crate::util::stats::Accum;
use crate::workload::{JobSpec, Llm, PerfModel, COMM_PAYLOAD_GB, GPU_PRICE_PER_S,
                      N_LLM, STORAGE_PRICE_PER_GB_H};

/// Simulator parameters.
#[derive(Clone, Debug)]
pub struct SimConfig {
    /// Total GPUs available to the provider (cold pool size ceiling).
    pub max_gpus: usize,
    /// Hard horizon after the last arrival (stragglers beyond it stay
    /// unfinished and count as SLO violations).
    pub horizon_s: f64,
    /// Sampling period of the utilization timeline (Fig 3a series).
    pub util_sample_s: f64,
    /// Debug flag: audit the full [`StateAudit`] invariant set after
    /// every executed round and event, panicking on the first violation.
    /// Defaults to off; set the `PT_SIM_ORACLE` environment variable to a
    /// non-empty value other than `0`/`false` to enable globally. Tests
    /// wrap policies in [`SimOracle`] instead.
    pub debug_oracle: bool,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            max_gpus: 32,
            horizon_s: 7200.0,
            util_sample_s: 10.0,
            debug_oracle: std::env::var("PT_SIM_ORACLE").is_ok_and(|v| {
                !v.is_empty() && v != "0" && !v.eq_ignore_ascii_case("false")
            }),
        }
    }
}

/// Checkpoint/restore cost model for faulted runs (installed by the
/// fault engine, `fault::FaultInjector`). While armed, jobs pay a
/// periodic checkpoint overhead as a uniform slowdown of effective
/// iteration time (`1 + overhead_s / period_s`), an involuntary
/// revocation loses the work done since the last periodic checkpoint
/// (graceful revocations — spot reclaims inside their notice window —
/// checkpoint on the way out and lose none), and the next launch of a
/// revoked job pays `restore_s` of restore-from-checkpoint overhead on
/// top of the policy's own allocation delay. `None` (the default) keeps
/// every computation bit-identical to the fault-free simulator.
#[derive(Clone, Debug, PartialEq)]
pub struct CheckpointModel {
    /// Seconds between periodic checkpoints.
    pub period_s: f64,
    /// Seconds of overhead per checkpoint (amortized as a slowdown).
    pub overhead_s: f64,
    /// Seconds to restore a revoked job from its last checkpoint.
    pub restore_s: f64,
}

impl Default for CheckpointModel {
    fn default() -> Self {
        // Defaults sized for LPT jobs: a prompt-state checkpoint is small
        // (soft prompt + optimizer state), so checkpointing each minute
        // costs ~2.5 % throughput and a restore reloads in ~12 s.
        CheckpointModel { period_s: 60.0, overhead_s: 1.5, restore_s: 12.0 }
    }
}

impl CheckpointModel {
    /// Effective iteration-time multiplier from the amortized periodic
    /// checkpoint overhead.
    pub fn slowdown(&self) -> f64 {
        if self.period_s.is_finite() && self.period_s > 0.0 {
            1.0 + self.overhead_s / self.period_s
        } else {
            1.0
        }
    }
}

/// Chaos latency-injection model (installed by the chaos engine,
/// `fault::ChaosEngine`). While armed, a deterministic hash-derived
/// fraction of launches pays a stretched initialization delay and a
/// stretched Prompt-Bank lookup — the latency tails real fleets see on
/// cold container starts and overloaded bank replicas. Draws are keyed
/// on `(salt, stream, job, generation)` and computed at the launch call
/// itself — no RNG state persists between rounds, so coalesced and
/// dense ticking make exactly the same draws and runs stay bit-identical.
/// `None` (the default) keeps every computation bit-identical to the
/// chaos-free simulator.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosInjection {
    /// Hash salt (derived from the run seed by the chaos engine).
    pub salt: u64,
    /// Fraction of launches whose initialization delay is stretched.
    pub launch_tail_frac: f64,
    /// Maximum initialization-delay multiplier (tail position is a
    /// second hash draw in `[1, factor]`).
    pub launch_tail_factor: f64,
    /// Fraction of Prompt-Bank lookups whose latency is stretched.
    pub lookup_tail_frac: f64,
    /// Maximum bank-lookup latency multiplier.
    pub lookup_tail_factor: f64,
}

impl ChaosInjection {
    /// One uniform draw in `[0, 1)` from the keyed hash stream. A fresh
    /// generator per call keeps the model stateless (lookup-order
    /// independent), the same discipline `promptbank::task_feature` uses.
    fn u01(&self, stream: u64, job_id: usize, gen: u64) -> f64 {
        Rng::new(
            self.salt
                ^ stream
                ^ (job_id as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15)
                ^ (gen + 1).wrapping_mul(0xC2B2_AE3D_27D4_EB4F),
        )
        .f64()
    }

    fn stretch(&self, frac: f64, factor: f64, gate: u64, pos: u64,
               job_id: usize, gen: u64) -> f64 {
        if self.u01(gate, job_id, gen) < frac {
            1.0 + self.u01(pos, job_id, gen) * (factor - 1.0).max(0.0)
        } else {
            1.0
        }
    }

    /// Initialization-delay multiplier for this (job, generation) launch.
    pub fn launch_stretch(&self, job_id: usize, gen: u64) -> f64 {
        self.stretch(self.launch_tail_frac, self.launch_tail_factor,
                     0x11, 0x12, job_id, gen)
    }

    /// Prompt-Bank lookup-latency multiplier for this launch.
    pub fn lookup_stretch(&self, job_id: usize, gen: u64) -> f64 {
        self.stretch(self.lookup_tail_frac, self.lookup_tail_factor,
                     0x21, 0x22, job_id, gen)
    }
}

/// One preempted job inside a [`RevokeEvent`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Revoked {
    pub job_id: usize,
    /// GPUs the job held when preempted (all returned to Pending).
    pub held: usize,
    /// How many of those GPUs actually failed / were reclaimed — they
    /// leave the policy's footprint entirely; the `held - failed`
    /// survivors go back to its pools.
    pub failed: usize,
}

/// An involuntary revocation delivered to [`Policy::on_revoke`]. The
/// fault engine has already preempted the victims back to `Pending`
/// (`ClusterState::revoke_job`) and lowered the provider budget; the
/// policy must reconcile its own bookkeeping: requeue the victims, drop
/// each victim's `failed` GPUs from any pools (returning the survivors),
/// and shed up to `idle_gpus_lost` idle/pre-warming instances.
#[derive(Clone, Debug)]
pub struct RevokeEvent {
    pub victims: Vec<Revoked>,
    /// Failed GPUs not covered by victim allocations — they hit the
    /// policy's idle footprint (warm pools, pre-warming instances).
    pub idle_gpus_lost: usize,
    /// Graceful revocations (spot reclaims with notice) checkpoint on
    /// the way out; abrupt ones lose work back to the last checkpoint.
    pub graceful: bool,
}

/// A failed tuning run delivered to [`Policy::on_retry`]. The chaos
/// engine has already failed the completion back to `Pending`
/// ([`ClusterState::fail_completion`]): the job keeps its realized
/// prompt quality and carries the redo iterations, and its next launch
/// restores from the last checkpoint. The policy must reconcile its own
/// bookkeeping — return the attempt's GPUs to its pools and requeue the
/// job no earlier than `not_before` (exponential backoff).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryEvent {
    pub job_id: usize,
    /// GPUs the failed attempt held (already released by the simulator;
    /// the policy folds them back into its own pools).
    pub gpus: usize,
    /// 1-based retry attempt this event starts.
    pub attempt: u32,
    /// Earliest relaunch time (absolute seconds): `now + backoff`.
    pub not_before: f64,
}

/// A tuned prompt produced by a completed tuning run — the unit of
/// cross-shard Prompt-Bank gossip in the shard plane (`crate::shard`).
/// Policies record these when a plane enables the gossip log, a gossip
/// round drains them, and peer shards absorb them into their own banks
/// (the Fig 5b feedback edge, stretched across shards).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TunedPrompt {
    pub llm: Llm,
    pub task_id: usize,
    pub quality: f64,
}

#[derive(Clone, Copy, Debug, PartialEq)]
enum EventKind {
    Arrival(usize),
    /// (job, generation) — stale generations are ignored.
    JobDone(usize, u64),
    End,
}

#[derive(Clone, Copy, Debug)]
struct Event {
    time: f64,
    seq: u64,
    kind: EventKind,
}

impl PartialEq for Event {
    fn eq(&self, other: &Self) -> bool {
        self.time == other.time && self.seq == other.seq
    }
}
impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap; invert for earliest-first.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap_or(Ordering::Equal)
            .then(other.seq.cmp(&self.seq))
    }
}

/// A policy's answer to "when is your next time-driven action?", used by
/// the run loop to coalesce no-op scheduling rounds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Wake {
    /// Tick every round (the dense reference behavior; the default).
    Dense,
    /// No round before the first grid tick at or after this absolute
    /// time can perform any action. Rounds strictly before it are
    /// skipped; discrete events (arrivals/completions) always re-query.
    At(f64),
    /// No round can perform any action until the next discrete event.
    Idle,
}

impl Wake {
    /// The earlier of two wake hints (used by policy combinators that
    /// merge their own timed actions with the wrapped policy's).
    pub fn earliest(a: Wake, b: Wake) -> Wake {
        match (a, b) {
            (Wake::Dense, _) | (_, Wake::Dense) => Wake::Dense,
            (Wake::Idle, w) | (w, Wake::Idle) => w,
            (Wake::At(x), Wake::At(y)) => Wake::At(x.min(y)),
        }
    }
}

/// Mutable cluster state policies operate on.
pub struct ClusterState {
    now: f64,
    pub jobs: Vec<JobState>,
    pub perf: PerfModel,
    pub cfg: SimConfig,
    /// Current billed GPU level (policy-maintained).
    billable_gpus: f64,
    /// Current busy GPU level (maintained by launch/finish/realloc).
    busy_gpus: f64,
    last_integrate_t: f64,
    /// Integrated billed GPU-seconds.
    pub cost_gpu_s: f64,
    /// Integrated busy GPU-seconds.
    pub busy_gpu_s: f64,
    /// Integrated billable GPU-seconds while *any* billable capacity
    /// exists (denominator of utilization).
    pub billable_gpu_s: f64,
    /// Storage cost accumulator (synchronous-communication channel, $).
    pub storage_cost: f64,
    /// (time, utilization) samples.
    pub util_timeline: Vec<(f64, f64)>,
    next_util_sample: f64,
    queued: Vec<(f64, EventKind)>,
    seq: u64,
    /// Per-LLM incremental index of jobs currently holding GPUs
    /// (Initializing or Running), so policies need not scan `jobs`
    /// wholesale every round. Order is arbitrary (swap-remove).
    active: [Vec<usize>; N_LLM],
    /// Position of each job in its LLM's `active` list (usize::MAX when
    /// the job holds no GPUs).
    active_pos: Vec<usize>,
    /// Checkpoint/restore cost model (None = fault-free semantics,
    /// bit-identical to the pre-fault simulator).
    ckpt: Option<CheckpointModel>,
    /// GPUs currently revoked by faults (failed / reclaimed, not yet
    /// repaired). The effective provider budget is `max_gpus - revoked`;
    /// the oracle audits that billable capacity never exceeds it.
    revoked_gpus: f64,
    /// Lifetime involuntary revocations (`revoke_job` calls).
    pub revocations: u64,
    /// Total iterations lost to restore-from-checkpoint (conserved
    /// against the per-job `lost_iters` sums by the oracle).
    pub total_lost_iters: f64,
    /// Total extra iterations added by straggler slowdowns.
    pub total_straggler_iters: f64,
    /// Chaos latency-injection model (None = chaos-free semantics,
    /// bit-identical to the pre-chaos simulator).
    chaos: Option<ChaosInjection>,
    /// GPUs currently inside dead failure domains (chaos topology).
    /// Always covered by the revoked level: the oracle audits
    /// `revoked ≥ dead_domain`, so `billable ≤ budget - revoked` implies
    /// no billable capacity sits inside a dead domain.
    dead_domain_gpus: f64,
    /// Lifetime failed completions (`fail_completion` calls, conserved
    /// against the per-job `retries` sums by the oracle).
    pub total_retries: u64,
    /// Total iterations re-queued by failed completions (conserved
    /// against the per-job `retry_iters` sums by the oracle).
    pub total_retry_iters: f64,
    /// Total extra seconds injected by chaos latency tails.
    pub total_chaos_delay_s: f64,
}

impl ClusterState {
    fn new(cfg: SimConfig, perf: PerfModel, specs: Vec<JobSpec>) -> Self {
        let n = specs.len();
        ClusterState {
            now: 0.0,
            jobs: specs.into_iter().map(JobState::new).collect(),
            perf,
            cfg,
            billable_gpus: 0.0,
            busy_gpus: 0.0,
            last_integrate_t: 0.0,
            cost_gpu_s: 0.0,
            busy_gpu_s: 0.0,
            billable_gpu_s: 0.0,
            storage_cost: 0.0,
            util_timeline: vec![],
            next_util_sample: 0.0,
            queued: vec![],
            seq: 0,
            active: Default::default(),
            active_pos: vec![usize::MAX; n],
            ckpt: None,
            revoked_gpus: 0.0,
            revocations: 0,
            total_lost_iters: 0.0,
            total_straggler_iters: 0.0,
            chaos: None,
            dead_domain_gpus: 0.0,
            total_retries: 0,
            total_retry_iters: 0.0,
            total_chaos_delay_s: 0.0,
        }
    }

    /// Current simulated time, seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Last event sequence number consumed (ticks included). Strictly
    /// monotone over the run; exposed so the oracle can audit it.
    pub fn event_seq(&self) -> u64 {
        self.seq
    }

    /// Jobs of `llm` currently holding GPUs (Initializing or Running),
    /// in arbitrary order. Maintained incrementally by launch/complete.
    pub fn active_jobs(&self, llm: Llm) -> &[usize] {
        &self.active[llm.index()]
    }

    fn activate(&mut self, job_id: usize) {
        let li = self.jobs[job_id].spec.llm.index();
        debug_assert_eq!(self.active_pos[job_id], usize::MAX);
        self.active_pos[job_id] = self.active[li].len();
        self.active[li].push(job_id);
    }

    fn deactivate(&mut self, job_id: usize) {
        let li = self.jobs[job_id].spec.llm.index();
        let pos = self.active_pos[job_id];
        debug_assert!(pos != usize::MAX && self.active[li][pos] == job_id);
        self.active[li].swap_remove(pos);
        if let Some(&moved) = self.active[li].get(pos) {
            self.active_pos[moved] = pos;
        }
        self.active_pos[job_id] = usize::MAX;
    }

    /// Advance simulated time to `t`, flushing any utilization samples
    /// that fell due. Called by the run loop at every executed round and
    /// event; batch-skipped rounds go *through* here in one jump, which
    /// is safe because levels cannot change while the policy sleeps —
    /// every sample in the span reads the same levels it would have read
    /// under dense ticking, and the sample clock advances by the same
    /// repeated addition. GPU-second accumulation lives in
    /// [`ClusterState::commit_levels`], not here.
    fn integrate_to(&mut self, t: f64) {
        while self.next_util_sample <= t {
            let util = if self.billable_gpus > 0.0 {
                self.busy_gpus / self.billable_gpus
            } else {
                0.0
            };
            self.util_timeline.push((self.next_util_sample, util.min(1.0)));
            self.next_util_sample += self.cfg.util_sample_s;
        }
        self.now = t;
    }

    /// Accumulate the GPU-second integrals over the segment since the
    /// last commit, at the current levels. Must run *before* any
    /// mutation of `billable_gpus`/`busy_gpus` (and once more at run
    /// end). Segment boundaries are therefore exactly the level-change
    /// instants — which occur only inside policy/event callbacks, at
    /// identical times in dense and batch-skip runs — so the float
    /// accumulation sequence, and hence the cost bits, are identical
    /// however many rounds were skipped in between.
    fn commit_levels(&mut self) {
        let dt = self.now - self.last_integrate_t;
        if dt > 0.0 {
            self.cost_gpu_s += self.billable_gpus * dt;
            self.busy_gpu_s += self.busy_gpus * dt;
            self.billable_gpu_s += self.billable_gpus.max(0.0) * dt;
            self.last_integrate_t = self.now;
        }
    }

    /// Set the current billed GPU level (e.g. warm-pool size, or the
    /// fixed cluster size). Integration is handled by the run loop.
    pub fn set_billable(&mut self, gpus: f64) {
        self.commit_levels();
        self.billable_gpus = gpus;
    }

    pub fn billable(&self) -> f64 {
        self.billable_gpus
    }

    pub fn busy(&self) -> f64 {
        self.busy_gpus
    }

    /// Install (or clear) the checkpoint/restore cost model. Called once
    /// at run start by the fault engine; `None` keeps the fault-free
    /// semantics bit-identical to the pre-fault simulator.
    pub fn set_checkpoint_model(&mut self, model: Option<CheckpointModel>) {
        self.ckpt = model;
    }

    pub fn checkpoint_model(&self) -> Option<&CheckpointModel> {
        self.ckpt.as_ref()
    }

    /// Record the current level of revoked (failed / reclaimed, not yet
    /// repaired) GPUs. Maintained by the fault engine; the oracle audits
    /// `billable ≤ max_gpus - revoked` against it.
    pub fn set_revoked(&mut self, gpus: f64) {
        self.revoked_gpus = gpus;
    }

    pub fn revoked(&self) -> f64 {
        self.revoked_gpus
    }

    /// Install (or clear) the chaos latency-injection model. Called once
    /// at run start by the chaos engine; `None` keeps the chaos-free
    /// semantics bit-identical to the pre-chaos simulator.
    pub fn set_chaos(&mut self, model: Option<ChaosInjection>) {
        self.chaos = model;
    }

    pub fn chaos_model(&self) -> Option<&ChaosInjection> {
        self.chaos.as_ref()
    }

    /// Record the GPU count currently inside dead failure domains.
    /// Maintained by the chaos engine alongside the revoked level; the
    /// oracle audits `revoked ≥ dead_domain` against it.
    pub fn set_dead_domain(&mut self, gpus: f64) {
        self.dead_domain_gpus = gpus;
    }

    pub fn dead_domain(&self) -> f64 {
        self.dead_domain_gpus
    }

    /// Fail a just-completed tuning run back to the queue (chaos
    /// engine): the job returns to `Pending` carrying `redo_iters` of
    /// rework, its in-flight state is invalidated, and `needs_restore`
    /// is set so the relaunch resumes from the last checkpoint (keeping
    /// the realized prompt quality) instead of paying a second bank
    /// lookup. Must be called while the job is `Done` with no GPUs —
    /// i.e. from inside the completion callback, after the simulator has
    /// released the allocation. `backoff_s` is recorded for the oracle's
    /// backoff-monotonicity audit.
    pub fn fail_completion(&mut self, job_id: usize, redo_iters: f64,
                           backoff_s: f64) {
        let job = &mut self.jobs[job_id];
        debug_assert_eq!(job.status, JobStatus::Done, "job {job_id}");
        debug_assert_eq!(job.gpus, 0, "job {job_id}");
        debug_assert!(redo_iters > 0.0 && redo_iters.is_finite());
        debug_assert!(backoff_s >= job.retry_backoff_s);
        job.status = JobStatus::Pending;
        job.completed_at = f64::INFINITY;
        job.iters_remaining = redo_iters;
        job.needs_restore = true;
        job.gen += 1; // invalidate any stale completion event
        job.retries += 1;
        job.retry_iters += redo_iters;
        job.retry_backoff_s = backoff_s;
        // The earliest round a policy may act on this retry — the anchor
        // for the starved-wake audit ([`StateAudit::check_wake`]). Same
        // float expression the chaos engine uses for `RetryEvent::
        // not_before`, so policies holding the event's time back-merge
        // bit-identically.
        job.retry_not_before = self.now + backoff_s;
        self.total_retries += 1;
        self.total_retry_iters += redo_iters;
    }

    /// Effective seconds per iteration: the perf model's time, slowed by
    /// the amortized periodic-checkpoint overhead when a checkpoint model
    /// is armed. Without one, this is exactly `PerfModel::iter_time`.
    pub fn eff_iter_time(&self, llm: Llm, gpus: usize) -> f64 {
        let base = self.perf.iter_time(llm, gpus);
        match &self.ckpt {
            Some(m) => base * m.slowdown(),
            None => base,
        }
    }

    /// Involuntarily preempt a job holding GPUs (fault engine): progress
    /// is brought up to date, work since the last periodic checkpoint is
    /// lost (unless `graceful` — spot reclaims checkpoint inside their
    /// notice window), the in-flight completion event is invalidated, and
    /// the job returns to `Pending` with `needs_restore` set so its next
    /// launch resumes from the checkpoint (paying the restore overhead)
    /// instead of silently restarting from scratch. Returns the GPUs the
    /// job held.
    pub fn revoke_job(&mut self, job_id: usize, graceful: bool) -> usize {
        let now = self.now;
        let llm = self.jobs[job_id].spec.llm;
        let it = self.eff_iter_time(llm, self.jobs[job_id].gpus.max(1));
        let held;
        {
            let job = &mut self.jobs[job_id];
            debug_assert!(
                matches!(job.status,
                         JobStatus::Initializing | JobStatus::Running),
                "revoking job {job_id} in state {:?}",
                job.status
            );
            job.advance_progress(now, it);
            if job.status == JobStatus::Running && !graceful {
                if let Some(m) = &self.ckpt {
                    let ran = (now - job.seg_start_t).max(0.0);
                    let since_ckpt =
                        if m.period_s.is_finite() && m.period_s > 0.0 {
                            ran % m.period_s
                        } else {
                            ran // no periodic checkpoints: segment lost
                        };
                    let lost = since_ckpt / it;
                    job.iters_remaining += lost;
                    job.lost_iters += lost;
                    self.total_lost_iters += lost;
                }
            }
            held = job.gpus;
            job.status = JobStatus::Pending;
            job.gpus = 0;
            job.gen += 1; // invalidate the in-flight completion event
            job.needs_restore = true;
            job.restarts += 1;
        }
        self.commit_levels();
        self.busy_gpus -= held as f64;
        self.deactivate(job_id);
        self.revocations += 1;
        held
    }

    /// Straggler slowdown (fault engine): inflate a running job's
    /// remaining work by `factor` (a slow node stretches its execution)
    /// and reschedule its completion. The disturbance instant acts as an
    /// implicit checkpoint boundary.
    pub fn slow_job(&mut self, job_id: usize, factor: f64) {
        debug_assert!(factor >= 1.0);
        let now = self.now;
        let llm = self.jobs[job_id].spec.llm;
        let it = self.eff_iter_time(llm, self.jobs[job_id].gpus.max(1));
        let finish;
        {
            let job = &mut self.jobs[job_id];
            debug_assert!(matches!(
                job.status,
                JobStatus::Initializing | JobStatus::Running
            ));
            job.advance_progress(now, it);
            if job.status != JobStatus::Running {
                return; // still initializing: nothing to slow down yet
            }
            let extra = job.iters_remaining * (factor - 1.0);
            job.iters_remaining += extra;
            job.straggler_iters += extra;
            self.total_straggler_iters += extra;
            job.gen += 1;
            job.last_progress_t = now;
            job.seg_start_t = now;
            finish = now + job.iters_remaining * it;
        }
        let gen = self.jobs[job_id].gen;
        self.push(finish, EventKind::JobDone(job_id, gen));
    }

    /// Launch a pending job on `gpus` GPUs after `init_delay` seconds of
    /// initialization, starting from a prompt of quality `quality` after
    /// `bank_latency` seconds of Prompt-Bank lookup (sequential with the
    /// job, §5.2). Schedules the completion event.
    pub fn launch(
        &mut self,
        job_id: usize,
        gpus: usize,
        init_delay: f64,
        bank_latency: f64,
        quality: f64,
    ) {
        debug_assert!(gpus > 0);
        let now = self.now;
        let llm = self.jobs[job_id].spec.llm;
        let iter_time = self.eff_iter_time(llm, gpus);
        let needs_restore = self.jobs[job_id].needs_restore;
        let restore_s = if needs_restore {
            self.ckpt.as_ref().map_or(0.0, |m| m.restore_s)
        } else {
            0.0
        };
        // Chaos latency tails: stretch the delays this launch will
        // actually pay (a restore launch skips the bank, so its lookup
        // draw is skipped too). Policies estimated with the nominal
        // delays — the tail is unpredicted, exactly like production.
        let (init_delay, bank_latency) = match &self.chaos {
            Some(c) => {
                let gen = self.jobs[job_id].gen;
                let ls = c.launch_stretch(job_id, gen);
                let bs = if needs_restore {
                    1.0
                } else {
                    c.lookup_stretch(job_id, gen)
                };
                self.total_chaos_delay_s +=
                    init_delay * (ls - 1.0) + bank_latency * (bs - 1.0);
                (init_delay * ls, bank_latency * bs)
            }
            None => (init_delay, bank_latency),
        };
        let (iters, exec);
        {
            let job = &mut self.jobs[job_id];
            debug_assert_eq!(job.status, JobStatus::Pending, "job {job_id}");
            if job.needs_restore {
                // Restore from the last checkpoint (after an involuntary
                // revocation): realized prompt quality and remaining
                // iterations survive; the job pays the restore overhead
                // instead of a second Prompt-Bank lookup, so the
                // quality/bank arguments are ignored.
                job.needs_restore = false;
                job.gpus = gpus;
                job.status = JobStatus::Initializing;
                job.launched_at = now;
                job.init_wait += init_delay + restore_s;
                job.init_until = now + init_delay + restore_s;
            } else {
                job.quality = quality.max(job.spec.user_prompt_quality);
                job.bank_latency = bank_latency;
                job.iters_remaining = job.spec.iters_at(job.quality);
                job.gpus = gpus;
                job.status = JobStatus::Initializing;
                job.launched_at = now;
                job.init_wait = init_delay;
                job.init_until = now + init_delay + bank_latency;
            }
            job.last_progress_t = job.init_until;
            job.seg_start_t = job.init_until;
            job.gen += 1;
            iters = job.iters_remaining;
            exec = job.init_until + iters * iter_time;
            // storage cost of the synchronous gradient channel
            let replicas = (gpus / job.spec.llm.gpus_per_replica()).max(1);
            if replicas > 1 {
                let exec_h = (iters * iter_time) / 3600.0;
                self.storage_cost +=
                    COMM_PAYLOAD_GB * replicas as f64 * exec_h * STORAGE_PRICE_PER_GB_H;
            }
        }
        self.commit_levels();
        self.busy_gpus += gpus as f64;
        self.activate(job_id);
        let gen = self.jobs[job_id].gen;
        self.push(exec, EventKind::JobDone(job_id, gen));
    }

    /// Elastically change a running/initializing job's allocation. The
    /// remaining work is recomputed and the completion event rescheduled.
    /// Returns the old allocation.
    pub fn realloc(&mut self, job_id: usize, new_gpus: usize,
                   extra_delay: f64) -> usize {
        let now = self.now;
        let llm = self.jobs[job_id].spec.llm;
        let it_old = self.eff_iter_time(llm, self.jobs[job_id].gpus.max(1));
        let it_new = self.eff_iter_time(llm, new_gpus.max(1));
        let (old, finish);
        {
            let job = &mut self.jobs[job_id];
            debug_assert!(matches!(job.status,
                JobStatus::Running | JobStatus::Initializing));
            job.advance_progress(now, it_old);
            old = job.gpus;
            job.gpus = new_gpus;
            job.gen += 1;
            if job.status == JobStatus::Initializing {
                job.init_until = job.init_until.max(now + extra_delay);
                job.last_progress_t = job.init_until;
                job.seg_start_t = job.init_until;
                finish = job.init_until + job.iters_remaining * it_new;
            } else if extra_delay > 0.0 {
                job.status = JobStatus::Initializing;
                job.init_until = now + extra_delay;
                job.init_wait += extra_delay;
                job.last_progress_t = job.init_until;
                job.seg_start_t = job.init_until;
                finish = job.init_until + job.iters_remaining * it_new;
            } else {
                job.last_progress_t = now;
                // reallocation reshards state — an implicit checkpoint
                job.seg_start_t = now;
                finish = now + job.iters_remaining * it_new;
            }
        }
        self.commit_levels();
        self.busy_gpus += new_gpus as f64 - old as f64;
        let gen = self.jobs[job_id].gen;
        self.push(finish, EventKind::JobDone(job_id, gen));
        old
    }

    /// Estimated completion time if `job` were launched now on `gpus`
    /// GPUs with the given delays (the T_i(a) the algorithms reason
    /// with). Checkpoint-model aware: iteration time includes the
    /// amortized checkpoint slowdown, and a revoked job awaiting restore
    /// is estimated from its preserved remaining iterations plus the
    /// restore overhead (matching what `launch` will actually do) —
    /// without a model armed this is bit-identical to the fault-free
    /// estimator.
    pub fn estimate_completion(&self, job_id: usize, gpus: usize,
                               init_delay: f64, bank_latency: f64,
                               quality: f64) -> f64 {
        let job = &self.jobs[job_id];
        if job.needs_restore {
            let restore = self.ckpt.as_ref().map_or(0.0, |m| m.restore_s);
            return self.now + init_delay + restore
                + job.iters_remaining * self.eff_iter_time(job.spec.llm, gpus);
        }
        let iters = job.spec.iters_at(quality.max(job.spec.user_prompt_quality));
        self.now + init_delay + bank_latency
            + iters * self.eff_iter_time(job.spec.llm, gpus)
    }

    fn push(&mut self, time: f64, kind: EventKind) {
        self.queued.push((time, kind));
    }

    fn drain_queued(&mut self, heap: &mut BinaryHeap<Event>) {
        for (time, kind) in self.queued.drain(..) {
            self.seq += 1;
            heap.push(Event { time, seq: self.seq, kind });
        }
    }
}

// --------------------------------------------- tuner knob declarations

/// One tunable knob a policy declares to the self-tuning control plane
/// (`slo::Tuned`): a bounded lattice of `steps` evenly spaced values in
/// `[lo, hi]`. The declaration is a contract — [`StateAudit::check_tuner`]
/// fails any run whose logged knob values ever leave the declared bounds.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct KnobSpec {
    /// Stable knob name (`"capacity"`, `"bank_ceiling"`, ...).
    pub name: &'static str,
    /// Inclusive lower lattice bound.
    pub lo: f64,
    /// Inclusive upper lattice bound.
    pub hi: f64,
    /// Number of lattice points in `[lo, hi]` (clamped to ≥ 2).
    pub steps: usize,
}

impl KnobSpec {
    /// The `i`-th lattice value (evenly spaced, both endpoints included;
    /// `i` saturates at the last point).
    pub fn value_at(&self, i: usize) -> f64 {
        let steps = self.steps.max(2);
        let i = i.min(steps - 1);
        self.lo + (self.hi - self.lo) * (i as f64) / ((steps - 1) as f64)
    }
}

/// What one tuner decision did.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TunerAction {
    /// Switched a knob onto an exploration arm's lattice value.
    Explore,
    /// Promoted the measured winner's value to incumbent.
    Promote,
    /// Reverted a misbehaving arm back to the incumbent value.
    Revert,
    /// Froze exploration (budget cap hit) and pinned the incumbent.
    Freeze,
}

/// One audited tuner decision: at evaluation-window boundary `t`, knob
/// `knob` was set to `value` on behalf of exploration arm `arm` (arm 0
/// is always the incumbent configuration).
#[derive(Clone, Debug)]
pub struct TunerDecision {
    /// Simulated time the decision executed (a window boundary).
    pub t: f64,
    pub action: TunerAction,
    /// Arm whose configuration the knob was moved to.
    pub arm: usize,
    pub knob: &'static str,
    /// The value the knob was set to.
    pub value: f64,
}

/// Append-only audit log of every tuner decision; consumed by
/// [`StateAudit::check_tuner`] and surfaced through
/// [`Policy::tuner_report`] counters.
#[derive(Clone, Debug, Default)]
pub struct TunerLog {
    pub decisions: Vec<TunerDecision>,
}

/// Per-knob telemetry surfaced into bench records: the declared bounds,
/// the final (incumbent) value, and the extremes the tuner ever set.
#[derive(Clone, Debug)]
pub struct KnobStat {
    pub name: &'static str,
    pub lo: f64,
    pub hi: f64,
    /// Incumbent value at end of run.
    pub value: f64,
    /// Smallest value the tuner ever set this knob to.
    pub min_seen: f64,
    /// Largest value the tuner ever set this knob to.
    pub max_seen: f64,
}

/// End-of-run tuner summary ([`Policy::tuner_report`]); the bench
/// harness embeds it in `BENCH_tuning.json` cells.
#[derive(Clone, Debug, Default)]
pub struct TunerReport {
    pub knobs: Vec<KnobStat>,
    /// Total logged decisions.
    pub decisions: usize,
    /// Arms promoted to incumbent.
    pub promotions: usize,
    /// Fast-burn reverts to the incumbent.
    pub reverts: usize,
    /// SLO-missing completions observed while an exploration arm was
    /// live (the exploration spend charged against the error budget).
    pub explore_bad: usize,
    /// True once the exploration budget cap froze further exploration.
    pub frozen: bool,
}

/// A scheduling policy (PromptTuner's Workload Scheduler or a baseline).
pub trait Policy {
    fn name(&self) -> &str;

    /// Scheduling round period (the paper uses 50 ms rounds, §5.3).
    fn tick_interval(&self) -> f64 {
        0.05
    }

    /// A job was submitted.
    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize);

    /// A job finished and released its GPUs.
    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize);

    /// One scheduling round.
    fn on_tick(&mut self, st: &mut ClusterState);

    /// When is this policy's next *time-driven* action, given the state
    /// it just observed? Queried after every policy callback; rounds the
    /// answer proves idle are coalesced (skipped). A policy must only
    /// return [`Wake::At`]/[`Wake::Idle`] when every skipped round would
    /// have been a no-op (no state changes, no RNG draws) under dense
    /// ticking. The default keeps dense rounds.
    fn next_timed_action(&self, st: &ClusterState) -> Wake {
        let _ = st;
        Wake::Dense
    }

    /// Involuntary revocation (fault engine, `fault::FaultInjector`):
    /// the listed victim jobs have already been preempted back to
    /// `Pending` ([`ClusterState::revoke_job`]) and the provider budget
    /// lowered. The policy must reconcile its own bookkeeping — requeue
    /// the victims, drop each victim's failed GPUs from any pools
    /// (returning the survivors), and shed up to `ev.idle_gpus_lost`
    /// idle/pre-warming instances. The default ignores the event (such a
    /// policy strands its victims; every policy in this crate recovers).
    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        let _ = (st, ev);
    }

    /// Failed tuning run (chaos engine, `fault::ChaosEngine`): the job's
    /// completion was rejected and it is back in `Pending`
    /// ([`ClusterState::fail_completion`]) carrying redo work. The policy
    /// must reconcile its own bookkeeping — fold the attempt's
    /// `ev.gpus` back into its pools and requeue the job no earlier than
    /// `ev.not_before` (the engine's exponential backoff). The default
    /// ignores the event (such a policy strands the retried job; every
    /// policy in this crate recovers).
    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        let _ = (st, ev);
    }

    /// Billable-capacity ceiling this policy currently schedules within
    /// (None when it has no such knob). Capacity governors
    /// (`slo::Governed`) read this before scaling.
    fn capacity(&self) -> Option<usize> {
        None
    }

    /// Move the policy's billable-capacity ceiling — the scale-up /
    /// scale-down hook capacity governors drive. Implementations must
    /// preserve the cluster invariants (busy ≤ billable ≤ provider
    /// budget); e.g. a statically-billed policy must clamp the new size
    /// to its busy level. The default ignores the request.
    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        let _ = (st, gpus);
    }

    /// Prompt-Bank coverage this policy realizes for `(llm, task)` right
    /// now — the shard-plane router's placement signal. `None` means the
    /// policy has no bank (or it is disabled); the router treats that as
    /// zero coverage. Must be a pure read (no bank mutation, no RNG).
    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        let _ = (llm, task_id);
        None
    }

    /// Start recording tuned prompts (completion feedback) for
    /// cross-shard gossip. Off by default, and never enabled outside a
    /// gossiping shard plane — so unsharded runs carry no log and stay
    /// bit-identical to the pre-gossip simulator.
    fn enable_gossip_log(&mut self) {}

    /// Move every tuned prompt recorded since the last drain into `out`
    /// (append; callers batch several shards into one vector). No-op
    /// unless [`Policy::enable_gossip_log`] armed the log.
    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        let _ = out;
    }

    /// Merge tuned prompts gossiped from peer shards into this policy's
    /// bank. Absorbed prompts are not re-logged (gossip converges
    /// instead of echoing).
    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        let _ = items;
    }

    /// Tunable knobs this policy declares to the self-tuning control
    /// plane (`slo::Tuned`). Empty by default — a policy with no
    /// declarations is simply not tunable. Must be stable over a run
    /// (the tuner snapshots it once).
    fn knobs(&self) -> Vec<KnobSpec> {
        vec![]
    }

    /// Current value of declared knob `name` (`None` when undeclared).
    /// Must be a pure read.
    fn knob_value(&self, name: &str) -> Option<f64> {
        let _ = name;
        None
    }

    /// Set declared knob `name` to `value`. Implementations round/clamp
    /// as needed but must preserve the cluster invariants (busy ≤
    /// billable ≤ provider budget) — capacity-like knobs route through
    /// the same machinery as [`Policy::set_capacity`]. The default
    /// ignores the request.
    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        let _ = (st, name, value);
    }

    /// End-of-run tuner telemetry (`None` for untuned policies);
    /// wrappers forward it so the bench harness can surface it from
    /// behind `FaultInjector`/oracle layers.
    fn tuner_report(&self) -> Option<TunerReport> {
        None
    }
}

/// Forward [`Policy`] through boxes so trait objects (e.g. the
/// `Box<dyn Policy>` the bench harness builds) can be wrapped by
/// [`SimOracle`] and other combinators.
impl<P: Policy + ?Sized> Policy for Box<P> {
    fn name(&self) -> &str {
        (**self).name()
    }
    fn tick_interval(&self) -> f64 {
        (**self).tick_interval()
    }
    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        (**self).on_arrival(st, job_id)
    }
    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        (**self).on_job_complete(st, job_id)
    }
    fn on_tick(&mut self, st: &mut ClusterState) {
        (**self).on_tick(st)
    }
    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        (**self).on_revoke(st, ev)
    }
    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        (**self).on_retry(st, ev)
    }
    fn next_timed_action(&self, st: &ClusterState) -> Wake {
        (**self).next_timed_action(st)
    }
    fn capacity(&self) -> Option<usize> {
        (**self).capacity()
    }
    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        (**self).set_capacity(st, gpus)
    }
    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        (**self).bank_coverage(llm, task_id)
    }
    fn enable_gossip_log(&mut self) {
        (**self).enable_gossip_log()
    }
    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        (**self).drain_tuned(out)
    }
    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        (**self).absorb_tuned(items)
    }
    fn knobs(&self) -> Vec<KnobSpec> {
        (**self).knobs()
    }
    fn knob_value(&self, name: &str) -> Option<f64> {
        (**self).knob_value(name)
    }
    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        (**self).set_knob(st, name, value)
    }
    fn tuner_report(&self) -> Option<TunerReport> {
        (**self).tuner_report()
    }
}

// ------------------------------------------------------ event observers

/// Passive observer of the simulation event stream: called after every
/// policy callback with the (immutable) post-callback state, so telemetry
/// layers (`slo::SloMonitor`) can maintain online indicators — rolling
/// SLO attainment, lateness percentiles, queue depth — without being able
/// to perturb the run. All hooks default to no-ops; `()` is the null
/// observer [`Simulator::run`] uses.
pub trait SimObserver {
    /// A job arrived (after the policy's `on_arrival`).
    fn on_arrival(&mut self, st: &ClusterState, job_id: usize) {
        let _ = (st, job_id);
    }
    /// A job completed (after the policy's `on_job_complete`).
    fn on_job_complete(&mut self, st: &ClusterState, job_id: usize) {
        let _ = (st, job_id);
    }
    /// An executed (non-coalesced) scheduling round finished.
    fn on_round(&mut self, st: &ClusterState) {
        let _ = st;
    }
    /// The run ended (final integrated state).
    fn on_end(&mut self, st: &ClusterState) {
        let _ = st;
    }
}

/// The null observer.
impl SimObserver for () {}

// ------------------------------------------------------- simulation oracle

/// Reusable from-scratch invariant auditor — the core of the simulation
/// oracle. One pass over the cluster per call (scratch buffers reused, so
/// auditing every round stays cheap), checking:
///
/// * **GPU-capacity conservation** — busy and billable levels are
///   non-negative, within the provider budget (`SimConfig::max_gpus`),
///   busy never exceeds billable, and the busy level equals a from-scratch
///   recount over job allocations;
/// * **no grants to departed jobs** — GPUs are held exactly by
///   Initializing/Running jobs: Pending and Done jobs hold none, Done jobs
///   have no work remaining;
/// * **index agreement** — the incremental per-LLM active-job index
///   matches a from-scratch recount (membership, LLM, no duplicates);
/// * **monotone event sequence numbers** and simulated time;
/// * **non-negative incremental cost** — the billed/busy GPU-second
///   integrals never decrease between audits and stay finite;
/// * **chaos accounting** — retries are conserved (per-job `retries` /
///   `retry_iters` sums match the cluster totals, monotone over the
///   run), per-job retry backoff never shrinks, and dead failure
///   domains are fully covered by the revoked level (so no billable
///   capacity sits inside a dead domain).
///
/// Use one auditor per simulated run (the monotonicity history resets
/// with it). The stateless starved-wake check
/// ([`StateAudit::check_wake`]) rides alongside: it audits each wake
/// hint a policy emits, not the cluster state, and so is an associated
/// function rather than part of [`StateAudit::check`].
#[derive(Debug, Default)]
pub struct StateAudit {
    /// Scratch: whether job i should appear in the active index.
    mark: Vec<bool>,
    last_seq: u64,
    last_now: f64,
    last_cost_gpu_s: f64,
    last_busy_gpu_s: f64,
    last_lost_iters: f64,
    last_straggler_iters: f64,
    last_revocations: u64,
    last_retries: u64,
    /// Per-job last observed retry backoff (monotonicity history).
    backoff: Vec<f64>,
    /// Number of audits performed (so tests can assert coverage).
    pub audits: u64,
}

impl StateAudit {
    pub fn new() -> Self {
        StateAudit::default()
    }

    /// Audit `st`, appending one message per violated invariant to `out`.
    pub fn check(&mut self, st: &ClusterState, whence: &str,
                 out: &mut Vec<String>) {
        self.audits += 1;
        let eps = 1e-9;
        let t = st.now();
        let budget = st.cfg.max_gpus as f64;

        // ---- capacity conservation (levels) ----
        let busy = st.busy();
        let billable = st.billable();
        if busy < -eps {
            out.push(format!("{whence}@{t:.3}: negative busy level {busy}"));
        }
        if billable < -eps {
            out.push(format!("{whence}@{t:.3}: negative billable level {billable}"));
        }
        if billable > budget + eps {
            out.push(format!(
                "{whence}@{t:.3}: billable {billable} exceeds provider budget {budget}"
            ));
        }
        // ---- fault capacity: revoked GPUs never re-granted before repair
        let revoked = st.revoked();
        if revoked < -eps || revoked > budget + eps {
            out.push(format!(
                "{whence}@{t:.3}: revoked level {revoked} outside [0, {budget}]"
            ));
        }
        // ---- chaos domains: a dead rack's GPUs are all revoked, so
        // `billable ≤ budget - revoked ≤ budget - dead` and no billable
        // capacity sits inside a dead domain.
        let dead = st.dead_domain();
        if dead < -eps || dead > budget + eps {
            out.push(format!(
                "{whence}@{t:.3}: dead-domain level {dead} outside [0, {budget}]"
            ));
        }
        if revoked + eps < dead {
            out.push(format!(
                "{whence}@{t:.3}: dead-domain GPUs {dead} exceed the revoked \
                 level {revoked}: capacity inside a dead domain is billable"
            ));
        }
        if billable > budget - revoked + eps {
            out.push(format!(
                "{whence}@{t:.3}: billable {billable} exceeds the effective \
                 budget {} ({budget} - {revoked} revoked): revoked GPUs \
                 re-granted before repair",
                budget - revoked
            ));
        }
        if busy > billable + eps {
            out.push(format!(
                "{whence}@{t:.3}: busy {busy} exceeds billable {billable} \
                 (capacity conservation)"
            ));
        }

        // ---- per-job grants + busy recount ----
        let n = st.jobs.len();
        self.mark.clear();
        self.mark.resize(n, false);
        if self.backoff.len() < n {
            self.backoff.resize(n, 0.0);
        }
        let mut busy_recount = 0.0f64;
        let mut lost_recount = 0.0f64;
        let mut straggler_recount = 0.0f64;
        let mut restarts_recount = 0u64;
        let mut retries_recount = 0u64;
        let mut retry_iters_recount = 0.0f64;
        for (i, job) in st.jobs.iter().enumerate() {
            let holds = matches!(
                job.status,
                JobStatus::Initializing | JobStatus::Running
            );
            if holds {
                if job.gpus == 0 {
                    out.push(format!(
                        "{whence}@{t:.3}: job {i} is {:?} with no GPUs",
                        job.status
                    ));
                }
                busy_recount += job.gpus as f64;
            } else if job.gpus != 0 {
                out.push(format!(
                    "{whence}@{t:.3}: grant to departed job {i} \
                     ({:?} holding {} GPUs)",
                    job.status, job.gpus
                ));
            }
            if job.status == JobStatus::Done && job.iters_remaining != 0.0 {
                out.push(format!(
                    "{whence}@{t:.3}: done job {i} has {} iters remaining",
                    job.iters_remaining
                ));
            }
            // ---- per-job fault accounting ----
            if job.lost_iters < 0.0 || !job.lost_iters.is_finite() {
                out.push(format!(
                    "{whence}@{t:.3}: job {i} lost_iters is {}",
                    job.lost_iters
                ));
            }
            if job.straggler_iters < 0.0 || !job.straggler_iters.is_finite() {
                out.push(format!(
                    "{whence}@{t:.3}: job {i} straggler_iters is {}",
                    job.straggler_iters
                ));
            }
            if job.needs_restore && job.status != JobStatus::Pending {
                out.push(format!(
                    "{whence}@{t:.3}: job {i} ({:?}) awaits restore but is \
                     not Pending",
                    job.status
                ));
            }
            // ---- per-job retry accounting ----
            if job.retry_iters < 0.0 || !job.retry_iters.is_finite() {
                out.push(format!(
                    "{whence}@{t:.3}: job {i} retry_iters is {}",
                    job.retry_iters
                ));
            }
            if job.retry_backoff_s < 0.0 || !job.retry_backoff_s.is_finite() {
                out.push(format!(
                    "{whence}@{t:.3}: job {i} retry backoff is {}",
                    job.retry_backoff_s
                ));
            } else if job.retry_backoff_s + eps < self.backoff[i] {
                out.push(format!(
                    "{whence}@{t:.3}: job {i} retry backoff shrank \
                     ({} after {}): backoff must be monotone per job",
                    job.retry_backoff_s, self.backoff[i]
                ));
            }
            self.backoff[i] = self.backoff[i].max(job.retry_backoff_s);
            lost_recount += job.lost_iters;
            straggler_recount += job.straggler_iters;
            restarts_recount += u64::from(job.restarts);
            retries_recount += u64::from(job.retries);
            retry_iters_recount += job.retry_iters;
            self.mark[i] = holds;
        }
        if (busy_recount - busy).abs() > eps {
            out.push(format!(
                "{whence}@{t:.3}: busy level {busy} disagrees with job \
                 recount {busy_recount}"
            ));
        }

        // ---- lost-work accounting conserved ----
        let tol = |x: f64| eps * x.abs().max(1.0);
        if (lost_recount - st.total_lost_iters).abs() > tol(lost_recount) {
            out.push(format!(
                "{whence}@{t:.3}: lost-work accounting diverged: per-job \
                 sum {lost_recount} vs cluster total {}",
                st.total_lost_iters
            ));
        }
        if (straggler_recount - st.total_straggler_iters).abs()
            > tol(straggler_recount)
        {
            out.push(format!(
                "{whence}@{t:.3}: straggler accounting diverged: per-job \
                 sum {straggler_recount} vs cluster total {}",
                st.total_straggler_iters
            ));
        }
        if restarts_recount != st.revocations {
            out.push(format!(
                "{whence}@{t:.3}: restart accounting diverged: per-job \
                 sum {restarts_recount} vs {} revocations",
                st.revocations
            ));
        }
        // ---- retry conservation ----
        if retries_recount != st.total_retries {
            out.push(format!(
                "{whence}@{t:.3}: retry accounting diverged: per-job \
                 sum {retries_recount} vs {} total retries",
                st.total_retries
            ));
        }
        if (retry_iters_recount - st.total_retry_iters).abs()
            > tol(retry_iters_recount)
        {
            out.push(format!(
                "{whence}@{t:.3}: retry-work accounting diverged: per-job \
                 sum {retry_iters_recount} vs cluster total {}",
                st.total_retry_iters
            ));
        }
        if st.total_retries < self.last_retries {
            out.push(format!(
                "{whence}@{t:.3}: retry count went backwards \
                 ({} after {})",
                st.total_retries, self.last_retries
            ));
        }
        if st.total_chaos_delay_s < -eps || !st.total_chaos_delay_s.is_finite()
        {
            out.push(format!(
                "{whence}@{t:.3}: chaos delay accumulator is {}",
                st.total_chaos_delay_s
            ));
        }
        if st.total_lost_iters < self.last_lost_iters - eps {
            out.push(format!(
                "{whence}@{t:.3}: total lost work decreased ({} after {})",
                st.total_lost_iters, self.last_lost_iters
            ));
        }
        if st.total_straggler_iters < self.last_straggler_iters - eps {
            out.push(format!(
                "{whence}@{t:.3}: total straggler work decreased \
                 ({} after {})",
                st.total_straggler_iters, self.last_straggler_iters
            ));
        }
        if st.revocations < self.last_revocations {
            out.push(format!(
                "{whence}@{t:.3}: revocation count went backwards \
                 ({} after {})",
                st.revocations, self.last_revocations
            ));
        }

        // ---- per-LLM active index vs from-scratch recount ----
        for llm in Llm::ALL {
            for &id in st.active_jobs(llm) {
                if id >= n {
                    out.push(format!(
                        "{whence}@{t:.3}: active index of {llm:?} holds bad id {id}"
                    ));
                    continue;
                }
                if st.jobs[id].spec.llm != llm {
                    out.push(format!(
                        "{whence}@{t:.3}: job {id} ({:?}) listed under {llm:?}",
                        st.jobs[id].spec.llm
                    ));
                }
                if self.mark[id] {
                    self.mark[id] = false; // seen once
                } else {
                    out.push(format!(
                        "{whence}@{t:.3}: active index of {llm:?} lists job {id}, \
                         which is {:?} (departed or duplicated)",
                        st.jobs[id].status
                    ));
                }
            }
        }
        for (i, &still_marked) in self.mark.iter().enumerate() {
            if still_marked {
                out.push(format!(
                    "{whence}@{t:.3}: job {i} ({:?}) missing from the active index",
                    st.jobs[i].status
                ));
            }
        }

        // ---- monotone sequence numbers / time ----
        let seq = st.event_seq();
        if seq < self.last_seq {
            out.push(format!(
                "{whence}@{t:.3}: event sequence went backwards \
                 ({} after {})",
                seq, self.last_seq
            ));
        }
        if t + eps < self.last_now {
            out.push(format!(
                "{whence}: time went backwards ({t} after {})",
                self.last_now
            ));
        }

        // ---- non-negative incremental cost ----
        for (name, cur, last) in [
            ("billed", st.cost_gpu_s, self.last_cost_gpu_s),
            ("busy", st.busy_gpu_s, self.last_busy_gpu_s),
        ] {
            if !cur.is_finite() {
                out.push(format!(
                    "{whence}@{t:.3}: {name} GPU-second integral is {cur}"
                ));
            } else if cur < last - eps {
                out.push(format!(
                    "{whence}@{t:.3}: negative incremental {name} cost \
                     ({cur} after {last})"
                ));
            }
        }

        self.last_seq = seq;
        self.last_now = t;
        self.last_cost_gpu_s = st.cost_gpu_s;
        self.last_busy_gpu_s = st.busy_gpu_s;
        self.last_lost_iters = st.total_lost_iters;
        self.last_straggler_iters = st.total_straggler_iters;
        self.last_revocations = st.revocations;
        self.last_retries = st.total_retries;
    }

    /// Starved-wake check: may the policy really sleep on `wake` given
    /// the current state? Under batch skipping a hint governs whole
    /// blocks of rounds, so a hint that sleeps past a due action is a
    /// lost wakeup — the run diverges from its dense reference.
    ///
    /// A policy may never sleep past a round where a fresh arrival,
    /// retry expiry, fault, or governor evaluation would have acted. Of
    /// those, only retry expiries are observable from `ClusterState`
    /// alone (`JobState::retry_not_before`): arrivals and accepted
    /// completions are heap events that structurally end a skip batch
    /// and re-query the hint, while fault-plan and governor deadlines
    /// live inside the `FaultInjector`/`Governed` wrappers, which merge
    /// their own wakes via [`Wake::earliest`] and can only make the
    /// inner hint *earlier*. So the check is: every pending job whose
    /// retry backoff expires in the future must be covered by the
    /// declared wake. Pending jobs whose backoff already expired are
    /// waiting on capacity, which only returns through a completion
    /// event — event-driven, hence exempt.
    ///
    /// Associated function (no audit history needed) so both the
    /// immutable [`SimOracle::next_timed_action`] forward path and the
    /// run loop's `debug_oracle` hook can call it.
    pub fn check_wake(st: &ClusterState, wake: Wake, out: &mut Vec<String>) {
        if wake == Wake::Dense {
            return; // ticking every round can never starve anything
        }
        let eps = 1e-9;
        let now = st.now();
        for (i, job) in st.jobs.iter().enumerate() {
            if job.status != JobStatus::Pending {
                continue;
            }
            let due = job.retry_not_before;
            if due <= now + eps {
                continue; // backoff expired: capacity-waiting, event-driven
            }
            match wake {
                Wake::At(w) if w <= due + eps => {}
                Wake::At(w) => out.push(format!(
                    "wake@{now:.3}: policy sleeps to {w:.3} past job {i}'s \
                     retry-backoff expiry at {due:.3} (starved wake)"
                )),
                _ => out.push(format!(
                    "wake@{now:.3}: policy sleeps until the next event \
                     while job {i}'s retry backoff expires at {due:.3} \
                     (starved wake)"
                )),
            }
        }
    }

    /// Tuner-legality audit (`slo::Tuned`). Checks, over a finished
    /// [`TunerLog`] against the declared [`KnobSpec`] lattice and the
    /// incumbent knob values captured before any tuning:
    ///
    /// - every logged value lies inside its knob's declared `[lo, hi]`;
    /// - decisions land only on evaluation-window boundaries: decisions
    ///   sharing a timestamp form one boundary batch, and the window
    ///   index `floor(t / eval_period_s)` strictly increases between
    ///   batches (at most one decision batch per window, never between
    ///   windows);
    /// - `Revert`/`Freeze` decisions restore the incumbent value
    ///   exactly (capacity accounting is conserved — a revert is a
    ///   bit-exact return to the configuration being protected), where
    ///   the incumbent is updated by each `Promote`.
    ///
    /// Associated function like [`StateAudit::check_wake`] so the
    /// tuner's finish path, the bench harness, and tests can all call
    /// it without an audit history.
    pub fn check_tuner(
        log: &TunerLog,
        specs: &[KnobSpec],
        incumbent: &[f64],
        eval_period_s: f64,
        out: &mut Vec<String>,
    ) {
        let eps = 1e-9;
        if incumbent.len() != specs.len() {
            out.push(format!(
                "tuner: incumbent snapshot covers {} knobs but {} are \
                 declared",
                incumbent.len(),
                specs.len()
            ));
            return;
        }
        let mut current: Vec<f64> = incumbent.to_vec();
        let mut last_window: Option<(i64, f64)> = None;
        for d in &log.decisions {
            let Some(k) = specs.iter().position(|s| s.name == d.knob)
            else {
                out.push(format!(
                    "tuner@{:.3}: decision moves undeclared knob {:?}",
                    d.t, d.knob
                ));
                continue;
            };
            let spec = &specs[k];
            if d.value < spec.lo - eps || d.value > spec.hi + eps {
                out.push(format!(
                    "tuner@{:.3}: knob {:?} set to {} outside its \
                     declared lattice [{}, {}]",
                    d.t, d.knob, d.value, spec.lo, spec.hi
                ));
            }
            if eval_period_s > 0.0 {
                let window = (d.t / eval_period_s).floor() as i64;
                match last_window {
                    Some((w, t)) if (d.t - t).abs() <= eps => {
                        // same boundary batch — same window by
                        // construction
                        debug_assert_eq!(w, window);
                    }
                    Some((w, _)) if window <= w => out.push(format!(
                        "tuner@{:.3}: second decision batch inside \
                         evaluation window {w} (knob {:?}) — knob \
                         changes are only legal at window boundaries",
                        d.t, d.knob
                    )),
                    _ => last_window = Some((window, d.t)),
                }
            }
            match d.action {
                TunerAction::Promote => current[k] = d.value,
                TunerAction::Revert | TunerAction::Freeze => {
                    if (d.value - current[k]).abs() > eps {
                        out.push(format!(
                            "tuner@{:.3}: {:?} sets knob {:?} to {} but \
                             the incumbent value is {} — reverts must \
                             conserve the incumbent configuration",
                            d.t, d.action, d.knob, d.value, current[k]
                        ));
                    }
                }
                TunerAction::Explore => {}
            }
        }
    }
}

/// The simulation oracle: wraps any [`Policy`] and runs the full
/// [`StateAudit`] invariant set after every policy callback, plus the
/// starved-wake check ([`StateAudit::check_wake`]) on every wake hint
/// the wrapped policy emits. Strict mode ([`SimOracle::new`]) panics on
/// the first violation with the offending invariant and simulated time;
/// collecting mode ([`SimOracle::collecting`]) records messages for
/// property harnesses to report. The wrapper forwards
/// `next_timed_action` results unchanged, so coalescing behavior (and
/// therefore simulated results) are unchanged — it is a pure observer.
pub struct SimOracle<P: Policy> {
    inner: P,
    audit: StateAudit,
    /// Interior mutability: `next_timed_action` takes `&self` but must
    /// still record starved-wake violations.
    violations: std::cell::RefCell<Vec<String>>,
    panic_on_violation: bool,
}

impl<P: Policy> SimOracle<P> {
    /// Strict oracle: panic on the first violated invariant.
    pub fn new(inner: P) -> Self {
        Self::with_mode(inner, true)
    }

    /// Collecting oracle: record violations in [`SimOracle::violations`].
    pub fn collecting(inner: P) -> Self {
        Self::with_mode(inner, false)
    }

    fn with_mode(inner: P, panic_on_violation: bool) -> Self {
        SimOracle {
            inner,
            audit: StateAudit::new(),
            violations: std::cell::RefCell::new(vec![]),
            panic_on_violation,
        }
    }

    /// Violations recorded so far (owned snapshot: the backing store is
    /// a `RefCell` so the immutable wake-audit path can append too).
    pub fn violations(&self) -> Vec<String> {
        self.violations.borrow().clone()
    }

    /// Number of audits performed (each checks the full invariant set).
    pub fn audits(&self) -> u64 {
        self.audit.audits
    }

    pub fn into_inner(self) -> P {
        self.inner
    }

    fn run_audit(&mut self, st: &ClusterState, whence: &str) {
        let v = self.violations.get_mut();
        let before = v.len();
        self.audit.check(st, whence, v);
        if self.panic_on_violation && v.len() > before {
            panic!(
                "SimOracle[{}]: {}",
                self.inner.name(),
                v[before..].join("; ")
            );
        }
    }
}

impl<P: Policy> Policy for SimOracle<P> {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn tick_interval(&self) -> f64 {
        self.inner.tick_interval()
    }
    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        self.inner.on_arrival(st, job_id);
        self.run_audit(st, "arrival");
    }
    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        self.inner.on_job_complete(st, job_id);
        self.run_audit(st, "complete");
    }
    fn on_tick(&mut self, st: &mut ClusterState) {
        self.inner.on_tick(st);
        self.run_audit(st, "tick");
    }
    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        // No audit here: on_revoke runs mid-fault (the engine lowers the
        // ceiling right after), so the state is legitimately
        // transitional; the post-round audit covers the settled state.
        self.inner.on_revoke(st, ev);
    }
    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        self.inner.on_retry(st, ev);
        self.run_audit(st, "retry");
    }
    fn next_timed_action(&self, st: &ClusterState) -> Wake {
        let wake = self.inner.next_timed_action(st);
        let mut v = self.violations.borrow_mut();
        let before = v.len();
        StateAudit::check_wake(st, wake, &mut v);
        if self.panic_on_violation && v.len() > before {
            panic!(
                "SimOracle[{}]: {}",
                self.inner.name(),
                v[before..].join("; ")
            );
        }
        wake
    }
    fn capacity(&self) -> Option<usize> {
        self.inner.capacity()
    }
    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        self.inner.set_capacity(st, gpus);
        self.run_audit(st, "set_capacity");
    }
    fn knobs(&self) -> Vec<KnobSpec> {
        self.inner.knobs()
    }
    fn knob_value(&self, name: &str) -> Option<f64> {
        self.inner.knob_value(name)
    }
    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        // A knob move can re-bill capacity (capacity-like knobs route
        // through set_capacity machinery) — audit like set_capacity.
        self.inner.set_knob(st, name, value);
        self.run_audit(st, "set_knob");
    }
    fn tuner_report(&self) -> Option<TunerReport> {
        self.inner.tuner_report()
    }
    // Gossip hooks touch only the policy's own bank, never ClusterState,
    // so there is no cluster invariant to audit — forward verbatim.
    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        self.inner.bank_coverage(llm, task_id)
    }
    fn enable_gossip_log(&mut self) {
        self.inner.enable_gossip_log()
    }
    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        self.inner.drain_tuned(out)
    }
    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        self.inner.absorb_tuned(items)
    }
}

/// Outcome of one simulated experiment.
#[derive(Clone, Debug)]
pub struct SimResult {
    pub policy: String,
    pub n_jobs: usize,
    pub n_done: usize,
    pub n_violations: usize,
    /// Total dollar cost (GPU time + storage channel).
    pub cost_usd: f64,
    pub gpu_seconds_billed: f64,
    pub gpu_seconds_busy: f64,
    /// Mean utilization over the billed capacity (Fig 3a).
    pub mean_utilization: f64,
    pub util_timeline: Vec<(f64, f64)>,
    /// Per-job (latency, slo, init_wait, bank_latency) for CDFs.
    pub job_latencies: Vec<(f64, f64, f64, f64)>,
    /// Per-job realized initial-prompt quality, indexed by job id (the
    /// user's own quality for jobs that skipped or never reached the
    /// bank). With the stateful bank this reflects coverage at launch
    /// time, so it exposes warm-up and task-drift recovery curves.
    pub job_quality: Vec<f64>,
    /// Mean realized prompt quality over completed jobs (0 when none).
    pub mean_prompt_quality: f64,
    /// Wall-clock scheduler decision overhead (paper §6.2: 13/67 ms).
    pub sched_overhead_ms_mean: f64,
    pub sched_overhead_ms_max: f64,
    /// Scheduling rounds actually executed (policy `on_tick` calls).
    pub rounds_executed: u64,
    /// Rounds proven idle and batch-skipped by tick coalescing
    /// (`rounds_skipped` in the emitted bench records).
    pub rounds_coalesced: u64,
    /// Discrete heap events processed (arrivals, completions including
    /// stale ones, end-of-horizon) — the O(events) core's unit of work.
    pub events_processed: u64,
    /// Involuntary revocations (fault-engine preemptions) over the run.
    pub revocations: u64,
    /// Iterations lost to restore-from-last-checkpoint over the run.
    pub lost_iters: f64,
    /// Extra iterations added by straggler slowdowns over the run.
    pub straggler_iters: f64,
    /// Failed completions injected by the chaos engine over the run.
    pub retries: u64,
    /// Iterations re-queued by those failed completions.
    pub retry_iters: f64,
    /// Extra seconds of chaos-injected launch / bank-lookup latency.
    pub chaos_delay_s: f64,
    /// Wall-clock seconds for the whole simulated experiment.
    pub wall_s: f64,
}

impl SimResult {
    pub fn violation_rate(&self) -> f64 {
        if self.n_jobs == 0 {
            0.0
        } else {
            self.n_violations as f64 / self.n_jobs as f64
        }
    }

    /// Executed scheduling rounds per wall-clock second (the
    /// BENCH_sim.json throughput metric; includes all event handling).
    pub fn ticks_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.rounds_executed as f64 / self.wall_s
        } else {
            0.0
        }
    }

    /// Heap events processed per wall-clock second — the headline
    /// throughput metric of the batch-skip core (ROADMAP's hyperscale
    /// sweep tracks sim-events/s, which this feeds).
    pub fn events_per_s(&self) -> f64 {
        if self.wall_s > 0.0 {
            self.events_processed as f64 / self.wall_s
        } else {
            0.0
        }
    }
}

/// Drives a [`Policy`] over a trace.
pub struct Simulator {
    pub cfg: SimConfig,
    pub perf: PerfModel,
}

/// `SimConfig::debug_oracle` hook: audit and panic on the first violation
/// (`scratch` stays empty on the happy path, so no per-round allocation).
fn debug_audit(audit: &mut Option<StateAudit>, scratch: &mut Vec<String>,
               st: &ClusterState, whence: &str) {
    if let Some(a) = audit.as_mut() {
        a.check(st, whence, scratch);
        if !scratch.is_empty() {
            panic!("debug sim oracle: {}", scratch.join("; "));
        }
    }
}

/// `SimConfig::debug_oracle` hook for wake hints: every hint the run
/// loop is about to batch-skip on goes through the starved-wake check.
fn debug_wake(audit: &Option<StateAudit>, scratch: &mut Vec<String>,
              st: &ClusterState, wake: Wake) {
    if audit.is_some() {
        StateAudit::check_wake(st, wake, scratch);
        if !scratch.is_empty() {
            panic!("debug sim oracle: {}", scratch.join("; "));
        }
    }
}

impl Simulator {
    pub fn new(cfg: SimConfig, perf: PerfModel) -> Self {
        Simulator { cfg, perf }
    }

    /// Run `policy` over the trace and collect metrics.
    pub fn run(&self, policy: &mut dyn Policy, specs: Vec<JobSpec>) -> SimResult {
        self.run_observed(policy, specs, &mut ())
    }

    /// Like [`Simulator::run`], with a passive [`SimObserver`] attached
    /// to the event stream (online telemetry: SLI windows, burn rates —
    /// see `slo::SloMonitor`). The observer only ever sees post-callback
    /// state immutably, so attaching one cannot change simulated results.
    pub fn run_observed(&self, policy: &mut dyn Policy, specs: Vec<JobSpec>,
                        observer: &mut dyn SimObserver) -> SimResult {
        let wall0 = Instant::now();
        let n_jobs = specs.len();
        let last_arrival =
            specs.iter().map(|s| s.submit_s).fold(0.0f64, f64::max);
        let horizon = last_arrival + self.cfg.horizon_s;
        let mut st = ClusterState::new(self.cfg.clone(), self.perf.clone(), specs);
        let mut heap: BinaryHeap<Event> = BinaryHeap::with_capacity(n_jobs + 2);
        let mut seq = 0u64;
        for (i, job) in st.jobs.iter().enumerate() {
            seq += 1;
            heap.push(Event {
                time: job.spec.submit_s,
                seq,
                kind: EventKind::Arrival(i),
            });
        }
        // The tick stream is managed outside the heap but consumes
        // sequence numbers exactly as the dense heap-resident tick events
        // did, so equal-time ordering against job events is unchanged.
        seq += 1;
        let mut tick_time = 0.0f64;
        let mut tick_seq = seq;
        seq += 1;
        heap.push(Event { time: horizon, seq, kind: EventKind::End });
        st.seq = seq;

        let mut overhead = Accum::new();
        let mut done = 0usize;
        let mut rounds: u64 = 0;
        let mut coalesced: u64 = 0;
        let mut events: u64 = 0;
        let tick = policy.tick_interval();
        let mut wake = Wake::Dense;
        let mut audit = self.cfg.debug_oracle.then(StateAudit::new);
        let mut audit_scratch: Vec<String> = vec![];
        loop {
            // Earliest of (pending tick, heap top) by (time, seq).
            let tick_first = match heap.peek() {
                Some(ev) => (tick_time, tick_seq) < (ev.time, ev.seq),
                None => true,
            };
            if tick_first {
                if tick_time > horizon {
                    break;
                }
                let skip = match wake {
                    Wake::Dense => false,
                    Wake::Idle => true,
                    Wake::At(t) => tick_time < t,
                };
                if skip {
                    // Batch skip: burn through every provably-idle round
                    // strictly before the wake target and the next heap
                    // event in one tight loop — no integration, no
                    // policy query, no heap access. The heap top cannot
                    // change while we skip (only callbacks push events,
                    // and none run here), so the snapshot is stable.
                    // Each skipped round advances `tick_time` by the
                    // same repeated addition dense ticking uses and
                    // consumes the sequence number its next-tick push
                    // would have taken; integration catches up at the
                    // resume point (next event or executed round).
                    let (ev_time, ev_seq) = match heap.peek() {
                        Some(ev) => (ev.time, ev.seq),
                        None => (f64::INFINITY, u64::MAX),
                    };
                    loop {
                        coalesced += 1;
                        st.seq += 1;
                        tick_seq = st.seq;
                        tick_time += tick;
                        if tick_time > horizon
                            || (tick_time, tick_seq) >= (ev_time, ev_seq)
                        {
                            break;
                        }
                        if let Wake::At(t) = wake {
                            if tick_time >= t {
                                break;
                            }
                        }
                    }
                    continue;
                }
                st.integrate_to(tick_time);
                let t0 = Instant::now();
                policy.on_tick(&mut st);
                overhead.add(t0.elapsed().as_secs_f64() * 1e3);
                rounds += 1;
                st.drain_queued(&mut heap);
                debug_audit(&mut audit, &mut audit_scratch, &st, "tick");
                observer.on_round(&st);
                wake = policy.next_timed_action(&st);
                debug_wake(&audit, &mut audit_scratch, &st, wake);
                if done == n_jobs {
                    break;
                }
                // Re-arm the next round: advance by one period (repeated
                // addition, the same float path dense ticking takes) and
                // consume the sequence number its push would have taken.
                st.seq += 1;
                tick_seq = st.seq;
                tick_time += tick;
            } else {
                let ev = match heap.pop() {
                    Some(ev) => ev,
                    None => break,
                };
                if ev.time > horizon {
                    break;
                }
                events += 1;
                st.integrate_to(ev.time);
                match ev.kind {
                    EventKind::Arrival(id) => {
                        policy.on_arrival(&mut st, id);
                        st.drain_queued(&mut heap);
                        debug_audit(&mut audit, &mut audit_scratch, &st,
                                    "arrival");
                        observer.on_arrival(&st, id);
                        wake = policy.next_timed_action(&st);
                        debug_wake(&audit, &mut audit_scratch, &st, wake);
                    }
                    EventKind::JobDone(id, gen) => {
                        let stale = st.jobs[id].gen != gen
                            || st.jobs[id].status == JobStatus::Done;
                        if !stale {
                            let gpus;
                            {
                                let job = &mut st.jobs[id];
                                job.status = JobStatus::Done;
                                job.completed_at = ev.time;
                                job.iters_remaining = 0.0;
                                gpus = job.gpus;
                                job.gpu_seconds =
                                    gpus as f64 * (ev.time - job.launched_at);
                                job.gpus = 0;
                            }
                            st.commit_levels();
                            st.busy_gpus -= gpus as f64;
                            st.deactivate(id);
                            policy.on_job_complete(&mut st, id);
                            st.drain_queued(&mut heap);
                            debug_audit(&mut audit, &mut audit_scratch, &st,
                                        "complete");
                            // The chaos engine may fail the completion
                            // back to Pending inside the callback; the
                            // job then isn't done, and observers (SLO
                            // burn gauges) never see the failed attempt
                            // — each job is sampled exactly once, at its
                            // accepted completion.
                            if st.jobs[id].status == JobStatus::Done {
                                done += 1;
                                observer.on_job_complete(&st, id);
                            }
                            wake = policy.next_timed_action(&st);
                            debug_wake(&audit, &mut audit_scratch, &st, wake);
                            if done == n_jobs {
                                break;
                            }
                        } else {
                            st.drain_queued(&mut heap);
                            // Refresh the wake even though the stale
                            // event mutated nothing: under batch
                            // skipping the hint governs whole blocks of
                            // rounds, so a hint must never outlive an
                            // event pop — even a no-op one. For a pure
                            // `next_timed_action` this re-query returns
                            // the same hint in dense and batch-skip runs
                            // alike (state is unchanged and stale pops
                            // happen at identical times), so equivalence
                            // is preserved; for an impure policy it is
                            // the difference between waking and sleeping
                            // forever.
                            wake = policy.next_timed_action(&st);
                            debug_wake(&audit, &mut audit_scratch, &st, wake);
                        }
                    }
                    EventKind::End => break,
                }
            }
        }
        st.integrate_to(st.now());
        st.commit_levels();
        observer.on_end(&st);

        let n_done = st.jobs.iter().filter(|j| j.status == JobStatus::Done).count();
        let n_violations = st.jobs.iter().filter(|j| !j.met_slo()).count();
        // Bank-state telemetry: realized prompt quality per job. Bank
        // mutation itself happens inside policy callbacks at discrete
        // events (lookups realized at launch, tuned prompts inserted at
        // completion), never in coalesced rounds, so these series are
        // bit-identical under dense and coalesced ticking.
        let mean_prompt_quality = if n_done > 0 {
            st.jobs
                .iter()
                .filter(|j| j.status == JobStatus::Done)
                .map(|j| j.quality)
                .sum::<f64>()
                / n_done as f64
        } else {
            0.0
        };
        let cost_usd = st.cost_gpu_s * GPU_PRICE_PER_S + st.storage_cost;
        let mean_utilization = if st.billable_gpu_s > 0.0 {
            st.busy_gpu_s / st.billable_gpu_s
        } else {
            0.0
        };
        SimResult {
            policy: policy.name().to_string(),
            n_jobs,
            n_done,
            n_violations,
            cost_usd,
            gpu_seconds_billed: st.cost_gpu_s,
            gpu_seconds_busy: st.busy_gpu_s,
            mean_utilization,
            util_timeline: std::mem::take(&mut st.util_timeline),
            job_latencies: st
                .jobs
                .iter()
                .map(|j| (j.latency(), j.spec.slo_s, j.init_wait, j.bank_latency))
                .collect(),
            job_quality: st.jobs.iter().map(|j| j.quality).collect(),
            mean_prompt_quality,
            sched_overhead_ms_mean: overhead.mean(),
            sched_overhead_ms_max: if overhead.n == 0 { 0.0 } else { overhead.max },
            rounds_executed: rounds,
            rounds_coalesced: coalesced,
            events_processed: events,
            revocations: st.revocations,
            lost_iters: st.total_lost_iters,
            straggler_iters: st.total_straggler_iters,
            retries: st.total_retries,
            retry_iters: st.total_retry_iters,
            chaos_delay_s: st.total_chaos_delay_s,
            wall_s: wall0.elapsed().as_secs_f64(),
        }
    }

    /// Run `policy` from a streaming [`TraceSource`] — arrivals are
    /// injected as the stream yields them, so resident trace memory is
    /// O(active jobs) instead of the full trace. Bit-identical to
    /// [`Simulator::run`] on the materialized trace (property-enforced
    /// by `tests/prop_shard.rs` for every scenario family).
    pub fn run_source(&self, policy: &mut dyn Policy,
                      source: &mut dyn TraceSource) -> SimResult {
        self.run_source_observed(policy, source, &mut ())
    }

    /// [`Simulator::run_source`] with a passive [`SimObserver`] attached.
    pub fn run_source_observed(&self, policy: &mut dyn Policy,
                               source: &mut dyn TraceSource,
                               observer: &mut dyn SimObserver) -> SimResult {
        let wall0 = Instant::now();
        let n_total = source.total_jobs();
        let horizon = source.last_arrival_s() + self.cfg.horizon_s;
        let tick = policy.tick_interval();
        let mut core = StreamCore::new(self.cfg.clone(), self.perf.clone(),
                                       tick, n_total, horizon);
        let mut injected = 0u64;
        while let Some(spec) = source.next_job() {
            // The pending arrival's (time, seq) key — seq i+1, exactly
            // the sequence number the materialized loop pre-assigns to
            // arrival i.
            let key = (spec.submit_s, injected + 1);
            let finished = core.advance_until(policy, observer, Some(key));
            debug_assert!(!finished,
                          "stream core finished with arrivals pending");
            if finished {
                break;
            }
            core.inject_arrival(policy, observer, spec);
            injected += 1;
        }
        core.exhaust();
        core.advance_until(policy, observer, None);
        core.finalize(policy, observer, wall0.elapsed().as_secs_f64())
    }
}

// ----------------------------------------------------------- stream core

/// The [`Simulator::run_observed`] state machine, refactored so arrivals
/// are *injected by a caller* instead of pre-loaded into the heap. This
/// is the kernel both streaming entry points share: `run_source` drives
/// one core from a [`TraceSource`], and the shard plane (`crate::shard`)
/// drives N of them in lockstep with a router deciding which core each
/// arrival enters.
///
/// Bit-identity with the materialized loop rests on one observation: an
/// arrival the materialized loop holds in its heap at key `(t, seq)`
/// influences the run *only* through that key — it bounds tick-vs-event
/// ordering and the batch-skip loop. [`StreamCore::advance_until`] takes
/// the pending injection's key as `limit` and folds it into both bounds
/// exactly as the heap top would be, so a not-yet-injected arrival
/// constrains the core identically to a heap-resident one. Every other
/// line is a verbatim transplant of `run_observed`; that loop remains as
/// the executable reference the equivalence properties compare against.
///
/// Event-sequence layout (must match the materialized loop bit-for-bit):
/// arrival `i` of the *global* stream owns seq `i + 1`, the tick stream
/// starts at `n_total + 1`, the end-of-horizon event takes
/// `n_total + 2`, and `ClusterState::event_seq` continues from there. A
/// sharded plane passes the same global `n_total` to every core, so
/// per-shard seqs stay unique and monotone (they are simply sparse).
pub struct StreamCore {
    st: ClusterState,
    heap: BinaryHeap<Event>,
    horizon: f64,
    tick: f64,
    tick_time: f64,
    tick_seq: u64,
    wake: Wake,
    overhead: Accum,
    done: usize,
    admitted: usize,
    /// `done` level at which the run ends: `usize::MAX` while the source
    /// may still yield (matching `done == n_jobs` being unreachable with
    /// arrivals outstanding), the admitted count after [`StreamCore::
    /// exhaust`].
    stop_done: usize,
    rounds: u64,
    coalesced: u64,
    events: u64,
    audit: Option<StateAudit>,
    audit_scratch: Vec<String>,
    finished: bool,
}

impl StreamCore {
    /// A core expecting up to `n_total` injected arrivals (the *global*
    /// stream length — per-shard cores of one plane all take the same
    /// value) over `horizon` seconds, ticking every `tick` seconds.
    pub fn new(cfg: SimConfig, perf: PerfModel, tick: f64, n_total: usize,
               horizon: f64) -> Self {
        let debug_oracle = cfg.debug_oracle;
        let mut st = ClusterState::new(cfg, perf, vec![]);
        let mut heap: BinaryHeap<Event> = BinaryHeap::new();
        // Arrivals own seqs 1..=n_total; replicate the materialized
        // loop's layout for the tick stream and the end event.
        let mut seq = n_total as u64;
        seq += 1;
        let tick_time = 0.0f64;
        let tick_seq = seq;
        seq += 1;
        heap.push(Event { time: horizon, seq, kind: EventKind::End });
        st.seq = seq;
        StreamCore {
            st,
            heap,
            horizon,
            tick,
            tick_time,
            tick_seq,
            wake: Wake::Dense,
            overhead: Accum::new(),
            done: 0,
            admitted: 0,
            stop_done: usize::MAX,
            rounds: 0,
            coalesced: 0,
            events: 0,
            audit: debug_oracle.then(StateAudit::new),
            audit_scratch: vec![],
            finished: false,
        }
    }

    /// Current simulated time.
    pub fn now(&self) -> f64 {
        self.st.now()
    }

    /// Jobs injected so far.
    pub fn admitted(&self) -> usize {
        self.admitted
    }

    /// Jobs completed (accepted completions) so far.
    pub fn done(&self) -> usize {
        self.done
    }

    /// Whether the run has ended (horizon reached or all admitted jobs
    /// done after exhaustion).
    pub fn is_finished(&self) -> bool {
        self.finished
    }

    /// The cluster state (router placement signals: billable/busy
    /// levels, config).
    pub fn state(&self) -> &ClusterState {
        &self.st
    }

    /// Heap events processed so far (arrivals, completions, chaos
    /// deliveries…). Part of the shard executor's score-cache staleness
    /// stamp: a cell whose event count has not moved cannot have
    /// changed its admitted/done/bank state through event callbacks.
    pub fn events_processed(&self) -> u64 {
        self.events
    }

    /// Scheduler rounds actually executed (coalesced idle rounds are
    /// skipped and run no policy code). The second component of the
    /// score-cache staleness stamp: busy/billable levels only move in
    /// executed rounds or event callbacks.
    pub fn rounds_executed(&self) -> u64 {
        self.rounds
    }

    /// Process every tick and heap event with key strictly before
    /// `limit` — the (time, seq) key of the caller's next injection, or
    /// `None` to run to completion. Returns `true` when the run ended,
    /// `false` when it stopped at `limit` (the caller injects now).
    pub fn advance_until(&mut self, policy: &mut dyn Policy,
                         observer: &mut dyn SimObserver,
                         limit: Option<(f64, u64)>) -> bool {
        if self.finished {
            return true;
        }
        loop {
            let heap_key = self.heap.peek().map(|ev| (ev.time, ev.seq));
            // Effective next event: the earlier of the heap top and the
            // pending injection — which stands in for the heap-resident
            // arrival event of the materialized loop.
            let (next_key, at_limit) = match (heap_key, limit) {
                (Some(h), Some(l)) if l < h => (Some(l), true),
                (None, Some(l)) => (Some(l), true),
                (h, _) => (h, false),
            };
            let tick_first = match next_key {
                Some(k) => (self.tick_time, self.tick_seq) < k,
                None => true,
            };
            if tick_first {
                if self.tick_time > self.horizon {
                    self.finished = true;
                    return true;
                }
                let skip = match self.wake {
                    Wake::Dense => false,
                    Wake::Idle => true,
                    Wake::At(t) => self.tick_time < t,
                };
                if skip {
                    let (ev_time, ev_seq) =
                        next_key.unwrap_or((f64::INFINITY, u64::MAX));
                    loop {
                        self.coalesced += 1;
                        self.st.seq += 1;
                        self.tick_seq = self.st.seq;
                        self.tick_time += self.tick;
                        if self.tick_time > self.horizon
                            || (self.tick_time, self.tick_seq)
                                >= (ev_time, ev_seq)
                        {
                            break;
                        }
                        if let Wake::At(t) = self.wake {
                            if self.tick_time >= t {
                                break;
                            }
                        }
                    }
                    continue;
                }
                self.st.integrate_to(self.tick_time);
                let t0 = Instant::now();
                policy.on_tick(&mut self.st);
                self.overhead.add(t0.elapsed().as_secs_f64() * 1e3);
                self.rounds += 1;
                self.st.drain_queued(&mut self.heap);
                debug_audit(&mut self.audit, &mut self.audit_scratch,
                            &self.st, "tick");
                observer.on_round(&self.st);
                self.wake = policy.next_timed_action(&self.st);
                debug_wake(&self.audit, &mut self.audit_scratch, &self.st,
                           self.wake);
                if self.done == self.stop_done {
                    self.finished = true;
                    return true;
                }
                self.st.seq += 1;
                self.tick_seq = self.st.seq;
                self.tick_time += self.tick;
            } else if at_limit {
                return false;
            } else {
                let ev = match self.heap.pop() {
                    Some(ev) => ev,
                    None => {
                        self.finished = true;
                        return true;
                    }
                };
                if ev.time > self.horizon {
                    self.finished = true;
                    return true;
                }
                self.events += 1;
                self.st.integrate_to(ev.time);
                match ev.kind {
                    EventKind::Arrival(_) => {
                        unreachable!("stream-core arrivals are injected, \
                                      never heap events")
                    }
                    EventKind::JobDone(id, gen) => {
                        let stale = self.st.jobs[id].gen != gen
                            || self.st.jobs[id].status == JobStatus::Done;
                        if !stale {
                            let gpus;
                            {
                                let job = &mut self.st.jobs[id];
                                job.status = JobStatus::Done;
                                job.completed_at = ev.time;
                                job.iters_remaining = 0.0;
                                gpus = job.gpus;
                                job.gpu_seconds = gpus as f64
                                    * (ev.time - job.launched_at);
                                job.gpus = 0;
                            }
                            self.st.commit_levels();
                            self.st.busy_gpus -= gpus as f64;
                            self.st.deactivate(id);
                            policy.on_job_complete(&mut self.st, id);
                            self.st.drain_queued(&mut self.heap);
                            debug_audit(&mut self.audit,
                                        &mut self.audit_scratch, &self.st,
                                        "complete");
                            if self.st.jobs[id].status == JobStatus::Done {
                                self.done += 1;
                                observer.on_job_complete(&self.st, id);
                            }
                            self.wake = policy.next_timed_action(&self.st);
                            debug_wake(&self.audit, &mut self.audit_scratch,
                                       &self.st, self.wake);
                            if self.done == self.stop_done {
                                self.finished = true;
                                return true;
                            }
                        } else {
                            self.st.drain_queued(&mut self.heap);
                            // See run_observed: a wake hint must never
                            // outlive an event pop, even a no-op one.
                            self.wake = policy.next_timed_action(&self.st);
                            debug_wake(&self.audit, &mut self.audit_scratch,
                                       &self.st, self.wake);
                        }
                    }
                    EventKind::End => {
                        self.finished = true;
                        return true;
                    }
                }
            }
        }
    }

    /// Inject the arrival [`StreamCore::advance_until`] just stopped at.
    /// The spec's id is re-assigned to the next dense local index (for a
    /// single-cluster run of a finalized trace this is the id the spec
    /// already carries). Verbatim the materialized loop's arrival branch,
    /// with the job-table entry created here instead of at construction.
    pub fn inject_arrival(&mut self, policy: &mut dyn Policy,
                          observer: &mut dyn SimObserver, mut spec: JobSpec) {
        debug_assert!(!self.finished);
        debug_assert!(spec.submit_s + 1e-12 >= self.st.now(),
                      "arrival at {} injected after t={}", spec.submit_s,
                      self.st.now());
        let id = self.st.jobs.len();
        spec.id = id;
        let submit = spec.submit_s;
        self.st.jobs.push(JobState::new(spec));
        self.st.active_pos.push(usize::MAX);
        self.admitted += 1;
        self.events += 1;
        self.st.integrate_to(submit);
        policy.on_arrival(&mut self.st, id);
        self.st.drain_queued(&mut self.heap);
        debug_audit(&mut self.audit, &mut self.audit_scratch, &self.st,
                    "arrival");
        observer.on_arrival(&self.st, id);
        self.wake = policy.next_timed_action(&self.st);
        debug_wake(&self.audit, &mut self.audit_scratch, &self.st,
                   self.wake);
    }

    /// Declare the arrival stream exhausted: the run now ends when every
    /// admitted job is done — the streaming equivalent of the
    /// materialized loop's `done == n_jobs`, which likewise only fires
    /// once no arrival is outstanding.
    pub fn exhaust(&mut self) {
        self.stop_done = self.admitted;
    }

    /// Final integration and metric extraction (the tail of
    /// `run_observed`), consuming the core.
    pub fn finalize(mut self, policy: &dyn Policy,
                    observer: &mut dyn SimObserver, wall_s: f64) -> SimResult {
        let st = &mut self.st;
        st.integrate_to(st.now());
        st.commit_levels();
        observer.on_end(st);

        let n_done =
            st.jobs.iter().filter(|j| j.status == JobStatus::Done).count();
        let n_violations = st.jobs.iter().filter(|j| !j.met_slo()).count();
        let mean_prompt_quality = if n_done > 0 {
            st.jobs
                .iter()
                .filter(|j| j.status == JobStatus::Done)
                .map(|j| j.quality)
                .sum::<f64>()
                / n_done as f64
        } else {
            0.0
        };
        let cost_usd = st.cost_gpu_s * GPU_PRICE_PER_S + st.storage_cost;
        let mean_utilization = if st.billable_gpu_s > 0.0 {
            st.busy_gpu_s / st.billable_gpu_s
        } else {
            0.0
        };
        SimResult {
            policy: policy.name().to_string(),
            n_jobs: st.jobs.len(),
            n_done,
            n_violations,
            cost_usd,
            gpu_seconds_billed: st.cost_gpu_s,
            gpu_seconds_busy: st.busy_gpu_s,
            mean_utilization,
            util_timeline: std::mem::take(&mut st.util_timeline),
            job_latencies: st
                .jobs
                .iter()
                .map(|j| (j.latency(), j.spec.slo_s, j.init_wait,
                          j.bank_latency))
                .collect(),
            job_quality: st.jobs.iter().map(|j| j.quality).collect(),
            mean_prompt_quality,
            sched_overhead_ms_mean: self.overhead.mean(),
            sched_overhead_ms_max: if self.overhead.n == 0 {
                0.0
            } else {
                self.overhead.max
            },
            rounds_executed: self.rounds,
            rounds_coalesced: self.coalesced,
            events_processed: self.events,
            revocations: st.revocations,
            lost_iters: st.total_lost_iters,
            straggler_iters: st.total_straggler_iters,
            retries: st.total_retries,
            retry_iters: st.total_retry_iters,
            chaos_delay_s: st.total_chaos_delay_s,
            wall_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Llm;

    fn spec(id: usize, submit: f64, iters: f64) -> JobSpec {
        JobSpec {
            id,
            llm: Llm::Gpt2B,
            task_id: 0,
            submit_s: submit,
            duration_s: iters * 0.12,
            traced_gpus: 1,
            base_iters: iters,
            user_prompt_quality: 1.0, // multiplier 1 => deterministic time
            slo_s: 1e9,
            // qual 1.0 so iters_at == base_iters
        }
    }

    /// Greedy test policy: run every arrival immediately on 1 GPU.
    struct Greedy {
        billable: f64,
    }
    impl Policy for Greedy {
        fn name(&self) -> &str {
            "greedy"
        }
        fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
            self.billable += 1.0;
            st.set_billable(self.billable);
            st.launch(id, 1, 0.0, 0.0, 1.0);
        }
        fn on_job_complete(&mut self, st: &mut ClusterState, _id: usize) {
            self.billable -= 1.0;
            st.set_billable(self.billable);
        }
        fn on_tick(&mut self, _st: &mut ClusterState) {}
    }

    #[test]
    fn single_job_completes_at_exact_time() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = Greedy { billable: 0.0 };
        let res = sim.run(&mut p, vec![spec(0, 5.0, 100.0)]);
        assert_eq!(res.n_done, 1);
        assert_eq!(res.n_violations, 0);
        let (lat, _, _, _) = res.job_latencies[0];
        // 100 iters × 0.12 s = 12 s
        assert!((lat - 12.0).abs() < 1e-6, "{lat}");
    }

    #[test]
    fn init_delay_postpones_completion_and_counts() {
        struct Delayed;
        impl Policy for Delayed {
            fn name(&self) -> &str {
                "delayed"
            }
            fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
                st.set_billable(1.0);
                st.launch(id, 1, 3.0, 2.0, 1.0);
            }
            fn on_job_complete(&mut self, st: &mut ClusterState, _id: usize) {
                st.set_billable(0.0);
            }
            fn on_tick(&mut self, _st: &mut ClusterState) {}
        }
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let res = sim.run(&mut Delayed, vec![spec(0, 0.0, 100.0)]);
        let (lat, _, init_wait, bank) = res.job_latencies[0];
        assert!((lat - 17.0).abs() < 1e-6, "{lat}"); // 3 + 2 + 12
        assert!((init_wait - 3.0).abs() < 1e-12);
        assert!((bank - 2.0).abs() < 1e-12);
    }

    #[test]
    fn cost_integration_matches_busy_time() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = Greedy { billable: 0.0 };
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0), spec(1, 0.0, 50.0)]);
        // job0: 12 gpu-s, job1: 6 gpu-s, billable == busy for greedy
        assert!((res.gpu_seconds_billed - 18.0).abs() < 1e-6,
                "{}", res.gpu_seconds_billed);
        assert!((res.gpu_seconds_busy - 18.0).abs() < 1e-6);
        assert!((res.mean_utilization - 1.0).abs() < 1e-9);
        assert!(res.cost_usd > 0.0);
    }

    #[test]
    fn quality_scales_iterations() {
        struct LowQ;
        impl Policy for LowQ {
            fn name(&self) -> &str {
                "lowq"
            }
            fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
                st.set_billable(1.0);
                st.launch(id, 1, 0.0, 0.0, 0.0);
            }
            fn on_job_complete(&mut self, _st: &mut ClusterState, _id: usize) {}
            fn on_tick(&mut self, _st: &mut ClusterState) {}
        }
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut s = spec(0, 0.0, 100.0);
        s.user_prompt_quality = 0.0; // multiplier 4.5
        let res = sim.run(&mut LowQ, vec![s]);
        let (lat, _, _, _) = res.job_latencies[0];
        assert!((lat - 12.0 * 4.5).abs() < 1e-6, "{lat}");
    }

    #[test]
    fn realloc_speeds_up_remaining_work() {
        struct Boost {
            boosted: bool,
        }
        impl Policy for Boost {
            fn name(&self) -> &str {
                "boost"
            }
            fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
                st.set_billable(4.0);
                st.launch(id, 1, 0.0, 0.0, 1.0);
            }
            fn on_job_complete(&mut self, _st: &mut ClusterState, _id: usize) {}
            fn on_tick(&mut self, st: &mut ClusterState) {
                if !self.boosted && st.now() >= 6.0 {
                    self.boosted = true;
                    st.realloc(0, 4, 0.0);
                }
            }
        }
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let res = sim.run(&mut Boost { boosted: false }, vec![spec(0, 0.0, 100.0)]);
        let (lat, _, _, _) = res.job_latencies[0];
        // ~6 s at 1 GPU (50 iters), remaining 50 iters at 4 GPUs ≈ 1.52 s
        assert!(lat < 8.0, "{lat}");
        assert!(lat > 7.0, "{lat}");
        assert_eq!(res.n_done, 1);
    }

    #[test]
    fn unfinished_jobs_count_as_violations() {
        struct Never;
        impl Policy for Never {
            fn name(&self) -> &str {
                "never"
            }
            fn on_arrival(&mut self, _st: &mut ClusterState, _id: usize) {}
            fn on_job_complete(&mut self, _st: &mut ClusterState, _id: usize) {}
            fn on_tick(&mut self, _st: &mut ClusterState) {}
        }
        let cfg = SimConfig { horizon_s: 50.0, ..Default::default() };
        let sim = Simulator::new(cfg, PerfModel::default());
        let res = sim.run(&mut Never, vec![spec(0, 0.0, 100.0)]);
        assert_eq!(res.n_done, 0);
        assert_eq!(res.n_violations, 1);
        assert_eq!(res.violation_rate(), 1.0);
    }

    #[test]
    fn stale_completion_events_ignored_after_realloc() {
        struct ReallocEarly {
            done: bool,
        }
        impl Policy for ReallocEarly {
            fn name(&self) -> &str {
                "re"
            }
            fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
                st.set_billable(2.0);
                st.launch(id, 1, 0.0, 0.0, 1.0);
            }
            fn on_job_complete(&mut self, _st: &mut ClusterState, _id: usize) {
                assert!(!self.done, "double completion");
                self.done = true;
            }
            fn on_tick(&mut self, st: &mut ClusterState) {
                if st.now() >= 1.0 && st.now() < 1.1 && st.jobs[0].gpus == 1 {
                    st.realloc(0, 2, 0.0);
                }
            }
        }
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let res = sim.run(&mut ReallocEarly { done: false }, vec![spec(0, 0.0, 100.0)]);
        assert_eq!(res.n_done, 1);
    }

    #[test]
    fn ticks_fire_at_interval() {
        struct CountTicks {
            n: usize,
        }
        impl Policy for CountTicks {
            fn name(&self) -> &str {
                "ticks"
            }
            fn tick_interval(&self) -> f64 {
                1.0
            }
            fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
                st.launch(id, 1, 0.0, 0.0, 1.0);
            }
            fn on_job_complete(&mut self, _st: &mut ClusterState, _id: usize) {}
            fn on_tick(&mut self, _st: &mut ClusterState) {
                self.n += 1;
            }
        }
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = CountTicks { n: 0 };
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0)]);
        assert_eq!(res.n_done, 1);
        // 12 s of work, 1 s ticks => ~12 ticks observed
        assert!((11..=14).contains(&p.n), "{}", p.n);
        assert_eq!(res.rounds_executed, p.n as u64);
        assert_eq!(res.rounds_coalesced, 0);
    }

    #[test]
    fn utilization_timeline_sampled() {
        let sim = Simulator::new(
            SimConfig { util_sample_s: 1.0, ..Default::default() },
            PerfModel::default(),
        );
        let mut p = Greedy { billable: 0.0 };
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0)]);
        assert!(res.util_timeline.len() >= 10);
    }

    #[test]
    fn scheduler_overhead_measured() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = Greedy { billable: 0.0 };
        let res = sim.run(&mut p, vec![spec(0, 0.0, 10.0)]);
        assert!(res.sched_overhead_ms_mean >= 0.0);
        assert!(res.sched_overhead_ms_max >= res.sched_overhead_ms_mean);
    }

    /// Greedy launch-at-arrival policy that (correctly) declares itself
    /// idle between events: its rounds do nothing.
    struct LazyGreedy {
        ticks: usize,
    }
    impl Policy for LazyGreedy {
        fn name(&self) -> &str {
            "lazy"
        }
        fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
            st.set_billable(st.billable() + 1.0);
            st.launch(id, 1, 0.0, 0.0, 1.0);
        }
        fn on_job_complete(&mut self, st: &mut ClusterState, _id: usize) {
            st.set_billable(st.billable() - 1.0);
        }
        fn on_tick(&mut self, _st: &mut ClusterState) {
            self.ticks += 1;
        }
        fn next_timed_action(&self, _st: &ClusterState) -> Wake {
            Wake::Idle
        }
    }

    #[test]
    fn coalescing_skips_idle_rounds_with_identical_metrics() {
        let specs = vec![spec(0, 0.0, 100.0), spec(1, 3.0, 50.0)];
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut dense = Greedy { billable: 0.0 };
        let ref_res = sim.run(&mut dense, specs.clone());
        let mut lazy = LazyGreedy { ticks: 0 };
        let res = sim.run(&mut lazy, specs);
        // every 50 ms round over the ~12 s busy window was coalesced
        assert_eq!(lazy.ticks, 0);
        assert_eq!(res.rounds_executed, 0);
        assert!(res.rounds_coalesced > 100, "{}", res.rounds_coalesced);
        // metrics bit-identical to the dense reference
        assert_eq!(res.n_done, ref_res.n_done);
        assert_eq!(res.n_violations, ref_res.n_violations);
        assert_eq!(res.cost_usd, ref_res.cost_usd);
        assert_eq!(res.gpu_seconds_billed, ref_res.gpu_seconds_billed);
        assert_eq!(res.util_timeline, ref_res.util_timeline);
        assert_eq!(res.job_latencies, ref_res.job_latencies);
    }

    #[test]
    fn wake_at_resumes_on_the_tick_grid() {
        struct WakeLater {
            acted_at: Option<f64>,
        }
        impl Policy for WakeLater {
            fn name(&self) -> &str {
                "wakelater"
            }
            fn on_arrival(&mut self, _st: &mut ClusterState, _id: usize) {}
            fn on_job_complete(&mut self, _st: &mut ClusterState, _id: usize) {}
            fn on_tick(&mut self, st: &mut ClusterState) {
                if self.acted_at.is_none() && st.now() >= 0.9999 {
                    self.acted_at = Some(st.now());
                    st.set_billable(1.0);
                    st.launch(0, 1, 0.0, 0.0, 1.0);
                }
            }
            fn next_timed_action(&self, _st: &ClusterState) -> Wake {
                if self.acted_at.is_none() {
                    Wake::At(1.0)
                } else {
                    Wake::Idle
                }
            }
        }
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = WakeLater { acted_at: None };
        let res = sim.run(&mut p, vec![spec(0, 0.0, 10.0)]);
        assert_eq!(res.n_done, 1);
        let t = p.acted_at.expect("policy never woke");
        // first 50 ms grid point at/after 1.0
        assert!((0.9999..1.1).contains(&t), "{t}");
        // the ~20 rounds before the wake were skipped (a dense run would
        // have executed them all)
        assert!(res.rounds_coalesced >= 15, "{}", res.rounds_coalesced);
        assert!(res.rounds_executed <= 5, "{}", res.rounds_executed);
    }

    /// Rogue policy for the oracle self-test: bills one GPU but grants
    /// one GPU to *every* arrival, over-committing the capacity.
    struct OverCommit;
    impl Policy for OverCommit {
        fn name(&self) -> &str {
            "overcommit"
        }
        fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
            st.set_billable(1.0);
            st.launch(id, 1, 0.0, 0.0, 1.0);
        }
        fn on_job_complete(&mut self, _st: &mut ClusterState, _id: usize) {}
        fn on_tick(&mut self, _st: &mut ClusterState) {}
    }

    #[test]
    fn oracle_passes_a_compliant_policy() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = SimOracle::new(Greedy { billable: 0.0 });
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0), spec(1, 3.0, 50.0)]);
        assert_eq!(res.n_done, 2);
        assert!(p.violations().is_empty());
        // every arrival, completion and executed round was audited
        assert!(p.audits() >= 4, "{}", p.audits());
    }

    #[test]
    fn oracle_catches_injected_capacity_violation() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = SimOracle::collecting(OverCommit);
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0), spec(1, 0.0, 100.0)]);
        assert_eq!(res.n_done, 2); // the rogue run itself still completes
        assert!(
            p.violations().iter().any(|v| v.contains("capacity conservation")),
            "expected a capacity violation, got {:?}",
            p.violations()
        );
    }

    #[test]
    #[should_panic(expected = "SimOracle")]
    fn strict_oracle_panics_on_violation() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = SimOracle::new(OverCommit);
        sim.run(&mut p, vec![spec(0, 0.0, 100.0), spec(1, 0.0, 100.0)]);
    }

    #[test]
    fn oracle_does_not_perturb_results() {
        let specs = vec![spec(0, 0.0, 100.0), spec(1, 2.0, 50.0)];
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut plain = LazyGreedy { ticks: 0 };
        let ref_res = sim.run(&mut plain, specs.clone());
        let mut wrapped = SimOracle::new(LazyGreedy { ticks: 0 });
        let res = sim.run(&mut wrapped, specs);
        assert_eq!(res.n_done, ref_res.n_done);
        assert_eq!(res.cost_usd, ref_res.cost_usd);
        assert_eq!(res.job_latencies, ref_res.job_latencies);
        // coalescing hints pass through the wrapper untouched
        assert_eq!(res.rounds_coalesced, ref_res.rounds_coalesced);
        assert_eq!(res.rounds_executed, ref_res.rounds_executed);
    }

    #[test]
    fn debug_oracle_flag_audits_in_the_run_loop() {
        let cfg = SimConfig { debug_oracle: true, ..Default::default() };
        let sim = Simulator::new(cfg, PerfModel::default());
        let mut p = Greedy { billable: 0.0 };
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0)]);
        assert_eq!(res.n_done, 1); // clean run: no panic
    }

    #[test]
    #[should_panic(expected = "debug sim oracle")]
    fn debug_oracle_flag_catches_violations() {
        let cfg = SimConfig { debug_oracle: true, ..Default::default() };
        let sim = Simulator::new(cfg, PerfModel::default());
        sim.run(&mut OverCommit, vec![spec(0, 0.0, 100.0), spec(1, 0.0, 100.0)]);
    }

    #[test]
    fn boxed_policies_forward_through_the_trait() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let boxed: Box<dyn Policy> = Box::new(Greedy { billable: 0.0 });
        let mut p = SimOracle::new(boxed);
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0)]);
        assert_eq!(res.n_done, 1);
        assert_eq!(res.policy, "greedy");
    }

    #[test]
    fn observer_sees_the_event_stream_without_perturbing_results() {
        #[derive(Default)]
        struct Count {
            arrivals: usize,
            completions: usize,
            rounds: usize,
            ended: usize,
        }
        impl SimObserver for Count {
            fn on_arrival(&mut self, _st: &ClusterState, _id: usize) {
                self.arrivals += 1;
            }
            fn on_job_complete(&mut self, st: &ClusterState, id: usize) {
                assert_eq!(st.jobs[id].status, JobStatus::Done);
                self.completions += 1;
            }
            fn on_round(&mut self, _st: &ClusterState) {
                self.rounds += 1;
            }
            fn on_end(&mut self, st: &ClusterState) {
                assert!(st.now() >= 0.0);
                self.ended += 1;
            }
        }
        let specs = vec![spec(0, 0.0, 100.0), spec(1, 3.0, 50.0)];
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut plain = Greedy { billable: 0.0 };
        let ref_res = sim.run(&mut plain, specs.clone());
        let mut obs = Count::default();
        let mut p = Greedy { billable: 0.0 };
        let res = sim.run_observed(&mut p, specs, &mut obs);
        assert_eq!(obs.arrivals, 2);
        assert_eq!(obs.completions, 2);
        assert_eq!(obs.rounds as u64, res.rounds_executed);
        assert_eq!(obs.ended, 1);
        // attaching an observer cannot change simulated results
        assert_eq!(res.cost_usd, ref_res.cost_usd);
        assert_eq!(res.job_latencies, ref_res.job_latencies);
    }

    /// Test policy for the fault-engine primitives: launches arrivals on
    /// one GPU, revokes (or slows) job 0 at the first round at/after
    /// t = 5 s (recording the exact round time — the accumulated 50 ms
    /// grid does not land on 5.0 exactly), and relaunches revoked jobs
    /// on the following round.
    struct FaultDriver {
        /// revoke graceful flag, or None to apply a straggler slowdown.
        graceful: Option<bool>,
        acted_at: Option<f64>,
        requeued: Vec<usize>,
    }
    impl FaultDriver {
        fn revoke(graceful: bool) -> Self {
            FaultDriver { graceful: Some(graceful), acted_at: None,
                          requeued: vec![] }
        }
        fn straggle() -> Self {
            FaultDriver { graceful: None, acted_at: None, requeued: vec![] }
        }
    }
    impl Policy for FaultDriver {
        fn name(&self) -> &str {
            "faultdriver"
        }
        fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
            st.set_checkpoint_model(Some(CheckpointModel {
                period_s: 2.0,
                overhead_s: 0.0, // slowdown 1.0: keep the timing math exact
                restore_s: 3.0,
            }));
            st.set_billable(st.billable() + 1.0);
            st.launch(id, 1, 0.0, 0.0, 1.0);
        }
        fn on_job_complete(&mut self, st: &mut ClusterState, _id: usize) {
            st.set_billable(st.billable() - 1.0);
        }
        fn on_tick(&mut self, st: &mut ClusterState) {
            if self.acted_at.is_none() && st.now() >= 5.0 {
                self.acted_at = Some(st.now());
                match self.graceful {
                    Some(graceful) => {
                        st.set_revoked(1.0);
                        st.revoke_job(0, graceful);
                        st.set_billable(st.billable() - 1.0);
                        self.requeued.push(0);
                    }
                    None => st.slow_job(0, 2.0),
                }
            } else if let Some(id) = self.requeued.pop() {
                // repaired: the GPU returns and the job restores
                st.set_revoked(0.0);
                st.set_billable(st.billable() + 1.0);
                st.launch(id, 1, 0.0, 0.0, 1.0);
            }
        }
    }

    #[test]
    fn revoked_job_restores_from_checkpoint_and_completes() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = SimOracle::new(FaultDriver::revoke(false));
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0)]);
        assert_eq!(res.n_done, 1);
        assert_eq!(res.revocations, 1);
        let t = p.into_inner().acted_at.expect("never revoked");
        assert!((5.0..5.2).contains(&t), "{t}");
        // 2 s checkpoint period: work past the last checkpoint is lost
        let ckpt_t = (t / 2.0).floor() * 2.0;
        assert!((res.lost_iters - (t - ckpt_t) / 0.12).abs() < 1e-6,
                "{} at t={t}", res.lost_iters);
        // relaunch one round later + 3 s restore + re-run from the
        // checkpoint: total latency = (t + 0.05) + 3 + (12 - ckpt_t)
        let (lat, _, init_wait, _) = res.job_latencies[0];
        assert!((lat - (t + 0.05 + 3.0 + 12.0 - ckpt_t)).abs() < 1e-6,
                "{lat} at t={t}");
        assert!((init_wait - 3.0).abs() < 1e-9, "{init_wait}");
    }

    #[test]
    fn graceful_revocation_checkpoints_and_loses_no_work() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = SimOracle::new(FaultDriver::revoke(true));
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0)]);
        assert_eq!(res.n_done, 1);
        assert_eq!(res.revocations, 1);
        assert_eq!(res.lost_iters, 0.0);
        let t = p.into_inner().acted_at.expect("never revoked");
        // relaunch one round later + 3 s restore + exactly the work that
        // was left at t: latency = (t + 0.05) + 3 + (12 - t) = 15.05
        let (lat, _, _, _) = res.job_latencies[0];
        assert!((lat - 15.05).abs() < 1e-6, "{lat} at t={t}");
    }

    #[test]
    fn straggler_slowdown_inflates_remaining_work() {
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = SimOracle::new(FaultDriver::straggle());
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0)]);
        assert_eq!(res.n_done, 1);
        assert_eq!(res.revocations, 0);
        let t = p.into_inner().acted_at.expect("never slowed");
        // at t the job has (12 - t)/0.12 iters left; 2× doubles them
        let remaining = (12.0 - t) / 0.12;
        assert!((res.straggler_iters - remaining).abs() < 1e-6,
                "{} at t={t}", res.straggler_iters);
        let (lat, _, _, _) = res.job_latencies[0];
        assert!((lat - (24.0 - t)).abs() < 1e-6, "{lat} at t={t}");
    }

    #[test]
    fn checkpoint_slowdown_stretches_execution() {
        struct Slowed;
        impl Policy for Slowed {
            fn name(&self) -> &str {
                "slowed"
            }
            fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
                st.set_checkpoint_model(Some(CheckpointModel {
                    period_s: 10.0,
                    overhead_s: 1.0, // 10 % amortized overhead
                    restore_s: 0.0,
                }));
                st.set_billable(1.0);
                st.launch(id, 1, 0.0, 0.0, 1.0);
            }
            fn on_job_complete(&mut self, _st: &mut ClusterState, _id: usize) {}
            fn on_tick(&mut self, _st: &mut ClusterState) {}
        }
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let res = sim.run(&mut Slowed, vec![spec(0, 0.0, 100.0)]);
        let (lat, _, _, _) = res.job_latencies[0];
        assert!((lat - 12.0 * 1.1).abs() < 1e-6, "{lat}");
    }

    #[test]
    fn audit_catches_regrant_of_revoked_capacity() {
        // Rogue policy: declares 16 of the 32 budget GPUs revoked but
        // keeps billing 20 — the "revoked GPUs never re-granted before
        // repair" invariant must fire.
        struct Regrant;
        impl Policy for Regrant {
            fn name(&self) -> &str {
                "regrant"
            }
            fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
                st.set_revoked(16.0);
                st.set_billable(20.0);
                st.launch(id, 1, 0.0, 0.0, 1.0);
            }
            fn on_job_complete(&mut self, _st: &mut ClusterState, _id: usize) {}
            fn on_tick(&mut self, _st: &mut ClusterState) {}
        }
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = SimOracle::collecting(Regrant);
        sim.run(&mut p, vec![spec(0, 0.0, 100.0)]);
        assert!(
            p.violations().iter().any(|v| v.contains("re-granted")),
            "expected a revoked-capacity violation, got {:?}",
            p.violations()
        );
    }

    #[test]
    fn wake_earliest_combinator() {
        assert_eq!(Wake::earliest(Wake::Dense, Wake::Idle), Wake::Dense);
        assert_eq!(Wake::earliest(Wake::At(3.0), Wake::Dense), Wake::Dense);
        assert_eq!(Wake::earliest(Wake::Idle, Wake::At(2.0)), Wake::At(2.0));
        assert_eq!(Wake::earliest(Wake::At(5.0), Wake::At(2.0)), Wake::At(2.0));
        assert_eq!(Wake::earliest(Wake::Idle, Wake::Idle), Wake::Idle);
    }

    #[test]
    fn active_index_tracks_gpu_holding_jobs() {
        struct Probe {
            seen_active: bool,
        }
        impl Policy for Probe {
            fn name(&self) -> &str {
                "probe"
            }
            fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
                assert!(!st.active_jobs(Llm::Gpt2B).contains(&id));
                st.set_billable(1.0);
                st.launch(id, 1, 0.0, 0.0, 1.0);
                assert!(st.active_jobs(Llm::Gpt2B).contains(&id));
                assert!(st.active_jobs(Llm::V7B).is_empty());
            }
            fn on_job_complete(&mut self, st: &mut ClusterState, id: usize) {
                assert!(!st.active_jobs(Llm::Gpt2B).contains(&id));
            }
            fn on_tick(&mut self, st: &mut ClusterState) {
                if !st.active_jobs(Llm::Gpt2B).is_empty() {
                    self.seen_active = true;
                }
            }
        }
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = Probe { seen_active: false };
        let res = sim.run(&mut p, vec![spec(0, 0.0, 100.0), spec(1, 1.0, 50.0)]);
        assert_eq!(res.n_done, 2);
        assert!(p.seen_active);
    }

    /// Chaos-style retry driver for the stale-`JobDone` regression test:
    /// fails job 0's first completion back to Pending with a 1 s
    /// backoff, relaunches at the backoff expiry, then slows the relaunch
    /// mid-flight — the gen bump leaves the relaunch's completion event
    /// stale in the heap, and it pops while the policy sleeps on
    /// `Wake::Idle`. With `dense` set, the same policy runs on the dense
    /// grid as the bit-identity reference.
    struct ChaosRetry {
        dense: bool,
        failed: bool,
        /// Relaunch not-before (the failed completion's backoff expiry).
        holdback: Option<f64>,
        /// When to apply the mid-flight slowdown that stales the event.
        slow_at: Option<f64>,
    }
    impl ChaosRetry {
        fn new(dense: bool) -> Self {
            ChaosRetry { dense, failed: false, holdback: None, slow_at: None }
        }
    }
    impl Policy for ChaosRetry {
        fn name(&self) -> &str {
            "chaosretry"
        }
        fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
            st.set_billable(2.0);
            st.launch(id, 1, 0.0, 0.0, 1.0);
        }
        fn on_job_complete(&mut self, st: &mut ClusterState, id: usize) {
            if !self.failed {
                self.failed = true;
                st.fail_completion(id, 20.0, 1.0);
                self.holdback = Some(st.now() + 1.0);
            }
        }
        fn on_tick(&mut self, st: &mut ClusterState) {
            if let Some(t) = self.holdback {
                if st.now() >= t {
                    self.holdback = None;
                    st.launch(0, 1, 0.0, 0.0, 1.0);
                    self.slow_at = Some(st.now() + 0.3);
                }
            } else if let Some(t) = self.slow_at {
                if st.now() >= t {
                    self.slow_at = None;
                    // gen bump mid-flight: the relaunch's completion
                    // event in the heap goes stale
                    st.slow_job(0, 1.5);
                }
            }
        }
        fn next_timed_action(&self, _st: &ClusterState) -> Wake {
            if self.dense {
                return Wake::Dense;
            }
            if let Some(t) = self.holdback {
                return Wake::At(t); // == job 0's retry_not_before
            }
            if let Some(t) = self.slow_at {
                return Wake::At(t);
            }
            Wake::Idle
        }
    }

    #[test]
    fn stale_event_mid_sleep_matches_dense_reference() {
        // Regression (stale-JobDone wake refresh): the staled completion
        // event pops while the coalesced run sleeps on Wake::Idle; the
        // run loop must survive the no-op pop, refresh the hint, and
        // stay bit-identical to the dense grid.
        let specs = vec![spec(0, 0.0, 10.0)];
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut dense = SimOracle::new(ChaosRetry::new(true));
        let ref_res = sim.run(&mut dense, specs.clone());
        let mut fast = SimOracle::new(ChaosRetry::new(false));
        let res = sim.run(&mut fast, specs);
        assert_eq!(ref_res.n_done, 1);
        assert_eq!(ref_res.retries, 1);
        // arrival + failed completion + stale pop + accepted completion
        assert_eq!(ref_res.events_processed, 4);
        assert_eq!(res.events_processed, 4);
        // bit-identical across the retry, the stale pop and the slowdown
        assert_eq!(res.n_done, ref_res.n_done);
        assert_eq!(res.retries, ref_res.retries);
        assert_eq!(res.cost_usd, ref_res.cost_usd);
        assert_eq!(res.gpu_seconds_billed, ref_res.gpu_seconds_billed);
        assert_eq!(res.util_timeline, ref_res.util_timeline);
        assert_eq!(res.job_latencies, ref_res.job_latencies);
        // every round the dense reference ran is accounted for
        assert_eq!(res.rounds_executed + res.rounds_coalesced,
                   ref_res.rounds_executed);
        assert!(res.rounds_coalesced > 0, "{}", res.rounds_coalesced);
    }

    #[test]
    fn oracle_catches_a_starved_wake() {
        // Rogue policy: fails the first completion back to Pending with
        // a 1 s backoff but then sleeps until the next event — there is
        // none before the horizon, so the retry's due round is starved
        // (the lost-wakeup class the wake audit patrols).
        struct SleepyRetry {
            failed: bool,
        }
        impl Policy for SleepyRetry {
            fn name(&self) -> &str {
                "sleepyretry"
            }
            fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
                st.set_billable(1.0);
                st.launch(id, 1, 0.0, 0.0, 1.0);
            }
            fn on_job_complete(&mut self, st: &mut ClusterState, id: usize) {
                if !self.failed {
                    self.failed = true;
                    st.fail_completion(id, 10.0, 1.0);
                }
            }
            fn on_tick(&mut self, _st: &mut ClusterState) {}
            fn next_timed_action(&self, _st: &ClusterState) -> Wake {
                Wake::Idle
            }
        }
        let cfg = SimConfig { horizon_s: 50.0, ..Default::default() };
        let sim = Simulator::new(cfg, PerfModel::default());
        let mut p = SimOracle::collecting(SleepyRetry { failed: false });
        let res = sim.run(&mut p, vec![spec(0, 0.0, 10.0)]);
        assert_eq!(res.n_done, 0); // the retry really was starved
        assert!(
            p.violations().iter().any(|v| v.contains("starved wake")),
            "expected a starved-wake violation, got {:?}",
            p.violations()
        );
    }

    #[test]
    fn batch_skip_and_wake_audit_pass_an_honest_retry_policy() {
        // The flip side of `oracle_catches_a_starved_wake`: ChaosRetry
        // declares Wake::At(retry_not_before) while its retry is held
        // back, so the strict oracle's wake audit stays silent — already
        // exercised above; here we pin that the collecting oracle
        // records nothing at all over the full retry lifecycle.
        let sim = Simulator::new(SimConfig::default(), PerfModel::default());
        let mut p = SimOracle::collecting(ChaosRetry::new(false));
        let res = sim.run(&mut p, vec![spec(0, 0.0, 10.0)]);
        assert_eq!(res.n_done, 1);
        assert!(p.violations().is_empty(), "{:?}", p.violations());
    }
}
