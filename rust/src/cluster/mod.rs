//! Discrete-event GPU-cluster substrate.
//!
//! The paper evaluates on 32–96 physical A100s behind Knative; we rebuild
//! that substrate as a deterministic discrete-event simulator (DESIGN.md
//! §Substitutions): GPUs, allocation overheads (container + framework +
//! runtime + weight load), synchronous per-iteration execution, elastic
//! reallocation, and GPU-second cost integration. Scheduling policies
//! (PromptTuner and the baselines) plug in through the [`sim::Policy`]
//! trait; the simulator measures their *real wall-clock* decision overhead
//! (§6.2 reports 13/67 ms avg/max) alongside the simulated metrics.

pub mod job;
pub mod sim;

pub use job::{JobState, JobStatus};
pub use sim::{ChaosInjection, CheckpointModel, ClusterState, KnobSpec,
              KnobStat, Policy, RetryEvent, Revoked, RevokeEvent,
              SimConfig, SimObserver, SimOracle, SimResult, Simulator,
              StateAudit, StreamCore, TunedPrompt, TunerAction,
              TunerDecision, TunerLog, TunerReport, Wake};
