//! Dynamic-traffic trace generator.
//!
//! Reproduces the statistical shape of the paper's production trace
//! (Fig 2b): minute-granularity arrivals with large spikes (max requests
//! per minute ≈ 5× the mean), job durations from a few seconds to several
//! minutes, and the per-LLM job counts of §6.1's low/medium/high loads
//! (41/55/42, 77/71/65, 99/85/76 jobs in 20 minutes for GPT2-B/GPT2-L/V7B).

use crate::util::rng::Rng;
use crate::workload::{
    ita_multiplier, JobSpec, Llm, PerfModel, MEDIAN_USER_QUALITY,
};

/// §6.1 load levels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Load {
    Low,
    Medium,
    High,
}

impl Load {
    pub fn from_name(s: &str) -> Option<Load> {
        match s {
            "low" => Some(Load::Low),
            "medium" => Some(Load::Medium),
            "high" => Some(Load::High),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Load::Low => "low",
            Load::Medium => "medium",
            Load::High => "high",
        }
    }

    /// Paper job counts for (GPT2-B, GPT2-L, V7B) over the 20-min window.
    pub fn main_counts(self) -> [usize; 3] {
        match self {
            Load::Low => [41, 55, 42],
            Load::Medium => [77, 71, 65],
            Load::High => [99, 85, 76],
        }
    }
}

/// Job-duration distribution family. The paper's traces use the
/// log-uniform shape of §6.1; the scenario engine (`crate::scenario`)
/// swaps in heavier-tailed families without touching the rest of the
/// generation pipeline. Every variant draws exactly one uniform sample,
/// so switching families never perturbs the RNG stream consumed by the
/// other per-job draws.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DurationDist {
    /// Log-uniform in [lo, hi] seconds ("a few seconds to several
    /// minutes", §6.1).
    LogUniform { lo: f64, hi: f64 },
    /// Bounded Pareto: minimum `xm`, tail index `alpha`, hard cap `cap`
    /// seconds (the heavy-tail scenario family).
    Pareto { xm: f64, alpha: f64, cap: f64 },
}

impl DurationDist {
    /// The paper's §6.1 duration shape (~8 s to ~6 min).
    pub const PAPER: DurationDist = DurationDist::LogUniform { lo: 8.0, hi: 360.0 };

    pub fn sample(self, rng: &mut Rng) -> f64 {
        match self {
            DurationDist::LogUniform { lo, hi } => lo * (hi / lo).powf(rng.f64()),
            DurationDist::Pareto { xm, alpha, cap } => {
                // Inverse-CDF with u in (0, 1]: xm / u^(1/alpha) >= xm.
                let u = 1.0 - rng.f64();
                (xm / u.powf(1.0 / alpha)).min(cap)
            }
        }
    }
}

/// Trace-generation parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub seed: u64,
    /// Experiment window in seconds (paper uses 20-minute samples).
    pub window_s: f64,
    /// SLO emergence S (§6.1): SLO = duration × S + allocation overhead.
    pub slo_emergence: f64,
    /// Fraction of minutes that are traffic spikes.
    pub spike_frac: f64,
    /// Spike intensity: spike-minute rate ≈ this × base rate.
    pub spike_mult: f64,
    /// Number of synthetic tasks to draw task ids from.
    pub n_tasks: usize,
    /// Job-duration distribution (default: the paper's log-uniform shape).
    pub duration: DurationDist,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 42,
            window_s: 1200.0,
            slo_emergence: 1.0,
            spike_frac: 0.10,
            spike_mult: 8.0,
            n_tasks: 64,
            duration: DurationDist::PAPER,
        }
    }
}

/// Generates [`JobSpec`] traces with the paper's traffic shape.
pub struct TraceGenerator {
    pub cfg: TraceConfig,
    pub perf: PerfModel,
    rng: Rng,
    next_id: usize,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig, perf: PerfModel) -> Self {
        let rng = Rng::new(cfg.seed);
        TraceGenerator { cfg, perf, rng, next_id: 0 }
    }

    /// Generate `count` jobs for one LLM across the window.
    pub fn generate_for(&mut self, llm: Llm, count: usize) -> Vec<JobSpec> {
        let minutes = (self.cfg.window_s / 60.0).ceil() as usize;
        // Minute weights: mostly ~1, some spike minutes (Fig 2b shape).
        let mut weights = vec![0.0f64; minutes];
        for w in weights.iter_mut() {
            let spike = self.rng.f64() < self.cfg.spike_frac;
            let base = 0.3 + 1.0 * self.rng.f64();
            *w = if spike { self.cfg.spike_mult * base } else { base };
        }
        self.generate_weighted(llm, count, &weights)
    }

    /// Scenario-engine hook: generate `count` jobs for one LLM with an
    /// explicit per-minute arrival-weight profile (diurnal curves, flash
    /// crowds, ... — see `crate::scenario`). `weights.len()` minutes are
    /// covered; arrivals are clamped into the window.
    pub fn generate_weighted(&mut self, llm: Llm, count: usize,
                             weights: &[f64]) -> Vec<JobSpec> {
        // Multinomial split of `count` arrivals across minutes.
        let mut jobs = Vec::with_capacity(count);
        for _ in 0..count {
            let m = self.rng.categorical(weights);
            let t = (m as f64) * 60.0 + self.rng.f64() * 60.0;
            jobs.push(self.sample_job(llm, t.min(self.cfg.window_s - 1.0)));
        }
        jobs.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
        jobs
    }

    /// Generate the §6.1 main-experiment trace: all three main LLMs at the
    /// given load level, merged and sorted by submission time.
    pub fn generate_main(&mut self, load: Load) -> Vec<JobSpec> {
        let counts = load.main_counts();
        let mut jobs = vec![];
        for (i, llm) in Llm::MAIN.into_iter().enumerate() {
            jobs.extend(self.generate_for(llm, counts[i]));
        }
        Self::finalize(&mut jobs);
        jobs
    }

    /// Heavy-workload traces (Table 7): 59 LLaMA-30B or 70 Qwen7B-R1 jobs.
    pub fn generate_heavy(&mut self, llm: Llm) -> Vec<JobSpec> {
        let count = match llm {
            Llm::Llama30B => 59,
            Llm::Qwen7BR1 => 70,
            _ => 60,
        };
        let mut jobs = self.generate_for(llm, count);
        Self::finalize(&mut jobs);
        jobs
    }

    /// Scale a load proportionally (the 96-GPU large-scale run of §6.2).
    pub fn generate_scaled(&mut self, load: Load, factor: f64) -> Vec<JobSpec> {
        let counts = load.main_counts();
        let mut jobs = vec![];
        for (i, llm) in Llm::MAIN.into_iter().enumerate() {
            let n = ((counts[i] as f64) * factor).round() as usize;
            jobs.extend(self.generate_for(llm, n));
        }
        Self::finalize(&mut jobs);
        jobs
    }

    /// Sort by submission time and assign dense ids — the simulator
    /// indexes jobs by position, so every merged trace must end with this
    /// (public for the scenario engine, which merges several generators'
    /// outputs; an associated function because it reads no generator
    /// state).
    pub fn finalize(jobs: &mut [JobSpec]) {
        jobs.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
        for (i, j) in jobs.iter_mut().enumerate() {
            j.id = i;
        }
    }

    fn sample_job(&mut self, llm: Llm, submit_s: f64) -> JobSpec {
        let id = self.next_id;
        self.next_id += 1;
        let duration_s = self.cfg.duration.sample(&mut self.rng);
        // Traced GPU counts: replicas of the LLM's TP group size.
        let per = llm.gpus_per_replica();
        let replicas = *[1usize, 1, 1, 2, 2, 4]
            .get(self.rng.below(6))
            .unwrap_or(&1);
        let traced_gpus = per * replicas;
        // Work: traced duration assumed achieved at median user-prompt
        // quality on the traced allocation.
        let iters_med = duration_s / self.perf.iter_time(llm, traced_gpus);
        let base_iters = iters_med / ita_multiplier(MEDIAN_USER_QUALITY);
        // User prompt quality: Beta(2.2, 1.8) gives median ≈ 0.57.
        let user_prompt_quality = self.rng.beta(2.2, 1.8).clamp(0.02, 0.98);
        let slo_s =
            duration_s * self.cfg.slo_emergence + self.perf.cold_start(llm);
        JobSpec {
            id,
            llm,
            task_id: self.rng.below(self.cfg.n_tasks),
            submit_s,
            duration_s,
            traced_gpus,
            base_iters,
            user_prompt_quality,
            slo_s,
        }
    }
}

/// Arrivals per minute over the window (Fig 2b series).
pub fn arrivals_per_minute(jobs: &[JobSpec], window_s: f64) -> Vec<usize> {
    let minutes = (window_s / 60.0).ceil() as usize;
    let mut counts = vec![0usize; minutes];
    for j in jobs {
        let m = ((j.submit_s / 60.0) as usize).min(minutes.saturating_sub(1));
        counts[m] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    fn gen(seed: u64) -> TraceGenerator {
        let cfg = TraceConfig { seed, ..TraceConfig::default() };
        TraceGenerator::new(cfg, PerfModel::default())
    }

    #[test]
    fn counts_match_load_levels() {
        for load in [Load::Low, Load::Medium, Load::High] {
            let jobs = gen(1).generate_main(load);
            let expect: usize = load.main_counts().iter().sum();
            assert_eq!(jobs.len(), expect);
        }
    }

    #[test]
    fn jobs_sorted_with_dense_ids() {
        let jobs = gen(2).generate_main(Load::Medium);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i);
        }
        for w in jobs.windows(2) {
            assert!(w[0].submit_s <= w[1].submit_s);
        }
    }

    #[test]
    fn traffic_is_spiky_like_fig2b() {
        // max arrivals/minute should be several times the mean — the
        // paper reports ≈5×. Accept ≥3× to keep the test seed-robust.
        let jobs = gen(3).generate_scaled(Load::High, 3.0);
        let counts = arrivals_per_minute(&jobs, 1200.0);
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max / mean >= 3.0, "max/mean = {}", max / mean);
    }

    #[test]
    fn durations_span_seconds_to_minutes() {
        let jobs = gen(4).generate_main(Load::High);
        let min = jobs.iter().map(|j| j.duration_s).fold(f64::MAX, f64::min);
        let max = jobs.iter().map(|j| j.duration_s).fold(0.0, f64::max);
        assert!(min < 30.0, "{min}");
        assert!(max > 120.0, "{max}");
        assert!(max <= 360.0 + 1e-9);
    }

    #[test]
    fn tp_llms_get_multiples_of_replica_size() {
        let jobs = gen(5).generate_heavy(Llm::Llama30B);
        assert_eq!(jobs.len(), 59);
        for j in &jobs {
            assert_eq!(j.traced_gpus % 4, 0, "{:?}", j);
        }
    }

    #[test]
    fn slo_uses_emergence_and_overhead() {
        let cfg = TraceConfig { seed: 6, slo_emergence: 0.5, ..Default::default() };
        let perf = PerfModel::default();
        let mut g = TraceGenerator::new(cfg, perf.clone());
        let jobs = g.generate_main(Load::Low);
        for j in &jobs {
            let expect = j.duration_s * 0.5 + perf.cold_start(j.llm);
            assert!((j.slo_s - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = gen(7).generate_main(Load::Medium);
        let b = gen(7).generate_main(Load::Medium);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.submit_s, y.submit_s);
            assert_eq!(x.task_id, y.task_id);
        }
        let c = gen(8).generate_main(Load::Medium);
        assert!(a.iter().zip(&c).any(|(x, y)| x.submit_s != y.submit_s));
    }

    #[test]
    fn prop_base_iters_consistent_with_duration() {
        check("duration = base_iters × mult(median) × iter_time", 100, |r| {
            let mut g = gen(r.next_u64());
            let jobs = g.generate_main(Load::Low);
            let perf = PerfModel::default();
            for j in &jobs {
                let d = j.base_iters
                    * ita_multiplier(MEDIAN_USER_QUALITY)
                    * perf.iter_time(j.llm, j.traced_gpus);
                ensure(
                    (d - j.duration_s).abs() < 1e-6,
                    format!("job {} duration {} vs reconstructed {d}", j.id, j.duration_s),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quality_in_bounds() {
        check("user prompt quality in (0,1)", 50, |r| {
            let mut g = gen(r.next_u64());
            for j in g.generate_main(Load::Low) {
                ensure(
                    j.user_prompt_quality > 0.0 && j.user_prompt_quality < 1.0,
                    format!("quality {}", j.user_prompt_quality),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn arrivals_histogram_total() {
        let jobs = gen(9).generate_main(Load::Medium);
        let counts = arrivals_per_minute(&jobs, 1200.0);
        assert_eq!(counts.iter().sum::<usize>(), jobs.len());
        assert_eq!(counts.len(), 20);
    }

    #[test]
    fn pareto_durations_bounded_and_heavy() {
        let dist = DurationDist::Pareto { xm: 5.0, alpha: 1.1, cap: 1800.0 };
        let mut rng = crate::util::rng::Rng::new(10);
        let mut max = 0.0f64;
        for _ in 0..20_000 {
            let d = dist.sample(&mut rng);
            assert!((5.0..=1800.0).contains(&d), "{d}");
            max = max.max(d);
        }
        // the tail must actually reach far past the body
        assert!(max > 500.0, "{max}");
    }

    #[test]
    fn duration_dist_draws_exactly_one_sample() {
        // Swapping families must not shift the RNG stream of other draws.
        for dist in [DurationDist::PAPER,
                     DurationDist::Pareto { xm: 5.0, alpha: 1.1, cap: 1800.0 }] {
            let mut a = crate::util::rng::Rng::new(3);
            let _ = dist.sample(&mut a);
            let mut b = crate::util::rng::Rng::new(3);
            let _ = b.f64();
            assert_eq!(a.next_u64(), b.next_u64(), "{dist:?}");
        }
    }

    #[test]
    fn weighted_arrivals_follow_profile() {
        // All weight on minute 7: every arrival lands in [420, 480).
        let mut g = gen(12);
        let mut weights = vec![0.0; 20];
        weights[7] = 1.0;
        let jobs = g.generate_weighted(Llm::Gpt2B, 40, &weights);
        assert_eq!(jobs.len(), 40);
        for j in &jobs {
            assert!((420.0..480.0).contains(&j.submit_s), "{}", j.submit_s);
        }
    }

    #[test]
    fn generate_for_matches_explicit_weight_path() {
        // generate_for == (spike weight draw) + generate_weighted on the
        // same RNG stream. The weight draw is replicated externally with
        // the documented formula; a zero-count generate_for call advances
        // the second generator past its own (identical) weight draw so
        // both job loops start at the same stream position.
        let a = gen(13).generate_for(Llm::V7B, 25);

        let cfg = TraceConfig { seed: 13, ..TraceConfig::default() };
        let mut r = Rng::new(13);
        let minutes = (cfg.window_s / 60.0).ceil() as usize;
        let mut weights = vec![0.0f64; minutes];
        for w in weights.iter_mut() {
            let spike = r.f64() < cfg.spike_frac;
            let base = 0.3 + 1.0 * r.f64();
            *w = if spike { cfg.spike_mult * base } else { base };
        }
        let mut g = gen(13);
        assert!(g.generate_for(Llm::V7B, 0).is_empty()); // consume weight draw
        let b = g.generate_weighted(Llm::V7B, 25, &weights);

        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.submit_s.to_bits(), y.submit_s.to_bits());
            assert_eq!(x.duration_s.to_bits(), y.duration_s.to_bits());
            assert_eq!(x.task_id, y.task_id);
        }
    }
}
