//! Dynamic-traffic trace generator.
//!
//! Reproduces the statistical shape of the paper's production trace
//! (Fig 2b): minute-granularity arrivals with large spikes (max requests
//! per minute ≈ 5× the mean), job durations from a few seconds to several
//! minutes, and the per-LLM job counts of §6.1's low/medium/high loads
//! (41/55/42, 77/71/65, 99/85/76 jobs in 20 minutes for GPT2-B/GPT2-L/V7B).

use crate::util::rng::Rng;
use crate::workload::{
    ita_multiplier, JobSpec, Llm, PerfModel, MEDIAN_USER_QUALITY,
};

/// §6.1 load levels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Load {
    Low,
    Medium,
    High,
}

impl Load {
    pub fn from_name(s: &str) -> Option<Load> {
        match s {
            "low" => Some(Load::Low),
            "medium" => Some(Load::Medium),
            "high" => Some(Load::High),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Load::Low => "low",
            Load::Medium => "medium",
            Load::High => "high",
        }
    }

    /// Paper job counts for (GPT2-B, GPT2-L, V7B) over the 20-min window.
    pub fn main_counts(self) -> [usize; 3] {
        match self {
            Load::Low => [41, 55, 42],
            Load::Medium => [77, 71, 65],
            Load::High => [99, 85, 76],
        }
    }
}

/// Trace-generation parameters.
#[derive(Clone, Debug)]
pub struct TraceConfig {
    pub seed: u64,
    /// Experiment window in seconds (paper uses 20-minute samples).
    pub window_s: f64,
    /// SLO emergence S (§6.1): SLO = duration × S + allocation overhead.
    pub slo_emergence: f64,
    /// Fraction of minutes that are traffic spikes.
    pub spike_frac: f64,
    /// Spike intensity: spike-minute rate ≈ this × base rate.
    pub spike_mult: f64,
    /// Number of synthetic tasks to draw task ids from.
    pub n_tasks: usize,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 42,
            window_s: 1200.0,
            slo_emergence: 1.0,
            spike_frac: 0.10,
            spike_mult: 8.0,
            n_tasks: 64,
        }
    }
}

/// Generates [`JobSpec`] traces with the paper's traffic shape.
pub struct TraceGenerator {
    pub cfg: TraceConfig,
    pub perf: PerfModel,
    rng: Rng,
    next_id: usize,
}

impl TraceGenerator {
    pub fn new(cfg: TraceConfig, perf: PerfModel) -> Self {
        let rng = Rng::new(cfg.seed);
        TraceGenerator { cfg, perf, rng, next_id: 0 }
    }

    /// Generate `count` jobs for one LLM across the window.
    pub fn generate_for(&mut self, llm: Llm, count: usize) -> Vec<JobSpec> {
        let minutes = (self.cfg.window_s / 60.0).ceil() as usize;
        // Minute weights: mostly ~1, some spike minutes (Fig 2b shape).
        let mut weights = vec![0.0f64; minutes];
        for w in weights.iter_mut() {
            let spike = self.rng.f64() < self.cfg.spike_frac;
            let base = 0.3 + 1.0 * self.rng.f64();
            *w = if spike { self.cfg.spike_mult * base } else { base };
        }
        let total_w: f64 = weights.iter().sum();
        // Multinomial split of `count` arrivals across minutes.
        let mut jobs = Vec::with_capacity(count);
        for _ in 0..count {
            let m = self.rng.categorical(&weights);
            let t = (m as f64) * 60.0 + self.rng.f64() * 60.0;
            jobs.push(self.sample_job(llm, t.min(self.cfg.window_s - 1.0)));
        }
        let _ = total_w;
        jobs.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
        jobs
    }

    /// Generate the §6.1 main-experiment trace: all three main LLMs at the
    /// given load level, merged and sorted by submission time.
    pub fn generate_main(&mut self, load: Load) -> Vec<JobSpec> {
        let counts = load.main_counts();
        let mut jobs = vec![];
        for (i, llm) in Llm::MAIN.into_iter().enumerate() {
            jobs.extend(self.generate_for(llm, counts[i]));
        }
        self.finalize(&mut jobs);
        jobs
    }

    /// Heavy-workload traces (Table 7): 59 LLaMA-30B or 70 Qwen7B-R1 jobs.
    pub fn generate_heavy(&mut self, llm: Llm) -> Vec<JobSpec> {
        let count = match llm {
            Llm::Llama30B => 59,
            Llm::Qwen7BR1 => 70,
            _ => 60,
        };
        let mut jobs = self.generate_for(llm, count);
        self.finalize(&mut jobs);
        jobs
    }

    /// Scale a load proportionally (the 96-GPU large-scale run of §6.2).
    pub fn generate_scaled(&mut self, load: Load, factor: f64) -> Vec<JobSpec> {
        let counts = load.main_counts();
        let mut jobs = vec![];
        for (i, llm) in Llm::MAIN.into_iter().enumerate() {
            let n = ((counts[i] as f64) * factor).round() as usize;
            jobs.extend(self.generate_for(llm, n));
        }
        self.finalize(&mut jobs);
        jobs
    }

    fn finalize(&mut self, jobs: &mut [JobSpec]) {
        jobs.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
        for (i, j) in jobs.iter_mut().enumerate() {
            j.id = i;
        }
    }

    fn sample_job(&mut self, llm: Llm, submit_s: f64) -> JobSpec {
        let id = self.next_id;
        self.next_id += 1;
        // Durations: log-uniform between ~8 s and ~6 min ("a few seconds
        // to several minutes", §6.1).
        let lo: f64 = 8.0;
        let hi: f64 = 360.0;
        let duration_s = lo * (hi / lo).powf(self.rng.f64());
        // Traced GPU counts: replicas of the LLM's TP group size.
        let per = llm.gpus_per_replica();
        let replicas = *[1usize, 1, 1, 2, 2, 4]
            .get(self.rng.below(6))
            .unwrap_or(&1);
        let traced_gpus = per * replicas;
        // Work: traced duration assumed achieved at median user-prompt
        // quality on the traced allocation.
        let iters_med = duration_s / self.perf.iter_time(llm, traced_gpus);
        let base_iters = iters_med / ita_multiplier(MEDIAN_USER_QUALITY);
        // User prompt quality: Beta(2.2, 1.8) gives median ≈ 0.57.
        let user_prompt_quality = self.rng.beta(2.2, 1.8).clamp(0.02, 0.98);
        let slo_s =
            duration_s * self.cfg.slo_emergence + self.perf.cold_start(llm);
        JobSpec {
            id,
            llm,
            task_id: self.rng.below(self.cfg.n_tasks),
            submit_s,
            duration_s,
            traced_gpus,
            base_iters,
            user_prompt_quality,
            slo_s,
        }
    }
}

/// Arrivals per minute over the window (Fig 2b series).
pub fn arrivals_per_minute(jobs: &[JobSpec], window_s: f64) -> Vec<usize> {
    let minutes = (window_s / 60.0).ceil() as usize;
    let mut counts = vec![0usize; minutes];
    for j in jobs {
        let m = ((j.submit_s / 60.0) as usize).min(minutes.saturating_sub(1));
        counts[m] += 1;
    }
    counts
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, ensure};

    fn gen(seed: u64) -> TraceGenerator {
        let cfg = TraceConfig { seed, ..TraceConfig::default() };
        TraceGenerator::new(cfg, PerfModel::default())
    }

    #[test]
    fn counts_match_load_levels() {
        for load in [Load::Low, Load::Medium, Load::High] {
            let jobs = gen(1).generate_main(load);
            let expect: usize = load.main_counts().iter().sum();
            assert_eq!(jobs.len(), expect);
        }
    }

    #[test]
    fn jobs_sorted_with_dense_ids() {
        let jobs = gen(2).generate_main(Load::Medium);
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i);
        }
        for w in jobs.windows(2) {
            assert!(w[0].submit_s <= w[1].submit_s);
        }
    }

    #[test]
    fn traffic_is_spiky_like_fig2b() {
        // max arrivals/minute should be several times the mean — the
        // paper reports ≈5×. Accept ≥3× to keep the test seed-robust.
        let jobs = gen(3).generate_scaled(Load::High, 3.0);
        let counts = arrivals_per_minute(&jobs, 1200.0);
        let mean = counts.iter().sum::<usize>() as f64 / counts.len() as f64;
        let max = *counts.iter().max().unwrap() as f64;
        assert!(max / mean >= 3.0, "max/mean = {}", max / mean);
    }

    #[test]
    fn durations_span_seconds_to_minutes() {
        let jobs = gen(4).generate_main(Load::High);
        let min = jobs.iter().map(|j| j.duration_s).fold(f64::MAX, f64::min);
        let max = jobs.iter().map(|j| j.duration_s).fold(0.0, f64::max);
        assert!(min < 30.0, "{min}");
        assert!(max > 120.0, "{max}");
        assert!(max <= 360.0 + 1e-9);
    }

    #[test]
    fn tp_llms_get_multiples_of_replica_size() {
        let jobs = gen(5).generate_heavy(Llm::Llama30B);
        assert_eq!(jobs.len(), 59);
        for j in &jobs {
            assert_eq!(j.traced_gpus % 4, 0, "{:?}", j);
        }
    }

    #[test]
    fn slo_uses_emergence_and_overhead() {
        let cfg = TraceConfig { seed: 6, slo_emergence: 0.5, ..Default::default() };
        let perf = PerfModel::default();
        let mut g = TraceGenerator::new(cfg, perf.clone());
        let jobs = g.generate_main(Load::Low);
        for j in &jobs {
            let expect = j.duration_s * 0.5 + perf.cold_start(j.llm);
            assert!((j.slo_s - expect).abs() < 1e-9);
        }
    }

    #[test]
    fn deterministic_by_seed() {
        let a = gen(7).generate_main(Load::Medium);
        let b = gen(7).generate_main(Load::Medium);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.submit_s, y.submit_s);
            assert_eq!(x.task_id, y.task_id);
        }
        let c = gen(8).generate_main(Load::Medium);
        assert!(a.iter().zip(&c).any(|(x, y)| x.submit_s != y.submit_s));
    }

    #[test]
    fn prop_base_iters_consistent_with_duration() {
        check("duration = base_iters × mult(median) × iter_time", 100, |r| {
            let mut g = gen(r.next_u64());
            let jobs = g.generate_main(Load::Low);
            let perf = PerfModel::default();
            for j in &jobs {
                let d = j.base_iters
                    * ita_multiplier(MEDIAN_USER_QUALITY)
                    * perf.iter_time(j.llm, j.traced_gpus);
                ensure(
                    (d - j.duration_s).abs() < 1e-6,
                    format!("job {} duration {} vs reconstructed {d}", j.id, j.duration_s),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn prop_quality_in_bounds() {
        check("user prompt quality in (0,1)", 50, |r| {
            let mut g = gen(r.next_u64());
            for j in g.generate_main(Load::Low) {
                ensure(
                    j.user_prompt_quality > 0.0 && j.user_prompt_quality < 1.0,
                    format!("quality {}", j.user_prompt_quality),
                )?;
            }
            Ok(())
        });
    }

    #[test]
    fn arrivals_histogram_total() {
        let jobs = gen(9).generate_main(Load::Medium);
        let counts = arrivals_per_minute(&jobs, 1200.0);
        assert_eq!(counts.iter().sum::<usize>(), jobs.len());
        assert_eq!(counts.len(), 20);
    }
}
