//! The paper's Table 6 task catalogue (12 datasets across 6 task types)
//! mapped onto the synthetic task universe: each catalogue entry owns a
//! contiguous slice of synthetic task ids (the paper partitions each
//! dataset into 10 exclusive partitions to build 120 tasks per LLM; we
//! mirror that by fanning each catalogue entry out over universe tasks).

/// One Table 6 row.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TaskEntry {
    pub task_type: &'static str,
    pub dataset: &'static str,
    /// Paper's target accuracy value (bleu or rouge, informational).
    pub target_accuracy: f64,
    /// Metric name, "bleu" or "rouge".
    pub metric: &'static str,
}

/// Table 6 of the paper.
pub const TABLE6: [TaskEntry; 12] = [
    TaskEntry { task_type: "Dialog", dataset: "DA", target_accuracy: 54.0, metric: "bleu" },
    TaskEntry { task_type: "Dialog", dataset: "PC", target_accuracy: 19.0, metric: "bleu" },
    TaskEntry { task_type: "QuestionAnswer", dataset: "COQAQG", target_accuracy: 51.0, metric: "bleu" },
    TaskEntry { task_type: "QuestionAnswer", dataset: "QUORA", target_accuracy: 21.0, metric: "bleu" },
    TaskEntry { task_type: "TextGeneration", dataset: "WIKIBIO", target_accuracy: 70.0, metric: "rouge" },
    TaskEntry { task_type: "TextGeneration", dataset: "WIKIP", target_accuracy: 22.0, metric: "rouge" },
    TaskEntry { task_type: "Summarization", dataset: "CNNDM", target_accuracy: 34.0, metric: "bleu" },
    TaskEntry { task_type: "Summarization", dataset: "SAMSUM", target_accuracy: 46.0, metric: "bleu" },
    TaskEntry { task_type: "Summarization", dataset: "XSUM", target_accuracy: 40.0, metric: "bleu" },
    TaskEntry { task_type: "Summarization", dataset: "CMV", target_accuracy: 26.0, metric: "rouge" },
    TaskEntry { task_type: "StoryGeneration", dataset: "WP", target_accuracy: 20.0, metric: "rouge" },
    TaskEntry { task_type: "StoryGeneration", dataset: "ROC", target_accuracy: 25.0, metric: "rouge" },
];

/// Map a synthetic universe task id onto its Table 6 catalogue entry
/// (round-robin slices, mirroring the paper's 10-partition fan-out).
pub fn catalogue_entry(task_id: usize, n_universe_tasks: usize) -> &'static TaskEntry {
    let per = (n_universe_tasks / TABLE6.len()).max(1);
    &TABLE6[(task_id / per).min(TABLE6.len() - 1)]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn twelve_datasets_five_types() {
        assert_eq!(TABLE6.len(), 12);
        let mut types: Vec<&str> = TABLE6.iter().map(|t| t.task_type).collect();
        types.sort_unstable();
        types.dedup();
        // Table 6 spans five task types across twelve datasets
        assert_eq!(types.len(), 5);
    }

    #[test]
    fn metrics_are_valid() {
        for t in &TABLE6 {
            assert!(t.metric == "bleu" || t.metric == "rouge");
            assert!(t.target_accuracy > 0.0);
        }
    }

    #[test]
    fn catalogue_mapping_covers_all_entries() {
        let n = 64;
        let mut seen = std::collections::BTreeSet::new();
        for id in 0..n {
            seen.insert(catalogue_entry(id, n).dataset);
        }
        assert_eq!(seen.len(), TABLE6.len());
    }

    #[test]
    fn catalogue_mapping_in_bounds_for_small_universe() {
        for id in 0..4 {
            let _ = catalogue_entry(id, 4); // must not panic
        }
        assert_eq!(catalogue_entry(1000, 64).dataset, "ROC");
    }
}
