//! LPT workload traces: the spiky dynamic-traffic generator (stand-in for
//! the paper's anonymized 2-hour production trace, Fig 2b), the task table
//! (stand-in for Table 6), and a plain-text trace (de)serializer.

pub mod generator;
pub mod source;
pub mod tasks;

pub use generator::{arrivals_per_minute, DurationDist, Load, TraceConfig,
                    TraceGenerator};
pub use source::{ArrivalHistogram, ReplaySource, ScaleSource,
                 ScaleSourceConfig, TraceSource, VecSource};

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::workload::{JobSpec, Llm};

/// Serialize a trace to a plain-text file (one job per line).
pub fn save(path: impl AsRef<Path>, jobs: &[JobSpec]) -> Result<()> {
    let mut out = String::from(
        "# id llm task submit_s duration_s gpus base_iters quality slo_s\n",
    );
    for j in jobs {
        out.push_str(&format!(
            "{} {} {} {:.3} {:.3} {} {:.3} {:.4} {:.3}\n",
            j.id,
            j.llm.name(),
            j.task_id,
            j.submit_s,
            j.duration_s,
            j.traced_gpus,
            j.base_iters,
            j.user_prompt_quality,
            j.slo_s
        ));
    }
    std::fs::write(path.as_ref(), out)
        .with_context(|| format!("writing {}", path.as_ref().display()))?;
    Ok(())
}

/// Load a trace written by [`save`].
pub fn load(path: impl AsRef<Path>) -> Result<Vec<JobSpec>> {
    let text = std::fs::read_to_string(path.as_ref())
        .with_context(|| format!("reading {}", path.as_ref().display()))?;
    parse(&text)
}

/// Parse trace text.
pub fn parse(text: &str) -> Result<Vec<JobSpec>> {
    let mut jobs = vec![];
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let t: Vec<&str> = line.split_whitespace().collect();
        if t.len() != 9 {
            bail!("trace line {} malformed: '{line}'", lineno + 1);
        }
        jobs.push(JobSpec {
            id: t[0].parse()?,
            llm: Llm::from_name(t[1])?,
            task_id: t[2].parse()?,
            submit_s: t[3].parse()?,
            duration_s: t[4].parse()?,
            traced_gpus: t[5].parse()?,
            base_iters: t[6].parse()?,
            user_prompt_quality: t[7].parse()?,
            slo_s: t[8].parse()?,
        });
    }
    Ok(jobs)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_job(id: usize) -> JobSpec {
        JobSpec {
            id,
            llm: Llm::V7B,
            task_id: 5,
            submit_s: 1.5,
            duration_s: 120.0,
            traced_gpus: 2,
            base_iters: 88.25,
            user_prompt_quality: 0.61,
            slo_s: 180.0,
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = std::env::temp_dir().join("pt_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.trace");
        let jobs = vec![sample_job(0), sample_job(1)];
        save(&path, &jobs).unwrap();
        let back = load(&path).unwrap();
        assert_eq!(back.len(), 2);
        assert_eq!(back[1].id, 1);
        assert_eq!(back[0].llm, Llm::V7B);
        assert!((back[0].base_iters - 88.25).abs() < 1e-6);
        assert!((back[0].user_prompt_quality - 0.61).abs() < 1e-3);
    }

    #[test]
    fn parse_skips_comments_and_blank() {
        let text = "# header\n\n0 gpt2-base 1 0.0 10.0 1 5.0 0.5 20.0\n";
        let jobs = parse(text).unwrap();
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].llm, Llm::Gpt2B);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(parse("0 gpt2-base 1 0.0\n").is_err());
        assert!(parse("0 unknown-llm 1 0 10 1 5 0.5 20\n").is_err());
    }
}
