//! Streaming trace sources: arrivals yielded incrementally so resident
//! trace memory is O(active jobs), not O(trace length).
//!
//! The classic path materializes a full `Vec<JobSpec>` before the run —
//! fine for the paper's 20-minute windows, fatal for the hyperscale
//! sweep (multi-day traces, ~1M jobs). A [`TraceSource`] instead yields
//! jobs one at a time in submission order; the simulator's `StreamCore`
//! injects each arrival when simulated time reaches it, so the only
//! per-job state resident before a job's submit time is the source's
//! own generation buffer (one minute's batch for [`ScaleSource`], one
//! 52-byte record for [`ReplaySource`]).
//!
//! Contract (load-bearing for bit-identity with the materialized path):
//!
//! * `next_job` yields jobs in non-decreasing `submit_s` order;
//! * [`TraceSource::total_jobs`] and [`TraceSource::last_arrival_s`] are
//!   known up front without materializing (the run loop pre-computes its
//!   event-sequence layout and horizon from them, exactly as
//!   `Simulator::run` derives them from the full slice);
//! * `last_arrival_s` is `0.0` for an empty source, the maximum
//!   `submit_s` otherwise — the same `fold(0.0, max)` the materialized
//!   run loop computes.
//!
//! Job ids in yielded specs are advisory: the simulator re-assigns each
//! injected job the next dense index, which for a single-cluster run of
//! a finalized trace reproduces the ids the spec already carries.

use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::trace::generator::DurationDist;
use crate::util::rng::Rng;
use crate::workload::{ita_multiplier, JobSpec, Llm, PerfModel,
                      MEDIAN_USER_QUALITY};

/// A stream of job arrivals in submission order. See the module docs for
/// the contract.
pub trait TraceSource {
    /// Total number of jobs this source will yield (known up front).
    fn total_jobs(&self) -> usize;
    /// Maximum `submit_s` over the whole trace; `0.0` when empty.
    fn last_arrival_s(&self) -> f64;
    /// The next job in non-decreasing `submit_s` order.
    fn next_job(&mut self) -> Option<JobSpec>;
}

// --------------------------------------------------------- materialized

/// Adapter: a fully materialized trace as a [`TraceSource`]. Exists so
/// every classic `Vec<JobSpec>` path (scenario catalogue, bench cells)
/// can drive the streaming run loop — and so the streaming-vs-
/// materialized equivalence property has a trivial reference.
pub struct VecSource {
    jobs: std::vec::IntoIter<JobSpec>,
    total: usize,
    last_arrival: f64,
}

impl VecSource {
    /// Wrap a finalized trace (sorted by `submit_s`, dense ids — what
    /// `TraceGenerator::finalize` produces).
    pub fn new(jobs: Vec<JobSpec>) -> Self {
        debug_assert!(
            jobs.windows(2).all(|w| w[0].submit_s <= w[1].submit_s),
            "VecSource requires a submit-sorted trace"
        );
        let total = jobs.len();
        // Same floor-at-zero fold the materialized run loop uses.
        let last_arrival =
            jobs.iter().map(|j| j.submit_s).fold(0.0f64, f64::max);
        VecSource { jobs: jobs.into_iter(), total, last_arrival }
    }
}

impl TraceSource for VecSource {
    fn total_jobs(&self) -> usize {
        self.total
    }
    fn last_arrival_s(&self) -> f64 {
        self.last_arrival
    }
    fn next_job(&mut self) -> Option<JobSpec> {
        self.jobs.next()
    }
}

// --------------------------------------------------------------- replay

/// Size of one on-disk job record in the `PTR1` binary trace format
/// (three `u32` fields + five `f64` fields, little-endian).
const REPLAY_RECORD_BYTES: usize = 12 + 40;
const REPLAY_HEADER_BYTES: usize = 12;

/// Streaming reader for `PTR1` binary traces (`scenario::replay`): one
/// record is decoded per `next_job` call, so no `Vec<JobSpec>` ever
/// exists. The whole byte buffer is held (unavoidable for a file), but
/// that is 52 bytes/job against ~200 for a decoded spec plus job state.
///
/// Unlike `scenario::replay::from_bytes` — which sorts defensively —
/// streaming cannot reorder, so `open` validates up front (one O(jobs)
/// scan over the raw bytes, no allocation) that records are already in
/// non-decreasing submit order, which is what `replay::save` writes for
/// every finalized trace.
pub struct ReplaySource {
    bytes: Vec<u8>,
    pos: usize,
    next_id: usize,
    total: usize,
    last_arrival: f64,
}

fn u32_at(bytes: &[u8], pos: usize) -> u32 {
    u32::from_le_bytes(bytes[pos..pos + 4].try_into().unwrap())
}

fn f64_at(bytes: &[u8], pos: usize) -> f64 {
    f64::from_le_bytes(bytes[pos..pos + 8].try_into().unwrap())
}

impl ReplaySource {
    /// Open a `PTR1` byte buffer, validating the header, the exact byte
    /// length, every record's physical bounds, and submit-order — after
    /// which `next_job` decodes infallibly.
    pub fn from_bytes(bytes: Vec<u8>) -> Result<Self> {
        if bytes.len() < REPLAY_HEADER_BYTES {
            bail!("binary trace: truncated header ({} bytes)", bytes.len());
        }
        let magic = u32_at(&bytes, 0);
        if magic != crate::scenario::replay::MAGIC {
            bail!("binary trace: bad magic {magic:#010x}");
        }
        let version = u32_at(&bytes, 4);
        if version != crate::scenario::replay::VERSION {
            bail!("binary trace: unsupported version {version}");
        }
        let total = u32_at(&bytes, 8) as usize;
        let want = REPLAY_HEADER_BYTES + total * REPLAY_RECORD_BYTES;
        if bytes.len() != want {
            bail!("binary trace: {} bytes for {total} jobs (want {want})",
                  bytes.len());
        }
        // One flat validation scan over the raw records.
        let mut last_arrival = 0.0f64;
        let mut prev_submit = f64::NEG_INFINITY;
        for i in 0..total {
            let p = REPLAY_HEADER_BYTES + i * REPLAY_RECORD_BYTES;
            let llm_idx = u32_at(&bytes, p) as usize;
            if llm_idx >= Llm::ALL.len() {
                bail!("job {i}: bad LLM index {llm_idx}");
            }
            let traced_gpus = u32_at(&bytes, p + 8);
            if traced_gpus == 0 {
                bail!("job {i}: zero traced GPUs");
            }
            let submit_s = f64_at(&bytes, p + 12);
            let duration_s = f64_at(&bytes, p + 20);
            let base_iters = f64_at(&bytes, p + 28);
            let quality = f64_at(&bytes, p + 36);
            let slo_s = f64_at(&bytes, p + 44);
            if !submit_s.is_finite() || submit_s < 0.0 {
                bail!("job {i}: bad submit time {submit_s}");
            }
            if !(duration_s.is_finite() && duration_s > 0.0) {
                bail!("job {i}: bad duration {duration_s}");
            }
            if !(base_iters.is_finite() && base_iters > 0.0) {
                bail!("job {i}: bad base iterations {base_iters}");
            }
            if !(0.0..=1.0).contains(&quality) {
                bail!("job {i}: prompt quality {quality} outside [0, 1]");
            }
            if !(slo_s.is_finite() && slo_s > 0.0) {
                bail!("job {i}: bad SLO {slo_s}");
            }
            if submit_s < prev_submit {
                bail!("job {i}: submit {submit_s} before predecessor \
                       {prev_submit} — streaming replay needs a \
                       submit-sorted trace (replay::save writes one)");
            }
            prev_submit = submit_s;
            last_arrival = last_arrival.max(submit_s);
        }
        Ok(ReplaySource {
            bytes,
            pos: REPLAY_HEADER_BYTES,
            next_id: 0,
            total,
            last_arrival,
        })
    }

    /// Open a binary trace file written by `scenario::replay::save`.
    pub fn open(path: impl AsRef<Path>) -> Result<Self> {
        let bytes = std::fs::read(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::from_bytes(bytes)
            .with_context(|| format!("parsing {}", path.as_ref().display()))
    }
}

impl TraceSource for ReplaySource {
    fn total_jobs(&self) -> usize {
        self.total
    }
    fn last_arrival_s(&self) -> f64 {
        self.last_arrival
    }
    fn next_job(&mut self) -> Option<JobSpec> {
        if self.next_id == self.total {
            return None;
        }
        let p = self.pos;
        let b = &self.bytes;
        let job = JobSpec {
            id: self.next_id,
            llm: Llm::ALL[u32_at(b, p) as usize],
            task_id: u32_at(b, p + 4) as usize,
            traced_gpus: u32_at(b, p + 8) as usize,
            submit_s: f64_at(b, p + 12),
            duration_s: f64_at(b, p + 20),
            base_iters: f64_at(b, p + 28),
            user_prompt_quality: f64_at(b, p + 36),
            slo_s: f64_at(b, p + 44),
        };
        self.pos += REPLAY_RECORD_BYTES;
        self.next_id += 1;
        Some(job)
    }
}

// ---------------------------------------------------------------- scale

const PHI: u64 = 0x9E37_79B9_7F4A_7C15;
const SCALE_COUNT_STREAM: u64 = 0x5CA1_E000_C000;
const SCALE_JOB_STREAM: u64 = 0x5CA1_E000_0B00;

/// Configuration of the hyperscale streaming generator.
#[derive(Clone, Debug)]
pub struct ScaleSourceConfig {
    pub seed: u64,
    /// Trace span in minutes (a multi-day trace is just a big number —
    /// memory stays one minute's batch regardless).
    pub minutes: usize,
    /// Mean arrivals per minute across the whole span.
    pub jobs_per_minute: f64,
    /// SLO emergence factor S (same meaning as `TraceConfig`).
    pub slo_emergence: f64,
    /// Task-universe size.
    pub n_tasks: usize,
    /// First task id. `0` draws from the seeded-corpus range; the
    /// hyperscale sweep uses `scenario::NOVEL_TASK_BASE` so every task
    /// starts cold and the bank/gossip flywheel carries the signal.
    pub task_base: usize,
    /// Fraction of spike minutes and their traffic multiplier (Fig 2b).
    pub spike_frac: f64,
    pub spike_mult: f64,
    pub duration: DurationDist,
}

impl Default for ScaleSourceConfig {
    fn default() -> Self {
        ScaleSourceConfig {
            seed: 42,
            minutes: 60,
            jobs_per_minute: 8.0,
            slo_emergence: 1.0,
            n_tasks: 64,
            task_base: 0,
            spike_frac: 0.10,
            spike_mult: 8.0,
            duration: DurationDist::PAPER,
        }
    }
}

/// Streaming generator for hyperscale traces: arrivals are produced one
/// minute-batch at a time from per-minute hash-keyed draws, so a
/// multi-day million-job trace is never resident — only the current
/// minute's batch is. Both the per-minute arrival *count* and the job
/// *contents* are pure functions of `(seed, minute)`, drawn from two
/// independent keyed streams, which buys two properties:
///
/// * `total_jobs` is an O(minutes) pre-pass over the count stream alone
///   (no job sampling), satisfying the [`TraceSource`] contract;
/// * regeneration is trivially bit-deterministic — `materialize` and a
///   fresh streaming pass agree exactly (property-enforced).
pub struct ScaleSource {
    cfg: ScaleSourceConfig,
    total: usize,
    last_arrival: f64,
    perf: PerfModel,
    minute: usize,
    buf: Vec<JobSpec>,
    buf_pos: usize,
    next_id: usize,
}

impl ScaleSource {
    pub fn new(cfg: ScaleSourceConfig) -> Self {
        let perf = PerfModel::default();
        let mut src = ScaleSource {
            cfg,
            total: 0,
            last_arrival: 0.0,
            perf,
            minute: 0,
            buf: vec![],
            buf_pos: 0,
            next_id: 0,
        };
        // O(minutes) pre-pass: totals from the count stream, the last
        // arrival from the final non-empty minute's batch.
        let mut total = 0usize;
        let mut last_nonempty = None;
        for m in 0..src.cfg.minutes {
            let c = src.minute_count(m);
            total += c;
            if c > 0 {
                last_nonempty = Some(m);
            }
        }
        src.total = total;
        if let Some(m) = last_nonempty {
            let mut batch = vec![];
            src.fill_minute(m, &mut batch);
            src.last_arrival = batch
                .last()
                .map(|j| j.submit_s)
                .expect("non-empty minute produced an empty batch");
        }
        src
    }

    pub fn cfg(&self) -> &ScaleSourceConfig {
        &self.cfg
    }

    /// Arrival count of minute `m`: Bernoulli-rounded rate from the
    /// keyed count stream (mean `jobs_per_minute`, spike minutes ~8x).
    fn minute_count(&self, m: usize) -> usize {
        let mut rng = Rng::new(
            self.cfg.seed
                ^ SCALE_COUNT_STREAM
                ^ (m as u64 + 1).wrapping_mul(PHI),
        );
        let spike = rng.f64() < self.cfg.spike_frac;
        let base = 0.3 + rng.f64();
        let w = if spike { self.cfg.spike_mult * base } else { base };
        // E[base] = 0.8, so this normalization keeps E[count] at exactly
        // jobs_per_minute whatever the spike parameters are.
        let mean_w = 0.8
            * ((1.0 - self.cfg.spike_frac)
                + self.cfg.spike_frac * self.cfg.spike_mult);
        let rate = self.cfg.jobs_per_minute * w / mean_w;
        let mut count = rate.floor();
        if rng.f64() < rate - count {
            count += 1.0;
        }
        count as usize
    }

    /// Generate minute `m`'s batch, submit-sorted, ids unassigned (the
    /// streaming cursor assigns dense global ids on yield).
    fn fill_minute(&self, m: usize, out: &mut Vec<JobSpec>) {
        out.clear();
        let count = self.minute_count(m);
        let mut rng = Rng::new(
            self.cfg.seed
                ^ SCALE_JOB_STREAM
                ^ (m as u64 + 1).wrapping_mul(PHI),
        );
        for _ in 0..count {
            let llm = Llm::MAIN[rng.below(Llm::MAIN.len())];
            let submit_s = m as f64 * 60.0 + rng.f64() * 60.0;
            out.push(self.sample_job(llm, submit_s, &mut rng));
        }
        out.sort_by(|a, b| a.submit_s.partial_cmp(&b.submit_s).unwrap());
    }

    /// Same job model as `TraceGenerator::sample_job`, fed from the
    /// minute's keyed stream.
    fn sample_job(&self, llm: Llm, submit_s: f64, rng: &mut Rng) -> JobSpec {
        let duration_s = self.cfg.duration.sample(rng);
        let per = llm.gpus_per_replica();
        let replicas = *[1usize, 1, 1, 2, 2, 4].get(rng.below(6)).unwrap_or(&1);
        let traced_gpus = per * replicas;
        let iters_med = duration_s / self.perf.iter_time(llm, traced_gpus);
        let base_iters = iters_med / ita_multiplier(MEDIAN_USER_QUALITY);
        let user_prompt_quality = rng.beta(2.2, 1.8).clamp(0.02, 0.98);
        let slo_s =
            duration_s * self.cfg.slo_emergence + self.perf.cold_start(llm);
        JobSpec {
            id: 0, // assigned at yield
            llm,
            task_id: self.cfg.task_base + rng.below(self.cfg.n_tasks),
            submit_s,
            duration_s,
            traced_gpus,
            base_iters,
            user_prompt_quality,
            slo_s,
        }
    }

    /// Materialize the whole stream (small configs / equivalence tests
    /// only — this is exactly what streaming exists to avoid at scale).
    pub fn materialize(&self) -> Vec<JobSpec> {
        let mut fresh = ScaleSource::new(self.cfg.clone());
        let mut jobs = Vec::with_capacity(fresh.total);
        while let Some(j) = fresh.next_job() {
            jobs.push(j);
        }
        jobs
    }
}

impl TraceSource for ScaleSource {
    fn total_jobs(&self) -> usize {
        self.total
    }
    fn last_arrival_s(&self) -> f64 {
        self.last_arrival
    }
    fn next_job(&mut self) -> Option<JobSpec> {
        loop {
            if self.buf_pos < self.buf.len() {
                let mut job = self.buf[self.buf_pos].clone();
                self.buf_pos += 1;
                job.id = self.next_id;
                self.next_id += 1;
                return Some(job);
            }
            if self.minute == self.cfg.minutes {
                return None;
            }
            let m = self.minute;
            self.minute += 1;
            let mut buf = std::mem::take(&mut self.buf);
            self.fill_minute(m, &mut buf);
            self.buf = buf;
            self.buf_pos = 0;
        }
    }
}

// ------------------------------------------------------------ histogram

/// Streaming counterpart of [`crate::trace::arrivals_per_minute`]: the
/// same per-minute binning fed one arrival at a time, so the hyperscale
/// sweep's traffic telemetry never needs the full job slice either.
#[derive(Clone, Debug)]
pub struct ArrivalHistogram {
    counts: Vec<usize>,
}

impl ArrivalHistogram {
    pub fn new(window_s: f64) -> Self {
        let minutes = (window_s / 60.0).ceil() as usize;
        ArrivalHistogram { counts: vec![0; minutes] }
    }

    /// Record one arrival (same clamp-into-last-bin rule as the batch
    /// helper).
    pub fn record(&mut self, submit_s: f64) {
        if self.counts.is_empty() {
            return;
        }
        let m = ((submit_s / 60.0) as usize).min(self.counts.len() - 1);
        self.counts[m] += 1;
    }

    pub fn counts(&self) -> &[usize] {
        &self.counts
    }

    pub fn total(&self) -> usize {
        self.counts.iter().sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scenario::replay;
    use crate::trace::generator::{arrivals_per_minute, Load, TraceConfig,
                                  TraceGenerator};

    fn trace(seed: u64) -> Vec<JobSpec> {
        let mut g = TraceGenerator::new(
            TraceConfig { seed, ..Default::default() },
            PerfModel::default(),
        );
        g.generate_main(Load::Low)
    }

    fn assert_specs_equal(a: &JobSpec, b: &JobSpec) {
        assert_eq!(a.id, b.id);
        assert_eq!(a.llm, b.llm);
        assert_eq!(a.task_id, b.task_id);
        assert_eq!(a.traced_gpus, b.traced_gpus);
        assert_eq!(a.submit_s.to_bits(), b.submit_s.to_bits());
        assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
        assert_eq!(a.base_iters.to_bits(), b.base_iters.to_bits());
        assert_eq!(
            a.user_prompt_quality.to_bits(),
            b.user_prompt_quality.to_bits()
        );
        assert_eq!(a.slo_s.to_bits(), b.slo_s.to_bits());
    }

    #[test]
    fn vec_source_yields_the_trace_verbatim() {
        let jobs = trace(1);
        let expect_last =
            jobs.iter().map(|j| j.submit_s).fold(0.0f64, f64::max);
        let mut src = VecSource::new(jobs.clone());
        assert_eq!(src.total_jobs(), jobs.len());
        assert_eq!(src.last_arrival_s().to_bits(), expect_last.to_bits());
        for j in &jobs {
            assert_specs_equal(j, &src.next_job().unwrap());
        }
        assert!(src.next_job().is_none());
        assert_eq!(VecSource::new(vec![]).last_arrival_s(), 0.0);
    }

    #[test]
    fn replay_source_matches_batch_loader_bitwise() {
        let jobs = trace(2);
        let bytes = replay::to_bytes(&jobs);
        let batch = replay::from_bytes(&bytes).unwrap();
        let mut src = ReplaySource::from_bytes(bytes).unwrap();
        assert_eq!(src.total_jobs(), batch.len());
        let expect_last =
            batch.iter().map(|j| j.submit_s).fold(0.0f64, f64::max);
        assert_eq!(src.last_arrival_s().to_bits(), expect_last.to_bits());
        for j in &batch {
            assert_specs_equal(j, &src.next_job().unwrap());
        }
        assert!(src.next_job().is_none());
    }

    #[test]
    fn replay_source_rejects_malformed_inputs() {
        assert!(ReplaySource::from_bytes(vec![]).is_err());
        assert!(ReplaySource::from_bytes(vec![0u8; 12]).is_err());
        let jobs = trace(3);
        let bytes = replay::to_bytes(&jobs);
        // truncated record
        assert!(
            ReplaySource::from_bytes(bytes[..bytes.len() - 4].to_vec())
                .is_err()
        );
        // unsorted file: streaming cannot reorder, so it must refuse
        let mut rev = jobs.clone();
        rev.reverse();
        assert!(ReplaySource::from_bytes(replay::to_bytes(&rev)).is_err());
        // non-physical value
        let mut bad = jobs;
        bad[2].traced_gpus = 0;
        assert!(ReplaySource::from_bytes(replay::to_bytes(&bad)).is_err());
    }

    #[test]
    fn scale_source_stream_matches_materialize_bitwise() {
        let cfg = ScaleSourceConfig {
            seed: 7,
            minutes: 30,
            jobs_per_minute: 5.0,
            ..Default::default()
        };
        let mut src = ScaleSource::new(cfg.clone());
        let jobs = src.materialize();
        assert_eq!(jobs.len(), src.total_jobs());
        let mut prev = 0.0f64;
        for (i, j) in jobs.iter().enumerate() {
            assert_eq!(j.id, i);
            assert!(j.submit_s >= prev, "unsorted at {i}");
            prev = j.submit_s;
            assert_specs_equal(j, &src.next_job().unwrap());
        }
        assert!(src.next_job().is_none());
        let expect_last =
            jobs.iter().map(|j| j.submit_s).fold(0.0f64, f64::max);
        assert_eq!(src.last_arrival_s().to_bits(), expect_last.to_bits());
    }

    #[test]
    fn scale_source_rate_and_determinism() {
        let cfg = ScaleSourceConfig {
            seed: 11,
            minutes: 240,
            jobs_per_minute: 10.0,
            ..Default::default()
        };
        let a = ScaleSource::new(cfg.clone());
        let b = ScaleSource::new(cfg.clone());
        assert_eq!(a.total_jobs(), b.total_jobs());
        assert_eq!(a.last_arrival_s().to_bits(), b.last_arrival_s().to_bits());
        // mean rate lands near the configured one (law of large numbers
        // over 240 keyed minutes; generous band for spike variance)
        let mean = a.total_jobs() as f64 / cfg.minutes as f64;
        assert!((5.0..20.0).contains(&mean), "mean {mean}");
        // a different seed moves the stream
        let c = ScaleSource::new(ScaleSourceConfig { seed: 12, ..cfg });
        assert!(
            c.total_jobs() != a.total_jobs()
                || c.last_arrival_s() != a.last_arrival_s()
        );
    }

    #[test]
    fn scale_source_task_base_offsets_tasks() {
        let cfg = ScaleSourceConfig {
            seed: 5,
            minutes: 10,
            jobs_per_minute: 6.0,
            task_base: 4096,
            n_tasks: 32,
            ..Default::default()
        };
        let jobs = ScaleSource::new(cfg).materialize();
        assert!(!jobs.is_empty());
        for j in &jobs {
            assert!((4096..4128).contains(&j.task_id), "task {}", j.task_id);
        }
    }

    #[test]
    fn arrival_histogram_matches_batch_helper() {
        let jobs = trace(4);
        let window = 1200.0;
        let batch = arrivals_per_minute(&jobs, window);
        let mut h = ArrivalHistogram::new(window);
        for j in &jobs {
            h.record(j.submit_s);
        }
        assert_eq!(h.counts(), &batch[..]);
        assert_eq!(h.total(), jobs.len());
        // out-of-window arrivals clamp into the last bin, same as batch
        let mut h2 = ArrivalHistogram::new(120.0);
        h2.record(10_000.0);
        assert_eq!(h2.counts(), &[0, 1]);
    }
}
