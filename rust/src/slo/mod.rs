//! SLO telemetry & error-budget control plane.
//!
//! The simulator computes SLO attainment *after* a run finishes
//! (`SimResult::violation_rate`); this subsystem observes deadlines
//! *online* and feeds the signal back into admission and capacity
//! decisions, turning the offline scheduler comparison into a
//! serviceable control loop:
//!
//! * **SLI windows** ([`window`]) — rolling per-class/per-LLM indicators
//!   (attainment, p50/p99 lateness, queue depth), fed by the simulator's
//!   event-stream observer hook ([`crate::cluster::SimObserver`]);
//! * **error budgets & burn rates** ([`budget`]) — configurable SLO
//!   targets with fast/slow multi-window burn-rate computation (the SRE
//!   multiwindow alerting shape);
//! * **controllers** ([`control`]) — an [`AdmissionController`] that
//!   defers provably-unmeetable jobs at arrival, and the [`Governed`]
//!   policy wrapper that scales billable capacity up when the burn rate
//!   pages and releases it as the budget recovers. Works over PromptTuner
//!   *and* both baselines through the [`crate::cluster::Policy`] trait's
//!   `set_capacity` knob, so it can never break the cluster invariants
//!   (busy ≤ billable ≤ budget) the simulation oracle audits.
//! * **self-tuning** ([`tuner`]) — the [`Tuned`] policy wrapper that
//!   races seeded lattice configurations of any knob-declaring policy
//!   (successive halving, budget-guarded exploration, fast-burn
//!   reverts) and promotes the winner only if it did not lose to the
//!   hand-set incumbent on attainment.
//!
//! Everything here is deterministic (no RNG state survives a decision —
//! tuner arm lattices are pure hashes of the seed — and no wall clock)
//! and purely trait-driven, so governed and tuned runs stay
//! bit-reproducible per seed and oracle-clean.

pub mod budget;
pub mod control;
pub mod monitor;
pub mod tuner;
pub mod window;

pub use budget::{BurnGauge, ErrorBudget};
pub use control::{Admission, AdmissionController, Governed, GovernorConfig};
pub use monitor::{AttainmentCell, SloMonitor};
pub use tuner::{Tuned, TunerConfig};
pub use window::{nearest_rank, SliWindow};

use crate::scenario::TENANT_TIERS;
use crate::workload::{JobSpec, PerfModel};

/// Number of service classes (SLO tiers) telemetry buckets jobs into.
pub const N_CLASS: usize = TENANT_TIERS.len();

/// SLO targets and burn-window parameters shared by the monitor and the
/// controllers.
#[derive(Clone, Debug)]
pub struct SloConfig {
    /// Target SLO attainment (fraction of jobs meeting their deadline);
    /// the error budget is `1 − target_attainment`.
    pub target_attainment: f64,
    /// Fast burn window, seconds — reacts to storms quickly.
    pub fast_window_s: f64,
    /// Slow burn window, seconds — confirms the burn is sustained.
    pub slow_window_s: f64,
    /// Minimum fast-window samples before the burn gauge may fire.
    pub min_samples: usize,
}

impl Default for SloConfig {
    fn default() -> Self {
        SloConfig {
            target_attainment: 0.9,
            fast_window_s: 120.0,
            slow_window_s: 600.0,
            min_samples: 5,
        }
    }
}

/// Service class of a job: the nearest [`TENANT_TIERS`] SLO tier implied
/// by its spec (`(slo − cold_start) / duration` recovers the emergence
/// factor S the generator applied, and the multi-tenant scenario's tier
/// factors on top of it). Single-tenant traces all map to the S = 1.0
/// class; multi-tenant traces split cleanly across the four tiers.
pub fn service_class(spec: &JobSpec, perf: &PerfModel) -> usize {
    let implied = (spec.slo_s - perf.cold_start(spec.llm)) / spec.duration_s;
    let mut best = 0usize;
    let mut best_d = f64::INFINITY;
    for (i, &tier) in TENANT_TIERS.iter().enumerate() {
        let d = (implied - tier).abs();
        if d < best_d {
            best_d = d;
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::Llm;

    fn spec_with_tier(tier: f64, perf: &PerfModel) -> JobSpec {
        let duration = 100.0;
        JobSpec {
            id: 0,
            llm: Llm::Gpt2B,
            task_id: 0,
            submit_s: 0.0,
            duration_s: duration,
            traced_gpus: 1,
            base_iters: 10.0,
            user_prompt_quality: 0.5,
            slo_s: duration * tier + perf.cold_start(Llm::Gpt2B),
        }
    }

    #[test]
    fn service_class_recovers_tenant_tiers() {
        let perf = PerfModel::default();
        for (i, &tier) in TENANT_TIERS.iter().enumerate() {
            assert_eq!(service_class(&spec_with_tier(tier, &perf), &perf), i);
        }
        // off-grid values snap to the nearest tier
        assert_eq!(service_class(&spec_with_tier(0.1, &perf), &perf), 0);
        assert_eq!(
            service_class(&spec_with_tier(9.0, &perf), &perf),
            TENANT_TIERS.len() - 1
        );
    }

    #[test]
    fn multi_tenant_scenario_spans_all_classes() {
        use crate::scenario::Scenario;
        let sc = Scenario::MultiTenant { tenants: 4, jobs_per_tenant: 40 };
        let jobs = sc.generate(7, 1.0).unwrap();
        let perf = PerfModel::default();
        let mut seen = [false; N_CLASS];
        for j in &jobs {
            seen[service_class(j, &perf)] = true;
        }
        assert!(seen.iter().all(|&s| s), "{seen:?}");
    }
}
