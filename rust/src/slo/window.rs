//! Rolling-window service-level indicators (SLIs): the online
//! counterpart of `SimResult::violation_rate`. A [`SliWindow`] holds the
//! recent completion observations of one key (service class, LLM, or the
//! whole cluster) and answers attainment / bad-fraction /
//! lateness-quantile queries over a fixed trailing time window.

use std::collections::VecDeque;

/// Nearest-rank quantile over an ascending-sorted slice (q clamped to
/// [0, 1]); 0 when empty. Shared by the rolling windows and the lifetime
/// attainment table so both report identical percentile semantics.
pub fn nearest_rank(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    let rank = ((q.clamp(0.0, 1.0) * n as f64).ceil() as usize).clamp(1, n);
    sorted[rank - 1]
}

/// One observation: a job that finished (or was proven hopeless) at `t`.
#[derive(Clone, Copy, Debug)]
struct Sample {
    t: f64,
    met: bool,
    lateness_s: f64,
}

/// A trailing-time-window SLI accumulator. [`SliWindow::record`] must be
/// called with non-decreasing timestamps (simulated time is monotone);
/// samples older than the window are evicted on every record/advance.
#[derive(Clone, Debug)]
pub struct SliWindow {
    window_s: f64,
    samples: VecDeque<Sample>,
    met_in_window: usize,
    /// Lifetime observation count (never evicted).
    pub total_seen: u64,
    /// Lifetime SLO-met count.
    pub total_met: u64,
}

impl SliWindow {
    pub fn new(window_s: f64) -> Self {
        SliWindow {
            window_s,
            samples: VecDeque::new(),
            met_in_window: 0,
            total_seen: 0,
            total_met: 0,
        }
    }

    pub fn window_s(&self) -> f64 {
        self.window_s
    }

    /// Record one observation at time `t`. `lateness_s` is how far past
    /// its deadline the job finished (0 when the SLO was met).
    pub fn record(&mut self, t: f64, met: bool, lateness_s: f64) {
        debug_assert!(lateness_s >= 0.0);
        self.evict(t);
        self.samples.push_back(Sample { t, met, lateness_s });
        if met {
            self.met_in_window += 1;
            self.total_met += 1;
        }
        self.total_seen += 1;
    }

    /// Advance time without recording (evicts stale samples so queries at
    /// `now` see only the trailing window).
    pub fn advance(&mut self, now: f64) {
        self.evict(now);
    }

    fn evict(&mut self, now: f64) {
        while let Some(s) = self.samples.front() {
            if now - s.t > self.window_s {
                if s.met {
                    self.met_in_window -= 1;
                }
                self.samples.pop_front();
            } else {
                break;
            }
        }
    }

    /// Samples currently inside the window.
    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    /// SLO attainment over the window; None when the window is empty
    /// (no evidence either way).
    pub fn attainment(&self) -> Option<f64> {
        if self.samples.is_empty() {
            None
        } else {
            Some(self.met_in_window as f64 / self.samples.len() as f64)
        }
    }

    /// Fraction of SLO-missing samples in the window (0 when empty — an
    /// empty window burns no budget).
    pub fn bad_fraction(&self) -> f64 {
        match self.attainment() {
            Some(a) => 1.0 - a,
            None => 0.0,
        }
    }

    /// Nearest-rank lateness quantile (q in [0, 1]) over the window's
    /// samples; 0 when the window is empty.
    pub fn lateness_quantile(&self, q: f64) -> f64 {
        let mut xs: Vec<f64> =
            self.samples.iter().map(|s| s.lateness_s).collect();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        nearest_rank(&xs, q)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn window_attainment_and_eviction() {
        let mut w = SliWindow::new(10.0);
        assert!(w.attainment().is_none());
        assert_eq!(w.bad_fraction(), 0.0);
        w.record(0.0, true, 0.0);
        w.record(1.0, false, 5.0);
        assert_eq!(w.len(), 2);
        assert_eq!(w.attainment(), Some(0.5));
        assert_eq!(w.bad_fraction(), 0.5);
        // at t = 10.5 the t = 0 sample ages out, the t = 1 sample stays
        w.advance(10.5);
        assert_eq!(w.len(), 1);
        assert_eq!(w.attainment(), Some(0.0));
        assert_eq!(w.bad_fraction(), 1.0);
        // lifetime totals are never evicted
        assert_eq!(w.total_seen, 2);
        assert_eq!(w.total_met, 1);
        w.advance(100.0);
        assert!(w.is_empty());
        assert!(w.attainment().is_none());
    }

    #[test]
    fn lateness_quantiles_nearest_rank() {
        let mut w = SliWindow::new(100.0);
        for i in 0..10 {
            w.record(i as f64, false, i as f64);
        }
        assert_eq!(w.lateness_quantile(0.5), 4.0); // rank 5 of 10
        assert_eq!(w.lateness_quantile(0.99), 9.0); // rank 10
        assert_eq!(w.lateness_quantile(0.0), 0.0); // rank clamped to 1
        assert_eq!(w.lateness_quantile(1.0), 9.0);
        assert_eq!(SliWindow::new(1.0).lateness_quantile(0.5), 0.0);
    }

    #[test]
    fn record_evicts_as_it_goes() {
        let mut w = SliWindow::new(5.0);
        for i in 0..20 {
            w.record(i as f64, i % 2 == 0, 0.0);
        }
        // at t = 19 the window holds t in [14, 19]: 6 samples
        assert_eq!(w.len(), 6);
        assert_eq!(w.attainment(), Some(0.5));
        assert_eq!(w.total_seen, 20);
    }
}
