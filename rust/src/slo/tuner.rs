//! Self-tuning control plane: the [`Tuned`] policy wrapper closes the
//! loop on the knobs the rest of the crate hand-sets (ROADMAP item 5).
//!
//! The system accumulated many hand-set constants — the cold-pool GPU
//! budget, the Prompt-Bank ceiling, the §4.4.1 lookup-latency budget,
//! the checkpoint period. SCOOT and SLO-Guard (PAPERS.md) show that
//! tuning exactly these serving-system knobs *online against the SLO
//! signal* recovers attainment/cost headroom hand-tuning leaves on the
//! table — provided exploration is budget-consistent (a bounded share
//! of the error budget may be spent probing) and crash-guarded (an arm
//! that burns hot is abandoned immediately).
//!
//! [`Tuned`] wraps any [`Policy`] that declares knobs
//! ([`Policy::knobs`]) and races a deterministic, seeded set of
//! configurations ("arms") drawn from the declared lattice with
//! successive halving: every arm is measured for
//! [`TunerConfig::windows_per_arm`] evaluation windows against the
//! multiwindow burn-rate signal ([`SloMonitor`]/[`crate::slo::budget`]),
//! the worse half is eliminated each rung (the incumbent is immune),
//! and the last survivor is promoted only if it did not lose to the
//! incumbent on attainment. Guards, in the SLO-Guard shape:
//!
//! * **fast-burn revert** — an exploration arm whose window pushes the
//!   fast burn rate past [`TunerConfig::revert_burn`] is reverted to
//!   the incumbent at the boundary and eliminated;
//! * **exploration budget cap** — at most
//!   [`TunerConfig::explore_budget_frac`] of the rolling error budget
//!   may be spent on SLO misses observed under exploration arms; past
//!   the cap, exploration freezes and the incumbent is pinned for the
//!   rest of the run.
//!
//! Every decision is appended to a [`TunerLog`] and checked against
//! [`StateAudit::check_tuner`] at the boundary it lands on (knob values
//! inside the declared lattice, one decision batch per evaluation
//! window, reverts restoring the incumbent bit-exactly) — a violation
//! is a programming error and panics, benches included.
//!
//! Determinism follows the [`Governed`](crate::slo::Governed) template:
//! evaluation instants live on an *absolute* time grid declared through
//! [`Wake::At`], every knob move happens inside a mutating callback at
//! such a boundary, and arm lattices are pure hashes of the seed — so
//! tuned runs are bit-identical under dense and coalesced ticking, and
//! a [`TunerConfig::explore`]` = false` wrapper never calls
//! [`Policy::set_knob`] at all and is a bit-exact pass-through
//! (property-enforced in `tests/prop_policies.rs`).

use crate::cluster::{ClusterState, KnobSpec, KnobStat, Policy, RetryEvent,
                     RevokeEvent, StateAudit, TunedPrompt, TunerAction,
                     TunerDecision, TunerLog, TunerReport, Wake};
use crate::slo::monitor::SloMonitor;
use crate::slo::SloConfig;
use crate::util::rng::Rng;
use crate::workload::Llm;

/// Tuner parameters. Defaults size the race for multi-hour scenario
/// traces: 6 arms × 2 windows × 30 s converges in roughly 12 minutes
/// of simulated time, leaving the bulk of the run to exploit the
/// winner.
#[derive(Clone, Debug)]
pub struct TunerConfig {
    /// SLO target and burn windows for the tuner's own monitor.
    pub slo: SloConfig,
    /// Evaluation-window period, seconds (the decision grid).
    pub eval_period_s: f64,
    /// Evaluation windows each live arm is measured for per rung.
    pub windows_per_arm: usize,
    /// Total arms, incumbent included (arm 0 is always the incumbent
    /// configuration).
    pub n_arms: usize,
    /// Master switch: `false` never calls `set_knob` — the wrapper is a
    /// bit-exact pass-through (property-enforced).
    pub explore: bool,
    /// Hard cap on exploration spend, as a fraction of the error
    /// budget: exploration freezes once the SLO misses observed under
    /// exploration arms exceed `explore_budget_frac × budget_frac ×
    /// total completions`.
    pub explore_budget_frac: f64,
    /// Fast-burn rate at which a live exploration arm is immediately
    /// reverted to the incumbent and eliminated.
    pub revert_burn: f64,
    /// Seed for the deterministic arm-lattice assignment.
    pub seed: u64,
}

impl Default for TunerConfig {
    fn default() -> Self {
        TunerConfig {
            slo: SloConfig::default(),
            eval_period_s: 30.0,
            windows_per_arm: 2,
            n_arms: 6,
            explore: true,
            explore_budget_frac: 0.25,
            revert_burn: 2.0,
            seed: 1,
        }
    }
}

/// Measurement accumulated for one arm over its rung windows.
#[derive(Clone, Copy, Debug, Default)]
struct ArmScore {
    bad: u64,
    total: u64,
    /// Eliminated by the fast-burn guard: ranks behind everything.
    burned: bool,
}

impl ArmScore {
    fn bad_frac(&self) -> f64 {
        if self.burned {
            f64::INFINITY
        } else if self.total == 0 {
            0.0
        } else {
            self.bad as f64 / self.total as f64
        }
    }
}

/// The race state machine: which arm is on the cluster right now.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Phase {
    /// `alive[pos]` is applied; measurement started at the recorded
    /// budget counters.
    Measuring { pos: usize },
    /// Converged (or frozen): the incumbent is pinned, no more
    /// boundaries are declared.
    Done,
}

/// Online knob tuner over any knob-declaring [`Policy`] — see the
/// module docs for the algorithm and guards.
pub struct Tuned<P: Policy> {
    inner: P,
    pub cfg: TunerConfig,
    pub monitor: SloMonitor,
    name: String,
    started: bool,
    needs_round: bool,
    next_eval_t: f64,
    /// Knob lattice snapshot (taken once, before any mutation).
    specs: Vec<KnobSpec>,
    /// Incumbent values per knob (snapshot of the hand-set config,
    /// updated only by promotion).
    incumbent: Vec<f64>,
    /// Arm → per-knob values; `arms[0]` is the incumbent snapshot.
    arms: Vec<Vec<f64>>,
    /// Arms still racing this rung (always contains arm 0).
    alive: Vec<usize>,
    scores: Vec<ArmScore>,
    phase: Phase,
    /// Arm whose configuration is currently applied to the cluster.
    active_arm: usize,
    /// Windows the active arm has been measured for.
    windows_done: usize,
    /// Budget counters at the active arm's measurement start.
    mark_bad: u64,
    mark_total: u64,
    /// SLO misses completed while a non-incumbent arm was active.
    explore_bad: u64,
    frozen: bool,
    promotions: usize,
    reverts: usize,
    log: TunerLog,
    min_seen: Vec<f64>,
    max_seen: Vec<f64>,
}

impl<P: Policy> Tuned<P> {
    pub fn new(inner: P, cfg: TunerConfig) -> Self {
        let name = format!("{}+tuned", inner.name());
        let monitor = SloMonitor::new(cfg.slo.clone());
        Tuned {
            inner,
            monitor,
            name,
            started: false,
            needs_round: true,
            next_eval_t: 0.0,
            specs: vec![],
            incumbent: vec![],
            arms: vec![],
            alive: vec![],
            scores: vec![],
            phase: Phase::Done,
            active_arm: 0,
            windows_done: 0,
            mark_bad: 0,
            mark_total: 0,
            explore_bad: 0,
            frozen: false,
            promotions: 0,
            reverts: 0,
            log: TunerLog::default(),
            min_seen: vec![],
            max_seen: vec![],
        }
    }

    pub fn into_inner(self) -> P {
        self.inner
    }

    /// The audited decision log.
    pub fn log(&self) -> &TunerLog {
        &self.log
    }

    /// The knob-lattice snapshot the race runs over (empty until the
    /// first event, or when the inner policy declares nothing).
    pub fn specs(&self) -> &[KnobSpec] {
        &self.specs
    }

    /// Run [`StateAudit::check_tuner`] over the decision log as it
    /// stands. Called internally after every decision batch (a
    /// violation panics — it is a tuner bug, not a workload property);
    /// public so tests and harnesses can re-assert on the final log.
    pub fn audit_violations(&self) -> Vec<String> {
        let mut out = vec![];
        StateAudit::check_tuner(
            &self.log,
            &self.specs,
            self.arms.first().map(Vec::as_slice).unwrap_or(&[]),
            self.cfg.eval_period_s,
            &mut out,
        );
        out
    }

    fn ensure_started(&mut self, st: &mut ClusterState) {
        if self.started {
            return;
        }
        self.started = true;
        let _ = st;
        if !self.cfg.explore {
            return; // pass-through: no snapshot, no grid, no decisions
        }
        // Snapshot the declared lattice and the hand-set (incumbent)
        // values *before* any mutation, so bounds and the revert target
        // cannot drift however the knobs move later.
        self.specs = self
            .inner
            .knobs()
            .into_iter()
            .filter(|s| self.inner.knob_value(s.name).is_some())
            .collect();
        if self.specs.is_empty() {
            return; // nothing declared: stay a pass-through
        }
        self.incumbent = self
            .specs
            .iter()
            .map(|s| self.inner.knob_value(s.name).expect("filtered above"))
            .collect();
        self.min_seen = self.incumbent.clone();
        self.max_seen = self.incumbent.clone();
        // Arm 0 is the incumbent; arms 1.. are seeded lattice draws
        // (pure hashes — no RNG state survives, so dense and coalesced
        // runs build identical arms).
        let n_arms = self.cfg.n_arms.max(2);
        self.arms.push(self.incumbent.clone());
        for arm in 1..n_arms {
            let values = self
                .specs
                .iter()
                .enumerate()
                .map(|(k, spec)| {
                    let key = self
                        .cfg
                        .seed
                        .wrapping_mul(0x9E37_79B9_7F4A_7C15)
                        .wrapping_add((arm as u64) << 32)
                        .wrapping_add(k as u64 + 1);
                    let idx = Rng::new(key).below(spec.steps.max(2));
                    spec.value_at(idx)
                })
                .collect();
            self.arms.push(values);
        }
        self.alive = (0..n_arms).collect();
        self.scores = vec![ArmScore::default(); n_arms];
        self.phase = Phase::Measuring { pos: 0 };
        self.active_arm = 0;
        self.mark_bad = self.monitor.gauge.budget.bad_seen;
        self.mark_total = self.monitor.gauge.budget.total_seen;
        // First boundary on the absolute grid (strictly after t = 0).
        self.next_eval_t = self.cfg.eval_period_s;
    }

    /// Apply `arm`'s configuration and log one `action` decision per
    /// knob at boundary `t` (one batch: identical timestamps).
    fn apply_arm(&mut self, st: &mut ClusterState, t: f64, arm: usize,
                 action: TunerAction) {
        for (k, spec) in self.specs.iter().enumerate() {
            let value = self.arms[arm][k];
            self.inner.set_knob(st, spec.name, value);
            if value < self.min_seen[k] {
                self.min_seen[k] = value;
            }
            if value > self.max_seen[k] {
                self.max_seen[k] = value;
            }
            self.log.decisions.push(TunerDecision {
                t,
                action,
                arm,
                knob: spec.name,
                value,
            });
        }
        self.active_arm = arm;
        self.needs_round = true;
        match action {
            TunerAction::Promote => self.promotions += 1,
            TunerAction::Revert => self.reverts += 1,
            _ => {}
        }
        // Self-audit the batch just logged: lattice bounds, one batch
        // per window, revert conservation. A violation here is a tuner
        // bug — fail loudly everywhere, benches included.
        let violations = self.audit_violations();
        assert!(
            violations.is_empty(),
            "Tuned[{}]: illegal decision batch: {}",
            self.name,
            violations.join("; ")
        );
    }

    /// Start measuring the arm at `alive[pos]`.
    fn start_measuring(&mut self, st: &mut ClusterState, t: f64, pos: usize) {
        let arm = self.alive[pos];
        self.phase = Phase::Measuring { pos };
        self.windows_done = 0;
        self.mark_bad = self.monitor.gauge.budget.bad_seen;
        self.mark_total = self.monitor.gauge.budget.total_seen;
        self.apply_arm(st, t, arm, TunerAction::Explore);
    }

    /// Rung complete: rank, halve (incumbent immune), and either start
    /// the next rung or settle the race.
    fn finish_rung(&mut self, st: &mut ClusterState, t: f64) {
        // Rank alive arms: attainment first (lower bad fraction), then
        // cheaper capacity, then arm index for determinism.
        let cap_of = |this: &Self, arm: usize| -> f64 {
            this.specs
                .iter()
                .position(|s| s.name == "capacity")
                .map(|k| this.arms[arm][k])
                .unwrap_or(0.0)
        };
        let mut ranked = self.alive.clone();
        ranked.sort_by(|&a, &b| {
            let fa = self.scores[a].bad_frac();
            let fb = self.scores[b].bad_frac();
            fa.partial_cmp(&fb)
                .unwrap()
                .then(
                    cap_of(self, a)
                        .partial_cmp(&cap_of(self, b))
                        .unwrap(),
                )
                .then(a.cmp(&b))
        });
        if ranked.len() <= 2 {
            // Final rung: promote the winner only if it did not lose to
            // the incumbent on attainment — tuning never structurally
            // hurts the SLO.
            let winner = ranked[0];
            if winner != 0
                && self.scores[winner].bad_frac()
                    <= self.scores[0].bad_frac()
            {
                // NB: `arms[0]` keeps the *original* hand-set snapshot —
                // `check_tuner` replays the log from it and tracks the
                // promotion itself; only the live incumbent moves.
                self.incumbent = self.arms[winner].clone();
                self.apply_arm(st, t, winner, TunerAction::Promote);
            } else if self.active_arm != 0 {
                self.apply_arm(st, t, 0, TunerAction::Revert);
            }
            self.phase = Phase::Done;
            return;
        }
        let keep = ranked.len().div_ceil(2);
        let mut kept: Vec<usize> = ranked[..keep].to_vec();
        if !kept.contains(&0) {
            kept.push(0); // the incumbent is immune to elimination
        }
        kept.sort_unstable();
        self.alive = kept;
        for &arm in &self.alive {
            self.scores[arm] = ArmScore::default();
        }
        self.start_measuring(st, t, 0);
    }

    /// One evaluation-window boundary (rate-limited to the absolute
    /// grid, like `Governed::govern`): close the active arm's window,
    /// run the guards, and advance the race.
    fn tune(&mut self, st: &mut ClusterState) {
        let Phase::Measuring { pos } = self.phase else {
            return;
        };
        let now = st.now();
        if now < self.next_eval_t {
            return;
        }
        // Absolute-grid re-arm: evaluation instants are a pure function
        // of simulated time, never of which rounds executed — the
        // backbone of dense/coalesced bit-identity.
        self.next_eval_t = self.cfg.eval_period_s
            * ((now / self.cfg.eval_period_s).floor() + 1.0);
        self.monitor.gauge.advance(now);

        // Budget-consistency guard: exploration may spend at most
        // `explore_budget_frac` of the rolling error budget. Past the
        // cap, pin the incumbent for good.
        let budget = &self.monitor.gauge.budget;
        let cap = self.cfg.explore_budget_frac
            * budget.budget_frac()
            * budget.total_seen as f64;
        if self.explore_bad as f64 > cap {
            if self.active_arm != 0 {
                self.apply_arm(st, now, 0, TunerAction::Freeze);
            } else {
                // Already on the incumbent: log the freeze for audit
                // without moving any knob.
                for (k, spec) in self.specs.iter().enumerate() {
                    self.log.decisions.push(TunerDecision {
                        t: now,
                        action: TunerAction::Freeze,
                        arm: 0,
                        knob: spec.name,
                        value: self.incumbent[k],
                    });
                }
            }
            self.frozen = true;
            self.phase = Phase::Done;
            return;
        }

        // Fast-burn guard: a hot exploration arm is reverted at the
        // first boundary that sees it and eliminated from the race.
        let gauge = &self.monitor.gauge;
        if self.active_arm != 0
            && gauge.fast.len() >= gauge.min_samples
            && gauge.fast_burn() >= self.cfg.revert_burn
        {
            let burned = self.active_arm;
            self.scores[burned].burned = true;
            self.alive.retain(|&a| a != burned);
            self.apply_arm(st, now, 0, TunerAction::Revert);
            // `pos` now indexes the next arm (the burned one was
            // removed); resume the rung at the next boundary.
            if pos >= self.alive.len() {
                self.finish_rung(st, now);
            } else {
                self.phase = Phase::Measuring { pos };
                self.windows_done = 0;
                self.mark_bad = self.monitor.gauge.budget.bad_seen;
                self.mark_total = self.monitor.gauge.budget.total_seen;
            }
            return;
        }

        self.windows_done += 1;
        if self.windows_done < self.cfg.windows_per_arm {
            return; // keep measuring the same arm
        }
        // Window quota reached: book the arm's score and move on.
        let arm = self.alive[pos];
        let score = &mut self.scores[arm];
        score.bad += self.monitor.gauge.budget.bad_seen - self.mark_bad;
        score.total +=
            self.monitor.gauge.budget.total_seen - self.mark_total;
        if pos + 1 < self.alive.len() {
            self.start_measuring(st, now, pos + 1);
        } else {
            self.finish_rung(st, now);
        }
    }

    /// End-of-run telemetry (also available mid-run).
    pub fn report(&self) -> TunerReport {
        TunerReport {
            knobs: self
                .specs
                .iter()
                .enumerate()
                .map(|(k, s)| KnobStat {
                    name: s.name,
                    lo: s.lo,
                    hi: s.hi,
                    value: self.incumbent[k],
                    min_seen: self.min_seen[k],
                    max_seen: self.max_seen[k],
                })
                .collect(),
            decisions: self.log.decisions.len(),
            promotions: self.promotions,
            reverts: self.reverts,
            explore_bad: self.explore_bad as usize,
            frozen: self.frozen,
        }
    }
}

/// Earliest of two wake hints.
fn earliest(a: Wake, b: Wake) -> Wake {
    match (a, b) {
        (Wake::Dense, _) | (_, Wake::Dense) => Wake::Dense,
        (Wake::Idle, w) | (w, Wake::Idle) => w,
        (Wake::At(x), Wake::At(y)) => Wake::At(x.min(y)),
    }
}

impl<P: Policy> Policy for Tuned<P> {
    fn name(&self) -> &str {
        &self.name
    }

    fn tick_interval(&self) -> f64 {
        self.inner.tick_interval()
    }

    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        self.ensure_started(st);
        self.monitor.note_arrival(st);
        self.inner.on_arrival(st, job_id);
        self.tune(st);
        self.needs_round = true;
    }

    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        self.inner.on_job_complete(st, job_id);
        if self.cfg.explore
            && self.active_arm != 0
            && !st.jobs[job_id].met_slo()
        {
            // Exploration spend: an SLO miss completed under a
            // non-incumbent arm is charged to the exploration budget.
            self.explore_bad += 1;
        }
        self.monitor.note_completion(st, job_id, false);
        self.tune(st);
    }

    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        self.inner.on_revoke(st, ev);
        self.needs_round = true;
    }

    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        self.inner.on_retry(st, ev);
        self.tune(st);
        self.needs_round = true;
    }

    fn on_tick(&mut self, st: &mut ClusterState) {
        self.ensure_started(st);
        self.needs_round = false;
        self.inner.on_tick(st);
        self.monitor.note_round(st);
        self.tune(st);
    }

    fn next_timed_action(&self, st: &ClusterState) -> Wake {
        if self.needs_round {
            return Wake::Dense;
        }
        let wake = self.inner.next_timed_action(st);
        // The evaluation grid is declared only while the race is live:
        // rounds before `next_eval_t` are provable no-ops for the tuner
        // (tune() is clock-gated), and once the race settles the
        // wrapper declares nothing — a settled Tuned<P> coalesces
        // exactly like bare P. Merging only ever makes the inner wake
        // *earlier*, so no inner action can be starved.
        if self.cfg.explore && self.phase != Phase::Done {
            earliest(wake, Wake::At(self.next_eval_t))
        } else {
            wake
        }
    }

    fn capacity(&self) -> Option<usize> {
        self.inner.capacity()
    }

    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        self.inner.set_capacity(st, gpus);
        self.needs_round = true;
    }

    // Gossip hooks: pure pass-throughs — the tuner owns no bank.
    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        self.inner.bank_coverage(llm, task_id)
    }

    fn enable_gossip_log(&mut self) {
        self.inner.enable_gossip_log()
    }

    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        self.inner.drain_tuned(out)
    }

    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        self.inner.absorb_tuned(items)
    }

    // Knob hooks are deliberately NOT forwarded: the tuner consumes its
    // inner policy's declarations; re-exporting them outward would
    // invite a second tuner to fight this one over the same knobs.

    fn tuner_report(&self) -> Option<TunerReport> {
        Some(self.report())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SimConfig, SimOracle, Simulator};
    use crate::coordinator::{PromptTuner, PromptTunerConfig};
    use crate::scenario::Scenario;
    use crate::workload::PerfModel;

    fn run_tuned(explore: bool, seed: u64) -> (crate::cluster::SimResult,
                                               TunerReport, Vec<String>) {
        let sc = Scenario::FlashCrowd { storms: 2, intensity: 10.0,
                                        jobs_per_llm: 20 };
        let jobs = sc.generate(seed, 1.0).unwrap();
        let base = 32;
        // Widen the provider budget to the capacity knob's upper bound
        // so an up-lattice arm is actually realizable (mirrors what the
        // bench harness does for governed/tuned cells).
        let sim = Simulator::new(
            SimConfig { max_gpus: base + base / 4, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = Tuned::new(
            PromptTuner::new(PromptTunerConfig {
                max_gpus: base,
                seed,
                ..Default::default()
            }),
            TunerConfig { explore, ..Default::default() },
        );
        let result = sim.run(&mut policy, jobs);
        let report = policy.report();
        let violations = policy.audit_violations();
        (result, report, violations)
    }

    #[test]
    fn exploration_off_is_a_bit_exact_pass_through() {
        let seed = 47;
        let sc = Scenario::FlashCrowd { storms: 2, intensity: 10.0,
                                        jobs_per_llm: 20 };
        let mk_sim = || Simulator::new(
            SimConfig { max_gpus: 32, ..Default::default() },
            PerfModel::default(),
        );
        let mk_inner = || PromptTuner::new(PromptTunerConfig {
            max_gpus: 32,
            seed,
            ..Default::default()
        });
        let bare = mk_sim().run(&mut mk_inner(), sc.generate(seed, 1.0)
                                                    .unwrap());
        let mut wrapped = Tuned::new(
            mk_inner(),
            TunerConfig { explore: false, ..Default::default() },
        );
        let tuned = mk_sim().run(&mut wrapped, sc.generate(seed, 1.0)
                                                  .unwrap());
        assert_eq!(bare.n_done, tuned.n_done);
        assert_eq!(bare.n_violations, tuned.n_violations);
        assert_eq!(bare.cost_usd, tuned.cost_usd);
        assert_eq!(bare.job_latencies, tuned.job_latencies);
        assert_eq!(bare.util_timeline, tuned.util_timeline);
        assert!(wrapped.log().decisions.is_empty(),
                "pass-through must not decide anything");
    }

    #[test]
    fn tuned_runs_are_deterministic_and_legal() {
        let (a, ra, va) = run_tuned(true, 51);
        let (b, rb, vb) = run_tuned(true, 51);
        assert_eq!(a.n_done, b.n_done);
        assert_eq!(a.cost_usd, b.cost_usd);
        assert_eq!(a.job_latencies, b.job_latencies);
        assert_eq!(ra.decisions, rb.decisions);
        assert!(va.is_empty(), "{va:?}");
        assert!(vb.is_empty(), "{vb:?}");
        // The race actually ran: a decision log and full completion.
        assert!(ra.decisions > 0, "tuner never acted");
        assert_eq!(a.n_done, a.n_jobs, "tuned run stranded jobs");
        // Every knob stat stays inside its declared lattice.
        for k in &ra.knobs {
            assert!(k.lo <= k.min_seen && k.max_seen <= k.hi,
                    "{}: [{}, {}] seen [{}, {}]",
                    k.name, k.lo, k.hi, k.min_seen, k.max_seen);
            assert!(k.lo <= k.value && k.value <= k.hi, "{}", k.name);
        }
    }

    #[test]
    fn tuned_run_is_oracle_clean() {
        let seed = 53;
        let sc = Scenario::FlashCrowd { storms: 2, intensity: 10.0,
                                        jobs_per_llm: 20 };
        let sim = Simulator::new(
            SimConfig { max_gpus: 40, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = SimOracle::new(Tuned::new(
            PromptTuner::new(PromptTunerConfig {
                max_gpus: 32,
                seed,
                ..Default::default()
            }),
            TunerConfig::default(),
        ));
        let r = sim.run(&mut policy, sc.generate(seed, 1.0).unwrap());
        assert_eq!(r.n_done, r.n_jobs);
        assert!(policy.audits() > 0);
    }

    #[test]
    fn check_tuner_flags_out_of_lattice_and_mid_window_changes() {
        let specs = [KnobSpec { name: "capacity", lo: 16.0, hi: 40.0,
                                steps: 4 }];
        let incumbent = [32.0];
        // Out-of-lattice value.
        let mut log = TunerLog::default();
        log.decisions.push(TunerDecision {
            t: 30.0, action: TunerAction::Explore, arm: 1,
            knob: "capacity", value: 48.0,
        });
        let mut out = vec![];
        StateAudit::check_tuner(&log, &specs, &incumbent, 30.0, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("lattice"), "{out:?}");
        // Two decision batches inside one window.
        let mut log = TunerLog::default();
        for t in [30.0, 45.0] {
            log.decisions.push(TunerDecision {
                t, action: TunerAction::Explore, arm: 1,
                knob: "capacity", value: 24.0,
            });
        }
        let mut out = vec![];
        StateAudit::check_tuner(&log, &specs, &incumbent, 30.0, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("window"), "{out:?}");
        // A revert that fails to restore the incumbent.
        let mut log = TunerLog::default();
        log.decisions.push(TunerDecision {
            t: 30.0, action: TunerAction::Revert, arm: 0,
            knob: "capacity", value: 24.0,
        });
        let mut out = vec![];
        StateAudit::check_tuner(&log, &specs, &incumbent, 30.0, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert!(out[0].contains("conserve"), "{out:?}");
    }

    #[test]
    fn checkpoint_period_knob_is_declared_and_tunable() {
        use crate::cluster::CheckpointModel;
        use crate::fault::{FaultInjector, FaultPlan};
        let fi = FaultInjector::new(
            PromptTuner::new(PromptTunerConfig::default()),
            FaultPlan::new(vec![]),
            CheckpointModel::default(),
        );
        // The injector declares its own knob on top of the inner set.
        assert!(fi.knobs().iter().any(|s| s.name == "checkpoint_period_s"));
        assert_eq!(fi.knob_value("checkpoint_period_s"), Some(60.0));
        assert_eq!(fi.knob_value("capacity"), Some(32.0));
        // And the tuner can race it end-to-end without stranding jobs.
        let seed = 59;
        let sc = Scenario::FlashCrowd { storms: 2, intensity: 10.0,
                                        jobs_per_llm: 20 };
        let sim = Simulator::new(
            SimConfig { max_gpus: 40, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = Tuned::new(fi, TunerConfig::default());
        let r = sim.run(&mut policy, sc.generate(seed, 1.0).unwrap());
        assert_eq!(r.n_done, r.n_jobs);
        let rep = policy.report();
        assert!(rep.knobs.iter().any(|k| k.name == "checkpoint_period_s"));
        assert!(policy.audit_violations().is_empty());
    }
}
