//! The SLO monitor: per-class / per-LLM rolling SLI windows plus the
//! cluster-aggregate burn gauge, fed by the simulator's event-stream
//! observer hook ([`SimObserver`]) or directly by the control plane
//! (`slo::Governed`). Purely observational — it never touches cluster
//! state.

use crate::cluster::{ClusterState, SimObserver};
use crate::scenario::TENANT_TIERS;
use crate::slo::budget::BurnGauge;
use crate::slo::window::{nearest_rank, SliWindow};
use crate::slo::{service_class, SloConfig, N_CLASS};
use crate::workload::{Llm, N_LLM};

/// Lifetime stats of one (service class, LLM) cell.
#[derive(Clone, Debug, Default)]
struct CellStats {
    jobs: u64,
    met: u64,
    lateness: Vec<f64>,
}

/// One row of the per-tenant attainment table (see
/// `metrics::render_attainment`).
#[derive(Clone, Debug)]
pub struct AttainmentCell {
    /// Service-class index (see [`crate::slo::service_class`]).
    pub class: usize,
    /// SLO tier factor of the class (`scenario::TENANT_TIERS`).
    pub tier: f64,
    pub llm: Llm,
    pub jobs: u64,
    pub met: u64,
    pub p50_lateness_s: f64,
    pub p99_lateness_s: f64,
}

impl AttainmentCell {
    pub fn attainment(&self) -> f64 {
        if self.jobs == 0 {
            1.0
        } else {
            self.met as f64 / self.jobs as f64
        }
    }
}

/// Online SLO telemetry for one simulated run: arrival/completion
/// counters, pending-queue depth, rolling per-LLM and per-class SLI
/// windows, and the aggregate error-budget burn gauge.
pub struct SloMonitor {
    pub cfg: SloConfig,
    /// Cluster-aggregate burn gauge (error budget + fast/slow windows).
    pub gauge: BurnGauge,
    per_llm: [SliWindow; N_LLM],
    per_class: [SliWindow; N_CLASS],
    cells: [[CellStats; N_LLM]; N_CLASS],
    arrived: usize,
    finished: usize,
    /// Peak pending-queue depth observed across the run.
    pub peak_queue_depth: usize,
}

impl SloMonitor {
    pub fn new(cfg: SloConfig) -> Self {
        SloMonitor {
            gauge: BurnGauge::new(&cfg),
            per_llm: std::array::from_fn(|_| SliWindow::new(cfg.fast_window_s)),
            per_class: std::array::from_fn(|_| {
                SliWindow::new(cfg.fast_window_s)
            }),
            cells: Default::default(),
            arrived: 0,
            finished: 0,
            peak_queue_depth: 0,
            cfg,
        }
    }

    pub fn arrived(&self) -> usize {
        self.arrived
    }

    pub fn finished(&self) -> usize {
        self.finished
    }

    /// Jobs submitted but neither holding GPUs nor done.
    pub fn queue_depth(&self, st: &ClusterState) -> usize {
        let holding: usize =
            Llm::ALL.iter().map(|&l| st.active_jobs(l).len()).sum();
        self.arrived.saturating_sub(self.finished + holding)
    }

    /// Rolling SLI window of one LLM.
    pub fn llm_window(&self, llm: Llm) -> &SliWindow {
        &self.per_llm[llm.index()]
    }

    /// Rolling SLI window of one service class.
    pub fn class_window(&self, class: usize) -> &SliWindow {
        &self.per_class[class]
    }

    pub fn note_arrival(&mut self, st: &ClusterState) {
        self.arrived += 1;
        self.note_depth(st);
    }

    /// Observe a completion. `already_burned` marks jobs whose budget hit
    /// was recorded at arrival (`note_doomed`): they still land in the
    /// attainment table and rolling windows, but not in the burn gauge.
    pub fn note_completion(&mut self, st: &ClusterState, job_id: usize,
                           already_burned: bool) {
        self.finished += 1;
        let job = &st.jobs[job_id];
        let met = job.met_slo();
        let lateness = (job.completed_at - job.spec.deadline()).max(0.0);
        let t = st.now();
        let li = job.spec.llm.index();
        let class = service_class(&job.spec, &st.perf);
        if !already_burned {
            self.gauge.record(t, met, lateness);
        }
        self.per_llm[li].record(t, met, lateness);
        self.per_class[class].record(t, met, lateness);
        let cell = &mut self.cells[class][li];
        cell.jobs += 1;
        if met {
            cell.met += 1;
        }
        cell.lateness.push(lateness);
        self.note_depth(st);
    }

    /// A job proven unmeetable at arrival: the violation is certain, so
    /// the budget burns now with the provable minimum lateness (the
    /// eventual completion fills the table without re-burning).
    pub fn note_doomed(&mut self, st: &ClusterState, min_lateness_s: f64) {
        self.gauge.record(st.now(), false, min_lateness_s.max(0.0));
    }

    /// An executed scheduling round finished.
    pub fn note_round(&mut self, st: &ClusterState) {
        self.gauge.advance(st.now());
        self.note_depth(st);
    }

    fn note_depth(&mut self, st: &ClusterState) {
        let depth = self.queue_depth(st);
        if depth > self.peak_queue_depth {
            self.peak_queue_depth = depth;
        }
    }

    /// Lifetime per-(class, LLM) attainment table; empty cells are
    /// skipped.
    pub fn attainment_table(&self) -> Vec<AttainmentCell> {
        let mut out = vec![];
        for (c, row) in self.cells.iter().enumerate() {
            for (li, cell) in row.iter().enumerate() {
                if cell.jobs == 0 {
                    continue;
                }
                let mut xs = cell.lateness.clone();
                xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
                out.push(AttainmentCell {
                    class: c,
                    tier: TENANT_TIERS[c],
                    llm: Llm::ALL[li],
                    jobs: cell.jobs,
                    met: cell.met,
                    p50_lateness_s: nearest_rank(&xs, 0.5),
                    p99_lateness_s: nearest_rank(&xs, 0.99),
                });
            }
        }
        out
    }
}

impl SimObserver for SloMonitor {
    fn on_arrival(&mut self, st: &ClusterState, _job_id: usize) {
        self.note_arrival(st);
    }
    fn on_job_complete(&mut self, st: &ClusterState, job_id: usize) {
        self.note_completion(st, job_id, false);
    }
    fn on_round(&mut self, st: &ClusterState) {
        self.note_round(st);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SimConfig, Simulator};
    use crate::coordinator::{PromptTuner, PromptTunerConfig};
    use crate::trace::{Load, TraceConfig, TraceGenerator};
    use crate::workload::PerfModel;

    #[test]
    fn monitor_counts_every_job_through_the_observer_hook() {
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 51, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(Load::Low);
        let n = jobs.len();
        let sim = Simulator::new(SimConfig::default(), perf);
        let mut policy =
            PromptTuner::new(PromptTunerConfig { seed: 51, ..Default::default() });
        let mut mon = SloMonitor::new(SloConfig::default());
        let res = sim.run_observed(&mut policy, jobs, &mut mon);
        assert_eq!(res.n_done, n);
        assert_eq!(mon.arrived(), n);
        assert_eq!(mon.finished(), n);
        assert_eq!(mon.gauge.budget.total_seen, n as u64);
        // the attainment table partitions the run exactly
        let table = mon.attainment_table();
        let total: u64 = table.iter().map(|c| c.jobs).sum();
        assert_eq!(total as usize, n);
        let met: u64 = table.iter().map(|c| c.met).sum();
        assert_eq!(met as usize, n - res.n_violations);
        for c in &table {
            assert!((0.0..=1.0).contains(&c.attainment()));
            assert!(c.p99_lateness_s >= c.p50_lateness_s);
        }
        assert!(mon.peak_queue_depth <= n);
    }

    #[test]
    fn doomed_jobs_burn_once() {
        // doom at arrival + completion with already_burned keeps the
        // gauge at one bad sample while the table still records the job
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 52, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(Load::Low);
        let n = jobs.len();
        let sim = Simulator::new(SimConfig::default(), perf);
        struct Doomer {
            mon: SloMonitor,
        }
        impl SimObserver for Doomer {
            fn on_arrival(&mut self, st: &ClusterState, _id: usize) {
                self.mon.note_arrival(st);
                self.mon.note_doomed(st, 1.0);
            }
            fn on_job_complete(&mut self, st: &ClusterState, id: usize) {
                self.mon.note_completion(st, id, true);
            }
        }
        let mut policy =
            PromptTuner::new(PromptTunerConfig { seed: 52, ..Default::default() });
        let mut obs = Doomer { mon: SloMonitor::new(SloConfig::default()) };
        let res = sim.run_observed(&mut policy, jobs, &mut obs);
        assert_eq!(res.n_done, n);
        // every gauge sample came from the doom path, none from completion
        assert_eq!(obs.mon.gauge.budget.total_seen, n as u64);
        assert_eq!(obs.mon.gauge.budget.bad_seen, n as u64);
        let table_jobs: u64 =
            obs.mon.attainment_table().iter().map(|c| c.jobs).sum();
        assert_eq!(table_jobs as usize, n);
    }

    #[test]
    fn burn_gauge_counts_each_job_once_under_flaky_chaos() {
        // Failed completions re-enter the queue through the chaos
        // engine's retry path; the simulator only fires the observer on
        // the attempt that sticks, so the gauge must see exactly one
        // sample per job no matter how many attempts it took.
        use crate::cluster::CheckpointModel;
        use crate::fault::{ChaosEngine, ChaosProfile, FaultInjector,
                           FaultPlan};
        let perf = PerfModel::default();
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 53, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(Load::Medium);
        let n = jobs.len();
        let sim = Simulator::new(SimConfig::default(), perf);
        let mut policy = FaultInjector::with_chaos(
            PromptTuner::new(PromptTunerConfig { seed: 53, ..Default::default() }),
            FaultPlan::new(vec![]),
            CheckpointModel::default(),
            ChaosEngine::new(ChaosProfile::flaky(), 53, 32),
        );
        let mut mon = SloMonitor::new(SloConfig::default());
        let res = sim.run_observed(&mut policy, jobs, &mut mon);
        assert_eq!(res.n_done, n);
        assert!(res.retries > 0, "flaky profile injected no failures");
        assert_eq!(mon.arrived(), n);
        assert_eq!(mon.finished(), n);
        assert_eq!(mon.gauge.budget.total_seen, n as u64);
        let table_jobs: u64 =
            mon.attainment_table().iter().map(|c| c.jobs).sum();
        assert_eq!(table_jobs as usize, n);
    }
}
