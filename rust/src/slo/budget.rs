//! Error budgets and multi-window burn rates.
//!
//! An SLO target of attainment `A` grants an error budget of `1 − A`:
//! that fraction of jobs may miss their deadline before the objective is
//! violated. The *burn rate* of a window is its bad fraction divided by
//! the budget fraction — 1.0 means the budget is being consumed exactly
//! at the sustainable rate, 10 means it will be gone in a tenth of the
//! period. [`BurnGauge`] combines a *fast* and a *slow* window (the SRE
//! multiwindow/multi-burn-rate alerting shape): the fast window reacts to
//! storms within seconds, the slow window keeps a lone hiccup from
//! paging, and control actions fire only when both run hot.

use crate::slo::window::SliWindow;
use crate::slo::SloConfig;

/// Lifetime error-budget accounting for one SLO target.
#[derive(Clone, Debug)]
pub struct ErrorBudget {
    /// Allowed bad fraction (1 − target attainment), floored above zero
    /// so burn rates stay finite.
    budget_frac: f64,
    /// Lifetime SLO-missing observations.
    pub bad_seen: u64,
    /// Lifetime observations.
    pub total_seen: u64,
}

impl ErrorBudget {
    pub fn new(target_attainment: f64) -> Self {
        let target = target_attainment.clamp(0.0, 0.999);
        ErrorBudget {
            budget_frac: (1.0 - target).max(1e-3),
            bad_seen: 0,
            total_seen: 0,
        }
    }

    pub fn budget_frac(&self) -> f64 {
        self.budget_frac
    }

    pub fn observe(&mut self, met: bool) {
        self.total_seen += 1;
        if !met {
            self.bad_seen += 1;
        }
    }

    /// Fraction of the lifetime error budget consumed (exceeds 1 once the
    /// objective is violated outright).
    pub fn consumed(&self) -> f64 {
        if self.total_seen == 0 {
            0.0
        } else {
            (self.bad_seen as f64 / self.total_seen as f64) / self.budget_frac
        }
    }

    /// Remaining lifetime budget fraction, floored at 0.
    pub fn remaining(&self) -> f64 {
        (1.0 - self.consumed()).max(0.0)
    }

    /// Burn rate of `window`: bad fraction ÷ budget fraction.
    pub fn burn_rate(&self, window: &SliWindow) -> f64 {
        window.bad_fraction() / self.budget_frac
    }
}

/// Multi-window burn-rate gauge: one error budget read through a fast and
/// a slow rolling window. Fires only when *both* windows burn above the
/// threshold and the fast window holds enough evidence.
#[derive(Clone, Debug)]
pub struct BurnGauge {
    pub budget: ErrorBudget,
    pub fast: SliWindow,
    pub slow: SliWindow,
    /// Minimum fast-window samples before the gauge may fire.
    pub min_samples: usize,
}

impl BurnGauge {
    pub fn new(cfg: &SloConfig) -> Self {
        BurnGauge {
            budget: ErrorBudget::new(cfg.target_attainment),
            fast: SliWindow::new(cfg.fast_window_s),
            slow: SliWindow::new(cfg.slow_window_s),
            min_samples: cfg.min_samples,
        }
    }

    pub fn record(&mut self, t: f64, met: bool, lateness_s: f64) {
        self.budget.observe(met);
        self.fast.record(t, met, lateness_s);
        self.slow.record(t, met, lateness_s);
    }

    /// Advance both windows to `now` (evicts stale samples).
    pub fn advance(&mut self, now: f64) {
        self.fast.advance(now);
        self.slow.advance(now);
    }

    pub fn fast_burn(&self) -> f64 {
        self.budget.burn_rate(&self.fast)
    }

    pub fn slow_burn(&self) -> f64 {
        self.budget.burn_rate(&self.slow)
    }

    /// Both windows burning at or above `threshold`, with enough
    /// fast-window evidence.
    pub fn firing(&self, threshold: f64) -> bool {
        self.fast.len() >= self.min_samples
            && self.fast_burn() >= threshold
            && self.slow_burn() >= threshold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn burn_rate_is_bad_fraction_over_budget() {
        let budget = ErrorBudget::new(0.9); // budget fraction 0.1
        let mut w = SliWindow::new(100.0);
        for i in 0..10 {
            w.record(i as f64, i % 5 != 0, 0.0); // 2 bad of 10
        }
        assert!((budget.burn_rate(&w) - 2.0).abs() < 1e-9);
        assert!((budget.budget_frac() - 0.1).abs() < 1e-12);
    }

    #[test]
    fn budget_consumed_and_remaining() {
        let mut b = ErrorBudget::new(0.9);
        assert_eq!(b.consumed(), 0.0);
        assert_eq!(b.remaining(), 1.0);
        for i in 0..20 {
            b.observe(i != 0); // 1 bad of 20: 0.05 / 0.1 = half consumed
        }
        assert!((b.consumed() - 0.5).abs() < 1e-9);
        assert!((b.remaining() - 0.5).abs() < 1e-9);
        for _ in 0..5 {
            b.observe(false); // 6 bad of 25: burned through
        }
        assert!(b.consumed() > 1.0);
        assert_eq!(b.remaining(), 0.0);
    }

    #[test]
    fn multiwindow_gauge_requires_both_windows_hot() {
        let cfg = SloConfig {
            fast_window_s: 10.0,
            slow_window_s: 100.0,
            ..Default::default()
        };
        let mut g = BurnGauge::new(&cfg);
        // a long healthy stretch fills the slow window with good samples
        for i in 0..50 {
            g.record(i as f64, true, 0.0);
        }
        assert!(!g.firing(2.0));
        // a short storm: the fast window goes hot, but the slow window is
        // still diluted by healthy history — not firing yet
        for i in 0..6 {
            g.record(95.0 + i as f64 * 0.5, false, 30.0);
        }
        assert!(g.fast_burn() > 2.0);
        assert!(!g.firing(2.0));
        // the storm persists: the slow window heats up too and the gauge
        // fires
        for i in 0..20 {
            g.record(110.0 + i as f64, false, 30.0);
        }
        assert!(g.firing(2.0));
    }

    #[test]
    fn gauge_needs_minimum_evidence() {
        let cfg = SloConfig { min_samples: 5, ..Default::default() };
        let mut g = BurnGauge::new(&cfg);
        for i in 0..4 {
            g.record(i as f64, false, 1.0); // 100 % bad, but only 4 samples
        }
        assert!(!g.firing(2.0));
        g.record(4.0, false, 1.0);
        assert!(g.firing(2.0));
    }
}
