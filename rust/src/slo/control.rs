//! SLO controllers: provable-miss admission control and the error-budget
//! capacity governor, both layered over any [`Policy`] (PromptTuner and
//! both baselines) through the policy trait — the governor never touches
//! cluster state directly, it only drives the wrapped policy's
//! `set_capacity` knob and withholds/releases arrivals, so every cluster
//! invariant the oracle audits (busy ≤ billable ≤ budget) is preserved by
//! construction.

use crate::cluster::{ClusterState, KnobSpec, Policy, RetryEvent,
                     RevokeEvent, TunedPrompt, TunerReport, Wake};
use crate::slo::monitor::SloMonitor;
use crate::slo::SloConfig;
use crate::workload::Llm;

/// Admission verdict for one arrival.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Admission {
    /// The job can still meet its SLO under the most optimistic schedule.
    Admit,
    /// Provably unmeetable: even the per-job GPU cap, a warm connect, no
    /// bank lookup and a perfect prompt miss the deadline. Deferred to
    /// the best-effort (post-deadline) path instead of competing for
    /// SLO-driven allocations it cannot use.
    Defer,
}

/// Screens arrivals with a *sound* miss proof and parks deferred jobs
/// until their deadline passes (LPT users still get their optimized
/// prompt — deferral trades a certain violation's priority for the
/// meetable jobs' capacity, mirroring the scheduler's own expired-job
/// best-effort pass).
#[derive(Clone, Debug, Default)]
pub struct AdmissionController {
    /// Withheld jobs: (release time = SLO deadline, job id).
    deferred: Vec<(f64, usize)>,
    /// Lifetime deferral count.
    pub deferred_total: u64,
}

impl AdmissionController {
    pub fn new() -> Self {
        Self::default()
    }

    /// The provable-miss screen: completion under the best case any
    /// policy could offer — `gpu_cap` GPUs (the service's per-job cap)
    /// from a warm pool, zero bank latency, perfect prompt quality.
    /// Returns the verdict and that optimistic completion estimate.
    pub fn classify(st: &ClusterState, job_id: usize,
                    gpu_cap: usize) -> (Admission, f64) {
        let spec = &st.jobs[job_id].spec;
        let per = spec.llm.gpus_per_replica();
        let cap = gpu_cap.min(st.cfg.max_gpus);
        let gpus = ((cap / per) * per).max(per);
        let best = st.estimate_completion(job_id, gpus,
                                          st.perf.warm_connect_s, 0.0, 1.0);
        if best > spec.deadline() {
            (Admission::Defer, best)
        } else {
            (Admission::Admit, best)
        }
    }

    pub fn defer(&mut self, release_t: f64, job_id: usize) {
        self.deferred.push((release_t, job_id));
        self.deferred_total += 1;
    }

    /// Jobs currently withheld.
    pub fn pending(&self) -> usize {
        self.deferred.len()
    }

    /// Earliest pending release time.
    pub fn next_release(&self) -> Option<f64> {
        self.deferred.iter().map(|&(t, _)| t).reduce(f64::min)
    }

    /// Pop every deferred job due at or before `now`.
    pub fn take_due(&mut self, now: f64) -> Vec<usize> {
        let mut due = vec![];
        self.deferred.retain(|&(t, id)| {
            if t <= now {
                due.push(id);
                false
            } else {
                true
            }
        });
        due
    }
}

/// Configuration of the [`Governed`] control plane.
#[derive(Clone, Debug)]
pub struct GovernorConfig {
    /// SLO target + burn-window parameters.
    pub slo: SloConfig,
    /// Baseline capacity (GPUs) the operator provisioned — should match
    /// the wrapped policy's own budget at construction.
    pub baseline_gpus: usize,
    /// Surge ceiling (clamped to the run's `SimConfig::max_gpus`).
    pub ceiling_gpus: usize,
    /// GPUs added/removed per scaling action.
    pub step_gpus: usize,
    /// Scale up when both burn windows reach this rate.
    pub page_burn: f64,
    /// Scale back toward baseline when both windows are at or below this.
    pub release_burn: f64,
    /// Governor evaluation period, seconds.
    pub eval_period_s: f64,
    /// Minimum time between two capacity changes, seconds.
    pub cooldown_s: f64,
    /// Defer provably-unmeetable arrivals to the best-effort path.
    pub defer_unmeetable: bool,
    /// Per-job allocation cap assumed by the provable-miss screen (the
    /// service contract's `max_gpus_per_job`; 8 for every policy here).
    pub admission_gpu_cap: usize,
}

impl Default for GovernorConfig {
    fn default() -> Self {
        Self::for_cluster(32)
    }
}

impl GovernorConfig {
    /// Defaults for a cluster of `baseline` billable GPUs: 25 % surge
    /// headroom, scaled in steps of an eighth of the baseline.
    pub fn for_cluster(baseline: usize) -> Self {
        GovernorConfig {
            slo: SloConfig::default(),
            baseline_gpus: baseline,
            ceiling_gpus: baseline + (baseline / 4).max(1),
            step_gpus: (baseline / 8).max(1),
            page_burn: 2.0,
            release_burn: 1.0,
            eval_period_s: 5.0,
            cooldown_s: 30.0,
            defer_unmeetable: true,
            admission_gpu_cap: 8,
        }
    }
}

/// The budget governor: wraps any [`Policy`] with the SLO control plane —
/// admission deferral of provably-unmeetable jobs, online burn-rate
/// telemetry, and billable-capacity scaling between the baseline and the
/// surge ceiling. Deterministic (no RNG, no wall clock) and
/// coalescing-correct: every round it lets the simulator skip is a
/// provable no-op, so governed runs stay bit-reproducible per seed.
pub struct Governed<P: Policy> {
    inner: P,
    pub cfg: GovernorConfig,
    pub monitor: SloMonitor,
    admission: AdmissionController,
    name: String,
    capacity_gpus: usize,
    /// Per-job flag: budget already burned at arrival (deferred jobs).
    doomed: Vec<bool>,
    started: bool,
    last_change_t: f64,
    next_eval_t: f64,
    scale_ups: u64,
    scale_downs: u64,
    needs_round: bool,
}

impl<P: Policy> Governed<P> {
    pub fn new(inner: P, cfg: GovernorConfig) -> Self {
        let name = format!("{}+slo", inner.name());
        let monitor = SloMonitor::new(cfg.slo.clone());
        Governed {
            inner,
            monitor,
            admission: AdmissionController::new(),
            name,
            capacity_gpus: cfg.baseline_gpus,
            doomed: vec![],
            started: false,
            last_change_t: f64::NEG_INFINITY,
            next_eval_t: 0.0,
            scale_ups: 0,
            scale_downs: 0,
            needs_round: true,
            cfg,
        }
    }

    pub fn into_inner(self) -> P {
        self.inner
    }

    pub fn scale_ups(&self) -> u64 {
        self.scale_ups
    }

    pub fn scale_downs(&self) -> u64 {
        self.scale_downs
    }

    pub fn deferred_total(&self) -> u64 {
        self.admission.deferred_total
    }

    /// Capacity the governor currently grants the wrapped policy.
    pub fn governed_capacity(&self) -> usize {
        self.capacity_gpus
    }

    fn ensure_started(&mut self, st: &mut ClusterState) {
        if !self.started {
            self.started = true;
            self.capacity_gpus = self.capacity_gpus.min(st.cfg.max_gpus);
            self.inner.set_capacity(st, self.capacity_gpus);
        }
    }

    /// One governor evaluation (rate-limited to the eval grid): scale up
    /// when both burn windows page, release toward baseline when the
    /// budget recovers on both.
    fn govern(&mut self, st: &mut ClusterState) {
        let now = st.now();
        if now < self.next_eval_t {
            return;
        }
        // Re-arm on the *absolute* eval grid (next multiple of the
        // period strictly after now), so evaluation instants depend only
        // on simulated time — never on which earlier rounds happened to
        // execute. Combined with the unconditional eval wake below, this
        // keeps governed runs identical under dense and coalesced
        // ticking.
        self.next_eval_t =
            self.cfg.eval_period_s * ((now / self.cfg.eval_period_s).floor() + 1.0);
        self.monitor.gauge.advance(now);
        if now - self.last_change_t < self.cfg.cooldown_s {
            return;
        }
        let fast = self.monitor.gauge.fast_burn();
        let slow = self.monitor.gauge.slow_burn();
        let ceiling = self.cfg.ceiling_gpus.min(st.cfg.max_gpus);
        let mut target = self.capacity_gpus;
        if self.monitor.gauge.firing(self.cfg.page_burn) {
            target = (self.capacity_gpus + self.cfg.step_gpus).min(ceiling);
        } else if fast <= self.cfg.release_burn
            && slow <= self.cfg.release_burn
            && self.capacity_gpus > self.cfg.baseline_gpus
        {
            target = self
                .capacity_gpus
                .saturating_sub(self.cfg.step_gpus)
                .max(self.cfg.baseline_gpus);
        }
        if target != self.capacity_gpus {
            if target > self.capacity_gpus {
                self.scale_ups += 1;
            } else {
                self.scale_downs += 1;
            }
            self.inner.set_capacity(st, target);
            // Read the level actually reached: a policy may clamp (e.g.
            // ElasticFlow cannot release busy GPUs). Recording the
            // clamped value keeps capacity above baseline visible, so
            // the release branch retries after the cooldown instead of
            // pinning billable capacity above baseline forever.
            self.capacity_gpus = self.inner.capacity().unwrap_or(target);
            self.last_change_t = now;
            self.needs_round = true;
        }
    }

}

/// Earliest of two wake hints.
fn earliest(a: Wake, b: Wake) -> Wake {
    match (a, b) {
        (Wake::Dense, _) | (_, Wake::Dense) => Wake::Dense,
        (Wake::Idle, w) | (w, Wake::Idle) => w,
        (Wake::At(x), Wake::At(y)) => Wake::At(x.min(y)),
    }
}

impl<P: Policy> Policy for Governed<P> {
    fn name(&self) -> &str {
        &self.name
    }

    fn tick_interval(&self) -> f64 {
        self.inner.tick_interval()
    }

    fn on_arrival(&mut self, st: &mut ClusterState, job_id: usize) {
        self.ensure_started(st);
        if self.doomed.len() <= job_id {
            self.doomed.resize(job_id + 1, false);
        }
        self.monitor.note_arrival(st);
        let verdict = if self.cfg.defer_unmeetable {
            AdmissionController::classify(st, job_id,
                                          self.cfg.admission_gpu_cap)
        } else {
            (Admission::Admit, 0.0)
        };
        match verdict {
            (Admission::Admit, _) => self.inner.on_arrival(st, job_id),
            (Admission::Defer, best) => {
                let deadline = st.jobs[job_id].spec.deadline();
                self.doomed[job_id] = true;
                self.monitor.note_doomed(st, best - deadline);
                self.admission.defer(deadline, job_id);
            }
        }
        self.govern(st);
        self.needs_round = true;
    }

    fn on_job_complete(&mut self, st: &mut ClusterState, job_id: usize) {
        self.inner.on_job_complete(st, job_id);
        let burned = self.doomed.get(job_id).copied().unwrap_or(false);
        self.monitor.note_completion(st, job_id, burned);
        self.govern(st);
    }

    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        // Revocations are the wrapped policy's to recover from; the
        // governor only needs to re-evaluate at the next round (the
        // fault engine re-clamps any surged capacity itself).
        self.inner.on_revoke(st, ev);
        self.needs_round = true;
    }

    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        // A failed completion is not a completion: the burn gauge only
        // samples a job's final outcome (the chaos engine intercepts the
        // completion before it reaches this wrapper), so no monitor feed
        // here — just let the wrapped policy recover and re-evaluate.
        self.inner.on_retry(st, ev);
        self.govern(st);
        self.needs_round = true;
    }

    fn on_tick(&mut self, st: &mut ClusterState) {
        self.ensure_started(st);
        self.needs_round = false;
        // Past-deadline release of deferred jobs: they land in the inner
        // policy's expired best-effort path and still complete.
        let due = self.admission.take_due(st.now());
        for id in due {
            self.inner.on_arrival(st, id);
            self.needs_round = true;
        }
        self.inner.on_tick(st);
        self.monitor.note_round(st);
        self.govern(st);
    }

    fn next_timed_action(&self, st: &ClusterState) -> Wake {
        if self.needs_round {
            return Wake::Dense;
        }
        // Starved-wake audit (batch-skip core): this wrapper only merges
        // *earlier* wakes (deferred-admission releases, the governor
        // grid) on top of the inner hint via `earliest`, so it can never
        // starve an action the inner policy declared.
        let mut wake = self.inner.next_timed_action(st);
        if let Some(t) = self.admission.next_release() {
            wake = earliest(wake, Wake::At(t));
        }
        // The governor's own grid, declared unconditionally: rounds
        // before `next_eval_t` are provable no-ops for it (govern() is
        // gated on the clock), and the first round at/after it executes
        // in both dense and coalesced runs — evaluation instants are a
        // pure function of simulated time (~1 round per eval period of
        // overhead; runs end when the last job completes).
        earliest(wake, Wake::At(self.next_eval_t))
    }

    fn capacity(&self) -> Option<usize> {
        Some(self.capacity_gpus)
    }

    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        self.capacity_gpus = gpus.min(self.cfg.ceiling_gpus);
        self.inner.set_capacity(st, self.capacity_gpus);
    }

    // Gossip hooks: pure pass-throughs — the governor has no bank of its
    // own, so the wrapped policy's answers are authoritative.
    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        self.inner.bank_coverage(llm, task_id)
    }

    fn enable_gossip_log(&mut self) {
        self.inner.enable_gossip_log();
    }

    fn drain_tuned(&mut self, out: &mut Vec<TunedPrompt>) {
        self.inner.drain_tuned(out);
    }

    fn absorb_tuned(&mut self, items: &[TunedPrompt]) {
        self.inner.absorb_tuned(items);
    }

    // Knob hooks: forward the inner declarations, but route the
    // `capacity` knob through the governor's own ceiling-clamped
    // setter so a tuner layered outside can never out-scale the
    // governor it wraps.
    fn knobs(&self) -> Vec<KnobSpec> {
        self.inner.knobs()
    }

    fn knob_value(&self, name: &str) -> Option<f64> {
        if name == "capacity" {
            Some(self.capacity_gpus as f64)
        } else {
            self.inner.knob_value(name)
        }
    }

    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        if name == "capacity" {
            self.set_capacity(st, value.round().max(1.0) as usize);
        } else {
            self.inner.set_knob(st, name, value);
        }
    }

    fn tuner_report(&self) -> Option<TunerReport> {
        self.inner.tuner_report()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{SimConfig, SimOracle, Simulator};
    use crate::coordinator::{PromptTuner, PromptTunerConfig};
    use crate::scenario::Scenario;
    use crate::workload::{JobSpec, Llm, PerfModel};

    fn pt(gpus: usize, seed: u64) -> PromptTuner {
        PromptTuner::new(PromptTunerConfig {
            max_gpus: gpus,
            seed,
            ..Default::default()
        })
    }

    fn spec(id: usize, submit: f64, iters: f64, slo: f64) -> JobSpec {
        JobSpec {
            id,
            llm: Llm::Gpt2B,
            task_id: 0,
            submit_s: submit,
            duration_s: iters * 0.12,
            traced_gpus: 1,
            base_iters: iters,
            user_prompt_quality: 1.0,
            slo_s: slo,
        }
    }

    #[test]
    fn governed_flash_crowd_completes_under_oracle() {
        let sc = Scenario::FlashCrowd {
            storms: 3,
            intensity: 25.0,
            jobs_per_llm: 40,
        };
        let jobs = sc.generate(41, 1.0).unwrap();
        let n = jobs.len();
        let gcfg = GovernorConfig::for_cluster(32);
        let sim = Simulator::new(
            SimConfig { max_gpus: gcfg.ceiling_gpus, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = SimOracle::collecting(Governed::new(pt(32, 41), gcfg));
        let res = sim.run(&mut policy, jobs);
        assert_eq!(res.n_done, n);
        assert!(policy.violations().is_empty(), "{:?}", policy.violations());
        assert_eq!(res.policy, "prompttuner+slo");
        assert!(policy.audits() > 0);
    }

    #[test]
    fn governed_baselines_run_oracle_clean() {
        use crate::baselines::{ElasticFlow, ElasticFlowConfig, Infless,
                               InflessConfig};
        let sc = Scenario::MultiTenant { tenants: 4, jobs_per_tenant: 15 };
        let jobs = sc.generate(53, 1.0).unwrap();
        let n = jobs.len();
        let gcfg = GovernorConfig::for_cluster(32);
        let sim = Simulator::new(
            SimConfig { max_gpus: gcfg.ceiling_gpus, ..Default::default() },
            PerfModel::default(),
        );
        let mut ef = SimOracle::collecting(Governed::new(
            ElasticFlow::new(ElasticFlowConfig {
                cluster_size: 32,
                seed: 53,
                ..Default::default()
            }),
            gcfg.clone(),
        ));
        let res = sim.run(&mut ef, jobs.clone());
        assert_eq!(res.n_done, n);
        assert!(ef.violations().is_empty(), "{:?}", ef.violations());
        assert_eq!(res.policy, "elasticflow+slo");
        let mut inf = SimOracle::collecting(Governed::new(
            Infless::new(InflessConfig {
                max_gpus: 32,
                seed: 53,
                ..Default::default()
            }),
            gcfg,
        ));
        let res = sim.run(&mut inf, jobs);
        assert_eq!(res.n_done, n);
        assert!(inf.violations().is_empty(), "{:?}", inf.violations());
        assert_eq!(res.policy, "infless+slo");
    }

    #[test]
    fn governed_runs_are_deterministic() {
        let run = || {
            let sc = Scenario::MultiTenant { tenants: 4, jobs_per_tenant: 20 };
            let jobs = sc.generate(43, 1.0).unwrap();
            let gcfg = GovernorConfig::for_cluster(24);
            let sim = Simulator::new(
                SimConfig { max_gpus: gcfg.ceiling_gpus, ..Default::default() },
                PerfModel::default(),
            );
            let mut p = Governed::new(pt(24, 43), gcfg);
            sim.run(&mut p, jobs)
        };
        let a = run();
        let b = run();
        assert_eq!(a.cost_usd, b.cost_usd);
        assert_eq!(a.n_violations, b.n_violations);
        assert_eq!(a.job_latencies, b.job_latencies);
    }

    #[test]
    fn neutral_governor_is_a_bit_exact_pass_through() {
        // Defer off + no surge headroom + unreachable page threshold: the
        // governor observes but never acts, so results must be
        // bit-identical to the bare policy (its extra executed rounds are
        // no-ops by the coalescing contract).
        let sc = Scenario::FlashCrowd {
            storms: 2,
            intensity: 10.0,
            jobs_per_llm: 20,
        };
        let jobs = sc.generate(47, 1.0).unwrap();
        let sim = Simulator::new(
            SimConfig { max_gpus: 32, ..Default::default() },
            PerfModel::default(),
        );
        let mut plain = pt(32, 47);
        let ref_res = sim.run(&mut plain, jobs.clone());
        let mut gcfg = GovernorConfig::for_cluster(32);
        gcfg.ceiling_gpus = 32;
        gcfg.page_burn = f64::INFINITY;
        gcfg.defer_unmeetable = false;
        let mut gov = Governed::new(pt(32, 47), gcfg);
        let res = sim.run(&mut gov, jobs);
        assert_eq!(res.n_done, ref_res.n_done);
        assert_eq!(res.n_violations, ref_res.n_violations);
        assert_eq!(res.cost_usd, ref_res.cost_usd);
        assert_eq!(res.job_latencies, ref_res.job_latencies);
        assert_eq!(res.util_timeline, ref_res.util_timeline);
        assert_eq!(gov.scale_ups() + gov.scale_downs(), 0);
        assert_eq!(gov.deferred_total(), 0);
    }

    #[test]
    fn unmeetable_job_is_deferred_and_still_completes() {
        // Job 0's SLO is shorter than its best possible execution even on
        // the per-job GPU cap: provably unmeetable, deferred at arrival,
        // finished best-effort after its deadline. Job 1 is easy.
        let jobs = vec![
            spec(0, 0.0, 1000.0, 5.0),
            spec(1, 0.0, 100.0, 1e6),
        ];
        let gcfg = GovernorConfig::for_cluster(8);
        let sim = Simulator::new(
            SimConfig { max_gpus: gcfg.ceiling_gpus, ..Default::default() },
            PerfModel::default(),
        );
        let mut gov = Governed::new(pt(8, 1), gcfg);
        let res = sim.run(&mut gov, jobs);
        assert_eq!(gov.deferred_total(), 1);
        assert_eq!(res.n_done, 2);
        assert_eq!(res.n_violations, 1);
        // the doomed job burned the budget at arrival
        assert!(gov.monitor.gauge.budget.bad_seen >= 1);
    }

    #[test]
    fn sustained_burn_scales_capacity_up() {
        // A single-GPU baseline facing a stream of 12 s jobs with 20 s
        // SLOs: each is meetable alone (admitted), hopeless under
        // queueing — completions burn the budget, the governor surges.
        let mut jobs = vec![];
        for i in 0..30 {
            jobs.push(spec(i, i as f64 * 2.0, 100.0, 20.0));
        }
        let mut gcfg = GovernorConfig::for_cluster(1);
        gcfg.ceiling_gpus = 4;
        gcfg.step_gpus = 1;
        gcfg.cooldown_s = 10.0;
        let sim = Simulator::new(
            SimConfig { max_gpus: 4, ..Default::default() },
            PerfModel::default(),
        );
        let mut gov = Governed::new(pt(1, 2), gcfg);
        let res = sim.run(&mut gov, jobs);
        assert_eq!(res.n_done, 30);
        assert!(gov.scale_ups() > 0, "governor never scaled up");
        assert!(gov.governed_capacity() <= 4);
        assert!(gov.governed_capacity() >= 1);
    }

    #[test]
    fn classify_is_optimistic_about_capacity() {
        // indirectly: an easy job must never be deferred even at tiny
        // baseline capacity, because the screen assumes the per-job cap
        let jobs = vec![spec(0, 0.0, 100.0, 1e6)];
        let gcfg = GovernorConfig::for_cluster(1);
        let sim = Simulator::new(
            SimConfig { max_gpus: gcfg.ceiling_gpus, ..Default::default() },
            PerfModel::default(),
        );
        let mut gov = Governed::new(pt(1, 3), gcfg);
        let res = sim.run(&mut gov, jobs);
        assert_eq!(gov.deferred_total(), 0);
        assert_eq!(res.n_done, 1);
        assert_eq!(res.n_violations, 0);
    }

    #[test]
    fn earliest_wake_combinator() {
        assert_eq!(earliest(Wake::Dense, Wake::Idle), Wake::Dense);
        assert_eq!(earliest(Wake::At(3.0), Wake::Dense), Wake::Dense);
        assert_eq!(earliest(Wake::Idle, Wake::At(2.0)), Wake::At(2.0));
        assert_eq!(earliest(Wake::At(5.0), Wake::At(2.0)), Wake::At(2.0));
        assert_eq!(earliest(Wake::Idle, Wake::Idle), Wake::Idle);
    }
}
