//! Offline stand-in for the `anyhow` crate.
//!
//! This build environment has no crates.io access, so the subset of
//! `anyhow` the codebase uses is vendored here as a path dependency:
//! [`Error`], [`Result`], the [`anyhow!`]/[`bail!`]/[`ensure!`] macros and
//! the [`Context`] extension trait. Semantics follow upstream: `{}` shows
//! the outermost message, `{:#}` the whole cause chain joined with `": "`,
//! and `{:?}` a report with a `Caused by:` section.

use std::fmt;

/// A dynamic error: an outermost message plus a cause chain (outer-to-
/// inner order). Like upstream `anyhow::Error`, this type deliberately
/// does NOT implement `std::error::Error`, which is what makes the
/// blanket `From<E: std::error::Error>` impl coherent.
pub struct Error {
    msg: String,
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), chain: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        let mut chain = Vec::with_capacity(self.chain.len() + 1);
        chain.push(self.msg);
        chain.extend(self.chain);
        Error { msg: context.to_string(), chain }
    }

    /// The cause chain, outermost message first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str()).chain(self.chain.iter().map(|s| s.as_str()))
    }

    /// The outermost message (upstream: `root_cause` is the innermost;
    /// we expose both ends).
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(|s| s.as_str()).unwrap_or(&self.msg)
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.msg)?;
            for cause in &self.chain {
                write!(f, ": {cause}")?;
            }
            Ok(())
        } else {
            f.write_str(&self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)?;
        if !self.chain.is_empty() {
            f.write_str("\n\nCaused by:")?;
            for (i, cause) in self.chain.iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), chain }
    }
}

/// `anyhow::Result<T>` — `Result` with [`Error`] as the default error.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(..)` / `.with_context(..)` to
/// `Result` and `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or any `Display` value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an error built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e = Error::from(io_err()).context("reading x");
        assert_eq!(format!("{e}"), "reading x");
        assert_eq!(format!("{e:#}"), "reading x: gone");
        assert!(format!("{e:?}").contains("Caused by:"));
    }

    #[test]
    fn macros_build_errors() {
        let x = 3;
        let e = anyhow!("bad {x}");
        assert_eq!(format!("{e}"), "bad 3");
        fn f() -> Result<()> {
            bail!("nope {}", 7)
        }
        assert_eq!(format!("{}", f().unwrap_err()), "nope 7");
        fn g(ok: bool) -> Result<u32> {
            ensure!(ok, "flag was {ok}");
            Ok(1)
        }
        assert!(g(true).is_ok());
        assert_eq!(format!("{}", g(false).unwrap_err()), "flag was false");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<String> {
            let s = std::fs::read_to_string("/definitely/not/here")?;
            Ok(s)
        }
        assert!(f().is_err());
    }

    #[test]
    fn context_on_result_and_option() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("step {}", 2)).unwrap_err();
        assert_eq!(format!("{e:#}"), "step 2: gone");
        let o: Option<u32> = None;
        assert_eq!(format!("{}", o.context("missing").unwrap_err()), "missing");
    }
}
