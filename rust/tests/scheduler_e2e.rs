//! End-to-end scheduler integration: all three systems over the paper's
//! load levels and SLO emergencies on the discrete-event cluster, checking
//! the qualitative relationships the paper reports (who wins, and
//! roughly where). Pure simulation — fast, no artifacts needed. Every run
//! executes under the strict simulation oracle ([`SimOracle`]), which
//! panics on any violated cluster invariant.

use prompttuner::baselines::{ElasticFlow, ElasticFlowConfig, Infless, InflessConfig};
use prompttuner::cluster::{Policy, SimConfig, SimOracle, SimResult, Simulator};
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::trace::{Load, TraceConfig, TraceGenerator};
use prompttuner::workload::{Llm, PerfModel};

fn run_system(system: &str, load: Load, slo: f64, gpus: usize, seed: u64) -> SimResult {
    let perf = PerfModel::default();
    let mut gen = TraceGenerator::new(
        TraceConfig { seed, slo_emergence: slo, ..Default::default() },
        perf.clone(),
    );
    let jobs = gen.generate_main(load);
    let sim = Simulator::new(SimConfig { max_gpus: gpus, ..Default::default() }, perf);
    let policy: Box<dyn Policy> = match system {
        "prompttuner" => Box::new(PromptTuner::new(PromptTunerConfig {
            max_gpus: gpus,
            seed,
            ..Default::default()
        })),
        "infless" => Box::new(Infless::new(InflessConfig {
            max_gpus: gpus,
            seed,
            ..Default::default()
        })),
        "elasticflow" => Box::new(ElasticFlow::new(ElasticFlowConfig {
            cluster_size: gpus,
            seed,
            ..Default::default()
        })),
        _ => unreachable!(),
    };
    let mut policy = SimOracle::new(policy);
    sim.run(&mut policy, jobs)
}

/// Average over a few seeds to de-noise qualitative comparisons.
fn avg(system: &str, load: Load, slo: f64, gpus: usize) -> (f64, f64) {
    let seeds = [42u64, 43, 44];
    let mut viol = 0.0;
    let mut cost = 0.0;
    for &s in &seeds {
        let r = run_system(system, load, slo, gpus, s);
        assert_eq!(r.n_done, r.n_jobs, "{system} left jobs unfinished");
        viol += r.violation_rate();
        cost += r.cost_usd;
    }
    (viol / seeds.len() as f64, cost / seeds.len() as f64)
}

#[test]
fn prompttuner_beats_baselines_at_medium_load() {
    let (pv, pc) = avg("prompttuner", Load::Medium, 1.0, 32);
    let (iv, ic) = avg("infless", Load::Medium, 1.0, 32);
    let (ev, ec) = avg("elasticflow", Load::Medium, 1.0, 32);
    // Fig 7a/b: PromptTuner lowest on both axes.
    assert!(pv < iv, "viol: pt {pv} vs infless {iv}");
    assert!(pv < ev, "viol: pt {pv} vs elasticflow {ev}");
    assert!(pc < ic, "cost: pt {pc} vs infless {ic}");
    assert!(pc < ec, "cost: pt {pc} vs elasticflow {ec}");
    // ElasticFlow's statically provisioned cluster is the most expensive.
    assert!(ec > ic, "elasticflow should cost most: {ec} vs {ic}");
}

#[test]
fn violations_grow_with_tighter_slo() {
    // Fig 7c: S = 0.5 is harsher than S = 1.5 for every system.
    for system in ["prompttuner", "infless", "elasticflow"] {
        let (tight, _) = avg(system, Load::Medium, 0.5, 32);
        let (loose, _) = avg(system, Load::Medium, 1.5, 32);
        assert!(
            tight >= loose,
            "{system}: tight {tight} should be >= loose {loose}"
        );
    }
}

#[test]
fn prompttuner_wins_across_slo_levels() {
    for slo in [0.5, 1.0, 1.5] {
        let (pv, _) = avg("prompttuner", Load::Medium, slo, 32);
        let (iv, _) = avg("infless", Load::Medium, slo, 32);
        let (ev, _) = avg("elasticflow", Load::Medium, slo, 32);
        assert!(pv <= iv + 0.02, "S={slo}: pt {pv} vs infless {iv}");
        assert!(pv <= ev + 0.02, "S={slo}: pt {pv} vs elasticflow {ev}");
    }
}

#[test]
fn infless_suffers_most_at_tight_slo() {
    // §6.2: at S = 0.5 multi-GPU jobs expose INFless's per-instance
    // initialization — its violation rate approaches ElasticFlow's.
    let (iv, _) = avg("infless", Load::Medium, 0.5, 32);
    let (pv, _) = avg("prompttuner", Load::Medium, 0.5, 32);
    assert!(iv > pv * 1.5, "infless {iv} vs prompttuner {pv}");
}

#[test]
fn heavy_tensor_parallel_workload_table7() {
    // Table 7 shape: PromptTuner < INFless < ElasticFlow on violations
    // for the 4-GPU-per-replica LLMs.
    let perf = PerfModel::default();
    for llm in [Llm::Llama30B, Llm::Qwen7BR1] {
        let mut viols = vec![];
        for system in ["prompttuner", "infless", "elasticflow"] {
            let mut gen = TraceGenerator::new(
                TraceConfig { seed: 7, ..Default::default() },
                perf.clone(),
            );
            let jobs = gen.generate_heavy(llm);
            let sim = Simulator::new(
                SimConfig { max_gpus: 32, ..Default::default() },
                perf.clone(),
            );
            let policy: Box<dyn Policy> = match system {
                "prompttuner" => Box::new(PromptTuner::new(PromptTunerConfig {
                    max_gpus: 32,
                    max_gpus_per_job: 8,
                    seed: 7,
                    ..Default::default()
                })),
                "infless" => Box::new(Infless::new(InflessConfig {
                    max_gpus: 32,
                    seed: 7,
                    ..Default::default()
                })),
                _ => Box::new(ElasticFlow::new(ElasticFlowConfig {
                    cluster_size: 32,
                    seed: 7,
                    ..Default::default()
                })),
            };
            let mut policy = SimOracle::new(policy);
            let res = sim.run(&mut policy, jobs);
            assert_eq!(res.n_done, res.n_jobs, "{system} {llm:?}");
            viols.push(res.violation_rate());
        }
        assert!(viols[0] <= viols[1] + 0.03,
                "{llm:?}: pt {} vs infless {}", viols[0], viols[1]);
        assert!(viols[0] < viols[2],
                "{llm:?}: pt {} vs elasticflow {}", viols[0], viols[2]);
    }
}

#[test]
fn scale_to_96_gpus_keeps_ordering() {
    // §6.2 scalability: at 96 GPUs with 3× load, PromptTuner's advantage
    // persists and scheduling overhead stays in the low-millisecond range.
    let perf = PerfModel::default();
    let mut results = vec![];
    for system in ["prompttuner", "infless", "elasticflow"] {
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 11, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_scaled(Load::Medium, 3.0);
        let sim = Simulator::new(
            SimConfig { max_gpus: 96, ..Default::default() },
            perf.clone(),
        );
        let policy: Box<dyn Policy> = match system {
            "prompttuner" => Box::new(PromptTuner::new(PromptTunerConfig {
                max_gpus: 96,
                seed: 11,
                ..Default::default()
            })),
            "infless" => Box::new(Infless::new(InflessConfig {
                max_gpus: 96,
                seed: 11,
                ..Default::default()
            })),
            _ => Box::new(ElasticFlow::new(ElasticFlowConfig {
                cluster_size: 96,
                seed: 11,
                ..Default::default()
            })),
        };
        let mut policy = SimOracle::new(policy);
        let res = sim.run(&mut policy, jobs);
        assert_eq!(res.n_done, res.n_jobs, "{system}");
        // paper §6.2: avg/max scheduling overhead 13/67 ms — ours must not
        // be the bottleneck either
        assert!(res.sched_overhead_ms_max < 67.0,
                "{system} overhead {}ms", res.sched_overhead_ms_max);
        results.push(res);
    }
    assert!(results[0].violation_rate() < results[1].violation_rate());
    assert!(results[0].violation_rate() < results[2].violation_rate());
    assert!(results[0].cost_usd < results[2].cost_usd);
}

#[test]
fn ablations_match_table8_directions() {
    // Table 8: removing any scheduler component hurts SLO violation.
    let perf = PerfModel::default();
    let run_cfg = |cfg: PromptTunerConfig| -> SimResult {
        let mut gen = TraceGenerator::new(
            TraceConfig { seed: 13, ..Default::default() },
            perf.clone(),
        );
        let jobs = gen.generate_main(Load::Medium);
        let sim = Simulator::new(
            SimConfig { max_gpus: 32, ..Default::default() },
            perf.clone(),
        );
        let mut p = SimOracle::new(PromptTuner::new(cfg));
        sim.run(&mut p, jobs)
    };
    let full = run_cfg(PromptTunerConfig { seed: 13, ..Default::default() });
    let no_warm_alloc = run_cfg(PromptTunerConfig {
        use_warm_allocator: false,
        seed: 13,
        ..Default::default()
    });
    let no_delay = run_cfg(PromptTunerConfig {
        use_delay_schedulable: false,
        seed: 13,
        ..Default::default()
    });
    assert_eq!(full.n_done, full.n_jobs);
    // w/o warm allocator: violations rise (Table 8: 12.4 -> 27.8)
    assert!(
        no_warm_alloc.violation_rate() >= full.violation_rate(),
        "warm allocator: {} vs {}",
        no_warm_alloc.violation_rate(),
        full.violation_rate()
    );
    // w/o DelaySchedulable: cost rises (Table 8: 22.9 -> 26.6)
    assert!(
        no_delay.cost_usd >= full.cost_usd * 0.98,
        "delay: {} vs {}",
        no_delay.cost_usd,
        full.cost_usd
    );
}
