//! Conformance suite for the shard plane and the streaming trace path.
//!
//! Two bit-identity oracles anchor the new subsystem to the proven one,
//! the same playbook as the dense-vs-coalesced rotation:
//!
//! * **streaming = materialized** — `Simulator::run_source` (the
//!   `StreamCore` injection path) must be bit-identical to
//!   `Simulator::run` (the materialized heap path) for every scenario
//!   family, every system, and the hyperscale/replay sources, with the
//!   strict in-loop oracle armed;
//! * **1 shard = unsharded** — a 1-shard, gossip-off `ShardPlane` must
//!   be bit-identical to the unsharded simulator for all three systems
//!   (router, barriers and gossip must all vanish exactly);
//! * **parallel = sequential** — a plane on the fork-join worker pool
//!   (workers ∈ {2, 4}) must be bit-identical to the inline sequential
//!   executor (workers = 1) per system × gossip × partition: executor
//!   width is a pure performance knob;
//!
//! plus the partition-chaos property: a partitioned multi-shard plane
//! replays bit-identically across repeats *and* across dense-vs-
//! coalesced ticking, never routes into a severed shard while an
//! alternative lives, and never loses a job.

use prompttuner::bench::{self, SweepCell, SYSTEMS};
use prompttuner::cluster::{SimConfig, SimResult, Simulator};
use prompttuner::fault::ChaosProfile;
use prompttuner::scenario::{replay, Scenario, NOVEL_TASK_BASE};
use prompttuner::shard::{make_shard_policy, ShardPlane, ShardPlaneConfig};
use prompttuner::trace::{ReplaySource, ScaleSource, ScaleSourceConfig,
                         TraceSource, VecSource};
use prompttuner::util::prop::{check, ensure};
use prompttuner::workload::PerfModel;

/// Bitwise comparison of everything a run computes deterministically —
/// wall-clock and scheduler-overhead timings are the only exclusions.
/// `same_rounds` is false for dense-vs-coalesced comparisons, where the
/// executed/skipped round split legitimately differs.
fn assert_results_identical(tag: &str, a: &SimResult, b: &SimResult,
                            same_rounds: bool) -> Result<(), String> {
    ensure(a.n_jobs == b.n_jobs && a.n_done == b.n_done,
           format!("{tag}: jobs {}/{} vs {}/{}", a.n_jobs, a.n_done,
                   b.n_jobs, b.n_done))?;
    ensure(a.n_violations == b.n_violations,
           format!("{tag}: violations {} vs {}", a.n_violations,
                   b.n_violations))?;
    ensure(a.cost_usd.to_bits() == b.cost_usd.to_bits(),
           format!("{tag}: cost {} vs {}", a.cost_usd, b.cost_usd))?;
    ensure(a.gpu_seconds_billed.to_bits() == b.gpu_seconds_billed.to_bits()
               && a.gpu_seconds_busy.to_bits()
                   == b.gpu_seconds_busy.to_bits()
               && a.mean_utilization.to_bits()
                   == b.mean_utilization.to_bits(),
           format!("{tag}: GPU-second accounting diverged"))?;
    ensure(a.mean_prompt_quality.to_bits() == b.mean_prompt_quality.to_bits(),
           format!("{tag}: quality {} vs {}", a.mean_prompt_quality,
                   b.mean_prompt_quality))?;
    if same_rounds {
        ensure(a.rounds_executed == b.rounds_executed
                   && a.rounds_coalesced == b.rounds_coalesced,
               format!("{tag}: rounds {}+{} vs {}+{}", a.rounds_executed,
                       a.rounds_coalesced, b.rounds_executed,
                       b.rounds_coalesced))?;
        ensure(a.events_processed == b.events_processed,
               format!("{tag}: events {} vs {}", a.events_processed,
                       b.events_processed))?;
    }
    ensure(a.revocations == b.revocations
               && a.lost_iters.to_bits() == b.lost_iters.to_bits()
               && a.straggler_iters.to_bits() == b.straggler_iters.to_bits(),
           format!("{tag}: fault telemetry diverged"))?;
    ensure(a.retries == b.retries
               && a.retry_iters.to_bits() == b.retry_iters.to_bits()
               && a.chaos_delay_s.to_bits() == b.chaos_delay_s.to_bits(),
           format!("{tag}: chaos telemetry diverged"))?;
    ensure(a.util_timeline.len() == b.util_timeline.len(),
           format!("{tag}: util timeline {} vs {} samples",
                   a.util_timeline.len(), b.util_timeline.len()))?;
    for (x, y) in a.util_timeline.iter().zip(&b.util_timeline) {
        ensure(x.0.to_bits() == y.0.to_bits()
                   && x.1.to_bits() == y.1.to_bits(),
               format!("{tag}: util sample {x:?} vs {y:?}"))?;
    }
    ensure(a.job_latencies.len() == b.job_latencies.len(),
           format!("{tag}: latency counts"))?;
    for (x, y) in a.job_latencies.iter().zip(&b.job_latencies) {
        ensure(x.0.to_bits() == y.0.to_bits()
                   && x.1.to_bits() == y.1.to_bits()
                   && x.2.to_bits() == y.2.to_bits()
                   && x.3.to_bits() == y.3.to_bits(),
               format!("{tag}: per-job latency {x:?} vs {y:?}"))?;
    }
    for (x, y) in a.job_quality.iter().zip(&b.job_quality) {
        ensure(x.to_bits() == y.to_bits(),
               format!("{tag}: per-job quality {x} vs {y}"))?;
    }
    Ok(())
}

fn oracle_cfg(sc: Option<&Scenario>) -> SimConfig {
    let mut cfg = SimConfig { max_gpus: 32, debug_oracle: true,
                              ..Default::default() };
    if let Some(h) = sc.and_then(Scenario::horizon_hint) {
        cfg.horizon_s = cfg.horizon_s.max(h);
    }
    cfg
}

/// Streaming vs materialized, every catalogue family, under the strict
/// in-loop oracle — the `StreamCore` refactor's conformance property.
#[test]
fn prop_streaming_matches_materialized_for_every_family() {
    check("stream = materialized per family", 2, |rng| {
        let seed = rng.next_u64();
        for sc in Scenario::catalogue() {
            let cell = SweepCell::scenario(
                format!("ps/{}", sc.name()), "prompttuner", sc.clone(), 1.0,
                32, seed);
            let jobs = bench::gen_jobs(&cell);
            let sim = Simulator::new(oracle_cfg(Some(&sc)),
                                     PerfModel::default());
            let mut p1 = bench::make_policy(&cell);
            let a = sim.run(p1.as_mut(), jobs.clone());
            let mut p2 = bench::make_policy(&cell);
            let b = sim.run_source(p2.as_mut(), &mut VecSource::new(jobs));
            assert_results_identical(
                &format!("{} seed={seed}", sc.name()), &a, &b, true)?;
        }
        Ok(())
    });
}

/// The same equality for all three systems on one family, and for the
/// two genuinely streaming sources (hyperscale generator, binary
/// replay) against their materialized counterparts.
#[test]
fn prop_streaming_matches_materialized_across_systems_and_sources() {
    check("stream = materialized across systems/sources", 2, |rng| {
        let seed = rng.next_u64();
        let sc = Scenario::catalogue().into_iter().next().unwrap();
        for system in SYSTEMS {
            let cell = SweepCell::scenario(
                format!("ps/{system}"), system, sc.clone(), 1.0, 32, seed);
            let jobs = bench::gen_jobs(&cell);
            let sim = Simulator::new(oracle_cfg(Some(&sc)),
                                     PerfModel::default());
            let mut p1 = bench::make_policy(&cell);
            let a = sim.run(p1.as_mut(), jobs.clone());
            let mut p2 = bench::make_policy(&cell);
            let b = sim.run_source(p2.as_mut(), &mut VecSource::new(jobs));
            assert_results_identical(&format!("{system} seed={seed}"), &a,
                                     &b, true)?;
        }

        // Hyperscale generator: stream vs its own materialization.
        let scfg = ScaleSourceConfig {
            seed,
            minutes: 15,
            jobs_per_minute: 6.0,
            ..Default::default()
        };
        let sim = Simulator::new(oracle_cfg(None), PerfModel::default());
        let mut p1 = make_shard_policy("prompttuner", seed, 32);
        let a = sim.run(p1.as_mut(), ScaleSource::new(scfg.clone())
            .materialize());
        let mut p2 = make_shard_policy("prompttuner", seed, 32);
        let b = sim.run_source(p2.as_mut(), &mut ScaleSource::new(scfg));
        assert_results_identical(&format!("scale seed={seed}"), &a, &b,
                                 true)?;

        // Binary replay: streaming decoder vs the batch loader.
        let jobs = sc.generate(seed, 1.0).map_err(|e| e.to_string())?;
        let bytes = replay::to_bytes(&jobs);
        let mut p1 = make_shard_policy("prompttuner", seed, 32);
        let a = sim.run(p1.as_mut(),
                        replay::from_bytes(&bytes).map_err(|e| e.to_string())?);
        let mut p2 = make_shard_policy("prompttuner", seed, 32);
        let b = sim.run_source(
            p2.as_mut(),
            &mut ReplaySource::from_bytes(bytes).map_err(|e| e.to_string())?);
        assert_results_identical(&format!("replay seed={seed}"), &a, &b,
                                 true)?;
        Ok(())
    });
}

/// A 1-shard gossip-off plane is the unsharded simulator, bit for bit,
/// for all three systems — the shard plane's conformance oracle.
#[test]
fn prop_one_shard_plane_bit_identical_to_unsharded() {
    check("1-shard plane = unsharded simulator", 3, |rng| {
        let seed = rng.next_u64();
        for system in SYSTEMS {
            let trace = ScaleSourceConfig {
                seed,
                minutes: 15,
                jobs_per_minute: 5.0,
                ..Default::default()
            };
            let mut pc = ShardPlaneConfig::new(system, 1, 32, seed);
            pc.gossip = false;
            pc.sim.debug_oracle = true;
            let pr = ShardPlane::new(pc)
                .run(&mut ScaleSource::new(trace.clone()));
            ensure(pr.violations.is_empty(),
                   format!("{system}: plane violations {:?}", pr.violations))?;

            let sim = Simulator::new(
                SimConfig { max_gpus: 32, debug_oracle: true,
                            ..Default::default() },
                PerfModel::default(),
            );
            let mut policy = make_shard_policy(system, seed, 32);
            let reference =
                sim.run(policy.as_mut(), ScaleSource::new(trace).materialize());
            assert_results_identical(&format!("{system} seed={seed}"),
                                     &pr.per_shard[0], &reference, true)?;
        }
        Ok(())
    });
}

/// Partition chaos is a pure function of (seed, window): a partitioned
/// plane replays bit-identically across repeats and across dense vs
/// coalesced ticking, routes around severed shards, and admits every
/// streamed job exactly once.
#[test]
fn prop_partitioned_plane_deterministic_across_repeats_and_ticking() {
    check("partitioned plane deterministic", 2, |rng| {
        let seed = rng.next_u64();
        for system in SYSTEMS {
            let trace = ScaleSourceConfig {
                seed,
                minutes: 25,
                jobs_per_minute: 6.0,
                ..Default::default()
            };
            let mut pc = ShardPlaneConfig::new(system, 3, 16, seed);
            pc.gossip_period_s = 300.0;
            pc.partition = Some(ChaosProfile::partition());
            let run = |dense: bool| {
                let mut cfg = pc.clone();
                cfg.force_dense = dense;
                ShardPlane::new(cfg)
                    .run(&mut ScaleSource::new(trace.clone()))
            };
            let a = run(false);
            let b = run(false);
            let d = run(true);
            let total = ScaleSource::new(trace.clone()).total_jobs();
            let tag = format!("{system} seed={seed}");
            for r in [&a, &b, &d] {
                ensure(r.violations.is_empty(),
                       format!("{tag}: plane violations {:?}", r.violations))?;
                ensure(r.routed.iter().sum::<usize>() == total,
                       format!("{tag}: {} of {total} jobs routed",
                               r.routed.iter().sum::<usize>()))?;
            }
            ensure(a.routed == b.routed && a.routed == d.routed,
                   format!("{tag}: routing not replayable: {:?} / {:?} / \
                            {:?}", a.routed, b.routed, d.routed))?;
            ensure(a.failovers == b.failovers && a.failovers == d.failovers,
                   format!("{tag}: failovers diverged"))?;
            assert_results_identical(&format!("{tag} repeat"), &a.merged(),
                                     &b.merged(), true)?;
            assert_results_identical(&format!("{tag} dense"), &a.merged(),
                                     &d.merged(), false)?;
        }
        Ok(())
    });
}

/// The fork-join executor is bit-identical to the sequential inline
/// loop for every system, with and without gossip, with and without
/// partition chaos, at widths 2 and 4 (4 clamps to the shard count):
/// every cell sees the identical command sequence whatever the thread
/// interleaving, so width cannot change a single bit of the result.
#[test]
fn prop_parallel_plane_bit_identical_to_sequential() {
    check("parallel plane = sequential plane", 1, |rng| {
        let seed = rng.next_u64();
        for system in SYSTEMS {
            for (gossip, partition) in
                [(false, false), (true, false), (false, true), (true, true)]
            {
                let trace = ScaleSourceConfig {
                    seed,
                    minutes: 20,
                    jobs_per_minute: 6.0,
                    n_tasks: 12,
                    task_base: NOVEL_TASK_BASE,
                    ..Default::default()
                };
                let mut pc = ShardPlaneConfig::new(system, 3, 16, seed);
                pc.gossip = gossip;
                pc.gossip_period_s = 300.0;
                if partition {
                    pc.partition = Some(ChaosProfile::partition());
                }
                let run = |w: usize| {
                    let mut cfg = pc.clone();
                    cfg.workers = w;
                    ShardPlane::new(cfg)
                        .run(&mut ScaleSource::new(trace.clone()))
                };
                let seq = run(1);
                let tag = format!(
                    "{system} gossip={gossip} partition={partition} \
                     seed={seed}");
                ensure(seq.workers == 1, format!("{tag}: seq width"))?;
                ensure(seq.violations.is_empty(),
                       format!("{tag}: seq violations {:?}",
                               seq.violations))?;
                for w in [2usize, 4] {
                    let par = run(w);
                    ensure(par.workers == w.min(3),
                           format!("{tag}: width {w} ran at {}",
                                   par.workers))?;
                    ensure(par.violations.is_empty(),
                           format!("{tag}: par violations {:?}",
                                   par.violations))?;
                    ensure(seq.routed == par.routed,
                           format!("{tag} w={w}: routing diverged \
                                    {:?} vs {:?}", seq.routed, par.routed))?;
                    ensure(seq.failovers == par.failovers
                               && seq.gossip_rounds == par.gossip_rounds
                               && seq.gossip_items == par.gossip_items,
                           format!("{tag} w={w}: plane telemetry \
                                    diverged"))?;
                    ensure(seq.score_cache_hits == par.score_cache_hits
                               && seq.score_cache_misses
                                   == par.score_cache_misses,
                           format!("{tag} w={w}: score-cache telemetry \
                                    diverged"))?;
                    for (s, (x, y)) in seq
                        .per_shard
                        .iter()
                        .zip(&par.per_shard)
                        .enumerate()
                    {
                        assert_results_identical(
                            &format!("{tag} w={w} shard={s}"), x, y, true)?;
                    }
                }
            }
        }
        Ok(())
    });
}

/// Gossip moves first-hand prompts across shards without breaking
/// conservation, and a gossiping plane still replays exactly.
#[test]
fn prop_gossip_plane_conserves_and_replays() {
    check("gossip plane conserves and replays", 2, |rng| {
        let seed = rng.next_u64();
        let trace = ScaleSourceConfig {
            seed,
            minutes: 30,
            jobs_per_minute: 8.0,
            n_tasks: 16,
            task_base: NOVEL_TASK_BASE,
            ..Default::default()
        };
        let mut pc = ShardPlaneConfig::new("prompttuner", 2, 16, seed);
        pc.gossip_period_s = 180.0;
        let a = ShardPlane::new(pc.clone())
            .run(&mut ScaleSource::new(trace.clone()));
        let b = ShardPlane::new(pc)
            .run(&mut ScaleSource::new(trace.clone()));
        let total = ScaleSource::new(trace).total_jobs();
        ensure(a.violations.is_empty(),
               format!("plane violations {:?}", a.violations))?;
        ensure(a.routed.iter().sum::<usize>() == total,
               format!("{} of {total} jobs routed",
                       a.routed.iter().sum::<usize>()))?;
        ensure(a.gossip_items > 0,
               "novel-task plane exchanged no prompts".to_string())?;
        ensure(a.gossip_items == b.gossip_items
                   && a.gossip_rounds == b.gossip_rounds,
               format!("gossip telemetry not replayable: {}/{} vs {}/{}",
                       a.gossip_rounds, a.gossip_items, b.gossip_rounds,
                       b.gossip_items))?;
        assert_results_identical("gossip repeat", &a.merged(), &b.merged(),
                                 true)
    });
}

/// `scenario::FAMILIES` (the manifest benches emit into every perf
/// record) names the whole catalogue plus replay — pinned here from the
/// test side too, so a new family cannot ship without joining the
/// manifest the tooling consumes.
#[test]
fn families_manifest_covers_catalogue_and_replay() {
    let mut expect: Vec<String> = Scenario::catalogue()
        .iter()
        .map(|sc| sc.name().to_string())
        .collect();
    expect.push("replay".to_string());
    expect.sort();
    expect.dedup();
    let mut got: Vec<String> = prompttuner::scenario::FAMILIES
        .iter()
        .map(|s| s.to_string())
        .collect();
    got.sort();
    assert_eq!(got, expect);
}
