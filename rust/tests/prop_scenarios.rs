//! Conformance suite for the scenario engine: every workload family is
//! bit-deterministic in its seed, emits the configured job counts, keeps
//! arrivals inside its window, and never issues a deadline before the
//! arrival. Scenario runs through the simulator are audited by the
//! simulation oracle ([`SimOracle`]).

use prompttuner::bench::{self, SweepCell, SYSTEMS};
use prompttuner::cluster::{ClusterState, Policy, RetryEvent, RevokeEvent,
                           SimConfig, SimOracle, Simulator};
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::fault::ChaosKind;
use prompttuner::scenario::{replay, Scenario};
use prompttuner::util::prop::{check, check_sized, ensure};
use prompttuner::workload::{JobSpec, Llm, PerfModel};

/// Compare two generated traces field-by-field, bitwise for floats.
fn assert_identical(name: &str, a: &[JobSpec], b: &[JobSpec]) -> Result<(), String> {
    ensure(a.len() == b.len(),
           format!("{name}: {} vs {} jobs", a.len(), b.len()))?;
    for (x, y) in a.iter().zip(b) {
        let same = x.id == y.id
            && x.llm == y.llm
            && x.task_id == y.task_id
            && x.traced_gpus == y.traced_gpus
            && x.submit_s.to_bits() == y.submit_s.to_bits()
            && x.duration_s.to_bits() == y.duration_s.to_bits()
            && x.base_iters.to_bits() == y.base_iters.to_bits()
            && x.user_prompt_quality.to_bits() == y.user_prompt_quality.to_bits()
            && x.slo_s.to_bits() == y.slo_s.to_bits();
        ensure(same, format!("{name}: job {} diverged:\n  {x:?}\n  {y:?}", x.id))?;
    }
    Ok(())
}

#[test]
fn prop_families_bit_deterministic_per_seed() {
    check("scenario generation deterministic per seed", 8, |rng| {
        let seed = rng.next_u64();
        let slo = [0.5, 1.0, 1.5][rng.below(3)];
        for sc in Scenario::catalogue() {
            let a = sc.generate(seed, slo).map_err(|e| e.to_string())?;
            let b = sc.generate(seed, slo).map_err(|e| e.to_string())?;
            assert_identical(sc.name(), &a, &b)?;
            // ... and a different seed must actually change the trace
            let c = sc.generate(seed ^ 1, slo).map_err(|e| e.to_string())?;
            ensure(
                a.len() != c.len()
                    || a.iter().zip(&c).any(|(x, y)| {
                        x.submit_s.to_bits() != y.submit_s.to_bits()
                    }),
                format!("{}: seed change had no effect", sc.name()),
            )?;
        }
        Ok(())
    });
}

#[test]
fn prop_families_emit_configured_counts_and_windows() {
    check("scenario counts / windows / deadlines", 8, |rng| {
        let seed = rng.next_u64();
        let slo = [0.5, 1.0, 2.0][rng.below(3)];
        for sc in Scenario::catalogue() {
            let jobs = sc.generate(seed, slo).map_err(|e| e.to_string())?;
            let name = sc.name();
            ensure(jobs.len() == sc.expected_jobs().unwrap(),
                   format!("{name}: {} jobs", jobs.len()))?;
            let window = sc.window_s().unwrap();
            for (i, j) in jobs.iter().enumerate() {
                ensure(j.id == i, format!("{name}: non-dense id {}", j.id))?;
                ensure(
                    (0.0..window).contains(&j.submit_s),
                    format!("{name}: job {i} arrives at {} outside [0, {window})",
                            j.submit_s),
                )?;
                ensure(
                    j.deadline() > j.submit_s,
                    format!("{name}: job {i} deadline {} before arrival {}",
                            j.deadline(), j.submit_s),
                )?;
                ensure(j.duration_s > 0.0 && j.duration_s.is_finite(),
                       format!("{name}: job {i} duration {}", j.duration_s))?;
                ensure(j.base_iters > 0.0,
                       format!("{name}: job {i} base iters {}", j.base_iters))?;
                ensure(
                    j.user_prompt_quality > 0.0 && j.user_prompt_quality < 1.0,
                    format!("{name}: job {i} quality {}", j.user_prompt_quality),
                )?;
            }
            for w in jobs.windows(2) {
                ensure(w[0].submit_s <= w[1].submit_s,
                       format!("{name}: arrivals out of order"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn prop_replay_roundtrip_is_exact() {
    let dir = std::env::temp_dir().join("pt_prop_replay");
    std::fs::create_dir_all(&dir).unwrap();
    check("replay file round-trip bit-exact", 6, |rng| {
        let seed = rng.next_u64();
        let sc = Scenario::catalogue()
            .into_iter()
            .nth(rng.below(4))
            .unwrap();
        let jobs = sc.generate(seed, 1.0).map_err(|e| e.to_string())?;
        let path = dir.join(format!("t{seed}.bin"));
        replay::save(&path, &jobs).map_err(|e| e.to_string())?;
        let re = Scenario::Replay { path: path.clone() };
        // replay ignores seed and SLO emergence: both draws identical
        let a = re.generate(1, 0.5).map_err(|e| e.to_string())?;
        let b = re.generate(2, 2.0).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        assert_identical("replay", &a, &jobs)?;
        assert_identical("replay-indep", &a, &b)?;
        Ok(())
    });
}

#[test]
fn prop_replay_roundtrip_random_traces() {
    // Fuzz the binary trace format directly: random (not
    // generator-shaped) job specs — including boundary qualities 0/1 and
    // extreme-but-valid durations — must survive a binio write + read
    // with exact f64 bit equality, both in memory and through a file.
    let dir = std::env::temp_dir().join("pt_prop_replay_random");
    std::fs::create_dir_all(&dir).unwrap();
    let mut case = 0u64;
    check("random trace binio round-trip is bit-exact", 40, |rng| {
        case += 1;
        let n = rng.below(60);
        let mut t = 0.0f64;
        let mut jobs = Vec::with_capacity(n);
        for i in 0..n {
            // non-decreasing arrivals with dense ids, so the loader's
            // stable re-sort/re-id pass is the identity
            t += rng.f64() * 90.0;
            let llm = Llm::ALL[rng.below(Llm::ALL.len())];
            let duration_s = match rng.below(8) {
                0 => 5e-3,
                1 => 1e7,
                _ => rng.range_f64(1.0, 900.0),
            };
            let user_prompt_quality = match rng.below(8) {
                0 => 0.0,
                1 => 1.0,
                _ => rng.f64(),
            };
            jobs.push(JobSpec {
                id: i,
                llm,
                task_id: rng.below(1 << 20),
                submit_s: t,
                duration_s,
                traced_gpus: llm.gpus_per_replica() * (1 + rng.below(4)),
                base_iters: rng.range_f64(1e-3, 1e6),
                user_prompt_quality,
                slo_s: rng.range_f64(1e-3, 1e5),
            });
        }
        // in-memory round trip
        let bytes = replay::to_bytes(&jobs);
        let back = replay::from_bytes(&bytes).map_err(|e| e.to_string())?;
        assert_identical("random-roundtrip", &back, &jobs)?;
        // file round trip
        let path = dir.join(format!("r{case}.bin"));
        replay::save(&path, &jobs).map_err(|e| e.to_string())?;
        let from_file = replay::load(&path).map_err(|e| e.to_string())?;
        let _ = std::fs::remove_file(&path);
        assert_identical("random-roundtrip-file", &from_file, &jobs)?;
        Ok(())
    });
}

/// Forces dense 50 ms rounds on any policy by leaving
/// `next_timed_action` at its `Wake::Dense` default — the reference for
/// the chaos coalescing-equality property below.
struct DenseTick(Box<dyn Policy>);

impl Policy for DenseTick {
    fn name(&self) -> &str {
        self.0.name()
    }
    fn tick_interval(&self) -> f64 {
        self.0.tick_interval()
    }
    fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
        self.0.on_arrival(st, id)
    }
    fn on_job_complete(&mut self, st: &mut ClusterState, id: usize) {
        self.0.on_job_complete(st, id)
    }
    fn on_tick(&mut self, st: &mut ClusterState) {
        self.0.on_tick(st)
    }
    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        self.0.on_revoke(st, ev)
    }
    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        self.0.on_retry(st, ev)
    }
    fn capacity(&self) -> Option<usize> {
        self.0.capacity()
    }
    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        self.0.set_capacity(st, gpus)
    }
    fn bank_coverage(&self, llm: Llm, task_id: usize) -> Option<f64> {
        self.0.bank_coverage(llm, task_id)
    }
    fn enable_gossip_log(&mut self) {
        self.0.enable_gossip_log()
    }
    fn drain_tuned(&mut self, out: &mut Vec<prompttuner::cluster::TunedPrompt>) {
        self.0.drain_tuned(out)
    }
    fn absorb_tuned(&mut self, items: &[prompttuner::cluster::TunedPrompt]) {
        self.0.absorb_tuned(items)
    }
    fn knobs(&self) -> Vec<prompttuner::cluster::KnobSpec> {
        self.0.knobs()
    }
    fn knob_value(&self, name: &str) -> Option<f64> {
        self.0.knob_value(name)
    }
    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        self.0.set_knob(st, name, value)
    }
    fn tuner_report(&self) -> Option<prompttuner::cluster::TunerReport> {
        self.0.tuner_report()
    }
    // next_timed_action: default Wake::Dense — never coalesce.
}

/// Chaos injection is hash-derived, never RNG-state-derived, so a
/// chaos-wrapped run must be bit-identical across repeated same-seed
/// runs AND across dense vs coalesced ticking — for every profile and
/// every system (the full `bench::make_policy` wiring: FaultInjector +
/// ChaosEngine, rolling rack storms included). Since the tick core moved
/// to O(events) batch skipping, the coalesced leg of this rotation drives
/// chaos storms — retry-backoff holdbacks, staled completion events and
/// rack fan-out — straight through the batch-skip fast path, so the
/// equality here doubles as its conformance oracle.
#[test]
fn prop_chaos_runs_bit_identical_across_ticking_and_repeats() {
    let mut retries_total: u64 = 0;
    let mut delay_total: f64 = 0.0;
    check_sized("chaos runs identical dense/coalesced/repeated", 6,
                |rng, case| {
        let seed = rng.next_u64();
        let kind = ChaosKind::ALL[(case % 3) as usize];
        let sc = Scenario::Chaos { kind, jobs_per_llm: 16 };
        for system in SYSTEMS {
            let cell = SweepCell::scenario(
                format!("chaos-eq/{}/{system}", sc.name()), system,
                sc.clone(), 1.0, 32, seed);
            let sim = Simulator::new(
                SimConfig { max_gpus: 32, ..Default::default() },
                PerfModel::default(),
            );
            let run = |dense: bool| {
                let mut p: Box<dyn Policy> = if dense {
                    Box::new(DenseTick(bench::make_policy(&cell)))
                } else {
                    bench::make_policy(&cell)
                };
                sim.run(p.as_mut(), bench::gen_jobs(&cell))
            };
            let a = run(false);
            let b = run(false);
            let d = run(true);
            let tag = format!("{}/{system} seed={seed}", sc.name());
            for (what, o) in [("repeat", &b), ("dense", &d)] {
                ensure(a.n_done == o.n_done && a.n_violations == o.n_violations,
                       format!("{tag}: {what}: done/violations diverged"))?;
                ensure(a.cost_usd.to_bits() == o.cost_usd.to_bits(),
                       format!("{tag}: {what}: cost {} vs {}",
                               a.cost_usd, o.cost_usd))?;
                ensure(
                    a.retries == o.retries
                        && a.retry_iters.to_bits() == o.retry_iters.to_bits()
                        && a.chaos_delay_s.to_bits()
                            == o.chaos_delay_s.to_bits(),
                    format!("{tag}: {what}: chaos telemetry diverged: \
                             {} retries / {} iters / {} delay vs \
                             {} / {} / {}",
                            a.retries, a.retry_iters, a.chaos_delay_s,
                            o.retries, o.retry_iters, o.chaos_delay_s),
                )?;
                ensure(
                    a.revocations == o.revocations
                        && a.lost_iters.to_bits() == o.lost_iters.to_bits(),
                    format!("{tag}: {what}: fault telemetry diverged"),
                )?;
                ensure(a.job_latencies.len() == o.job_latencies.len(),
                       format!("{tag}: {what}: latency count"))?;
                for (x, y) in a.job_latencies.iter().zip(&o.job_latencies) {
                    ensure(
                        x.0.to_bits() == y.0.to_bits()
                            && x.1.to_bits() == y.1.to_bits()
                            && x.2.to_bits() == y.2.to_bits()
                            && x.3.to_bits() == y.3.to_bits(),
                        format!("{tag}: {what}: per-job latency \
                                 {x:?} vs {y:?}"),
                    )?;
                }
            }
            retries_total += a.retries;
            delay_total += a.chaos_delay_s;
        }
        Ok(())
    });
    // the profiles must actually have misbehaved somewhere
    assert!(delay_total > 0.0, "no chaos latency was ever injected");
    assert!(retries_total > 0, "no completion was ever failed");
}

/// Every family must actually run through the scheduler stack — audited
/// by the collecting oracle — and make progress.
#[test]
fn scenarios_run_under_the_oracle() {
    for sc in Scenario::catalogue() {
        let jobs = sc.generate(23, 1.0).unwrap();
        let n = jobs.len();
        // widen the horizon: a heavy-tail job granted a single GPU can
        // legally run for hours of simulated time
        let sim = Simulator::new(
            SimConfig { max_gpus: 32, horizon_s: 14400.0, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = SimOracle::collecting(PromptTuner::new(PromptTunerConfig {
            max_gpus: 32,
            seed: 23,
            ..Default::default()
        }));
        let res = sim.run(&mut policy, jobs);
        assert_eq!(res.n_done, n, "{} left jobs unfinished", sc.name());
        assert!(policy.violations().is_empty(), "{}", sc.name());
        assert!(policy.audits() > 0, "{}", sc.name());
    }
}
