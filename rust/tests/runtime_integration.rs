//! Cross-layer integration tests: the Rust runtime executing the real AOT
//! artifacts (L1 Pallas kernel + L2 model, lowered to HLO text, compiled
//! by PJRT). Requires `make artifacts`.
//!
//! Tests are grouped into a few large functions so each PJRT model load
//! (~seconds of XLA compilation) is amortized over many assertions.

use prompttuner::runtime::{ModelRuntime, TuneState};
use prompttuner::tuning::{dp_tune_step, DpState, TaskUniverse, Trainer, TrainerConfig};
use prompttuner::util::manifest::Manifest;
use prompttuner::util::rng::Rng;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// These tests need both `make artifacts` output and the `pjrt` feature
/// (the real PJRT runtime); otherwise they skip rather than fail.
fn runnable() -> bool {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return false;
    }
    if !artifacts_dir().join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return false;
    }
    true
}

fn load() -> (Manifest, TaskUniverse, ModelRuntime) {
    let manifest = Manifest::load(artifacts_dir()).expect("run `make artifacts`");
    let uni = TaskUniverse::load(manifest.tasks_path_abs()).unwrap();
    let rt = ModelRuntime::load(&manifest, "sim-gpt2b").unwrap();
    (manifest, uni, rt)
}

#[test]
fn manifest_covers_all_variants_and_artifacts() {
    if !runnable() {
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).expect("run `make artifacts`");
    for variant in ["sim-gpt2b", "sim-gpt2l", "sim-v7b", "e2e-90m"] {
        let m = &manifest.models[variant];
        assert_eq!(m.artifacts.len(), 6, "{variant}");
        for f in ["embed_prompt", "score", "features", "tune_step",
                  "eval_loss", "grad_prompt"] {
            let p = manifest.artifact_path(variant, f).unwrap();
            assert!(p.exists(), "{} missing", p.display());
        }
    }
    // sim variants ship pretrained weights; the e2e variant does not
    assert!(manifest.models["sim-gpt2b"].theta_path.is_some());
    assert!(manifest.models["e2e-90m"].theta_path.is_none());
}

#[test]
fn score_features_and_embed_are_consistent() {
    if !runnable() {
        return;
    }
    let (_m, uni, rt) = load();
    let mut rng = Rng::new(1);
    let (etoks, etgts) = uni.sample_batch(&mut rng, 0, rt.info.batch_eval, rt.info.seq);

    // --- embed_prompt returns P*D floats and is deterministic ---
    let tag = uni.tag(0);
    let e1 = rt.embed_prompt(tag).unwrap();
    let e2 = rt.embed_prompt(tag).unwrap();
    assert_eq!(e1.len(), rt.info.prompt_len * rt.info.d_model);
    assert_eq!(e1, e2);

    // --- score(ptoks) == eval_loss(embed(ptoks)) (same HLO semantics) ---
    let s = rt.score(tag, &etoks, &etgts).unwrap();
    let e = rt.eval_loss(&e1, &etoks, &etgts).unwrap();
    assert!((s - e).abs() < 1e-4, "score {s} vs eval {e}");
    assert!(s.is_finite() && s > 0.0);

    // --- the RIGHT tag scores better than a WRONG tag on task 0 ---
    // (this is the pretrained tag-conditioning the whole paper rests on)
    let wrong = uni.tag(uni.n_tasks / 2);
    let s_wrong = rt.score(wrong, &etoks, &etgts).unwrap();
    assert!(
        s + 0.05 < s_wrong,
        "right-tag score {s} not better than wrong-tag {s_wrong}"
    );

    // --- features: deterministic, D-dimensional, prompt-dependent ---
    let f1 = rt.features(tag).unwrap();
    let f2 = rt.features(tag).unwrap();
    let f3 = rt.features(wrong).unwrap();
    assert_eq!(f1.len(), rt.info.d_model);
    assert_eq!(f1, f2);
    let diff: f32 = f1.iter().zip(&f3).map(|(a, b)| (a - b).abs()).sum();
    assert!(diff > 1e-3, "features identical across prompts");

    // --- same-archetype tags have more similar features ---
    let arch0 = uni.arch_id[0];
    let same = (1..uni.n_tasks).find(|&t| uni.arch_id[t] == arch0);
    let cross = (1..uni.n_tasks).find(|&t| uni.arch_id[t] != arch0);
    if let (Some(same), Some(cross)) = (same, cross) {
        use prompttuner::promptbank::cosine_distance;
        let fs = rt.features(uni.tag(same)).unwrap();
        let fc = rt.features(uni.tag(cross)).unwrap();
        let d_same = cosine_distance(&f1, &fs);
        let d_cross = cosine_distance(&f1, &fc);
        assert!(
            d_same < d_cross + 0.3,
            "archetype structure lost: same {d_same} cross {d_cross}"
        );
    }
}

#[test]
fn tune_step_learns_and_matches_dp_path() {
    if !runnable() {
        return;
    }
    let (_m, uni, rt) = load();
    let mut rng = Rng::new(2);
    let task = 3usize;
    let (toks, tgts) = uni.sample_batch(&mut rng, task, rt.info.batch_train, rt.info.seq);

    // --- losses decrease over repeated steps on a fixed batch ---
    let prompt0 = rt.embed_prompt(uni.tag((task + 7) % uni.n_tasks)).unwrap();
    let mut st = TuneState::new(prompt0.clone());
    let first = rt.tune_step(&mut st, &toks, &tgts, 0.05).unwrap();
    let mut last = first;
    for _ in 0..20 {
        last = rt.tune_step(&mut st, &toks, &tgts, 0.05).unwrap();
    }
    assert!(last < first - 0.05, "no learning: {first} -> {last}");
    assert!(st.prompt != prompt0, "prompt unchanged");

    // --- dp path with one replica reproduces the fused tune_step ---
    let mut fused = TuneState::new(prompt0.clone());
    let mut dp = DpState::new(prompt0.clone());
    for i in 0..3 {
        let (t2, g2) = uni.sample_batch(&mut rng, task, rt.info.batch_train, rt.info.seq);
        let lf = rt.tune_step(&mut fused, &t2, &g2, 0.05).unwrap();
        let ld = dp_tune_step(&rt, &mut dp, &[(t2.clone(), g2.clone())], 0.05).unwrap();
        assert!((lf - ld).abs() < 1e-3, "step {i}: fused {lf} vs dp {ld}");
    }
    let max_diff = fused
        .prompt
        .iter()
        .zip(&dp.prompt)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(max_diff < 2e-3, "prompt divergence {max_diff}");

    // --- dp with two replicas (synchronous gradient averaging) ---
    let mut dp2 = DpState::new(prompt0);
    let (ta, ga) = uni.sample_batch(&mut rng, task, rt.info.batch_train, rt.info.seq);
    let (tb, gb) = uni.sample_batch(&mut rng, task, rt.info.batch_train, rt.info.seq);
    let loss = dp_tune_step(&rt, &mut dp2, &[(ta, ga), (tb, gb)], 0.05).unwrap();
    assert!(loss.is_finite() && loss > 0.0);
    assert!(dp2.prompt.iter().all(|x| x.is_finite()));
}

#[test]
fn good_initial_prompts_reach_target_in_fewer_iterations() {
    // The paper's central ITA claim (Fig 2c): convergence is highly
    // sensitive to the initial prompt. On the real pretrained model, the
    // task's own tag must reach the target in (far) fewer iterations than
    // a wrong-archetype tag.
    if !runnable() {
        return;
    }
    let (_m, uni, rt) = load();
    let task = 5usize;
    let trainer = Trainer::new(
        &rt,
        &uni,
        TrainerConfig { lr: 0.08, max_iters: 120, eval_every: 5, seed: 3 },
    );
    // target: midway between right-tag score and a plateau
    let right_score = trainer.score_tokens(task, uni.tag(task)).unwrap();
    let target = right_score + 0.10;

    let good = trainer.tune(task, uni.tag(task), target).unwrap();
    // a wrong tag from a different archetype
    let wrong_task = (0..uni.n_tasks)
        .find(|&t| uni.arch_id[t] != uni.arch_id[task])
        .unwrap();
    let bad = trainer.tune(task, uni.tag(wrong_task), target).unwrap();

    assert!(good.reached_target, "good prompt never reached target");
    assert!(
        good.iters < bad.iters || !bad.reached_target,
        "good {} iters vs bad {} iters (bad reached: {})",
        good.iters, bad.iters, bad.reached_target
    );
}

#[test]
fn two_layer_bank_lookup_with_real_scorer() {
    if !runnable() {
        return;
    }
    use prompttuner::promptbank::{PromptCandidate, TwoLayerBank};
    use prompttuner::runtime::RuntimeScorer;
    let (_m, uni, rt) = load();
    let mut rng = Rng::new(4);
    // candidate corpus: every task tag + noisy variants
    let mut cands = vec![];
    for t in 0..uni.n_tasks {
        let tokens = uni.tag(t).to_vec();
        let feature = rt.features(&tokens).unwrap();
        cands.push(PromptCandidate { tokens, feature, source_task: Some(t) });
    }
    for t in 0..32 {
        let tokens = uni.noisy_tag(&mut rng, t, 0.25);
        let feature = rt.features(&tokens).unwrap();
        cands.push(PromptCandidate { tokens, feature, source_task: Some(t) });
    }
    let n = cands.len();
    let bank = TwoLayerBank::build(cands, 8, 3000, &mut rng).unwrap();

    let task = 2usize;
    let trainer = Trainer::new(&rt, &uni, TrainerConfig::default());
    let (etoks, etgts) = trainer.eval_batch(task);

    let mut scorer = RuntimeScorer::new(&rt, etoks.clone(), etgts.clone());
    let two = bank.lookup(&mut scorer);
    assert!(two.evals < n, "two-layer not cheaper than brute force");

    let mut brute_scorer = RuntimeScorer::new(&rt, etoks, etgts);
    let brute = bank.lookup_bruteforce(&mut brute_scorer);
    assert_eq!(brute.evals, n);
    // the two-layer pick must be close to the global optimum (paper: the
    // score candidate retains >= 90% of ideal performance)
    assert!(
        two.best_score <= brute.best_score + 0.25,
        "two-layer {} vs brute {}",
        two.best_score,
        brute.best_score
    );
    // and both should identify a candidate related to the queried task's
    // archetype more often than chance — check the brute-force optimum
    let best = bank.candidate(brute.best);
    assert!(best.source_task.is_some());
}
