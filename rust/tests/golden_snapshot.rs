//! Golden snapshot: a serialized Medium paper trace plus per-policy
//! `SimResult` summary fixtures (binary, `util::binio`), and a test that
//! fails with a readable field-by-field diff when either the trace
//! generator or the simulation metrics drift unintentionally.
//!
//! Bootstrap: on a machine where the fixtures don't exist yet (or with
//! `GOLDEN_UPDATE=1`), the test writes `tests/golden/*.bin` and passes —
//! commit the generated files to arm the snapshot. Simulation summaries
//! involve libm calls (`powf` in the iteration-scaling law), so fixtures
//! are pinned to the CI platform; regenerate with `GOLDEN_UPDATE=1` when
//! a metric change is *intended*.

use std::path::PathBuf;

use prompttuner::baselines::{ElasticFlow, ElasticFlowConfig, Infless, InflessConfig};
use prompttuner::cluster::{Policy, SimConfig, SimOracle, SimResult, Simulator};
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::scenario::replay;
use prompttuner::trace::{Load, TraceConfig, TraceGenerator};
use prompttuner::util::binio::{read_all, LeReader, LeWriter};
use prompttuner::workload::{JobSpec, PerfModel};

const SEED: u64 = 4242;
const GPUS: usize = 32;
const SYSTEMS: [&str; 3] = ["prompttuner", "infless", "elasticflow"];
const RESULTS_MAGIC: u32 = u32::from_le_bytes(*b"PTG1");

fn golden_dir() -> PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR"))
        .join("tests")
        .join("golden")
}

fn fresh_trace() -> Vec<JobSpec> {
    let mut gen = TraceGenerator::new(
        TraceConfig { seed: SEED, ..Default::default() },
        PerfModel::default(),
    );
    gen.generate_main(Load::Medium)
}

fn make_policy(system: &str) -> Box<dyn Policy> {
    match system {
        "prompttuner" => Box::new(PromptTuner::new(PromptTunerConfig {
            max_gpus: GPUS,
            seed: SEED,
            ..Default::default()
        })),
        "infless" => Box::new(Infless::new(InflessConfig {
            max_gpus: GPUS,
            seed: SEED,
            ..Default::default()
        })),
        _ => Box::new(ElasticFlow::new(ElasticFlowConfig {
            cluster_size: GPUS,
            seed: SEED,
            ..Default::default()
        })),
    }
}

/// The summary fields the snapshot pins (rounds/wall-clock are perf
/// metrics, free to change; these are the simulation's semantics).
#[derive(Debug, PartialEq)]
struct Summary {
    n_jobs: u32,
    n_done: u32,
    n_violations: u32,
    cost_usd: f64,
    gpu_seconds_billed: f64,
    gpu_seconds_busy: f64,
    mean_utilization: f64,
}

impl Summary {
    fn of(r: &SimResult) -> Summary {
        Summary {
            n_jobs: r.n_jobs as u32,
            n_done: r.n_done as u32,
            n_violations: r.n_violations as u32,
            cost_usd: r.cost_usd,
            gpu_seconds_billed: r.gpu_seconds_billed,
            gpu_seconds_busy: r.gpu_seconds_busy,
            mean_utilization: r.mean_utilization,
        }
    }

    fn diff(&self, golden: &Summary, system: &str, out: &mut Vec<String>) {
        let mut num = |name: &str, got: f64, want: f64| {
            // tolerate libm-level noise, catch behavioral drift
            let tol = 1e-9 * want.abs().max(1.0);
            if (got - want).abs() > tol {
                out.push(format!(
                    "{system}: {name} drifted {want} -> {got} (golden -> current)"
                ));
            }
        };
        num("n_jobs", self.n_jobs as f64, golden.n_jobs as f64);
        num("n_done", self.n_done as f64, golden.n_done as f64);
        num("n_violations", self.n_violations as f64,
            golden.n_violations as f64);
        num("cost_usd", self.cost_usd, golden.cost_usd);
        num("gpu_seconds_billed", self.gpu_seconds_billed,
            golden.gpu_seconds_billed);
        num("gpu_seconds_busy", self.gpu_seconds_busy, golden.gpu_seconds_busy);
        num("mean_utilization", self.mean_utilization, golden.mean_utilization);
    }
}

fn run_summaries(jobs: &[JobSpec]) -> Vec<Summary> {
    SYSTEMS
        .iter()
        .map(|s| {
            let sim = Simulator::new(
                SimConfig { max_gpus: GPUS, ..Default::default() },
                PerfModel::default(),
            );
            let mut policy = SimOracle::collecting(make_policy(s));
            let res = sim.run(&mut policy, jobs.to_vec());
            assert!(policy.violations().is_empty(), "{s}: oracle violations");
            Summary::of(&res)
        })
        .collect()
}

fn write_results(path: &PathBuf, summaries: &[Summary]) {
    let mut w = LeWriter::new();
    w.u32(RESULTS_MAGIC);
    w.u32(1); // version
    w.u32(summaries.len() as u32);
    for s in summaries {
        w.u32(s.n_jobs);
        w.u32(s.n_done);
        w.u32(s.n_violations);
        w.f64(s.cost_usd);
        w.f64(s.gpu_seconds_billed);
        w.f64(s.gpu_seconds_busy);
        w.f64(s.mean_utilization);
    }
    w.write_to(path).expect("writing golden results fixture");
}

fn read_results(path: &PathBuf) -> Vec<Summary> {
    let bytes = read_all(path).expect("reading golden results fixture");
    let mut r = LeReader::new(&bytes);
    assert_eq!(r.u32().unwrap(), RESULTS_MAGIC, "bad results-fixture magic");
    assert_eq!(r.u32().unwrap(), 1, "bad results-fixture version");
    let n = r.u32().unwrap() as usize;
    assert_eq!(n, SYSTEMS.len(), "results fixture covers {n} systems");
    (0..n)
        .map(|_| Summary {
            n_jobs: r.u32().unwrap(),
            n_done: r.u32().unwrap(),
            n_violations: r.u32().unwrap(),
            cost_usd: r.f64().unwrap(),
            gpu_seconds_billed: r.f64().unwrap(),
            gpu_seconds_busy: r.f64().unwrap(),
            mean_utilization: r.f64().unwrap(),
        })
        .collect()
}

fn diff_traces(golden: &[JobSpec], fresh: &[JobSpec]) -> Vec<String> {
    let mut out = vec![];
    if golden.len() != fresh.len() {
        out.push(format!(
            "trace length drifted {} -> {} jobs", golden.len(), fresh.len()
        ));
        return out;
    }
    for (g, f) in golden.iter().zip(fresh) {
        let same = g.llm == f.llm
            && g.task_id == f.task_id
            && g.traced_gpus == f.traced_gpus
            && g.submit_s.to_bits() == f.submit_s.to_bits()
            && g.duration_s.to_bits() == f.duration_s.to_bits()
            && g.base_iters.to_bits() == f.base_iters.to_bits()
            && g.user_prompt_quality.to_bits() == f.user_prompt_quality.to_bits()
            && g.slo_s.to_bits() == f.slo_s.to_bits();
        if !same {
            out.push(format!(
                "trace job {} drifted:\n  golden:  {g:?}\n  current: {f:?}",
                g.id
            ));
            if out.len() >= 5 {
                out.push("... (further trace diffs elided)".into());
                break;
            }
        }
    }
    out
}

#[test]
fn golden_medium_trace_and_metrics_are_stable() {
    let dir = golden_dir();
    let trace_path = dir.join("medium_trace.bin");
    let results_path = dir.join("medium_results.bin");
    let update = std::env::var_os("GOLDEN_UPDATE").is_some();

    if update || !trace_path.exists() || !results_path.exists() {
        std::fs::create_dir_all(&dir).unwrap();
        let jobs = fresh_trace();
        replay::save(&trace_path, &jobs).unwrap();
        write_results(&results_path, &run_summaries(&jobs));
        eprintln!(
            "golden_snapshot: bootstrapped fixtures under {} — commit them \
             to arm the snapshot",
            dir.display()
        );
        return;
    }

    let golden_jobs = replay::load(&trace_path).unwrap();
    let mut diffs = diff_traces(&golden_jobs, &fresh_trace());
    // Metrics are snapshotted over the *golden* trace so a generator
    // drift (reported above) doesn't cascade into every metric row.
    let golden_summaries = read_results(&results_path);
    for (summary, (golden, system)) in run_summaries(&golden_jobs)
        .iter()
        .zip(golden_summaries.iter().zip(SYSTEMS))
    {
        summary.diff(golden, system, &mut diffs);
    }
    assert!(
        diffs.is_empty(),
        "golden snapshot drift ({} diffs) — if intended, regenerate with \
         GOLDEN_UPDATE=1 and commit:\n{}",
        diffs.len(),
        diffs.join("\n")
    );
}
