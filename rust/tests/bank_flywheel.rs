//! The Prompt-Bank convergence flywheel, end to end on the simulator:
//! completed jobs feed tuned prompts back into the stateful bank
//! (`promptbank::SimBank`), so subsequent lookups of the same task launch
//! from near-ideal prompts. The task-drift scenario is the family that
//! makes this observable — novel tasks arrive mid-run with zero warm
//! coverage, dip to user-prompt quality, and recover as insertions land.
//! Every run executes under the simulation oracle.

use prompttuner::bench::{self, SweepCell, SYSTEMS};
use prompttuner::cluster::{SimConfig, SimOracle, Simulator};
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::promptbank::SimBankConfig;
use prompttuner::scenario::{Scenario, NOVEL_TASK_BASE};
use prompttuner::trace::Load;
use prompttuner::workload::PerfModel;

fn drift_scenario() -> Scenario {
    Scenario::TaskDrift {
        drift_at_frac: 0.4,
        novel_tasks: 8,
        jobs_per_llm: 60,
    }
}

/// The acceptance-criterion assertion: on the task-drift scenario,
/// completed jobs demonstrably raise subsequent lookup quality — the
/// late drifted jobs launch from markedly better prompts than the early
/// drifted jobs, purely through completion feedback (nothing else can
/// cover a task beyond the banks' seeded corpus).
#[test]
fn task_drift_recovery_raises_drifted_job_quality() {
    let sc = drift_scenario();
    let jobs = sc.generate(7, 1.0).unwrap();
    let drifted: Vec<usize> = jobs
        .iter()
        .filter(|j| j.task_id >= NOVEL_TASK_BASE)
        .map(|j| j.id)
        .collect();
    assert!(drifted.len() >= 30, "only {} drifted jobs", drifted.len());
    let sim = Simulator::new(
        SimConfig { max_gpus: 32, ..Default::default() },
        PerfModel::default(),
    );
    let mut policy = SimOracle::collecting(PromptTuner::new(PromptTunerConfig {
        max_gpus: 32,
        seed: 7,
        ..Default::default()
    }));
    let res = sim.run(&mut policy, jobs);
    assert_eq!(res.n_done, res.n_jobs, "drift run left jobs unfinished");
    assert!(policy.violations().is_empty(), "{:?}",
            policy.violations().first());
    // drifted is in arrival order (ids are dense over the sorted trace)
    let third = drifted.len() / 3;
    let mean = |ids: &[usize]| -> f64 {
        ids.iter().map(|&i| res.job_quality[i]).sum::<f64>() / ids.len() as f64
    };
    let early = mean(&drifted[..third]);
    let late = mean(&drifted[drifted.len() - third..]);
    assert!(
        late > early + 0.05,
        "completion feedback did not raise drifted lookup quality: \
         early {early:.3} vs late {late:.3}"
    );
    // pre-drift jobs ran against warm coverage the whole time
    let pre: Vec<usize> = (0..res.job_quality.len())
        .filter(|i| !drifted.contains(i))
        .collect();
    assert!(mean(&pre) > early, "warm coverage should beat the cold dip");
}

/// Warm-vs-cold separation must be visible to every system through the
/// shared Bank interface (the fig14 sweep's gated claim, in-tree).
#[test]
fn warm_bank_beats_cold_bank_for_every_system() {
    for system in SYSTEMS {
        let warm = bench::run_cell(
            &SweepCell::new(format!("w/{system}"), system, Load::Medium, 1.0,
                            32, 5)
                .with_bank(SimBankConfig::default()),
        );
        let cold = bench::run_cell(
            &SweepCell::new(format!("c/{system}"), system, Load::Medium, 1.0,
                            32, 5)
                .with_bank(SimBankConfig::cold()),
        );
        assert_eq!(warm.result.n_done, warm.result.n_jobs, "{system}");
        assert_eq!(cold.result.n_done, cold.result.n_jobs, "{system}");
        assert!(
            warm.result.mean_prompt_quality > cold.result.mean_prompt_quality,
            "{system}: warm {} vs cold {}",
            warm.result.mean_prompt_quality,
            cold.result.mean_prompt_quality
        );
        // Attainment ordering is the CI-gated claim for PromptTuner (the
        // baselines' schedulers add noise of their own on this axis).
        if system == "prompttuner" {
            assert!(
                warm.result.n_violations <= cold.result.n_violations,
                "{system}: warm {} vs cold {} violations",
                warm.result.n_violations,
                cold.result.n_violations
            );
        }
    }
}

/// All three systems survive the drift family under the collecting
/// oracle (the bank feedback path runs inside their completion hooks).
#[test]
fn all_systems_run_task_drift_under_the_oracle() {
    let sc = drift_scenario();
    for system in SYSTEMS {
        let cell = SweepCell::scenario(
            format!("d/{system}"), system, sc.clone(), 1.0, 32, 11);
        let jobs = bench::gen_jobs(&cell);
        let n = jobs.len();
        let sim = Simulator::new(
            SimConfig { max_gpus: 32, ..Default::default() },
            PerfModel::default(),
        );
        let mut policy = SimOracle::collecting(bench::make_policy(&cell));
        let res = sim.run(&mut policy, jobs);
        assert_eq!(res.n_done, n, "{system} left drift jobs unfinished");
        assert!(policy.violations().is_empty(), "{system}: {:?}",
                policy.violations().first());
        assert!(policy.audits() > 0);
    }
}

/// The induction baseline runs through the same Bank interface and loses
/// to the real (warm) bank on realized prompt quality.
#[test]
fn induction_bank_loses_to_two_layer_bank() {
    let real = bench::run_cell(
        &SweepCell::new("r", "prompttuner", Load::Medium, 1.0, 32, 13)
            .with_bank(SimBankConfig::default()),
    );
    let induction = bench::run_cell(
        &SweepCell::new("i", "prompttuner", Load::Medium, 1.0, 32, 13)
            .with_bank(SimBankConfig {
                induction: true,
                ..Default::default()
            }),
    );
    assert_eq!(induction.result.n_done, induction.result.n_jobs);
    assert!(
        real.result.mean_prompt_quality
            > induction.result.mean_prompt_quality,
        "two-layer {} vs induction {}",
        real.result.mean_prompt_quality,
        induction.result.mean_prompt_quality
    );
}
