//! Integration test for the real serving plane: worker threads with
//! real PJRT model loads, warm-vs-cold routing, and job completion.
//! Requires `make artifacts`.

use std::sync::Arc;

use prompttuner::serve::{ServeEngine, ServeJob};
use prompttuner::tuning::TaskUniverse;
use prompttuner::util::manifest::Manifest;

fn artifacts_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn job(id: usize, task: usize, uni: &TaskUniverse) -> ServeJob {
    ServeJob {
        id,
        variant: "sim-gpt2b".into(),
        task_id: task,
        init_tokens: uni.tag(task).to_vec(),
        use_bank: false,
        target_loss: 0.0, // unreachable => run max_iters
        max_iters: 15,
        lr: 0.05,
    }
}

#[test]
fn serve_engine_runs_jobs_and_reuses_runtime() {
    // Needs both `make artifacts` output and the `pjrt` feature (the
    // real PJRT runtime); otherwise skip rather than fail.
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return;
    }
    if !artifacts_dir().join("manifest.txt").exists() {
        eprintln!("skipping: no artifacts (run `make artifacts`)");
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).expect("run `make artifacts`");
    let uni = Arc::new(TaskUniverse::load(manifest.tasks_path_abs()).unwrap());
    let mut engine = ServeEngine::start(artifacts_dir(), 2, uni.clone(), None).unwrap();
    assert_eq!(engine.n_workers(), 2);

    // two jobs back-to-back on the same variant: the second served by a
    // warm worker must skip the model load entirely
    engine.submit(job(0, 1, &uni)).unwrap();
    let first = engine.collect(1).unwrap();
    assert_eq!(first.len(), 1);
    let cold = &first[0];
    assert!(cold.cold_start_s > 0.5,
            "first job should pay a real cold start, got {}", cold.cold_start_s);
    assert_eq!(cold.iters, 15);
    assert!(cold.final_loss.is_finite());

    engine.submit(job(1, 2, &uni)).unwrap();
    let second = engine.collect(1).unwrap();
    let warm = &second[0];
    assert_eq!(warm.worker, cold.worker, "warm routing must reuse the worker");
    assert_eq!(warm.cold_start_s, 0.0, "warm job must not reload the model");
    assert!(warm.tune_s < cold.tune_s + cold.cold_start_s,
            "warm e2e should beat cold e2e");

    // a burst of jobs exercising both workers
    for i in 2..6 {
        engine.submit(job(i, i % 4, &uni)).unwrap();
    }
    let rest = engine.collect_all().unwrap();
    assert_eq!(rest.len(), 4);
    for o in &rest {
        assert_eq!(o.iters, 15);
        assert!(o.final_loss.is_finite());
    }
    engine.shutdown();
}
