//! Property-based tests over the full policy stack: randomized workloads
//! through each scheduler, audited by the simulation oracle
//! ([`SimOracle`]: GPU-capacity conservation, no grants to departed jobs,
//! index agreement, monotone sequence numbers, non-negative incremental
//! cost) plus completion and cost-floor checks. Uses the in-crate mini
//! property harness.

use prompttuner::baselines::{ElasticFlow, ElasticFlowConfig, Infless, InflessConfig};
use prompttuner::bench::{self, SweepCell, SYSTEMS};
use prompttuner::cluster::{ClusterState, KnobSpec, Policy, RetryEvent,
                           RevokeEvent, SimConfig, SimOracle, Simulator,
                           TunerReport, Wake};
use prompttuner::fault::ChaosKind;
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::scenario::Scenario;
use prompttuner::slo::{GovernorConfig, Tuned, TunerConfig};
use prompttuner::trace::{Load, TraceConfig, TraceGenerator};
use prompttuner::util::prop::{check, check_sized, ensure};
use prompttuner::util::rng::Rng;
use prompttuner::workload::{PerfModel, GPU_PRICE_PER_S};

fn random_load(rng: &mut Rng) -> Load {
    [Load::Low, Load::Medium, Load::High][rng.below(3)]
}

fn run_checked(system: usize, rng: &mut Rng) -> Result<(), String> {
    let seed = rng.next_u64();
    let gpus = 8 + 8 * rng.below(4); // 8..32
    let slo = [0.5, 1.0, 1.5][rng.below(3)];
    let perf = PerfModel::default();
    let mut gen = TraceGenerator::new(
        TraceConfig { seed, slo_emergence: slo, ..Default::default() },
        perf.clone(),
    );
    let jobs = gen.generate_main(random_load(rng));
    let n_jobs = jobs.len();
    let sim = Simulator::new(SimConfig { max_gpus: gpus, ..Default::default() }, perf);
    let (res, violations) = match system {
        0 => {
            let mut p = SimOracle::collecting(PromptTuner::new(PromptTunerConfig {
                max_gpus: gpus,
                seed,
                // randomize the ablation switches too
                use_bank: rng.below(2) == 0,
                use_warm_pools: rng.below(2) == 0,
                use_warm_allocator: rng.below(2) == 0,
                use_delay_schedulable: rng.below(2) == 0,
                use_latency_budget: rng.below(2) == 0,
                ..Default::default()
            }));
            let r = sim.run(&mut p, jobs);
            (r, p.violations().to_vec())
        }
        1 => {
            let mut p = SimOracle::collecting(Infless::new(InflessConfig {
                max_gpus: gpus,
                seed,
                ..Default::default()
            }));
            let r = sim.run(&mut p, jobs);
            (r, p.violations().to_vec())
        }
        _ => {
            let mut p = SimOracle::collecting(ElasticFlow::new(ElasticFlowConfig {
                cluster_size: gpus,
                seed,
                ..Default::default()
            }));
            let r = sim.run(&mut p, jobs);
            (r, p.violations().to_vec())
        }
    };
    ensure(violations.is_empty(), format!("{:?}", violations.first()))?;
    ensure(res.n_done == n_jobs,
           format!("only {}/{} jobs finished (gpus={gpus}, slo={slo})",
                   res.n_done, n_jobs))?;
    // cost must be at least the busy GPU time (can't bill less than used)
    ensure(
        res.cost_usd >= res.gpu_seconds_busy * GPU_PRICE_PER_S - 1e-6,
        format!("cost {} below busy-time floor", res.cost_usd),
    )?;
    ensure(res.mean_utilization <= 1.0 + 1e-9, "utilization > 1")?;
    // every job latency positive and init wait non-negative
    for (lat, slo_s, init, bank) in &res.job_latencies {
        ensure(*lat > 0.0, "non-positive latency")?;
        ensure(*slo_s > 0.0, "non-positive slo")?;
        ensure(*init >= 0.0 && *bank >= 0.0, "negative wait")?;
    }
    Ok(())
}

/// Forces the seed's dense 50 ms rounds on any policy by leaving
/// `next_timed_action` at its `Wake::Dense` default — the reference
/// behavior the coalescing-equivalence property compares against.
struct DenseTick(Box<dyn Policy>);

impl Policy for DenseTick {
    fn name(&self) -> &str {
        self.0.name()
    }
    fn tick_interval(&self) -> f64 {
        self.0.tick_interval()
    }
    fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
        self.0.on_arrival(st, id)
    }
    fn on_job_complete(&mut self, st: &mut ClusterState, id: usize) {
        self.0.on_job_complete(st, id)
    }
    fn on_tick(&mut self, st: &mut ClusterState) {
        self.0.on_tick(st)
    }
    fn on_revoke(&mut self, st: &mut ClusterState, ev: &RevokeEvent) {
        self.0.on_revoke(st, ev)
    }
    fn on_retry(&mut self, st: &mut ClusterState, ev: &RetryEvent) {
        self.0.on_retry(st, ev)
    }
    fn capacity(&self) -> Option<usize> {
        self.0.capacity()
    }
    fn set_capacity(&mut self, st: &mut ClusterState, gpus: usize) {
        self.0.set_capacity(st, gpus)
    }
    fn knobs(&self) -> Vec<KnobSpec> {
        self.0.knobs()
    }
    fn knob_value(&self, name: &str) -> Option<f64> {
        self.0.knob_value(name)
    }
    fn set_knob(&mut self, st: &mut ClusterState, name: &str, value: f64) {
        self.0.set_knob(st, name, value)
    }
    fn tuner_report(&self) -> Option<TunerReport> {
        self.0.tuner_report()
    }
    // next_timed_action: default Wake::Dense — never coalesce.
}

/// Tick coalescing must be a pure wall-clock optimization: for every
/// policy — over the paper's Medium/High traces AND the scenario engine's
/// flash-crowd / heavy-tail families (the adversarial cases: correlated
/// queue floods and durations far past the paper's cap) AND the faulted
/// spot-market / az-outage families (involuntary revocations, repairs and
/// stragglers applied through the fault engine's `Wake::At` grid) — the
/// optimized simulator yields the same n_done / n_violations / cost as a
/// dense-tick reference run. The chaos-storm family rides the rotation
/// too: latency tails, retry-with-backoff and correlated rack fan-out
/// all hit the same bit-equality bar. Both runs execute under the
/// simulation oracle.
#[test]
fn prop_tick_coalescing_matches_dense_reference() {
    let mut coalesced_total: u64 = 0;
    check_sized("coalesced run == dense reference (all policies)", 8,
                |rng, case| {
        let seed = rng.next_u64();
        let gpus = 16 + 16 * rng.below(2); // 16 or 32
        let load = [Load::Medium, Load::High][rng.below(2)];
        // rotate the workload family with the case index: 8 cases cover
        // paper/flash-crowd/heavy-tail and the stateful-bank task-drift
        // family, and the case%4==3 slot alternates the two fault
        // families (once each per run)
        let scenario: Option<Scenario> = match case % 4 {
            // the second case%4==0 slot exercises mid-run bank mutation
            // (novel-task insertions at completion events) under the
            // dense-vs-coalesced bit-equality check
            0 if case >= 4 => Some(Scenario::TaskDrift {
                drift_at_frac: 0.4,
                novel_tasks: 8,
                jobs_per_llm: 40,
            }),
            1 => Some(Scenario::FlashCrowd {
                storms: 2,
                intensity: 20.0,
                jobs_per_llm: 40,
            }),
            // the second case%4==2 slot runs the full chaos stack —
            // latency tails, failed completions with backoff holdbacks,
            // and rolling rack storms — through the same bit-equality bar
            2 if case >= 4 => Some(Scenario::Chaos {
                kind: ChaosKind::RackStorm,
                jobs_per_llm: 30,
            }),
            2 => Some(Scenario::HeavyTail { alpha: 1.1, jobs_per_llm: 40 }),
            3 if case < 4 => Some(Scenario::SpotMarket {
                waves: 2,
                reclaim_frac: 0.25,
                jobs_per_llm: 30,
            }),
            3 => Some(Scenario::AzOutage {
                outage_frac: 0.5,
                repair_s: 240.0,
                jobs_per_llm: 30,
            }),
            _ => None,
        };
        let family = scenario.as_ref().map_or("paper", |s| s.name());
        for system in SYSTEMS {
            let cell = match &scenario {
                Some(sc) => SweepCell::scenario(
                    format!("eq/{family}/{system}"), system, sc.clone(), 1.0,
                    gpus, seed),
                None => SweepCell::new(
                    format!("eq/{system}"), system, load, 1.0, gpus, seed),
            };
            let sim = Simulator::new(
                SimConfig { max_gpus: gpus, ..Default::default() },
                PerfModel::default(),
            );
            let mut fast = SimOracle::collecting(bench::make_policy(&cell));
            let fast_res = sim.run(&mut fast, bench::gen_jobs(&cell));
            let mut dense =
                SimOracle::collecting(DenseTick(bench::make_policy(&cell)));
            let dense_res = sim.run(&mut dense, bench::gen_jobs(&cell));

            ensure(dense_res.rounds_coalesced == 0, "reference run coalesced")?;
            let tag = format!(
                "{system} seed={seed} gpus={gpus} workload={family}/{load:?}");
            ensure(
                fast.violations().is_empty(),
                format!("{tag}: oracle (fast): {:?}", fast.violations().first()),
            )?;
            ensure(
                dense.violations().is_empty(),
                format!("{tag}: oracle (dense): {:?}", dense.violations().first()),
            )?;
            ensure(
                fast_res.n_done == dense_res.n_done,
                format!("{tag}: n_done {} vs {}", fast_res.n_done, dense_res.n_done),
            )?;
            ensure(
                fast_res.n_violations == dense_res.n_violations,
                format!("{tag}: violations {} vs {}",
                        fast_res.n_violations, dense_res.n_violations),
            )?;
            ensure(
                (fast_res.cost_usd - dense_res.cost_usd).abs() < 1e-9,
                format!("{tag}: cost {} vs {}",
                        fast_res.cost_usd, dense_res.cost_usd),
            )?;
            ensure(
                (fast_res.mean_utilization - dense_res.mean_utilization).abs()
                    < 1e-9,
                format!("{tag}: util {} vs {}",
                        fast_res.mean_utilization, dense_res.mean_utilization),
            )?;
            ensure(
                (fast_res.gpu_seconds_billed - dense_res.gpu_seconds_billed).abs()
                    < 1e-9,
                format!("{tag}: billed {} vs {}",
                        fast_res.gpu_seconds_billed,
                        dense_res.gpu_seconds_billed),
            )?;
            ensure(
                fast_res.revocations == dense_res.revocations
                    && (fast_res.lost_iters - dense_res.lost_iters).abs() < 1e-9,
                format!("{tag}: faults diverged: {} rev / {} lost vs \
                         {} rev / {} lost",
                        fast_res.revocations, fast_res.lost_iters,
                        dense_res.revocations, dense_res.lost_iters),
            )?;
            ensure(
                fast_res.retries == dense_res.retries
                    && fast_res.retry_iters.to_bits()
                        == dense_res.retry_iters.to_bits()
                    && fast_res.chaos_delay_s.to_bits()
                        == dense_res.chaos_delay_s.to_bits(),
                format!("{tag}: chaos diverged: {} retries / {} iters / \
                         {} delay vs {} / {} / {}",
                        fast_res.retries, fast_res.retry_iters,
                        fast_res.chaos_delay_s, dense_res.retries,
                        dense_res.retry_iters, dense_res.chaos_delay_s),
            )?;
            ensure(
                fast_res.job_latencies.len() == dense_res.job_latencies.len(),
                format!("{tag}: latency count"),
            )?;
            for (a, b) in fast_res.job_latencies.iter()
                .zip(&dense_res.job_latencies)
            {
                ensure((a.0 - b.0).abs() < 1e-9 && (a.2 - b.2).abs() < 1e-9,
                       format!("{tag}: per-job latency {a:?} vs {b:?}"))?;
            }
            // skipped + executed rounds must re-tile the dense tick grid
            ensure(
                fast_res.rounds_executed + fast_res.rounds_coalesced
                    == dense_res.rounds_executed,
                format!("{tag}: rounds {}+{} vs dense {}",
                        fast_res.rounds_executed, fast_res.rounds_coalesced,
                        dense_res.rounds_executed),
            )?;
            coalesced_total += fast_res.rounds_coalesced;
        }
        Ok(())
    });
    // the optimization must actually have engaged somewhere
    assert!(coalesced_total > 0, "no rounds were ever coalesced");
}

/// Efficiency bound for the O(events) batch-skip core: on an idle-heavy
/// diurnal trace (12 h of wall-clock, 30 jobs, deep overnight troughs)
/// every system must *execute* at most a tenth of the 50 ms grid — the
/// rest is batch-skipped. The bound is intentionally generous: each
/// pending job keeps rounds dense for at most ~its SLO slack
/// (duration × emergence + cold start, a few minutes), and every
/// `Wake::At` timer (keep-alives, rescale windows, holdbacks) costs one
/// executed round per expiry — orders of magnitude below the ~900k-round
/// grid. The grid size is recovered from the run itself
/// (`rounds_executed + rounds_coalesced` re-tiles the dense grid exactly;
/// `prop_tick_coalescing_matches_dense_reference` pins that identity), so
/// no dense reference run is needed here.
#[test]
fn prop_batch_skip_is_sublinear_on_idle_heavy_trace() {
    let sc = Scenario::Diurnal { hours: 12.0, jobs_per_llm: 10, peak_to_trough: 6.0 };
    for system in SYSTEMS {
        let gpus = 32;
        let cell = SweepCell::scenario(
            format!("eff/diurnal/{system}"), system, sc.clone(), 1.0, gpus, 7,
        );
        let sim = Simulator::new(
            SimConfig { max_gpus: gpus, ..Default::default() },
            PerfModel::default(),
        );
        let mut p = SimOracle::collecting(bench::make_policy(&cell));
        let res = sim.run(&mut p, bench::gen_jobs(&cell));
        assert!(p.violations().is_empty(), "{system}: {:?}",
                p.violations().first());
        let grid = res.rounds_executed + res.rounds_coalesced;
        // 12 h on a 50 ms grid is ~900k rounds; sanity-check the trace
        // is actually long enough to make the bound meaningful
        assert!(grid > 500_000, "{system}: grid only {grid} rounds");
        assert!(
            res.rounds_executed * 10 <= grid,
            "{system}: executed {} of {} grid rounds — batch skip is not \
             sublinear on an idle-heavy trace",
            res.rounds_executed, grid,
        );
    }
}

/// With exploration off, `Tuned<P>` must be a bit-exact pass-through
/// for every system: it never calls `set_knob`, its evaluation grid is
/// never declared, and the monitor only observes. Same argument as the
/// neutral-governor property — any extra executed rounds would be
/// no-ops the inner policy declared skippable, and here not even those
/// exist.
#[test]
fn prop_tuned_exploration_off_is_a_bit_exact_pass_through() {
    let sc = Scenario::FlashCrowd { storms: 2, intensity: 10.0,
                                    jobs_per_llm: 20 };
    let seed = 47;
    let gpus = 32;
    for system in SYSTEMS {
        let cell = SweepCell::scenario(
            format!("pt-eq/{system}"), system, sc.clone(), 1.0, gpus, seed);
        let mk_sim = || Simulator::new(
            SimConfig { max_gpus: gpus, ..Default::default() },
            PerfModel::default(),
        );
        let bare = mk_sim().run(
            bench::make_policy(&cell).as_mut(), bench::gen_jobs(&cell));
        let mut wrapped = Tuned::new(
            bench::make_policy(&cell),
            TunerConfig { explore: false, ..Default::default() },
        );
        let tuned = mk_sim().run(&mut wrapped, bench::gen_jobs(&cell));
        assert_eq!(bare.n_done, tuned.n_done, "{system}");
        assert_eq!(bare.n_violations, tuned.n_violations, "{system}");
        assert_eq!(bare.cost_usd.to_bits(), tuned.cost_usd.to_bits(),
                   "{system}: cost {} vs {}", bare.cost_usd, tuned.cost_usd);
        assert_eq!(bare.job_latencies, tuned.job_latencies, "{system}");
        assert_eq!(bare.util_timeline, tuned.util_timeline, "{system}");
        assert!(wrapped.log().decisions.is_empty(),
                "{system}: pass-through decided something");
    }
}

/// Tuned runs must stay bit-identical dense-vs-coalesced: every knob
/// move happens at a `Wake::At` evaluation boundary on an absolute time
/// grid, so batch-skipping rounds can never change what the tuner sees
/// or does. Both runs execute under the strict in-loop oracle (which
/// also re-audits cluster invariants after every `set_knob`).
#[test]
fn prop_tuned_runs_are_coalescing_invariant() {
    let scenarios = [
        Scenario::FlashCrowd { storms: 2, intensity: 20.0,
                               jobs_per_llm: 30 },
        Scenario::TaskDrift { drift_at_frac: 0.4, novel_tasks: 8,
                              jobs_per_llm: 30 },
    ];
    let gpus = 32;
    for sc in &scenarios {
        for system in SYSTEMS {
            let cell = SweepCell::scenario(
                format!("tuned-eq/{}/{system}", sc.name()),
                system, sc.clone(), 1.0, gpus, 47,
            ).tuned();
            // Same surge-widened provider budget run_cell gives tuned
            // cells, so up-lattice capacity arms are realizable.
            let budget = GovernorConfig::for_cluster(gpus).ceiling_gpus;
            let sim = Simulator::new(
                SimConfig { max_gpus: budget, ..Default::default() },
                PerfModel::default(),
            );
            let mut fast = SimOracle::collecting(bench::make_policy(&cell));
            let fast_res = sim.run(&mut fast, bench::gen_jobs(&cell));
            let mut dense =
                SimOracle::collecting(DenseTick(bench::make_policy(&cell)));
            let dense_res = sim.run(&mut dense, bench::gen_jobs(&cell));
            let tag = format!("{}/{system}", sc.name());
            assert!(dense_res.rounds_coalesced == 0,
                    "{tag}: reference run coalesced");
            assert!(fast.violations().is_empty(),
                    "{tag}: oracle (fast): {:?}", fast.violations().first());
            assert!(dense.violations().is_empty(),
                    "{tag}: oracle (dense): {:?}",
                    dense.violations().first());
            assert_eq!(fast_res.n_done, dense_res.n_done, "{tag}");
            assert_eq!(fast_res.n_violations, dense_res.n_violations,
                       "{tag}");
            assert_eq!(fast_res.cost_usd.to_bits(),
                       dense_res.cost_usd.to_bits(),
                       "{tag}: cost {} vs {}",
                       fast_res.cost_usd, dense_res.cost_usd);
            assert_eq!(fast_res.job_latencies, dense_res.job_latencies,
                       "{tag}");
            // The tuner raced identically in both runs.
            let (fr, dr) = (fast.tuner_report(), dense.tuner_report());
            let fr = fr.expect("tuned cell must report");
            let dr = dr.expect("dense tuned cell must report");
            assert_eq!(fr.decisions, dr.decisions, "{tag}");
            assert_eq!(fr.promotions, dr.promotions, "{tag}");
            assert_eq!(fr.reverts, dr.reverts, "{tag}");
            assert!(fr.decisions > 0, "{tag}: the tuner never acted");
            // and the executed/skipped rounds re-tile the dense grid
            assert_eq!(
                fast_res.rounds_executed + fast_res.rounds_coalesced,
                dense_res.rounds_executed,
                "{tag}: rounds do not re-tile the dense grid",
            );
        }
    }
}

#[test]
fn prop_prompttuner_invariants_hold() {
    check("prompttuner invariants over random workloads", 12, |rng| {
        run_checked(0, rng)
    });
}

#[test]
fn prop_infless_invariants_hold() {
    check("infless invariants over random workloads", 12, |rng| {
        run_checked(1, rng)
    });
}

#[test]
fn prop_elasticflow_invariants_hold() {
    check("elasticflow invariants over random workloads", 12, |rng| {
        run_checked(2, rng)
    });
}
