//! Property-based tests over the full policy stack: randomized workloads
//! through each scheduler with per-tick invariant checks (GPU
//! conservation, billable within provider budget, completion, cost
//! accounting sanity). Uses the in-crate mini property harness.

use prompttuner::baselines::{ElasticFlow, ElasticFlowConfig, Infless, InflessConfig};
use prompttuner::cluster::{ClusterState, Policy, SimConfig, Simulator};
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::trace::{Load, TraceConfig, TraceGenerator};
use prompttuner::util::prop::{check, ensure};
use prompttuner::util::rng::Rng;
use prompttuner::workload::{PerfModel, GPU_PRICE_PER_S};

/// Wraps a policy and asserts cluster-wide invariants on every callback.
struct Checked<P: Policy> {
    inner: P,
    max_gpus: f64,
    violations: Vec<String>,
}

impl<P: Policy> Checked<P> {
    fn new(inner: P, max_gpus: usize) -> Self {
        Checked { inner, max_gpus: max_gpus as f64, violations: vec![] }
    }

    fn audit(&mut self, st: &ClusterState, whence: &str) {
        if st.busy() < -1e-9 {
            self.violations.push(format!("{whence}: negative busy {}", st.busy()));
        }
        if st.billable() > self.max_gpus + 1e-9 {
            self.violations.push(format!(
                "{whence}: billable {} exceeds provider budget {}",
                st.billable(),
                self.max_gpus
            ));
        }
    }
}

impl<P: Policy> Policy for Checked<P> {
    fn name(&self) -> &str {
        self.inner.name()
    }
    fn tick_interval(&self) -> f64 {
        self.inner.tick_interval()
    }
    fn on_arrival(&mut self, st: &mut ClusterState, id: usize) {
        self.inner.on_arrival(st, id);
        self.audit(st, "arrival");
    }
    fn on_job_complete(&mut self, st: &mut ClusterState, id: usize) {
        self.inner.on_job_complete(st, id);
        self.audit(st, "complete");
    }
    fn on_tick(&mut self, st: &mut ClusterState) {
        self.inner.on_tick(st);
        self.audit(st, "tick");
    }
}

fn random_load(rng: &mut Rng) -> Load {
    [Load::Low, Load::Medium, Load::High][rng.below(3)]
}

fn run_checked(system: usize, rng: &mut Rng) -> Result<(), String> {
    let seed = rng.next_u64();
    let gpus = 8 + 8 * rng.below(4); // 8..32
    let slo = [0.5, 1.0, 1.5][rng.below(3)];
    let perf = PerfModel::default();
    let mut gen = TraceGenerator::new(
        TraceConfig { seed, slo_emergence: slo, ..Default::default() },
        perf.clone(),
    );
    let jobs = gen.generate_main(random_load(rng));
    let n_jobs = jobs.len();
    let sim = Simulator::new(SimConfig { max_gpus: gpus, ..Default::default() }, perf);
    let (res, violations) = match system {
        0 => {
            let mut p = Checked::new(
                PromptTuner::new(PromptTunerConfig {
                    max_gpus: gpus,
                    seed,
                    // randomize the ablation switches too
                    use_bank: rng.below(2) == 0,
                    use_warm_pools: rng.below(2) == 0,
                    use_warm_allocator: rng.below(2) == 0,
                    use_delay_schedulable: rng.below(2) == 0,
                    use_latency_budget: rng.below(2) == 0,
                    ..Default::default()
                }),
                gpus,
            );
            let r = sim.run(&mut p, jobs);
            (r, p.violations)
        }
        1 => {
            let mut p = Checked::new(
                Infless::new(InflessConfig { max_gpus: gpus, seed, ..Default::default() }),
                gpus,
            );
            let r = sim.run(&mut p, jobs);
            (r, p.violations)
        }
        _ => {
            let mut p = Checked::new(
                ElasticFlow::new(ElasticFlowConfig {
                    cluster_size: gpus,
                    seed,
                    ..Default::default()
                }),
                gpus,
            );
            let r = sim.run(&mut p, jobs);
            (r, p.violations)
        }
    };
    ensure(violations.is_empty(), format!("{:?}", violations.first()))?;
    ensure(res.n_done == n_jobs,
           format!("only {}/{} jobs finished (gpus={gpus}, slo={slo})",
                   res.n_done, n_jobs))?;
    // cost must be at least the busy GPU time (can't bill less than used)
    ensure(
        res.cost_usd >= res.gpu_seconds_busy * GPU_PRICE_PER_S - 1e-6,
        format!("cost {} below busy-time floor", res.cost_usd),
    )?;
    ensure(res.mean_utilization <= 1.0 + 1e-9, "utilization > 1")?;
    // every job latency positive and init wait non-negative
    for (lat, slo_s, init, bank) in &res.job_latencies {
        ensure(*lat > 0.0, "non-positive latency")?;
        ensure(*slo_s > 0.0, "non-positive slo")?;
        ensure(*init >= 0.0 && *bank >= 0.0, "negative wait")?;
    }
    Ok(())
}

#[test]
fn prop_prompttuner_invariants_hold() {
    check("prompttuner invariants over random workloads", 12, |rng| {
        run_checked(0, rng)
    });
}

#[test]
fn prop_infless_invariants_hold() {
    check("infless invariants over random workloads", 12, |rng| {
        run_checked(1, rng)
    });
}

#[test]
fn prop_elasticflow_invariants_hold() {
    check("elasticflow invariants over random workloads", 12, |rng| {
        run_checked(2, rng)
    });
}
