//! Fig 3 — inefficiencies of existing DL systems on LPT workloads:
//! (a) ElasticFlow cluster utilization over time (paper: ~56 % average),
//! (b) CDF of the waiting-delay fraction caused by instance
//!     initialization in INFless (paper: avg 11 %, up to 50 %),
//! (c) SLO violation vs maximum GPU count for both baselines
//!     (paper: up to 70 %).
//!
//! Uses the first-20-minutes Vicuna-7B slice of the trace, as §3 does.

#[path = "common.rs"]
mod common;

use common::*;

use prompttuner::trace::{Load, TraceConfig, TraceGenerator};
use prompttuner::util::stats::{cdf_points, mean};
use prompttuner::workload::{Llm, PerfModel};

/// §3 workload: only the V7B share of the medium trace.
fn v7b_trace(seed: u64, slo: f64) -> Vec<prompttuner::workload::JobSpec> {
    let perf = PerfModel::default();
    let mut gen = TraceGenerator::new(
        TraceConfig { seed, slo_emergence: slo, ..Default::default() },
        perf,
    );
    let mut jobs = gen.generate_for(Llm::V7B, 65);
    for (i, j) in jobs.iter_mut().enumerate() {
        j.id = i;
    }
    jobs
}

fn main() {
    banner("Fig 3a — ElasticFlow cluster utilization over time (32 GPUs)");
    let res = run_sim("elasticflow", gen_trace(Load::Medium, 1.0, 42), 32, 42);
    let utils: Vec<f64> = res.util_timeline.iter().map(|(_, u)| *u).collect();
    println!("{:<10} {:>12}", "minute", "utilization");
    for chunk in res.util_timeline.chunks(6) {
        let t = chunk[0].0 / 60.0;
        let u = mean(&chunk.iter().map(|(_, u)| *u).collect::<Vec<_>>());
        println!("{:<10.1} {:>11.1}%", t, u * 100.0);
    }
    println!("average utilization: {:.1}% (paper: ~56%)",
             mean(&utils) * 100.0);

    banner("Fig 3b — INFless: init-wait fraction of end-to-end latency (CDF)");
    let res = run_sim("infless", v7b_trace(42, 1.0), 32, 42);
    let fracs: Vec<f64> = res
        .job_latencies
        .iter()
        .filter(|(lat, ..)| *lat > 0.0 && lat.is_finite())
        .map(|(lat, _, init, _)| init / lat)
        .collect();
    println!("{:<14} {:>8}", "init fraction", "CDF");
    for (x, q) in cdf_points(&fracs, 10) {
        println!("{:<14.3} {:>8.2}", x, q);
    }
    println!("mean init fraction: {:.1}% (paper: ~11%), max: {:.1}% (paper: ~50%)",
             mean(&fracs) * 100.0,
             fracs.iter().cloned().fold(0.0f64, f64::max) * 100.0);

    banner("Fig 3c — SLO violation (%) vs maximum GPUs (S = 0.5, V7B slice)");
    println!("{:<10} {:>12} {:>14}", "max GPUs", "INFless", "ElasticFlow");
    for gpus in [8usize, 16, 24, 32] {
        let iv = run_sim("infless", v7b_trace(42, 0.5), gpus, 42).violation_rate();
        let ev = run_sim("elasticflow", v7b_trace(42, 0.5), gpus, 42).violation_rate();
        println!("{:<10} {:>11.1}% {:>13.1}%", gpus, iv * 100.0, ev * 100.0);
    }
    println!("(paper: violations reach ~70% at constrained GPU counts)");
}
