//! Fig 7 — end-to-end performance: SLO violation and cost of PromptTuner
//! vs INFless vs ElasticFlow under (a, b) varying job loads and (c, d)
//! varying SLO emergence S, on 32 GPUs serving all three main LLMs.
//!
//! Paper reference: PromptTuner achieves 15–25 % lower violation than
//! INFless, 48–51 % lower than ElasticFlow; cost savings of 17–38 % vs
//! INFless and up to 70 % vs ElasticFlow at S = 1.5.
//!
//! All (system × load × S × seed) cells run in parallel through the
//! sweep harness; a BENCH_fig7.json perf record is emitted.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::trace::Load;

fn main() {
    let seeds = [42u64, 43, 44];
    let loads = [("low", Load::Low), ("medium", Load::Medium), ("high", Load::High)];
    let slos = [0.5, 1.0, 1.5];

    // ---- build the full grid up front, run it once in parallel --------
    let mut cells = vec![];
    for (name, load) in loads {
        for system in SYSTEMS {
            for &seed in &seeds {
                cells.push(SweepCell::new(
                    format!("fig7ab/{name}"), system, load, 1.0, 32, seed));
            }
        }
    }
    for &slo in &slos {
        for system in SYSTEMS {
            for &seed in &seeds {
                cells.push(SweepCell::new(
                    format!("fig7cd/S{slo}"), system, Load::Medium, slo, 32, seed));
            }
        }
    }
    let t0 = Instant::now();
    let results = run_sweep(&cells);
    let total_wall = t0.elapsed().as_secs_f64();

    let select = |label: &str, system: &str| -> Vec<&CellResult> {
        results
            .iter()
            .filter(|r| r.cell.label == label && r.cell.system == system)
            .collect()
    };

    banner("Fig 7a/7b — SLO violation (%) and cost ($) vs load (S = 1.0)");
    println!("{:<14} {:>12} {:>12} {:>12}", "load", "prompttuner", "infless",
             "elasticflow");
    for (name, _) in loads {
        let v: Vec<(f64, f64)> = SYSTEMS
            .iter()
            .map(|s| avg_of(&select(&format!("fig7ab/{name}"), s)))
            .collect();
        println!("{:<14} {:>11.1}% {:>11.1}% {:>11.1}%", format!("viol {name}"),
                 v[0].0, v[1].0, v[2].0);
        println!("{:<14} {:>11.2}$ {:>11.2}$ {:>11.2}$", format!("cost {name}"),
                 v[0].1, v[1].1, v[2].1);
    }

    banner("Fig 7c/7d — SLO violation (%) and cost ($) vs SLO emergence (medium load)");
    println!("{:<14} {:>12} {:>12} {:>12}", "S", "prompttuner", "infless",
             "elasticflow");
    let mut improvements = vec![];
    for &slo in &slos {
        let v: Vec<(f64, f64)> = SYSTEMS
            .iter()
            .map(|s| avg_of(&select(&format!("fig7cd/S{slo}"), s)))
            .collect();
        println!("{:<14} {:>11.1}% {:>11.1}% {:>11.1}%", format!("viol S={slo}"),
                 v[0].0, v[1].0, v[2].0);
        println!("{:<14} {:>11.2}$ {:>11.2}$ {:>11.2}$", format!("cost S={slo}"),
                 v[0].1, v[1].1, v[2].1);
        improvements.push((
            slo,
            v[1].0 / v[0].0.max(1e-9),
            v[2].0 / v[0].0.max(1e-9),
            v[1].1 / v[0].1.max(1e-9),
            v[2].1 / v[0].1.max(1e-9),
        ));
    }

    banner("Headline factors (paper: up to 4.0x / 7.9x violation, 1.6x / 4.5x cost)");
    println!("{:<8} {:>16} {:>20} {:>14} {:>18}", "S", "viol vs INFless",
             "viol vs ElasticFlow", "cost vs INFless", "cost vs ElasticFlow");
    for (slo, vi, ve, ci, ce) in improvements {
        println!("{:<8} {:>15.2}x {:>19.2}x {:>13.2}x {:>17.2}x",
                 slo, vi, ve, ci, ce);
    }

    let report = BenchReport::new("fig7", results, total_wall);
    match report.write_default() {
        Ok(path) => println!("\n[{} cells in {total_wall:.2}s wall] perf record: {}",
                             report.cells.len(), path.display()),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }
}
