//! §Perf — wall-clock performance of the L3 hot paths (the paper's §6.2
//! reports 13/67 ms avg/max scheduling overhead; ours must be far below
//! since the simulator executes thousands of rounds):
//! * one Algorithm-1 + Algorithm-2 scheduling round at 96 GPUs with large
//!   pending queues,
//! * K-medoid bank construction and two-layer lookup data-path costs,
//! * PJRT runtime micro-benchmarks (tune_step / score / features) when
//!   artifacts are available.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::coordinator::{allocate_from_cold_pool, allocate_from_warm_pool};
use prompttuner::promptbank::{PromptCandidate, TwoLayerBank};
use prompttuner::trace::Load;
use prompttuner::util::rng::Rng;

fn main() {
    banner("scheduling-round cost (pure algorithm, 1000-job queue)");
    // synthetic worst-ish case: 1000 pending jobs, 96 free GPUs
    let n = 1000usize;
    let mut rng = Rng::new(1);
    let work: Vec<f64> = (0..n).map(|_| rng.range_f64(1.0, 400.0)).collect();
    let slo: Vec<f64> = (0..n).map(|_| rng.range_f64(10.0, 400.0)).collect();
    let mut pending: Vec<usize> = (0..n).collect();
    pending.sort_by(|&a, &b| slo[a].partial_cmp(&slo[b]).unwrap());
    let iters = 200;
    let t0 = Instant::now();
    for _ in 0..iters {
        let w = &work;
        let s = &slo;
        let (grants, _) = allocate_from_warm_pool(
            &pending, 96, 1, 8, |j| s[j], |j, g| w[j] / g as f64);
        std::hint::black_box(grants);
    }
    println!("Algorithm 1 (warm), 1000 jobs: {:.3} ms/round",
             t0.elapsed().as_secs_f64() * 1e3 / iters as f64);
    let t0 = Instant::now();
    for _ in 0..iters {
        let w = &work;
        let s = &slo;
        let mut e_l: Vec<f64> = (0..96).map(|i| i as f64).collect();
        let exec = |j: usize, g: usize| w[j] / g as f64;
        let plans = allocate_from_cold_pool(
            &pending, 96, 1, 8, 0.0, |j| s[j], &exec, 30.0, &mut e_l, true);
        std::hint::black_box(plans);
    }
    println!("Algorithm 2 (cold + DelaySchedulable), 1000 jobs: {:.3} ms/round",
             t0.elapsed().as_secs_f64() * 1e3 / iters as f64);

    banner("end-to-end simulated 96-GPU run (3x medium): per-tick overhead");
    // The acceptance-tracked hot-path benchmark: one cell per system,
    // recorded to BENCH_sim.json (wall-clock per cell, executed/coalesced
    // rounds, rounds/s). Cells run SERIALLY on purpose: per-cell wall_s
    // is the CI regression baseline and sched_overhead_ms is compared
    // against the paper's 13/67 ms, so neither may pick up cross-cell
    // cache/CPU contention noise (the figure/table benches, whose cells
    // are only aggregated, use the parallel run_sweep instead).
    let cells: Vec<SweepCell> = SYSTEMS
        .iter()
        .map(|s| {
            let mut c = SweepCell::new(
                format!("perf/96gpu-medium-x3/{s}"), *s, Load::Medium, 1.0, 96, 11);
            c.scale = 3.0;
            c
        })
        .collect();
    let t0 = Instant::now();
    let results: Vec<_> = cells.iter().map(run_cell).collect();
    let total_wall = t0.elapsed().as_secs_f64();
    for r in &results {
        println!(
            "{:<14} tick avg/max {:.3}/{:.2} ms (paper: 13/67 ms)  \
             [{} jobs in {:.2}s wall; {} rounds run, {} coalesced, {:.0} rounds/s]",
            r.cell.system, r.result.sched_overhead_ms_mean,
            r.result.sched_overhead_ms_max, r.result.n_jobs, r.wall_s,
            r.result.rounds_executed, r.result.rounds_coalesced,
            r.result.ticks_per_s()
        );
    }
    let report = BenchReport::new("sim", results, total_wall);
    match report.write_default() {
        Ok(path) => println!("[suite in {total_wall:.2}s wall] perf record: {}",
                             path.display()),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }

    banner("Prompt Bank data-path (synthetic features, C = 3000, K = 50)");
    let mut rng = Rng::new(2);
    let cands: Vec<PromptCandidate> = (0..3000)
        .map(|i| {
            let c = i % 12;
            PromptCandidate {
                tokens: vec![i as i32; 16],
                feature: (0..64)
                    .map(|j| ((c * 97 + j) % 13) as f32 + 0.1 * rng.normal() as f32)
                    .collect(),
                source_task: Some(c),
            }
        })
        .collect();
    let t0 = Instant::now();
    let bank = TwoLayerBank::build(cands, 50, 3000, &mut rng).unwrap();
    println!("K-medoid construction (C=3000, K=50): {:.2} s (paper: ~5 min \
              offline incl. feature extraction)", t0.elapsed().as_secs_f64());
    let t0 = Instant::now();
    let reps = 100;
    for i in 0..reps {
        let mut scorer = |t: &[i32]| (t[0] as f32 * 31.0 + i as f32) % 7.0;
        std::hint::black_box(bank.lookup(&mut scorer));
    }
    println!("two-layer lookup data path (excl. score evals): {:.3} ms",
             t0.elapsed().as_secs_f64() * 1e3 / reps as f64);

    if have_artifacts() {
        banner("PJRT runtime micro-benchmarks (sim-gpt2b)");
        use prompttuner::runtime::{ModelRuntime, TuneState};
        use prompttuner::tuning::TaskUniverse;
        use prompttuner::util::manifest::Manifest;
        let manifest = Manifest::load(artifacts_dir()).unwrap();
        let uni = TaskUniverse::load(manifest.tasks_path_abs()).unwrap();
        let t0 = Instant::now();
        let rt = ModelRuntime::load(&manifest, "sim-gpt2b").unwrap();
        println!("model load (cold start): {:.2} s", t0.elapsed().as_secs_f64());
        let mut r = Rng::new(3);
        let (toks, tgts) = uni.sample_batch(&mut r, 0, rt.info.batch_train, rt.info.seq);
        let (etoks, etgts) = uni.sample_batch(&mut r, 0, rt.info.batch_eval, rt.info.seq);
        let mut st = TuneState::new(rt.embed_prompt(uni.tag(0)).unwrap());
        rt.tune_step(&mut st, &toks, &tgts, 0.05).unwrap();
        let t0 = Instant::now();
        for _ in 0..50 {
            rt.tune_step(&mut st, &toks, &tgts, 0.05).unwrap();
        }
        let step_ms = t0.elapsed().as_secs_f64() * 1e3 / 50.0;
        let tok_s = (rt.info.batch_train * rt.info.seq) as f64 / (step_ms / 1e3);
        println!("tune_step: {:.2} ms ({:.0} tokens/s)", step_ms, tok_s);
        let t0 = Instant::now();
        for _ in 0..50 {
            rt.score(uni.tag(0), &etoks, &etgts).unwrap();
        }
        println!("score (Eqn.1): {:.2} ms", t0.elapsed().as_secs_f64() * 1e3 / 50.0);
        let t0 = Instant::now();
        for _ in 0..50 {
            rt.features(uni.tag(0)).unwrap();
        }
        println!("features: {:.2} ms", t0.elapsed().as_secs_f64() * 1e3 / 50.0);
    }
}
