//! Table 8 — impact of the Workload Scheduler's components at S = 1.0,
//! medium load: full system vs w/o warm (simultaneous multi-GPU)
//! allocator vs w/o DelaySchedulable vs w/o the Prompt-Bank latency
//! budget.
//!
//! Paper reference: 12.4 % / 27.8 % / 15.6 % / 16.3 % violation and
//! $22.9 / $20.9 / $26.6 / $23.2 cost.

#[path = "common.rs"]
mod common;

use common::*;
use prompttuner::cluster::{SimConfig, Simulator};
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::trace::Load;
use prompttuner::workload::PerfModel;

fn main() {
    banner("Table 8 — Workload Scheduler component ablations (S = 1.0, medium)");
    let seeds = [42u64, 43, 44, 45];
    let configs: [(&str, PromptTunerConfig); 4] = [
        ("Workload Scheduler", PromptTunerConfig::default()),
        ("w/o Warm Allocator", PromptTunerConfig {
            use_warm_allocator: false,
            ..Default::default()
        }),
        ("w/o DelaySchedulable", PromptTunerConfig {
            use_delay_schedulable: false,
            ..Default::default()
        }),
        ("w/o Latency Budget", PromptTunerConfig {
            use_latency_budget: false,
            ..Default::default()
        }),
    ];
    println!("{:<22} {:>16} {:>10}", "config", "SLO violation", "cost");
    for (label, cfg) in configs {
        let mut viol = 0.0;
        let mut cost = 0.0;
        for &seed in &seeds {
            let jobs = gen_trace(Load::Medium, 1.0, seed);
            let sim = Simulator::new(
                SimConfig { max_gpus: 32, ..Default::default() },
                PerfModel::default(),
            );
            let mut p = PromptTuner::new(PromptTunerConfig { seed, ..cfg.clone() });
            let r = sim.run(&mut p, jobs);
            viol += r.violation_rate();
            cost += r.cost_usd;
        }
        println!("{:<22} {:>15.1}% {:>9.2}$",
                 label,
                 100.0 * viol / seeds.len() as f64,
                 cost / seeds.len() as f64);
    }
    println!("(paper: 12.4/27.8/15.6/16.3 % and 22.9/20.9/26.6/23.2 $)");
}
