//! Table 8 — impact of the Workload Scheduler's components at S = 1.0,
//! medium load: full system vs w/o warm (simultaneous multi-GPU)
//! allocator vs w/o DelaySchedulable vs w/o the Prompt-Bank latency
//! budget.
//!
//! Paper reference: 12.4 % / 27.8 % / 15.6 % / 16.3 % violation and
//! $22.9 / $20.9 / $26.6 / $23.2 cost.
//!
//! All (config × seed) cells run in parallel through the sweep harness;
//! a BENCH_table8.json perf record is emitted.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::coordinator::PromptTunerConfig;
use prompttuner::trace::Load;

fn main() {
    banner("Table 8 — Workload Scheduler component ablations (S = 1.0, medium)");
    let seeds = [42u64, 43, 44, 45];
    let configs: [(&str, PromptTunerConfig); 4] = [
        ("Workload Scheduler", PromptTunerConfig::default()),
        ("w/o Warm Allocator", PromptTunerConfig {
            use_warm_allocator: false,
            ..Default::default()
        }),
        ("w/o DelaySchedulable", PromptTunerConfig {
            use_delay_schedulable: false,
            ..Default::default()
        }),
        ("w/o Latency Budget", PromptTunerConfig {
            use_latency_budget: false,
            ..Default::default()
        }),
    ];

    let mut cells = vec![];
    for (label, cfg) in &configs {
        for &seed in &seeds {
            let mut c = SweepCell::new(
                format!("table8/{label}"), "prompttuner", Load::Medium, 1.0, 32, seed);
            c.cfg = Some(cfg.clone());
            cells.push(c);
        }
    }
    let t0 = Instant::now();
    let results = run_sweep(&cells);
    let total_wall = t0.elapsed().as_secs_f64();

    println!("{:<22} {:>16} {:>10}", "config", "SLO violation", "cost");
    for (label, _) in &configs {
        let sel: Vec<&CellResult> = results
            .iter()
            .filter(|r| r.cell.label == format!("table8/{label}"))
            .collect();
        let (v, c) = avg_of(&sel);
        println!("{:<22} {:>15.1}% {:>9.2}$", label, v, c);
    }
    println!("(paper: 12.4/27.8/15.6/16.3 % and 22.9/20.9/26.6/23.2 $)");

    let report = BenchReport::new("table8", results, total_wall);
    match report.write_default() {
        Ok(path) => println!("\n[{} cells in {total_wall:.2}s wall] perf record: {}",
                             report.cells.len(), path.display()),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }
}
