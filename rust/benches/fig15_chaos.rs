//! Fig 15 (beyond the paper) — the chaos & latency-realism sweep: SLO
//! violation and cost of all three systems under continuous misbehavior,
//! on the paper's 32-GPU cluster.
//!
//! Three chaos families from the scenario engine
//! (`fault::ChaosProfile` presets):
//! * **chaos-latency** — heavy launch/bank latency tails, no failures:
//!   30 % of launches stretch up to 4×, 30 % of bank lookups up to 3×;
//! * **chaos-flaky** — mild tails plus failed completions: 12 % of
//!   finishing runs are rejected and re-enter the queue with half their
//!   work redone, a 2-retry budget and 15 s ×2 exponential backoff;
//! * **chaos-storm** — flaky completions while three rolling hard
//!   failures each fan out to a whole rack of the 4-domain topology.
//!
//! Every cell runs through `fault::FaultInjector` with a
//! `fault::ChaosEngine` (the bench harness wraps automatically for chaos
//! scenarios). Emits a BENCH_chaos.json perf record; tools/check_bench.py
//! validates family × system coverage, that the profiles actually fired
//! (retries under flaky/storm, revocations under storm), that every
//! retried job still completed, and that attainment stays above the
//! per-profile floors. Run with PT_SIM_ORACLE=1 (CI does) to audit every
//! round — including the chaos invariants (retry conservation, backoff
//! monotonicity, no billable capacity inside a dead domain) — under the
//! strict in-loop oracle.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::fault::ChaosKind;
use prompttuner::metrics::{render_table, Row};
use prompttuner::scenario::Scenario;

fn main() {
    let seed = 41u64;
    let gpus = 32;

    let scenarios: Vec<Scenario> = ChaosKind::ALL
        .into_iter()
        .map(|kind| Scenario::Chaos { kind, jobs_per_llm: 60 })
        .collect();

    let mut cells = vec![];
    for sc in &scenarios {
        for system in SYSTEMS {
            cells.push(SweepCell::scenario(
                format!("fig15/{}", sc.name()), system, sc.clone(), 1.0,
                gpus, seed));
        }
    }
    let t0 = Instant::now();
    let results = run_sweep(&cells);
    let total_wall = t0.elapsed().as_secs_f64();

    for sc in &scenarios {
        let label = format!("fig15/{}", sc.name());
        let rows: Vec<Row> = results
            .iter()
            .filter(|r| r.cell.label == label)
            .map(|r| Row::from(&r.result))
            .collect();
        let jobs = results
            .iter()
            .find(|r| r.cell.label == label)
            .map_or(0, |r| r.result.n_jobs);
        print!("\n{}", render_table(
            &format!("Fig 15 — {} ({jobs} jobs, {gpus} GPUs, S = 1.0)",
                     sc.name()),
            &rows));
        for r in results.iter().filter(|r| r.cell.label == label) {
            println!(
                "  {:<14} {} retries, {:.1} retry iters, \
                 {:.1}s chaos delay, {} revocations",
                r.cell.system,
                r.result.retries,
                r.result.retry_iters,
                r.result.chaos_delay_s,
                r.result.revocations,
            );
        }
    }

    let report = BenchReport::new("chaos", results, total_wall);
    match report.write_default() {
        Ok(path) => println!(
            "\n[{} cells in {total_wall:.2}s wall] perf record: {}",
            report.cells.len(),
            path.display()
        ),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }
}
