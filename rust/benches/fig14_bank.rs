//! Fig 14 (beyond the paper) — the Prompt-Bank state sweep: SLO
//! violation, cost and realized prompt quality of all three systems with
//! {cold, warm, drifting} banks on the paper's 32-GPU cluster.
//!
//! The stateful `promptbank::SimBank` makes bank quality *emerge* from
//! bank state instead of a fixed statistical draw, so these regimes are
//! now distinguishable:
//! * **cold** — empty banks at t = 0: early jobs launch from user
//!   prompts; completions feed tuned prompts back and the bank warms
//!   over the run (the convergence flywheel);
//! * **warm** — the default seeded corpus (3000 candidates per LLM);
//! * **drifting** — warm banks, but the `task-drift` scenario switches
//!   the arrival stream to never-seen tasks mid-run: coverage dips cold
//!   for them and recovers through feedback.
//!
//! Emits a BENCH_bank.json perf record; tools/check_bench.py validates
//! state × system coverage and that warm-bank PromptTuner beats
//! cold-bank on attainment and quality. Run with PT_SIM_ORACLE=1 (CI
//! does) to audit every round under the strict in-loop oracle.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::metrics::{render_table, Row};
use prompttuner::promptbank::SimBankConfig;
use prompttuner::scenario::Scenario;
use prompttuner::trace::Load;

fn main() {
    let seed = 42u64;
    let gpus = 32;
    let cold = SimBankConfig::cold();
    let warm = SimBankConfig::default();
    let drift = Scenario::TaskDrift {
        drift_at_frac: 0.4,
        novel_tasks: 8,
        jobs_per_llm: 60,
    };

    let mut cells = vec![];
    for system in SYSTEMS {
        cells.push(
            SweepCell::new("fig14/cold", system, Load::Medium, 1.0, gpus, seed)
                .with_bank(cold.clone()),
        );
        cells.push(
            SweepCell::new("fig14/warm", system, Load::Medium, 1.0, gpus, seed)
                .with_bank(warm.clone()),
        );
        cells.push(
            SweepCell::scenario("fig14/drifting", system, drift.clone(), 1.0,
                                gpus, seed)
                .with_bank(warm.clone()),
        );
    }
    let t0 = Instant::now();
    let results = run_sweep(&cells);
    let total_wall = t0.elapsed().as_secs_f64();

    for state in ["cold", "warm", "drifting"] {
        let label = format!("fig14/{state}");
        let rows: Vec<Row> = results
            .iter()
            .filter(|r| r.cell.label == label)
            .map(|r| Row::from(&r.result))
            .collect();
        let jobs = results
            .iter()
            .find(|r| r.cell.label == label)
            .map_or(0, |r| r.result.n_jobs);
        print!("\n{}", render_table(
            &format!("Fig 14 — {state} bank ({jobs} jobs, {gpus} GPUs, \
                      S = 1.0)"),
            &rows));
        for r in results.iter().filter(|r| r.cell.label == label) {
            println!("  {:<14} mean prompt quality {:.3}",
                     r.cell.system, r.result.mean_prompt_quality);
        }
    }

    let report = BenchReport::new("bank", results, total_wall);
    match report.write_default() {
        Ok(path) => println!(
            "\n[{} cells in {total_wall:.2}s wall] perf record: {}",
            report.cells.len(),
            path.display()
        ),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }
}
