//! Fig 12 (beyond the paper) — the SLO control plane sweep: violation and
//! cost of {governed, ungoverned} × {PromptTuner, INFless, ElasticFlow}
//! on the multi-tenant and flash-crowd scenarios.
//!
//! "Governed" wraps the policy in `slo::Governed`: rolling SLI windows,
//! error-budget burn rates over fast/slow windows, provable-miss
//! admission deferral, and a billable-capacity governor with 25 % surge
//! headroom over the 32-GPU baseline (the simulator budget is widened to
//! the surge ceiling for governed cells, so surge capacity is billed when
//! — and only when — the governor claims it).
//!
//! Emits a BENCH_slo.json perf record; tools/check_bench.py validates the
//! full governed/ungoverned × system × scenario coverage and that the
//! governed PromptTuner flash-crowd run improves on at least one axis.
//! Run with PT_SIM_ORACLE=1 (CI does) to audit every governed round under
//! the strict in-loop oracle.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::cluster::{SimConfig, Simulator};
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::metrics::{render_attainment, render_table, Row};
use prompttuner::scenario::Scenario;
use prompttuner::slo::{Governed, GovernorConfig, SloConfig, SloMonitor};
use prompttuner::workload::PerfModel;

fn main() {
    let seed = 29u64;
    let gpus = 32;

    let scenarios = [
        Scenario::MultiTenant { tenants: 4, jobs_per_tenant: 45 },
        Scenario::FlashCrowd { storms: 3, intensity: 25.0, jobs_per_llm: 70 },
    ];

    let mut cells = vec![];
    for sc in &scenarios {
        for system in SYSTEMS {
            for governed in [false, true] {
                let mode = if governed { "governed" } else { "ungoverned" };
                let mut cell = SweepCell::scenario(
                    format!("fig12/{}/{mode}", sc.name()),
                    system,
                    sc.clone(),
                    1.0,
                    gpus,
                    seed,
                );
                if governed {
                    cell = cell.governed();
                }
                cells.push(cell);
            }
        }
    }

    let t0 = Instant::now();
    let results = run_sweep(&cells);
    let total_wall = t0.elapsed().as_secs_f64();

    for sc in &scenarios {
        for mode in ["ungoverned", "governed"] {
            let label = format!("fig12/{}/{mode}", sc.name());
            let rows: Vec<Row> = results
                .iter()
                .filter(|r| r.cell.label == label)
                .map(|r| Row::from(&r.result))
                .collect();
            print!(
                "\n{}",
                render_table(
                    &format!("Fig 12 — {} / {mode} ({gpus}-GPU baseline, \
                              S = 1.0)", sc.name()),
                    &rows
                )
            );
        }
    }

    // Per-class attainment table: one governed PromptTuner flash-crowd
    // run with the SLO monitor attached to the simulator event stream.
    let gcfg = GovernorConfig::for_cluster(gpus);
    let jobs = scenarios[1].generate(seed, 1.0).expect("flash-crowd trace");
    let sim = Simulator::new(
        SimConfig { max_gpus: gcfg.ceiling_gpus, ..Default::default() },
        PerfModel::default(),
    );
    let mut policy = Governed::new(
        PromptTuner::new(PromptTunerConfig {
            max_gpus: gpus,
            seed,
            ..Default::default()
        }),
        gcfg,
    );
    let mut monitor = SloMonitor::new(SloConfig::default());
    let _ = sim.run_observed(&mut policy, jobs, &mut monitor);
    print!(
        "\n{}",
        render_attainment(
            "Fig 12 — per-class SLO attainment (flash-crowd, governed \
             prompttuner)",
            &monitor.attainment_table()
        )
    );
    println!(
        "governor: {} deferred, {} scale-ups, {} scale-downs, peak queue {}",
        policy.deferred_total(),
        policy.scale_ups(),
        policy.scale_downs(),
        monitor.peak_queue_depth
    );

    let report = BenchReport::new("slo", results, total_wall);
    match report.write_default() {
        Ok(path) => println!(
            "\n[{} cells in {total_wall:.2}s wall] perf record: {}",
            report.cells.len(),
            path.display()
        ),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }
}
