//! Fig 11 (beyond the paper) — the scenario-engine sweep: SLO violation
//! and cost of all three systems across the named workload families
//! (diurnal / flash-crowd / heavy-tail / multi-tenant / replay), on the
//! paper's 32-GPU cluster.
//!
//! The paper evaluates one production trace shape at three load levels;
//! related SLO-serving work (SCOOT, EconoServe) shows scheduler rankings
//! flip under bursty and heavy-tailed traffic, so this bench tracks the
//! comparison under every family the scenario engine generates. The
//! replay family round-trips a Medium paper trace through the binary
//! serializer first, proving the file path end to end.
//!
//! Emits a BENCH_scenarios.json perf record (validated in CI by
//! tools/check_bench.py, which also requires all families present).

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::metrics::{render_table, Row};
use prompttuner::scenario::{replay, Scenario};
use prompttuner::trace::{Load, TraceConfig, TraceGenerator};
use prompttuner::workload::PerfModel;

fn main() {
    let seed = 17u64;
    let gpus = 32;

    // ---- replay fixture: serialize a Medium paper trace, then replay it
    let replay_path = std::env::temp_dir().join("pt_fig11_replay.trace.bin");
    {
        let mut gen = TraceGenerator::new(
            TraceConfig { seed, ..Default::default() },
            PerfModel::default(),
        );
        let jobs = gen.generate_main(Load::Medium);
        replay::save(&replay_path, &jobs).expect("writing replay fixture");
    }

    let mut scenarios = Scenario::catalogue();
    scenarios.push(Scenario::Replay { path: replay_path.clone() });

    let mut cells = vec![];
    for sc in &scenarios {
        for system in SYSTEMS {
            cells.push(SweepCell::scenario(
                format!("fig11/{}", sc.name()), system, sc.clone(), 1.0,
                gpus, seed));
        }
    }
    let t0 = Instant::now();
    let results = run_sweep(&cells);
    let total_wall = t0.elapsed().as_secs_f64();

    for sc in &scenarios {
        let label = format!("fig11/{}", sc.name());
        let rows: Vec<Row> = results
            .iter()
            .filter(|r| r.cell.label == label)
            .map(|r| Row::from(&r.result))
            .collect();
        let jobs = results
            .iter()
            .find(|r| r.cell.label == label)
            .map_or(0, |r| r.result.n_jobs);
        print!("\n{}", render_table(
            &format!("Fig 11 — {} ({jobs} jobs, {gpus} GPUs, S = 1.0)",
                     sc.name()),
            &rows));
    }

    let report = BenchReport::new("scenarios", results, total_wall);
    match report.write_default() {
        Ok(path) => println!("\n[{} cells in {total_wall:.2}s wall] perf record: {}",
                             report.cells.len(), path.display()),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }
    let _ = std::fs::remove_file(&replay_path);
}
