//! Table 7 — heavy-workload evaluation: LLaMA-30B and Qwen7B-R1 (4-GPU
//! tensor-parallel replicas, 32 GPUs) and the 96-GPU large-scale run
//! (3× medium load).
//!
//! Paper reference: PromptTuner cuts violations 1.36–2.90× (LLaMA-30B),
//! 1.56–3.24× (Qwen7B-R1) and dominates the 96-GPU run (25.4 % vs
//! 57.1 % / 78.2 %), with sub-70 ms scheduling overhead.

#[path = "common.rs"]
mod common;

use common::*;
use prompttuner::cluster::{SimConfig, Simulator};
use prompttuner::trace::{Load, TraceConfig, TraceGenerator};
use prompttuner::workload::{Llm, PerfModel};

fn main() {
    let perf = PerfModel::default();
    banner("Table 7 — heavy workload evaluation");
    println!("{:<14} {:<22} {:>12} {:>12} {:>12}", "setting", "metric",
             "prompttuner", "infless", "elasticflow");

    for (label, llm) in [("LLaMA-30B", Llm::Llama30B), ("Qwen7B-R1", Llm::Qwen7BR1)] {
        let mut viol = vec![];
        let mut cost = vec![];
        for system in SYSTEMS {
            let mut v = 0.0;
            let mut c = 0.0;
            let seeds = [7u64, 8, 9];
            for &seed in &seeds {
                let mut gen = TraceGenerator::new(
                    TraceConfig { seed, ..Default::default() },
                    perf.clone(),
                );
                let jobs = gen.generate_heavy(llm);
                let r = run_sim(system, jobs, 32, seed);
                v += r.violation_rate();
                c += r.cost_usd;
            }
            viol.push(100.0 * v / 3.0);
            cost.push(c / 3.0);
        }
        println!("{:<14} {:<22} {:>11.1}% {:>11.1}% {:>11.1}%",
                 label, "SLO violation (%)", viol[0], viol[1], viol[2]);
        println!("{:<14} {:<22} {:>11.2}$ {:>11.2}$ {:>11.2}$",
                 "", "cost ($)", cost[0], cost[1], cost[2]);
    }

    // ---- large-scale: 96 GPUs, 3x medium load ----
    let mut viol = vec![];
    let mut cost = vec![];
    let mut overhead = vec![];
    for system in SYSTEMS {
        let mut v = 0.0;
        let mut c = 0.0;
        let mut o: f64 = 0.0;
        let seeds = [11u64, 12, 13];
        for &seed in &seeds {
            let mut gen = TraceGenerator::new(
                TraceConfig { seed, ..Default::default() },
                perf.clone(),
            );
            let jobs = gen.generate_scaled(Load::Medium, 3.0);
            let sim = Simulator::new(
                SimConfig { max_gpus: 96, ..Default::default() },
                perf.clone(),
            );
            let mut p = make_policy(system, 96, seed);
            let r = sim.run(p.as_mut(), jobs);
            v += r.violation_rate();
            c += r.cost_usd;
            o = o.max(r.sched_overhead_ms_max);
        }
        viol.push(100.0 * v / 3.0);
        cost.push(c / 3.0);
        overhead.push(o);
    }
    println!("{:<14} {:<22} {:>11.1}% {:>11.1}% {:>11.1}%",
             "Large-Scale", "SLO violation (%)", viol[0], viol[1], viol[2]);
    println!("{:<14} {:<22} {:>11.2}$ {:>11.2}$ {:>11.2}$",
             "(96 GPUs)", "cost ($)", cost[0], cost[1], cost[2]);
    println!("\nscheduler overhead, max over runs (paper: avg/max 13/67 ms):");
    for (s, o) in SYSTEMS.iter().zip(&overhead) {
        println!("  {s:<14} {o:.2} ms");
    }
}
