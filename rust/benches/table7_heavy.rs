//! Table 7 — heavy-workload evaluation: LLaMA-30B and Qwen7B-R1 (4-GPU
//! tensor-parallel replicas, 32 GPUs) and the 96-GPU large-scale run
//! (3× medium load).
//!
//! Paper reference: PromptTuner cuts violations 1.36–2.90× (LLaMA-30B),
//! 1.56–3.24× (Qwen7B-R1) and dominates the 96-GPU run (25.4 % vs
//! 57.1 % / 78.2 %), with sub-70 ms scheduling overhead.
//!
//! All (setting × system × seed) cells run in parallel through the
//! sweep harness; a BENCH_table7.json perf record is emitted.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::trace::Load;
use prompttuner::workload::Llm;

fn main() {
    banner("Table 7 — heavy workload evaluation");
    println!("{:<14} {:<22} {:>12} {:>12} {:>12}", "setting", "metric",
             "prompttuner", "infless", "elasticflow");

    let mut cells = vec![];
    for (label, llm) in [("LLaMA-30B", Llm::Llama30B), ("Qwen7B-R1", Llm::Qwen7BR1)] {
        for system in SYSTEMS {
            for seed in [7u64, 8, 9] {
                let mut c = SweepCell::new(
                    format!("table7/{label}"), system, Load::Medium, 1.0, 32, seed);
                c.heavy = Some(llm);
                cells.push(c);
            }
        }
    }
    // large-scale: 96 GPUs, 3x medium load
    for system in SYSTEMS {
        for seed in [11u64, 12, 13] {
            let mut c = SweepCell::new(
                "table7/large-scale", system, Load::Medium, 1.0, 96, seed);
            c.scale = 3.0;
            cells.push(c);
        }
    }
    let t0 = Instant::now();
    let results = run_sweep(&cells);
    let total_wall = t0.elapsed().as_secs_f64();

    let select = |label: &str, system: &str| -> Vec<&CellResult> {
        results
            .iter()
            .filter(|r| r.cell.label == label && r.cell.system == system)
            .collect()
    };

    for label in ["LLaMA-30B", "Qwen7B-R1"] {
        let per: Vec<(f64, f64)> = SYSTEMS
            .iter()
            .map(|s| avg_of(&select(&format!("table7/{label}"), s)))
            .collect();
        println!("{:<14} {:<22} {:>11.1}% {:>11.1}% {:>11.1}%",
                 label, "SLO violation (%)", per[0].0, per[1].0, per[2].0);
        println!("{:<14} {:<22} {:>11.2}$ {:>11.2}$ {:>11.2}$",
                 "", "cost ($)", per[0].1, per[1].1, per[2].1);
    }

    let large: Vec<(f64, f64)> = SYSTEMS
        .iter()
        .map(|s| avg_of(&select("table7/large-scale", s)))
        .collect();
    println!("{:<14} {:<22} {:>11.1}% {:>11.1}% {:>11.1}%",
             "Large-Scale", "SLO violation (%)", large[0].0, large[1].0, large[2].0);
    println!("{:<14} {:<22} {:>11.2}$ {:>11.2}$ {:>11.2}$",
             "(96 GPUs)", "cost ($)", large[0].1, large[1].1, large[2].1);
    println!("\nscheduler overhead, max over runs (paper: avg/max 13/67 ms):");
    for system in SYSTEMS {
        let o = select("table7/large-scale", system)
            .iter()
            .map(|r| r.result.sched_overhead_ms_max)
            .fold(0.0f64, f64::max);
        println!("  {system:<14} {o:.2} ms");
    }

    let report = BenchReport::new("table7", results, total_wall);
    match report.write_default() {
        Ok(path) => println!("\n[{} cells in {total_wall:.2}s wall] perf record: {}",
                             report.cells.len(), path.display()),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }
}
