//! Fig 8 — feature evaluations:
//! (a, b) impact of prompt reusing (P.R.) and runtime reusing (R.R.) on
//!        SLO violation and cost across SLO levels (paper: P.R. cuts
//!        violations 13–23 % and cost 30–40 %);
//! (c)    cold-allocator window-size sweep (paper: 60 s is the sweet spot);
//! (d)    Prompt-Bank size sweep (paper: below ~2000 candidates both
//!        violations and cost rise).
//!
//! All ablation cells run in parallel through the sweep harness; a
//! BENCH_fig8.json perf record is emitted.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::coordinator::PromptTunerConfig;
use prompttuner::promptbank::SimBankConfig;
use prompttuner::trace::Load;

fn ablation_cell(label: String, cfg: PromptTunerConfig, slo: f64,
                 seed: u64) -> SweepCell {
    let mut c = SweepCell::new(label, "prompttuner", Load::Medium, slo, 32, seed);
    c.cfg = Some(cfg);
    c
}

fn main() {
    let seeds = [42u64, 43, 44];

    let configs: [(&str, PromptTunerConfig); 4] = [
        ("full (P.R.+R.R.)", PromptTunerConfig::default()),
        ("w/o P.R.", PromptTunerConfig { use_bank: false, ..Default::default() }),
        ("w/o R.R.", PromptTunerConfig { use_warm_pools: false, ..Default::default() }),
        ("w/o both", PromptTunerConfig {
            use_bank: false,
            use_warm_pools: false,
            ..Default::default()
        }),
    ];
    let windows = [15.0f64, 30.0, 60.0, 120.0, 300.0];
    let sizes = [500usize, 1000, 2000, 3000];

    // ---- build the whole grid, run it once in parallel ----------------
    let mut cells = vec![];
    for (label, cfg) in &configs {
        for slo in [0.5, 1.0, 1.5] {
            for &seed in &seeds {
                cells.push(ablation_cell(
                    format!("fig8ab/{label}/S{slo}"), cfg.clone(), slo, seed));
            }
        }
    }
    for &window in &windows {
        for &seed in &seeds {
            cells.push(ablation_cell(
                format!("fig8c/w{window}"),
                PromptTunerConfig { window_s: window, ..Default::default() },
                1.0,
                seed,
            ));
        }
    }
    for &size in &sizes {
        for &seed in &seeds {
            // A size-capped stateful bank: fewer seeded candidates cover
            // fewer tasks, and the ceiling caps feedback growth (Fig 8d).
            let bank = SimBankConfig {
                initial_size: size,
                max_size: size,
                ..Default::default()
            };
            cells.push(ablation_cell(
                format!("fig8d/c{size}"),
                PromptTunerConfig { bank, ..Default::default() },
                1.0,
                seed,
            ));
        }
    }
    let t0 = Instant::now();
    let results = run_sweep(&cells);
    let total_wall = t0.elapsed().as_secs_f64();

    let avg = |label: String| -> (f64, f64) {
        let sel: Vec<&CellResult> =
            results.iter().filter(|r| r.cell.label == label).collect();
        avg_of(&sel)
    };

    banner("Fig 8a/8b — prompt reusing (P.R.) & runtime reusing (R.R.) ablation");
    println!("{:<22} {:>10} {:>10} {:>10}  |  {:>9} {:>9} {:>9}",
             "config", "S=0.5", "S=1.0", "S=1.5", "S=0.5$", "S=1.0$", "S=1.5$");
    for (label, _) in &configs {
        let mut viols = vec![];
        let mut costs = vec![];
        for slo in [0.5, 1.0, 1.5] {
            let (v, c) = avg(format!("fig8ab/{label}/S{slo}"));
            viols.push(v);
            costs.push(c);
        }
        println!("{:<22} {:>9.1}% {:>9.1}% {:>9.1}%  |  {:>8.2} {:>8.2} {:>8.2}",
                 label, viols[0], viols[1], viols[2],
                 costs[0], costs[1], costs[2]);
    }

    banner("Fig 8c — warm-pool idle-window size sweep (S = 1.0, medium)");
    println!("{:<12} {:>14} {:>10}", "window (s)", "violation", "cost");
    for &window in &windows {
        let (v, c) = avg(format!("fig8c/w{window}"));
        println!("{:<12} {:>13.1}% {:>9.2}$", window, v, c);
    }
    println!("(paper: 60 s balances violation against cost)");

    banner("Fig 8d — Prompt Bank size sweep (S = 1.0, medium)");
    println!("{:<12} {:>14} {:>10}", "bank size", "violation", "cost");
    for &size in &sizes {
        let (v, c) = avg(format!("fig8d/c{size}"));
        println!("{:<12} {:>13.1}% {:>9.2}$", size, v, c);
    }
    println!("(paper: shrinking below ~2000 raises both metrics)");

    let report = BenchReport::new("fig8", results, total_wall);
    match report.write_default() {
        Ok(path) => println!("\n[{} cells in {total_wall:.2}s wall] perf record: {}",
                             report.cells.len(), path.display()),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }
}
