//! Fig 8 — feature evaluations:
//! (a, b) impact of prompt reusing (P.R.) and runtime reusing (R.R.) on
//!        SLO violation and cost across SLO levels (paper: P.R. cuts
//!        violations 13–23 % and cost 30–40 %);
//! (c)    cold-allocator window-size sweep (paper: 60 s is the sweet spot);
//! (d)    Prompt-Bank size sweep (paper: below ~2000 candidates both
//!        violations and cost rise).

#[path = "common.rs"]
mod common;

use common::*;
use prompttuner::cluster::{SimConfig, Simulator};
use prompttuner::coordinator::{PromptTuner, PromptTunerConfig};
use prompttuner::promptbank::BankModel;
use prompttuner::trace::Load;
use prompttuner::workload::PerfModel;

fn run_cfg(cfg: PromptTunerConfig, slo: f64, seeds: &[u64]) -> (f64, f64) {
    let mut viol = 0.0;
    let mut cost = 0.0;
    for &s in seeds {
        let jobs = gen_trace(Load::Medium, slo, s);
        let sim = Simulator::new(
            SimConfig { max_gpus: 32, ..Default::default() },
            PerfModel::default(),
        );
        let mut p = PromptTuner::new(PromptTunerConfig { seed: s, ..cfg.clone() });
        let r = sim.run(&mut p, jobs);
        viol += r.violation_rate();
        cost += r.cost_usd;
    }
    (100.0 * viol / seeds.len() as f64, cost / seeds.len() as f64)
}

fn main() {
    let seeds = [42u64, 43, 44];

    banner("Fig 8a/8b — prompt reusing (P.R.) & runtime reusing (R.R.) ablation");
    println!("{:<22} {:>10} {:>10} {:>10}  |  {:>9} {:>9} {:>9}",
             "config", "S=0.5", "S=1.0", "S=1.5", "S=0.5$", "S=1.0$", "S=1.5$");
    let configs: [(&str, PromptTunerConfig); 4] = [
        ("full (P.R.+R.R.)", PromptTunerConfig::default()),
        ("w/o P.R.", PromptTunerConfig { use_bank: false, ..Default::default() }),
        ("w/o R.R.", PromptTunerConfig { use_warm_pools: false, ..Default::default() }),
        ("w/o both", PromptTunerConfig {
            use_bank: false,
            use_warm_pools: false,
            ..Default::default()
        }),
    ];
    for (label, cfg) in configs {
        let mut viols = vec![];
        let mut costs = vec![];
        for slo in [0.5, 1.0, 1.5] {
            let (v, c) = run_cfg(cfg.clone(), slo, &seeds);
            viols.push(v);
            costs.push(c);
        }
        println!("{:<22} {:>9.1}% {:>9.1}% {:>9.1}%  |  {:>8.2} {:>8.2} {:>8.2}",
                 label, viols[0], viols[1], viols[2],
                 costs[0], costs[1], costs[2]);
    }

    banner("Fig 8c — warm-pool idle-window size sweep (S = 1.0, medium)");
    println!("{:<12} {:>14} {:>10}", "window (s)", "violation", "cost");
    for window in [15.0f64, 30.0, 60.0, 120.0, 300.0] {
        let (v, c) = run_cfg(
            PromptTunerConfig { window_s: window, ..Default::default() },
            1.0,
            &seeds,
        );
        println!("{:<12} {:>13.1}% {:>9.2}$", window, v, c);
    }
    println!("(paper: 60 s balances violation against cost)");

    banner("Fig 8d — Prompt Bank size sweep (S = 1.0, medium)");
    println!("{:<12} {:>14} {:>10}", "bank size", "violation", "cost");
    for size in [500usize, 1000, 2000, 3000] {
        let bank = BankModel { bank_size: size, ..Default::default() };
        let (v, c) = run_cfg(
            PromptTunerConfig { bank, ..Default::default() },
            1.0,
            &seeds,
        );
        println!("{:<12} {:>13.1}% {:>9.2}$", size, v, c);
    }
    println!("(paper: shrinking below ~2000 raises both metrics)");
}
