//! Fig 13 (beyond the paper) — the fault & preemption sweep: SLO
//! violation and cost of all three systems under involuntary churn, on
//! the paper's 32-GPU cluster.
//!
//! Two fault families from the scenario engine:
//! * **spot-market** — three seeded reclaim waves, each taking a quarter
//!   of the fleet with a 30 s notice (victims checkpoint gracefully) and
//!   returning ~3 min later;
//! * **az-outage** — one correlated mass failure of half the fleet
//!   mid-window (work since the last checkpoint lost), repaired after
//!   5 min, with straggler slowdowns in the recovery wake.
//!
//! Every cell runs through `fault::FaultInjector` with the default
//! checkpoint/restore cost model (the bench harness wraps automatically
//! for fault scenarios), so preempted jobs restore from checkpoints
//! instead of silently restarting. Emits a BENCH_faults.json perf record;
//! tools/check_bench.py validates family × system coverage, that the
//! plans actually fired, and that every preempted job still completed.
//! Run with PT_SIM_ORACLE=1 (CI does) to audit every round — including
//! the fault invariants (revoked GPUs never re-granted before repair,
//! lost-work accounting conserved) — under the strict in-loop oracle.

#[path = "common.rs"]
mod common;

use std::time::Instant;

use common::*;
use prompttuner::metrics::{render_table, Row};
use prompttuner::scenario::Scenario;

fn main() {
    let seed = 37u64;
    let gpus = 32;

    let scenarios = [
        Scenario::SpotMarket { waves: 3, reclaim_frac: 0.25, jobs_per_llm: 60 },
        Scenario::AzOutage { outage_frac: 0.5, repair_s: 300.0,
                             jobs_per_llm: 60 },
    ];

    let mut cells = vec![];
    for sc in &scenarios {
        for system in SYSTEMS {
            cells.push(SweepCell::scenario(
                format!("fig13/{}", sc.name()), system, sc.clone(), 1.0,
                gpus, seed));
        }
    }
    let t0 = Instant::now();
    let results = run_sweep(&cells);
    let total_wall = t0.elapsed().as_secs_f64();

    for sc in &scenarios {
        let label = format!("fig13/{}", sc.name());
        let rows: Vec<Row> = results
            .iter()
            .filter(|r| r.cell.label == label)
            .map(|r| Row::from(&r.result))
            .collect();
        let jobs = results
            .iter()
            .find(|r| r.cell.label == label)
            .map_or(0, |r| r.result.n_jobs);
        print!("\n{}", render_table(
            &format!("Fig 13 — {} ({jobs} jobs, {gpus} GPUs, S = 1.0)",
                     sc.name()),
            &rows));
        for r in results.iter().filter(|r| r.cell.label == label) {
            println!(
                "  {:<14} {} revocations, {:.1} iters lost, \
                 {:.1} straggler iters",
                r.cell.system,
                r.result.revocations,
                r.result.lost_iters,
                r.result.straggler_iters,
            );
        }
    }

    let report = BenchReport::new("faults", results, total_wall);
    match report.write_default() {
        Ok(path) => println!(
            "\n[{} cells in {total_wall:.2}s wall] perf record: {}",
            report.cells.len(),
            path.display()
        ),
        Err(e) => eprintln!("warning: could not write perf record: {e}"),
    }
}
