//! Fig 9 — quality of the score metric (Eqn. 1), on the real runtime:
//! (a) relative ITA of the *score candidate* vs the *ideal candidate*
//!     (paper: most score candidates reach ≥ 90 % of ideal performance),
//! (b) relative ITA speedup of the score candidate over the *induction
//!     candidate* (paper: ≥1.81× / 1.38× / 1.28× for GPT2-B/L/V7B, with
//!     the weakest base model benefiting most).
//!
//! Ideal: shortlist the best few candidates by score, tune each, keep the
//! best ITA (the paper's computationally-infeasible oracle, shrunk).
//! Induction: the LLM writing its own prompt — simulated as a capability-
//! dependent pick (see DESIGN.md §Substitutions).

#[path = "common.rs"]
mod common;

use common::*;
use prompttuner::promptbank::{PromptCandidate, TwoLayerBank};
use prompttuner::runtime::{ModelRuntime, RuntimeScorer};
use prompttuner::tuning::{TaskUniverse, Trainer, TrainerConfig};
use prompttuner::util::manifest::Manifest;
use prompttuner::util::rng::Rng;
use prompttuner::util::stats::{mean, median};

fn build_bank(rt: &ModelRuntime, uni: &TaskUniverse, size: usize,
              rng: &mut Rng) -> TwoLayerBank {
    let mut cands = vec![];
    for i in 0..size {
        let t = i % uni.n_tasks;
        let tokens = if i < uni.n_tasks {
            uni.tag(t).to_vec()
        } else {
            uni.noisy_tag(rng, t, 0.3)
        };
        let feature = rt.features(&tokens).unwrap();
        cands.push(PromptCandidate { tokens, feature, source_task: Some(t) });
    }
    TwoLayerBank::build(cands, 12, 3000, rng).unwrap()
}

/// Induction baseline: the base model generating its own initial prompt.
/// Simulated capability-dependent: with probability = capability the pick
/// lands in the right archetype (a noisy same-archetype tag), otherwise
/// it is an unrelated noisy tag. Capabilities follow the model ladder.
fn induction_pick(uni: &TaskUniverse, task: usize, capability: f64,
                  rng: &mut Rng) -> Vec<i32> {
    if rng.f64() < capability {
        let arch = uni.arch_id[task];
        let same: Vec<usize> = (0..uni.n_tasks)
            .filter(|&t| uni.arch_id[t] == arch)
            .collect();
        let pick = same[rng.below(same.len())];
        uni.noisy_tag(rng, pick, 0.35)
    } else {
        let t = rng.below(uni.n_tasks);
        uni.noisy_tag(rng, t, 0.5)
    }
}

fn main() {
    if !have_artifacts() {
        println!("skipped: run `make artifacts` first");
        return;
    }
    let manifest = Manifest::load(artifacts_dir()).unwrap();
    let uni = TaskUniverse::load(manifest.tasks_path_abs()).unwrap();
    let variants: [(&str, f64); 3] = [
        ("sim-gpt2b", 0.30),
        ("sim-gpt2l", 0.45),
        ("sim-v7b", 0.62),
    ];
    banner("Fig 9 — score vs ideal vs induction (relative ITA, real runtime)");
    let n_tasks = 6usize;
    for (variant, capability) in variants {
        let rt = ModelRuntime::load(&manifest, variant).unwrap();
        let mut rng = Rng::new(3);
        let bank = build_bank(&rt, &uni, 160, &mut rng);
        let trainer = Trainer::new(
            &rt,
            &uni,
            TrainerConfig { lr: 0.05, max_iters: 220, eval_every: 1, seed: 4 },
        );
        let mut rel_ideal = vec![];
        let mut speedup_induction = vec![];
        for task in (0..uni.n_tasks).step_by(uni.n_tasks / n_tasks) {
            let target = trainer
                .reference_target(task, uni.tag(task), 80, 0.05)
                .unwrap();
            let (etoks, etgts) = trainer.eval_batch(task);
            // --- score candidate: two-layer lookup ---
            let mut scorer = RuntimeScorer::new(&rt, etoks.clone(), etgts.clone());
            let pick = bank.lookup(&mut scorer);
            let ita_of = |tokens: &[i32]| -> f64 {
                let out = trainer.tune(task, tokens, target).unwrap();
                if out.reached_target { out.iters.max(1) as f64 } else { 220.0 }
            };
            let score_ita = ita_of(&bank.candidate(pick.best).tokens.clone());
            // --- ideal candidate: tune the top-3 by score, keep the best --
            let mut brute = RuntimeScorer::new(&rt, etoks, etgts);
            let mut scored: Vec<(f32, usize)> = (0..bank.len())
                .map(|i| {
                    use prompttuner::promptbank::Scorer;
                    (brute.score(&bank.candidate(i).tokens), i)
                })
                .collect();
            scored.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let ideal_ita = scored
                .iter()
                .take(4)
                .map(|&(_, i)| ita_of(&bank.candidate(i).tokens.clone()))
                .fold(f64::MAX, f64::min);
            // --- induction candidate ---
            let ind = induction_pick(&uni, task, capability, &mut rng);
            let ind_ita = ita_of(&ind);
            rel_ideal.push(ideal_ita / score_ita);
            speedup_induction.push(ind_ita / score_ita);
        }
        println!(
            "{variant:<10} rel. ITA vs ideal: median {:.2} mean {:.2} \
             (paper: >=0.9 for most)   |   speedup vs induction: median \
             {:.2}x mean {:.2}x",
            median(&rel_ideal),
            mean(&rel_ideal),
            median(&speedup_induction),
            mean(&speedup_induction)
        );
        print!("           per-task speedup vs induction:");
        for s in &speedup_induction {
            print!(" {s:.2}x");
        }
        println!();
    }
    println!("(paper Fig 9b: GPT2-B benefits most — its own generated \
              prompts are weakest)");
}
